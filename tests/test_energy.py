"""Energy model + monitor + accounting invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partition import HaloPlan
from repro.energy.accounting import (
    CostModel,
    OpCounts,
    cg_iteration_counts,
    spmv_counts,
)
from repro.energy.model import PowerModel
from repro.energy.monitor import PowerMonitor


def test_power_model_calibration_points():
    m = PowerModel()
    # roofline-saturating matmul draws peak
    assert m.chip_power(m.chip.peak_flops_bf16, m.chip.hbm_bw, 0) == m.chip.p_peak_w
    # HBM stream draws idle + 65% envelope
    assert np.isclose(
        m.chip_power(0, m.chip.hbm_bw, 0),
        m.chip.p_idle_w + 0.65 * (m.chip.p_peak_w - m.chip.p_idle_w),
    )
    # idle
    assert m.chip_power(0, 0, 0) == m.chip.p_idle_w
    # clamped
    assert m.chip_power(1e18, 1e14, 1e13) == m.chip.p_peak_w


@settings(max_examples=30, deadline=None)
@given(
    flops=st.floats(0, 1e15),
    hbm=st.floats(0, 1e12),
    ici=st.floats(0, 1e11),
)
def test_power_is_monotone_and_bounded(flops, hbm, ici):
    m = PowerModel()
    p = m.chip_power(flops, hbm, ici)
    assert m.chip.p_idle_w <= p <= m.chip.p_peak_w
    assert p >= m.chip_power(flops * 0.5, hbm * 0.5, ici * 0.5) - 1e-9


def test_monitor_energy_identities():
    mon = PowerMonitor(n_devices=4)
    mon.idle(0.1)
    c = OpCounts(flops=1e9, hbm_bytes=4e9, ici_bytes=1e7, n_collectives=2)
    mon.region("work", c, n_shards=4, repeats=10)
    mon.idle(0.1)
    e = mon.energy()
    # TE = SE + DE (per component)
    assert np.isclose(e["te_gpu"], e["se_gpu"] + e["de_gpu"])
    assert np.isclose(e["te_cpu"], e["se_cpu"] + e["de_cpu"])
    # static energy = P_idle * T * n_devices
    assert np.isclose(e["se_gpu"], 60.0 * e["runtime"] * 4)
    # dynamic >= 0, peak within envelope
    assert e["de_gpu"] > 0
    assert 60.0 <= e["gpu_power_peak"] <= 215.0
    # curve covers the whole duration
    ts, pc, ph = mon.curve(hz=2000)
    assert ts[-1] == pytest.approx(e["runtime"])
    assert pc.max() == pytest.approx(e["gpu_power_peak"], abs=1.0)


def test_opcounts_algebra():
    a = OpCounts(1, 2, 3, 4)
    b = OpCounts(10, 20, 30, 40)
    s = a + b
    assert (s.flops, s.hbm_bytes, s.ici_bytes, s.n_collectives) == (11, 22, 33, 44)
    d = 2 * a
    assert d.flops == 2 and d.n_collectives == 8


def _fake_mat(n_shards=8, R=1000, mode="ring"):
    import jax.numpy as jnp

    from repro.core.partition import DistELL

    if mode == "ring":
        plan = HaloPlan("ring", (-1, 1), (100, 100), R, n_shards)
    else:
        plan = HaloPlan("allgather", (), (), R, n_shards)
    z = jnp.zeros((n_shards, R, 7))
    zi = jnp.zeros((n_shards, R, 7), jnp.int32)
    return DistELL(
        data_loc=z, col_loc=zi, data_ext=z[:, :, :1], col_ext=zi[:, :, :1],
        bnd_rows=zi[:, :, 0], send_sel=zi[:, :, 0],
        plan=plan, n_global=R * n_shards,
        row_starts=tuple(range(0, R * (n_shards + 1), R)),
        n_bnd=(R,) * n_shards,
    )


def test_comm_reduction_ordering():
    """The paper's claim structure: fused/ring variants cost less than naive."""
    mat_ring = _fake_mat(mode="ring")
    mat_ag = _fake_mat(mode="allgather")
    cm = CostModel()
    c_hs = cg_iteration_counts(mat_ring, "hs")
    c_fcg = cg_iteration_counts(mat_ring, "fcg")
    c_sstep = cg_iteration_counts(mat_ring, "sstep")
    c_naive = cg_iteration_counts(mat_ag, "naive")
    # reduction counts (net of the SpMV halo collectives) strictly ordered:
    # sstep (1/s) < fcg (1) < hs (2) < naive (3)
    sp_ring = spmv_counts(mat_ring).n_collectives
    sp_ag = spmv_counts(mat_ag).n_collectives
    red = lambda c, sp: c.n_collectives - sp
    assert red(c_sstep, sp_ring) < red(c_fcg, sp_ring) < red(c_hs, sp_ring)
    assert red(c_hs, sp_ring) < red(c_naive, sp_ag)
    # ici bytes: ring << allgather
    assert c_hs.ici_bytes < c_naive.ici_bytes / 3
    # modeled time: naive (serialized) slower than hs (overlapped)
    t_hs, _ = cm.times(c_hs, 8, overlap=True)
    t_naive, _ = cm.times(c_naive, 8, overlap=False)
    assert t_naive > t_hs
    # energy ordering follows
    _, _, de_hs, _ = cm.device_energy(c_hs, 8, True)
    _, _, de_naive, _ = cm.device_energy(c_naive, 8, False)
    assert de_naive > de_hs


def test_spmv_counts_scale_with_halo():
    small = spmv_counts(_fake_mat(mode="ring"))
    big = spmv_counts(_fake_mat(mode="allgather"))
    assert big.ici_bytes > small.ici_bytes
    assert small.flops == big.flops
