"""Energy model + monitor + accounting invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partition import HaloPlan
from repro.energy.accounting import (
    CostModel,
    OpCounts,
    cg_iteration_counts,
    spmv_counts,
)
from repro.energy.model import PowerModel
from repro.energy.monitor import PowerMonitor


def test_power_model_calibration_points():
    m = PowerModel()
    # roofline-saturating matmul draws peak
    assert m.chip_power(m.chip.peak_flops_bf16, m.chip.hbm_bw, 0) == m.chip.p_peak_w
    # HBM stream draws idle + 65% envelope
    assert np.isclose(
        m.chip_power(0, m.chip.hbm_bw, 0),
        m.chip.p_idle_w + 0.65 * (m.chip.p_peak_w - m.chip.p_idle_w),
    )
    # idle
    assert m.chip_power(0, 0, 0) == m.chip.p_idle_w
    # clamped
    assert m.chip_power(1e18, 1e14, 1e13) == m.chip.p_peak_w


@settings(max_examples=30, deadline=None)
@given(
    flops=st.floats(0, 1e15),
    hbm=st.floats(0, 1e12),
    ici=st.floats(0, 1e11),
)
def test_power_is_monotone_and_bounded(flops, hbm, ici):
    m = PowerModel()
    p = m.chip_power(flops, hbm, ici)
    assert m.chip.p_idle_w <= p <= m.chip.p_peak_w
    assert p >= m.chip_power(flops * 0.5, hbm * 0.5, ici * 0.5) - 1e-9


def test_monitor_energy_identities():
    mon = PowerMonitor(n_devices=4)
    mon.idle(0.1)
    c = OpCounts(flops=1e9, hbm_bytes=4e9, ici_bytes=1e7, n_collectives=2)
    mon.region("work", c, n_shards=4, repeats=10)
    mon.idle(0.1)
    e = mon.energy()
    # TE = SE + DE (per component)
    assert np.isclose(e["te_gpu"], e["se_gpu"] + e["de_gpu"])
    assert np.isclose(e["te_cpu"], e["se_cpu"] + e["de_cpu"])
    # static energy = P_idle * T * n_devices
    assert np.isclose(e["se_gpu"], 60.0 * e["runtime"] * 4)
    # dynamic >= 0, peak within envelope
    assert e["de_gpu"] > 0
    assert 60.0 <= e["gpu_power_peak"] <= 215.0
    # curve covers the whole duration
    ts, pc, ph = mon.curve(hz=2000)
    assert ts[-1] == pytest.approx(e["runtime"])
    assert pc.max() == pytest.approx(e["gpu_power_peak"], abs=1.0)


def test_opcounts_algebra():
    a = OpCounts(1, 2, 3, 4)
    b = OpCounts(10, 20, 30, 40)
    s = a + b
    assert (s.flops, s.hbm_bytes, s.ici_bytes, s.n_collectives) == (11, 22, 33, 44)
    d = 2 * a
    assert d.flops == 2 and d.n_collectives == 8


def _fake_mat(n_shards=8, R=1000, mode="ring"):
    import jax.numpy as jnp

    from repro.core.partition import DistELL

    if mode == "ring":
        plan = HaloPlan("ring", (-1, 1), (100, 100), R, n_shards)
    else:
        plan = HaloPlan("allgather", (), (), R, n_shards)
    z = jnp.zeros((n_shards, R, 7))
    zi = jnp.zeros((n_shards, R, 7), jnp.int32)
    return DistELL(
        data_loc=z, col_loc=zi, data_ext=z[:, :, :1], col_ext=zi[:, :, :1],
        bnd_rows=zi[:, :, 0], send_sel=zi[:, :, 0],
        plan=plan, n_global=R * n_shards,
        row_starts=tuple(range(0, R * (n_shards + 1), R)),
        n_bnd=(R,) * n_shards,
    )


def test_comm_reduction_ordering():
    """The paper's claim structure: fused/ring variants cost less than naive."""
    mat_ring = _fake_mat(mode="ring")
    mat_ag = _fake_mat(mode="allgather")
    cm = CostModel()
    c_hs = cg_iteration_counts(mat_ring, "hs")
    c_fcg = cg_iteration_counts(mat_ring, "fcg")
    c_sstep = cg_iteration_counts(mat_ring, "sstep")
    c_naive = cg_iteration_counts(mat_ag, "naive")
    # reduction counts (net of the SpMV halo collectives) strictly ordered:
    # sstep (1/s) < fcg (1) < hs (2) < naive (3)
    sp_ring = spmv_counts(mat_ring).n_collectives
    sp_ag = spmv_counts(mat_ag).n_collectives
    red = lambda c, sp: c.n_collectives - sp
    assert red(c_sstep, sp_ring) < red(c_fcg, sp_ring) < red(c_hs, sp_ring)
    assert red(c_hs, sp_ring) < red(c_naive, sp_ag)
    # ici bytes: ring << allgather
    assert c_hs.ici_bytes < c_naive.ici_bytes / 3
    # modeled time: naive (serialized) slower than hs (overlapped)
    t_hs, _ = cm.times(c_hs, 8, overlap=True)
    t_naive, _ = cm.times(c_naive, 8, overlap=False)
    assert t_naive > t_hs
    # energy ordering follows
    _, _, de_hs, _ = cm.device_energy(c_hs, 8, True)
    _, _, de_naive, _ = cm.device_energy(c_naive, 8, False)
    assert de_naive > de_hs


def test_spmv_counts_scale_with_halo():
    small = spmv_counts(_fake_mat(mode="ring"))
    big = spmv_counts(_fake_mat(mode="allgather"))
    assert big.ici_bytes > small.ici_bytes
    assert small.flops == big.flops


# ---------------------------------------------------------------------------
# DVFS axis: the frequency-scaled chip must preserve the calibration
# invariants the default model is built on (docs/autotune.md)
# ---------------------------------------------------------------------------


def test_freq_axis_preserves_calibration_invariants():
    base = PowerModel()
    prev_e_hbm = -1.0
    for f in sorted(base.chip.freq_points):
        m = base.at_freq(f)
        # ICI energy-per-byte stays exactly 2x HBM energy-per-byte
        assert np.isclose(m.e_ici, 2.0 * m.e_hbm)
        # instantaneous power is clamped to the (scaled) p_peak_w
        assert m.chip_power(1e18, 1e14, 1e13) == m.chip.p_peak_w
        assert m.chip.p_peak_w <= base.chip.p_peak_w
        # the roofline-saturating point still draws exactly peak
        assert np.isclose(
            m.chip_power(m.chip.peak_flops_bf16, m.chip.hbm_bw, 0),
            m.chip.p_peak_w,
        )
        # static power is leakage: it does not scale with the core clock
        assert m.chip_static_w == base.chip_static_w
        # energy-per-byte is monotone in frequency (drops as f drops)
        assert m.e_hbm > prev_e_hbm
        prev_e_hbm = m.e_hbm
    # identity at nominal frequency — the default path is untouched
    assert base.at_freq(1.0) is base
    assert base.chip.at_freq(1.0) is base.chip


def test_freq_axis_scales_compute_not_bandwidth():
    chip = PowerModel().chip
    half = chip.at_freq(0.5)
    assert half.peak_flops_bf16 == chip.peak_flops_bf16 * 0.5
    assert half.peak_flops_f32 == chip.peak_flops_f32 * 0.5
    assert half.hbm_bw == chip.hbm_bw
    assert half.ici_bw == chip.ici_bw
    # dynamic envelope scales ~ f * V(f)^2 with the voltage floor
    v = chip.v_frac(0.5)
    assert np.isclose(
        half.p_peak_w - half.p_idle_w,
        (chip.p_peak_w - chip.p_idle_w) * 0.5 * v * v,
    )
    with pytest.raises(ValueError):
        chip.at_freq(0.0)
    with pytest.raises(ValueError):
        chip.at_freq(1.5)


def test_region_sum_equals_monitor_total_at_nondefault_freq():
    """The executed-ledger invariant must survive a downclocked pricing."""
    cm = CostModel().at_freq(0.6)
    mon = PowerMonitor(n_devices=4, cost=cm)
    mon.idle(0.01)
    mon.region(
        "overlap",
        OpCounts(flops=1e9, hbm_bytes=4e9, ici_bytes=1e7, n_collectives=2),
        n_shards=4, repeats=7,
    )
    mon.region(
        "reductions",
        OpCounts(flops=2e8, hbm_bytes=8e8, ici_bytes=8.0, n_collectives=1),
        n_shards=4, repeats=7,
    )
    mon.idle(0.01)
    tot = mon.energy()
    by_region = mon.energy_by_region()
    regions = {k: v for k, v in by_region.items() if k != "idle"}
    assert np.isclose(
        sum(r["de_j"] for r in regions.values()), tot["de_total"]
    )
    # peak respects the scaled envelope, and the downclocked solve is
    # strictly cheaper than the nominal one on identical counts
    assert tot["gpu_power_peak"] <= cm.power.chip.p_peak_w
    mon1 = PowerMonitor(n_devices=4, cost=CostModel())
    mon1.region(
        "overlap",
        OpCounts(flops=1e9, hbm_bytes=4e9, ici_bytes=1e7, n_collectives=2),
        n_shards=4, repeats=7,
    )
    mon1.region(
        "reductions",
        OpCounts(flops=2e8, hbm_bytes=8e8, ici_bytes=8.0, n_collectives=1),
        n_shards=4, repeats=7,
    )
    assert tot["de_gpu"] < mon1.energy()["de_gpu"]
