"""Partition/halo-plan invariants (property-based)."""

import numpy as np
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partition import (
    HaloPlan,
    balanced_partition,
    pad_vector,
    partition_csr,
    plane_partition,
    unpad_vector,
)


def test_balanced_partition_covers_all_rows():
    part = balanced_partition(103, 8)
    assert part.row_starts[0] == 0 and part.row_starts[-1] == 103
    sizes = np.diff(part.row_starts)
    assert sizes.min() >= 12 and sizes.max() <= 13


def test_owner_of_is_consistent():
    part = balanced_partition(100, 7)
    cols = np.arange(100)
    owners = part.owner_of(cols)
    for s in range(7):
        lo, hi = part.owner_range(s)
        assert (owners[lo:hi] == s).all()


def test_plane_partition_alignment():
    part = plane_partition(6 * 6 * 12, 36, 4)
    for s in range(4):
        lo, hi = part.owner_range(s)
        assert lo % 36 == 0 and hi % 36 == 0


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(24, 80),
    n_shards=st.sampled_from([2, 3, 4]),
    density=st.floats(0.05, 0.25),
    seed=st.integers(0, 1000),
)
def test_partition_roundtrip_vector(n, n_shards, density, seed):
    a = sp.random(n, n, density=density, format="csr", random_state=seed)
    a = a + sp.eye(n)
    mat = partition_csr(a.tocsr(), n_shards)
    x = np.random.default_rng(seed).standard_normal(n)
    assert np.allclose(unpad_vector(pad_vector(x, mat), mat), x)


def test_ring_vs_allgather_same_matrix_semantics():
    """Both layouts encode the same matrix (checked via dense reassembly of
    local blocks applied to unit vectors on 1 device)."""
    rng = np.random.default_rng(3)
    a = sp.random(40, 40, density=0.15, format="csr", random_state=3)
    a.setdiag(2.0)
    a = a.tocsr()
    m_ring = partition_csr(a, 4)
    m_ag = partition_csr(a, 4, force_allgather=True)
    assert m_ring.plan.n_own_pad == m_ag.plan.n_own_pad
    assert m_ag.plan.mode == "allgather"
    # nnz conservation: sum of |data| equal in both splits
    tot_ring = float(np.abs(np.asarray(m_ring.data_loc)).sum() + np.abs(np.asarray(m_ring.data_ext)).sum())
    tot_ag = float(np.abs(np.asarray(m_ag.data_loc)).sum() + np.abs(np.asarray(m_ag.data_ext)).sum())
    assert np.isclose(tot_ring, tot_ag)
    assert np.isclose(tot_ring, float(np.abs(a).sum()))


def test_banded_matrix_stays_ring_irregular_falls_back():
    n = 60
    band = sp.diags([np.ones(n - 1), np.full(n, 2.0), np.ones(n - 1)], [-1, 0, 1])
    m = partition_csr(band.tocsr(), 4)
    assert m.plan.mode == "ring"
    assert all(abs(d) <= 1 for d in m.plan.shifts)
    # long-range coupling -> allgather fallback
    rng = np.random.default_rng(0)
    rows = rng.integers(0, n, 50)
    cols = (rows + n // 2) % n
    far = sp.coo_matrix((np.ones(50), (rows, cols)), shape=(n, n))
    m2 = partition_csr((band + far).tocsr(), 4, max_ring=1)
    assert m2.plan.mode == "allgather"


def test_abstract_split_shapes_match_partition_stencil():
    """abstract_stencil_dist (dry-run/modeled shapes) must stay in lockstep
    with partition_stencil's interior/boundary compaction — the modeled
    energy baselines derive nnz_stored from the abstract shapes."""
    from repro.core.cg import abstract_stencil_dist
    from repro.core.partition import partition_stencil
    from repro.matrices.poisson import PoissonProblem

    for stencil in ("7pt", "27pt"):
        for nx, ny, nz, shards in [(4, 4, 4, 1), (4, 4, 4, 2), (4, 4, 8, 4),
                                   (4, 4, 4, 4), (3, 5, 6, 3)]:
            p = PoissonProblem(nx, ny, nz, stencil)
            real = partition_stencil(p, shards)
            sds = abstract_stencil_dist(p, shards)
            for field in ("data_loc", "col_loc", "data_ext", "col_ext",
                          "bnd_rows", "send_sel"):
                assert getattr(real, field).shape == getattr(sds, field).shape, (
                    stencil, (nx, ny, nz, shards), field
                )
            # (dtype not compared: the no-x64 pytest process downcasts the
            # materialized arrays to f32; shapes/plan are what the modeled
            # counts consume)
            assert real.n_bnd == sds.n_bnd, (stencil, (nx, ny, nz, shards))
            assert real.plan == sds.plan


def test_expand_boundary_roundtrip_every_format():
    """expand_boundary inverts the boundary-row compaction exactly for every
    interior format — the boundary block is format-agnostic by design."""
    from repro.core.partition import expand_boundary

    a = sp.random(60, 60, density=0.12, format="csr", random_state=11)
    a.setdiag(3.0)
    a = a.tocsr()
    for fmt in ("ell", "hyb", "bcsr"):
        mat = partition_csr(a, 3, fmt=fmt)
        de_full, ce_full = expand_boundary(mat)
        de = np.asarray(mat.data_ext)
        ce = np.asarray(mat.col_ext)
        rows = np.asarray(mat.bnd_rows)
        for s in range(3):
            nb = mat.n_bnd[s]
            sel = rows[s, :nb]
            np.testing.assert_array_equal(de_full[s, sel], de[s, :nb])
            np.testing.assert_array_equal(ce_full[s, sel], ce[s, :nb])
            other = np.ones(de_full.shape[1], bool)
            other[sel] = False
            assert (de_full[s, other] == 0).all()
            assert (ce_full[s, other] == 0).all()


def test_haloplan_bytes_accounting():
    plan = HaloPlan("ring", (-1, 1), (36, 36), 100, 8)
    assert plan.collective_bytes_per_shard(8) == 72 * 8
    assert plan.ext_len == 100 + 72
    ag = HaloPlan("allgather", (), (), 100, 8)
    assert ag.collective_bytes_per_shard(8) == 100 * 7 * 8
    assert ag.ext_len == 800
