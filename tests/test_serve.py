"""Serving engine (launch/serve_solver.py): sessions, admission, energy.

In-process single-device tests (f32 — the main pytest process runs
without x64, so tolerances are loose); each test gets its own
:class:`SessionPool` so warm state never leaks between tests. The engine's
``clock`` is injectable: a deterministic counter makes the latency
percentiles exactly reproducible.

Covers the serving acceptance invariants at unit scale:

* session reuse — the second batch against the same matrix fingerprint
  does zero partitions and zero tuning trials;
* ragged admission — r-1 requests into r slots flush as one padded batch
  whose solutions still match the direct solve (the deflation mask
  retires the zero padding column at iteration 0);
* per-request energy — ``split_block_energy`` shares sum back to the
  engine-total energy (exactly, by the residue correction);
* determinism — two engines under the same scripted clock report
  identical p50/p99 latency;
* the autotune warm path — a second engine over the same tuning cache
  serves with zero trials and the same decision.
"""

import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.autotune.pool import SessionPool
from repro.launch.serve_solver import ServeEngine


def _poisson(side):
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(side, "7pt")
    return poisson_scipy(p, dtype=np.float64)


def _rhs(n, r, seed=0):
    return np.random.default_rng(seed).standard_normal((n, r))


def _counter_clock():
    it = iter(range(10**9))
    return lambda: float(next(it))


def _engine(**kw):
    kw.setdefault("slots", 4)
    kw.setdefault("tol", 1e-5)  # f32 in-process
    kw.setdefault("maxiter", 200)
    kw.setdefault("pool", SessionPool())
    return ServeEngine(1, **kw)


def test_warm_batches_do_zero_setup():
    a = _poisson(6)
    eng = _engine(slots=4)
    results = eng.serve(a, _rhs(a.shape[0], 8).T)
    led = eng.ledger()
    assert led["n_batches"] == 2 and led["n_requests"] == 8
    b0, b1 = led["batches"]
    assert b0["cold"] and b0["new_partitions"] >= 1
    assert not b1["cold"]
    assert b1["new_partitions"] == 0 and b1["new_tune_trials"] == 0
    # one session, partitioned exactly once, all 8 solves through it
    (sess,) = led["sessions"]
    assert sess["partitions"] == b0["new_partitions"]
    assert sess["tune_trials"] == 0
    assert sess["solves"] == 8
    assert [r.rid for r in results] == list(range(8))
    assert all(not r.cold for r in results[4:])


def test_ragged_admission_pads_and_solves():
    a = _poisson(6)
    n = a.shape[0]
    B = _rhs(n, 3)
    eng = _engine(slots=4)
    results = eng.serve(a, B.T)  # 3 requests into 4 slots
    led = eng.ledger()
    assert led["n_batches"] == 1
    (batch,) = led["batches"]
    assert batch["size"] == 3 and batch["slots"] == 4
    x_ref = spla.spsolve(a.tocsc(), B)
    for j, r in enumerate(results):
        assert r.iters > 0 and r.relres <= 1e-4
        np.testing.assert_allclose(r.x, x_ref[:, j], rtol=2e-3, atol=2e-3)


def test_sequential_slots_one():
    a = _poisson(5)
    eng = _engine(slots=1)
    results = eng.serve(a, _rhs(a.shape[0], 3).T)
    led = eng.ledger()
    assert led["n_batches"] == 3 and led["warm_batches"] == 2
    x_ref = spla.spsolve(a.tocsc(), _rhs(a.shape[0], 3))
    for j, r in enumerate(results):
        np.testing.assert_allclose(r.x, x_ref[:, j], rtol=2e-3, atol=2e-3)


def test_per_request_energy_sums_to_engine_total():
    a = _poisson(6)
    eng = _engine(slots=4)
    results = eng.serve(a, _rhs(a.shape[0], 7).T)  # full + ragged batch
    led = eng.ledger()
    total = led["totals"]["energy_j"]
    req_sum = sum(r.energy_j for r in results)
    assert total > 0
    # exact by the attribution's residue correction (up to the float
    # summation-order difference between per-batch and per-request sums)
    assert abs(req_sum - total) <= 1e-9 * total
    assert led["totals"]["energy_requests_j"] == pytest.approx(req_sum)
    # every request pays something: setup share + >= 0 iterations
    assert all(r.energy_j > 0 for r in results)


def test_latency_percentiles_deterministic_under_scripted_clock():
    a = _poisson(5)
    stats = []
    for _ in range(2):
        eng = _engine(slots=2, pool=SessionPool(), clock=_counter_clock())
        eng.serve(a, _rhs(a.shape[0], 6).T)
        tot = eng.ledger()["totals"]
        stats.append((tot["wall_latency_p50_s"], tot["wall_latency_p99_s"]))
    assert stats[0] == stats[1]
    assert stats[0][1] >= stats[0][0] > 0


def test_autotune_warm_path_across_engines(tmp_path):
    a = _poisson(6)
    cache = str(tmp_path / "cache.json")
    kw = dict(slots=2, autotune=True, tune_budget=2, tune_cache=cache)
    eng1 = _engine(pool=SessionPool(), **kw)
    eng1.serve(a, _rhs(a.shape[0], 4).T)
    led1 = eng1.ledger()
    assert led1["sessions"][0]["tune_trials"] > 0
    assert not led1["tuned"][0]["tune_cached"]
    # a fresh engine + pool over the same persistent cache: zero trials
    eng2 = _engine(pool=SessionPool(), **kw)
    eng2.serve(a, _rhs(a.shape[0], 4).T)
    led2 = eng2.ledger()
    assert led2["sessions"][0]["tune_trials"] == 0
    assert led2["tuned"][0]["tune_cached"]
    assert led2["tuned"][0]["tuned_label"] == led1["tuned"][0]["tuned_label"]


def test_split_block_energy_properties():
    from repro.energy.attribution import split_block_energy

    iters_cols = np.array([3, 10, 7, 0])  # col 3 is padding
    real = np.array([True, True, True, False])
    shares = split_block_energy(10.0, 1.0, 10, iters_cols, real)
    assert shares.shape == (4,)
    assert shares[3] == 0.0  # padding pays nothing
    assert float(shares.sum()) == 10.0  # exact
    # the column that iterated longest pays the most
    assert shares[1] == shares.max()
    # zero iterations: the whole budget is setup, split evenly
    flat = split_block_energy(6.0, 6.0, 0, np.zeros(3, int),
                              np.ones(3, bool))
    np.testing.assert_allclose(flat, 2.0)
    assert float(flat.sum()) == 6.0


def test_split_block_energy_idle_iterations_are_overhead():
    from repro.energy.attribution import split_block_energy

    # the caller reports 4 trailing iterations past the last real
    # convergence (cols converge at 2 and 4, iters=8): their energy has no
    # causal owner and must split evenly — not be dumped, via the residue
    # correction, on whichever request converged last
    shares = split_block_energy(10.0, 1.0, 8, np.array([2, 4]),
                                np.ones(2, bool))
    assert float(shares.sum()) == 10.0
    e_iter = (10.0 - 1.0) / 8
    # the columns differ only by the 2 iterations col 1 was alone in;
    # the 4 idle iterations' energy (4 * e_iter) is shared equally
    assert shares[1] - shares[0] == pytest.approx(2 * e_iter)


def test_same_pattern_different_values_are_distinct_sessions():
    from repro.autotune.pool import session_key

    a = _poisson(5)
    a2 = a.copy()
    a2.data = a2.data * 1.5  # same pattern + statistics, new coefficients
    assert session_key(a, 1) != session_key(a2, 1)
    n = a.shape[0]
    B = _rhs(n, 2)
    eng = _engine(slots=1)
    r1 = eng.submit(a, B[:, 0])
    r2 = eng.submit(a2, B[:, 1])
    # two sessions, not one: the pool must not serve a2's request from
    # a's warm session (same-stats collision == wrong linear system)
    assert eng.pool.misses == 2 and len(eng.pool) == 2
    by_rid = {r.rid: r for r in eng.results}
    np.testing.assert_allclose(
        by_rid[r1].x, spla.spsolve(a.tocsc(), B[:, 0]),
        rtol=2e-3, atol=2e-3,
    )
    np.testing.assert_allclose(
        by_rid[r2].x, spla.spsolve(a2.tocsc(), B[:, 1]),
        rtol=2e-3, atol=2e-3,
    )


def test_pool_lru_eviction_closes_sessions():
    class FakeSession:
        def __init__(self, a_csr, n_shards, key=None):
            self.key = key
            self.closed = False

        def close(self):
            self.closed = True

    a1, a2, a3 = _poisson(3), _poisson(4), _poisson(5)
    pool = SessionPool(factory=FakeSession, capacity=2)
    s1 = pool.session(a1, 1)
    s2 = pool.session(a2, 1)
    assert pool.session(a1, 1) is s1  # hit refreshes s1's recency
    s3 = pool.session(a3, 1)  # past capacity: evicts s2, the LRU
    assert len(pool) == 2 and pool.evictions == 1
    assert s2.closed and not s1.closed and not s3.closed
    assert pool.session(a1, 1) is s1  # survivors still warm
    assert pool.session(a2, 1) is not s2  # evicted: rebuilt on next use
    assert pool.stats()["evictions"] == 2
    assert pool.stats()["capacity"] == 2


def test_submit_rejects_mismatched_rhs():
    a = _poisson(4)
    eng = _engine(slots=2)
    with pytest.raises(ValueError, match="does not match the session"):
        eng.submit(a, np.ones(a.shape[0] + 1))
    # nothing was admitted or counted
    led = eng.ledger()
    assert led["n_requests"] == 0 and led["n_batches"] == 0


def test_session_close_drops_warm_state_but_stays_usable():
    a = _poisson(4)
    n = a.shape[0]
    eng = _engine(slots=2)
    eng.serve(a, _rhs(n, 2).T)
    (sess,) = eng.pool.sessions.values()
    assert sess.mats and sess.handles
    sess.close()
    assert not sess.mats and not sess.handles
    # the next solve through the closed session pays the cold path again
    B = _rhs(n, 2, seed=1)
    results = eng.serve(a, B.T)[-2:]
    x_ref = spla.spsolve(a.tocsc(), B)
    for j, r in enumerate(results):
        np.testing.assert_allclose(r.x, x_ref[:, j], rtol=2e-3, atol=2e-3)


def test_global_handle_cache_is_lru_bounded(monkeypatch):
    from repro.core import cg

    cg.clear_solver_handles()
    monkeypatch.setattr(cg, "make_solver", lambda *a, **k: (lambda *x: None))
    prev = cg.set_solver_handle_limit(2)
    try:
        mesh = object()
        mats = [object() for _ in range(3)]
        handles = [cg.solver_handle(mesh, m) for m in mats]
        assert len(cg._HANDLES) == 2
        # the oldest handle was evicted; re-requesting rebuilds it
        assert cg.solver_handle(mesh, mats[0]) is not handles[0]
        # a session-owned cache is scoped by its owner, not the global cap
        own = {}
        for m in mats:
            cg.solver_handle(mesh, m, cache=own)
        assert len(own) == 3 and len(cg._HANDLES) == 2
    finally:
        cg.set_solver_handle_limit(prev)
        cg.clear_solver_handles()
