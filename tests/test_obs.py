"""Observability layer: timelines, sampling, traces, telemetry, metrics."""

import json
import logging
import os
import subprocess
import sys

import numpy as np
import pytest

from tests.conftest import REPO, SRC


def _reference_monitor(iters=40, n_shards=4):
    from repro.energy.accounting import OpCounts
    from repro.energy.trace import EnergyTrace, monitor_from_trace

    tr = EnergyTrace()
    tr.enter("setup")
    tr.enter("iteration")
    tr.record("setup", "spmv", "spmv", OpCounts(flops=1e11, hbm_bytes=1e11))
    tr.record("iteration", "overlap", "spmv",
              OpCounts(flops=5e10, hbm_bytes=6e10, ici_bytes=1e7,
                       n_collectives=1))
    tr.record("iteration", "reductions", "dot",
              OpCounts(flops=1e9, hbm_bytes=4e9, ici_bytes=64,
                       n_collectives=1))
    return monitor_from_trace(tr, iters=iters, n_shards=n_shards,
                              idle_s=0.01)


# -- timeline: exact replay of the monitor --------------------------------


def test_timeline_spans_cover_duration_exactly():
    from repro.obs.timeline import build_timeline

    mon = _reference_monitor()
    tl = build_timeline(mon)
    assert len(tl.spans) == len(mon.segments)
    assert sum(sp.dt for sp in tl.spans) == mon.duration
    # spans are contiguous on the wall clock
    for a, b in zip(tl.spans, tl.spans[1:]):
        assert a.t1 == b.t0


def test_timeline_energy_bitwise_matches_monitor():
    from repro.obs.timeline import build_timeline

    mon = _reference_monitor()
    tl = build_timeline(mon)
    e_mon, e_tl = mon.energy(), tl.energy()
    for k, v in e_tl.items():
        assert v == e_mon[k], k  # bitwise: same sums over the same floats
    assert tl.energy_by_region() == mon.energy_by_region()


def test_sections_annotate_spans():
    from repro.energy.trace import ITERATION, SETUP
    from repro.obs.timeline import build_timeline

    tl = build_timeline(_reference_monitor())
    sections = {sp.section for sp in tl.spans}
    assert SETUP in sections and ITERATION in sections


# -- emulated fixed-rate power sampler ------------------------------------


def test_sample_power_tiles_the_timeline():
    from repro.obs.timeline import build_timeline, sample_power

    tl = build_timeline(_reference_monitor())
    sp = sample_power(tl, 100.0)
    assert sp.hz == 100.0
    assert np.isclose(sp.widths.sum(), tl.duration, rtol=0, atol=1e-9)
    assert (sp.ts >= 0).all() and (sp.ts <= tl.duration).all()
    assert (sp.p_chip > 0).all() and (sp.p_host > 0).all()


def test_sampled_energy_converges_to_ledger():
    from repro.obs.timeline import build_timeline, sampling_error

    tl = build_timeline(_reference_monitor())
    coarse, fine = sampling_error(tl, 10), sampling_error(tl, 10_000)
    assert fine <= 0.01, f"10 kHz sampling error {fine:.3e} above 1%"
    assert fine < coarse, (fine, coarse)


def test_integrate_samples_static_term_is_exact():
    from repro.obs.timeline import (
        build_timeline,
        integrate_samples,
        sample_power,
    )

    mon = _reference_monitor()
    tl = build_timeline(mon)
    e = integrate_samples(tl, sample_power(tl, 50.0))
    # static energy depends only on the duration, not the sampling rate
    assert e["se_gpu"] == mon.energy()["se_gpu"]


# -- Chrome trace export ---------------------------------------------------


def _trace_obj(tmp_path, timelines, **kw):
    from repro.obs.trace_export import write_chrome_trace

    path = os.path.join(tmp_path, "out.trace.json")
    write_chrome_trace(path, timelines, meta=dict(problem="test"), **kw)
    with open(path) as f:
        return json.load(f)


def test_chrome_trace_validates(tmp_path):
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        from check_trace import validate_trace
    finally:
        sys.path.pop(0)
    from repro.obs.timeline import build_timeline

    tl = build_timeline(_reference_monitor())
    obj = _trace_obj(str(tmp_path), [("solve", tl)])
    assert validate_trace(obj) == []
    phases = {e["ph"] for e in obj["traceEvents"]}
    assert {"M", "X", "C"} <= phases
    names = {e["name"] for e in obj["traceEvents"] if e["ph"] == "C"}
    assert {"chip_power_w", "hbm_bytes_total"} <= names


def test_chrome_trace_sequential_offsets(tmp_path):
    from repro.obs.timeline import build_timeline

    tl = build_timeline(_reference_monitor(iters=5))
    obj = _trace_obj(str(tmp_path), [("a", tl), ("b", tl)], sequential=True)
    by_pid = {}
    for e in obj["traceEvents"]:
        if e["ph"] == "X":
            by_pid.setdefault(e["pid"], []).append(e)
    assert len(by_pid) == 2
    p0, p1 = sorted(by_pid)
    end0 = max(e["ts"] + e["dur"] for e in by_pid[p0])
    start1 = min(e["ts"] for e in by_pid[p1])
    assert start1 >= end0  # laid end-to-end, not overlapped


def test_check_trace_rejects_overlapping_lanes():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        from check_trace import validate_trace
    finally:
        sys.path.pop(0)
    bad = {
        "traceEvents": [
            {"ph": "X", "name": "a", "pid": 1, "tid": 0, "ts": 0.0,
             "dur": 10.0},
            {"ph": "X", "name": "b", "pid": 1, "tid": 0, "ts": 5.0,
             "dur": 10.0},
            {"ph": "C", "name": "chip_power_w", "pid": 1, "ts": 0.0,
             "args": {"w": 1.0}},
            {"ph": "C", "name": "hbm_bytes_total", "pid": 1, "ts": 0.0,
             "args": {"b": 1.0}},
        ]
    }
    errs = validate_trace(bad)
    assert any("overlap" in e for e in errs)


# -- convergence telemetry -------------------------------------------------


def test_convergence_record_splits_runs():
    from repro.obs import convergence

    rec = convergence.ConvergenceRecord()
    for i in (1, 2, 3, 1, 2):  # warm-up run, then the recorded solve
        rec.add(i, 10.0 ** -i)
    assert len(rec.runs()) == 2
    assert rec.history() == [(1, 0.1), (2, 0.01)]
    led = rec.ledger()
    assert led["iters_recorded"] == 2 and led["first_iter"] == 1


def test_emit_keeps_only_shard_zero():
    from repro.obs import convergence

    with convergence.record() as rec:
        convergence.emit(1, 1, 0.5)  # another shard: dropped
        convergence.emit(0, 1, 0.5)
    assert rec.entries == [(1, 0.5)]
    convergence.emit(0, 2, 0.25)  # no active recorder: no-op
    assert rec.entries == [(1, 0.5)]


@pytest.mark.parametrize("variant", ["hs", "fcg"])
def test_telemetry_history_length_matches_iters(single_mesh, variant):
    import jax

    from repro.core.cg import solve_cg
    from repro.core.partition import partition_csr
    from repro.core.spmv import shard_matrix
    from repro.matrices.poisson import cube, default_rhs, poisson_scipy
    from repro.obs import convergence

    p = cube(6, "7pt")
    a = poisson_scipy(p, dtype=np.float64)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    with convergence.record() as rec:
        res = solve_cg(
            single_mesh, mat, default_rhs(p.n), variant=variant,
            tol=1e-8, maxiter=100, telemetry=True,
        )
        jax.effects_barrier()
    hist = rec.history()
    if variant == "hs":
        # one report per executed iteration, tail == the final residual
        assert len(hist) == int(res.iters)
        assert hist[0][0] == 1
        assert np.isclose(hist[-1][1], float(res.rel_residual), rtol=1e-6)
    else:
        # fcg peels iteration 1 into the prologue (the loop body starts at
        # i=1 with its residual lagging one update), so the instrumented
        # body reports iterations 2..iters
        assert len(hist) == int(res.iters) - 1
        assert hist[0][0] == 2
    assert hist[-1][0] == int(res.iters)
    rel = [v for _, v in hist]
    assert rel[-1] < 1e-6 * rel[0]  # the curve actually converged


# -- metrics registry ------------------------------------------------------


def test_metrics_counter_gauge_histogram():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc()
    c.inc(2)
    with pytest.raises(ValueError):
        c.inc(-1)  # counters are monotone
    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["req_total"]["value"] == 3.0
    assert snap["depth"]["value"] == 3.0
    assert snap["lat_s"]["count"] == 4 and snap["lat_s"]["counts"] == [
        1, 1, 1, 1,
    ]
    # same name + kind is idempotent; same name + other kind is an error
    assert reg.counter("req_total") is c
    with pytest.raises(TypeError):
        reg.gauge("req_total")


def test_metrics_prometheus_format():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.counter("served_total", "requests served").inc(7)
    h = reg.histogram("e_j", "energy", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(20.0)
    text = reg.to_prometheus()
    assert "# TYPE served_total counter" in text
    assert "served_total 7" in text
    assert 'e_j_bucket{le="1"} 1' in text
    assert 'e_j_bucket{le="+Inf"} 2' in text
    assert "e_j_sum 20.5" in text and "e_j_count 2" in text


# -- structured logging ----------------------------------------------------


def test_log_default_output_is_bare_message(capsys):
    from repro.obs import log as olog

    olog.setup("info")
    try:
        olog.get_logger("test").info("hello %d", 7)
        assert capsys.readouterr().out == "hello 7\n"
        olog.setup("debug")
        olog.get_logger("test").debug("deep")
        assert capsys.readouterr().out == "[D repro.test] deep\n"
        olog.setup("warning")
        olog.get_logger("test").info("hidden")
        assert capsys.readouterr().out == ""
    finally:
        olog.setup("info")


def test_log_level_from_env(monkeypatch):
    from repro.obs import log as olog

    monkeypatch.setenv("REPRO_LOG", "error")
    try:
        olog.setup("error")
        assert logging.getLogger("repro").level == logging.ERROR
    finally:
        olog.setup("info")


# -- provenance ------------------------------------------------------------


def test_ledger_meta_fields():
    import jax

    from repro.obs.provenance import SCHEMA_VERSION, ledger_meta

    meta = ledger_meta()
    assert meta["schema_version"] == SCHEMA_VERSION
    assert meta["jax"] == jax.__version__
    assert meta["backend"] == jax.default_backend()
    assert meta["device_count"] == jax.device_count()


def test_git_sha_matches_head():
    from repro.obs.provenance import git_sha

    sha = git_sha()
    if sha is None:  # not a checkout (e.g. installed package): allowed
        pytest.skip("no git checkout")
    head = subprocess.run(
        ["git", "rev-parse", "--short", "HEAD"], cwd=REPO,
        capture_output=True, text=True,
    ).stdout.strip()
    assert sha == head


# -- CLI surface (parse-time safety) ---------------------------------------


def test_obs_package_init_is_jax_free():
    # the launchers import obs.log/obs.provenance before device-env setup;
    # the package __init__ must not pull jax in transitively
    code = (
        "import sys; import repro.obs, repro.obs.log, repro.obs.provenance;"
        "assert 'jax' not in sys.modules, 'obs import pulled in jax'"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
