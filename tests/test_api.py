"""Typed API surface (repro/api.py): dataclasses, validation, CLI shim.

The deprecation-shim contract: ``launch.solve`` flag spellings and the
``api`` dataclasses are two views of the same configuration, so

* argv -> ``from_args`` -> ``to_argv`` -> argparse -> ``from_args`` must
  be the identity (both directions of the round trip);
* invalid combinations raise typed :class:`ConfigError` from the API and
  the byte-identical historical ``SystemExit`` message from the CLI;
* ``api.solve`` against a shared pool serves repeat calls from the warm
  session (zero new partitions).

NOTE: the main pytest process runs f32 single-device; ``api.solve`` tests
pass ``x64=False`` and solve tiny Poisson systems.
"""

import numpy as np
import pytest

from repro import api
from repro.launch import solve as solve_cli


def _roundtrip_spec(spec: api.ProblemSpec) -> api.ProblemSpec:
    args = solve_cli.parse_args(spec.to_argv())
    return api.ProblemSpec.from_args(args)


def _roundtrip_config(cfg: api.SolverConfig) -> api.SolverConfig:
    # CLI argv needs a full command line; ride along on default problem args
    args = solve_cli.parse_args(api.ProblemSpec().to_argv() + cfg.to_argv())
    return api.SolverConfig.from_args(args)


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------


def test_problem_spec_roundtrip_defaults():
    spec = api.ProblemSpec()
    assert _roundtrip_spec(spec) == spec


def test_problem_spec_roundtrip_custom():
    spec = api.ProblemSpec(problem="powerlaw", side=10, scale=0.05, shards=4)
    assert _roundtrip_spec(spec) == spec


def test_solver_config_roundtrip_defaults():
    cfg = api.SolverConfig()
    assert _roundtrip_config(cfg) == cfg


@pytest.mark.parametrize(
    "cfg",
    [
        api.SolverConfig(op="spmv", fmt="hyb", overlap=False),
        api.SolverConfig(variant="pipecg", tol=1e-6, maxiter=50, repeats=3),
        api.SolverConfig(nrhs=8, fmt="bcsr", block=8),
        api.SolverConfig(variant="sstep", s=4),
        api.SolverConfig(amg=True),
        api.SolverConfig(amgx_analog=True),
        api.SolverConfig(autotune=True, objective="time", tune_budget=3,
                         tune_cache="/tmp/tc.json"),
    ],
)
def test_solver_config_roundtrip_custom(cfg):
    assert _roundtrip_config(cfg) == cfg


def test_cli_defaults_match_dataclass_defaults():
    # the argparse defaults ARE the dataclass defaults (one source of truth
    # would be nicer, but the shim contract is that they never diverge)
    args = solve_cli.parse_args([])
    assert api.ProblemSpec.from_args(args) == api.ProblemSpec()
    assert api.SolverConfig.from_args(args) == api.SolverConfig()


# ---------------------------------------------------------------------------
# validation: typed ConfigError from the API, SystemExit from the CLI
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kwargs",
    [
        dict(variant="cgs"),
        dict(op="gemm"),
        dict(fmt="csc"),
        dict(objective="power"),
        dict(nrhs=4, variant="fcg"),
        dict(nrhs=4, op="spmv"),
        dict(nrhs=4, amg=True),
        dict(autotune=True, amg=True),
        dict(autotune=True, amgx_analog=True),
        dict(autotune=True, op="spmv"),
        dict(repeats=0),
        dict(maxiter=0),
        dict(tol=0.0),
        dict(tol=-1e-8),
        dict(tune_budget=0),
        dict(nrhs=0),
        dict(block=0),
        dict(s=2),  # the s knob requires the sstep variant
        dict(s=2, variant="hs"),
        dict(s=0, variant="sstep"),
        dict(s=-1, variant="sstep"),
    ],
)
def test_invalid_configs_raise_config_error(kwargs):
    with pytest.raises(api.ConfigError):
        api.SolverConfig(**kwargs)


def test_config_error_is_value_error():
    with pytest.raises(ValueError):
        api.SolverConfig(variant="nope")


@pytest.mark.parametrize(
    "argv, message",
    [
        (["--nrhs", "4", "--variant", "fcg"], api._NRHS_MSG),
        (["--nrhs", "4", "--amg"], api._NRHS_MSG),
        (["--autotune", "--amg"], api._AUTOTUNE_MSG),
        (["--autotune", "--op", "spmv"], api._AUTOTUNE_MSG),
        (["--s", "2"], api._SSTEP_MSG),
    ],
)
def test_cli_shim_preserves_historical_exits(argv, message):
    # the CLI adapter converts ConfigError to the historical SystemExit
    # text byte-for-byte (scripts match on these strings)
    with pytest.raises(SystemExit) as exc:
        solve_cli.main(argv)
    assert str(exc.value) == message


# ---------------------------------------------------------------------------
# api.solve end to end (f32, single device, warm pool)
# ---------------------------------------------------------------------------


def test_solve_returns_report(tmp_path):
    from repro.autotune.pool import SessionPool

    spec = api.ProblemSpec(problem="poisson7", side=6, shards=1)
    cfg = api.SolverConfig(tol=1e-5, maxiter=80)
    ledger = str(tmp_path / "led.json")
    report = api.solve(spec, cfg, ledger=ledger, pool=SessionPool(),
                       x64=False, verbose=False)
    assert report.n == 6**3
    assert report.shards == 1
    assert report.config == cfg
    assert "BCMGX-analog" in report.solvers
    entry = report.solvers["BCMGX-analog"]
    assert entry["iters"] > 0
    assert entry["relres"] <= 1e-5
    assert report.summary["BCMGX-analog"]["iters"] == entry["iters"]
    import json

    with open(ledger) as f:
        on_disk = json.load(f)
    assert on_disk["solvers"].keys() == report.solvers.keys()


def test_solve_repeat_reuses_warm_session():
    from repro.autotune.pool import SessionPool

    pool = SessionPool()
    spec = api.ProblemSpec(problem="poisson7", side=6, shards=1)
    cfg = api.SolverConfig(tol=1e-5, maxiter=80)
    r1 = api.solve(spec, cfg, pool=pool, x64=False, verbose=False)
    assert len(pool) == 1
    sess = next(iter(pool.sessions.values()))
    parts = sess.partitions
    assert parts >= 1
    r2 = api.solve(spec, cfg, pool=pool, x64=False, verbose=False)
    # the second call hit the warm session: no new partitions, same mats
    assert len(pool) == 1
    assert pool.hits == 1
    assert sess.partitions == parts
    assert r2.solvers["BCMGX-analog"]["iters"] == \
        r1.solvers["BCMGX-analog"]["iters"]


def test_solve_validates_config():
    cfg = api.SolverConfig()
    bad = api.SolverConfig.__new__(api.SolverConfig)  # bypass __post_init__
    object.__setattr__(bad, "__dict__", dict(cfg.__dict__, variant="bogus"))
    with pytest.raises(api.ConfigError):
        api.solve(api.ProblemSpec(side=4), bad, x64=False, verbose=False)


def test_default_rhs_block_deterministic():
    from repro.core.cg import default_rhs_block

    b1 = default_rhs_block(50, 4)
    b2 = default_rhs_block(50, 4)
    np.testing.assert_array_equal(b1, b2)
    assert b1.shape == (50, 4)
