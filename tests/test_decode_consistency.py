"""Serving correctness: prefill + one-step decode == full forward, per arch.

This validates every cache path (GQA KV, MLA latent, Mamba2 conv/ssm state,
mLSTM/sLSTM recurrent state, zamba2 shared-attention caches). MoE archs use
a drop-free capacity factor (capacity dropping makes the two paths
legitimately differ at cf=1.25).
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import lm, transformer as tfm
from repro.models.kvcache import init_cache
from repro.models.layers import unembed

S, B = 24, 2


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_matches_full_forward(name):
    cfg0 = ARCHS[name]
    cfg = dataclasses.replace(cfg0.smoke(), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    if cfg.is_encoder_only:
        pytest.skip("encoder-only: no decode")
    rng = np.random.default_rng(42)
    params = tfm.init_params(cfg, jax.random.key(0))
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1)).astype(np.int32)
    batch_full = {"tokens": jnp.asarray(toks)}
    batch_pre = {"tokens": jnp.asarray(toks[:, :S])}
    if cfg.n_patches:
        pe = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32
        )
        batch_full["patch_embeds"] = pe
        batch_pre["patch_embeds"] = pe

    hidden, _, _ = tfm.forward_full(params, cfg, batch_full, kv_chunk=16, remat=False)
    ref = np.asarray(unembed(hidden[:, -1:], tfm.head_table(params, cfg))[:, 0])

    _, cache = lm.prefill(params, cfg, batch_pre, kv_chunk=16)
    target = init_cache(cfg, B, S + 8)

    def splice(dst, src):
        if src.shape != dst.shape:
            pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src, pads).astype(dst.dtype)
        return src.astype(dst.dtype)

    cache2 = jax.tree.map(splice, target, cache)
    logits, _ = lm.serve_step(
        params, cfg, jnp.asarray(toks[:, S]), cache2, jnp.asarray(S, jnp.int32)
    )
    err = np.abs(np.asarray(logits) - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < 2e-3, f"{name}: rel err {err:.2e}"
