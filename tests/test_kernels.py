"""Pallas kernels: shape/dtype sweeps, allclose vs the ref.py oracles.

All kernels run in interpret mode on CPU (the TPU lowering is exercised by
construction: pl.pallas_call + explicit BlockSpecs).
"""

import numpy as np
import jax.numpy as jnp
import pytest
import scipy.sparse as sp

from repro.kernels import ops, ref
from repro.kernels.spmv_bcsr import pack_bcsr
from repro.matrices.poisson import PoissonProblem, poisson_scipy


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize(
    "shape,bz",
    [((8, 8, 8), 4), ((16, 12, 16), 8), ((8, 5, 9), 2), ((24, 16, 32), 8)],
)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_stencil_kernel_sweep(stencil, shape, bz, dtype):
    nz, ny, nx = shape
    rng = np.random.default_rng(nz * ny * nx)
    x = rng.standard_normal(shape).astype(dtype)
    y_ker = np.asarray(ops.stencil_spmv(x, stencil=stencil, bz=bz))
    y_ref = np.asarray(
        ref.stencil7_ref(x) if stencil == "7pt" else ref.stencil27_ref(x)
    )
    # no-x64 main process computes f64 inputs in f32; tol follows actual dtype
    tol = 1e-12 if y_ker.dtype == np.float64 else 1e-4
    np.testing.assert_allclose(y_ker, y_ref, rtol=tol, atol=tol)


def test_stencil_kernel_matches_assembled_matrix():
    for stencil in ("7pt", "27pt"):
        p = PoissonProblem(10, 6, 8, stencil)
        a = poisson_scipy(p, dtype=np.float64)
        x = np.random.default_rng(0).standard_normal((8, 6, 10))
        y = np.asarray(ops.stencil_spmv(x.astype(np.float64), stencil=stencil, bz=4))
        tol = 1e-12 if y.dtype == np.float64 else 2e-4
        np.testing.assert_allclose(
            y.reshape(-1), a @ x.reshape(-1), rtol=tol, atol=tol
        )


def test_stencil_kernel_anisotropic():
    p = PoissonProblem(8, 8, 8, "7pt", aniso=(1.0, 2.5, 7.0))
    a = poisson_scipy(p, dtype=np.float64)
    x = np.random.default_rng(1).standard_normal((8, 8, 8))
    y = np.asarray(ops.stencil_spmv(x, stencil="7pt", aniso=(1.0, 2.5, 7.0), bz=4))
    tol = 1e-12 if y.dtype == np.float64 else 2e-4
    np.testing.assert_allclose(y.reshape(-1), a @ x.reshape(-1), rtol=tol, atol=tol)


@pytest.mark.parametrize("br,bc", [(8, 8), (8, 16), (16, 8)])
@pytest.mark.parametrize("n,m,density", [(120, 96, 0.05), (64, 64, 0.2), (33, 57, 0.1)])
def test_bcsr_kernel_sweep(br, bc, n, m, density):
    a = sp.random(n, m, density=density, format="csr", random_state=n + m)
    blocks, bcol, n_brows, bpr, n_bcols = pack_bcsr(a, br, bc, dtype=np.float32)
    x = np.random.default_rng(0).standard_normal(n_bcols * bc).astype(np.float32)
    y = np.asarray(
        ops.bcsr_spmv(
            jnp.asarray(blocks), jnp.asarray(bcol),
            jnp.asarray(x.reshape(n_bcols, bc)), n_brows=n_brows, bpr=bpr,
        )
    ).reshape(-1)[:n]
    y_ref = a @ x[:m]
    np.testing.assert_allclose(y, y_ref, rtol=3e-5, atol=3e-5)
    # oracle agreement
    y_o = np.asarray(
        ref.bcsr_spmv_ref(
            jnp.asarray(blocks), jnp.asarray(bcol),
            jnp.asarray(x.reshape(n_bcols, bc)), n_brows, bpr,
        )
    )
    np.testing.assert_allclose(
        np.asarray(y).reshape(-1),
        y_o.reshape(-1)[: len(np.asarray(y).reshape(-1))],
        rtol=3e-5, atol=3e-5,
    )


@pytest.mark.parametrize("n,chunk", [(2048, 512), (8192, 1024), (1024, 1024)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fused_dots_sweep(n, chunk, dtype):
    rng = np.random.default_rng(n)
    p, w, r = (rng.standard_normal(n).astype(dtype) for _ in range(3))
    d = np.asarray(ops.fused_dots3(jnp.asarray(p), jnp.asarray(w), jnp.asarray(r), chunk=chunk))
    d_ref = np.asarray(ref.fused_dots3_ref(jnp.asarray(p), jnp.asarray(w), jnp.asarray(r)))
    tol = 1e-12 if d.dtype == np.float64 else 2e-4
    np.testing.assert_allclose(d, d_ref, rtol=tol, atol=tol * n)


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize("shape,bz", [((8, 8, 8), 4), ((12, 10, 14), 4)])
def test_jacobi_fused_kernel(stencil, shape, bz):
    rng = np.random.default_rng(7)
    x = rng.standard_normal(shape).astype(np.float32)
    b = rng.standard_normal(shape).astype(np.float32)
    dinv = (1.0 / (12.0 if stencil == "7pt" else 52.0)) * np.ones(shape, np.float32)
    y = np.asarray(
        ops.jacobi_stencil_sweep(x, b, jnp.asarray(dinv), stencil=stencil, bz=bz)
    )
    y_ref = np.asarray(
        ref.jacobi_stencil_ref(x, b, jnp.asarray(dinv), stencil=stencil)
    )
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


def test_jacobi_kernel_converges_on_poisson():
    """Fused sweeps actually smooth: residual decreases monotonically."""
    p = PoissonProblem(8, 8, 8, "7pt")
    a = poisson_scipy(p, dtype=np.float64)
    b3 = np.ones((8, 8, 8))
    dinv = np.asarray(1.0 / (a.diagonal() + (np.abs(a).sum(axis=1).A1 - np.abs(a.diagonal())))).reshape(8, 8, 8)
    x = np.zeros((8, 8, 8))
    res_prev = np.inf
    for _ in range(10):
        x = np.asarray(ops.jacobi_stencil_sweep(x, b3, jnp.asarray(dinv), stencil="7pt", bz=4))
        res = np.linalg.norm(b3.reshape(-1) - a @ x.reshape(-1))
        assert res < res_prev
        res_prev = res
