"""Communication-hiding layer: split SpMV, pipelined CG, overlap ledger.

Acceptance coverage for the overlap subsystem:

* the interior/boundary row split reproduces the unsplit (full-row ext
  block) SpMV **bitwise** on 1 and 4 shards, for the ring, stencil, and
  allgather layouts;
* the boundary-plane stencil kernel equals the corresponding planes of the
  single-call slab kernel bitwise, per backend;
* ``pipecg`` converges to the same residual as ``hs`` on the Poisson smoke
  problem (and within its 4-sweep hot-loop bound);
* the ledger region-sum invariant still holds with the ``overlap`` region
  active, and overlap strictly reduces ``totals.comm_exposed_s``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from tests.conftest import run_multidevice


# ---------------------------------------------------------------------------
# Interior/boundary split == unsplit SpMV, bitwise
# ---------------------------------------------------------------------------


def _unsplit_spmv(mesh, mat, de_full, ce_full, xp):
    """The pre-split formulation: full-row ext block, y = A_loc x + A_ext x."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.spmv import dist_specs, ell_matvec, gather_ext, local_block

    specs = dist_specs(mat)

    def fn(m, d, c, xv):
        mb = local_block(m)
        x_ext = gather_ext(mb, xv[0], "shards")
        y = ell_matvec(mb.data_loc, mb.col_loc, xv[0])
        y = y + ell_matvec(d[0], c[0], x_ext)
        return y[None]

    f = jax.jit(shard_map(
        fn, mesh=mesh,
        in_specs=(specs, P("shards", None, None), P("shards", None, None),
                  P("shards", None)),
        out_specs=P("shards", None),
    ))
    return np.asarray(f(mat, de_full, ce_full, xp))


def test_split_spmv_bitwise_single_shard(single_mesh):
    from repro.core.partition import expand_boundary, pad_vector, partition_csr
    from repro.core.spmv import make_spmv, shard_matrix, shard_vector
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(8, "7pt")
    a = poisson_scipy(p)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    assert mat.n_bnd == (0,)  # one shard: no ghost-touching rows
    x = np.random.default_rng(0).standard_normal(p.n)
    xp = shard_vector(single_mesh, pad_vector(x, mat))
    y_split = np.asarray(make_spmv(single_mesh, mat)(mat, xp))
    de, ce = expand_boundary(mat)
    y_ref = _unsplit_spmv(single_mesh, mat, jnp.asarray(de), jnp.asarray(ce), xp)
    np.testing.assert_array_equal(y_split, y_ref)


SPLIT_SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.matrices.poisson import cube, poisson_scipy
from repro.core.partition import (partition_csr, partition_stencil,
                                  pad_vector, expand_boundary)
from repro.core.spmv import (dist_specs, ell_matvec, gather_ext, local_block,
                             make_spmv, shard_matrix, shard_vector)
from repro.launch.mesh import make_solver_mesh

S = 4
p = cube(12, "7pt")
A = poisson_scipy(p)
x = np.random.default_rng(0).standard_normal(p.n)
mesh = make_solver_mesh(S)

for name, build in (("csr", lambda: partition_csr(A, S)),
                    ("stencil", lambda: partition_stencil(p, S)),
                    ("allgather",
                     lambda: partition_csr(A, S, force_allgather=True))):
    mat = shard_matrix(mesh, build())
    de, ce = expand_boundary(mat)
    de, ce = jnp.asarray(de), jnp.asarray(ce)
    xp = shard_vector(mesh, pad_vector(x, mat))
    for overlap in (True, False):
        y_split = np.asarray(make_spmv(mesh, mat, overlap=overlap)(mat, xp))
        specs = dist_specs(mat)
        def unsplit(m, d, c, xv):
            mb = local_block(m)
            x_ext = gather_ext(mb, xv[0], "shards")
            y = ell_matvec(mb.data_loc, mb.col_loc, xv[0])
            return (y + ell_matvec(d[0], c[0], x_ext))[None]
        f = jax.jit(shard_map(unsplit, mesh=mesh,
            in_specs=(specs, P("shards", None, None), P("shards", None, None),
                      P("shards", None)),
            out_specs=P("shards", None)))
        y_ref = np.asarray(f(mat, de, ce, xp))
        assert np.array_equal(y_split, y_ref), (name, overlap)
print("SPLIT_OK")
"""


def test_split_spmv_bitwise_4_shards():
    out = run_multidevice(SPLIT_SNIPPET, n_devices=4)
    assert "SPLIT_OK" in out


# ---------------------------------------------------------------------------
# Boundary-plane stencil kernel (the overlap fix-up) vs the slab kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize("shape", [(8, 6, 10), (2, 5, 9)])
def test_stencil_boundary_matches_slab_planes(stencil, shape):
    from repro.kernels import ref
    from repro.kernels.spmv_stencil import (
        pick_bz,
        stencil_spmv_boundary,
        stencil_spmv_halo,
    )

    rng = np.random.default_rng(sum(shape))
    x = rng.standard_normal(shape)
    prev = rng.standard_normal(shape[1:])
    nxt = rng.standard_normal(shape[1:])
    # interpret-mode kernel vs the full interpret-mode slab kernel: bitwise
    full_k = np.asarray(stencil_spmv_halo(
        x, prev, nxt, stencil=stencil, bz=pick_bz(shape[0]), interpret=True
    ))
    bd_k = np.asarray(stencil_spmv_boundary(
        x, prev, nxt, stencil=stencil, interpret=True
    ))
    np.testing.assert_array_equal(bd_k[0], full_k[0])
    np.testing.assert_array_equal(bd_k[1], full_k[-1])
    # jnp oracle vs the full jnp oracle: bitwise
    full_r = np.asarray(ref.stencil_halo_ref(x, prev, nxt, stencil=stencil))
    bd_r = np.asarray(ref.stencil_boundary_ref(x, prev, nxt, stencil=stencil))
    np.testing.assert_array_equal(bd_r[0], full_r[0])
    np.testing.assert_array_equal(bd_r[1], full_r[-1])


# ---------------------------------------------------------------------------
# pipecg: convergence + hot-loop sweep bound
# ---------------------------------------------------------------------------


def test_pipecg_matches_hs_residual(single_mesh):
    from repro.core.cg import solve_cg
    from repro.core.partition import partition_csr, unpad_vector
    from repro.core.spmv import shard_matrix
    from repro.matrices.poisson import cube, default_rhs, poisson_scipy

    p = cube(8, "7pt")
    a = poisson_scipy(p, dtype=np.float64)
    b = default_rhs(p.n)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    got = {}
    for variant in ("hs", "pipecg"):
        res = solve_cg(
            single_mesh, mat, b.astype(np.float32), variant=variant,
            tol=1e-6, maxiter=300,
        )
        got[variant] = res
        x = unpad_vector(np.asarray(res.x), mat)
        np.testing.assert_allclose(a @ x, b, rtol=1e-3, atol=1e-3)
    # same math, same tolerance: residuals agree (pipecg may run one extra
    # iteration — its convergence check lags the update by one reduction)
    hs, pipe = got["hs"], got["pipecg"]
    assert float(pipe.rel_residual) < 1e-5
    assert abs(int(pipe.iters) - int(hs.iters)) <= 2
    assert float(pipe.rel_residual) == pytest.approx(
        float(hs.rel_residual), rel=1.0
    )


def test_pipecg_sweep_bound():
    """pipecg: <= 4 full-vector HBM sweeps/iter outside the SpMV (the +1 vs
    hs/fcg buys the hidden all-reduce), exactly one SpMV per iteration."""
    from repro.core.stencil_solver import make_stencil_solver_fn
    from repro.kernels import dispatch as kd
    from repro.matrices.poisson import PoissonProblem
    from repro.roofline.analysis import CG_HOTPATH

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
    p = PoissonProblem(8, 8, 8, "7pt")
    vec = jax.ShapeDtypeStruct((1, p.n), "float64")
    with kd.record_sweeps() as led:
        solve = make_stencil_solver_fn(mesh, p, 1, variant="pipecg")
        solve.lower(vec, vec)
    sweeps = led.vector_sweeps("iteration")
    assert sweeps <= 4
    assert led.spmv_calls("iteration") == 1
    # the traced count is what the roofline hot-path model declares
    assert sweeps == CG_HOTPATH["pipecg"]["fused"][1]


PIPECG_MULTI_SNIPPET = r"""
import numpy as np
from repro.matrices.poisson import cube, poisson_scipy, default_rhs
from repro.core.partition import partition_stencil, unpad_vector
from repro.core.spmv import shard_matrix
from repro.core.cg import solve_cg
from repro.launch.mesh import make_solver_mesh
import scipy.sparse.linalg as spla

S = 8
p = cube(16, "7pt")
A = poisson_scipy(p)
b = default_rhs(p.n)
mesh = make_solver_mesh(S)
mat = shard_matrix(mesh, partition_stencil(p, S))
x_ref = spla.spsolve(A.tocsc(), b)
iters = {}
for variant in ("hs", "pipecg"):
    res = solve_cg(mesh, mat, b, variant=variant, tol=1e-10, maxiter=500)
    xs = unpad_vector(np.asarray(res.x), mat)
    assert np.abs(xs - x_ref).max() < 1e-6, variant
    iters[variant] = int(res.iters)
assert abs(iters["pipecg"] - iters["hs"]) <= 2, iters
print("PIPECG_MULTI_OK", iters)
"""


def test_pipecg_multidevice():
    out = run_multidevice(PIPECG_MULTI_SNIPPET, n_devices=8)
    assert "PIPECG_MULTI_OK" in out


# ---------------------------------------------------------------------------
# Ledger: overlap region active, region-sum invariant, exposed-comm ordering
# ---------------------------------------------------------------------------


def _solve_ledger(overlap: bool, *, amg: bool = False) -> dict:
    import json
    import os
    import subprocess
    import sys
    import tempfile

    from tests.conftest import REPO, SRC

    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "repro.launch.solve", "--devices", "2",
               "--problem", "poisson7", "--side", "8", "--tol", "1e-6",
               "--maxiter", "60", "--ledger", path]
        if amg:
            cmd.append("--amg")
        if not overlap:
            cmd.append("--no-overlap")
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                           env=env, cwd=REPO)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        return json.load(open(path))
    finally:
        os.unlink(path)


def test_overlap_ledger_invariants():
    led_on = _solve_ledger(overlap=True)
    led_off = _solve_ledger(overlap=False)
    on = led_on["solvers"]["BCMGX-analog"]
    off = led_off["solvers"]["BCMGX-analog"]
    # overlap region active; serialized run keeps the spmv/halo pair
    assert "overlap" in on["regions"]
    assert {"spmv", "halo"} <= set(off["regions"])
    # region-sum invariant holds with the overlap region active
    for s in (on, off):
        total = s["totals"]["de_total"]
        region_sum = sum(r["de_j"] for r in s["regions"].values())
        assert abs(region_sum - total) <= 0.01 * total
    # identical algorithm: same iterations either way
    assert on["iters"] == off["iters"]
    # the acceptance ordering: same total comm, strictly less exposed
    assert on["totals"]["comm_s"] == pytest.approx(off["totals"]["comm_s"])
    assert on["totals"]["comm_exposed_s"] < off["totals"]["comm_exposed_s"]
    assert on["totals"]["comm_hidden_s"] > 0 == off["totals"]["comm_hidden_s"]


def test_no_overlap_serializes_the_vcycle_spmvs():
    """--amg --no-overlap must serialize the preconditioner's level SpMVs
    too (the overlap_default plumbing): no overlap region anywhere, the
    halo back in its own region."""
    led = _solve_ledger(overlap=False, amg=True)
    s = led["solvers"]["BCMGX-analog"]
    assert "overlap" not in s["regions"]
    assert {"halo", "spmv", "vcycle", "reductions"} <= set(s["regions"])
    assert s["totals"]["comm_hidden_s"] == 0.0
