"""Format-polymorphic DistMat layer: ELL/HYB/BCSR/auto interiors.

Acceptance coverage for the format refactor (docs/formats.md):

* every interior format reproduces the scipy reference SpMV on 1 shard and
  agrees with the ELL path on 4 shards (overlap on and off);
* HYB stored bytes <= ELL stored bytes, strictly when ``max_row_nnz >
  2 * median`` (the padding-blowup regime);
* ``auto`` (the stored-bytes cost model) never picks a format storing more
  than ELL;
* the executed-trace SpMV traffic drops with the HYB layout — the ledger
  charges the bytes each format actually moves;
* the BCSR dispatch op agrees between the jnp reference and the Pallas
  kernel in interpret mode, including the ``n % br != 0`` guard;
* padding slots carry ``data == 0`` / ``col == 0`` under every format, for
  empty rows and non-square inputs too.
"""

import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partition import (
    BCSRBlock,
    ELLBlock,
    HYBBlock,
    partition_csr,
)
from tests.conftest import run_multidevice


def _powerlaw_csr(n: int, seed: int, hub_every: int = 11):
    """Band matrix + a few hub rows with ~n/3 nonzeros (max >> median)."""
    rng = np.random.default_rng(seed)
    band = sp.diags(
        [np.ones(n - 1), np.full(n, 4.0), np.ones(n - 1)], [-1, 0, 1]
    ).tocsr()
    rows, cols = [], []
    for h in range(0, n, hub_every):
        tgt = rng.integers(0, n, max(n // 3, 4))
        rows.append(np.full(len(tgt), h))
        cols.append(tgt)
    r, c = np.concatenate(rows), np.concatenate(cols)
    keep = r != c
    hubs = sp.coo_matrix(
        (rng.uniform(0.1, 1.0, keep.sum()), (r[keep], c[keep])), shape=(n, n)
    )
    return (band + hubs).tocsr()


# ---------------------------------------------------------------------------
# (a) every format matches the scipy reference SpMV
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(24, 96),
    seed=st.integers(0, 1000),
)
def test_formats_match_scipy_and_ell(single_mesh, n, seed):
    from repro.core.partition import pad_vector, unpad_vector
    from repro.core.spmv import make_spmv, shard_matrix, shard_vector

    a = _powerlaw_csr(n, seed)
    x = np.random.default_rng(seed).standard_normal(n)
    ys = {}
    for fmt in ("ell", "hyb", "bcsr", "auto"):
        mat = shard_matrix(single_mesh, partition_csr(a, 1, fmt=fmt))
        xp = shard_vector(single_mesh, pad_vector(x, mat))
        ys[fmt] = unpad_vector(
            np.asarray(make_spmv(single_mesh, mat)(mat, xp)), mat
        )
    # main pytest process runs without x64: device math is f32
    np.testing.assert_allclose(ys["ell"], a @ x, rtol=2e-4, atol=2e-4)
    # acceptance criterion: every format equals the ELL path within the
    # fp32 tolerance on 1 shard (the 4-shard fp64 check is below)
    scale = max(np.abs(ys["ell"]).max(), 1.0)
    for fmt in ("hyb", "bcsr", "auto"):
        np.testing.assert_allclose(
            ys[fmt], ys["ell"], rtol=1e-6, atol=1e-6 * scale
        )


# ---------------------------------------------------------------------------
# (b) HYB stored bytes <= ELL, strictly in the padding-blowup regime
# (c) auto never stores more than ELL
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(32, 128),
    seed=st.integers(0, 1000),
    n_shards=st.sampled_from([1, 2, 4]),
)
def test_hyb_and_auto_stored_bytes(n, seed, n_shards):
    a = _powerlaw_csr(n, seed)
    counts = np.diff(a.indptr)
    mats = {
        fmt: partition_csr(a, n_shards, fmt=fmt)
        for fmt in ("ell", "hyb", "auto")
    }
    e = mats["ell"].interior_stored_bytes()
    h = mats["hyb"].interior_stored_bytes()
    assert h <= e
    if counts.max() > 2 * np.median(counts):
        assert h < e  # strict: the long rows no longer pad every row
    assert mats["auto"].interior_stored_bytes() <= e
    # the boundary block is format-agnostic: identical across formats
    for m in mats.values():
        np.testing.assert_array_equal(
            np.asarray(m.data_ext), np.asarray(mats["ell"].data_ext)
        )


# ---------------------------------------------------------------------------
# executed trace: the ledger charges what each format actually moves
# ---------------------------------------------------------------------------


def test_trace_spmv_bytes_drop_with_hyb(single_mesh):
    from repro.core.partition import pad_vector
    from repro.core.spmv import make_spmv, shard_matrix, shard_vector
    from repro.energy import trace

    a = _powerlaw_csr(96, seed=3)
    xg = np.random.default_rng(0).standard_normal(96)
    hbm = {}
    for fmt in ("ell", "hyb"):
        mat = shard_matrix(single_mesh, partition_csr(a, 1, fmt=fmt))
        x = shard_vector(single_mesh, pad_vector(xg, mat))
        fn = make_spmv(single_mesh, mat)
        with trace.capture() as tr:
            fn(mat, x)  # compile under the trace: executed counts recorded
        hbm[fmt] = tr.total().hbm_bytes
    assert hbm["hyb"] < hbm["ell"]
    # the byte gap matches the stored-bytes gap of the layouts (value+index
    # traffic; the vector terms are identical)
    e = partition_csr(a, 1, fmt="ell")
    h = partition_csr(a, 1, fmt="hyb")
    # f32 in-process arrays: 4 B values + 4 B indices
    gap_stored = e.interior_stored_bytes(4) - h.interior_stored_bytes(4)
    assert hbm["ell"] - hbm["hyb"] == pytest.approx(gap_stored)


# ---------------------------------------------------------------------------
# 4 shards: all formats agree with the ELL path, overlap on and off
# ---------------------------------------------------------------------------


FORMATS_MULTI_SNIPPET = r"""
import numpy as np
import scipy.sparse as sp
from repro.core.partition import partition_csr, pad_vector, unpad_vector
from repro.core.spmv import make_spmv, shard_matrix, shard_vector
from repro.launch.mesh import make_solver_mesh

rng = np.random.default_rng(7)
n = 160
band = sp.diags([np.ones(n-1), np.full(n, 4.0), np.ones(n-1)], [-1, 0, 1]).tocsr()
rows, cols = [], []
for h in range(0, n, 13):
    tgt = rng.integers(0, n, 50)
    rows.append(np.full(len(tgt), h)); cols.append(tgt)
r, c = np.concatenate(rows), np.concatenate(cols)
keep = r != c
A = (band + sp.coo_matrix((rng.uniform(0.1, 1.0, keep.sum()),
                           (r[keep], c[keep])), shape=(n, n))).tocsr()
mesh = make_solver_mesh(4)
x = rng.standard_normal(n)
ys = {}
for fmt in ("ell", "hyb", "bcsr", "auto"):
    for overlap in (True, False):
        mat = shard_matrix(mesh, partition_csr(A, 4, fmt=fmt))
        xp = shard_vector(mesh, pad_vector(x, mat))
        y = unpad_vector(np.asarray(make_spmv(mesh, mat, overlap=overlap)(mat, xp)), mat)
        ys[(fmt, overlap)] = y
ref = ys[("ell", True)]
assert np.abs(ref - A @ x).max() < 1e-10
for k, y in ys.items():
    assert np.abs(y - ref).max() < 1e-12, k
print("FORMATS_MULTI_OK")
"""


def test_formats_agree_4_shards():
    out = run_multidevice(FORMATS_MULTI_SNIPPET, n_devices=4)
    assert "FORMATS_MULTI_OK" in out


# ---------------------------------------------------------------------------
# BCSR dispatch op: jnp reference == Pallas interpret, n % br guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [10, 16, 23])
def test_ops_bcsr_spmv_ragged_guard(n):
    """Flat vectors with n % br != 0 pad the trailing block-row instead of
    crashing, through both the ops wrapper and the dispatch OpSet."""
    from repro.core.sparse import pack_bcsr
    from repro.kernels import dispatch as kd
    from repro.kernels import ops

    a = sp.random(n, n, density=0.35, format="csr", random_state=n)
    a.setdiag(2.0)
    blocks, bcol, n_brows, bpr, _ = pack_bcsr(a.tocsr(), 4, 4, np.float32)
    x = np.random.default_rng(n).standard_normal(n).astype(np.float32)
    y_ref = a @ x
    y_ops = np.asarray(
        ops.bcsr_spmv(blocks, bcol, x, n_brows=n_brows, bpr=bpr,
                      interpret=True)
    )
    assert y_ops.shape == (n,)
    np.testing.assert_allclose(y_ops, y_ref, rtol=2e-5, atol=2e-5)
    for backend in ("jnp", "interpret"):
        y = np.asarray(
            kd.OpSet(backend).bcsr_spmv(
                blocks, bcol, x, n_brows=n_brows, bpr=bpr
            )
        )
        np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


def test_ops_bcsr_spmv_rejects_mispacked_blocks():
    from repro.kernels import ops

    blocks = np.zeros((6, 4, 4), np.float32)
    with pytest.raises(ValueError, match="n_brows"):
        ops.bcsr_spmv(blocks, np.zeros(6, np.int32), np.zeros(8, np.float32),
                      n_brows=4, bpr=2)


# ---------------------------------------------------------------------------
# padding invariants: data == 0, col == 0 under every format
# ---------------------------------------------------------------------------


def _empty_row_nonsquare():
    """4x7-in-5 shards worth of pathology: empty rows, non-square pattern
    embedded in a square operator (partition_csr requires square)."""
    n = 20
    rows = np.array([1, 1, 5, 9, 9, 9, 14])
    cols = np.array([0, 6, 5, 2, 9, 17, 3])
    vals = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0])
    return sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()


def test_padding_invariants_every_format():
    a = _empty_row_nonsquare()
    for fmt in ("ell", "hyb", "bcsr"):
        mat = partition_csr(a, 2, fmt=fmt)
        intr = mat.interior
        if isinstance(intr, ELLBlock):
            d, c = np.asarray(intr.data), np.asarray(intr.col)
            assert ((d != 0) | (c == 0)).all()  # col set only on entries
            assert np.count_nonzero(d) <= a.nnz
        elif isinstance(intr, HYBBlock):
            d, c = np.asarray(intr.data), np.asarray(intr.col)
            assert ((d != 0) | (c == 0)).all()
            td = np.asarray(intr.tail_data)
            tc = np.asarray(intr.tail_col)
            trw = np.asarray(intr.tail_row)
            assert ((td != 0) | ((tc == 0) & (trw == 0))).all()
            for s, nt in enumerate(intr.n_tail):
                assert (td[s, nt:] == 0).all()
        elif isinstance(intr, BCSRBlock):
            bl = np.asarray(intr.blocks)
            bcl = np.asarray(intr.bcol)
            # padding blocks are entirely zero with bcol == 0
            zero_blocks = ~bl.any(axis=(2, 3))
            assert (bcl[zero_blocks] == 0).all()
        # format-agnostic boundary block: padding rows zero everywhere
        de = np.asarray(mat.data_ext)
        ce = np.asarray(mat.col_ext)
        for s, nb in enumerate(mat.n_bnd):
            assert (de[s, nb:] == 0).all() and (ce[s, nb:] == 0).all()


def test_csr_pad_capacity_raises_like_ell():
    """csr_from_scipy used to silently ignore pad_nnz_to < nnz while
    ell_from_scipy raised for the equivalent k — both raise now."""
    from repro.core.sparse import csr_from_scipy, ell_from_scipy

    a = _empty_row_nonsquare()
    with pytest.raises(ValueError):
        csr_from_scipy(a, pad_nnz_to=a.nnz - 1)
    with pytest.raises(ValueError):
        ell_from_scipy(a, k=1)
    # empty rows / trailing empty rows survive the round trip in both
    x = np.random.default_rng(0).standard_normal(a.shape[1]).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(csr_from_scipy(a).matvec(x)), a @ x, rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(ell_from_scipy(a).matvec(x)), a @ x, rtol=1e-5, atol=1e-5
    )


def test_pack_bcsr_matches_ragged_bcsr():
    """The unified block packer: the kernel's uniform layout and the ragged
    BCSR device format describe the same blocks."""
    from repro.core.sparse import bcsr_from_scipy, pack_bcsr

    a = sp.random(30, 30, density=0.2, format="csr", random_state=5)
    ragged = bcsr_from_scipy(a, br=4, bc=4, dtype=np.float32)
    blocks, bcol, n_brows, bpr, n_bcols = pack_bcsr(a, 4, 4, np.float32)
    assert n_brows == ragged.n_brows and n_bcols == ragged.n_bcols
    # every ragged block appears at its (row, slot) position in the uniform
    # layout, in the same (sorted) column order
    rb = np.asarray(ragged.blocks)
    rbc = np.asarray(ragged.bcol)
    rbr = np.asarray(ragged.brow_ids)
    pos = np.zeros(n_brows, np.int64)
    for i in range(len(rbr)):
        dst = rbr[i] * bpr + pos[rbr[i]]
        np.testing.assert_array_equal(blocks[dst], rb[i])
        assert bcol[dst] == rbc[i]
        pos[rbr[i]] += 1
