"""Region trace + executed-energy ledger invariants.

Regions nest (innermost attribution), dispatched ops record their OpCounts
into the active region, the executed AMG V-cycle PCG converges, and the
per-region energies integrated from the trace sum to the PowerMonitor
total — the acceptance invariant CI's energy-ledger job gates.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.energy import trace
from repro.energy.accounting import OpCounts
from repro.kernels import dispatch as kd


# ---------------------------------------------------------------------------
# Region stack semantics
# ---------------------------------------------------------------------------


def test_regions_nest_innermost_wins():
    with trace.capture() as tr:
        with trace.region("outer"):
            trace.record_op("a", OpCounts(flops=1.0))
            with trace.region("inner"):
                trace.record_op("b", OpCounts(flops=10.0))
                assert trace.current_region() == "inner"
            trace.record_op("c", OpCounts(flops=100.0))
            assert trace.current_region() == "outer"
    regs = tr.regions(trace.SETUP)
    assert regs["outer"].flops == 101.0
    assert regs["inner"].flops == 10.0
    assert tr.total().flops == 111.0


def test_no_active_trace_is_noop():
    trace.record_op("x", OpCounts(flops=1.0))  # must not raise
    with trace.capture() as tr:
        pass
    assert tr.empty


def test_default_region_and_sections():
    with trace.capture() as tr:
        trace.record_op("a", OpCounts(hbm_bytes=8.0))
        with trace.section("iteration"):
            trace.record_op("b", OpCounts(hbm_bytes=16.0))
            trace.record_op("b", OpCounts(hbm_bytes=16.0))
    assert tr.regions("setup")["other"].hbm_bytes == 8.0
    assert tr.regions("iteration")["other"].hbm_bytes == 32.0
    # entries normalization: two entries of the same section halve the counts
    with trace.capture() as tr2:
        for _ in range(2):
            with trace.section("iteration"):
                trace.record_op("b", OpCounts(hbm_bytes=16.0))
    assert tr2.regions("iteration")["other"].hbm_bytes == 16.0


def test_repeated_scales_scan_bodies():
    """Bodies traced once but executed k times (lax.scan) scale their
    recorded counts by k — the s-step basis build relies on this."""
    with trace.capture() as tr:
        with trace.repeated(3):
            trace.record_op("a", OpCounts(flops=2.0, hbm_bytes=8.0))
            with trace.repeated(2):  # nesting multiplies
                trace.record_op("b", OpCounts(flops=1.0))
        trace.record_op("c", OpCounts(flops=1.0))
    t = tr.total()
    assert t.flops == 3 * 2.0 + 6 * 1.0 + 1.0
    assert t.hbm_bytes == 3 * 8.0


def test_capture_restores_previous_trace():
    with trace.capture() as outer:
        trace.record_op("a", OpCounts(flops=1.0))
        with trace.capture() as inner:
            trace.record_op("b", OpCounts(flops=2.0))
        trace.record_op("c", OpCounts(flops=4.0))
    assert inner.total().flops == 2.0
    assert outer.total().flops == 5.0  # a + c, not b


# ---------------------------------------------------------------------------
# Dispatch ops record executed counts into the innermost region
# ---------------------------------------------------------------------------


def test_dispatch_ops_record_counts():
    ops = kd.ops_for("jnp")
    n = 1000
    x = jnp.ones((n,), jnp.float32)
    with trace.capture() as tr:
        with trace.region("reductions"):
            ops.axpy(1.0, x, x)
            ops.fused_dots_n([(x, x)])
    c = tr.regions(trace.SETUP)["reductions"]
    # axpy: 2n flops, 3n*4B; fused dot over the aliased pair: 2n flops, n*4B
    assert c.flops == 4 * n
    assert c.hbm_bytes == 3 * n * 4 + n * 4
    calls = tr.calls(trace.SETUP)["reductions"]
    assert calls["axpy"] == 1 and calls["fused_dots_n"] == 1


def test_ledger_section_switches_trace_section():
    ops = kd.ops_for("jnp")
    x = jnp.ones((64,), jnp.float32)
    with trace.capture() as tr:
        with kd.ledger_section("iteration"):
            with trace.region("reductions"):
                ops.axpy(1.0, x, x)
    assert "reductions" in tr.regions("iteration")
    assert tr.regions("setup") == {}


def _traced_amg_solve(single_mesh):
    """Trace an executed AMG-PCG solve; returns (trace, iters, rel_residual)."""
    from repro.core.amg import make_amg_preconditioner
    from repro.core.cg import make_solver
    from repro.core.partition import pad_vector, partition_csr
    from repro.core.spmv import shard_matrix, shard_vector
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(8, "7pt")
    a = poisson_scipy(p)
    pre, info = make_amg_preconditioner(a, 1)
    assert info.n_levels >= 2
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    b = pad_vector(np.ones(p.n), mat)
    bp = shard_vector(single_mesh, b)
    x0 = shard_vector(single_mesh, np.zeros_like(b))
    with trace.capture() as tr:
        solver = make_solver(single_mesh, mat, precond=pre, tol=1e-8,
                             maxiter=100)
        res = solver(bp, x0)
    return tr, int(res.iters), float(res.rel_residual)


def test_spmv_and_halo_attribution(single_mesh):
    """ell_matvec counts land in the caller's region; a traced solve
    attributes spmv / reductions / vcycle to their own regions."""
    tr, iters, relres = _traced_amg_solve(single_mesh)
    # executed V-cycle PCG converges fast on Poisson
    assert relres < 1e-8
    assert iters < 20
    it = tr.regions(trace.ITERATION)
    assert {"spmv", "reductions", "vcycle"} <= set(it)
    # the V-cycle does far more work per iteration than the single SpMV
    assert it["vcycle"].hbm_bytes > it["spmv"].hbm_bytes
    # reductions carry the iteration's collectives (2 all-reduces for hs)
    assert it["reductions"].n_collectives >= 2


# ---------------------------------------------------------------------------
# Ledger: per-region energies sum to the monitor total
# ---------------------------------------------------------------------------


def test_ledger_regions_sum_to_monitor_total(single_mesh):
    tr, iters, _ = _traced_amg_solve(single_mesh)
    led = trace.ledger_from_trace(tr, iters=iters, n_shards=1, idle_s=0.01)
    total = led["totals"]["de_total"]
    region_sum = sum(r["de_j"] for r in led["regions"].values())
    assert total > 0
    assert abs(region_sum - total) <= 0.01 * total  # acceptance: within 1%
    # idle padding is kept out of the per-region ledger (zero counts/DE);
    # regions + the two idle pads partition the monitored runtime
    assert "idle" not in led["regions"]
    t = sum(r["time_s"] for r in led["regions"].values())
    assert t + 2 * 0.01 == pytest.approx(led["totals"]["runtime"])
    # energy_by_region is consistent with the totals on the te side too
    mon = trace.monitor_from_trace(tr, iters=iters, n_shards=1)
    by = mon.energy_by_region()
    assert sum(r["te_gpu_j"] for r in by.values()) == pytest.approx(
        mon.energy()["te_gpu"]
    )


def test_executed_vcycle_pcg_multidevice_ledger():
    """End-to-end: launch.solve --amg on 2 devices writes a ledger whose
    executed regions include the overlapped SpMV+halo phase and sum to the
    monitor total."""
    import json
    import os
    import subprocess
    import sys
    import tempfile

    from tests.conftest import REPO, SRC

    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.solve", "--devices", "2",
             "--problem", "poisson7", "--side", "8", "--amg",
             "--tol", "1e-6", "--maxiter", "50", "--ledger", path],
            capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
        )
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        led = json.load(open(path))
    finally:
        os.unlink(path)
    s = led["solvers"]["BCMGX-analog"]
    assert s["iters"] > 0
    regions = s["regions"]
    # communication hiding is on by default: every SpMV + its in-flight halo
    # merges into the "overlap" region (no separate spmv/halo regions)
    assert {"overlap", "reductions", "vcycle"} <= set(regions)
    assert "halo" not in regions and "spmv" not in regions
    total = s["totals"]["de_total"]
    region_sum = sum(r["de_j"] for r in regions.values())
    assert abs(region_sum - total) <= 0.01 * total
    # the level SpMVs (smoothing sweeps) dominate the cycle's compute
    assert regions["overlap"]["flops"] > regions["reductions"]["flops"]
    # the overlap region carries the halo traffic, part of it hidden
    assert regions["overlap"]["ici_bytes"] > 0
    assert s["totals"]["comm_hidden_s"] > 0
    assert regions["overlap"]["comm_exposed_s"] < regions["overlap"]["comm_s"]


def test_identity_precond_traces_no_vcycle(single_mesh):
    from repro.core.cg import make_solver
    from repro.core.partition import pad_vector, partition_csr
    from repro.core.spmv import shard_matrix, shard_vector
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(6, "7pt")
    a = poisson_scipy(p)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    b = pad_vector(np.ones(p.n), mat)
    with trace.capture() as tr:
        solver = make_solver(single_mesh, mat, tol=1e-8, maxiter=200)
        res = solver(shard_vector(single_mesh, b),
                     shard_vector(single_mesh, np.zeros_like(b)))
    jax.block_until_ready(res.x)
    it = tr.regions(trace.ITERATION)
    assert "vcycle" not in it and "precond" not in it
    assert {"spmv", "reductions"} <= set(it)
