"""Multi-RHS SpMM + block-CG (PR 6).

Three layers, mirroring how the batched path is built:

* SpMM interiors — the distributed SpMV applied to an (n, r) block equals
  the per-column SpMV for every interior format, on both kernel backends;
* block kernels — block_gram / block_update / block_update2 against their
  dense oracles (including the order-sensitive Gram dedup and the
  deflation mask), and the 1-D-only guards on the scalar fused family;
* block-CG — solutions agree with per-column single-RHS ``hs`` solves:
  at f32 tolerances in-process, and to <= 1e-10 relative error on 1 and 4
  shards in the x64 subprocess, overlap on and off; converged columns
  deflate (a zero RHS column is a breakdown for unguarded block-CG and
  must converge at iteration 0 here).

NOTE: the main pytest process runs WITHOUT x64 (dry-run/smoke parity), so
device math is f32 even for f64 inputs; the tight f64 agreement checks
live in the ``run_multidevice`` subprocesses (JAX_ENABLE_X64=1 there).
"""

import numpy as np
import pytest

from tests.conftest import run_multidevice


def _poisson(side, stencil="7pt"):
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(side, stencil)
    return poisson_scipy(p, dtype=np.float64)


def _block(n, r, seed=0):
    return np.random.default_rng(seed).standard_normal((n, r))


# ---------------------------------------------------------------------------
# SpMM interiors: (n, r) block through the distributed SpMV == per-column
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["ell", "hyb", "bcsr"])
@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_spmm_matches_per_column(single_mesh, fmt, backend):
    from repro.core.partition import pad_block, partition_csr, unpad_block
    from repro.core.spmv import make_spmv, shard_matrix, shard_vector
    from repro.kernels import dispatch as kd

    a = _poisson(6)
    x = _block(a.shape[0], 5)
    mat = shard_matrix(single_mesh, partition_csr(a, 1, fmt=fmt))
    with kd.use_backend(backend):
        spmv = make_spmv(single_mesh, mat)
        xp = shard_vector(single_mesh, pad_block(x, mat))
        y = unpad_block(np.asarray(spmv(mat, xp)), mat)
    np.testing.assert_allclose(y, a @ x, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("overlap", [True, False])
def test_spmm_multishard_overlap(overlap):
    out = run_multidevice(
        f"""
import numpy as np
from jax.sharding import Mesh
import jax
from repro.matrices.poisson import cube, poisson_scipy
from repro.core.partition import pad_block, partition_csr, unpad_block
from repro.core.spmv import make_spmv, shard_matrix, shard_vector

mesh = Mesh(np.array(jax.devices()[:4]), ("shards",))
p = cube(8, "27pt")
a = poisson_scipy(p, dtype=np.float64)
x = np.random.default_rng(1).standard_normal((p.n, 3))
for fmt in ("ell", "hyb", "bcsr"):
    mat = shard_matrix(mesh, partition_csr(a, 4, fmt=fmt))
    spmv = make_spmv(mesh, mat, overlap={overlap})
    xp = shard_vector(mesh, pad_block(x, mat))
    y = unpad_block(np.asarray(spmv(mat, xp)), mat)
    np.testing.assert_allclose(y, a @ x, rtol=1e-12, atol=1e-12)
print("SPMM_OK")
""",
        n_devices=4,
    )
    assert "SPMM_OK" in out


# ---------------------------------------------------------------------------
# Block kernels vs oracles
# ---------------------------------------------------------------------------


def test_block_gram_matches_oracle_and_order():
    import jax.numpy as jnp

    from repro.kernels.fused_reductions import block_gram
    from repro.kernels.ref import block_gram_ref

    x = jnp.asarray(_block(137, 4, 1))
    y = jnp.asarray(_block(137, 4, 2))
    # XtY != YtX: the order-sensitive dedup must keep both directions
    got = block_gram([(x, y), (y, x), (x, x)], chunk=64, interpret=True)
    ref = block_gram_ref([(np.asarray(x), np.asarray(y)),
                          (np.asarray(y), np.asarray(x)),
                          (np.asarray(x), np.asarray(x))])
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), r, rtol=1e-4, atol=1e-4)
    assert not np.allclose(np.asarray(got[0]), np.asarray(got[1]))
    np.testing.assert_allclose(
        np.asarray(got[0]), np.asarray(got[1]).T, rtol=1e-4, atol=1e-4
    )


def test_block_update_mask_freezes_columns():
    import jax.numpy as jnp

    from repro.kernels.fused_reductions import block_update
    from repro.kernels.ref import block_update_ref

    n, r = 97, 3
    m = jnp.asarray(_block(r, r, 3))
    x = jnp.asarray(_block(n, r, 4))
    y = jnp.asarray(_block(n, r, 5))
    mask = jnp.asarray([1.0, 0.0, 1.0])
    got = np.asarray(block_update(m, x, y, mask=mask, chunk=32,
                                  interpret=True))
    ref = block_update_ref(np.asarray(m), np.asarray(x), np.asarray(y),
                           mask=np.asarray(mask))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    # the masked column carries no y contribution, only the x @ m term
    np.testing.assert_allclose(
        got[:, 1], (np.asarray(x) @ np.asarray(m))[:, 1], rtol=1e-5,
        atol=1e-5,
    )


def test_block_update2_matches_oracle():
    import jax.numpy as jnp

    from repro.kernels.fused_reductions import block_update2
    from repro.kernels.ref import block_update2_ref

    n, r = 130, 4
    a1, a2 = jnp.asarray(_block(r, r, 6)), jnp.asarray(_block(r, r, 7))
    x1, y1 = jnp.asarray(_block(n, r, 8)), jnp.asarray(_block(n, r, 9))
    x2, y2 = jnp.asarray(_block(n, r, 10)), jnp.asarray(_block(n, r, 11))
    o1, o2 = block_update2(a1, x1, y1, a2, x2, y2, chunk=64, interpret=True)
    r1, r2 = block_update2_ref(
        np.asarray(a1), np.asarray(x1), np.asarray(y1),
        np.asarray(a2), np.asarray(x2), np.asarray(y2),
    )
    np.testing.assert_allclose(np.asarray(o1), r1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o2), r2, rtol=1e-5, atol=1e-5)


def test_scalar_fused_family_rejects_blocks():
    """The 1-D fused family must refuse (n, r) operands by name, pointing
    at the block kernels — silently flattening would corrupt the solve."""
    import jax.numpy as jnp

    from repro.kernels import dispatch as kd
    from repro.kernels.fused_reductions import (
        fused_axpy,
        fused_axpy2,
        fused_axpy2_dots,
        fused_dots_n,
    )

    x2 = jnp.asarray(_block(50, 2))
    x1 = jnp.asarray(np.ones(50))
    a = jnp.asarray(0.5)
    with pytest.raises(ValueError, match="block"):
        fused_dots_n([(x2, x2)])
    with pytest.raises(ValueError, match="block"):
        fused_axpy(a, x2, x2)
    with pytest.raises(ValueError, match="block"):
        fused_axpy2(a, x2, x2, a, x1, x1)
    with pytest.raises(ValueError, match="block"):
        fused_axpy2_dots(a, x1, x1, a, x2, x2)
    ops = kd.ops_for("jnp")
    with pytest.raises(ValueError, match="block"):
        ops.fused_dots_n([(x2, x2)])
    with pytest.raises(ValueError, match="block"):
        ops.axpy(a, x2, x2)
    with pytest.raises(ValueError, match="block"):
        ops.fused_axpy2(a, x2, x2, a, x1, x1)
    with pytest.raises(ValueError, match="block"):
        ops.fused_axpy2_dots(a, x2, x2, a, x2, x2)


# ---------------------------------------------------------------------------
# Block-CG vs per-column single-RHS solves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("overlap", [True, False])
@pytest.mark.parametrize("fmt", ["ell", "bcsr"])
def test_block_cg_matches_per_column(single_mesh, fmt, overlap):
    from repro.core.cg import default_rhs_block, solve_block_cg, solve_cg
    from repro.core.partition import partition_csr, unpad_block, unpad_vector
    from repro.core.spmv import shard_matrix

    a = _poisson(8)
    nrhs = 4
    B = default_rhs_block(a.shape[0], nrhs)
    B[:, 3] = B[:, 1]  # duplicate column: the ridge guard's breakdown case
    mat = shard_matrix(single_mesh, partition_csr(a, 1, fmt=fmt))
    res = solve_block_cg(
        single_mesh, mat, B, tol=1e-5, maxiter=400, overlap=overlap
    )
    X = unpad_block(np.asarray(res.x), mat)
    assert np.asarray(res.rel_residual).shape == (nrhs,)
    for j in range(nrhs):
        r1 = solve_cg(
            single_mesh, mat, B[:, j], variant="hs", tol=1e-5,
            maxiter=400, overlap=overlap,
        )
        x1 = unpad_vector(np.asarray(r1.x), mat)
        err = np.linalg.norm(X[:, j] - x1) / np.linalg.norm(x1)
        # f32 in-process: both solves stop at relres 1e-5, so they agree
        # to ~cond(A)*tol; the <=1e-10 f64 check is the subprocess test
        assert err <= 1e-3, (fmt, overlap, j, err)
    # duplicated columns produced identical solutions (identical inputs
    # walk identical recurrences — the ridge keeps the Grams nonsingular)
    np.testing.assert_allclose(X[:, 3], X[:, 1], rtol=1e-12, atol=1e-12)


def test_block_cg_multishard_matches_per_column():
    out = run_multidevice(
        """
import numpy as np
from jax.sharding import Mesh
import jax
from repro.matrices.poisson import cube, poisson_scipy
from repro.core.partition import partition_csr, unpad_block, unpad_vector
from repro.core.spmv import shard_matrix
from repro.core.cg import default_rhs_block, solve_block_cg, solve_cg

p = cube(10, "7pt")
a = poisson_scipy(p, dtype=np.float64)
B = default_rhs_block(p.n, 4)
for shards in (1, 4):
    mesh = Mesh(np.array(jax.devices()[:shards]), ("shards",))
    mat = shard_matrix(mesh, partition_csr(a, shards))
    for overlap in (True, False):
        res = solve_block_cg(mesh, mat, B, tol=1e-10, maxiter=400,
                             overlap=overlap)
        X = unpad_block(np.asarray(res.x), mat)
        for j in range(4):
            r1 = solve_cg(mesh, mat, B[:, j], variant="hs", tol=1e-10,
                          maxiter=400, overlap=overlap)
            x1 = unpad_vector(np.asarray(r1.x), mat)
            err = np.linalg.norm(X[:, j] - x1) / np.linalg.norm(x1)
            assert err <= 1e-10, (shards, overlap, j, err)
print("BLOCKCG_OK")
""",
        n_devices=4,
    )
    assert "BLOCKCG_OK" in out


def test_block_cg_deflates_converged_columns(single_mesh):
    """A zero RHS column is converged at iteration 0 — unguarded block-CG
    would divide by a singular Gram; the deflation mask must freeze it."""
    from repro.core.cg import default_rhs_block, solve_block_cg
    from repro.core.partition import partition_csr, unpad_block
    from repro.core.spmv import shard_matrix

    a = _poisson(6)
    B = default_rhs_block(a.shape[0], 3)
    B[:, 1] = 0.0
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    res = solve_block_cg(single_mesh, mat, B, tol=1e-5, maxiter=300)
    X = unpad_block(np.asarray(res.x), mat)
    iters_cols = np.asarray(res.iters_cols)
    assert iters_cols[1] == 0  # deflated immediately
    np.testing.assert_allclose(X[:, 1], 0.0, atol=1e-14)  # frozen at x0
    # the live columns still converged normally
    assert (iters_cols[[0, 2]] > 0).all()
    assert int(res.iters) == iters_cols.max()
    rel = np.asarray(res.rel_residual)
    assert (rel[[0, 2]] <= 1e-5 * 1.01).all()


def test_block_cg_rejects_non_identity_precond(single_mesh):
    from repro.core.cg import Preconditioner, make_block_solver
    from repro.core.partition import partition_csr
    from repro.core.spmv import shard_matrix

    a = _poisson(6)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    pre = Preconditioner(
        data=(), specs=(), apply=lambda d, r, axis: r,
        localize=None, is_identity=False,
    )
    with pytest.raises(ValueError, match="identity"):
        make_block_solver(single_mesh, mat, precond=pre)
