"""REQUIRED per-arch smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + no NaNs (deliverable f)."""

import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.configs.base import ShapeConfig
from repro.models import lm, transformer as tfm

TRAIN = ShapeConfig("t", 64, 2, "train")
PREFILL = ShapeConfig("p", 64, 2, "prefill")
DECODE = ShapeConfig("d", 64, 2, "decode")


def _smoke(name):
    return dataclasses.replace(get_config(name).smoke(), dtype="float32")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_shapes_and_finite(name):
    cfg = _smoke(name)
    params = tfm.init_params(cfg, jax.random.key(0))
    batch = lm.make_inputs(cfg, TRAIN)["batch"]
    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch, kv_chunk=32)
    )(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()
    # hidden shape check
    hidden, _, _ = tfm.forward_full(params, cfg, batch, kv_chunk=32, remat=False)
    assert hidden.shape == (2, 64, cfg.d_model)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_and_decode_shapes(name):
    cfg = _smoke(name)
    params = tfm.init_params(cfg, jax.random.key(1))
    batch = lm.make_inputs(cfg, PREFILL)["batch"]
    logits, cache = lm.prefill(params, cfg, batch, kv_chunk=32)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.is_encoder_only:
        return  # no decode for encoders
    dec = lm.make_inputs(cfg, DECODE)
    logits, cache2 = lm.serve_step(params, cfg, dec["token"], dec["cache"], dec["pos"])
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits)).all()
    # cache tree structure preserved
    assert jax.tree.structure(cache2) == jax.tree.structure(dec["cache"])


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_full_config_param_count_scale(name):
    """Full (non-smoke) config param count is in the family's expected range."""
    cfg = get_config(name)
    n = cfg.param_count()
    expected = {
        "xlstm-350m": (0.2e9, 0.6e9),
        "qwen2.5-3b": (2.5e9, 4.5e9),
        "qwen3-8b": (7e9, 10e9),
        "minicpm3-4b": (3e9, 5.5e9),
        "gemma-7b": (7e9, 10e9),
        "zamba2-7b": (5.5e9, 9e9),
        "hubert-xlarge": (0.8e9, 1.6e9),
        "arctic-480b": (400e9, 560e9),
        "moonshot-v1-16b-a3b": (14e9, 32e9),
        "llava-next-34b": (30e9, 40e9),
    }[name]
    assert expected[0] <= n <= expected[1], f"{name}: {n/1e9:.2f}B"


def test_shape_applicability_matrix():
    """40 cells: the skip pattern matches the assignment rules."""
    total = skipped = 0
    for name, cfg in ARCHS.items():
        app = applicable_shapes(cfg)
        assert set(app) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
        total += 4
        skipped += sum(1 for v in app.values() if v is None)
        if cfg.is_encoder_only:
            assert app["decode_32k"] is None and app["long_500k"] is None
        if cfg.family in ("ssm", "hybrid"):
            assert app["long_500k"] is not None
        if name in ("qwen2.5-3b", "qwen3-8b", "gemma-7b", "minicpm3-4b",
                    "arctic-480b", "moonshot-v1-16b-a3b", "llava-next-34b"):
            assert app["long_500k"] is None
    assert total == 40


def test_moe_load_balance_loss_positive():
    cfg = _smoke("arctic-480b")
    params = tfm.init_params(cfg, jax.random.key(0))
    batch = lm.make_inputs(cfg, TRAIN)["batch"]
    _, _, aux = tfm.forward_full(params, cfg, batch, kv_chunk=32, remat=False)
    assert float(aux) > 0.5  # ~1.0 for balanced routing


def test_mla_cache_is_compressed():
    """MiniCPM3's decode cache stores the latent, not full K/V."""
    from repro.models.kvcache import cache_shapes

    cfg = get_config("minicpm3-4b")
    tree = cache_shapes(cfg, batch=1, max_len=1024)
    leaves = jax.tree.leaves(tree)
    total = sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves)
    # full GQA cache would be L * S * 2 * h * hd * 2B
    full = cfg.n_layers * 1024 * 2 * cfg.n_heads * cfg.hd * 2
    assert total < full / 8, (total, full)
