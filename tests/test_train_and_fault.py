"""Training loop, optimizer, data pipeline, checkpoint/restart, fault
tolerance, gradient compression."""

import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.synthetic import TokenStream
from repro.models import transformer as tfm
from repro.train.loop import make_train_step
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def _tiny():
    return dataclasses.replace(get_config("qwen2.5-3b").smoke(), dtype="float32")


def test_loss_decreases():
    cfg = _tiny()
    opt_cfg = OptConfig(lr=1e-2, warmup_steps=5)
    params = tfm.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, kv_chunk=32))
    stream = TokenStream(cfg.vocab_size, 32, 4, seed=7)
    losses = []
    batch = stream.batch_at(0)  # overfit one batch -> must decrease
    for i in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert int(m["skipped"]) == 0


def test_microbatch_equivalence():
    cfg = _tiny()
    opt_cfg = OptConfig(lr=0.0, weight_decay=0.0)
    params = tfm.init_params(cfg, jax.random.key(0))
    stream = TokenStream(cfg.vocab_size, 32, 8, seed=3)
    batch = stream.batch_at(0)
    s1 = make_train_step(cfg, opt_cfg, microbatches=1, kv_chunk=32)
    s4 = make_train_step(cfg, opt_cfg, microbatches=4, kv_chunk=32)
    o1 = init_opt_state(params, opt_cfg)
    o4 = init_opt_state(params, opt_cfg)
    _, _, m1 = jax.jit(s1)(params, o1, batch)
    _, _, m4 = jax.jit(s4)(params, o4, batch)
    assert np.isclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    assert np.isclose(float(m1["grad_norm"]), float(m4["grad_norm"]), rtol=1e-3)


def test_nan_guard_skips_bad_step():
    cfg = _tiny()
    opt_cfg = OptConfig(lr=1e-3)
    params = tfm.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, kv_chunk=32))
    stream = TokenStream(cfg.vocab_size, 32, 4)
    good = stream.batch_at(0)
    p1, o1, m1 = step(params, opt, good)
    # poison the params so the loss goes NaN
    bad_params = jax.tree.map(lambda x: x * jnp.nan, params)
    p2, o2, m2 = step(bad_params, o1, good)
    assert not np.isfinite(float(m2["loss"]))
    assert int(o2["skipped"]) == 1
    # params passed through unchanged (still NaN inputs, not updated)
    leaf_in = jax.tree.leaves(bad_params)[0]
    leaf_out = jax.tree.leaves(p2)[0]
    assert np.array_equal(
        np.isnan(np.asarray(leaf_in)), np.isnan(np.asarray(leaf_out))
    )


def test_adamw_moment_dtype_bf16():
    cfg = _tiny()
    opt_cfg = OptConfig(moment_dtype="bfloat16")
    params = tfm.init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params, opt_cfg)
    assert jax.tree.leaves(opt["mu"])[0].dtype == jnp.bfloat16
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 0.01, params)
    p2, o2, gn = adamw_update(grads, opt, params, opt_cfg)
    assert jax.tree.leaves(o2["nu"])[0].dtype == jnp.bfloat16
    assert float(gn) > 0


# ---------------------------------------------------------------------------
# Data pipeline determinism
# ---------------------------------------------------------------------------


def test_stream_deterministic_and_resumable():
    s1 = TokenStream(1000, 16, 8, seed=5)
    s2 = TokenStream(1000, 16, 8, seed=5)
    b1 = s1.host_batch_at(42)
    b2 = s2.host_batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    full = s1.host_batch_at(3)
    assert (full["labels"][:, :-1] == full["tokens"][:, 1:]).all()
    # shard slices reassemble the global batch for any shard count
    for n_shards in (2, 4):
        parts = [s1.shard_batch_at(7, k, n_shards)["tokens"] for k in range(n_shards)]
        np.testing.assert_array_equal(np.concatenate(parts), s1.host_batch_at(7)["tokens"])


# ---------------------------------------------------------------------------
# Checkpoint + fault tolerance
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    ckpt = pytest.importorskip("repro.dist.checkpoint")

    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nest": {"b": jnp.ones((2,), jnp.int32)},
        "tup": (jnp.zeros(3), jnp.full((2, 2), 7.0)),
    }
    path = ckpt.save(str(tmp_path), 5, tree, extra={"note": "x"})
    assert os.path.exists(path)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored, step, extra = ckpt.restore(str(tmp_path))
    assert step == 5 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restart_is_bitwise_resumable(tmp_path):
    """Kill/restart: 10 straight steps == 5 steps + save + restore + 5."""
    ckpt = pytest.importorskip("repro.dist.checkpoint")

    cfg = _tiny()
    opt_cfg = OptConfig(lr=1e-3)
    stream = TokenStream(cfg.vocab_size, 32, 4, seed=1)
    step = jax.jit(make_train_step(cfg, opt_cfg, kv_chunk=32))

    def run(params, opt, lo, hi):
        for i in range(lo, hi):
            params, opt, m = step(params, opt, stream.batch_at(i))
        return params, opt, m

    p0 = tfm.init_params(cfg, jax.random.key(0))
    o0 = init_opt_state(p0, opt_cfg)
    pa, oa, ma = run(p0, o0, 0, 10)

    pb, ob, _ = run(p0, o0, 0, 5)
    ckpt.save(str(tmp_path), 5, (pb, ob))
    (pr, orr), s, _ = ckpt.restore(str(tmp_path))
    assert s == 5
    pc, oc, mc = run(pr, orr, 5, 10)
    np.testing.assert_allclose(float(ma["loss"]), float(mc["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_run_resilient_recovers_from_injected_failure(tmp_path):
    fault = pytest.importorskip("repro.dist.fault")
    ElasticMesh, run_resilient = fault.ElasticMesh, fault.run_resilient

    cfg = _tiny()
    opt_cfg = OptConfig(lr=1e-3)
    stream = TokenStream(cfg.vocab_size, 32, 4, seed=2)
    fail_at = {7}

    def failure_hook(step):
        if step in fail_at:
            fail_at.clear()  # fail once
            raise RuntimeError("injected device loss")

    def make_state(mesh):
        p = tfm.init_params(cfg, jax.random.key(0))
        return p, init_opt_state(p, opt_cfg)

    def make_step(mesh):
        return jax.jit(make_train_step(cfg, opt_cfg, kv_chunk=32))

    report = run_resilient(
        total_steps=12,
        ckpt_dir=str(tmp_path),
        make_state=make_state,
        make_step=make_step,
        batch_for=stream.batch_at,
        shardings_for=lambda mesh, s: None,
        ckpt_every=5,
        failure_hook=failure_hook,
        elastic=ElasticMesh(model_degree=1),
    )
    assert report.restarts == 1
    assert report.final_step == 12
    # restart resumed from step 5, so total steps run = 12 + (7 - 5)
    assert report.steps_run == 14


def test_watchdog_flags_straggler():
    fault = pytest.importorskip("repro.dist.fault")
    StepWatchdog, StragglerTimeout = fault.StepWatchdog, fault.StragglerTimeout

    wd = StepWatchdog(deadline_factor=3.0, warmup=3)
    for _ in range(6):
        wd.check(0.1)
    with pytest.raises(StragglerTimeout):
        wd.check(1.0)


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------


def test_compression_error_feedback_bounds():
    compress = pytest.importorskip("repro.dist.compress")
    compress_leaf, dequantize = compress.compress_leaf, compress.dequantize

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((300,)) * 0.01, jnp.float32)
    err = jnp.zeros_like(g)
    (q, scale), err2 = compress_leaf(g, err)
    deq = dequantize(q, scale, g.size, g.shape, jnp.float32)
    # reconstruction + error == original (error feedback identity)
    np.testing.assert_allclose(np.asarray(deq + err2), np.asarray(g), rtol=1e-5, atol=1e-7)
    # quantization error bounded by scale/2 per element
    per_block_scale = np.asarray(scale).ravel()
    assert np.abs(np.asarray(err2)).max() <= per_block_scale.max() * 0.5 + 1e-8


def test_pod_sum_compressed_matches_psum():
    pytest.importorskip("repro.dist.compress")
    from tests.conftest import run_multidevice

    code = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.compress import compressed_grad_sync, init_error_tree

devs = np.asarray(jax.devices()).reshape(4)
mesh = Mesh(devs, ("pod",))
g = jnp.asarray(np.random.default_rng(0).standard_normal((4, 512)), jnp.float32)

def f(g_local):
    grads = {"w": g_local[0]}
    err = init_error_tree(grads)
    synced, _ = compressed_grad_sync(grads, err, axis="pod")
    return synced["w"][None]

out = shard_map(f, mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None))(g)
ref = np.mean(np.asarray(g), axis=0)
got = np.asarray(out)[0]
rel = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
assert rel < 2e-2, rel
print("COMPRESS_OK", rel)
"""
    out = run_multidevice(code, n_devices=4, x64=False)
    assert "COMPRESS_OK" in out


def test_compression_ratio():
    compression_ratio = pytest.importorskip("repro.dist.compress").compression_ratio

    assert compression_ratio(4) < 0.26  # ~8x less than f32
