"""Autotune subsystem: space, pruning, trials, cache hygiene, e2e."""

import dataclasses
import os

import numpy as np
import pytest

from repro.autotune import (
    DEFAULT,
    Candidate,
    TuneCache,
    autotune,
    enumerate_space,
    extrapolate_iters,
    fingerprint,
    model_hash,
    sort_key,
)
from repro.autotune.objective import OBJECTIVES, score
from repro.autotune.prune import (
    format_stored_bytes,
    interior_stats,
    pareto_front,
    prune,
)
from repro.autotune.prune import Prediction
from repro.energy.accounting import CostModel
from repro.energy.model import PowerModel
from repro.roofline.hw import TPU_V5E


def _poisson(side=8):
    from repro.matrices import poisson

    return poisson.poisson_scipy(poisson.cube(side, "7pt"))


# ---------------------------------------------------------------------------
# space
# ---------------------------------------------------------------------------


def test_space_enumeration():
    space = enumerate_space()
    assert DEFAULT in space
    assert len(space) == len(set(space))
    # 6 format points (ell, hyb, bcsr x {2,4,8}, auto) x 3 variants x
    # 2 overlap x 3 freqs
    assert len(space) == 6 * 3 * 2 * 3
    # deterministic order
    assert space == enumerate_space()


def test_exec_key_ignores_frequency_and_dead_block():
    a = Candidate("hyb", "fcg", True, 4, 1.0)
    b = Candidate("hyb", "fcg", True, 4, 0.6)
    assert a.exec_key == b.exec_key
    # block is dead weight unless the format is bcsr
    assert (
        Candidate("ell", "hs", True, 2, 1.0).exec_key
        == Candidate("ell", "hs", True, 8, 1.0).exec_key
    )
    assert (
        Candidate("bcsr", "hs", True, 2, 1.0).exec_key
        != Candidate("bcsr", "hs", True, 8, 1.0).exec_key
    )


def test_sort_key_prefers_nominal_frequency_then_simplicity():
    tied = [
        Candidate("hyb", "hs", True, 4, 0.6),
        Candidate("ell", "hs", True, 4, 1.0),
        Candidate("ell", "hs", True, 4, 0.6),
    ]
    assert min(tied, key=sort_key) == Candidate("ell", "hs", True, 4, 1.0)


def test_candidate_roundtrip_and_label():
    c = Candidate("bcsr", "pipecg", False, 8, 0.8)
    assert Candidate.from_dict(c.to_dict()) == c
    assert c.label == "bcsr8/pipecg/ser/f0.8"
    assert DEFAULT.label == "ell/hs/ov/f1"


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------


def test_objective_scores():
    totals = dict(te_gpu=3.0, te_cpu=1.0, runtime=2.0)
    assert score("energy", totals) == 4.0
    assert score("time", totals) == 2.0
    assert score("edp", totals) == 8.0
    with pytest.raises(ValueError):
        score("joules", totals)
    assert set(OBJECTIVES) == {"energy", "edp", "time"}


# ---------------------------------------------------------------------------
# prune
# ---------------------------------------------------------------------------


def test_interior_stats_and_format_bytes():
    a = _poisson(6)
    row_starts = (0, a.shape[0])
    stats = interior_stats(a, row_starts)
    assert stats.n_rows == a.shape[0]
    # single shard: interior row lens are the full row lens
    assert np.array_equal(
        np.concatenate(stats.shard_row_lens), np.diff(a.indptr)
    )
    stored = format_stored_bytes(stats)
    assert set(stored) == {"ell", "hyb", "bcsr2", "bcsr4", "bcsr8"}
    assert all(v > 0 for v in stored.values())


def test_pareto_front_strict_dominance_keeps_time_ties():
    mk = lambda f, t, e: Prediction(
        Candidate("ell", "hs", True, 4, f), t, e, e
    )
    a = mk(1.0, 1.0, 10.0)  # nominal: same time, more energy
    b = mk(0.6, 1.0, 5.0)  # downclocked: time-free energy win
    c = mk(0.8, 2.0, 20.0)  # strictly dominated by both
    front = pareto_front([a, b, c])
    assert a in front and b in front and c not in front


def test_prune_budget_counts_executions_and_keeps_default(single_mesh):
    from repro.core.partition import partition_csr
    from repro.core.spmv import shard_matrix

    a = _poisson(6)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    cost = CostModel()
    survivors, _ = prune(
        enumerate_space(), a, mat, cost=cost, objective="energy", keep=2
    )
    execs = {p.candidate.exec_key for p in survivors}
    assert len(execs) <= 3  # 2 budgeted + the always-kept default
    assert DEFAULT.exec_key in execs
    # every chosen execution carries its whole frequency column
    freqs = {p.candidate.freq for p in survivors}
    assert freqs == set(cost.power.chip.freq_points)
    # scores sorted ascending
    scores = [p.score for p in survivors]
    assert scores == sorted(scores)


# ---------------------------------------------------------------------------
# trial extrapolation
# ---------------------------------------------------------------------------


def test_extrapolate_iters():
    # converged within the trial: the measured count stands
    assert extrapolate_iters(5, 1e-12, 1e-8) == 5
    # rate 0.1/iter from 4 trial iters: 1e-8 needs ~8 total at that rate
    # (9 when float log rounding tips the ceil)
    assert extrapolate_iters(4, 1e-4, 1e-8) in (8, 9)
    # stagnation hits the cap
    assert extrapolate_iters(8, 0.99999999999999, 1e-8, cap=123) == 123
    # degenerate inputs
    assert extrapolate_iters(0, 1.0, 1e-8) == 1
    # never extrapolates below what already ran
    assert extrapolate_iters(10, 1e-4, 1e-3) == 10


# ---------------------------------------------------------------------------
# cache hygiene
# ---------------------------------------------------------------------------


def test_cache_roundtrip(tmp_path):
    cache = TuneCache(os.path.join(tmp_path, "cache.json"))
    a = _poisson(6)
    cost = CostModel()
    fp = fingerprint(a, 2, "energy")
    chosen = Candidate("hyb", "pipecg", True, 4, 0.6)
    assert cache.get(fp, cost) is None
    cache.put(fp, cost, chosen)
    assert cache.get(fp, cost) == chosen
    # a different objective or shard count is a different key
    assert cache.get(fingerprint(a, 4, "energy"), cost) is None
    assert cache.get(fingerprint(a, 2, "time"), cost) is None


def test_cache_invalidates_on_frequency_grid_change(tmp_path):
    """Regression: an entry tuned against one DVFS grid must not be served
    for another — the chosen freq may not even exist there."""
    cache = TuneCache(os.path.join(tmp_path, "cache.json"))
    a = _poisson(6)
    fp = fingerprint(a, 2, "energy")
    cost_a = CostModel()
    cost_b = CostModel(
        power=PowerModel(
            chip=dataclasses.replace(TPU_V5E, freq_points=(0.5, 1.0))
        )
    )
    assert model_hash(cost_a) != model_hash(cost_b)
    cache.put(fp, cost_a, Candidate("ell", "hs", True, 4, 0.6))
    assert cache.get(fp, cost_b) is None
    assert cache.get(fp, cost_a) is not None
    # any PowerModel recalibration invalidates too
    cost_c = CostModel(power=PowerModel(hbm_fraction=0.7))
    assert cache.get(fp, cost_c) is None


def test_cache_schema_version_gates_entries(tmp_path):
    import json

    from repro.autotune import cache as cache_mod

    path = os.path.join(tmp_path, "cache.json")
    cache = TuneCache(path)
    a = _poisson(6)
    fp = fingerprint(a, 1, "energy")
    cost = CostModel()
    key = cache.put(fp, cost, DEFAULT)
    # simulate an entry written by an older schema
    with open(path) as f:
        d = json.load(f)
    d["entries"][key]["schema"] = cache_mod.SCHEMA - 1
    with open(path, "w") as f:
        json.dump(d, f)
    assert cache.get(fp, cost) is None


@pytest.mark.parametrize("content", ["{not json", '{"entries": []}', "[1]"])
def test_cache_survives_corrupt_file(tmp_path, content):
    path = os.path.join(tmp_path, "cache.json")
    with open(path, "w") as f:
        f.write(content)
    cache = TuneCache(path)
    a = _poisson(6)
    fp = fingerprint(a, 1, "energy")
    assert cache.get(fp, CostModel()) is None
    cache.put(fp, CostModel(), DEFAULT)  # overwrites the corrupt file
    assert cache.get(fp, CostModel()) == DEFAULT


def test_fingerprint_shape():
    a = _poisson(6)
    fp = fingerprint(a, 2, "edp")
    assert fp["n"] == a.shape[0] and fp["nnz"] == a.nnz
    assert len(fp["row_nnz_q"]) == 5
    assert fp["row_nnz_q"][0] <= fp["row_nnz_q"][-1]
    assert fp["bandwidth"] > 0
    assert fp["shards"] == 2 and fp["objective"] == "edp"
    assert fp["nrhs"] == 1  # default: single-RHS solve


def test_cache_nrhs_never_collides(tmp_path):
    """Regression: a decision tuned for an nrhs=1 solve must MISS for a
    batched nrhs=32 solve (and vice versa) — the batched solve's matrix
    traffic is amortized r ways, so the format/frequency trade-offs
    differ and sharing an entry would serve the wrong config."""
    cache = TuneCache(os.path.join(tmp_path, "cache.json"))
    a = _poisson(6)
    cost = CostModel()
    fp1 = fingerprint(a, 2, "energy")
    fp32 = fingerprint(a, 2, "energy", nrhs=32)
    assert fp1["nrhs"] == 1 and fp32["nrhs"] == 32
    assert cache.key(fp1, cost) != cache.key(fp32, cost)
    cache.put(fp1, cost, Candidate("ell", "hs", True, 4, 1.0))
    assert cache.get(fp32, cost) is None, (
        "nrhs=32 lookup was served the nrhs=1 decision"
    )
    cache.put(fp32, cost, Candidate("hyb", "hs", True, 4, 0.6))
    # both entries coexist; each nrhs resolves to its own decision
    assert cache.get(fp1, cost) == Candidate("ell", "hs", True, 4, 1.0)
    assert cache.get(fp32, cost) == Candidate("hyb", "hs", True, 4, 0.6)


# ---------------------------------------------------------------------------
# end to end
# ---------------------------------------------------------------------------


def test_autotune_end_to_end(tmp_path, single_mesh):
    a = _poisson(6)
    cache_path = os.path.join(tmp_path, "cache.json")
    res = autotune(
        a, single_mesh, 1, objective="energy", budget=2,
        cache_path=cache_path, trial_iters=4,
    )
    assert not res.cached
    assert res.candidates_total == 108
    assert res.candidates_trialed >= 1
    assert res.candidates_pruned + len(res.trials) == res.candidates_total
    # the energy objective always downclocks a memory-bound solve, so the
    # winner cannot be the out-of-the-box default...
    assert res.chosen != DEFAULT
    assert res.chosen.freq < 1.0
    # ...and can never score worse than it (default always trials along)
    by_cand = {t.candidate: t for t in res.trials}
    assert DEFAULT in by_cand
    assert by_cand[res.chosen].score <= by_cand[DEFAULT].score
    assert by_cand[res.chosen].measured_energy_j <= by_cand[
        DEFAULT
    ].measured_energy_j
    # trials are best-first and carry prediction next to measurement
    assert res.trials[0].candidate == res.chosen
    for t in res.trials:
        assert t.predicted_energy_j > 0 and t.measured_energy_j > 0
        assert t.iters_est >= t.iters_trial

    # second invocation: served from the cache, nothing executes
    res2 = autotune(
        a, single_mesh, 1, objective="energy", budget=2,
        cache_path=cache_path,
    )
    assert res2.cached and res2.candidates_trialed == 0
    assert res2.chosen == res.chosen
    # force re-tunes even on a hit
    res3 = autotune(
        a, single_mesh, 1, objective="energy", budget=2,
        cache_path=cache_path, trial_iters=4, force=True,
    )
    assert not res3.cached and res3.chosen == res.chosen
    with pytest.raises(ValueError):
        autotune(a, single_mesh, 1, objective="watts")
