"""s-step CG building blocks: deep ghost zones + the matrix-powers SpMV.

Host-side property tests pin the partition-layer invariants of
``partition_csr(..., halo_depth=k)`` (format-agnostic ghost plans, nested
widening, depth-1 bit-identity); the 8-device subprocess tests prove the
value-level equivalence that makes the communication-avoiding trade
legal: ONE widened exchange + redundant ghost recompute
(``matrix_powers``) computes exactly what k serial depth-1 exchanges
(``spmv_shard`` chained) compute — on the 1-D ring, on the 2x2 grid, and
for every interior format.
"""

import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partition import partition_csr
from tests.conftest import run_multidevice


def _banded_spd(n: int, bw: int, seed: int) -> sp.csr_matrix:
    """Random symmetric positive-definite band matrix (ring-partitionable)."""
    rng = np.random.default_rng(seed)
    diags = [rng.standard_normal(n - d) * 0.3 for d in range(1, bw + 1)]
    a = sp.diags(diags, range(1, bw + 1), shape=(n, n))
    a = a + a.T
    a = a + sp.eye(n) * (2.0 * bw + 1.0)
    return a.tocsr()


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(48, 96),
    bw=st.integers(1, 3),
    n_shards=st.sampled_from([2, 3, 4]),
    k=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 1000),
)
def test_deep_halo_partition_invariants(n, bw, n_shards, k, seed):
    """halo_depth=k ghost zones: nested, bounded, format-agnostic.

    * the depth-k exchange carries at most k times the depth-1 rows (the
      transitive closure of a banded coupling widens by at most one
      depth-1 halo per step) and at least the depth-1 rows;
    * ghost rows replicate only depth < k ghosts, so depth 1 has none;
    * the ghost plan is a property of the PARTITION, not the interior
      format — ell/hyb/bcsr share the identical plan and ghost block.
    """
    a = _banded_spd(n, bw, seed)
    m1 = partition_csr(a, n_shards)
    mk = partition_csr(a, n_shards, halo_depth=k)
    if m1.plan.mode != "ring":
        return  # degenerate draw (single shard owns everything)
    assert mk.plan.mode == "ring"
    assert mk.halo_depth == k and m1.halo_depth == 1
    w1 = sum(m1.plan.widths)
    wk = sum(mk.plan.widths)
    assert w1 <= wk <= k * w1, (w1, wk, k)
    # depth 1 carries no replicated ghost rows; depth k replicates the
    # depth < k ghosts it must recompute between chained applications
    assert m1.n_ghost_rows == 0 and m1.ghost_slots == 0
    if wk > w1:
        assert mk.n_ghost_rows > 0
    for fmt in ("hyb", "bcsr"):
        mf = partition_csr(a, n_shards, fmt=fmt, halo_depth=k)
        assert mf.plan == mk.plan, fmt
        np.testing.assert_array_equal(
            np.asarray(mf.ghost_col), np.asarray(mk.ghost_col)
        )
        np.testing.assert_array_equal(
            np.asarray(mf.ghost_pos), np.asarray(mk.ghost_pos)
        )
        np.testing.assert_allclose(
            np.asarray(mf.ghost_data), np.asarray(mk.ghost_data)
        )


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(48, 96),
    bw=st.integers(1, 2),
    n_shards=st.sampled_from([2, 4]),
    seed=st.integers(0, 1000),
)
def test_depth1_is_bit_identical_to_historical_build(n, bw, n_shards, seed):
    """halo_depth=1 must reproduce the historical partition exactly —
    every gated baseline rests on this."""
    a = _banded_spd(n, bw, seed)
    m0 = partition_csr(a, n_shards)
    m1 = partition_csr(a, n_shards, halo_depth=1)
    assert m0.plan == m1.plan
    for field in ("data_loc", "col_loc", "data_ext", "col_ext",
                  "bnd_rows", "send_sel"):
        np.testing.assert_array_equal(
            np.asarray(getattr(m0, field)), np.asarray(getattr(m1, field))
        )
    assert m1.ghost_slots == 0 and m1.halo_depth == 1


MP_RING_SNIPPET = r"""
import numpy as np
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
import scipy.sparse as sp
from repro.core.partition import pad_vector, partition_csr, unpad_vector
from repro.core.spmv import (
    dist_specs, local_block, matrix_powers, shard_matrix, shard_vector,
    spmv_shard,
)
from repro.launch.mesh import make_solver_mesh
from repro.matrices.poisson import cube, poisson_scipy

S = 8
mesh = make_solver_mesh(S)


def banded_spd(n, bw, seed):
    rng = np.random.default_rng(seed)
    diags = [rng.standard_normal(n - d) * 0.3 for d in range(1, bw + 1)]
    a = sp.diags(diags, range(1, bw + 1), shape=(n, n))
    a = a + a.T + sp.eye(n) * (2.0 * bw + 1.0)
    return a.tocsr()


def powers(mesh, mat, p, s, axis="shards"):
    specs = dist_specs(mat, axis)

    def fn(m, x):
        return matrix_powers(local_block(m), x[0], s, axis)[None]

    return shard_map(
        fn, mesh=mesh, in_specs=(specs, P(axis, None)),
        out_specs=P(axis, None, None), check_rep=False,
    )(mat, p)


def serial(mesh, mat, p, s, axis="shards"):
    specs = dist_specs(mat, axis)

    def fn(m, x):
        mb = local_block(m)
        outs = []
        for _ in range(s):
            x = spmv_shard(mb, x[0], axis, overlap=False)[None]
            outs.append(x[0])
        return jax.numpy.stack(outs)[None]

    return shard_map(
        fn, mesh=mesh, in_specs=(specs, P(axis, None)),
        out_specs=P(axis, None, None), check_rep=False,
    )(mat, p)


cases = [poisson_scipy(cube(12, "7pt"))]
cases += [banded_spd(512, bw, seed) for bw, seed in ((1, 0), (2, 1), (3, 2))]
for a in cases:
    n = a.shape[0]
    x = np.random.default_rng(7).standard_normal(n)
    for fmt in ("ell", "hyb", "bcsr"):
        for s in (2, 3, 4):
            deep = shard_matrix(mesh, partition_csr(a, S, fmt=fmt, halo_depth=s))
            flat = shard_matrix(mesh, partition_csr(a, S, fmt=fmt))
            xp = shard_vector(mesh, pad_vector(x, deep))
            got = np.asarray(powers(mesh, deep, xp, s))
            ref = np.asarray(serial(mesh, flat, shard_vector(mesh, pad_vector(x, flat)), s))
            err = np.abs(got - ref).max() / max(np.abs(ref).max(), 1.0)
            assert err <= 1e-12, (fmt, s, err)
            # ground truth: the actual monomial basis
            acc = x.copy()
            for j in range(s):
                acc = a @ acc
                gj = unpad_vector(got[:, j], deep)
                ej = np.abs(gj - acc).max() / max(np.abs(acc).max(), 1.0)
                assert ej <= 1e-11, (fmt, s, j, ej)
print("MP_RING_OK")
"""


def test_matrix_powers_matches_serial_exchanges_ring():
    """ONE widened exchange == s serial depth-1 exchanges, to 1e-12,
    for every interior format on the 8-shard ring."""
    out = run_multidevice(MP_RING_SNIPPET, n_devices=8)
    assert "MP_RING_OK" in out


MP_GRID_SNIPPET = r"""
import numpy as np
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.partition import (
    pad_vector, partition_csr, pencil_partition, unpad_vector,
)
from repro.core.spmv import (
    dist_specs, local_block, matrix_powers, shard_matrix, shard_vector,
    spmv_shard,
)
from repro.launch.mesh import make_grid_mesh
from repro.matrices.poisson import cube, poisson_scipy

grid = (2, 2)
S = 4
mesh = make_grid_mesh(*grid)
axis = ("rows", "cols")
p = cube(12, "7pt")
a = poisson_scipy(p)
perm, part = pencil_partition(p, grid)
ag = a[perm][:, perm].tocsr()
x = np.random.default_rng(3).standard_normal(a.shape[0])


def powers(mat, xp, s):
    specs = dist_specs(mat, axis)

    def fn(m, v):
        return matrix_powers(local_block(m), v[0], s, axis)[None]

    return shard_map(
        fn, mesh=mesh, in_specs=(specs, P(axis, None)),
        out_specs=P(axis, None, None), check_rep=False,
    )(mat, xp)


def serial(mat, xp, s):
    specs = dist_specs(mat, axis)

    def fn(m, v):
        mb = local_block(m)
        outs = []
        for _ in range(s):
            v = spmv_shard(mb, v[0], axis, overlap=False)[None]
            outs.append(v[0])
        return jax.numpy.stack(outs)[None]

    return shard_map(
        fn, mesh=mesh, in_specs=(specs, P(axis, None)),
        out_specs=P(axis, None, None), check_rep=False,
    )(mat, xp)


for s in (2, 3):
    deep = shard_matrix(
        mesh, partition_csr(ag, S, grid=grid, partition=part, halo_depth=s)
    )
    assert deep.plan.mode == "grid"
    flat = shard_matrix(
        mesh, partition_csr(ag, S, grid=grid, partition=part)
    )
    xp = shard_vector(mesh, pad_vector(x, deep), axis)
    got = np.asarray(powers(deep, xp, s))
    ref = np.asarray(serial(flat, shard_vector(mesh, pad_vector(x, flat), axis), s))
    err = np.abs(got - ref).max() / max(np.abs(ref).max(), 1.0)
    assert err <= 1e-12, (s, err)
    acc = x.copy()
    for j in range(s):
        acc = ag @ acc
        gj = unpad_vector(got[:, j], deep)
        ej = np.abs(gj - acc).max() / max(np.abs(acc).max(), 1.0)
        assert ej <= 1e-11, (s, j, ej)
print("MP_GRID_OK")
"""


def test_matrix_powers_matches_serial_exchanges_grid():
    """Same equivalence on the 2x2 process grid (two-hop corner halos)."""
    out = run_multidevice(MP_GRID_SNIPPET, n_devices=4)
    assert "MP_GRID_OK" in out


ILL_COND_SNIPPET = r"""
import numpy as np
import scipy.sparse as sp
from repro.core.cg import solve_cg
from repro.core.partition import partition_csr, unpad_vector
from repro.core.spmv import shard_matrix
from repro.launch.mesh import make_solver_mesh

S = 4
n = 256
# 1-D Laplacian, symmetrically scaled by a 2-decade diagonal:
# cond ~ 4e5 — raw monomial bases lose independence here without the
# A-norm column scaling in the s-step body.  The attainable accuracy
# of the monomial basis degrades with s (the Gram system conditioning
# grows like cond(A)^s), so the agreement bound is per-s: 1e-8 at
# s=2 (the comm-avoiding gate's setting), 1e-7 at s=4.
lap = sp.diags([-np.ones(n - 1), 2.0 * np.ones(n), -np.ones(n - 1)],
               [-1, 0, 1]).tocsr()
d = np.logspace(0, 1, n)
D = sp.diags(d)
a = (D @ lap @ D).tocsr()
b = np.ones(n)
mesh = make_solver_mesh(S)

res_h = solve_cg(
    mesh, shard_matrix(mesh, partition_csr(a, S)), b,
    variant="hs", tol=1e-10, maxiter=8000,
)
assert float(res_h.rel_residual) < 1e-9, float(res_h.rel_residual)
for s, agree_tol in ((2, 1e-8), (4, 1e-7)):
    mat = shard_matrix(mesh, partition_csr(a, S, halo_depth=s))
    res_s = solve_cg(
        mesh, mat, b, variant="sstep", s=s, tol=1e-10, maxiter=8000,
    )
    assert float(res_s.rel_residual) < 1e-9, (s, float(res_s.rel_residual))
    xh = unpad_vector(np.asarray(res_h.x), mat)
    xs = unpad_vector(np.asarray(res_s.x), mat)
    err = np.abs(xs - xh).max() / np.abs(xh).max()
    assert err <= agree_tol, (s, err)
print("ILL_OK")
"""


def test_sstep_ill_conditioned_matches_hs():
    """The A-norm basis scaling keeps s-step CG convergent on a
    ~4e5-condition system; the solution agrees with hs to 1e-8 at s=2."""
    out = run_multidevice(ILL_COND_SNIPPET, n_devices=4)
    assert "ILL_OK" in out
