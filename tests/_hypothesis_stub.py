"""Deterministic fallback for the tiny hypothesis subset the tests use.

The real ``hypothesis`` is a test dependency (pyproject ``[test]``), but
this container cannot install packages. Rather than skipping every
property-based suite, conftest.py registers this stub in ``sys.modules``
when the real library is absent: ``@given`` then draws ``max_examples``
deterministic pseudo-random samples per strategy (seeded from the test
name), which preserves the coverage intent — many sampled cases per
property — minus shrinking/replay. With hypothesis installed, the stub is
never imported.

Supported surface: ``given`` (keyword strategies), ``settings``
(max_examples/deadline ignored otherwise), ``strategies.integers/floats/
booleans/sampled_from/just``.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def just(value):
    return _Strategy(lambda rng: value)


class settings:
    """Decorator recording max_examples on the wrapped test."""

    def __init__(self, max_examples: int = 20, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", 20)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                draw = {k: s.example_from(rng) for k, s in strategies.items()}
                fn(*args, **kwargs, **draw)

        # strategy-drawn params are not pytest fixtures: hide the wrapped
        # signature (functools.wraps would otherwise expose it)
        params = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strategies
        ]
        wrapper.__signature__ = inspect.Signature(params)
        try:
            del wrapper.__wrapped__
        except AttributeError:
            pass
        return wrapper

    return deco


def install():
    """Register the stub as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "just"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
