"""Distributed SpMV + CG + AMG: single-device in-process, 8-way subprocess.

The in-process tests run the full shard_map machinery on a 1-device mesh
(psum/ppermute are identities but every code path executes); the subprocess
tests prove real multi-shard correctness with 8 host devices.
"""

import numpy as np
import pytest

from tests.conftest import run_multidevice


def test_spmv_single_shard_matches_scipy(single_mesh):
    from repro.core.partition import pad_vector, partition_csr, unpad_vector
    from repro.core.spmv import make_spmv, shard_matrix, shard_vector
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(8, "7pt")
    a = poisson_scipy(p, dtype=np.float32)
    mat = shard_matrix(single_mesh, partition_csr(a, 1, dtype=np.float32))
    x = np.random.default_rng(0).standard_normal(p.n).astype(np.float32)
    xp = shard_vector(single_mesh, pad_vector(x, mat))
    y = unpad_vector(np.asarray(make_spmv(single_mesh, mat)(mat, xp)), mat)
    np.testing.assert_allclose(y, a @ x, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("variant", ["hs", "fcg", "pipecg", "sstep"])
def test_cg_single_shard_converges(single_mesh, variant):
    from repro.core.cg import solve_cg
    from repro.core.partition import partition_csr, unpad_vector
    from repro.core.spmv import shard_matrix
    from repro.matrices.poisson import cube, default_rhs, poisson_scipy

    p = cube(8, "7pt")
    a = poisson_scipy(p, dtype=np.float64)
    b = default_rhs(p.n)
    mat = shard_matrix(single_mesh, partition_csr(a, 1))
    res = solve_cg(
        single_mesh, mat, b.astype(np.float32), variant=variant,
        tol=1e-5, maxiter=300, s=2,
    )
    assert float(res.rel_residual) < 1e-4
    x = unpad_vector(np.asarray(res.x), mat)
    np.testing.assert_allclose(a @ x, b, rtol=1e-3, atol=1e-3)


MULTI_SNIPPET = r"""
import numpy as np
import jax
from repro.matrices.poisson import cube, poisson_scipy, default_rhs
from repro.core.partition import partition_csr, partition_stencil, pad_vector, unpad_vector
from repro.core.spmv import make_spmv, shard_matrix, shard_vector
from repro.core.cg import solve_cg
from repro.core.baselines import make_naive_solver, make_naive_spmv
from repro.launch.mesh import make_solver_mesh
import scipy.sparse.linalg as spla

S = 8
p = cube(16, "%(stencil)s")
A = poisson_scipy(p)
b = default_rhs(p.n)
mesh = make_solver_mesh(S)

# ring stencil partition, no global matrix
mat = shard_matrix(mesh, partition_stencil(p, S))
x = np.random.default_rng(0).standard_normal(p.n)
y = unpad_vector(np.asarray(make_spmv(mesh, mat)(mat, shard_vector(mesh, pad_vector(x, mat)))), mat)
assert np.abs(y - A @ x).max() < 1e-10, "ring stencil spmv"

# generic csr ring
mat2 = shard_matrix(mesh, partition_csr(A, S))
assert mat2.plan.mode == "ring"
y2 = unpad_vector(np.asarray(make_spmv(mesh, mat2)(mat2, shard_vector(mesh, pad_vector(x, mat2)))), mat2)
assert np.abs(y2 - A @ x).max() < 1e-10, "csr ring spmv"

# allgather baseline
mat3 = shard_matrix(mesh, partition_csr(A, S, force_allgather=True))
y3 = unpad_vector(np.asarray(make_naive_spmv(mesh, mat3)(mat3, shard_vector(mesh, pad_vector(x, mat3)))), mat3)
assert np.abs(y3 - A @ x).max() < 1e-10, "naive spmv"

x_ref = spla.spsolve(A.tocsc(), b)
for variant in ("hs", "fcg", "pipecg", "sstep"):
    res = solve_cg(mesh, mat, b, variant=variant, tol=1e-10, maxiter=500, s=4)
    xs = unpad_vector(np.asarray(res.x), mat)
    assert np.abs(xs - x_ref).max() < 1e-6, (variant, np.abs(xs - x_ref).max())
    assert int(res.iters) < 120, variant

solver = make_naive_solver(mesh, mat3, tol=1e-10, maxiter=500)
bp = shard_vector(mesh, pad_vector(b, mat3))
res = solver(bp, shard_vector(mesh, np.zeros_like(pad_vector(b, mat3))))
xs = unpad_vector(np.asarray(res.x), mat3)
assert np.abs(xs - x_ref).max() < 1e-6
print("MULTI_OK")
"""


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
def test_multidevice_spmv_cg(stencil):
    out = run_multidevice(MULTI_SNIPPET % {"stencil": stencil}, n_devices=8)
    assert "MULTI_OK" in out


AMG_SNIPPET = r"""
import numpy as np
import jax
from repro.matrices.poisson import cube, poisson_scipy, default_rhs
from repro.core.partition import partition_csr, unpad_vector
from repro.core.spmv import shard_matrix
from repro.core.cg import solve_cg
from repro.core.amg import build_amg
from repro.core.amg.baseline import build_amgx_analog
from repro.launch.mesh import make_solver_mesh
import scipy.sparse.linalg as spla

S = 8
p = cube(16, "7pt")
A = poisson_scipy(p)
b = default_rhs(p.n)
mesh = make_solver_mesh(S)
mat = shard_matrix(mesh, partition_csr(A, S))
x_ref = spla.spsolve(A.tocsc(), b)

res0 = solve_cg(mesh, mat, b, variant="hs", tol=1e-8, maxiter=1000)
for builder in (build_amg, build_amgx_analog):
    pre, info = builder(A, S)
    assert info.n_levels >= 2
    assert info.operator_complexity < 2.0
    res = solve_cg(mesh, mat, b, variant="hs", precond=pre, tol=1e-8, maxiter=200)
    assert int(res.iters) < int(res0.iters) / 2, (int(res.iters), int(res0.iters))
    xs = unpad_vector(np.asarray(res.x), mat)
    assert np.abs(xs - x_ref).max() < 1e-5
# flexible and pipelined CG with AMG (the real-preconditioner recurrences)
pre, _ = build_amg(A, S)
for variant in ("fcg", "pipecg"):
    res = solve_cg(mesh, mat, b, variant=variant, precond=pre, tol=1e-8, maxiter=200)
    assert float(res.rel_residual) < 1e-7, variant
print("AMG_OK")
"""


def test_multidevice_amg_pcg():
    out = run_multidevice(AMG_SNIPPET, n_devices=8)
    assert "AMG_OK" in out
