"""Local sparse formats: CSR/ELL/BCSR matvec vs scipy (+ property tests)."""

import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.sparse import bcsr_from_scipy, csr_from_scipy, ell_from_scipy


def _random_csr(n, m, density, seed):
    rng = np.random.default_rng(seed)
    a = sp.random(n, m, density=density, format="csr", random_state=seed)
    a.data = rng.standard_normal(a.nnz)
    return a


@pytest.mark.parametrize("fmt", ["csr", "ell", "bcsr"])
@pytest.mark.parametrize("n,m,density", [(40, 40, 0.1), (64, 48, 0.05), (17, 33, 0.3)])
def test_matvec_matches_scipy(fmt, n, m, density):
    a = _random_csr(n, m, density, seed=n + m)
    x = np.random.default_rng(0).standard_normal(m).astype(np.float32)
    y_ref = a @ x
    if fmt == "csr":
        dev = csr_from_scipy(a)
        y = np.asarray(dev.matvec(x.astype(np.float32)))
    elif fmt == "ell":
        dev = ell_from_scipy(a)
        y = np.asarray(dev.matvec(x.astype(np.float32)))
    else:
        dev = bcsr_from_scipy(a, br=8, bc=8, dtype=np.float32)
        xpad = np.zeros(dev.n_bcols * dev.bc, np.float32)
        xpad[:m] = x
        y = np.asarray(dev.matvec(xpad))[:n]
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


def test_csr_padding_is_free():
    a = _random_csr(30, 30, 0.1, seed=1)
    x = np.random.default_rng(1).standard_normal(30).astype(np.float32)
    y0 = np.asarray(csr_from_scipy(a).matvec(x))
    y1 = np.asarray(csr_from_scipy(a, pad_nnz_to=a.nnz + 64).matvec(x))
    np.testing.assert_allclose(y0, y1, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(8, 40),
    density=st.floats(0.05, 0.4),
    seed=st.integers(0, 10_000),
)
def test_ell_property_matches_scipy(n, density, seed):
    # NOTE: main pytest process runs WITHOUT x64 (dry-run/smoke parity), so
    # device math is f32 even for f64 inputs; f64 paths are covered by the
    # subprocess tests (JAX_ENABLE_X64=1 there).
    a = _random_csr(n, n, density, seed)
    x = np.random.default_rng(seed).standard_normal(n).astype(np.float64)
    y = np.asarray(ell_from_scipy(a, dtype=np.float64).matvec(x))
    np.testing.assert_allclose(y, a @ x, rtol=3e-4, atol=3e-4)
