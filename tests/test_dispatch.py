"""Kernel dispatch layer + fused hot-path kernels.

Interpret-mode Pallas vs the kernels/ref.py oracles in f32/f64 (including
non-multiple-of-chunk lengths), backend resolution, sweep-ledger
accounting, and kernels-on vs kernels-off end-to-end solves.
"""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import dispatch as kd
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# Backend resolution
# ---------------------------------------------------------------------------


def test_backend_resolution_auto():
    # CPU container: auto resolves to jnp; pallas only on TPU backends.
    assert kd.backend() in kd.BACKENDS
    assert kd.available_backend() == (
        "pallas" if jax.default_backend() == "tpu" else "jnp"
    )


def test_backend_override_and_env(monkeypatch):
    with kd.use_backend("interpret"):
        assert kd.backend() == "interpret"
        assert kd.ops_for(None).backend == "interpret"
        # explicit choice beats the override; 'auto' defers to it
        assert kd.ops_for("jnp").backend == "jnp"
        assert kd.ops_for("auto").backend == "interpret"
    monkeypatch.setenv(kd.ENV_VAR, "interpret")
    assert kd.backend() == "interpret"
    monkeypatch.setenv(kd.ENV_VAR, "auto")
    assert kd.backend() == kd.available_backend()
    monkeypatch.setenv(kd.ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        kd.backend()


def test_set_backend_validation():
    with pytest.raises(ValueError):
        kd.set_backend("nope")
    kd.set_backend("jnp")
    try:
        assert kd.backend() == "jnp"
    finally:
        kd.set_backend(None)


# ---------------------------------------------------------------------------
# Fused kernels vs oracles (interpret mode), incl. ragged lengths
# ---------------------------------------------------------------------------

LENGTHS = [(2048, 512), (1000, 512), (100, 65536), (513, 128)]


def _tol(dtype, n):
    # no-x64 main process computes f64 inputs in f32; tol follows ACTUAL dtype
    return (1e-12, 1e-12 * max(n, 1)) if dtype == np.float64 else (2e-4, 2e-4 * n)


@pytest.mark.parametrize("n,chunk", LENGTHS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fused_dots3_any_length(n, chunk, dtype):
    rng = np.random.default_rng(n)
    p, w, r = (jnp.asarray(rng.standard_normal(n).astype(dtype)) for _ in range(3))
    d = np.asarray(ops.fused_dots3(p, w, r, chunk=chunk, interpret=True))
    d_ref = np.asarray(ref.fused_dots3_ref(p, w, r))
    rtol, atol = _tol(d.dtype, n)
    np.testing.assert_allclose(d, d_ref, rtol=rtol, atol=atol)


@pytest.mark.parametrize("n,chunk", LENGTHS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fused_dots_n_dedup(n, chunk, dtype):
    rng = np.random.default_rng(n + 1)
    r, w = (jnp.asarray(rng.standard_normal(n).astype(dtype)) for _ in range(2))
    u = r  # identity-preconditioner aliasing: {r, w} read once, (r,r) once
    d = np.asarray(ops.fused_dots_n([(r, u), (w, u), (r, r)], chunk=chunk,
                                    interpret=True))
    d_ref = np.asarray(ref.fused_dots_n_ref([(r, u), (w, u), (r, r)]))
    rtol, atol = _tol(d.dtype, n)
    np.testing.assert_allclose(d, d_ref, rtol=rtol, atol=atol)
    assert abs(d[0] - d[2]) == 0.0  # deduped pair computed once


@pytest.mark.parametrize("n,chunk", LENGTHS)
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fused_axpy_family(n, chunk, dtype):
    rng = np.random.default_rng(n + 2)
    x1, y1, x2, y2 = (
        jnp.asarray(rng.standard_normal(n).astype(dtype)) for _ in range(4)
    )
    a1, a2 = dtype(0.37), dtype(-1.1)
    rtol, atol = _tol(np.asarray(x1).dtype, n)

    o = np.asarray(ops.fused_axpy(a1, x1, y1, chunk=chunk, interpret=True))
    np.testing.assert_allclose(o, np.asarray(ref.fused_axpy_ref(a1, x1, y1)),
                               rtol=rtol, atol=1e-5)

    o1, o2 = ops.fused_axpy2(a1, x1, y1, a2, x2, y2, chunk=chunk, interpret=True)
    r1, r2 = ref.fused_axpy2_ref(a1, x1, y1, a2, x2, y2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(r1), rtol=rtol, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), rtol=rtol, atol=1e-5)

    o1, o2, d = ops.fused_axpy2_dots(a1, x1, y1, a2, x2, y2, chunk=chunk,
                                     interpret=True)
    r1, r2, dr = ref.fused_axpy2_dots_ref(a1, x1, y1, a2, x2, y2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(r1), rtol=rtol, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(r2), rtol=rtol, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dr), rtol=rtol, atol=atol)


def test_fused_axpy_traced_scalar():
    f = jax.jit(lambda a, x, y: ops.fused_axpy(a, x, y, interpret=True))
    x = jnp.arange(300.0, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(f(2.0, x, x)), 3.0 * np.arange(300.0), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# Halo stencil kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize("shape,bz", [((8, 6, 10), 4), ((6, 5, 9), 3), ((4, 8, 8), 4)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_stencil_halo_kernel(stencil, shape, bz, dtype):
    rng = np.random.default_rng(sum(shape))
    x = rng.standard_normal(shape).astype(dtype)
    prev = rng.standard_normal(shape[1:]).astype(dtype)
    nxt = rng.standard_normal(shape[1:]).astype(dtype)
    y = np.asarray(ops.stencil_spmv_halo(x, prev, nxt, stencil=stencil, bz=bz,
                                         interpret=True))
    y_ref = np.asarray(ref.stencil_halo_ref(x, prev, nxt, stencil=stencil))
    tol = 1e-12 if y.dtype == np.float64 else 2e-4
    np.testing.assert_allclose(y, y_ref, rtol=tol, atol=tol)


def test_stencil_halo_zero_halo_matches_dirichlet():
    x = np.random.default_rng(0).standard_normal((8, 7, 11))
    z = np.zeros((7, 11))
    y = np.asarray(ops.stencil_spmv_halo(x, z, z, stencil="7pt", bz=4,
                                         interpret=True))
    tol = 1e-10 if y.dtype == np.float64 else 2e-4
    np.testing.assert_allclose(y, np.asarray(ref.stencil7_ref(x)),
                               rtol=tol, atol=tol)


def test_pick_bz():
    from repro.kernels.spmv_stencil import pick_bz

    assert pick_bz(16) == 8
    assert pick_bz(12) == 6
    assert pick_bz(7) == 7
    assert pick_bz(13) == 1


@pytest.mark.parametrize("n", [1000, 513])
@pytest.mark.parametrize("s", [2, 4])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_sstep_ops_family(n, s, dtype):
    # the three fused s-step ops (basis A-conjugation, one-pass Gram
    # reduction operands, blocked x/r update) vs the ref.py oracles,
    # jnp and interpret backends
    rng = np.random.default_rng(11)
    mk = lambda *shape: jnp.asarray(rng.standard_normal(shape), dtype)
    pb, wb, wp, qp = (mk(n, s) for _ in range(4))
    r, x = mk(n), mk(n)
    bmat, dinv, a = mk(s, s), mk(s), mk(s)
    rtol, atol = _tol(pb.dtype, n)
    g_ref = np.asarray(ref.sstep_gram_ref(pb, wb, wp, r))
    p_ref, w_ref = ref.sstep_basis_ref(bmat, dinv, qp, pb, wp, wb)
    x_ref, r_ref = ref.sstep_update_ref(a, qp, wp, x, r)
    assert g_ref.shape == (2 * s * s + s + 1,)
    for b in ("jnp", "interpret"):
        o = kd.ops_for(b)
        np.testing.assert_allclose(
            np.asarray(o.sstep_gram(pb, wb, wp, r)), g_ref,
            rtol=rtol, atol=atol)
        p_out, w_out = o.sstep_basis(bmat, dinv, qp, pb, wp, wb)
        np.testing.assert_allclose(np.asarray(p_out), np.asarray(p_ref),
                                   rtol=rtol, atol=atol)
        np.testing.assert_allclose(np.asarray(w_out), np.asarray(w_ref),
                                   rtol=rtol, atol=atol)
        x_out, r_out = o.sstep_update(a, qp, wp, x, r)
        np.testing.assert_allclose(np.asarray(x_out), np.asarray(x_ref),
                                   rtol=rtol, atol=atol)
        np.testing.assert_allclose(np.asarray(r_out), np.asarray(r_ref),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# OpSet dispatch + sweep ledger
# ---------------------------------------------------------------------------


def test_opset_backends_agree():
    rng = np.random.default_rng(3)
    n = 777
    x, y = (jnp.asarray(rng.standard_normal(n), jnp.float32) for _ in range(2))
    outs = {
        b: np.asarray(kd.ops_for(b).axpy(jnp.float32(0.5), x, y))
        for b in ("jnp", "interpret")
    }
    np.testing.assert_allclose(outs["jnp"], outs["interpret"], rtol=1e-6)


def test_ledger_counts_iteration_ops():
    ops_set = kd.ops_for("jnp")
    x = jnp.ones((64,))
    with kd.record_sweeps() as led:
        with kd.ledger_section("iteration"):
            ops_set.axpy(1.0, x, x)
            ops_set.fused_dots_n([(x, x)])
            ops_set.stencil_matvec(
                jnp.ones((4, 4, 4)), jnp.zeros((4, 4)), jnp.zeros((4, 4))
            )
    assert led.vector_sweeps("iteration") == 2
    assert led.spmv_calls("iteration") == 1
    # outside the recording context nothing is counted
    ops_set.axpy(1.0, x, x)
    assert led.vector_sweeps("iteration") == 2


@pytest.mark.parametrize("variant", ["hs", "fcg"])
def test_solver_hot_loop_sweep_bound(variant):
    """Acceptance: <= 3 full-vector HBM sweeps/iter outside the SpMV."""
    from repro.core.stencil_solver import make_stencil_solver_fn
    from repro.matrices.poisson import PoissonProblem

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
    p = PoissonProblem(8, 8, 8, "7pt")
    vec = jax.ShapeDtypeStruct((1, p.n), "float64")
    with kd.record_sweeps() as led:
        solve = make_stencil_solver_fn(mesh, p, 1, variant=variant)
        solve.lower(vec, vec)
    assert led.vector_sweeps("iteration") <= 3
    assert led.spmv_calls("iteration") == 1


# ---------------------------------------------------------------------------
# End-to-end: kernels on vs off, identical convergence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stencil", ["7pt", "27pt"])
@pytest.mark.parametrize("variant", ["hs", "fcg"])
def test_stencil_solver_kernels_on_off(stencil, variant):
    code = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices.poisson import PoissonProblem, poisson_scipy, default_rhs
from repro.core.stencil_solver import make_stencil_solver_fn
import scipy.sparse.linalg as spla

S = 4
mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:S]), ("shards",))
p = PoissonProblem(10, 9, 16, "%(stencil)s")
a = poisson_scipy(p, dtype=np.float64)
b = default_rhs(p.n)
R = p.n // S
bv = jnp.asarray(b).reshape(S, R); x0 = jnp.zeros_like(bv)
x_ref = spla.spsolve(a.tocsc(), b)
got = {}
for backend in ("jnp", "interpret"):
    solve = make_stencil_solver_fn(mesh, p, S, variant="%(variant)s",
                                   tol=1e-10, maxiter=500, kernels=backend)
    res = solve(bv, x0)
    xs = np.asarray(res.x).reshape(-1)
    assert np.abs(xs - x_ref).max() < 1e-8, backend
    got[backend] = (int(res.iters), float(res.rel_residual))
j, i = got["jnp"], got["interpret"]
assert j[0] == i[0], (j, i)                 # identical iteration count
assert abs(j[1] - i[1]) < 1e-10, (j, i)     # identical relative residual
print("ONOFF_OK", j)
"""
    from tests.conftest import run_multidevice

    out = run_multidevice(
        code % {"stencil": stencil, "variant": variant}, n_devices=4
    )
    assert "ONOFF_OK" in out


def test_hotpath_fusion_benchmark_smoke():
    """The sweep-accounting benchmark itself must keep running."""
    import subprocess
    import sys

    from tests.conftest import REPO, SRC

    env = dict(os.environ)
    env["PYTHONPATH"] = REPO + os.pathsep + SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c",
         "import benchmarks.hotpath_fusion as h; h.main(smoke=True)"],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "Measured (traced) HBM sweeps" in r.stdout
