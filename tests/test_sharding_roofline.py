"""Sharding-rule validity across all archs + roofline/HLO-parsing units."""

import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config

_sharding = pytest.importorskip("repro.dist.sharding")
axis_size, batch_specs = _sharding.axis_size, _sharding.batch_specs
cache_specs, param_specs = _sharding.cache_specs, _sharding.param_specs
from repro.models import lm, transformer as tfm
from repro.roofline import analysis as ra

SINGLE = AbstractMesh((16, 16), ("data", "model"))
MULTI = AbstractMesh((2, 16, 16), ("pod", "data", "model"))


def _check_specs(tree_sds, specs, mesh):
    flat_s = jax.tree.leaves(tree_sds)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        for dim, name in enumerate(spec):
            if name is None:
                continue
            assert leaf.shape[dim] % axis_size(mesh, name) == 0, (
                leaf.shape, spec, dim,
            )


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("name", sorted(ARCHS))
def test_param_specs_divisible_all_archs(name, mesh):
    cfg = get_config(name)
    params_sds = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.key(0)))
    specs = param_specs(params_sds, mesh)
    _check_specs(params_sds, specs, mesh)


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k", "long_500k"])
@pytest.mark.parametrize("name", ["qwen3-8b", "zamba2-7b", "xlstm-350m", "arctic-480b"])
def test_input_cache_specs_divisible(name, shape_name, mesh):
    cfg = get_config(name)
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and cfg.is_encoder_only:
        pytest.skip("no decode")
    if shape_name == "long_500k" and not cfg.subquadratic:
        pytest.skip("quadratic")
    specs_in = lm.input_specs(cfg, shape)
    if "batch" in specs_in:
        specs = batch_specs(specs_in["batch"], mesh, shape.global_batch)
        _check_specs(specs_in["batch"], specs, mesh)
    else:
        cs = cache_specs(specs_in["cache"], mesh, shape.global_batch, shape.seq_len)
        _check_specs(specs_in["cache"], cs, mesh)


def test_param_specs_use_model_and_data_axes():
    cfg = get_config("qwen3-8b")
    params_sds = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.key(0)))
    specs = param_specs(params_sds, SINGLE)
    names = set()
    for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for n in s:
            if n is not None:
                names.add(n)
    assert "model" in names and "data" in names  # TP + FSDP both active


def test_moe_expert_axis_sharded():
    cfg = get_config("arctic-480b")
    params_sds = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.key(0)))
    specs = param_specs(params_sds, SINGLE)
    wg = specs["blocks"]["ffn"]["wg"]
    assert wg[1] == "model"  # E axis = expert parallelism


# ---------------------------------------------------------------------------
# Roofline / HLO collective parsing
# ---------------------------------------------------------------------------

FAKE_HLO = """
HloModule test
%x1 = bf16[128,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[2,8]<=[16], dimensions={0}
%x2 = f32[512]{0} all-reduce(%p1), replica_groups=[1,16]<=[16], to_apply=%sum
%x3 = f32[64,32]{1,0} reduce-scatter(%p2), replica_groups=[2,8]<=[16], dimensions={0}
%x4 = bf16[16,16]{1,0} all-to-all(%p3), replica_groups=[4,4]<=[16]
%x5 = f64[100]{0} collective-permute(%p4), source_target_pairs={{0,1}}
%x6 = (f32[4]{0}, f32[4]{0}) all-reduce-start(%p5), replica_groups=[1,16]<=[16]
%x7 = f32[4]{0} all-reduce-done(%x6)
"""


def test_collective_bytes_parser():
    out = ra.collective_bytes(FAKE_HLO)
    # all-gather: result / participants = operand shard
    assert out["all-gather_bytes"] == 128 * 1024 * 2 / 8
    # all-reduce: result (incl. -start result half, not -done)
    assert out["all-reduce_bytes"] == 512 * 4 + 4 * 4
    # reduce-scatter: result * participants = unscattered operand
    assert out["reduce-scatter_bytes"] == 64 * 32 * 4 * 8
    assert out["all-to-all_bytes"] == 16 * 16 * 2
    assert out["collective-permute_bytes"] == 100 * 8
    assert out["total_count"] == 6


def test_roofline_terms_math():
    t = ra.roofline(
        hlo_flops_per_device=197e12 * 0.5,  # half a second of compute
        hlo_bytes_per_device=819e9 * 0.25,
        collective_bytes_per_device=50e9 * 0.1,
        chips=256,
        model_flops=197e12 * 0.5 * 256 * 0.8,
    )
    assert np.isclose(t.compute_s, 0.5)
    assert np.isclose(t.memory_s, 0.25)
    assert np.isclose(t.collective_s, 0.1)
    assert t.dominant == "compute"
    assert np.isclose(t.step_s, 0.5)
    assert np.isclose(t.useful_ratio, 0.8)
    assert np.isclose(t.mfu, 0.8)


def test_model_flops_formulas():
    cfg = get_config("arctic-480b")
    shape = SHAPES["train_4k"]
    mf = ra.model_flops_train(cfg, shape)
    # MoE uses ACTIVE params
    assert mf == 6.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    assert cfg.active_param_count() < cfg.param_count() / 10
