"""Scale-out conformance: 2-D (row x col) grid partitioning vs the 1-D ring.

The 2-D path (core/partition.GridPlan + pencil_partition, hierarchical
all-reduce in core/vectors.all_reduce, per-dimension halo ppermutes in
core/spmv) must produce the SAME arithmetic as the 1-D ring layout up to
the pencil row permutation — same SpMV values, same CG trajectory, same
solution — while its ledger halo bytes follow the closed-form pencil
surface model exactly.

Latent-assumption audit (this PR swept every shard_map body and halo plan
for hard-coded axis names / shard-count arithmetic):

* ``core.baselines.make_naive_spmv`` / ``make_naive_solver`` pin the flat
  ``"shards"`` axis BY DESIGN — the Ginkgo-analog naive leg is defined as
  the 1-D padded-global layout, and ``api.solve``'s ``need_naive`` gate
  excludes grid runs (a grid run's comparison leg is the 1-D run of the
  same problem). ``test_matrix_axis_dispatch`` pins the dispatch hinge
  every other consumer goes through.
* Everything else derives its axes from ``matrix_axis(mat)`` /
  ``plan.axes``; nothing assumes square grids, power-of-two shard counts,
  or grid-divisible problem sizes. The 3x2 (six devices), 8x4 (32 shards,
  host-side), and non-divisible-side cases below are the regressions.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from tests.conftest import run_multidevice


def _grid_mat(side, grid, stencil="7pt", fmt="ell", seed=None):
    """Pencil-permuted Poisson cube partitioned on ``grid``; returns
    (A_scipy_permuted, problem, row_partition, mat)."""
    from repro.core.partition import partition_csr, pencil_partition
    from repro.matrices.poisson import cube, poisson_scipy

    p = cube(side, stencil)
    a = poisson_scipy(p)
    if seed is not None:  # unique random entries make abs-sum checks exact
        a.data = np.random.default_rng(seed).standard_normal(a.data.shape)
    perm, part = pencil_partition(p, grid)
    ag = a[perm][:, perm].tocsr()
    s = grid[0] * grid[1]
    mat = partition_csr(ag, s, grid=grid, partition=part, fmt=fmt)
    return ag, p, part, mat


# ---------------------------------------------------------------------------
# host-side: partition round-trip properties
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    side=st.integers(min_value=4, max_value=7),
    grid=st.sampled_from(((2, 2), (3, 2), (2, 3), (2, 4))),
    stencil=st.sampled_from(("7pt", "27pt")),
)
def test_grid_partition_owns_every_entry_once(side, grid, stencil):
    """Every CSR entry lands in exactly one (row-block, col-slab) owner:
    with unique random entry values, the per-shard interior + boundary
    blocks conserve the global abs-sum exactly (no drop, no duplicate),
    and the row blocks tile [0, n) without gaps or overlap."""
    ag, p, part, mat = _grid_mat(side, grid, stencil, seed=side * 100 + grid[0])
    assert mat.plan.mode == "grid"
    assert mat.plan.grid == grid
    got = (
        np.abs(np.asarray(mat.data_loc)).sum()
        + np.abs(np.asarray(mat.data_ext)).sum()
    )
    # in-process DistMat arrays are f32 (tests run without x64)
    np.testing.assert_allclose(got, np.abs(ag.data).sum(), rtol=1e-5)
    # row blocks tile [0, n): contiguous, disjoint, complete
    s = grid[0] * grid[1]
    starts = [part.owner_range(k) for k in range(s)]
    assert starts[0][0] == 0 and starts[-1][1] == ag.shape[0]
    for (_, e0), (b1, _) in zip(starts, starts[1:]):
        assert e0 == b1


@settings(max_examples=20, deadline=None)
@given(
    side=st.integers(min_value=4, max_value=7),
    grid=st.sampled_from(((2, 2), (3, 2), (2, 4))),
    fmt=st.sampled_from(("ell", "hyb", "bcsr")),
)
def test_expand_boundary_grid_roundtrip(side, grid, fmt):
    """expand_boundary inverts the boundary-row compaction on grid
    partitions exactly — the boundary block stays format-agnostic and
    layout-agnostic (same contract as the 1-D ring)."""
    from repro.core.partition import expand_boundary

    _, _, _, mat = _grid_mat(side, grid, fmt=fmt)
    de_full, ce_full = expand_boundary(mat)
    de = np.asarray(mat.data_ext)
    ce = np.asarray(mat.col_ext)
    rows = np.asarray(mat.bnd_rows)
    for s in range(mat.n_shards):
        nb = mat.n_bnd[s]
        sel = rows[s, :nb]
        np.testing.assert_array_equal(de_full[s, sel], de[s, :nb])
        np.testing.assert_array_equal(ce_full[s, sel], ce[s, :nb])
        other = np.ones(de_full.shape[1], bool)
        other[sel] = False
        assert (de_full[s, other] == 0).all()
        assert (ce_full[s, other] == 0).all()


def test_empty_row_groups_and_col_slabs():
    """Grids wider/taller than the cube leave shards owning zero rows —
    partitioning must not crash and must still conserve every entry."""
    for grid in ((2, 4), (4, 4)):
        ag, _, part, mat = _grid_mat(3, grid, seed=3)
        s = grid[0] * grid[1]
        owned = [part.n_own(k) for k in range(s)]
        assert sum(owned) == ag.shape[0]
        assert 0 in owned  # the degenerate case actually exercised
        got = (
            np.abs(np.asarray(mat.data_loc)).sum()
            + np.abs(np.asarray(mat.data_ext)).sum()
        )
        np.testing.assert_allclose(got, np.abs(ag.data).sum(), rtol=1e-5)


@pytest.mark.parametrize(
    "side,grid,stencil",
    [
        (10, (3, 2), "7pt"),  # side not divisible by the z split
        (8, (2, 4), "27pt"),  # corner shifts present
        (9, (2, 3), "27pt"),  # corners + non-divisible y split
        (8, (8, 4), "7pt"),  # 32 shards, z split == side
    ],
)
def test_halo_widths_match_closed_form_model(side, grid, stencil):
    """GridPlan's per-shift receive widths equal the roofline closed form
    (pencil_halo_widths) shift-for-shift — the executed ledger's
    halo-byte fields derive from the plan, so plan == model makes the
    ledger match the 2-D model exactly."""
    from repro.roofline.analysis import pencil_halo_widths

    _, p, _, mat = _grid_mat(side, grid, stencil)
    model = pencil_halo_widths(p, grid)
    assert dict(zip(mat.plan.shifts, mat.plan.widths)) == model


def test_gridplan_byte_and_launch_accounting():
    """Hop-weighted byte/launch accounting on a synthetic plan: a corner
    buffer crosses both links (2 launches, counted in both dimensions)."""
    from repro.core.partition import GridPlan

    plan = GridPlan(
        mode="grid",
        grid=(3, 4),
        shifts=((1, 0), (-1, 0), (0, 1), (0, -1), (1, 1)),
        widths=(10, 10, 6, 6, 2),
        n_own_pad=100,
        n_shards=12,
    )
    assert plan.n_launches == 6  # 4 faces + 1 corner x 2 hops
    assert plan.ext_len == 100 + 34
    assert plan.buf_offset(2) == 100 + 20
    # hop-weighted total: faces once, the corner twice
    assert plan.collective_bytes_per_shard(8) == (10 + 10 + 6 + 6 + 2 * 2) * 8
    rows_b, cols_b = plan.dim_bytes_per_shard(8)
    assert (rows_b, cols_b) == ((10 + 10 + 2) * 8, (6 + 6 + 2) * 8)
    assert rows_b + cols_b == plan.collective_bytes_per_shard(8)
    # receive-from semantics: shift (1, 0) means (i, j) <- (i + 1, j)
    assert plan.perm_rows(4) == ((1, 0), (2, 1))
    assert plan.perm_cols(4) == ((1, 0), (2, 1), (3, 2))


def test_1xN_grid_is_the_1d_layout_exactly():
    """--grid 1xN must reproduce today's 1-D partitioning bit-for-bit:
    partition_csr normalizes (1, N) to the ring plan, so every array and
    the plan itself are identical to the plain call."""
    from repro.core.partition import partition_csr
    from repro.matrices.poisson import cube, poisson_scipy

    a = poisson_scipy(cube(6, "7pt"))
    plain = partition_csr(a, 4)
    via_grid = partition_csr(a, 4, grid=(1, 4))
    assert via_grid.plan == plain.plan
    assert via_grid.plan.mode == "ring"
    for f in ("data_loc", "col_loc", "data_ext", "col_ext", "bnd_rows"):
        np.testing.assert_array_equal(
            np.asarray(getattr(via_grid, f)), np.asarray(getattr(plain, f))
        )


def test_matrix_axis_dispatch():
    """The dispatch hinge of the audit: every shard_map consumer derives
    its mesh axes from matrix_axis(mat). Ring plans ride the flat
    "shards" axis (which is what core.baselines hard-codes, by design —
    the Ginkgo-analog naive leg is 1-D only and api.solve's need_naive
    excludes grid runs); grid plans ride ("rows", "cols")."""
    from repro.core.partition import partition_csr
    from repro.core.spmv import matrix_axis
    from repro.matrices.poisson import cube, poisson_scipy

    a = poisson_scipy(cube(6, "7pt"))
    assert matrix_axis(partition_csr(a, 4)) == "shards"
    _, _, _, mat = _grid_mat(6, (2, 2))
    assert matrix_axis(mat) == ("rows", "cols")


def test_reduce_depth_model_32_shards():
    """Hierarchical reduction depth on an 8x4 grid: two staged launches,
    neither deeper than the longer sub-axis — vs one 5-deep tree flat."""
    from repro.roofline.analysis import reduce_hops, reduce_launches

    assert reduce_hops(32) == 5
    assert reduce_hops(32, (8, 4)) == 3
    assert reduce_launches() == 1
    assert reduce_launches((8, 4)) == 2
    assert reduce_hops(32, (1, 32)) == 5  # 1xN is the flat layout


# ---------------------------------------------------------------------------
# multi-device: 1-D vs 2-D agreement (subprocess, x64)
# ---------------------------------------------------------------------------

AGREE_SNIPPET = r"""
import numpy as np
from repro.core.cg import make_solver
from repro.core.partition import (
    pad_vector, partition_csr, pencil_partition, unpad_vector,
)
from repro.core.spmv import (
    make_spmv, matrix_axis, shard_matrix, shard_vector,
)
from repro.launch.mesh import make_grid_mesh, make_solver_mesh
from repro.matrices.poisson import cube, poisson_scipy
from repro.roofline.analysis import pencil_halo_widths

side = %(side)d
grid = %(grid)s
fmts = %(fmts)s
S = grid[0] * grid[1]
p = cube(side, "7pt")
a = poisson_scipy(p)
n = a.shape[0]
perm, part = pencil_partition(p, grid)
inv = np.empty(n, np.int64)
inv[perm] = np.arange(n)
ag = a[perm][:, perm].tocsr()
b = np.ones(n)
x = np.random.default_rng(7).standard_normal(n)

mesh1 = make_solver_mesh(S)
meshg = make_grid_mesh(*grid)

for fmt in fmts:
    mat1 = shard_matrix(mesh1, partition_csr(a, S, fmt=fmt))
    matg_h = partition_csr(ag, S, grid=grid, partition=part, fmt=fmt)
    assert matg_h.plan.mode == "grid", (fmt, matg_h.plan.mode)
    model = pencil_halo_widths(p, grid)
    assert dict(zip(matg_h.plan.shifts, matg_h.plan.widths)) == model
    matg = shard_matrix(meshg, matg_h)
    axis = matrix_axis(matg)
    assert axis == ("rows", "cols")

    xp1 = shard_vector(mesh1, pad_vector(x, mat1))
    xpg = shard_vector(meshg, pad_vector(x[perm], matg), axis)
    for overlap in (True, False):
        y1 = unpad_vector(
            np.asarray(make_spmv(mesh1, mat1, overlap=overlap)(mat1, xp1)),
            mat1,
        )
        yg = unpad_vector(
            np.asarray(
                make_spmv(meshg, matg, axis, overlap=overlap)(matg, xpg)
            ),
            matg,
        )
        d = np.abs(y1 - yg[inv]).max()
        assert d <= 1e-12, ("spmv", fmt, overlap, d)

    bp1 = shard_vector(mesh1, pad_vector(b, mat1))
    bpg = shard_vector(meshg, pad_vector(b[perm], matg), axis)
    for overlap in (True, False):
        r1 = make_solver(
            mesh1, mat1, tol=1e-10, maxiter=400, overlap=overlap
        )(bp1, np.zeros_like(bp1))
        rg = make_solver(
            meshg, matg, tol=1e-10, maxiter=400, axis=axis, overlap=overlap
        )(bpg, np.zeros_like(bpg))
        assert int(r1.iters) == int(rg.iters), (
            "iters", fmt, overlap, int(r1.iters), int(rg.iters)
        )
        assert int(r1.iters) < 400, ("no convergence", fmt, overlap)
        x1 = unpad_vector(np.asarray(r1.x), mat1)
        xg = unpad_vector(np.asarray(rg.x), matg)
        d = np.abs(x1 - xg[inv]).max()
        assert d <= 1e-12, ("solution", fmt, overlap, d)
print("scaleout-agree-ok")
"""


@pytest.mark.parametrize(
    "n_devices,grid,side,fmts",
    [
        (8, (2, 4), 12, ("ell", "hyb", "bcsr")),
        (16, (4, 4), 16, ("ell",)),
    ],
    ids=["8shards-allfmts", "16shards-ell"],
)
def test_1d_vs_2d_agreement(n_devices, grid, side, fmts):
    """SpMV and full CG agree between the 1-D ring and the 2-D grid to
    1e-12 (x64) on 8 and 16 emulated shards, overlap on and off, for
    every interior format — identical iteration counts, solutions equal
    up to the pencil permutation."""
    out = run_multidevice(
        AGREE_SNIPPET % {"side": side, "grid": repr(grid), "fmts": repr(fmts)},
        n_devices=n_devices,
    )
    assert "scaleout-agree-ok" in out


def test_1d_vs_2d_agreement_3x2_six_devices():
    """Regression for grid-shape assumptions: a rectangular non-power-of-
    two 3x2 mesh with a side (10) not divisible by the z split."""
    out = run_multidevice(
        AGREE_SNIPPET % {"side": 10, "grid": repr((3, 2)), "fmts": repr(("ell",))},
        n_devices=6,
    )
    assert "scaleout-agree-ok" in out


# ---------------------------------------------------------------------------
# multi-device: ledger invariants on the grid path (subprocess, x64)
# ---------------------------------------------------------------------------

LEDGER_SNIPPET = r"""
import math

from repro.api import ProblemSpec, SolverConfig, solve
from repro.matrices.poisson import cube
from repro.roofline.analysis import pencil_halo_widths

side, grid = 12, (2, 4)
rep = solve(
    ProblemSpec(problem="poisson7", side=side, shards=8),
    SolverConfig(grid="2x4", tol=1e-8, maxiter=200),
    verbose=False,
)
led = rep.ledger
assert led["grid"] == [2, 4], led["grid"]

# halo bytes match the closed-form pencil model EXACTLY, per dimension
# (a corner buffer would count in both; the 7pt stencil has none)
model = pencil_halo_widths(cube(side, "7pt"), grid)
rows_b = 8.0 * sum(w for (di, dj), w in model.items() if di != 0)
cols_b = 8.0 * sum(w for (di, dj), w in model.items() if dj != 0)
assert led["halo_bytes_rows"] == rows_b, (led["halo_bytes_rows"], rows_b)
assert led["halo_bytes_cols"] == cols_b, (led["halo_bytes_cols"], cols_b)

# per-region dynamic energies sum back to each solver's monitor total
for name, sol in led["solvers"].items():
    tot = sol["totals"]["de_total"]
    parts = sum(r["de_j"] for r in sol["regions"].values())
    assert math.isclose(parts, tot, rel_tol=1e-9), (name, parts, tot)
    assert sol["iters"] < 200, (name, "no convergence")

# 1xN identity: the grid spelling of the 1-D layout produces the same
# partition, so its payload carries the ring plan's traffic split
rep1 = solve(
    ProblemSpec(problem="poisson7", side=side, shards=8),
    SolverConfig(grid="1x8", tol=1e-8, maxiter=200),
    verbose=False,
)
assert rep1.ledger["grid"] == [1, 8]
assert rep1.ledger["halo_bytes_rows"] == 0.0
print("scaleout-ledger-ok")
"""


def test_grid_ledger_invariants():
    """api.solve on a 2x4 grid: ledger halo bytes equal the pencil model
    exactly, per-region energies sum to the monitor total, and the 1x8
    spelling reports the ring plan's traffic (rows lane empty)."""
    out = run_multidevice(LEDGER_SNIPPET, n_devices=8)
    assert "scaleout-ledger-ok" in out
