"""Shared test helpers.

NOTE: no XLA_FLAGS here — the main pytest process sees ONE device (smoke
tests / kernels). Multi-device distributed tests run in subprocesses via
``run_multidevice`` with the device-count env set only there.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

try:  # real hypothesis when available; deterministic stub otherwise
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub

    _hypothesis_stub.install()


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 900, x64: bool = True):
    """Run a python snippet in a subprocess with N host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nstdout:\n{r.stdout[-4000:]}\nstderr:\n{r.stderr[-4000:]}"
        )
    return r.stdout


@pytest.fixture(scope="session")
def single_mesh():
    import jax
    import numpy as np

    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
