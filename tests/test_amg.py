"""AMG components: matching properties, JAX/numpy matcher equivalence,
aggregation, hierarchy quality."""

import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.amg.aggregation import (
    compose_matchings,
    decoupled_aggregate,
    tentative_prolongator,
)
from repro.core.amg.galerkin import l1_diagonal, rap
from repro.core.amg.matching import (
    compatible_weights,
    greedy_scan_matching_np,
    locally_dominant_matching_jax,
    locally_dominant_matching_np,
    plain_weights,
    weights_to_ell,
)
from repro.matrices.poisson import cube, poisson_scipy


def _sym_weights(n, density, seed):
    a = sp.random(n, n, density=density, format="csr", random_state=seed)
    a = a + a.T
    a.setdiag(0)
    a.eliminate_zeros()
    a.data = np.abs(a.data) + 0.1
    return a.tocsr()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 60), density=st.floats(0.05, 0.4), seed=st.integers(0, 99))
def test_matching_is_valid(n, density, seed):
    """match is an involution with no self-pair conflicts."""
    w = _sym_weights(n, density, seed)
    wd, wc = weights_to_ell(w)
    for matcher in (locally_dominant_matching_np, greedy_scan_matching_np):
        match = matcher(wd, wc)
        assert (match[match] == np.arange(n)).all()  # involution
        paired = match != np.arange(n)
        if paired.any():
            # every matched pair is a real edge
            i = np.nonzero(paired)[0]
            for a_, b_ in zip(i, match[i]):
                assert w[a_, b_] != 0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_jax_matcher_equals_numpy(seed):
    w = _sym_weights(40, 0.2, seed)
    wd, wc = weights_to_ell(w)
    m_np = locally_dominant_matching_np(wd, wc)
    m_jx = np.asarray(locally_dominant_matching_jax(wd, wc))
    np.testing.assert_array_equal(m_np, m_jx)


def test_compatible_weights_formula():
    a = sp.csr_matrix(np.array([[2.0, -1.0], [-1.0, 3.0]]))
    w = compatible_weights(a)
    # c_01 = 1 - 2*(-1)*1*1 / (2+3) = 1.4
    assert np.isclose(w[0, 1], 1.4)
    p = plain_weights(a)
    assert np.isclose(p[0, 1], 1.0)


def test_aggregates_have_bounded_size():
    p = cube(10, "7pt")
    a = poisson_scipy(p)
    agg = compose_matchings(a, sweeps=3, weighting_fn=compatible_weights)
    sizes = np.bincount(agg)
    assert sizes.max() <= 8
    assert agg.min() == 0 and agg.max() + 1 <= p.n
    # good coarsening on Poisson: mean size near 8
    assert sizes.mean() > 4.0


def test_tentative_prolongator_columns_unit_norm():
    agg = np.array([0, 0, 1, 1, 1, 2])
    w = np.random.default_rng(0).uniform(0.5, 2.0, 6)
    p = tentative_prolongator(agg, w)
    norms = np.sqrt(np.asarray(p.multiply(p).sum(axis=0)).ravel())
    np.testing.assert_allclose(norms, 1.0, rtol=1e-12)


def test_decoupled_aggregation_is_block_diagonal():
    p = cube(8, "7pt")
    a = poisson_scipy(p)
    row_starts = (0, 128, 256, 384, 512)
    P_, coarse_starts = decoupled_aggregate(a, row_starts)
    coo = P_.tocoo()
    owners_fine = np.searchsorted(np.asarray(row_starts[1:]), coo.row, side="right")
    owners_coarse = np.searchsorted(np.asarray(coarse_starts[1:]), coo.col, side="right")
    assert (owners_fine == owners_coarse).all()


def test_rap_preserves_spd():
    p = cube(6, "7pt")
    a = poisson_scipy(p)
    P_, _ = decoupled_aggregate(a, (0, a.shape[0]))
    ac = rap(a, P_)
    assert (np.abs(ac - ac.T) > 1e-12).nnz == 0
    evals = np.linalg.eigvalsh(ac.toarray())
    assert evals.min() > 0


def test_l1_diagonal_dominates():
    p = cube(5, "7pt")
    a = poisson_scipy(p)
    d = l1_diagonal(a)
    # D_l1 >= |offdiag row sum| guarantees convergent Jacobi
    offdiag = np.abs(a).sum(axis=1).A1 - np.abs(a.diagonal())
    assert (d >= a.diagonal() + offdiag - 1e-12).all()


def test_hierarchy_coarsens_geometrically(single_mesh):
    from repro.core.amg import AMGParams, build_amg

    p = cube(12, "7pt")
    a = poisson_scipy(p)
    pre, info = build_amg(a, 1, AMGParams(coarse_size=50))
    rows = info.level_rows
    assert len(rows) >= 3
    for i in range(len(rows) - 1):
        assert rows[i + 1] <= rows[i] / 3  # near the 8x target
    assert info.operator_complexity < 1.6
