"""Distributed solve across the paper's comparison grid.

Runs BCMGX-analog vs Ginkgo-analog CG and the PCG pair (compatible-matching
AMG vs AmgX-analog plain aggregation) on a multi-device mesh, printing
runtime / iterations / modeled energy for each — examples of every solver
configuration the benchmarks use.

    python examples/solve_poisson.py            # 4 forced host devices
    python examples/solve_poisson.py --side 24 --devices 8
"""

import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--side", type=int, default=20)
    ap.add_argument("--devices", type=int, default=4)
    args = ap.parse_args()

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")

    def run(extra):
        cmd = [sys.executable, "-m", "repro.launch.solve",
               "--devices", str(args.devices), "--side", str(args.side)] + extra
        print(f"\n$ {' '.join(cmd[2:])}")
        subprocess.run(cmd, env=env, check=True)

    # un-preconditioned CG, all three BCMGX variants vs the Ginkgo analog
    for variant in ("hs", "fcg", "sstep"):
        run(["--problem", "poisson7", "--variant", variant, "--tol", "1e-8"])
    # 27-point stencil
    run(["--problem", "poisson27", "--variant", "fcg", "--tol", "1e-8"])
    # PCG: compatible-matching AMG vs the AmgX-analog
    run(["--problem", "poisson7", "--amg", "--tol", "1e-6"])
    run(["--problem", "poisson7", "--amgx-analog", "--tol", "1e-6"])
    # a SuiteSparse-analog matrix
    run(["--problem", "ecology2", "--scale", "0.01", "--tol", "1e-8"])


if __name__ == "__main__":
    main()
