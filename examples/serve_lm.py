"""Batched serving example: prefill a prompt batch, decode N tokens with the
KV/state cache — exercises the same serve_step the decode dry-run lowers.

    PYTHONPATH=src python examples/serve_lm.py --arch minicpm3-4b --tokens 16
    PYTHONPATH=src python examples/serve_lm.py --arch xlstm-350m  # recurrent
"""

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import lm, transformer as tfm
    from repro.models.kvcache import init_cache

    cfg = dataclasses.replace(get_config(args.arch).smoke(), dtype="float32")
    print(f"serving {cfg.name} (reduced config), batch={args.batch}")
    params = tfm.init_params(cfg, jax.random.key(0))

    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    max_len = S + args.tokens + 1
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # prefill, splice into a max_len cache
    t0 = time.perf_counter()
    logits, cache = lm.prefill(params, cfg, {"tokens": prompts}, kv_chunk=32)
    target = init_cache(cfg, B, max_len)
    cache = jax.tree.map(
        lambda dst, src: jnp.pad(
            src, [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        ).astype(dst.dtype) if src.shape != dst.shape else src.astype(dst.dtype),
        target, cache,
    )
    jax.block_until_ready(logits)
    print(f"prefill({S} tokens): {time.perf_counter()-t0:.2f}s")

    step = jax.jit(
        lambda p, t, c, pos: lm.serve_step(p, cfg, t, c, pos)
    )
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, tok, cache, jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens*B/dt:.1f} tok/s aggregate)")
    print("generated ids (first sequence):", gen[0].tolist())


if __name__ == "__main__":
    main()
