"""Quickstart: solve a Poisson system with distributed PCG + AMG, get the
energy report — the paper's workload end to end in ~30 lines of API.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.amg import build_amg
from repro.core.cg import solve_cg
from repro.core.partition import partition_csr, unpad_vector
from repro.core.spmv import shard_matrix
from repro.energy.accounting import CostModel, cg_iteration_counts, vcycle_counts
from repro.energy.monitor import PowerMonitor
from repro.launch.mesh import make_solver_mesh
from repro.matrices.poisson import cube, default_rhs, poisson_scipy

# 1. the paper's benchmark problem (scaled down for CPU)
problem = cube(20, "7pt")
a = poisson_scipy(problem)
b = default_rhs(problem.n)
print(f"3-D Poisson, 7-point stencil: n={problem.n}, nnz={a.nnz}")

# 2. distribute block-rows over every device (1 here; 64+ in production)
mesh = make_solver_mesh()
n_shards = mesh.devices.size
mat = shard_matrix(mesh, partition_csr(a, n_shards))
print(f"partitioned over {n_shards} shard(s), halo plan: {mat.plan.mode}")

# 3. AMG preconditioner (compatible weighted matching, size-8 aggregates)
precond, info = build_amg(a, n_shards)
print(f"AMG: {info.n_levels} levels, rows/level {info.level_rows}, "
      f"operator complexity {info.operator_complexity:.2f}")

# 4. solve: communication-reduced flexible CG (1 all-reduce per iteration)
res = solve_cg(mesh, mat, b, variant="fcg", precond=precond, tol=1e-8, maxiter=100)
x = unpad_vector(np.asarray(res.x), mat)
print(f"PCG converged in {int(res.iters)} iters, "
      f"relative residual {float(res.rel_residual):.2e}")
print(f"true residual: {np.linalg.norm(b - a @ x) / np.linalg.norm(b):.2e}")

# 5. energy profile (powerMonitor analog; §4 of the paper)
counts = cg_iteration_counts(mat, "fcg") + vcycle_counts(info, mat)
mon = PowerMonitor(n_devices=n_shards, cost=CostModel())
mon.idle(0.02)
mon.region("pcg", counts, n_shards=n_shards, repeats=int(res.iters))
mon.idle(0.02)
e = mon.energy()
print(f"modeled on TPU v5e: runtime {e['runtime']*1e3:.2f} ms, "
      f"dynamic energy {e['de_total']:.3f} J "
      f"(GPU {e['de_gpu']:.3f} + CPU {e['de_cpu']:.3f}), "
      f"power peak {e['gpu_power_peak']:.0f} W")
