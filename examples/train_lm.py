"""End-to-end LM training driver example.

Default: a ~13M-parameter mid-size config (between smoke and full) trained
for a few hundred steps on CPU with 4 forced host devices — checkpointing,
NaN-guard, deterministic resumable data, FSDP+TP sharding all active. On
real hardware, drop --midi and pass the full arch + production mesh.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 300 --smoke
"""

import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="runs/train_lm_ckpt")
    args = ap.parse_args()

    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--steps", str(args.steps),
        "--devices", str(args.devices), "--batch", "8", "--seq", "128",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--lr", "1e-3",
    ]
    if args.smoke:
        cmd.append("--smoke")
    print("$ " + " ".join(cmd[2:]))
    subprocess.run(cmd, env=env, check=True)


if __name__ == "__main__":
    main()
