"""Reproduce the paper's Fig. 2: the power-time profile of the SpMV kernel
with idle <-> active transition markers, rendered as ASCII + CSV.

    PYTHONPATH=src python examples/energy_profile.py
"""

from repro.core.cg import abstract_stencil_dist
from repro.energy.accounting import CostModel, spmv_counts
from repro.energy.monitor import PowerMonitor
from repro.matrices.poisson import PoissonProblem

N_GPUS = 4  # the paper's Fig 2: one node, four GPUs
REPEATS = 100

p = PoissonProblem(405, 405, 405 * N_GPUS, "7pt")
mat = abstract_stencil_dist(p, N_GPUS)
counts = spmv_counts(mat, overlap=True)

mon = PowerMonitor(n_devices=N_GPUS, cost=CostModel())
mon.idle(0.3, "idle (before)")
t = mon.region("spmv x100", counts, n_shards=N_GPUS, repeats=REPEATS)
mon.idle(0.3, "idle (after)")

ts, p_chip, p_host = mon.curve(hz=2000)
e = mon.energy()

# ASCII power-time curve
H, W = 16, 72
lo, hi = 55.0, max(p_chip.max() * 1.05, 80)
grid = [[" "] * W for _ in range(H)]
for i in range(W):
    seg = p_chip[int(i * len(ts) / W)]
    row = int((seg - lo) / (hi - lo) * (H - 1))
    grid[H - 1 - row][i] = "#"
print(f"power-time profile: SpMV kernel x{REPEATS}, {N_GPUS} devices "
      f"(modeled TPU v5e)\n")
for r, line in enumerate(grid):
    w = hi - (hi - lo) * r / (H - 1)
    print(f"{w:6.0f} W |" + "".join(line))
print("         +" + "-" * W)
print(f"          0s{' ' * (W - 12)}{ts[-1]:.3f}s")
print()
print(f"region duration (100 SpMVs): {t*1e3:.2f} ms")
print(f"static power: {mon.model.chip_static_w:.0f} W/device; "
      f"peak: {e['gpu_power_peak']:.0f} W")
print(f"static energy {e['se_gpu']:.1f} J | dynamic {e['de_gpu']:.2f} J | "
      f"GPU dyn as % of static: {e['gpu_pct']:.1f}%")

# CSV dump for plotting
import csv, os
os.makedirs("runs", exist_ok=True)
with open("runs/power_profile.csv", "w", newline="") as f:
    w = csv.writer(f)
    w.writerow(["t_s", "p_chip_w", "p_host_w"])
    for row in zip(ts, p_chip, p_host):
        w.writerow([f"{v:.6f}" for v in row])
print("wrote runs/power_profile.csv")
