"""Stage 1 — analytic model pruning of the tuning space.

Every candidate is priced per CG iteration by composing the models the
earlier PRs calibrated, without executing anything:

* **matrix traffic** — ``roofline/format_model`` stored-bytes per interior
  format (``ell_cost``/``hyb_cost``/``bcsr_cost``; ``auto`` resolved via
  ``choose_format``), swapped into the ELL-partitioned
  :func:`energy/accounting.spmv_counts` base (the halo plan and boundary
  block are format-agnostic, so only the interior stored-bytes term
  moves);
* **vector-op traffic** — ``roofline/analysis.CG_HOTPATH`` fused-stream
  counts (``cg_vector_traffic`` / ``cg_vector_flops``) plus the variant's
  all-reduce pattern (``CG_COMM`` — pipecg's hidden reduction is credited
  only when the overlap schedule is on);
* **time + power** — the :class:`CostModel` engine times and calibrated
  chip/host power at the candidate's DVFS point
  (``CostModel.at_freq`` → ``ChipSpec.at_freq``: compute and dynamic power
  scale with frequency, HBM/ICI stay flat — this is where race-to-idle
  vs. slow-and-efficient falls out analytically).

The survivors are the Pareto front over (time, energy) ranked by the
objective, truncated to the trial budget (counted in *executions* — see
:func:`prune`), with :data:`space.DEFAULT` always retained — so stage 2's
argmin can never pick something worse than the out-of-the-box
configuration.

The model is a *ranking* device: flops are taken from the ELL layout for
every format (padding-flop differences are second-order on memory-bound
kernels) and the per-iteration segments mirror — but simplify — the trace
regions. Stage 2 (``trial.py``) re-scores every survivor on executed
counts, so pruning-model bias cannot pick the winner on its own.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.autotune.objective import score as objective_score
from repro.autotune.space import DEFAULT, BCSR_BLOCKS, Candidate, sort_key
from repro.energy.accounting import CostModel, OpCounts, spmv_counts
from repro.roofline.analysis import (
    CG_COMM,
    cg_reduce_scalars,
    cg_vector_flops,
    cg_vector_traffic,
)
from repro.roofline.format_model import (
    bcsr_cost,
    choose_format,
    ell_cost,
    hyb_cost,
)


# ---------------------------------------------------------------------------
# Host-side interior statistics (cheap numpy sweeps over the CSR)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InteriorStats:
    """Per-shard interior row/block statistics of one partitioned problem."""

    n_rows: int  # padded rows per shard (R)
    shard_row_lens: tuple  # per shard: interior nnz of each local row
    shard_blocks: dict  # block side -> per-shard (n_blocks, max bpr)


def interior_stats(a_csr, row_starts, blocks=BCSR_BLOCKS) -> InteriorStats:
    """Interior row-length + BCSR block statistics per shard.

    ``row_starts`` is the contiguous block-row partition actually used by
    the trial stage (``DistMat.row_starts``), so the stats priced here are
    the stats packed there.
    """
    from repro.core.partition import block_stats_from_arrays

    a = a_csr.tocsr()
    indptr, indices = a.indptr, a.indices.astype(np.int64)
    n_shards = len(row_starts) - 1
    R = max(
        row_starts[s + 1] - row_starts[s] for s in range(n_shards)
    )
    lens, blk = [], {b: [] for b in blocks}
    for s in range(n_shards):
        lo, hi = row_starts[s], row_starts[s + 1]
        cols = indices[indptr[lo]:indptr[hi]]
        rows = np.repeat(
            np.arange(lo, hi, dtype=np.int64), np.diff(indptr[lo:hi + 1])
        )
        mask = (cols >= lo) & (cols < hi)
        r_loc, c_loc = rows[mask] - lo, cols[mask] - lo
        lens.append(np.bincount(r_loc, minlength=hi - lo).astype(np.int64))
        for b in blocks:
            # same tile-counting code path the BCSR packer uses, so the
            # priced layout is the packed layout
            blk[b].append(block_stats_from_arrays(r_loc, c_loc, R, b, b))
    return InteriorStats(
        n_rows=int(R),
        shard_row_lens=tuple(lens),
        shard_blocks={b: tuple(v) for b, v in blk.items()},
    )


def format_stored_bytes(stats: InteriorStats) -> dict:
    """Modeled interior stored bytes per format key (``ell``, ``hyb``,
    ``bcsr<b>``) — the quantity that moves a candidate's SpMV traffic."""
    out = {
        "ell": ell_cost(stats.shard_row_lens, stats.n_rows).stored_bytes,
        "hyb": hyb_cost(stats.shard_row_lens, stats.n_rows).stored_bytes,
    }
    for b, sb in stats.shard_blocks.items():
        out[f"bcsr{b}"] = bcsr_cost(
            sb, stats.n_rows, br=b, bc=b
        ).stored_bytes
    return out


def resolve_auto(stats: InteriorStats, block: int = 4) -> tuple[str, int]:
    """Resolve ``fmt="auto"`` exactly like ``partition_csr`` does — via the
    stored-bytes/traffic model — returning ``(fmt, block)``."""
    fmt, _ = choose_format(
        stats.shard_row_lens, n_rows=stats.n_rows,
        shard_blocks=stats.shard_blocks.get(block), br=block, bc=block,
    )
    return fmt, block


# ---------------------------------------------------------------------------
# Per-candidate per-iteration prediction
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Stage-1 output for one candidate: modeled per-iteration cost."""

    candidate: Candidate
    time_s: float  # modeled seconds per iteration
    energy_j: float  # modeled total (static+dynamic) J per iteration
    score: float  # objective score per iteration (lower is better)


def _hotpath_variant(candidate: Candidate, nrhs: int) -> str:
    """CG_HOTPATH/CG_COMM row a candidate's vector phase is priced with:
    multi-RHS solves run the block-HS body regardless of the (hs-only)
    candidate variant axis."""
    return "block_hs" if nrhs > 1 else candidate.variant


def phase_counts(
    mat_ell, candidate: Candidate, stored: dict, *, nrhs: int = 1
) -> tuple[OpCounts, OpCounts]:
    """Per-iteration, per-shard (SpMV-phase, vector-phase) counts.

    The SpMV phase starts from the executed-counts formula on the ELL
    partition and swaps the interior stored-bytes term for the candidate
    format's (the boundary block + halo plan are format-agnostic); the
    vector phase carries the variant's CG_HOTPATH streams and all-reduce
    pattern. ``nrhs`` > 1 prices the SpMM sweep (matrix bytes once, vector
    bytes r times) and the block-HS vector/Gram phase.
    """
    S = max(mat_ell.n_shards, 1)
    fmt_key = (
        f"bcsr{candidate.block}" if candidate.fmt == "bcsr" else candidate.fmt
    )
    sp = spmv_counts(mat_ell, overlap=candidate.overlap, nrhs=nrhs)
    delta = (stored[fmt_key] - stored["ell"]) / S
    # the format swap moves *matrix* bytes, so both totals shift together
    sp = dataclasses.replace(
        sp,
        hbm_bytes=sp.hbm_bytes + delta,
        hbm_matrix_bytes=sp.hbm_matrix_bytes + delta,
    )
    n = mat_ell.n_own_pad
    v = _hotpath_variant(candidate, nrhs)
    s = max(candidate.s, 1)
    if v == "sstep" and s > 1 and mat_ell.plan.mode in ("ring", "grid"):
        # matrix-powers pricing (ranking approximation — the trial stage
        # re-scores on the depth-s partition's executed counts): the
        # widened depth-s exchange moves ~the same bytes per iteration in
        # 1/s the launches; the ghost zone adds ~(s-1) boundary layers of
        # ~halo rows each, recomputed on all but the last application of
        # the block ((s-1)/s sweeps per iteration).
        halo = max(mat_ell.plan.ext_len - n, 0)
        slots_row = mat_ell.nnz_stored / S / max(n, 1)
        ghost_rows = halo * (s - 1) * (s - 1) / s
        sp = dataclasses.replace(
            sp,
            flops=sp.flops + 2.0 * slots_row * ghost_rows,
            hbm_bytes=sp.hbm_bytes + 12.0 * slots_row * ghost_rows,
            n_collectives=sp.n_collectives / s,
        )
    n_red = float(CG_COMM[v]["allreduces"])
    if v == "sstep":
        n_red /= s  # CG_COMM counts per s-iteration block
    vec = OpCounts(
        flops=cg_vector_flops(n, variant=v, nrhs=nrhs, s=s),
        hbm_bytes=cg_vector_traffic(n, variant=v, nrhs=nrhs, s=s),
        ici_bytes=8.0 * cg_reduce_scalars(v, nrhs, s=s),
        n_collectives=n_red,
    )
    return sp, vec


def iteration_counts(
    mat_ell, candidate: Candidate, stored: dict, *, nrhs: int = 1
) -> OpCounts:
    """Total per-iteration, per-shard :class:`OpCounts` of one candidate."""
    sp, vec = phase_counts(mat_ell, candidate, stored, nrhs=nrhs)
    return sp + vec


def predict(
    mat_ell, candidate: Candidate, stored: dict, *, cost: CostModel,
    objective: str, nrhs: int = 1,
) -> Prediction:
    """Model one candidate's per-iteration (time, energy, score).

    The iteration is composed as SpMV-phase + vector-phase, mirroring the
    trace regions: the halo collective is absorbed into the SpMV max() when
    the overlap schedule is on, and the variant's all-reduce latency is
    hidden behind the SpMV only for the reductions ``CG_COMM`` marks hidden
    (pipecg) — hs/fcg block on theirs.
    """
    S = max(mat_ell.n_shards, 1)
    fcost = cost.at_freq(candidate.freq)
    sp, vec = phase_counts(mat_ell, candidate, stored, nrhs=nrhs)
    v = _hotpath_variant(candidate, nrhs)
    t_sp, _ = fcost.times(sp, S, candidate.overlap)
    _, (tc2, tm2, tl2) = fcost.times(vec, S, True)
    hidden = CG_COMM[v]["hidden"] / max(CG_COMM[v]["allreduces"], 1)
    tl_hidden = min(tl2 * hidden, t_sp) if candidate.overlap else 0.0
    t = t_sp + max(tc2, tm2) + (tl2 - tl_hidden)

    c = sp + vec
    power = fcost.power
    p_chip = power.chip_power(c.flops / t, c.hbm_bytes / t, c.ici_bytes / t)
    # Host priced at idle for ranking: the monitor's active-host increment
    # scales with the comm *fraction*, so at ranking time it would reward
    # extra HBM traffic (more bytes -> smaller fraction -> cheaper host).
    # The measured stage prices trials through the full monitor model.
    p_host = power.host_power(0.0)
    n_hosts = max(S // 4, 1)
    totals = dict(
        runtime=t,
        te_gpu=p_chip * t * S,
        te_cpu=p_host * t * n_hosts,
    )
    return Prediction(
        candidate=candidate,
        time_s=t,
        energy_j=totals["te_gpu"] + totals["te_cpu"],
        score=objective_score(objective, totals),
    )


# ---------------------------------------------------------------------------
# Pareto filter + top-K
# ---------------------------------------------------------------------------


def pareto_front(preds: list[Prediction]) -> list[Prediction]:
    """Predictions not *strictly* dominated on (time, energy).

    Strict domination (worse on both axes) — not the weak kind: on
    memory-bound problems downclocking is modeled time-*free*, so a weak
    filter would kill every nominal-frequency candidate on an exact time
    tie. Model ties are precisely what the model must not resolve; the
    tied candidates ride to stage 2, where the measured argmin's
    tie-break (``space.sort_key``) prefers nominal frequency — i.e. a
    ``time``-objective tuner only downclocks when measurement, not the
    model, says it is free.
    """
    out = []
    for p in preds:
        dominated = any(
            q.time_s < p.time_s and q.energy_j < p.energy_j for q in preds
        )
        if not dominated:
            out.append(p)
    return out


def prune(
    candidates: list[Candidate],
    a_csr,
    mat_ell,
    *,
    cost: CostModel,
    objective: str,
    keep: int,
    nrhs: int = 1,
) -> tuple[list[Prediction], InteriorStats]:
    """Stage 1: score ``candidates`` analytically; keep the Pareto front's
    top-``keep`` *executions* (objective-ranked) plus :data:`space.DEFAULT`,
    each with its full frequency column (see the exec-key comment below).

    ``mat_ell`` is the ELL partition of ``a_csr`` (built once by the
    caller; trials reuse it) — it supplies the halo plan and padded shard
    shape the counts need. ``auto`` candidates are resolved to their
    concrete format here and deduplicated against the explicit ones.
    """
    stats = interior_stats(
        a_csr, mat_ell.row_starts,
        blocks=sorted({c.block for c in candidates if c.fmt == "bcsr"})
        or list(BCSR_BLOCKS),
    )
    stored = format_stored_bytes(stats)

    resolved: list[Candidate] = []
    seen: set[tuple] = set()
    for c in sorted(candidates, key=sort_key):
        if c.fmt == "auto":
            fmt, block = resolve_auto(stats, c.block)
            c = dataclasses.replace(c, fmt=fmt, block=block)
        key = (c.exec_key, c.freq)
        if key in seen:
            continue
        seen.add(key)
        resolved.append(c)

    preds = [
        predict(mat_ell, c, stored, cost=cost, objective=objective, nrhs=nrhs)
        for c in resolved
    ]
    front = sorted(
        pareto_front(preds), key=lambda p: (p.score, sort_key(p.candidate))
    )
    # The budget counts *executions* (trial solves). A candidate differing
    # from a survivor only in frequency shares its execution
    # (Candidate.exec_key) and is merely re-priced, so every chosen
    # execution brings its whole DVFS column along for free — the measured
    # stage then owns the race-to-idle vs. downclock call even when the
    # model's ranking collapsed (tiny latency-dominated problems).
    exec_keys: list[tuple] = []
    for p in front:
        if p.candidate.exec_key not in exec_keys:
            exec_keys.append(p.candidate.exec_key)
        if len(exec_keys) >= max(keep, 1):
            break
    if DEFAULT.exec_key not in exec_keys:
        exec_keys.append(DEFAULT.exec_key)
    survivors = sorted(
        (p for p in preds if p.candidate.exec_key in exec_keys),
        key=lambda p: (p.score, sort_key(p.candidate)),
    )
    return survivors, stats
