"""Persistent tuning cache + matrix fingerprinting.

A tuning decision is a property of (problem, partitioning, objective,
model), so the cache key hashes all four:

* the **matrix fingerprint** — cheap host-side statistics that identify a
  problem without hashing its values: n, nnz, row-nnz quantiles
  (0/25/50/75/100%), bandwidth (max |i − j| over the pattern);
* the **shard count** — a different partition is a different search space;
* the **objective** — energy / edp / time rank candidates differently;
* the **model hash** — every parameter of the :class:`CostModel` chain
  (PowerModel → ChipSpec/HostSpec, including the DVFS grid
  ``freq_points`` and ``v_floor``) plus the cache :data:`SCHEMA` version.
  Recalibrating the power model, changing the frequency grid, or bumping
  the entry schema silently invalidates every stale entry — they simply
  stop being findable (hygiene regression-tested in
  ``tests/test_autotune.py``).

Entries store the chosen candidate plus the fingerprint/model context for
debuggability; lookups recompute the key, never trust stored context.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np

from repro.autotune.space import Candidate
from repro.energy.accounting import CostModel

#: Cache entry schema version. Bump on any change to the entry layout or
#: to the meaning of the fingerprint/key — old files keep working, their
#: entries just stop matching. v2: fingerprint gained the ``nrhs`` key
#: (multi-RHS block solves tune separately from single-RHS ones).
SCHEMA = 2

#: Default on-disk location (relative to the process cwd, which is the
#: repo root for ``launch.solve`` / the benchmarks).
DEFAULT_PATH = os.path.join("runs", "autotune", "cache.json")

_QUANTILES = (0.0, 0.25, 0.5, 0.75, 1.0)


def fingerprint(a_csr, n_shards: int, objective: str, *,
                nrhs: int = 1) -> dict:
    """Cheap, stable identity of one tuning problem (see module doc).

    ``nrhs`` is part of the problem identity: a decision tuned for a
    single-RHS solve (SpMV-bound, latency-sensitive reductions) must never
    be served to a batched multi-RHS solve whose matrix traffic is
    amortized r ways — the format/frequency trade-offs differ."""
    a = a_csr.tocsr()
    row_nnz = np.diff(a.indptr)
    if row_nnz.size:
        q = [int(v) for v in np.quantile(row_nnz, _QUANTILES)]
    else:
        q = [0] * len(_QUANTILES)
    coo = a.tocoo()
    bandwidth = int(np.abs(coo.row - coo.col).max()) if coo.nnz else 0
    return dict(
        n=int(a.shape[0]),
        nnz=int(a.nnz),
        row_nnz_q=q,
        bandwidth=bandwidth,
        shards=int(n_shards),
        objective=str(objective),
        nrhs=int(nrhs),
    )


def model_hash(cost: CostModel) -> str:
    """Hash of every cost/power/chip parameter (incl. the DVFS grid)."""
    params = dataclasses.astuple(cost)  # recurses into PowerModel/ChipSpec
    return hashlib.sha1(repr(params).encode()).hexdigest()[:16]


class TuneCache:
    """JSON-file cache of tuning decisions (``runs/autotune/cache.json``)."""

    def __init__(self, path: str = DEFAULT_PATH):
        self.path = path

    # -- keying -------------------------------------------------------------

    def key(self, fp: dict, cost: CostModel) -> str:
        payload = dict(schema=SCHEMA, fingerprint=fp, model=model_hash(cost))
        return hashlib.sha1(
            json.dumps(payload, sort_keys=True).encode()
        ).hexdigest()

    # -- IO -----------------------------------------------------------------

    def _load(self) -> dict:
        if not os.path.exists(self.path):
            return {"schema": SCHEMA, "entries": {}}
        try:
            with open(self.path) as f:
                d = json.load(f)
        except (OSError, json.JSONDecodeError):
            return {"schema": SCHEMA, "entries": {}}
        if not isinstance(d, dict) or not isinstance(d.get("entries"), dict):
            return {"schema": SCHEMA, "entries": {}}
        return d

    def get(self, fp: dict, cost: CostModel) -> Candidate | None:
        """The cached choice for this (problem, objective, model), if any."""
        entry = self._load()["entries"].get(self.key(fp, cost))
        if not entry or entry.get("schema") != SCHEMA:
            return None
        try:
            return Candidate.from_dict(entry["chosen"])
        except (KeyError, TypeError, ValueError):
            return None

    def put(self, fp: dict, cost: CostModel, chosen: Candidate,
            extra: dict | None = None) -> str:
        """Persist a decision; returns the entry key. Atomic write."""
        d = self._load()
        k = self.key(fp, cost)
        d["schema"] = SCHEMA
        d["entries"][k] = dict(
            schema=SCHEMA,
            chosen=chosen.to_dict(),
            fingerprint=fp,
            model=model_hash(cost),
            **(extra or {}),
        )
        dirname = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(dirname, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(d, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        return k
