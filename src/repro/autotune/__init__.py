"""Energy-aware autotuning: model-pruned, trial-measured configuration
selection for the distributed sparse solver stack.

PRs 1–4 built the knobs — kernel backend, ELL/HYB/BCSR interiors, the
communication-hiding schedule, hs/fcg/pipecg — and the per-region executed
energy ledger that prices them. This subsystem closes the loop from
measurement to decision (docs/autotune.md):

1. :func:`space.enumerate_space` spans {format × variant × overlap × BCSR
   block × DVFS frequency};
2. :func:`prune.prune` scores the whole space analytically (stored-bytes
   format model + CG hot-path traffic + the frequency-extended power
   model) and keeps the top-K Pareto candidates;
3. :func:`trial.run_trials` runs each survivor for a few real iterations
   under the region trace and scores the *executed* ledger extrapolated
   to convergence;
4. the winner is persisted in a fingerprint-keyed cache
   (:class:`cache.TuneCache`, ``runs/autotune/cache.json``) so repeat
   solves skip the search entirely.

Entry point: :func:`autotune`. ``launch.solve --autotune`` wires it into
the solver driver and reports the decision in the ledger's ``autotune``
section (docs/ledger_schema.md).
"""

from __future__ import annotations

import dataclasses

from repro.autotune.cache import DEFAULT_PATH, TuneCache, fingerprint, model_hash
from repro.autotune.objective import OBJECTIVES, score, total_energy_j
from repro.autotune.pool import SessionPool, matrix_hash, session_key
from repro.autotune.prune import Prediction, interior_stats, prune
from repro.autotune.space import (
    DEFAULT,
    SSTEP_S,
    Candidate,
    enumerate_space,
    sort_key,
)
from repro.autotune.trial import Trial, extrapolate_iters, run_trials
from repro.energy.accounting import CostModel

__all__ = [
    "OBJECTIVES", "DEFAULT", "DEFAULT_PATH", "SSTEP_S", "Candidate",
    "Prediction", "SessionPool", "Trial", "TuneCache", "TuneResult", "autotune",
    "enumerate_space", "extrapolate_iters", "fingerprint", "interior_stats",
    "matrix_hash", "model_hash", "prune", "run_trials", "score",
    "session_key", "sort_key", "total_energy_j",
]


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one :func:`autotune` call (cache hit or full search)."""

    chosen: Candidate
    objective: str
    fingerprint: dict
    cached: bool  # True = served from the tuning cache, nothing ran
    candidates_total: int  # enumerated space size (0 on a cache hit)
    candidates_pruned: int  # dropped by the analytic model stage
    candidates_trialed: int  # executed trial solves (0 on a cache hit)
    trials: tuple  # Trial records, best score first

    def ledger_section(self) -> dict:
        """The ledger's ``autotune`` section (docs/ledger_schema.md)."""
        return dict(
            objective=self.objective,
            fingerprint=self.fingerprint,
            cached=self.cached,
            candidates_total=self.candidates_total,
            candidates_pruned=self.candidates_pruned,
            candidates_trialed=self.candidates_trialed,
            chosen=self.chosen.to_dict(),
            chosen_label=self.chosen.label,
            trials=[t.to_ledger() for t in self.trials],
        )


def autotune(
    a_csr,
    mesh,
    n_shards: int,
    *,
    objective: str = "energy",
    budget: int = 6,
    cost: CostModel | None = None,
    cache_path: str = DEFAULT_PATH,
    tol: float = 1e-8,
    trial_iters: int = 8,
    maxiter_cap: int = 10000,
    force: bool = False,
    mats: dict | None = None,
    nrhs: int = 1,
) -> TuneResult:
    """Select the solver configuration minimizing ``objective``.

    Args:
        a_csr: host scipy CSR system matrix (SPD).
        mesh: 1-D ``shards`` mesh the trials and the final solve run on.
        n_shards: shard count (part of the fingerprint — a different
            partition is a different search).
        objective: ``"energy"`` | ``"edp"`` | ``"time"``.
        nrhs: right-hand sides per solve. ``nrhs`` > 1 tunes the batched
            block solver: the variant axis collapses to ``hs`` (the block
            body is block-HS), the model stage prices the SpMM's amortized
            matrix traffic, and the trials run the block solver. The
            fingerprint carries ``nrhs``, so batched and single-RHS
            decisions never share a cache entry.
        budget: max candidates the trial stage may execute (top-K of the
            model stage's Pareto front; the default config always rides
            along, so at most ``budget + 1`` are scored).
        cost: cost model to price with (hashed into the cache key).
        cache_path: tuning-cache location (``runs/autotune/cache.json``).
        tol: solve tolerance the iteration extrapolation targets.
        trial_iters: real iterations each trial executes.
        maxiter_cap: extrapolation cap for stagnating trials.
        force: re-tune even on a cache hit (the fresh result overwrites).
        mats: optional ``(fmt, block) -> sharded DistMat`` cache shared
            with the caller, so the final solve reuses the winner's
            partition.

    Returns:
        :class:`TuneResult`; ``result.chosen`` is the winning
        :class:`Candidate`. On a cache hit nothing is partitioned or run
        (``cached=True``, ``candidates_trialed == 0``).
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {OBJECTIVES}: {objective}")
    nrhs = max(int(nrhs), 1)
    cost = cost or CostModel()
    fp = fingerprint(a_csr, n_shards, objective, nrhs=nrhs)
    cache = TuneCache(cache_path)
    if not force:
        hit = cache.get(fp, cost)
        if hit is not None:
            return TuneResult(
                chosen=hit, objective=objective, fingerprint=fp, cached=True,
                candidates_total=0, candidates_pruned=0,
                candidates_trialed=0, trials=(),
            )

    from repro.core.partition import default_grid, partition_csr
    from repro.core.spmv import shard_matrix

    mats = mats if mats is not None else {}
    ell_key = ("ell", DEFAULT.block)
    if ell_key not in mats:
        mats[ell_key] = shard_matrix(mesh, partition_csr(a_csr, n_shards))
    mat_ell = mats[ell_key]

    # The 2-D layout axis opens only where it can pay: below 8 shards the
    # default grid is 1xS or 2x2 — same or more halo surface than 1-D — so
    # small searches (and their cached decisions) are untouched.
    grids: tuple = (None,)
    if n_shards >= 8:
        g = default_grid(n_shards)
        if g[0] > 1:
            grids = (None, g)
    # The s-step axis opens at the same threshold: below it the exposed
    # collective latency sstep amortizes cannot pay for the redundant
    # ghost compute, and small searches (and their cached decisions)
    # stay byte-identical to the pre-sstep tuner.
    sstep_s: tuple = SSTEP_S if n_shards >= 8 else ()
    if nrhs > 1:
        # the block body is block-HS; the fcg/pipecg recurrences have no
        # block counterpart here, so the variant axis collapses
        candidates = enumerate_space(
            cost.power.chip, variants=("hs",), grids=grids
        )
    else:
        candidates = enumerate_space(
            cost.power.chip, grids=grids, sstep_s=sstep_s
        )
    survivors, _ = prune(
        candidates, a_csr, mat_ell, cost=cost, objective=objective,
        keep=budget, nrhs=nrhs,
    )
    trials = run_trials(
        a_csr, mesh, n_shards, survivors, cost=cost, objective=objective,
        tol=tol, trial_iters=trial_iters, maxiter_cap=maxiter_cap, mats=mats,
        nrhs=nrhs,
    )
    trials = sorted(trials, key=lambda t: (t.score, sort_key(t.candidate)))
    chosen = trials[0].candidate
    cache.put(fp, cost, chosen, extra=dict(objective=objective))
    return TuneResult(
        chosen=chosen, objective=objective, fingerprint=fp, cached=False,
        candidates_total=len(candidates),
        candidates_pruned=len(candidates) - len(survivors),
        candidates_trialed=sum(1 for t in trials if t.executed),
        trials=tuple(trials),
    )
