"""Search space of the energy-aware autotuner.

One :class:`Candidate` is a full operating point of the solver stack built
by the earlier layers — every axis maps onto an existing knob:

* ``fmt``     — interior storage format (``core/partition.py`` DistMat:
  ``ell`` / ``hyb`` / ``bcsr``, or ``auto`` = resolve via the stored-bytes
  cost model ``roofline/format_model.choose_format`` at prune time);
* ``block``   — BCSR tile side (``br == bc``; ignored by the other formats);
* ``variant`` — CG variant (``core/cg.py``: ``hs`` / ``fcg`` / ``pipecg``,
  plus ``sstep`` when the caller opens the ``s`` axis);
* ``s``       — s-step block size (``sstep`` only): the candidate's trial
  partition is rebuilt with ``halo_depth=s`` ghost zones so the
  matrix-powers basis pays ONE widened exchange and 1/s of a reduction
  per iteration, against (s-1)/s redundant ghost sweeps — the
  latency/redundancy trade the tuner prices per matrix;
* ``overlap`` — the communication-hiding schedule (``core/spmv.py``);
* ``freq``    — relative DVFS point (``roofline/hw.ChipSpec.at_freq``:
  compute + dynamic power scale down, HBM/ICI held flat).

The space is deliberately small (~100 points): stage 1 (``prune.py``)
scores all of it analytically, stage 2 (``trial.py``) measures only the
top-K survivors.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.roofline.hw import DEFAULT_CHIP, ChipSpec

FORMATS = ("ell", "hyb", "bcsr", "auto")
VARIANTS = ("hs", "fcg", "pipecg")
BCSR_BLOCKS = (2, 4, 8)
#: Tuned s-step block sizes (the ``sstep_s`` axis of ``enumerate_space``;
#: :func:`autotune.autotune` opens it at shard counts where exposed
#: collective latency can pay for redundant ghost compute, >= 8).
SSTEP_S = (2, 4, 6)
# deterministic variant order for sort_key; sstep ranks after the
# single-exchange variants (it is the most intrusive choice)
_VORDER = VARIANTS + ("sstep",)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One operating point of the tuning space."""

    fmt: str  # "ell" | "hyb" | "bcsr" | "auto" (resolved at prune time)
    variant: str  # "hs" | "fcg" | "pipecg" | "sstep"
    overlap: bool
    block: int = 4  # BCSR tile side; meaningful only when fmt == "bcsr"
    freq: float = 1.0  # relative DVFS point (ChipSpec.at_freq)
    grid: tuple | None = None  # (rows, cols) process grid; None = 1-D
    s: int = 1  # s-step block size; meaningful only when variant == "sstep"

    @property
    def exec_key(self) -> tuple:
        """Key of the *execution* this candidate requires. Frequency is not
        part of it — downclocking only re-prices the traced counts, so
        candidates differing solely in ``freq`` share one measured trial."""
        return (
            self.fmt,
            self.block if self.fmt == "bcsr" else 0,
            self.variant,
            self.overlap,
            self.grid,
            self.s if self.variant == "sstep" else 0,
        )

    @property
    def label(self) -> str:
        """Stable human/ledger label, e.g. ``hyb/pipecg/ov/f0.6`` (a 2-D
        candidate appends ``/gRxC``; an s-step one ``/s4``)."""
        fmt = f"bcsr{self.block}" if self.fmt == "bcsr" else self.fmt
        ov = "ov" if self.overlap else "ser"
        base = f"{fmt}/{self.variant}/{ov}/f{self.freq:g}"
        if self.grid is not None:
            base += f"/g{self.grid[0]}x{self.grid[1]}"
        if self.variant == "sstep":
            base += f"/s{self.s}"
        return base

    def to_dict(self) -> dict:
        d = dict(
            fmt=self.fmt, variant=self.variant, overlap=self.overlap,
            block=self.block, freq=self.freq,
        )
        # omitted when 1-D so pre-grid ledgers/caches stay byte-identical
        if self.grid is not None:
            d["grid"] = list(self.grid)
        # omitted when 1 so pre-sstep ledgers/caches stay byte-identical
        if self.s != 1:
            d["s"] = self.s
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        g = d.get("grid")
        return cls(
            fmt=str(d["fmt"]), variant=str(d["variant"]),
            overlap=bool(d["overlap"]), block=int(d["block"]),
            freq=float(d["freq"]),
            grid=tuple(int(v) for v in g) if g else None,
            s=int(d.get("s", 1)),
        )


#: The repo's out-of-the-box configuration (``launch.solve`` defaults):
#: ELL interior, HS-CG, communication hiding on, nominal frequency. The
#: pruner always keeps it, so the chosen config can never score worse.
DEFAULT = Candidate(fmt="ell", variant="hs", overlap=True, block=4, freq=1.0)


def sort_key(c: Candidate) -> tuple:
    """Deterministic preference order for score ties: nominal frequency
    first (never downclock without a measured win), then the simplest
    format/variant/schedule, 1-D layout before a process grid."""
    return (
        -c.freq,
        FORMATS.index(c.fmt),
        c.block,
        _VORDER.index(c.variant),
        not c.overlap,
        c.grid or (),
        c.s,
    )


def enumerate_space(
    chip: ChipSpec = DEFAULT_CHIP,
    *,
    formats: Iterable[str] = FORMATS,
    variants: Iterable[str] = VARIANTS,
    overlaps: Iterable[bool] = (True, False),
    blocks: Iterable[int] = BCSR_BLOCKS,
    freqs: Iterable[float] | None = None,
    grids: Iterable[tuple | None] = (None,),
    sstep_s: Iterable[int] = (),
) -> list[Candidate]:
    """All candidates, deterministically ordered (``sort_key``).

    ``freqs`` defaults to the chip's DVFS grid (``ChipSpec.freq_points``).
    ``bcsr`` fans out over ``blocks``; the other formats carry the default
    tile side (it is dead weight for them). ``grids`` defaults to the 1-D
    layout only; :func:`autotune.autotune` opens the grid axis at shard
    counts where a 2-D layout can pay (>= 8). ``sstep_s`` opens the
    communication-avoiding axis: each value adds ``sstep`` candidates at
    that block size (default closed — small searches and their cached
    decisions stay byte-identical; :func:`autotune.autotune` opens it at
    the same >= 8 shard threshold as the grid axis).
    """
    freqs = tuple(freqs) if freqs is not None else chip.freq_points
    out = []
    for fmt in formats:
        fmt_blocks = tuple(blocks) if fmt == "bcsr" else (DEFAULT.block,)
        for block in fmt_blocks:
            for variant in variants:
                for overlap in overlaps:
                    for freq in freqs:
                        for grid in grids:
                            out.append(
                                Candidate(fmt, variant, overlap, block,
                                          freq, grid)
                            )
            for s in sstep_s:
                for overlap in overlaps:
                    for freq in freqs:
                        for grid in grids:
                            out.append(
                                Candidate(fmt, "sstep", overlap, block,
                                          freq, grid, s=int(s))
                            )
    return sorted(out, key=sort_key)
