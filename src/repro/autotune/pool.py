"""Fingerprint-keyed pool of warm solver sessions.

The tuning cache (``cache.py``) identifies a problem by cheap host-side
statistics (n, nnz, row-nnz quantiles, bandwidth) — good enough for a
tuning *decision*, where a collision only costs optimality. A session is
different: it pins the matrix itself (``session.a``), so its key is
correctness-critical — two matrices with the same pattern statistics but
different values (the same mesh with updated coefficients, a routine
serving pattern) must NOT share a session, or later requests would be
solved against the wrong system. :func:`session_key` therefore extends
the statistical fingerprint with :func:`matrix_hash`, a sha1 over the
exact CSR structure and values; only byte-identical matrices collide.

Serving flow (``launch/serve_solver.py``): every request carries a host
CSR matrix; :meth:`SessionPool.session` fingerprints it, and a hit means
zero partitions and zero tuning trials for that request — the pool *is*
the in-process warm path, the same way ``runs/autotune/cache.json`` is the
cross-process one.

The pool is LRU-bounded (``capacity``): a long-running engine that sees a
stream of distinct matrices evicts the least-recently-used session instead
of pinning every host CSR, partition, and compiled solver forever. An
evicted session is closed (its partition and handle caches are dropped) —
resubmitting its matrix simply pays the cold path again.
"""

from __future__ import annotations

import hashlib
import json
from collections import OrderedDict

import numpy as np

from repro.autotune.cache import fingerprint

#: Default LRU bound on concurrently-warm sessions. Each session pins its
#: host CSR, every partition built through it, and every compiled solver —
#: unbounded growth is the failure mode, not a feature.
DEFAULT_CAPACITY = 8


def matrix_hash(a_csr) -> str:
    """sha1 over the exact CSR bytes (indptr, indices, data) + shape.

    This is the value-level identity the statistical fingerprint lacks:
    same-pattern matrices with different coefficients hash differently."""
    a = a_csr.tocsr()
    h = hashlib.sha1()
    h.update(repr((a.shape, a.indptr.dtype.str, a.indices.dtype.str,
                   a.data.dtype.str)).encode())
    h.update(np.ascontiguousarray(a.indptr).tobytes())
    h.update(np.ascontiguousarray(a.indices).tobytes())
    h.update(np.ascontiguousarray(a.data).tobytes())
    return h.hexdigest()


def session_key(a_csr, n_shards: int) -> str:
    """Stable string identity of (matrix statistics, exact content, shards).

    The statistical fields keep the key debuggable (they name the problem);
    ``sha1`` makes it correct (it names the matrix)."""
    fp = dict(fingerprint(a_csr, n_shards, "-"))
    # decision axes, not matrix identity: one session serves every
    # objective and batch width of the same partitioned matrix
    fp.pop("objective", None)
    fp.pop("nrhs", None)
    fp["sha1"] = matrix_hash(a_csr)
    return json.dumps(fp, sort_keys=True)


class SessionPool:
    """LRU ``session_key -> session`` with hit/miss/eviction accounting.

    ``factory(a_csr, n_shards, key=...)`` builds a session on a miss; the
    default is :class:`repro.api.SolverSession` (injected lazily to keep
    this module import-light — it must not pull jax in at import time).
    ``capacity`` bounds the number of warm sessions (``None`` = unbounded);
    inserting past it closes and drops the least-recently-used session.
    """

    def __init__(self, factory=None, capacity: int | None = DEFAULT_CAPACITY):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None: {capacity}")
        self._factory = factory
        self.capacity = capacity
        self.sessions: OrderedDict[str, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self.sessions)

    def session(self, a_csr, n_shards: int, **kw):
        """The warm session for this matrix identity (create on miss)."""
        key = session_key(a_csr, n_shards)
        s = self.sessions.get(key)
        if s is not None:
            self.hits += 1
            self.sessions.move_to_end(key)
            return s
        self.misses += 1
        factory = self._factory
        if factory is None:
            from repro.api import SolverSession

            factory = SolverSession
        s = factory(a_csr, n_shards, key=key, **kw)
        self.sessions[key] = s
        while self.capacity is not None and len(self.sessions) > self.capacity:
            _, evicted = self.sessions.popitem(last=False)
            self._close(evicted)
            self.evictions += 1
        return s

    @staticmethod
    def _close(session):
        close = getattr(session, "close", None)
        if callable(close):
            close()

    def get(self, key: str):
        return self.sessions.get(key)

    def stats(self) -> dict:
        """JSON-ready pool counters (the serving ledger's ``pool`` block)."""
        return dict(
            sessions=len(self.sessions), hits=self.hits, misses=self.misses,
            evictions=self.evictions,
            capacity=self.capacity if self.capacity is not None else 0,
        )

    def clear(self):
        for s in self.sessions.values():
            self._close(s)
        self.sessions.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
