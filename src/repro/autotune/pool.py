"""Fingerprint-keyed pool of warm solver sessions.

The tuning cache (``cache.py``) already identifies a problem by cheap
host-side statistics (n, nnz, row-nnz quantiles, bandwidth) plus the shard
count. The session pool reuses exactly that identity — minus the
objective/nrhs axes, which select a *decision*, not a *matrix* — to map an
incoming matrix to its warm :class:`repro.api.SolverSession`: the object
holding the partitions, the tuning decision and the compiled solvers.

Serving flow (``launch/serve_solver.py``): every request carries a host
CSR matrix; :meth:`SessionPool.session` fingerprints it, and a hit means
zero partitions and zero tuning trials for that request — the pool *is*
the in-process warm path, the same way ``runs/autotune/cache.json`` is the
cross-process one.
"""

from __future__ import annotations

import json

from repro.autotune.cache import fingerprint


def session_key(a_csr, n_shards: int) -> str:
    """Stable string identity of (matrix statistics, shard count)."""
    fp = dict(fingerprint(a_csr, n_shards, "-"))
    # decision axes, not matrix identity: one session serves every
    # objective and batch width of the same partitioned matrix
    fp.pop("objective", None)
    fp.pop("nrhs", None)
    return json.dumps(fp, sort_keys=True)


class SessionPool:
    """``session_key -> session`` with hit/miss accounting.

    ``factory(a_csr, n_shards, key=...)`` builds a session on a miss; the
    default is :class:`repro.api.SolverSession` (injected lazily to keep
    this module import-light — it must not pull jax in at import time).
    """

    def __init__(self, factory=None):
        self._factory = factory
        self.sessions: dict[str, object] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self.sessions)

    def session(self, a_csr, n_shards: int, **kw):
        """The warm session for this matrix fingerprint (create on miss)."""
        key = session_key(a_csr, n_shards)
        s = self.sessions.get(key)
        if s is not None:
            self.hits += 1
            return s
        self.misses += 1
        factory = self._factory
        if factory is None:
            from repro.api import SolverSession

            factory = SolverSession
        s = factory(a_csr, n_shards, key=key, **kw)
        self.sessions[key] = s
        return s

    def get(self, key: str):
        return self.sessions.get(key)

    def stats(self) -> dict:
        """JSON-ready pool counters (the serving ledger's ``pool`` block)."""
        return dict(
            sessions=len(self.sessions), hits=self.hits, misses=self.misses
        )

    def clear(self):
        self.sessions.clear()
        self.hits = 0
        self.misses = 0
