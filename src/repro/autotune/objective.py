"""Objectives the autotuner can minimize.

All three are computed from the same PowerMonitor ``totals`` dict (the
executed-energy ledger's ``totals`` section, or the pruning model's
per-iteration monitor — the two stages score through one function so model
and measurement can never rank on different quantities):

* ``energy`` — total Joules to solution, ``te_gpu + te_cpu``. *Total*
  (static + dynamic), not the ledger's dynamic-only ``de_total`` headline:
  race-to-idle only exists as a trade-off when the idle power a slower run
  keeps burning is charged to it.
* ``time``   — modeled runtime (seconds).
* ``edp``    — energy-delay product, ``energy * time``: the standard
  compromise metric when neither axis should be sacrificed outright.

Lower is better for all objectives.
"""

from __future__ import annotations

OBJECTIVES = ("energy", "edp", "time")


def total_energy_j(totals: dict) -> float:
    """Total (static + dynamic) chip + host energy of a ledger/monitor."""
    return float(totals["te_gpu"]) + float(totals["te_cpu"])


def score(objective: str, totals: dict) -> float:
    """Scalar score (lower is better) of one ``totals`` dict."""
    if objective == "energy":
        return total_energy_j(totals)
    if objective == "time":
        return float(totals["runtime"])
    if objective == "edp":
        return total_energy_j(totals) * float(totals["runtime"])
    raise ValueError(
        f"unknown objective {objective!r} (one of {OBJECTIVES})"
    )
