"""Stage 2 — measured trials of the pruning survivors.

Each surviving candidate runs for a few *real* iterations through the
existing machinery: its format is partitioned (``core/partition``), its
solver built (``core/cg.make_solver``) and executed under the region trace
(``energy/trace.capture``), so the trial's operation counts are the
executed counts of the lowered program — not the pruning model's. The
trial's measured convergence rate extrapolates the iteration count to the
requested tolerance, and ``trace.ledger_from_trace`` integrates the counts
at that iteration count through the candidate's DVFS-point cost model. The
decision therefore rests on measurements; the analytic model only chose
*what* to measure.

Candidates that differ only in frequency share one execution
(``Candidate.exec_key``): downclocking changes how traced counts are
priced, never what executes.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.autotune.objective import score as objective_score
from repro.autotune.objective import total_energy_j
from repro.autotune.prune import Prediction
from repro.autotune.space import Candidate
from repro.energy import trace
from repro.energy.accounting import CostModel


@dataclasses.dataclass(frozen=True)
class Trial:
    """One scored survivor: model prediction next to measurement."""

    candidate: Candidate
    executed: bool  # False = priced off another candidate's execution
    iters_trial: int  # iterations the trial solve actually ran
    relres_trial: float  # relative residual after the trial iterations
    iters_est: int  # iterations extrapolated to convergence
    predicted_time_s: float  # stage-1 model, extrapolated to iters_est
    predicted_energy_j: float
    measured_time_s: float  # executed-counts ledger at iters_est
    measured_energy_j: float
    score: float  # objective score of the measured ledger

    def to_ledger(self) -> dict:
        d = self.candidate.to_dict()
        d.update(
            label=self.candidate.label,
            executed=self.executed,
            iters_trial=self.iters_trial,
            iters_est=self.iters_est,
            predicted_time_s=self.predicted_time_s,
            predicted_energy_j=self.predicted_energy_j,
            measured_time_s=self.measured_time_s,
            measured_energy_j=self.measured_energy_j,
            score=self.score,
        )
        return d


def extrapolate_iters(
    iters: int, relres: float, tol: float, cap: int = 100000
) -> int:
    """Iterations to reach ``tol`` at the trial's measured reduction rate.

    The trial solve ran ``iters`` iterations and ended at relative residual
    ``relres``; assuming the per-iteration reduction factor
    ``rho = relres**(1/iters)`` persists, convergence needs
    ``log(tol)/log(rho)`` iterations. Already-converged (or zero-iteration)
    trials return their own count; a stagnating trial (rho ~ 1) returns
    ``cap``.
    """
    iters = int(iters)
    if iters <= 0:
        return 1
    if relres <= tol:
        return iters
    rho = relres ** (1.0 / iters)
    if rho >= 1.0 - 1e-12:
        return int(cap)
    need = math.ceil(math.log(tol) / math.log(rho))
    return int(min(max(need, iters), cap))


def run_trials(
    a_csr,
    mesh,
    n_shards: int,
    survivors: list[Prediction],
    *,
    cost: CostModel,
    objective: str,
    tol: float,
    trial_iters: int = 8,
    maxiter_cap: int = 10000,
    mats: dict | None = None,
    nrhs: int = 1,
) -> list[Trial]:
    """Execute (or share) one trial per survivor and score it.

    ``mats`` optionally seeds/collects the ``(fmt, block) -> sharded
    DistMat`` partition cache, letting the caller reuse the winner's
    partition for the final solve. With ``nrhs`` > 1 each trial runs the
    block solver on the deterministic RHS block; its convergence is the
    slowest column's (relres = max over columns), so the extrapolated
    iteration count covers the whole batch.
    """
    import jax

    from repro.core.cg import default_rhs_block, make_block_solver, make_solver
    from repro.core.partition import pad_block, pad_vector, partition_csr
    from repro.core.spmv import shard_matrix, shard_vector
    from repro.launch.mesh import make_grid_mesh
    from repro.roofline.analysis import reduce_hops

    mats = mats if mats is not None else {}
    executions: dict[tuple, tuple] = {}  # exec_key -> (trace, iters, relres)
    trials: list[Trial] = []
    for pred in survivors:
        c = pred.candidate
        first = c.exec_key not in executions
        if first:
            # an s-step candidate executes on a halo_depth=s partition (its
            # matrix-powers basis needs s-deep ghost zones); the depth tag
            # keeps it from colliding with the depth-1 entry in ``mats``
            depth = c.s if c.variant == "sstep" else 1
            if c.grid is not None:
                tmesh, axis = make_grid_mesh(*c.grid), ("rows", "cols")
                fmt_key = (c.fmt, c.block, c.grid)
            else:
                tmesh, axis = mesh, "shards"
                fmt_key = (c.fmt, c.block)
            if depth > 1:
                fmt_key = fmt_key + (("halo", depth),)
            if fmt_key not in mats:
                mats[fmt_key] = shard_matrix(
                    tmesh,
                    partition_csr(
                        a_csr, n_shards, fmt=c.fmt, block=(c.block, c.block),
                        grid=c.grid, halo_depth=depth,
                    ),
                )
            mat = mats[fmt_key]
            if nrhs > 1:
                solver = make_block_solver(
                    tmesh, mat, overlap=c.overlap, tol=tol,
                    maxiter=trial_iters, axis=axis,
                )
                Bp = pad_block(default_rhs_block(a_csr.shape[0], nrhs), mat)
                bp = shard_vector(tmesh, Bp, axis)
                x0 = shard_vector(tmesh, np.zeros_like(Bp), axis)
                with trace.capture() as tr:
                    res = solver(bp, x0)
                jax.block_until_ready(res.x)
                relres = float(np.max(np.asarray(res.rel_residual)))
            else:
                skw = {"s": c.s} if c.variant == "sstep" else {}
                solver = make_solver(
                    tmesh, mat, variant=c.variant, overlap=c.overlap,
                    tol=tol, maxiter=trial_iters, axis=axis, **skw,
                )
                b = np.ones(a_csr.shape[0])
                bp = shard_vector(tmesh, pad_vector(b, mat), axis)
                x0 = shard_vector(
                    tmesh, np.zeros_like(pad_vector(b, mat)), axis
                )
                with trace.capture() as tr:
                    res = solver(bp, x0)
                jax.block_until_ready(res.x)
                relres = float(res.rel_residual)
            executions[c.exec_key] = (tr, int(res.iters), relres)
        tr, iters, relres = executions[c.exec_key]
        iters_est = extrapolate_iters(iters, relres, tol, cap=maxiter_cap)
        ccost = cost
        if c.grid is not None:
            ccost = dataclasses.replace(
                cost, coll_hops=float(reduce_hops(n_shards, c.grid))
            )
        led = trace.ledger_from_trace(
            tr, iters=iters_est, n_shards=n_shards,
            cost=ccost.at_freq(c.freq), overlap=c.overlap,
        )
        tot = led["totals"]
        trials.append(
            Trial(
                candidate=c,
                executed=first,
                iters_trial=iters,
                relres_trial=relres,
                iters_est=iters_est,
                predicted_time_s=pred.time_s * iters_est,
                predicted_energy_j=pred.energy_j * iters_est,
                measured_time_s=float(tot["runtime"]),
                measured_energy_j=total_energy_j(tot),
                score=objective_score(objective, tot),
            )
        )
    return trials
