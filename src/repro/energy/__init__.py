"""Energy measurement methodology (the paper's C4), adapted to this runtime.

The paper samples internal sensors (LIKWID/RAPL for the CPU, NVML via the
powerMonitor tool for GPUs), integrates the power-time curve, and splits
energy into *static* (P_idle * T) and *dynamic* (total - static) parts.

This container has neither TPUs nor accessible RAPL counters, so the power
*source* is a calibrated analytical model (model.py) driven by the same
roofline activity terms the dry-run produces; everything else — region
markers, per-device power-time curves, integration, static/dynamic
decomposition, power-peak extraction, 5-run averaging — reproduces the
paper's methodology exactly (monitor.py / accounting.py). Absolute Joules
are model outputs; like the paper, the analysis emphasizes *relative*
comparisons between library variants.

Region markers are *executed-code* markers (trace.py): the kernel dispatch
layer and the distributed solver bodies record the OpCounts of every op
that runs into the innermost active region, so the integrated per-component
energies describe the program that was actually compiled — not a
hand-declared estimate.
"""

from repro.energy.accounting import OpCounts, CostModel  # noqa: F401
from repro.energy.attribution import split_block_energy  # noqa: F401
from repro.energy.model import PowerModel  # noqa: F401
from repro.energy.monitor import PowerMonitor  # noqa: F401
from repro.energy import trace  # noqa: F401
