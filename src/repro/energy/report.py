"""Table / CSV rendering of energy results in the paper's formats."""

from __future__ import annotations

import csv
import io


def fmt_table(rows: list[dict], columns: list[tuple[str, str]], title: str = "") -> str:
    """rows = list of dicts; columns = [(key, header)]. Plain-text table."""
    widths = [
        max(len(h), *(len(_fmt(r.get(k, ""))) for r in rows)) if rows else len(h)
        for k, h in columns
    ]
    out = io.StringIO()
    if title:
        out.write(f"== {title} ==\n")
    out.write(
        "  ".join(h.ljust(w) for (k, h), w in zip(columns, widths)) + "\n"
    )
    out.write("  ".join("-" * w for w in widths) + "\n")
    for r in rows:
        out.write(
            "  ".join(_fmt(r.get(k, "")).ljust(w) for (k, h), w in zip(columns, widths))
            + "\n"
        )
    return out.getvalue()


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4f}"
    return str(v)


def write_csv(path: str, rows: list[dict]):
    if not rows:
        return
    keys = []
    for r in rows:  # union of keys, first-seen order (rows may be ragged)
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        for r in rows:
            w.writerow(r)


# Column presets matching the paper's tables -------------------------------

SPMV_COLUMNS = [  # Table 7 analog
    ("n_shards", "#GPUs"),
    ("matrix", "matrix"),
    ("library", "library"),
    ("time", "time (s)"),
    ("de_gpu", "GPU dyn E (J)"),
    ("de_cpu", "CPU dyn E (J)"),
    ("de_total", "total dyn E (J)"),
    ("gpu_power_peak", "GPU peak (W)"),
]

STATIC_DYNAMIC_COLUMNS = [  # Tables 2-6 analog
    ("n_shards", "#GPUs"),
    ("library", "library"),
    ("gpu_pct", "GPU %"),
    ("cpu_pct", "CPU %"),
    ("total_pct", "total %"),
]

CG_COLUMNS = [  # Table 8 analog
    ("n_shards", "#GPUs"),
    ("matrix", "matrix"),
    ("library", "library"),
    ("iters", "iters"),
    ("time", "runtime (s)"),
    ("de_gpu", "GPU dyn E (J)"),
    ("de_cpu", "CPU dyn E (J)"),
    ("de_total", "total dyn E (J)"),
    ("gpu_power_peak", "GPU peak (W)"),
]

PCG_COLUMNS = [  # Fig 11-16 analog
    ("n_shards", "#GPUs"),
    ("library", "library"),
    ("iters", "iters"),
    ("setup_time", "setup (s)"),
    ("solve_time", "solve (s)"),
    ("de_total", "total dyn E (J)"),
    ("de_per_iter", "dyn E/iter (J)"),
    ("gpu_power_peak", "GPU peak (W)"),
]
