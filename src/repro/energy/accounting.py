"""Analytic operation counts + roofline cost/energy accounting.

``OpCounts`` carries the per-device activity of one operation: useful FLOPs,
HBM bytes moved, ICI bytes sent, and the number of distinct collectives
(which pays a latency cost per hop — the quantity the paper's
communication-*reduced* CG variants minimize).

``CostModel`` turns counts into modeled time and energy:

    T_compute = flops / peak_flops
    T_memory  = hbm_bytes / hbm_bw
    T_coll    = n_collectives * alpha * ceil(log2(S)) + ici_bytes / link_bw

    T = max(T_compute, T_memory) + T_coll          (serialized comm)
    T = max(T_compute, T_memory, T_coll)           (overlapped comm)

Overlap is a *property of the implementation*: the BCMGX-analog paths
(interior-first SpMV, fused reductions) are modeled overlapped; the
Ginkgo-analog paths (gather-then-compute, unfused dots) serialized. This is
exactly the distinction the paper credits for the performance/energy gap.

Counting conventions (double precision, 8 B values / 4 B indices):
* ELL SpMV: 2 flops per stored slot; HBM = slots*(8+4) matrix traffic +
  (n + halo)*8 vector reads + n*8 write.
* dot/axpy/norm: 2 flops per element; HBM = streamed operands + result.
* halo exchange: ici bytes = plan.collective_bytes_per_shard; allgather =
  (S-1)*R*8 per shard.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.partition import DistMat
from repro.energy.model import PowerModel


@dataclasses.dataclass(frozen=True)
class OpCounts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    n_collectives: float = 0.0
    # The subset of ``hbm_bytes`` that is matrix traffic (stored values +
    # index layout). Multi-RHS SpMM pays this ONCE per sweep while the
    # vector terms scale with the RHS count — tracking it separately is
    # what lets the ledger gate the amortization.
    hbm_matrix_bytes: float = 0.0

    def __add__(self, o: "OpCounts") -> "OpCounts":
        return OpCounts(
            self.flops + o.flops,
            self.hbm_bytes + o.hbm_bytes,
            self.ici_bytes + o.ici_bytes,
            self.n_collectives + o.n_collectives,
            self.hbm_matrix_bytes + o.hbm_matrix_bytes,
        )

    def __mul__(self, k: float) -> "OpCounts":
        return OpCounts(
            self.flops * k, self.hbm_bytes * k, self.ici_bytes * k,
            self.n_collectives * k, self.hbm_matrix_bytes * k,
        )

    __rmul__ = __mul__


ZERO = OpCounts()


# ---------------------------------------------------------------------------
# Per-operation analytic counts (per device / shard)
# ---------------------------------------------------------------------------

_VB = 8  # value bytes (f64); index bytes (4 B int32 local ids) live in the
# per-format DistMat.stored_bytes accounting (roofline/format_model.py)


def spmv_counts(mat: DistMat, overlap: bool = True, nrhs: int = 1) -> OpCounts:
    """One distributed SpMV (or ``nrhs``-wide SpMM sweep), per shard.

    Matrix traffic is the *format-aware* stored-bytes term
    (``DistMat.stored_bytes``: values + the index layout of the interior
    format — per-entry 4 B ids for ELL, the prefix + (col, row)-pair tail
    for HYB, per-block ids for BCSR), so the modeled SpMV cost moves with
    the storage format exactly like the executed trace counts do.

    With ``nrhs > 1`` the matrix term is paid ONCE while flops, vector
    traffic, and halo payload scale with the RHS count — the amortization
    the multi-RHS block solver is built to exploit.
    """
    S = max(mat.n_shards, 1)
    r = max(int(nrhs), 1)
    slots = mat.nnz_stored / S
    n = mat.n_own_pad
    ringlike = mat.plan.mode in ("ring", "grid")
    halo = mat.plan.ext_len - n if ringlike else n * (mat.n_shards - 1)
    flops = 2.0 * slots * r
    mat_bytes = mat.stored_bytes(_VB) / S
    hbm = mat_bytes + ((n + halo) + n) * _VB * r
    ici = float(mat.plan.collective_bytes_per_shard(_VB)) * r
    if mat.plan.mode == "grid":
        # per-dimension sub-axis ppermutes: corners launch twice (and their
        # payload crosses two links — priced in collective_bytes already)
        n_coll = float(mat.plan.n_launches)
    elif mat.plan.mode == "ring":
        n_coll = len(mat.plan.shifts)
    else:
        n_coll = 1.0
    if mat.n_shards == 1:
        ici, n_coll = 0.0, 0.0
    return OpCounts(flops, hbm, ici, n_coll, hbm_matrix_bytes=mat_bytes)


def dot_counts(n: int, fused_terms: int = 1) -> OpCounts:
    """``fused_terms`` inner products computed in one fused reduction."""
    return OpCounts(
        flops=2.0 * n * fused_terms,
        hbm_bytes=2.0 * n * _VB * fused_terms,
        ici_bytes=8.0 * fused_terms,
        n_collectives=1.0,
    )


def axpy_counts(n: int) -> OpCounts:
    return OpCounts(flops=2.0 * n, hbm_bytes=3.0 * n * _VB)


def cg_iteration_counts(mat: DistMat, variant: str = "hs", *,
                        s: int = 2) -> OpCounts:
    """Per-iteration counts of the *unpreconditioned* CG variants.

    hs   : 1 SpMV + 2 reductions (one fused pair) + 3 axpy-class updates
    fcg  : 1 SpMV + 1 fused reduction (3 terms) + 5 updates
    sstep: amortized per iteration — 1 SpMV + (1/s) fused Gram reduction
           (the (2s² + s + 1)-scalar payload) + ~4 block updates. When
           ``mat`` carries ghost zones at least ``s`` deep the basis routes
           through the matrix-powers SpMV (``core/spmv.matrix_powers``),
           so the halo exchange is paid once per BLOCK — its ici bytes and
           launches divide by ``s`` — and the redundant ghost-row recompute
           ((s-1)/s passes per iteration, priced from the actual packed
           ghost block) is added honestly.
    naive: 1 SpMV + 3 separate reductions + 3 updates (Ginkgo analog)
    amgx : optimized halo SpMV but 3 separate reductions (AmgX-CG analog:
           tuned kernels, no reduction fusion)
    """
    n = mat.n_own_pad
    overlap = variant not in ("naive",)
    sp = spmv_counts(mat, overlap)
    if variant == "hs":
        return sp + dot_counts(n) + dot_counts(n, 2) + 3 * axpy_counts(n)
    if variant == "amgx":
        return sp + 3 * dot_counts(n) + 3 * axpy_counts(n)
    if variant == "fcg":
        return sp + dot_counts(n, 3) + 5 * axpy_counts(n)
    if variant == "sstep":
        s = max(int(s), 1)
        gram = OpCounts(
            flops=2.0 * n * (2 * s * s + s) / s,
            hbm_bytes=2.0 * n * _VB * (s + 1) / s,
            ici_bytes=8.0 * (2 * s * s + s + 1) / s,
            n_collectives=1.0 / s,
        )
        if s > 1 and mat.halo_depth >= s and mat.plan.mode != "allgather":
            # matrix-powers basis: the (widened) exchange is launched once
            # per s-iteration block, not per iteration
            sp = OpCounts(
                sp.flops, sp.hbm_bytes, sp.ici_bytes / s,
                sp.n_collectives / s, sp.hbm_matrix_bytes,
            )
            S = max(mat.n_shards, 1)
            gs = mat.ghost_slots / S  # per-shard packed ghost-row slots
            if gs:
                # one ghost_matvec per interior application except the
                # last — (s-1)/s per iteration; formulas mirror
                # core/spmv.ghost_matvec's recorded counts exactly
                gmat = gs * (_VB + 4)
                ghost = OpCounts(
                    flops=2.0 * gs,
                    hbm_bytes=gmat + min(mat.plan.ext_len, gs) * _VB
                    + mat.n_ghost_rows * (_VB + 4),
                    hbm_matrix_bytes=gmat,
                )
                sp = sp + ((s - 1) / s) * ghost
        return sp + gram + 4 * axpy_counts(n)
    if variant == "naive":
        return sp + 3 * dot_counts(n) + 3 * axpy_counts(n)
    raise ValueError(variant)


def vcycle_counts(levels_info, mat0: DistMat, n_smooth: int = 4) -> OpCounts:
    """One V-cycle, per shard; ``levels_info`` = AMGInfo (rows/nnz per level).

    Approximation: each level's SpMV-class work scales with its nnz share;
    smoothing = n_smooth sweeps (each ~1 SpMV + 1 axpy) pre + post, plus one
    residual SpMV and the (local) restriction/prolongation traffic.
    """
    S = max(mat0.n_shards, 1)
    base = spmv_counts(mat0)
    total = ZERO
    nnz0 = max(levels_info.level_nnz[0], 1)
    for lvl in range(levels_info.n_levels - 1):
        scale = levels_info.level_nnz[lvl] / nnz0
        n_l = levels_info.level_rows[lvl] / S
        sweep = base * scale + axpy_counts(int(n_l))
        total = total + (2 * n_smooth + 1) * sweep + 2 * axpy_counts(int(n_l))
    # coarsest: replicated dense solve after an all-gather
    nc = levels_info.coarse_rows
    total = total + OpCounts(
        flops=2.0 * nc * nc / S,
        hbm_bytes=nc * nc * _VB / S,
        ici_bytes=nc * _VB,
        n_collectives=1.0,
    )
    return total


# ---------------------------------------------------------------------------
# Cost model: counts -> modeled time / energy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModel:
    power: PowerModel = PowerModel()
    alpha_latency: float = 5e-6  # per-collective latency per log2(S) hop [s]
    flops_efficiency: float = 0.85  # achievable fraction of peak (memory-bound
    # sparse kernels rarely hit peak BW either; same knob applies)
    bw_efficiency: float = 0.80
    # Per-collective tree depth override. None (the default) keeps the flat
    # 1-D law ceil(log2(S)); grid runs set this to
    # roofline.analysis.reduce_hops(S, grid) = ceil(log2(max(R, C))) — no
    # staged sub-axis launch is deeper than its longer sub-axis (the extra
    # launches are counted by the trace, not here).
    coll_hops: float | None = None

    def at_freq(self, freq: float) -> "CostModel":
        """The same cost model on the chip downclocked to ``freq``
        (relative; :meth:`ChipSpec.at_freq` — compute and dynamic power
        scale down, HBM/ICI bandwidth and the latency term stay flat)."""
        if freq == 1.0:
            return self
        return dataclasses.replace(self, power=self.power.at_freq(freq))

    def times(self, c: OpCounts, n_shards: int, overlap: bool):
        chip = self.power.chip
        t_comp = c.flops / (chip.peak_flops_f32 * self.flops_efficiency)
        t_mem = c.hbm_bytes / (chip.hbm_bw * self.bw_efficiency)
        if self.coll_hops is not None:
            hops = self.coll_hops
        else:
            hops = max(math.ceil(math.log2(max(n_shards, 2))), 1)
        t_coll = (
            c.n_collectives * self.alpha_latency * hops
            + c.ici_bytes / chip.ici_bw
        )
        if n_shards == 1:
            t_coll = 0.0
        if overlap:
            t = max(t_comp, t_mem, t_coll)
        else:
            t = max(t_comp, t_mem) + t_coll
        return t, (t_comp, t_mem, t_coll)

    def device_energy(self, c: OpCounts, n_shards: int, overlap: bool):
        """(time, total_J, dynamic_J, peak_W) for ONE device executing c."""
        t, _ = self.times(c, n_shards, overlap)
        if t <= 0:
            return 0.0, 0.0, 0.0, self.power.chip_static_w
        p = self.power.chip_power(
            c.flops / t, c.hbm_bytes / t, c.ici_bytes / t
        )
        total = p * t
        dyn = (p - self.power.chip_static_w) * t
        return t, total, dyn, p
