"""Per-request energy attribution for batched block solves.

A width-``r`` block solve produces ONE energy ledger for the whole batch
(``trace.ledger_from_trace`` at the executed iteration count). A serving
engine admits ``r`` independent requests into that batch, so the paper's
J/solve methodology needs the batch energy *split back* into per-request
shares. The block solver's deflation bookkeeping makes a causal split
possible: ``BlockSolveResult.iters_cols`` records the iteration at which
each column converged — i.e. for how many iterations each request's column
actually participated in the SpMM/Gram work.

Attribution model (:func:`split_block_energy`):

* the setup share (trace integrated at ``iters=0``: partition-resident
  setup ops, RHS norms) is divided equally among the real requests;
* each iteration's share ``(E_total - E_setup) / iters`` is divided
  equally among the real columns still *unconverged* at that iteration —
  a deflated column stops paying the moment it converges, exactly
  mirroring the deflation mask freezing its updates;
* an iteration in which *no* real column is still unconverged (the block
  solver normally stops at the last real column's convergence, so this
  only happens if a caller reports extra trailing iterations) has no
  causal owner: its share is batch overhead, divided equally among the
  real requests — never silently dumped on one of them;
* padding columns (slots the admission queue filled with zero RHS; they
  deflate at iteration 0) are charged nothing;
* the float rounding residue is assigned to the last real request, so the
  shares sum to the batch total *exactly* — the serving ledger's
  per-request energies are a partition of the engine total, not an
  approximation (asserted in ``tests/test_serve.py`` and gated within 5%
  in ``benchmarks/serve_bench.py``).
"""

from __future__ import annotations

import numpy as np


def split_block_energy(
    total_j: float,
    setup_j: float,
    iters: int,
    iters_cols,
    real,
) -> np.ndarray:
    """Split one batch's energy across its ``r`` columns; see module doc.

    Args:
        total_j: batch ledger total at the executed iteration count.
        setup_j: same trace integrated at ``iters=0`` (setup-only energy).
        iters: executed iteration count (the last column's convergence).
        iters_cols: (r,) per-column convergence iteration
            (``BlockSolveResult.iters_cols``; unconverged columns carry
            ``maxiter`` and are clipped to ``iters``).
        real: (r,) bool mask — False marks padding columns (charged 0).

    Returns:
        (r,) float64 shares; ``shares[real].sum() == total_j`` exactly,
        ``shares[~real] == 0``.
    """
    iters_cols = np.asarray(iters_cols, dtype=np.int64)
    real = np.asarray(real, dtype=bool)
    r = int(iters_cols.shape[0])
    if real.shape != (r,):
        raise ValueError(
            f"real mask shape {real.shape} != iters_cols shape ({r},)"
        )
    shares = np.zeros(r, dtype=np.float64)
    idx = np.flatnonzero(real)
    if idx.size == 0:
        return shares
    total_j = float(total_j)
    iters = int(iters)
    if iters <= 0:
        shares[idx] = total_j / idx.size
    else:
        cols = np.minimum(iters_cols, iters)
        # active[i] = real columns still unconverged at iteration i
        active = np.zeros(iters, dtype=np.float64)
        for j in idx:
            active[: cols[j]] += 1.0
        e_iter = (total_j - float(setup_j)) / iters
        # iterations with zero active real columns have no causal owner
        # (the solver ran past the last real convergence): their energy is
        # batch overhead, split equally, so the residue correction below
        # only ever absorbs float rounding — never whole iterations
        idle = active == 0.0
        overhead = e_iter * float(idle.sum()) / idx.size
        per_iter = np.where(idle, 0.0, e_iter / np.maximum(active, 1.0))
        cum = np.concatenate([[0.0], np.cumsum(per_iter)])
        shares[idx] = float(setup_j) / idx.size + overhead + cum[cols[idx]]
    # exact-sum correction: assign the float rounding residue to the last
    # real column (a few ulps), iterating in case the re-sum rounds again
    for _ in range(4):
        resid = total_j - float(shares.sum())
        if resid == 0.0:
            break
        shares[idx[-1]] += resid
    return shares
