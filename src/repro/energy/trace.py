"""Region-marked execution tracing: energy accounting for *executed* code.

The paper instruments its solvers with powerMonitor/LIKWID region markers so
that every Joule is attributed to the component that actually ran (SpMV,
reductions, halo exchange, AMG preconditioner — Fig. 1/2). This module is
the trace-time analog for the JAX reproduction:

* ``region(name)`` marks a component. Regions nest; a dispatched op is
  attributed to the **innermost** active region (so the halo exchange inside
  an SpMV inside a V-cycle lands in "halo", not "vcycle").
* the ``"overlap"`` region (:data:`OVERLAP`) is special by convention: it
  holds compute *and* communication that the implementation co-schedules
  (the interior matvec + in-flight halo exchange of the split SpMV, or the
  pipelined-CG all-reduce + concurrent SpMV). ``monitor_from_trace`` always
  models it overlapped — segment time ``max(compute, memory, collective)``
  — so the ledger's ``comm_hidden_s``/``comm_exposed_s`` fields quantify how
  much of its communication disappears behind compute.
* ``section(name)`` separates per-solve setup from the ``lax.while_loop``
  iteration body. Because the loop body is traced exactly once, counts
  recorded under ``section("iteration")`` are *per-iteration* counts of the
  code that executes — not hand-declared estimates.
* ``record_op(op, counts)`` is called by the instrumented layers — the
  kernel dispatch OpSet (kernels/dispatch.py), the distributed vector ops
  (core/vectors.py), the SpMV/halo path (core/spmv.py), and the AMG V-cycle
  (core/amg/vcycle.py) — with the :class:`OpCounts` of one op invocation.

Recording happens at JAX *trace* time only (like PR 1's sweep ledger): it
costs nothing at execution time, and tracing a jitted solver under
``capture()`` yields the exact per-region, per-iteration operation counts of
the lowered program. ``monitor_from_trace`` then replays those counts —
scaled by the executed iteration count — through the PowerMonitor, giving a
per-region energy ledger that sums to the monitor total by construction.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import Counter

from repro.energy.accounting import ZERO, OpCounts

DEFAULT_REGION = "other"
SETUP = "setup"
ITERATION = "iteration"
# Region holding co-scheduled compute + communication (always modeled
# overlapped — see module docstring and energy/monitor.py).
OVERLAP = "overlap"


@dataclasses.dataclass
class RegionTally:
    """Accumulated counts + per-op call counter for one (section, region)."""

    counts: OpCounts = ZERO
    calls: Counter = dataclasses.field(default_factory=Counter)

    def add(self, op: str, c: OpCounts):
        self.counts = self.counts + c
        self.calls[op] += 1


class EnergyTrace:
    """Per-section, per-region operation counts gathered during tracing.

    ``sections[section][region]`` is a :class:`RegionTally`;
    ``entries[section]`` counts how many times the section was entered
    (normally once per trace — used to normalize if JAX retraces a body,
    e.g. the while_loop carry fixed-point pass).
    """

    def __init__(self):
        self.sections: dict[str, dict[str, RegionTally]] = {}
        self.entries: dict[str, int] = {}

    def enter(self, section: str):
        self.entries[section] = self.entries.get(section, 0) + 1

    def record(self, section: str, region: str, op: str, counts: OpCounts):
        self.sections.setdefault(section, {}).setdefault(
            region, RegionTally()
        ).add(op, counts)

    # -- views --------------------------------------------------------------

    def regions(self, section: str) -> dict[str, OpCounts]:
        """region -> OpCounts per section entry (per-iteration for the
        iteration section)."""
        norm = max(self.entries.get(section, 1), 1)
        return {
            name: tally.counts * (1.0 / norm)
            for name, tally in self.sections.get(section, {}).items()
        }

    def calls(self, section: str) -> dict[str, Counter]:
        return {
            name: tally.calls
            for name, tally in self.sections.get(section, {}).items()
        }

    def region_names(self) -> tuple[str, ...]:
        names: list[str] = []
        for sec in self.sections.values():
            for name in sec:
                if name not in names:
                    names.append(name)
        return tuple(names)

    @property
    def empty(self) -> bool:
        return not any(self.sections.values())

    def total(self, section: str | None = None) -> OpCounts:
        out = ZERO
        for sec, regs in self.sections.items():
            if section is not None and sec != section:
                continue
            norm = max(self.entries.get(sec, 1), 1)
            for tally in regs.values():
                out = out + tally.counts * (1.0 / norm)
        return out


# ---------------------------------------------------------------------------
# Module state: active trace + region stack + section
# ---------------------------------------------------------------------------

_trace: EnergyTrace | None = None
_stack: list[str] = []
_section: str = SETUP
_scale: float = 1.0


@contextlib.contextmanager
def capture():
    """Activate an :class:`EnergyTrace`; trace (jit/lower) solvers inside."""
    global _trace
    prev = _trace
    _trace = EnergyTrace()
    try:
        yield _trace
    finally:
        _trace = prev


@contextlib.contextmanager
def region(name: str):
    """Mark a component region for the ops recorded inside.

    ``name`` is a free-form region label; the solver layers use
    ``"spmv"``/``"halo"``/``"reductions"``/``"precond"``/``"vcycle"`` and
    the special :data:`OVERLAP`. Regions nest — an op is attributed to the
    *innermost* active region. Trace-time only: entering a region during
    execution of a compiled program costs nothing (markers run while JAX
    traces the python body).
    """
    _stack.append(name)
    try:
        yield
    finally:
        _stack.pop()


@contextlib.contextmanager
def section(name: str):
    """Switch the accounting section — :data:`SETUP` (default, straight-line
    per-solve code) vs :data:`ITERATION` (the ``lax.while_loop`` body).

    Counts recorded under a section are normalized by how many times the
    section was entered during the trace, then replayed per executed
    iteration (ITERATION) or per benchmark repeat (SETUP) by
    :func:`monitor_from_trace`. Solver bodies switch via
    ``kernels.dispatch.ledger_section`` so the sweep ledger stays in
    lockstep.
    """
    global _section
    prev = _section
    _section = name
    if _trace is not None:
        _trace.enter(name)
    try:
        yield
    finally:
        _section = prev


def active() -> EnergyTrace | None:
    return _trace


def current_region() -> str:
    return _stack[-1] if _stack else DEFAULT_REGION


def current_section() -> str:
    return _section


@contextlib.contextmanager
def repeated(k: float):
    """Scale ops recorded inside by ``k`` — for bodies that JAX traces once
    but executes ``k`` times (``lax.scan`` / ``lax.fori_loop`` with a static
    trip count, e.g. the s-step basis build). Fractional ``k`` normalizes a
    body whose one trace covers several accounting units — the s-step while
    body wraps its block in ``repeated(1/s)`` so the recorded counts are the
    per-iteration average the ledger replays."""
    global _scale
    prev = _scale
    _scale = _scale * k
    try:
        yield
    finally:
        _scale = prev


def record_op(op: str, counts: OpCounts):
    """Attribute one op invocation to the innermost region.

    ``op`` is a per-op label for the call counter; ``counts`` the
    per-device :class:`OpCounts` of ONE invocation (flops, HBM bytes, ICI
    bytes, collective launches). No-op when no trace is active —
    execution-time calls never pay for this.
    """
    if _trace is not None:
        if _scale != 1.0:
            counts = counts * _scale
        _trace.record(_section, current_region(), op, counts)


def record_collective(n_scalars: int, itemsize: int = 8, op: str = "allreduce"):
    """One fused all-reduce of ``n_scalars`` scalars of ``itemsize`` bytes
    (ici_bytes = n_scalars * itemsize, one collective launch — i.e. one
    latency hop term in the cost model)."""
    record_op(
        op,
        OpCounts(ici_bytes=float(n_scalars * itemsize), n_collectives=1.0),
    )


# ---------------------------------------------------------------------------
# Executed-counts formulas (single source — the dispatch layer, the
# distributed vector ops, the naive baseline, and the V-cycle all account
# streamed vector work through these, so the gated energy baselines cannot
# drift apart per call site)
# ---------------------------------------------------------------------------


def streamed_axpy_counts(n: int, itemsize: int, fused: int = 1) -> OpCounts:
    """``fused`` axpy-class updates in one pass: per update, stream x and y
    in and the result out (2 flops per element)."""
    return OpCounts(flops=2.0 * n * fused, hbm_bytes=3.0 * n * itemsize * fused)


def local_dots_counts(pairs) -> OpCounts:
    """Local partial inner products for ``[(x, y), ...]``: 2n flops per
    pair; each *distinct* operand streamed once (fused kernels dedup
    repeated vectors — id() is stable for tracers during one trace)."""
    n = pairs[0][0].size
    itemsize = pairs[0][0].dtype.itemsize
    distinct = {id(a) for x, y in pairs for a in (x, y)}
    return OpCounts(
        flops=2.0 * n * len(pairs),
        hbm_bytes=float(len(distinct)) * n * itemsize,
    )


def fused_dots_counts(pairs, n_out: int | None = None) -> OpCounts:
    """Local dots + the ONE all-reduce of the ``n_out`` reduced scalars."""
    itemsize = pairs[0][0].dtype.itemsize
    return local_dots_counts(pairs) + OpCounts(
        ici_bytes=float((n_out or len(pairs)) * itemsize), n_collectives=1.0
    )


def block_gram_counts(pairs) -> OpCounts:
    """Local (r, r) Gram blocks for ``[(X, Y), ...]`` of (n, r) operands:
    2·n·r² flops per pair; each *distinct* block streamed once (the block
    kernel dedups repeated operands, order-sensitively)."""
    n, r = pairs[0][0].shape
    itemsize = pairs[0][0].dtype.itemsize
    distinct = {id(a) for x, y in pairs for a in (x, y)}
    return OpCounts(
        flops=2.0 * n * r * r * len(pairs),
        hbm_bytes=float(len(distinct)) * n * r * itemsize,
    )


def block_update_counts(n: int, r: int, itemsize: int,
                        terms: int = 1) -> OpCounts:
    """``terms`` block updates ``Y + X @ M`` in one pass: per term, stream
    X and Y in and the result out (2·n·r² matmul flops + n·r adds); the
    (r, r) coefficient blocks are noise next to the streamed blocks."""
    return OpCounts(
        flops=(2.0 * n * r * r + n * r) * terms,
        hbm_bytes=3.0 * n * r * itemsize * terms,
    )


def pointwise_counts(n: int, itemsize: int, reads: int) -> OpCounts:
    """Elementwise vector work not covered by a dispatch op: ``reads``
    streamed operands + one written result, one flop per read."""
    return OpCounts(
        flops=float(reads * n), hbm_bytes=float((reads + 1) * n * itemsize)
    )


# ---------------------------------------------------------------------------
# Trace -> PowerMonitor ledger
# ---------------------------------------------------------------------------


def monitor_from_trace(
    tr: EnergyTrace,
    *,
    iters: int,
    n_shards: int,
    cost=None,
    devices_per_host: int = 4,
    overlap: bool = True,
    idle_s: float = 0.0,
    setup_repeats: int = 1,
):
    """Integrate the traced per-region counts into a PowerMonitor.

    Setup-section regions are replayed ``setup_repeats`` times (1 for a
    solve; the repeat count for a benchmark that re-runs a straight-line
    program); iteration-section regions are replayed ``iters`` times (the
    executed iteration count). The resulting monitor's segment names are the
    region names, so ``monitor.energy_by_region()`` is the executed
    per-component ledger and sums to ``monitor.energy()`` totals exactly.

    ``overlap`` is the implementation-wide default (True for the
    BCMGX-analog paths, False for the serialized Ginkgo analog); the
    :data:`OVERLAP` region is always modeled overlapped regardless — that
    region *is* the co-scheduled compute+communication phase.
    """
    from repro.energy.monitor import PowerMonitor

    mon = PowerMonitor(
        n_devices=n_shards, cost=cost, devices_per_host=devices_per_host
    )
    if idle_s > 0:
        mon.idle(idle_s)
    # hides_comm: only the OVERLAP region's compute is independent of its
    # collective by construction, so only it earns comm_hidden_s credit — a
    # blocking all-reduce (hs/fcg reductions) keeps the overlapped time
    # model but reports its latency exposed (matches roofline CG_COMM).
    for name, c in sorted(tr.regions(SETUP).items()):
        mon.region(
            name, c, n_shards=n_shards, overlap=overlap or name == OVERLAP,
            hides_comm=name == OVERLAP, repeats=max(int(setup_repeats), 1),
            section=SETUP,
        )
    for name, c in sorted(tr.regions(ITERATION).items()):
        mon.region(
            name, c, n_shards=n_shards, overlap=overlap or name == OVERLAP,
            hides_comm=name == OVERLAP, repeats=max(int(iters), 1),
            section=ITERATION,
        )
    if idle_s > 0:
        mon.idle(idle_s)
    return mon


def ledger_from_trace(
    tr: EnergyTrace,
    *,
    iters: int,
    n_shards: int,
    cost=None,
    devices_per_host: int = 4,
    overlap: bool = True,
    idle_s: float = 0.0,
    setup_repeats: int = 1,
) -> dict:
    """JSON-ready executed-energy ledger: per-region + totals.

    ``regions[name]`` carries modeled time, dynamic/total energy, the
    exposed-vs-hidden communication split (``comm_s`` / ``comm_exposed_s`` /
    ``comm_hidden_s``), and the raw activity counts; ``totals`` is the
    PowerMonitor energy dict (same comm split summed over regions). The idle
    padding segments carry zero dynamic energy and zero counts, so they are
    dropped from ``regions`` (their duration still extends
    ``totals.runtime`` and the static-energy terms) — by construction
    ``sum(regions[*].de_j) == totals.de_total``. Field-by-field reference:
    ``docs/ledger_schema.md``.
    """
    mon = monitor_from_trace(
        tr, iters=iters, n_shards=n_shards, cost=cost,
        devices_per_host=devices_per_host, overlap=overlap, idle_s=idle_s,
        setup_repeats=setup_repeats,
    )
    by_region = {
        k: v for k, v in mon.energy_by_region().items() if k != "idle"
    }
    iter_counts = tr.regions(ITERATION)
    setup_counts = tr.regions(SETUP)
    regions = {}
    for name, e in by_region.items():
        c = setup_counts.get(name, ZERO) * float(
            max(int(setup_repeats), 1)
        ) + iter_counts.get(name, ZERO) * float(max(int(iters), 1))
        regions[name] = dict(
            e,
            flops=c.flops,
            hbm_bytes=c.hbm_bytes,
            hbm_matrix_bytes=c.hbm_matrix_bytes,
            ici_bytes=c.ici_bytes,
            n_collectives=c.n_collectives,
        )
    return dict(
        iters=int(iters),
        n_shards=int(n_shards),
        regions=regions,
        totals=mon.energy(),
    )
