"""powerMonitor analog: region-marked power-time curves + integration.

Reproduces the workflow of the paper's powerMonitor/GPowerU + LIKWID
MarkerAPI setup (Fig. 1): a monitor is started, the application executes
region-marked kernels, and per-device power samples are integrated into
total / static / dynamic energy, with idle<->active transition markers and
power-peak extraction (Fig. 2).

Because the power source here is the analytical model (see energy/model.py),
a "sample" is generated from the region's activity rates rather than read
from NVML; the sampling frequency (default 1 kHz, the paper samples NVML
~20x per ms) only affects curve rendering, not the integral, which is
computed exactly per segment.
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager

import numpy as np

from repro.energy.accounting import CostModel, OpCounts
from repro.energy.model import PowerModel


@dataclasses.dataclass
class Segment:
    name: str
    t0: float
    t1: float
    chip_w: float  # per-device power during this segment
    host_active: float  # host active fraction (drives comm/launch)
    # modeled engine times over the whole segment (all repeats), seconds
    t_comp: float = 0.0
    t_mem: float = 0.0
    t_coll: float = 0.0
    overlapped: bool = True  # was the collective co-scheduled with compute?
    section: str = ""  # accounting section ("setup"/"iteration"/"idle")

    @property
    def dt(self) -> float:
        return self.t1 - self.t0

    @property
    def comm_hidden_s(self) -> float:
        """Collective time absorbed behind compute/memory (overlap model)."""
        if not self.overlapped:
            return 0.0
        return min(self.t_coll, max(self.t_comp, self.t_mem))

    @property
    def comm_exposed_s(self) -> float:
        """Collective time the segment actually waits on."""
        return self.t_coll - self.comm_hidden_s


class PowerMonitor:
    """Builds per-device power-time curves from region-marked execution."""

    def __init__(
        self,
        n_devices: int,
        cost: CostModel | None = None,
        devices_per_host: int = 4,  # the paper's nodes: 4 GPUs / dual-socket
    ):
        self.cost = cost or CostModel()
        self.model: PowerModel = self.cost.power
        self.n_devices = n_devices
        self.devices_per_host = devices_per_host
        self.segments: list[Segment] = []
        self._t = 0.0

    # -- recording ----------------------------------------------------------

    def idle(self, duration: float, name: str = "idle"):
        self._push(name, duration, self.model.chip_static_w, 0.0,
                   section="idle")

    def region(
        self,
        name: str,
        counts: OpCounts,
        *,
        n_shards: int | None = None,
        overlap: bool = True,
        hides_comm: bool | None = None,
        repeats: int = 1,
        duration: float | None = None,
        section: str = "",
    ) -> float:
        """Record a modeled region executing ``counts`` per device.

        Returns the modeled duration (seconds) of the whole region.
        ``duration`` overrides the modeled time (e.g. measured wall time on
        real hardware); the collective exposed/hidden split always comes
        from the modeled engine times. ``overlap`` selects the segment's
        comm schedule: ``max(compute, memory, collective)`` when True,
        ``max(compute, memory) + collective`` when False. ``hides_comm``
        controls whether the segment *credits* collective time as hidden
        (``comm_hidden_s``); default = ``overlap``. Trace-derived ledgers
        pass ``hides_comm`` only for the ``"overlap"`` region, where the
        compute is independent of the collective by construction — a
        blocking all-reduce whose result feeds the same region's updates
        keeps the overlapped *time* model but reports its latency exposed.
        """
        S = n_shards if n_shards is not None else self.n_devices
        _, (tc, tm, tl) = self.cost.times(counts, S, overlap)
        t, _, _, p = self.cost.device_energy(counts, S, overlap)
        t = t if duration is None else duration / max(repeats, 1)
        comm_frac = 0.0
        if counts.hbm_bytes + counts.ici_bytes > 0:
            comm_frac = counts.ici_bytes / (counts.hbm_bytes + counts.ici_bytes)
        self._push(
            name, t * repeats, p, min(1.0, 4.0 * comm_frac),
            t_comp=tc * repeats, t_mem=tm * repeats, t_coll=tl * repeats,
            overlapped=overlap if hides_comm is None else hides_comm,
            section=section,
        )
        return t * repeats

    def _push(self, name, dt, chip_w, host_active, *, t_comp=0.0, t_mem=0.0,
              t_coll=0.0, overlapped=True, section=""):
        if dt <= 0:
            return
        self.segments.append(
            Segment(name, self._t, self._t + dt, chip_w, host_active,
                    t_comp, t_mem, t_coll, overlapped, section)
        )
        self._t += dt

    @contextmanager
    def wall_region(self, name: str, counts: OpCounts, **kw):
        """Measured-wall-time region (for real-hardware runs)."""
        t0 = time.perf_counter()
        yield
        self.region(name, counts, duration=time.perf_counter() - t0, **kw)

    # -- curves & integration ------------------------------------------------

    @property
    def duration(self) -> float:
        return self._t

    def curve(self, hz: float = 1000.0):
        """(t, P_chip(t), P_host(t)) sampled curves (one device / one host)."""
        n = max(int(self.duration * hz), 2)
        ts = np.linspace(0.0, self.duration, n)
        p_chip = np.full(n, self.model.chip_static_w)
        p_host = np.full(n, self.model.host_static_w)
        for s in self.segments:
            m = (ts >= s.t0) & (ts < s.t1)
            p_chip[m] = s.chip_w
            p_host[m] = self.model.host_power(s.host_active)
        return ts, p_chip, p_host

    def energy_by_region(self):
        """Per-region energy ledger: segments aggregated by name.

        Returns ``{name: {time_s, te_gpu_j, de_gpu_j, de_cpu_j, de_j,
        comm_s, comm_exposed_s, comm_hidden_s}}`` summed over all
        devices/hosts (times are per-device-timeline seconds). Because
        segments partition the timeline, ``sum(de_j)`` over regions equals
        ``energy()['de_total']`` exactly — the invariant the executed-energy
        ledger is gated on. ``comm_s`` is the region's modeled collective
        time; ``comm_hidden_s`` the part absorbed behind concurrent
        compute/memory (nonzero only for overlapped segments, e.g. the
        ``"overlap"`` region); ``comm_exposed_s`` the remainder the timeline
        actually waits on.
        """
        n_hosts = max(self.n_devices // self.devices_per_host, 1)
        chip0 = self.model.chip_static_w
        host0 = self.model.host_static_w
        out: dict[str, dict] = {}
        for s in self.segments:
            d = out.setdefault(
                s.name,
                dict(time_s=0.0, te_gpu_j=0.0, de_gpu_j=0.0, de_cpu_j=0.0,
                     de_j=0.0, comm_s=0.0, comm_exposed_s=0.0,
                     comm_hidden_s=0.0),
            )
            de_gpu = (s.chip_w - chip0) * s.dt * self.n_devices
            de_cpu = (self.model.host_power(s.host_active) - host0) * s.dt * n_hosts
            d["time_s"] += s.dt
            d["te_gpu_j"] += s.chip_w * s.dt * self.n_devices
            d["de_gpu_j"] += de_gpu
            d["de_cpu_j"] += de_cpu
            d["de_j"] += de_gpu + de_cpu
            d["comm_s"] += s.t_coll
            d["comm_exposed_s"] += s.comm_exposed_s
            d["comm_hidden_s"] += s.comm_hidden_s
        return out

    def energy(self):
        """Exact per-segment integration -> paper §4.2 quantities.

        Returns a dict with chip/host total, static, dynamic energy (summed
        over all devices/hosts), the chip power peak, and the modeled
        communication split: ``comm_s`` (total collective seconds),
        ``comm_hidden_s`` (overlapped behind compute) and ``comm_exposed_s``
        (actually waited on) — all per device timeline.
        """
        T = self.duration
        n_hosts = max(self.n_devices // self.devices_per_host, 1)
        te_chip = sum(s.chip_w * s.dt for s in self.segments) * self.n_devices
        se_chip = self.model.chip_static_w * T * self.n_devices
        te_host = (
            sum(self.model.host_power(s.host_active) * s.dt for s in self.segments)
            * n_hosts
        )
        se_host = self.model.host_static_w * T * n_hosts
        peak = max((s.chip_w for s in self.segments), default=self.model.chip_static_w)
        return dict(
            runtime=T,
            comm_s=sum(s.t_coll for s in self.segments),
            comm_exposed_s=sum(s.comm_exposed_s for s in self.segments),
            comm_hidden_s=sum(s.comm_hidden_s for s in self.segments),
            te_gpu=te_chip,
            se_gpu=se_chip,
            de_gpu=te_chip - se_chip,
            te_cpu=te_host,
            se_cpu=se_host,
            de_cpu=te_host - se_host,
            de_total=(te_chip - se_chip) + (te_host - se_host),
            gpu_power_peak=peak,
            # paper Tables 2-6: dynamic as % of static
            gpu_pct=100.0 * (te_chip - se_chip) / max(se_chip, 1e-12),
            cpu_pct=100.0 * (te_host - se_host) / max(se_host, 1e-12),
            total_pct=100.0
            * ((te_chip - se_chip) + (te_host - se_host))
            / max(se_chip + se_host, 1e-12),
        )
