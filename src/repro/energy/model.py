"""Calibrated analytical power model (replaces hardware sensors — DESIGN §4).

Per-chip instantaneous power:

    P(t) = P_idle + e_flop * FLOP/s + e_hbm * B_hbm/s + e_ici * B_ici/s,
    clamped to P_peak.

Calibration (documented, per published energy-cost-of-data-movement studies
[Kestor'13, Delestrac'24] and the TPU v5e envelope in roofline/hw.py):

* a roofline-saturating bf16 matmul (197 TFLOP/s + ~819 GB/s) draws P_peak;
* an HBM-saturating stream (819 GB/s, negligible flops) draws ~65% of the
  dynamic envelope — data movement dominates FP energy;
* ICI transfer energy per byte is ~2x HBM energy per byte.

Solving those three constraints for (e_flop, e_hbm, e_ici):

    e_hbm  = 0.65 * (P_peak - P_idle) / HBM_bw            [J/B]
    e_flop = (0.35 * (P_peak - P_idle)) / peak_flops       [J/FLOP]
    e_ici  = 2 * e_hbm                                     [J/B]

The host (CPU) model is LIKWID-socket-scoped: P_idle plus an active
increment while the host drives collectives/launch work.

DVFS axis (used by the autotune subsystem): :meth:`PowerModel.at_freq`
re-derives the same three calibration constraints on the downclocked chip
(``ChipSpec.at_freq``: peak FLOP/s and the dynamic envelope scale with
``f`` and ``~f*V^2``; HBM/ICI bandwidth held flat). The calibration
invariants are therefore preserved at every grid point — ``e_ici ==
2 * e_hbm``, instantaneous power clamped to the (scaled) ``p_peak_w`` —
and energy-per-byte falls monotonically as the frequency drops, which is
exactly where the race-to-idle vs. slow-and-efficient trade-off comes
from (see docs/autotune.md).
"""

from __future__ import annotations

import dataclasses

from repro.roofline.hw import DEFAULT_CHIP, DEFAULT_HOST, ChipSpec, HostSpec


@dataclasses.dataclass(frozen=True)
class PowerModel:
    chip: ChipSpec = DEFAULT_CHIP
    host: HostSpec = DEFAULT_HOST
    hbm_fraction: float = 0.65  # share of dynamic envelope at HBM saturation
    ici_hbm_ratio: float = 2.0  # ICI J/B relative to HBM J/B

    def at_freq(self, freq: float) -> "PowerModel":
        """The same calibrated model on the chip downclocked to ``freq``
        (relative; see :meth:`ChipSpec.at_freq`). Identity at 1.0."""
        if freq == 1.0:
            return self
        return dataclasses.replace(self, chip=self.chip.at_freq(freq))

    @property
    def dyn_envelope(self) -> float:
        return self.chip.p_peak_w - self.chip.p_idle_w

    @property
    def e_hbm(self) -> float:  # J/B
        return self.hbm_fraction * self.dyn_envelope / self.chip.hbm_bw

    @property
    def e_flop(self) -> float:  # J/FLOP
        return (1.0 - self.hbm_fraction) * self.dyn_envelope / self.chip.peak_flops_bf16

    @property
    def e_ici(self) -> float:  # J/B
        return self.ici_hbm_ratio * self.e_hbm

    def chip_power(self, flops_per_s: float, hbm_bps: float, ici_bps: float) -> float:
        """Instantaneous per-chip power [W] for the given activity rates."""
        p = (
            self.chip.p_idle_w
            + self.e_flop * flops_per_s
            + self.e_hbm * hbm_bps
            + self.e_ici * ici_bps
        )
        return min(p, self.chip.p_peak_w)

    def host_power(self, active_fraction: float = 0.0) -> float:
        """Host socket power; ``active_fraction`` in [0, 1] scales the
        active increment (the paper's CPU contribution is small — it mostly
        drives communication)."""
        return self.host.p_idle_w + active_fraction * self.host.p_active_w

    # Convenience idle levels (static power in the paper's terminology).
    @property
    def chip_static_w(self) -> float:
        return self.chip.p_idle_w

    @property
    def host_static_w(self) -> float:
        return self.host.p_idle_w
