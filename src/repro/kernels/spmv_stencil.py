"""Matrix-free stencil SpMV Pallas kernel (7pt / 27pt, Dirichlet).

TPU adaptation of the paper's CSR SpMV hot spot: the benchmark matrices are
structured stencils, and on TPU the roofline-optimal formulation is
**matrix-free shift-and-add** on the 3-D grid held in VMEM — no matrix
values, no column indices, no gathers. Per-row HBM traffic (f64 values,
int32 column indices, read x + write y once):

    format        matrix bytes/row     vector bytes/row   total    vs matfree
    CSR/ELL 7pt   7*(8+4) = 84         ~16                ~100     ~6x
    CSR/ELL 27pt  27*(8+4) = 324       ~16                ~340     ~21x
    matrix-free   0                    ~16                ~16      1x

(f32 halves the vector term again.) The distributed shard_map form of this
operator lives in core/stencil_solver.py; backend selection between this
kernel, interpret mode, and the jnp reference is kernels/dispatch.py.

Tiling: grid over z-slabs of ``bz`` planes. The kernel reads its own
(bz, ny, nx) block plus ONE boundary plane from each z-neighbor (passed as
two extra (1, ny, nx) views of the same array, clamped at the edges and
masked by program_id) — HBM reads are bz+2 planes per bz planes of output,
i.e. within 2/bz of the minimum. x/y-direction neighbors live inside the
block; their shifted reads are VMEM-local. Lane dim = nx (pad to a multiple
of 128 for hardware alignment); sublane = ny.

``stencil_spmv_halo`` is the distributed variant: instead of zero Dirichlet
planes at the z-edges it takes explicit boundary planes (the halo received
from the slab neighbors via ppermute), so a shard_map solver can run the
whole local SpMV as one kernel call.

``stencil_spmv_boundary`` is the communication-hiding companion: it
recomputes ONLY the slab's first and last output planes from the received
halo planes. The overlapped distributed SpMV (core/stencil_solver.py) runs
the full slab with zero halos while the ppermute is in flight — every
interior plane is already final — and patches the two edge planes with this
kernel on arrival. Both kernels share ``_stencil_core``, so the patched
planes are bitwise identical to the serialized single-call result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift_yx(x: jax.Array, dy: int, dx: int) -> jax.Array:
    """Zero-fill shift within (z, y, x) block along y/x only."""
    z, ny, nx = x.shape
    out = x
    if dy:
        pad = ((0, 0), (dy, 0), (0, 0)) if dy > 0 else ((0, 0), (0, -dy), (0, 0))
        out = jnp.pad(out, pad)
        out = out[:, : ny, :] if dy > 0 else out[:, -dy : ny - dy, :]
    if dx:
        pad = ((0, 0), (0, 0), (dx, 0)) if dx > 0 else ((0, 0), (0, 0), (0, -dx))
        out = jnp.pad(out, pad)
        out = out[:, :, : nx] if dx > 0 else out[:, :, -dx : nx - dx]
    return out


def _stencil_core(c, prev_plane, next_plane, *, stencil, aniso):
    """Shared 7pt/27pt arithmetic on a (bz, ny, nx) block + boundary planes."""
    if stencil == "7pt":
        ax, ay, az = aniso
        zm = jnp.concatenate([prev_plane, c[:-1]], axis=0)
        zp = jnp.concatenate([c[1:], next_plane], axis=0)
        y = (2.0 * (ax + ay + az)) * c
        y = y - ax * (_shift_yx(c, 0, 1) + _shift_yx(c, 0, -1))
        y = y - ay * (_shift_yx(c, 1, 0) + _shift_yx(c, -1, 0))
        y = y - az * (zm + zp)
    else:  # 27pt
        ext = jnp.concatenate([prev_plane, c, next_plane], axis=0)  # (bz+2,..)
        s9 = jnp.zeros_like(ext)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                s9 = s9 + _shift_yx(ext, dy, dx)
        s27 = s9[:-2] + s9[1:-1] + s9[2:]
        y = 27.0 * c - s27
    return y


def _stencil_kernel(prev_ref, cur_ref, next_ref, y_ref, *, stencil, aniso, nzb):
    i = pl.program_id(0)
    c = cur_ref[...]  # (bz, ny, nx)
    dt = c.dtype
    # Boundary planes from neighbor blocks; zero at the global z edges.
    pmask = jnp.where(i > 0, 1, 0).astype(dt)
    nmask = jnp.where(i < nzb - 1, 1, 0).astype(dt)
    prev_plane = prev_ref[...] * pmask  # (1, ny, nx)
    next_plane = next_ref[...] * nmask
    y_ref[...] = _stencil_core(
        c, prev_plane, next_plane, stencil=stencil, aniso=aniso
    )


def _stencil_boundary_kernel(
    hp_ref, below_ref, cur_ref, above_ref, hn_ref, y_ref, *, stencil, aniso
):
    """Program 0 computes output plane 0 (needs prev_halo, x[0], x[1]);
    program 1 computes plane nz-1 (needs x[nz-2], x[nz-1], next_halo)."""
    i = pl.program_id(0)
    c = cur_ref[...]  # (1, ny, nx): plane 0 or nz-1
    prev_plane = jnp.where(i == 0, hp_ref[...], below_ref[...])
    next_plane = jnp.where(i == 0, above_ref[...], hn_ref[...])
    y_ref[...] = _stencil_core(
        c, prev_plane, next_plane, stencil=stencil, aniso=aniso
    )


def _stencil_halo_kernel(
    hp_ref, prev_ref, cur_ref, next_ref, hn_ref, y_ref, *, stencil, aniso, nzb
):
    i = pl.program_id(0)
    c = cur_ref[...]  # (bz, ny, nx)
    # Boundary planes: the clamped self-views interior, the supplied halo
    # planes at the slab edges (zeros arrive there for global-edge shards).
    prev_plane = jnp.where(i == 0, hp_ref[...], prev_ref[...])
    next_plane = jnp.where(i == nzb - 1, hn_ref[...], next_ref[...])
    y_ref[...] = _stencil_core(
        c, prev_plane, next_plane, stencil=stencil, aniso=aniso
    )


@functools.partial(
    jax.jit,
    static_argnames=("stencil", "aniso", "bz", "interpret"),
)
def stencil_spmv(
    x: jax.Array,
    *,
    stencil: str = "7pt",
    aniso: tuple = (1.0, 1.0, 1.0),
    bz: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """y = A_stencil @ x for x of shape (nz, ny, nx); nz % bz == 0."""
    nz, ny, nx = x.shape
    assert nz % bz == 0, f"nz={nz} must be a multiple of bz={bz}"
    nzb = nz // bz
    kernel = functools.partial(
        _stencil_kernel, stencil=stencil, aniso=aniso, nzb=nzb
    )
    # Plane views: block index along z is in *plane* units ((1, ny, nx)
    # blocks); clamped at the global edges (masked inside the kernel).
    prev_spec = pl.BlockSpec(
        (1, ny, nx), lambda i: (jnp.maximum(i * bz - 1, 0), 0, 0)
    )
    next_spec = pl.BlockSpec(
        (1, ny, nx), lambda i: (jnp.minimum(i * bz + bz, nz - 1), 0, 0)
    )
    cur_spec = pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nzb,),
        in_specs=[prev_spec, cur_spec, next_spec],
        out_specs=pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), x.dtype),
        interpret=interpret,
    )(x, x, x)


def pick_bz(nz: int, target: int = 8) -> int:
    """Largest z-block size <= target that divides nz (>= 1 always works)."""
    for bz in range(min(target, nz), 0, -1):
        if nz % bz == 0:
            return bz
    return 1


@functools.partial(
    jax.jit,
    static_argnames=("stencil", "aniso", "bz", "interpret"),
)
def stencil_spmv_halo(
    x: jax.Array,
    prev_halo: jax.Array,
    next_halo: jax.Array,
    *,
    stencil: str = "7pt",
    aniso: tuple = (1.0, 1.0, 1.0),
    bz: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Local-slab SpMV with explicit z-boundary planes (distributed form).

    ``x`` is the shard's (nz_loc, ny, nx) slab; ``prev_halo``/``next_halo``
    are the (ny, nx) boundary planes received from the z-neighbors (zeros at
    the global edges). nz_loc % bz == 0 (use ``pick_bz``).
    """
    nz, ny, nx = x.shape
    assert nz % bz == 0, f"nz={nz} must be a multiple of bz={bz}"
    nzb = nz // bz
    kernel = functools.partial(
        _stencil_halo_kernel, stencil=stencil, aniso=aniso, nzb=nzb
    )
    plane = pl.BlockSpec((1, ny, nx), lambda i: (0, 0, 0))
    prev_spec = pl.BlockSpec(
        (1, ny, nx), lambda i: (jnp.maximum(i * bz - 1, 0), 0, 0)
    )
    next_spec = pl.BlockSpec(
        (1, ny, nx), lambda i: (jnp.minimum(i * bz + bz, nz - 1), 0, 0)
    )
    cur_spec = pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nzb,),
        in_specs=[plane, prev_spec, cur_spec, next_spec, plane],
        out_specs=pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), x.dtype),
        interpret=interpret,
    )(prev_halo[None], x, x, x, next_halo[None])


@functools.partial(
    jax.jit,
    static_argnames=("stencil", "aniso", "interpret"),
)
def stencil_spmv_boundary(
    x: jax.Array,
    prev_halo: jax.Array,
    next_halo: jax.Array,
    *,
    stencil: str = "7pt",
    aniso: tuple = (1.0, 1.0, 1.0),
    interpret: bool = False,
) -> jax.Array:
    """The slab's first + last output planes only (communication-hiding form).

    ``x`` is the shard's (nz_loc, ny, nx) slab (nz_loc >= 2);
    ``prev_halo``/``next_halo`` the (ny, nx) planes received from the
    z-neighbors. Returns a (2, ny, nx) array: row 0 is output plane 0, row 1
    is output plane nz_loc-1 — bitwise equal to the corresponding planes of
    :func:`stencil_spmv_halo`. Grid of exactly two programs, so the
    on-arrival boundary fix-up costs two plane-sized kernel launches of
    work, independent of nz_loc.
    """
    nz, ny, nx = x.shape
    assert nz >= 2, "boundary split needs at least 2 local z-planes"
    kernel = functools.partial(
        _stencil_boundary_kernel, stencil=stencil, aniso=aniso
    )
    plane = pl.BlockSpec((1, ny, nx), lambda i: (0, 0, 0))
    cur = pl.BlockSpec((1, ny, nx), lambda i: (i * (nz - 1), 0, 0))
    below = pl.BlockSpec(
        (1, ny, nx), lambda i: (jnp.maximum(i * (nz - 1) - 1, 0), 0, 0)
    )
    above = pl.BlockSpec(
        (1, ny, nx), lambda i: (jnp.minimum(i * (nz - 1) + 1, nz - 1), 0, 0)
    )
    return pl.pallas_call(
        kernel,
        grid=(2,),
        in_specs=[plane, below, cur, above, plane],
        out_specs=pl.BlockSpec((1, ny, nx), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, ny, nx), x.dtype),
        interpret=interpret,
    )(prev_halo[None], x, x, x, next_halo[None])
