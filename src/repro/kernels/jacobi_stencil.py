"""Fused stencil l1-Jacobi sweep Pallas kernel.

One V-cycle smoothing sweep is x <- x + omega * dinv * (b - A x). Composed
from separate ops it streams x twice (SpMV read + update read) plus b, dinv,
and writes y and x_new. This kernel fuses the whole sweep into one pass:
reads x (+2 boundary planes), b, dinv; writes x_new. For the 7-point stencil
that cuts HBM traffic per sweep from ~6 arrays to ~4 — directly shrinking
the memory-roofline term of the PCG smoother, which dominates V-cycle cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spmv_stencil import _shift_yx


def _jacobi_kernel(
    prev_ref, cur_ref, next_ref, b_ref, dinv_ref, o_ref,
    *, stencil, aniso, omega, nzb,
):
    i = pl.program_id(0)
    c = cur_ref[...]
    dt = c.dtype
    pmask = jnp.where(i > 0, 1, 0).astype(dt)
    nmask = jnp.where(i < nzb - 1, 1, 0).astype(dt)
    prev_plane = prev_ref[...] * pmask
    next_plane = next_ref[...] * nmask

    if stencil == "7pt":
        ax, ay, az = aniso
        zm = jnp.concatenate([prev_plane, c[:-1]], axis=0)
        zp = jnp.concatenate([c[1:], next_plane], axis=0)
        y = (2.0 * (ax + ay + az)) * c
        y = y - ax * (_shift_yx(c, 0, 1) + _shift_yx(c, 0, -1))
        y = y - ay * (_shift_yx(c, 1, 0) + _shift_yx(c, -1, 0))
        y = y - az * (zm + zp)
    else:
        ext = jnp.concatenate([prev_plane, c, next_plane], axis=0)
        s9 = jnp.zeros_like(ext)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                s9 = s9 + _shift_yx(ext, dy, dx)
        y = 27.0 * c - (s9[:-2] + s9[1:-1] + s9[2:])

    o_ref[...] = c + omega * dinv_ref[...] * (b_ref[...] - y)


@functools.partial(
    jax.jit, static_argnames=("stencil", "aniso", "omega", "bz", "interpret")
)
def jacobi_stencil_sweep(
    x: jax.Array,
    b: jax.Array,
    dinv: jax.Array,
    *,
    stencil: str = "7pt",
    aniso: tuple = (1.0, 1.0, 1.0),
    omega: float = 1.0,
    bz: int = 8,
    interpret: bool = False,
) -> jax.Array:
    nz, ny, nx = x.shape
    assert nz % bz == 0
    nzb = nz // bz
    kernel = functools.partial(
        _jacobi_kernel, stencil=stencil, aniso=aniso, omega=omega, nzb=nzb
    )
    plane = lambda f: pl.BlockSpec((1, ny, nx), f)
    blk = pl.BlockSpec((bz, ny, nx), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nzb,),
        in_specs=[
            plane(lambda i: (jnp.maximum(i * bz - 1, 0), 0, 0)),
            blk,
            plane(lambda i: (jnp.minimum(i * bz + bz, nz - 1), 0, 0)),
            blk,
            blk,
        ],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((nz, ny, nx), x.dtype),
        interpret=interpret,
    )(x, x, x, b, dinv)
