"""Fused multi-dot Pallas kernel: [p·w, r·r, p·r] in ONE pass over HBM.

CG's per-iteration scalar work reads the same vectors several times when the
dots are computed separately (3 HBM passes). This kernel computes all three
partial sums in a single streaming pass (chunked grid, SMEM accumulation) —
the kernel-level counterpart of the algorithm-level reduction fusion in
core/vectors.fused_dots. On the CG roofline this removes ~2 vector reads per
iteration from the memory term.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dots_kernel(p_ref, w_ref, r_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0] = jnp.zeros((), out_ref.dtype)
        out_ref[1] = jnp.zeros((), out_ref.dtype)
        out_ref[2] = jnp.zeros((), out_ref.dtype)

    p = p_ref[...]
    w = w_ref[...]
    r = r_ref[...]
    out_ref[0] += jnp.sum(p * w)
    out_ref[1] += jnp.sum(r * r)
    out_ref[2] += jnp.sum(p * r)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def fused_dots3(
    p: jax.Array, w: jax.Array, r: jax.Array, *, chunk: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    """(n,) vectors -> (3,) [p·w, r·r, p·r]; n % chunk == 0 (pad upstream)."""
    (n,) = p.shape
    assert n % chunk == 0, f"n={n} must be a multiple of chunk={chunk}"
    grid = (n // chunk,)
    spec = pl.BlockSpec((chunk,), lambda i: (i,))
    return pl.pallas_call(
        _dots_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((3,), p.dtype),
        interpret=interpret,
    )(p, w, r)
