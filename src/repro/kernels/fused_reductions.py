"""Fused vector-op Pallas kernels: the CG hot path in minimal HBM passes.

CG's per-iteration scalar + vector work reads the same vectors several times
when expressed as separate ops (dots, axpys). The kernels here stream every
operand exactly once per call (chunked grid, SMEM scalar accumulation), so
each call is ONE full-vector HBM sweep:

* ``fused_dots_n``   — N inner products in one pass. Duplicate operands and
  duplicate pairs are deduplicated statically, so e.g. the fcg triple
  [(r,u), (w,u), (r,r)] with u==r reads only {r, w} and multiplies once per
  unique pair.
* ``fused_axpy``     — a*x + y.
* ``fused_axpy2``    — two independent axpys (the p/s and x/r update pairs)
  in one pass.
* ``fused_axpy2_dots`` — the CG update step ``x += a1*p; r -= a1*w`` PLUS
  the follow-up reduction ``r_new . r_new`` in the SAME pass: the freshly
  computed r chunk is still in VMEM when the partial dot accumulates, so the
  re-read of r that a separate dot would cost disappears from HBM traffic.
* ``fused_dots3``    — legacy fixed-arity [p.w, r.r, p.r] wrapper (kept for
  API stability; now handles any length, no shape restriction).

Arbitrary lengths dispatch unconditionally: the grid covers the vector in
lane-aligned chunks and the (possibly ragged) final block is masked inside
the kernel — reductions ignore out-of-range lanes, out-of-range output
writes are clipped by Pallas. No host-side padding copies, so the HBM
traffic really is one read per operand + one write per output. Scalars
(alpha/beta) arrive as a small SMEM operand so traced loop-carried values
work. Accumulation happens in the input dtype, matching the jnp oracles in
``kernels/ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _require_1d(op: str, *arrays):
    """The fused vector kernels stream (n,) vectors; (n, r) RHS blocks have
    their own one-pass kernels. Fail loudly instead of deep inside the
    masked ragged-block reshape."""
    for a in arrays:
        if a.ndim != 1:
            raise ValueError(
                f"{op} expects 1-D (n,) vectors, got shape {a.shape}; "
                "multi-RHS (n, r) column blocks go through the block "
                "kernels block_gram / block_update / block_update2"
            )


def _chunking(n: int, chunk: int) -> tuple[int, int]:
    """(effective chunk, grid size): lane-aligned, ragged tail allowed."""
    chunk_eff = min(chunk, _round_up(n, 128))
    return chunk_eff, -(-n // chunk_eff)


def _valid_mask(i, chunk: int, n: int):
    """(chunk,) bool mask of in-range lanes for grid step ``i``.

    TPU Mosaic requires >=2-D iota, hence the (1, chunk) detour.
    """
    lane = lax.broadcasted_iota(jnp.int32, (1, chunk), 1).reshape(chunk)
    return (i * chunk + lane) < n


# ---------------------------------------------------------------------------
# fused_dots_n — N inner products, one pass, deduplicated reads
# ---------------------------------------------------------------------------


def _dedup_pairs(pairs):
    """Static dedup: unique operand arrays, unique (i, j) products, and the
    map from output slot -> unique product."""
    uniq: list = []
    ids: dict[int, int] = {}

    def idx(a):
        if id(a) not in ids:
            ids[id(a)] = len(uniq)
            uniq.append(a)
        return ids[id(a)]

    out_map = []
    prod_ids: dict[tuple[int, int], int] = {}
    prods = []
    for x, y in pairs:
        key = tuple(sorted((idx(x), idx(y))))
        if key not in prod_ids:
            prod_ids[key] = len(prods)
            prods.append(key)
        out_map.append(prod_ids[key])
    return uniq, tuple(prods), tuple(out_map)


def fused_dots_n(pairs, *, chunk: int = 65536, interpret: bool = False) -> jax.Array:
    """Local partial dots for ``pairs = [(x, y), ...]`` — ONE HBM pass.

    Returns a (len(pairs),) vector of LOCAL sums (callers psum once in the
    distributed setting). Operands shared between pairs (by object identity)
    are read once; identical pairs are multiplied once.
    """
    uniq, prods, out_map = _dedup_pairs(pairs)
    _require_1d("fused_dots_n", *uniq)
    k = len(prods)
    (n,) = uniq[0].shape
    dt = uniq[0].dtype
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))

    def kernel(*refs):
        out_ref = refs[-1]
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            for j in range(k):
                out_ref[j] = jnp.zeros((), out_ref.dtype)

        valid = _valid_mask(i, chunk_eff, n)
        vals = [refs[t][...] for t in range(len(uniq))]
        zero = jnp.zeros((), dt)
        for j, (a, b) in enumerate(prods):
            out_ref[j] += jnp.sum(jnp.where(valid, vals[a] * vals[b], zero))

    partials = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[spec] * len(uniq),
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((k,), dt),
        interpret=interpret,
    )(*uniq)
    if out_map == tuple(range(len(pairs))) and k == len(pairs):
        return partials
    return partials[jnp.asarray(out_map, jnp.int32)]


# ---------------------------------------------------------------------------
# fused axpy family
# ---------------------------------------------------------------------------


def _axpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def fused_axpy(a, x, y, *, chunk: int = 65536, interpret: bool = False):
    """a*x + y in one pass; ``a`` may be a traced scalar."""
    _require_1d("fused_axpy", x, y)
    (n,) = x.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    av = jnp.asarray(a, x.dtype).reshape(1)
    return pl.pallas_call(
        _axpy_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(av, x, y)


def _axpy2_kernel(a_ref, x1_ref, y1_ref, x2_ref, y2_ref, o1_ref, o2_ref):
    o1_ref[...] = a_ref[0] * x1_ref[...] + y1_ref[...]
    o2_ref[...] = a_ref[1] * x2_ref[...] + y2_ref[...]


def fused_axpy2(a1, x1, y1, a2, x2, y2, *, chunk: int = 65536,
                interpret: bool = False):
    """(a1*x1 + y1, a2*x2 + y2) in one pass over all four vectors."""
    _require_1d("fused_axpy2", x1, y1, x2, y2)
    (n,) = x1.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    av = jnp.stack([jnp.asarray(a1, x1.dtype), jnp.asarray(a2, x1.dtype)])
    return pl.pallas_call(
        _axpy2_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + [spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), x1.dtype)] * 2,
        interpret=interpret,
    )(av, x1, y1, x2, y2)


def fused_axpy2_dots(a1, x1, y1, a2, x2, y2, *, chunk: int = 65536,
                     interpret: bool = False):
    """CG update + follow-up reduction in ONE pass.

    Returns (o1, o2, d) with o1 = a1*x1 + y1, o2 = a2*x2 + y2 and
    d = (1,) LOCAL partial [o2 . o2] — the new-residual norm accumulated
    while the o2 chunk is still in VMEM.
    """
    _require_1d("fused_axpy2_dots", x1, y1, x2, y2)
    (n,) = x1.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    av = jnp.stack([jnp.asarray(a1, x1.dtype), jnp.asarray(a2, x1.dtype)])

    def kernel(a_ref, x1_ref, y1_ref, x2_ref, y2_ref, o1_ref, o2_ref, d_ref):
        i = pl.program_id(0)
        o1_ref[...] = a_ref[0] * x1_ref[...] + y1_ref[...]
        v2 = a_ref[1] * x2_ref[...] + y2_ref[...]
        o2_ref[...] = v2

        @pl.when(i == 0)
        def _init():
            d_ref[0] = jnp.zeros((), d_ref.dtype)

        valid = _valid_mask(i, chunk_eff, n)
        d_ref[0] += jnp.sum(jnp.where(valid, v2 * v2, jnp.zeros((), v2.dtype)))

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + [spec] * 4,
        out_specs=[spec, spec, pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x1.dtype),
            jax.ShapeDtypeStruct((n,), x1.dtype),
            jax.ShapeDtypeStruct((1,), x1.dtype),
        ],
        interpret=interpret,
    )(av, x1, y1, x2, y2)


# ---------------------------------------------------------------------------
# Multi-RHS block kernels: (n, r) column blocks, one HBM pass each
# ---------------------------------------------------------------------------
#
# The block-CG hot path works on (n, r) column blocks instead of (n,)
# vectors. Same streaming discipline as above — every block is read once
# per call — but the reduction outputs are small (r, r) Gram matrices and
# the updates contract with (r, r) coefficient blocks:
#
# * ``block_gram``    — local Xᵀ·Y Gram blocks for a list of pairs, one
#   pass over the distinct operands. The (r, r) accumulators live in a
#   VMEM output block revisited at every grid step (index_map pins (0, 0)).
# * ``block_update``  — Y·diag(mask) + X @ M: the P-update of block-CG,
#   with the deflation column mask folded into the same pass.
# * ``block_update2`` — two independent block updates (the X/R pair) in
#   one pass over all four blocks.


def _require_block(op: str, *arrays):
    for a in arrays:
        if a.ndim != 2:
            raise ValueError(
                f"{op} expects 2-D (n, r) column blocks, got shape {a.shape}"
            )


def _dedup_pairs_ordered(pairs):
    """Like :func:`_dedup_pairs` but ORDER-SENSITIVE: XᵀY is the transpose
    of YᵀX, not the same product, so Gram pairs must not be symmetrized."""
    uniq: list = []
    ids: dict[int, int] = {}

    def idx(a):
        if id(a) not in ids:
            ids[id(a)] = len(uniq)
            uniq.append(a)
        return ids[id(a)]

    out_map = []
    prod_ids: dict[tuple[int, int], int] = {}
    prods = []
    for x, y in pairs:
        key = (idx(x), idx(y))
        if key not in prod_ids:
            prod_ids[key] = len(prods)
            prods.append(key)
        out_map.append(prod_ids[key])
    return uniq, tuple(prods), tuple(out_map)


def block_gram(pairs, *, chunk: int = 1024, interpret: bool = False):
    """Local Gram blocks ``[Xᵀ @ Y for (X, Y) in pairs]`` — ONE HBM pass.

    Returns a list of (r, r) LOCAL Grams (callers psum once). Operands
    shared between pairs are read once; identical ordered pairs are
    multiplied once. The ragged tail is masked on every operand so no
    out-of-range row can contribute.
    """
    uniq, prods, out_map = _dedup_pairs_ordered(pairs)
    _require_block("block_gram", *uniq)
    n, r = uniq[0].shape
    dt = uniq[0].dtype
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff, r), lambda i: (i, 0))
    acc = pl.BlockSpec((r, r), lambda i: (0, 0))

    def kernel(*refs):
        ins, outs = refs[: len(uniq)], refs[len(uniq):]
        i = pl.program_id(0)
        for out_ref in outs:
            @pl.when(i == 0)
            def _init(out_ref=out_ref):
                out_ref[...] = jnp.zeros_like(out_ref)

        valid = _valid_mask(i, chunk_eff, n)
        zero = jnp.zeros((), dt)
        vals = [jnp.where(valid[:, None], t[...], zero) for t in ins]
        for j, (a, b) in enumerate(prods):
            outs[j][...] += jnp.dot(
                vals[a].T, vals[b], preferred_element_type=dt
            )

    grams = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[spec] * len(uniq),
        out_specs=[acc] * len(prods),
        out_shape=[jax.ShapeDtypeStruct((r, r), dt)] * len(prods),
        interpret=interpret,
    )(*uniq)
    return [grams[m] for m in out_map]


def block_update(m, x, y, mask=None, *, chunk: int = 1024,
                 interpret: bool = False):
    """``y * mask + x @ m`` in one pass; ``mask`` is an optional (r,)
    column scale (the block-CG deflation mask), broadcast over rows."""
    _require_block("block_update", x, y)
    n, r = x.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff, r), lambda i: (i, 0))
    mm = jnp.asarray(m, x.dtype).reshape(r, r)
    kv = (jnp.ones((1, r), x.dtype) if mask is None
          else jnp.asarray(mask, x.dtype).reshape(1, r))

    def kernel(m_ref, k_ref, x_ref, y_ref, o_ref):
        o_ref[...] = y_ref[...] * k_ref[...] + jnp.dot(
            x_ref[...], m_ref[...], preferred_element_type=o_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((1, r), lambda i: (0, 0)),
            spec, spec,
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, r), x.dtype),
        interpret=interpret,
    )(mm, kv, x, y)


def block_update2(a1, x1, y1, a2, x2, y2, *, chunk: int = 1024,
                  interpret: bool = False):
    """``(y1 + x1 @ a1, y2 + x2 @ a2)`` in one pass over all four blocks —
    the block-CG X/R update (a2 = -alpha folds the sign into the
    coefficient block)."""
    _require_block("block_update2", x1, y1, x2, y2)
    n, r = x1.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff, r), lambda i: (i, 0))
    av = jnp.stack([
        jnp.asarray(a1, x1.dtype).reshape(r, r),
        jnp.asarray(a2, x1.dtype).reshape(r, r),
    ])

    def kernel(a_ref, x1_ref, y1_ref, x2_ref, y2_ref, o1_ref, o2_ref):
        o1_ref[...] = y1_ref[...] + jnp.dot(
            x1_ref[...], a_ref[0], preferred_element_type=o1_ref.dtype
        )
        o2_ref[...] = y2_ref[...] + jnp.dot(
            x2_ref[...], a_ref[1], preferred_element_type=o2_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((2, r, r), lambda i: (0, 0, 0))] + [spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n, r), x1.dtype)] * 2,
        interpret=interpret,
    )(av, x1, y1, x2, y2)


# ---------------------------------------------------------------------------
# s-step CG kernels: the whole block's vector work in three HBM passes
# ---------------------------------------------------------------------------
#
# s-step CG does s iterations' worth of vector algebra per block: one fused
# Gram reduction over the (n, s) basis blocks, one A-conjugation +
# column-normalization update forming the search block, and one x/r update
# contracting with the (s,) step coefficients. Each op below is ONE pass:
#
# * ``sstep_gram``   — [PᵀW | WpᵀP | Pᵀr | rᵀr] flattened to
#   (2s² + s + 1,): every scalar the block solve needs from one read of
#   {P, W, Wp, r}. The caller psums the flat vector once; the basis
#   column A-norms that feed the stability scaling are ``diag(PᵀW)``, so
#   no extra payload rides the collective.
# * ``sstep_basis``  — (Pb·diag(d) − Qp @ B, Wb·diag(d) − Wp @ B): the
#   normalized A-conjugated search/image blocks in one pass over all four
#   (n, s) operands.
# * ``sstep_update`` — (x + Q @ a, r − WQ @ a) with an (s,) coefficient
#   vector, one pass over both blocks and both vectors.


def sstep_gram(pb, wb, wp, r, *, chunk: int = 1024, interpret: bool = False):
    """Local s-step reduction ``[PᵀW | WpᵀP | Pᵀr | rᵀr]`` — ONE HBM pass
    over the (n, s) blocks P, W, Wp and the (n,) residual.

    Returns a flat (2s² + s + 1,) vector of LOCAL partial sums (callers
    psum once). The (s, s) accumulators live in VMEM output blocks pinned
    at (0, 0); the s + 1 scalars accumulate in SMEM.
    """
    _require_block("sstep_gram", pb, wb, wp)
    _require_1d("sstep_gram", r)
    n, s = pb.shape
    dt = pb.dtype
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff, s), lambda i: (i, 0))
    vspec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    acc = pl.BlockSpec((s, s), lambda i: (0, 0))

    def kernel(p_ref, w_ref, wp_ref, r_ref, gpp_ref, c_ref, v_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            gpp_ref[...] = jnp.zeros_like(gpp_ref)
            c_ref[...] = jnp.zeros_like(c_ref)
            for j in range(s + 1):
                v_ref[j] = jnp.zeros((), v_ref.dtype)

        valid = _valid_mask(i, chunk_eff, n)
        zero = jnp.zeros((), dt)
        p = jnp.where(valid[:, None], p_ref[...], zero)
        w = jnp.where(valid[:, None], w_ref[...], zero)
        wpv = jnp.where(valid[:, None], wp_ref[...], zero)
        rv = jnp.where(valid, r_ref[...], zero)
        gpp_ref[...] += jnp.dot(p.T, w, preferred_element_type=dt)
        c_ref[...] += jnp.dot(wpv.T, p, preferred_element_type=dt)
        g = jnp.sum(p * rv[:, None], axis=0)
        for j in range(s):
            v_ref[j] += g[j]
        v_ref[s] += jnp.sum(rv * rv)

    gpp, c, v = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, vspec],
        out_specs=[acc, acc, pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[
            jax.ShapeDtypeStruct((s, s), dt),
            jax.ShapeDtypeStruct((s, s), dt),
            jax.ShapeDtypeStruct((s + 1,), dt),
        ],
        interpret=interpret,
    )(pb, wb, wp, r)
    return jnp.concatenate([gpp.reshape(-1), c.reshape(-1), v])


def sstep_basis(b, dinv, qp, pb, wp, wb, *, chunk: int = 1024,
                interpret: bool = False):
    """``(Pb·diag(dinv) − Qp @ b, Wb·diag(dinv) − Wp @ b)`` in ONE pass
    over all four (n, s) blocks — the s-step A-conjugation with the basis
    column normalization folded into the same sweep."""
    _require_block("sstep_basis", qp, pb, wp, wb)
    n, s = pb.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff, s), lambda i: (i, 0))
    bm = jnp.asarray(b, pb.dtype).reshape(s, s)
    kv = jnp.asarray(dinv, pb.dtype).reshape(1, s)

    def kernel(b_ref, k_ref, qp_ref, pb_ref, wp_ref, wb_ref, o1_ref, o2_ref):
        o1_ref[...] = pb_ref[...] * k_ref[...] - jnp.dot(
            qp_ref[...], b_ref[...], preferred_element_type=o1_ref.dtype
        )
        o2_ref[...] = wb_ref[...] * k_ref[...] - jnp.dot(
            wp_ref[...], b_ref[...], preferred_element_type=o2_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((s, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
            spec, spec, spec, spec,
        ],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n, s), pb.dtype)] * 2,
        interpret=interpret,
    )(bm, kv, qp, pb, wp, wb)


def sstep_update(a, q, wq, x, r, *, chunk: int = 1024,
                 interpret: bool = False):
    """``(x + Q @ a, r − WQ @ a)`` with an (s,) coefficient vector — the
    s-step solution/residual update, ONE pass over both (n, s) blocks and
    both (n,) vectors. The vectors ride through as (n, 1) column blocks so
    the contraction stays a single fused dot per output."""
    _require_block("sstep_update", q, wq)
    _require_1d("sstep_update", x, r)
    n, s = q.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff, s), lambda i: (i, 0))
    cspec = pl.BlockSpec((chunk_eff, 1), lambda i: (i, 0))
    av = jnp.asarray(a, q.dtype).reshape(s, 1)

    def kernel(a_ref, q_ref, wq_ref, x_ref, r_ref, ox_ref, or_ref):
        ox_ref[...] = x_ref[...] + jnp.dot(
            q_ref[...], a_ref[...], preferred_element_type=ox_ref.dtype
        )
        or_ref[...] = r_ref[...] - jnp.dot(
            wq_ref[...], a_ref[...], preferred_element_type=or_ref.dtype
        )

    ox, orr = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
            spec, spec, cspec, cspec,
        ],
        out_specs=[cspec, cspec],
        out_shape=[jax.ShapeDtypeStruct((n, 1), q.dtype)] * 2,
        interpret=interpret,
    )(av, q, wq, x.reshape(n, 1), r.reshape(n, 1))
    return ox.reshape(n), orr.reshape(n)


# ---------------------------------------------------------------------------
# Legacy fixed-arity wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def fused_dots3(
    p: jax.Array, w: jax.Array, r: jax.Array, *, chunk: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    """(n,) vectors -> (3,) [p·w, r·r, p·r]; any n (masked internally)."""
    return fused_dots_n(
        [(p, w), (r, r), (p, r)], chunk=chunk, interpret=interpret
    )
