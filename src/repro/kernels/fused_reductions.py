"""Fused vector-op Pallas kernels: the CG hot path in minimal HBM passes.

CG's per-iteration scalar + vector work reads the same vectors several times
when expressed as separate ops (dots, axpys). The kernels here stream every
operand exactly once per call (chunked grid, SMEM scalar accumulation), so
each call is ONE full-vector HBM sweep:

* ``fused_dots_n``   — N inner products in one pass. Duplicate operands and
  duplicate pairs are deduplicated statically, so e.g. the fcg triple
  [(r,u), (w,u), (r,r)] with u==r reads only {r, w} and multiplies once per
  unique pair.
* ``fused_axpy``     — a*x + y.
* ``fused_axpy2``    — two independent axpys (the p/s and x/r update pairs)
  in one pass.
* ``fused_axpy2_dots`` — the CG update step ``x += a1*p; r -= a1*w`` PLUS
  the follow-up reduction ``r_new . r_new`` in the SAME pass: the freshly
  computed r chunk is still in VMEM when the partial dot accumulates, so the
  re-read of r that a separate dot would cost disappears from HBM traffic.
* ``fused_dots3``    — legacy fixed-arity [p.w, r.r, p.r] wrapper (kept for
  API stability; now handles any length, no shape restriction).

Arbitrary lengths dispatch unconditionally: the grid covers the vector in
lane-aligned chunks and the (possibly ragged) final block is masked inside
the kernel — reductions ignore out-of-range lanes, out-of-range output
writes are clipped by Pallas. No host-side padding copies, so the HBM
traffic really is one read per operand + one write per output. Scalars
(alpha/beta) arrive as a small SMEM operand so traced loop-carried values
work. Accumulation happens in the input dtype, matching the jnp oracles in
``kernels/ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _chunking(n: int, chunk: int) -> tuple[int, int]:
    """(effective chunk, grid size): lane-aligned, ragged tail allowed."""
    chunk_eff = min(chunk, _round_up(n, 128))
    return chunk_eff, -(-n // chunk_eff)


def _valid_mask(i, chunk: int, n: int):
    """(chunk,) bool mask of in-range lanes for grid step ``i``.

    TPU Mosaic requires >=2-D iota, hence the (1, chunk) detour.
    """
    lane = lax.broadcasted_iota(jnp.int32, (1, chunk), 1).reshape(chunk)
    return (i * chunk + lane) < n


# ---------------------------------------------------------------------------
# fused_dots_n — N inner products, one pass, deduplicated reads
# ---------------------------------------------------------------------------


def _dedup_pairs(pairs):
    """Static dedup: unique operand arrays, unique (i, j) products, and the
    map from output slot -> unique product."""
    uniq: list = []
    ids: dict[int, int] = {}

    def idx(a):
        if id(a) not in ids:
            ids[id(a)] = len(uniq)
            uniq.append(a)
        return ids[id(a)]

    out_map = []
    prod_ids: dict[tuple[int, int], int] = {}
    prods = []
    for x, y in pairs:
        key = tuple(sorted((idx(x), idx(y))))
        if key not in prod_ids:
            prod_ids[key] = len(prods)
            prods.append(key)
        out_map.append(prod_ids[key])
    return uniq, tuple(prods), tuple(out_map)


def fused_dots_n(pairs, *, chunk: int = 65536, interpret: bool = False) -> jax.Array:
    """Local partial dots for ``pairs = [(x, y), ...]`` — ONE HBM pass.

    Returns a (len(pairs),) vector of LOCAL sums (callers psum once in the
    distributed setting). Operands shared between pairs (by object identity)
    are read once; identical pairs are multiplied once.
    """
    uniq, prods, out_map = _dedup_pairs(pairs)
    k = len(prods)
    (n,) = uniq[0].shape
    dt = uniq[0].dtype
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))

    def kernel(*refs):
        out_ref = refs[-1]
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            for j in range(k):
                out_ref[j] = jnp.zeros((), out_ref.dtype)

        valid = _valid_mask(i, chunk_eff, n)
        vals = [refs[t][...] for t in range(len(uniq))]
        zero = jnp.zeros((), dt)
        for j, (a, b) in enumerate(prods):
            out_ref[j] += jnp.sum(jnp.where(valid, vals[a] * vals[b], zero))

    partials = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[spec] * len(uniq),
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((k,), dt),
        interpret=interpret,
    )(*uniq)
    if out_map == tuple(range(len(pairs))) and k == len(pairs):
        return partials
    return partials[jnp.asarray(out_map, jnp.int32)]


# ---------------------------------------------------------------------------
# fused axpy family
# ---------------------------------------------------------------------------


def _axpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def fused_axpy(a, x, y, *, chunk: int = 65536, interpret: bool = False):
    """a*x + y in one pass; ``a`` may be a traced scalar."""
    (n,) = x.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    av = jnp.asarray(a, x.dtype).reshape(1)
    return pl.pallas_call(
        _axpy_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(av, x, y)


def _axpy2_kernel(a_ref, x1_ref, y1_ref, x2_ref, y2_ref, o1_ref, o2_ref):
    o1_ref[...] = a_ref[0] * x1_ref[...] + y1_ref[...]
    o2_ref[...] = a_ref[1] * x2_ref[...] + y2_ref[...]


def fused_axpy2(a1, x1, y1, a2, x2, y2, *, chunk: int = 65536,
                interpret: bool = False):
    """(a1*x1 + y1, a2*x2 + y2) in one pass over all four vectors."""
    (n,) = x1.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    av = jnp.stack([jnp.asarray(a1, x1.dtype), jnp.asarray(a2, x1.dtype)])
    return pl.pallas_call(
        _axpy2_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + [spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), x1.dtype)] * 2,
        interpret=interpret,
    )(av, x1, y1, x2, y2)


def fused_axpy2_dots(a1, x1, y1, a2, x2, y2, *, chunk: int = 65536,
                     interpret: bool = False):
    """CG update + follow-up reduction in ONE pass.

    Returns (o1, o2, d) with o1 = a1*x1 + y1, o2 = a2*x2 + y2 and
    d = (1,) LOCAL partial [o2 . o2] — the new-residual norm accumulated
    while the o2 chunk is still in VMEM.
    """
    (n,) = x1.shape
    chunk_eff, grid = _chunking(n, chunk)
    spec = pl.BlockSpec((chunk_eff,), lambda i: (i,))
    av = jnp.stack([jnp.asarray(a1, x1.dtype), jnp.asarray(a2, x1.dtype)])

    def kernel(a_ref, x1_ref, y1_ref, x2_ref, y2_ref, o1_ref, o2_ref, d_ref):
        i = pl.program_id(0)
        o1_ref[...] = a_ref[0] * x1_ref[...] + y1_ref[...]
        v2 = a_ref[1] * x2_ref[...] + y2_ref[...]
        o2_ref[...] = v2

        @pl.when(i == 0)
        def _init():
            d_ref[0] = jnp.zeros((), d_ref.dtype)

        valid = _valid_mask(i, chunk_eff, n)
        d_ref[0] += jnp.sum(jnp.where(valid, v2 * v2, jnp.zeros((), v2.dtype)))

    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + [spec] * 4,
        out_specs=[spec, spec, pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x1.dtype),
            jax.ShapeDtypeStruct((n,), x1.dtype),
            jax.ShapeDtypeStruct((1,), x1.dtype),
        ],
        interpret=interpret,
    )(av, x1, y1, x2, y2)


# ---------------------------------------------------------------------------
# Legacy fixed-arity wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def fused_dots3(
    p: jax.Array, w: jax.Array, r: jax.Array, *, chunk: int = 65536,
    interpret: bool = False,
) -> jax.Array:
    """(n,) vectors -> (3,) [p·w, r·r, p·r]; any n (masked internally)."""
    return fused_dots_n(
        [(p, w), (r, r), (p, r)], chunk=chunk, interpret=interpret
    )
