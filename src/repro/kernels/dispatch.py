"""Backend-aware kernel dispatch for the solver hot path.

The repo carries three implementations of every hot-path op:

* ``pallas``    — the compiled Pallas TPU kernel (VMEM tiling, fused HBM
  passes). Only meaningful on a TPU backend; f64 calls fall back to ``jnp``
  (Mosaic has no f64).
* ``interpret`` — the same Pallas kernel run in interpret mode: exact kernel
  semantics on CPU, used by tests to validate the TPU code path.
* ``jnp``       — the pure-jnp reference (kernels/ref.py oracles). The
  default on CPU/GPU, where XLA fusion already does the right thing.

Selection: explicit argument > ``set_backend``/``use_backend`` override >
``REPRO_KERNELS`` env var > auto (TPU -> pallas, else jnp). Resolution
happens at TRACE time — a jitted solver bakes in whichever backend was
active when it was traced; build a fresh solver to switch.

Solvers obtain an :class:`OpSet` via :func:`ops_for` and call ops through
it. Every op invocation is recorded in the active :class:`SweepLedger`
(enabled with :func:`record_sweeps`), tagged with the current
:func:`ledger_section` — since ``lax.while_loop`` traces its body exactly
once, tracing a solver under the ledger yields the per-iteration HBM
sweep count directly. That is the accounting ``benchmarks/hotpath_fusion.py``
and the acceptance tests check: each vector op here streams its operands in
ONE pass, so "calls to vector ops per iteration" == "full-vector HBM sweeps
per iteration".
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
from collections import Counter

import jax
import jax.numpy as jnp

from repro.energy import trace
from repro.energy.accounting import OpCounts
from repro.kernels import ref
from repro.kernels.fused_reductions import (
    _require_1d,
    block_gram,
    block_update,
    block_update2,
    fused_axpy,
    fused_axpy2,
    fused_axpy2_dots,
    fused_dots_n,
    sstep_basis,
    sstep_gram,
    sstep_update,
)
from repro.kernels.spmv_bcsr import (
    bcsr_finish_y,
    bcsr_finish_yb,
    bcsr_prepare_x,
    bcsr_prepare_xb,
)
from repro.kernels.spmv_bcsr import bcsr_spmm as _bcsr_spmm_kernel
from repro.kernels.spmv_bcsr import bcsr_spmv as _bcsr_spmv_kernel
from repro.kernels.spmv_stencil import (
    pick_bz,
    stencil_spmv_boundary,
    stencil_spmv_halo,
)

BACKENDS = ("pallas", "interpret", "jnp")
ENV_VAR = "REPRO_KERNELS"

# Ops that stream full-length vectors exactly once per call (1 sweep each).
# The block_* ops are the multi-RHS generalization: each call streams its
# (n, r) operand blocks once, so one call is still one sweep (of n*r
# elements per operand).
VECTOR_OPS = (
    "axpy", "fused_axpy2", "fused_axpy2_dots", "fused_dots_n",
    "block_gram", "block_update", "block_update2",
    "sstep_gram", "sstep_basis", "sstep_update",
)
# The SpMV is accounted separately (its traffic is the matrix term);
# stencil_boundary is the overlap path's two-plane edge fix-up; bcsr_spmv
# is the blocked interior matvec of the BCSR-format DistMat and bcsr_spmm
# its multi-RHS sibling.
SPMV_OPS = ("stencil_matvec", "stencil_boundary", "bcsr_spmv", "bcsr_spmm")

_override: str | None = None


def available_backend() -> str:
    """Auto resolution from the JAX backend."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def resolve(choice: str | None = None) -> str:
    """Resolve a backend name: explicit > override > env > auto.

    ``None``/``''``/``'auto'`` at any level defers to the next one, so an
    explicit ``kernels='auto'`` still honors ``use_backend``/``REPRO_KERNELS``.
    """
    for cand in (choice, _override, os.environ.get(ENV_VAR)):
        if cand is None:
            continue
        cand = cand.strip().lower()
        if cand in ("", "auto"):
            continue  # defer to the next precedence level
        if cand not in BACKENDS:
            raise ValueError(
                f"unknown kernel backend {cand!r}; want one of {BACKENDS} or 'auto'"
            )
        return cand
    return available_backend()


def backend() -> str:
    """The currently active backend (no explicit choice)."""
    return resolve(None)


def set_backend(name: str | None) -> None:
    """Process-wide override (None restores env/auto resolution)."""
    global _override
    if name is not None and name.strip().lower() not in BACKENDS + ("auto",):
        raise ValueError(f"unknown kernel backend {name!r}")
    _override = name


@contextlib.contextmanager
def use_backend(name: str | None):
    """Scoped override: ``with use_backend('interpret'): make_solver(...)``."""
    global _override
    prev = _override
    set_backend(name)
    try:
        yield
    finally:
        _override = prev


# ---------------------------------------------------------------------------
# Sweep ledger (tracing-time accounting)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SweepLedger:
    """Counts op calls per section during tracing.

    ``ops[section]`` maps op name -> number of calls; ``entries[section]``
    counts how many times the section was entered (normally 1 per trace —
    used to normalize if a body is retraced).
    """

    ops: dict = dataclasses.field(default_factory=dict)
    entries: dict = dataclasses.field(default_factory=dict)

    def count(self, section: str, name: str):
        self.ops.setdefault(section, Counter())[name] += 1

    def enter(self, section: str):
        self.entries[section] = self.entries.get(section, 0) + 1

    def vector_sweeps(self, section: str = "iteration") -> float:
        """Full-vector HBM sweeps per section entry (excludes the SpMV)."""
        c = self.ops.get(section, Counter())
        n = max(self.entries.get(section, 1), 1)
        return sum(v for k, v in c.items() if k in VECTOR_OPS) / n

    def spmv_calls(self, section: str = "iteration") -> float:
        c = self.ops.get(section, Counter())
        n = max(self.entries.get(section, 1), 1)
        return sum(v for k, v in c.items() if k in SPMV_OPS) / n


_ledger: SweepLedger | None = None
_section: str = "default"


@contextlib.contextmanager
def record_sweeps():
    """Activate a ledger; trace (lower/eval_shape) solvers inside."""
    global _ledger
    prev = _ledger
    _ledger = SweepLedger()
    try:
        yield _ledger
    finally:
        _ledger = prev


@contextlib.contextmanager
def ledger_section(name: str):
    """Tag ops traced inside with ``name`` (e.g. 'iteration').

    Also switches the energy-trace section (energy/trace.py), so the sweep
    ledger and the executed-counts region ledger stay in lockstep: both see
    the while_loop body as the per-iteration accounting unit.
    """
    global _section
    prev = _section
    _section = name
    if _ledger is not None:
        _ledger.enter(name)
    try:
        with trace.section(name):
            yield
    finally:
        _section = prev


def _record(name: str, counts: OpCounts | None = None):
    if _ledger is not None:
        _ledger.count(_section, name)
    if counts is not None:
        trace.record_op(name, counts)


# ---------------------------------------------------------------------------
# Op set
# ---------------------------------------------------------------------------


def _pallas_mode(backend_name: str, dtype) -> str:
    """Compiled-pallas f64 calls fall back to jnp (Mosaic has no f64)."""
    if backend_name == "pallas" and jnp.dtype(dtype) == jnp.dtype("float64"):
        return "jnp"
    return backend_name


# executed-counts formulas shared with the other instrumented layers
_axpy_counts = trace.streamed_axpy_counts


class OpSet:
    """Hot-path ops bound to one backend. Obtain via :func:`ops_for`."""

    def __init__(self, backend_name: str, *, chunk: int = 65536):
        assert backend_name in BACKENDS
        self.backend = backend_name
        self.chunk = chunk

    def __repr__(self):
        return f"OpSet(backend={self.backend!r})"

    # -- fused vector ops (1 HBM sweep each) --------------------------------

    def axpy(self, a, x, y):
        """``a*x + y`` for a scalar ``a`` and (n,) vectors ``x``/``y``.

        One fused HBM pass: 2n flops, 3n elements streamed (read x, y;
        write the result). Returns the (n,) updated vector.
        """
        _require_1d("axpy", x, y)
        _record("axpy", _axpy_counts(x.size, x.dtype.itemsize))
        b = _pallas_mode(self.backend, x.dtype)
        if b == "jnp":
            return ref.fused_axpy_ref(a, x, y)
        return fused_axpy(a, x, y, chunk=self.chunk,
                          interpret=(b == "interpret"))

    def fused_axpy2(self, a1, x1, y1, a2, x2, y2):
        """``(a1*x1 + y1, a2*x2 + y2)`` — two independent axpys, ONE pass.

        The two updates may not feed each other (they are evaluated from
        the inputs as given). Returns the pair of (n,) results; counts as a
        single HBM sweep of 6n streamed elements / 4n flops.
        """
        _require_1d("fused_axpy2", x1, y1, x2, y2)
        _record("fused_axpy2", _axpy_counts(x1.size, x1.dtype.itemsize, 2))
        b = _pallas_mode(self.backend, x1.dtype)
        if b == "jnp":
            return ref.fused_axpy2_ref(a1, x1, y1, a2, x2, y2)
        return fused_axpy2(a1, x1, y1, a2, x2, y2, chunk=self.chunk,
                           interpret=(b == "interpret"))

    def fused_axpy2_dots(self, a1, x1, y1, a2, x2, y2):
        """``(a1*x1+y1, a2*x2+y2, [o2·o2])`` in ONE pass.

        The hs-update special: both axpys plus the *local* squared norm of
        the second output (a (1,) array — callers ``psum`` it), computed
        while the operands are already streaming. Same HBM traffic as
        :meth:`fused_axpy2`, +2n flops.
        """
        _require_1d("fused_axpy2_dots", x1, y1, x2, y2)
        n, ib = x1.size, x1.dtype.itemsize
        # two fused updates + the in-flight dot of the second output (no
        # extra HBM pass — the operands are already streaming).
        _record(
            "fused_axpy2_dots",
            _axpy_counts(n, ib, 2) + OpCounts(flops=2.0 * n),
        )
        b = _pallas_mode(self.backend, x1.dtype)
        if b == "jnp":
            return ref.fused_axpy2_dots_ref(a1, x1, y1, a2, x2, y2)
        return fused_axpy2_dots(a1, x1, y1, a2, x2, y2, chunk=self.chunk,
                                interpret=(b == "interpret"))

    def fused_dots_n(self, pairs):
        """Local partial dots ``[(x, y), ...] -> (len(pairs),)``, ONE pass.

        Repeated operands are deduplicated (each distinct vector is
        streamed once), so e.g. the fcg triple ``[(r,u),(w,u),(r,r)]`` with
        ``u is r`` reads only {r, w}. Results are LOCAL partial sums — the
        caller packs them into a single ``lax.psum``.
        """
        _require_1d("fused_dots_n", *[a for p in pairs for a in p])
        _record("fused_dots_n", trace.local_dots_counts(pairs))
        b = _pallas_mode(self.backend, pairs[0][0].dtype)
        if b == "jnp":
            return ref.fused_dots_n_ref(pairs)
        return fused_dots_n(pairs, chunk=self.chunk,
                            interpret=(b == "interpret"))

    # -- multi-RHS block ops (1 HBM sweep each) -----------------------------

    def block_gram(self, pairs):
        """Local Gram blocks ``[Xᵀ @ Y, ...]`` for (n, r) pairs, ONE pass.

        The block-CG reduction primitive: each distinct operand block is
        streamed once, the (r, r) accumulators stay resident. Results are
        LOCAL — callers pack them into a single psum (`fused_blocks`).
        Order-sensitive (XᵀY != YᵀX), unlike the scalar dots.
        """
        _record("block_gram", trace.block_gram_counts(pairs))
        b = _pallas_mode(self.backend, pairs[0][0].dtype)
        if b == "jnp":
            return ref.block_gram_ref(pairs)
        return block_gram(pairs, interpret=(b == "interpret"))

    def block_update(self, m, x, y, mask=None):
        """``y * mask + x @ m`` for (n, r) blocks and an (r, r) coefficient
        block; ``mask`` is an optional (r,) column scale (the deflation
        mask) folded into the same pass. One sweep: read x, y; write o.
        """
        n, r = x.shape
        _record("block_update", trace.block_update_counts(
            n, r, x.dtype.itemsize))
        b = _pallas_mode(self.backend, x.dtype)
        if b == "jnp":
            return ref.block_update_ref(m, x, y, mask)
        return block_update(m, x, y, mask, chunk=self.chunk,
                            interpret=(b == "interpret"))

    def block_update2(self, a1, x1, y1, a2, x2, y2):
        """``(y1 + x1 @ a1, y2 + x2 @ a2)`` — the block-CG X/R update pair
        in ONE pass over all four (n, r) blocks."""
        n, r = x1.shape
        _record("block_update2", trace.block_update_counts(
            n, r, x1.dtype.itemsize, terms=2))
        b = _pallas_mode(self.backend, x1.dtype)
        if b == "jnp":
            return ref.block_update2_ref(a1, x1, y1, a2, x2, y2)
        return block_update2(a1, x1, y1, a2, x2, y2, chunk=self.chunk,
                             interpret=(b == "interpret"))

    # -- s-step block ops (1 HBM sweep each) --------------------------------

    def sstep_gram(self, pb, wb, wp, r):
        """Local s-step reduction ``[PᵀW | WpᵀP | Pᵀr | rᵀr]`` as one flat
        (2s² + s + 1,) vector, ONE pass over {P, W, Wp, r}.

        Everything the s-step block solve needs from the data — both Gram
        blocks, the moment vector, and the residual norm — as LOCAL partial
        sums the caller psums once (`fused_blocks`). The basis column
        A-norms for the stability scaling are ``diag(PᵀW)``, so the
        collective payload matches the unscaled algorithm exactly.
        """
        n, s = pb.shape
        ib = pb.dtype.itemsize
        _record(
            "sstep_gram",
            OpCounts(
                flops=float(4 * n * s * s + 2 * n * s + 2 * n),
                hbm_bytes=float((3 * s + 1) * n + 2 * s * s + s + 1) * ib,
            ),
        )
        b = _pallas_mode(self.backend, pb.dtype)
        if b == "jnp":
            return ref.sstep_gram_ref(pb, wb, wp, r)
        return sstep_gram(pb, wb, wp, r, interpret=(b == "interpret"))

    def sstep_basis(self, b, dinv, qp, pb, wp, wb):
        """``(Pb·diag(dinv) − Qp @ b, Wb·diag(dinv) − Wp @ b)`` — the
        normalized A-conjugated search/image blocks, ONE pass over all four
        (n, s) blocks (read 4, write 2)."""
        n, s = pb.shape
        ib = pb.dtype.itemsize
        _record(
            "sstep_basis",
            OpCounts(
                flops=float(4 * n * s * s + 4 * n * s),
                hbm_bytes=6.0 * n * s * ib,
            ),
        )
        bk = _pallas_mode(self.backend, pb.dtype)
        if bk == "jnp":
            return ref.sstep_basis_ref(b, dinv, qp, pb, wp, wb)
        return sstep_basis(b, dinv, qp, pb, wp, wb,
                           interpret=(bk == "interpret"))

    def sstep_update(self, a, q, wq, x, r):
        """``(x + Q @ a, r − WQ @ a)`` for an (s,) coefficient vector — the
        s-step x/r update, ONE pass over both blocks and both vectors."""
        n, s = q.shape
        ib = q.dtype.itemsize
        _record(
            "sstep_update",
            OpCounts(
                flops=float(4 * n * s + 2 * n),
                hbm_bytes=float(2 * n * s + 4 * n) * ib,
            ),
        )
        b = _pallas_mode(self.backend, q.dtype)
        if b == "jnp":
            return ref.sstep_update_ref(a, q, wq, x, r)
        return sstep_update(a, q, wq, x, r, interpret=(b == "interpret"))

    # -- SpMV ---------------------------------------------------------------

    def stencil_matvec(self, x3, prev_halo, next_halo, *, stencil="7pt",
                       aniso=(1.0, 1.0, 1.0)):
        """Local-slab matrix-free SpMV with explicit z-halo planes.

        Args: ``x3`` the (nz_loc, ny, nx) slab, ``prev_halo``/``next_halo``
        the (ny, nx) neighbor boundary planes (zeros at the global edges).
        Returns the (nz_loc, ny, nx) product. Accounted as one full-slab
        HBM sweep plus the two halo planes (matrix-free: no value/index
        traffic).
        """
        n, ib = x3.size, x3.dtype.itemsize
        k = {"7pt": 7, "27pt": 27}[stencil]
        # matrix-free: NO matrix-value/index traffic — read the slab + both
        # halo planes once, write the result slab once.
        _record(
            "stencil_matvec",
            OpCounts(
                flops=2.0 * k * n,
                hbm_bytes=float(n + prev_halo.size + next_halo.size + n) * ib,
            ),
        )
        b = _pallas_mode(self.backend, x3.dtype)
        if b == "jnp":
            return ref.stencil_halo_ref(
                x3, prev_halo, next_halo, stencil=stencil, aniso=aniso
            )
        return stencil_spmv_halo(
            x3, prev_halo, next_halo, stencil=stencil, aniso=aniso,
            bz=pick_bz(x3.shape[0]), interpret=(b == "interpret"),
        )

    def bcsr_spmv(self, blocks, bcol, x, *, n_brows, bpr, n_out=None):
        """Uniform-layout block-CSR SpMV (the BCSR DistMat interior).

        ``blocks`` is the (n_brows*bpr, br, bc) dense-block array and
        ``bcol`` its block-column ids (``core.sparse.pack_bcsr`` layout,
        padding blocks all-zero with ``bcol == 0``). ``x`` may be the
        native (n_bcols, bc) tile layout or a flat (n,) vector — flat
        inputs are zero-padded up to the block grid and returned flat,
        trimmed to ``n_out``. Accounted as one streaming pass over blocks
        + block ids + the source vector, writing the blocked result.
        """
        _, br, bc = blocks.shape
        b = x.dtype.itemsize
        mat_bytes = float(blocks.size * b + bcol.size * bcol.dtype.itemsize)
        _record(
            "bcsr_spmv",
            OpCounts(
                flops=2.0 * blocks.size,
                hbm_bytes=mat_bytes + float(x.size * b + n_brows * br * b),
                hbm_matrix_bytes=mat_bytes,
            ),
        )
        backend_name = _pallas_mode(self.backend, x.dtype)
        x, flat, n_out = bcsr_prepare_x(
            blocks, x, n_brows=n_brows, bpr=bpr, n_out=n_out
        )
        if backend_name == "jnp":
            y = ref.bcsr_spmv_ref(blocks, bcol, x, n_brows, bpr)
        else:
            y = _bcsr_spmv_kernel(
                blocks, bcol, x, n_brows=n_brows, bpr=bpr,
                interpret=(backend_name == "interpret"),
            )
        return bcsr_finish_y(y, flat, n_out)

    def bcsr_spmm(self, blocks, bcol, x, *, n_brows, bpr, n_out=None):
        """Multi-RHS :meth:`bcsr_spmv`: ``x`` is an (n, r) RHS block (or
        the native (n_bcols, bc, r) tile layout). The matrix blocks and
        ids are streamed ONCE while vector traffic scales with ``r`` — the
        amortization the multi-RHS solver exists for, visible in the
        recorded ``hbm_matrix_bytes``."""
        _, br, bc = blocks.shape
        r = x.shape[-1]
        b = x.dtype.itemsize
        mat_bytes = float(blocks.size * b + bcol.size * bcol.dtype.itemsize)
        _record(
            "bcsr_spmm",
            OpCounts(
                flops=2.0 * blocks.size * r,
                hbm_bytes=mat_bytes + float(x.size * b + n_brows * br * r * b),
                hbm_matrix_bytes=mat_bytes,
            ),
        )
        backend_name = _pallas_mode(self.backend, x.dtype)
        x, flat, n_out = bcsr_prepare_xb(
            blocks, x, n_brows=n_brows, bpr=bpr, n_out=n_out
        )
        if backend_name == "jnp":
            y = ref.bcsr_spmm_ref(blocks, bcol, x, n_brows, bpr)
        else:
            y = _bcsr_spmm_kernel(
                blocks, bcol, x, n_brows=n_brows, bpr=bpr,
                interpret=(backend_name == "interpret"),
            )
        return bcsr_finish_yb(y, flat, n_out)

    def stencil_boundary(self, x3, prev_halo, next_halo, *, stencil="7pt",
                         aniso=(1.0, 1.0, 1.0)):
        """First + last output planes of the slab SpMV (overlap fix-up).

        The communication-hiding stencil path runs :meth:`stencil_matvec`
        with zero halos while the ppermute is in flight, then patches the
        two slab-edge output planes with this op once the halo planes
        arrive. Args as in :meth:`stencil_matvec` (``x3.shape[0] >= 2``);
        returns (2, ny, nx): output planes 0 and nz_loc-1, bitwise equal to
        the serialized single-call planes. Accounted as plane-sized traffic
        only (6 planes read, 2 written).
        """
        n_pl, ib = prev_halo.size, x3.dtype.itemsize
        k = {"7pt": 7, "27pt": 27}[stencil]
        _record(
            "stencil_boundary",
            OpCounts(flops=2.0 * k * 2 * n_pl, hbm_bytes=8.0 * n_pl * ib),
        )
        b = _pallas_mode(self.backend, x3.dtype)
        if b == "jnp":
            return ref.stencil_boundary_ref(
                x3, prev_halo, next_halo, stencil=stencil, aniso=aniso
            )
        return stencil_spmv_boundary(
            x3, prev_halo, next_halo, stencil=stencil, aniso=aniso,
            interpret=(b == "interpret"),
        )


def ops_for(kernels: str | None = None, *, chunk: int = 65536) -> OpSet:
    """Resolve a backend choice into a bound :class:`OpSet`.

    ``kernels``: None/'auto' (resolve from override/env/backend) or one of
    ``BACKENDS``. Solver factories thread their ``kernels=`` argument here.
    """
    return OpSet(resolve(kernels), chunk=chunk)
