"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the mathematical definition the corresponding kernel
must reproduce; tests sweep shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Matrix-free stencil SpMV (7pt / 27pt, Dirichlet) on a (nz, ny, nx) grid
# ---------------------------------------------------------------------------


def _shift(x: jax.Array, d: int, axis: int) -> jax.Array:
    """Shift with zero fill: result[i] = x[i - d] (zeros flow in)."""
    if d == 0:
        return x
    pad = [(0, 0)] * x.ndim
    if d > 0:
        pad[axis] = (d, 0)
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, x.shape[axis])
        return jnp.pad(x, pad)[tuple(sl)]
    pad[axis] = (0, -d)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(-d, x.shape[axis] - d)
    return jnp.pad(x, pad)[tuple(sl)]


def stencil7_ref(x: jax.Array, aniso=(1.0, 1.0, 1.0)) -> jax.Array:
    """y = A7 @ x on the (nz, ny, nx) grid, homogeneous Dirichlet."""
    ax, ay, az = aniso
    diag = 2.0 * (ax + ay + az)
    y = diag * x
    y = y - ax * (_shift(x, 1, 2) + _shift(x, -1, 2))
    y = y - ay * (_shift(x, 1, 1) + _shift(x, -1, 1))
    y = y - az * (_shift(x, 1, 0) + _shift(x, -1, 0))
    return y


def stencil27_ref(x: jax.Array) -> jax.Array:
    """y = A27 @ x (HPCG stencil: diag 26, all 26 neighbors -1)."""
    s9 = jnp.zeros_like(x)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            s9 = s9 + _shift(_shift(x, dx, 2), dy, 1)
    s27 = _shift(s9, -1, 0) + s9 + _shift(s9, 1, 0)
    return 27.0 * x - s27


def stencil_halo_ref(
    x: jax.Array,  # (nz_loc, ny, nx) local slab
    prev_halo: jax.Array,  # (ny, nx) boundary plane from the z- neighbor
    next_halo: jax.Array,  # (ny, nx) boundary plane from the z+ neighbor
    *,
    stencil: str = "7pt",
    aniso=(1.0, 1.0, 1.0),
) -> jax.Array:
    """Local-slab stencil SpMV with explicit z-boundary planes.

    The distributed-operator contract: zeros in the halo planes reproduce the
    global Dirichlet edges, so ``stencil_halo_ref(x, 0, 0) == stencil*_ref(x)``.
    """
    ext = jnp.concatenate([prev_halo[None], x, next_halo[None]], axis=0)
    c = ext[1:-1]
    if stencil == "7pt":
        ax, ay, az = aniso
        y = 2.0 * (ax + ay + az) * c
        y = y - ax * (_shift(c, 1, 2) + _shift(c, -1, 2))
        y = y - ay * (_shift(c, 1, 1) + _shift(c, -1, 1))
        y = y - az * (ext[:-2] + ext[2:])
        return y
    s9 = jnp.zeros_like(ext)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            s9 = s9 + _shift(_shift(ext, dx, 2), dy, 1)
    return 27.0 * c - (s9[:-2] + s9[1:-1] + s9[2:])


def stencil_boundary_ref(
    x: jax.Array,  # (nz_loc, ny, nx) local slab, nz_loc >= 2
    prev_halo: jax.Array,  # (ny, nx) boundary plane from the z- neighbor
    next_halo: jax.Array,  # (ny, nx) boundary plane from the z+ neighbor
    *,
    stencil: str = "7pt",
    aniso=(1.0, 1.0, 1.0),
) -> jax.Array:
    """First + last output planes of the slab SpMV (overlap fix-up oracle).

    Returns (2, ny, nx): rows 0/1 are output planes 0 and nz_loc-1 —
    bitwise the same planes :func:`stencil_halo_ref` produces. Computed on
    one-plane sub-slabs so only O(ny*nx) work is done, not the full slab.
    """
    y0 = stencil_halo_ref(x[:1], prev_halo, x[1], stencil=stencil, aniso=aniso)
    y1 = stencil_halo_ref(x[-1:], x[-2], next_halo, stencil=stencil, aniso=aniso)
    return jnp.concatenate([y0, y1], axis=0)


def jacobi_stencil_ref(
    x: jax.Array, b: jax.Array, dinv: jax.Array, *, stencil: str = "7pt",
    aniso=(1.0, 1.0, 1.0), omega: float = 1.0,
) -> jax.Array:
    """One fused l1-Jacobi sweep: x + omega * dinv * (b - A x)."""
    ax = stencil7_ref(x, aniso) if stencil == "7pt" else stencil27_ref(x)
    return x + omega * dinv * (b - ax)


# ---------------------------------------------------------------------------
# Block-CSR SpMV
# ---------------------------------------------------------------------------


def bcsr_spmv_ref(
    blocks: jax.Array,  # (n_brows * bpr, br, bc) uniform blocks-per-row
    bcol: jax.Array,  # (n_brows * bpr,) int32 block-column ids
    x: jax.Array,  # (n_bcols, bc)
    n_brows: int,
    bpr: int,
) -> jax.Array:
    """y (n_brows, br): padded blocks carry zeros so they contribute nothing."""
    xb = x[bcol]  # (n_brows*bpr, bc)
    contrib = jnp.einsum("nij,nj->ni", blocks, xb)
    return contrib.reshape(n_brows, bpr, -1).sum(axis=1)


def bcsr_spmm_ref(
    blocks: jax.Array,  # (n_brows * bpr, br, bc) uniform blocks-per-row
    bcol: jax.Array,  # (n_brows * bpr,) int32 block-column ids
    x: jax.Array,  # (n_bcols, bc, r) RHS block, blocked rows
    n_brows: int,
    bpr: int,
) -> jax.Array:
    """Multi-RHS sibling of :func:`bcsr_spmv_ref`: y (n_brows, br, r)."""
    xb = x[bcol]  # (n_brows*bpr, bc, r)
    contrib = jnp.einsum("nij,njc->nic", blocks, xb)
    br = blocks.shape[1]
    return contrib.reshape(n_brows, bpr, br, -1).sum(axis=1)


# ---------------------------------------------------------------------------
# Fused multi-dot reductions
# ---------------------------------------------------------------------------


def fused_dots3_ref(p: jax.Array, w: jax.Array, r: jax.Array) -> jax.Array:
    """[p.w, r.r, p.r] in one definition (kernel computes all in one pass)."""
    return jnp.stack([jnp.vdot(p, w), jnp.vdot(r, r), jnp.vdot(p, r)])


def fused_dots_n_ref(pairs) -> jax.Array:
    """Local partial dots for [(x, y), ...] (kernel: one pass, dedup'd)."""
    return jnp.stack([jnp.vdot(x, y) for x, y in pairs])


def fused_axpy_ref(a, x: jax.Array, y: jax.Array) -> jax.Array:
    return a * x + y


def fused_axpy2_ref(a1, x1, y1, a2, x2, y2):
    return a1 * x1 + y1, a2 * x2 + y2


def fused_axpy2_dots_ref(a1, x1, y1, a2, x2, y2):
    o1 = a1 * x1 + y1
    o2 = a2 * x2 + y2
    return o1, o2, jnp.vdot(o2, o2)[None]


# ---------------------------------------------------------------------------
# Multi-RHS block kernels
# ---------------------------------------------------------------------------


def block_gram_ref(pairs) -> list:
    """Local (r, r) Gram blocks [Xᵀ @ Y, ...] (kernel: one pass, dedup'd).

    Order-sensitive: XᵀY is the transpose of YᵀX, not the same product.
    """
    return [x.T @ y for x, y in pairs]


def block_update_ref(m, x: jax.Array, y: jax.Array, mask=None) -> jax.Array:
    """y * mask + x @ m with ``mask`` an optional (r,) column scale."""
    ym = y if mask is None else y * mask[None, :]
    return ym + x @ m


def block_update2_ref(a1, x1, y1, a2, x2, y2):
    return y1 + x1 @ a1, y2 + x2 @ a2


# ---------------------------------------------------------------------------
# s-step CG kernels
# ---------------------------------------------------------------------------


def sstep_gram_ref(pb, wb, wp, r) -> jax.Array:
    """Flat local s-step reduction ``[PᵀW | WpᵀP | Pᵀr | rᵀr]`` of length
    2s² + s + 1 (kernel: one pass over P, W, Wp, r)."""
    return jnp.concatenate([
        (pb.T @ wb).reshape(-1),
        (wp.T @ pb).reshape(-1),
        pb.T @ r,
        jnp.vdot(r, r)[None],
    ])


def sstep_basis_ref(b, dinv, qp, pb, wp, wb):
    """``(Pb·diag(dinv) − Qp @ b, Wb·diag(dinv) − Wp @ b)`` — the s-step
    A-conjugation with the column normalization folded in."""
    return pb * dinv[None, :] - qp @ b, wb * dinv[None, :] - wp @ b


def sstep_update_ref(a, q, wq, x, r):
    """``(x + Q @ a, r − WQ @ a)`` for an (s,) coefficient vector."""
    return x + q @ a, r - wq @ a
