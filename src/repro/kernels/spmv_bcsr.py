"""Block-CSR SpMV Pallas kernel with scalar-prefetched column indices.

The TPU-native analog of the paper's CSR warp-per-row SpMV for *unstructured*
matrices: TPUs have no efficient per-element gather, so the sparse structure
is blocked into dense (br, bc) tiles; the block-column indices are
**scalar-prefetched** (``PrefetchScalarGridSpec``) so the pipeline can issue
the HBM->VMEM copy of the right x tile ahead of compute — the TPU equivalent
of the GPU kernel's latency hiding via massive thread parallelism.

Layout: every block-row is padded to a uniform ``bpr`` blocks (padding blocks
are all-zero with bcol=0, contributing nothing). Grid = (n_brows, bpr),
j-fastest; the output tile for block-row i is revisited across j and
accumulated in place (sequential TPU grid semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bcsr_kernel(bcol_ref, blocks_ref, x_ref, y_ref, *, bpr):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0]  # (br, bc)
    xv = x_ref[0]  # (bc,)
    y_ref[0, :] += jnp.dot(blk, xv, preferred_element_type=y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_brows", "bpr", "interpret"))
def bcsr_spmv(
    blocks: jax.Array,  # (n_brows * bpr, br, bc)
    bcol: jax.Array,  # (n_brows * bpr,) int32
    x: jax.Array,  # (n_bcols, bc)
    *,
    n_brows: int,
    bpr: int,
    interpret: bool = False,
) -> jax.Array:
    _, br, bc = blocks.shape
    kernel = functools.partial(_bcsr_kernel, bpr=bpr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, bpr),
        in_specs=[
            pl.BlockSpec((1, br, bc), lambda i, j, bcol_ref: (i * bpr + j, 0, 0)),
            pl.BlockSpec((1, bc), lambda i, j, bcol_ref: (bcol_ref[i * bpr + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j, bcol_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, br), x.dtype),
        interpret=interpret,
    )(bcol, blocks, x)


def _bcsr_spmm_kernel(bcol_ref, blocks_ref, x_ref, y_ref, *, bpr):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0]  # (br, bc)
    xv = x_ref[0]  # (bc, r)
    y_ref[0] += jnp.dot(blk, xv, preferred_element_type=y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_brows", "bpr", "interpret"))
def bcsr_spmm(
    blocks: jax.Array,  # (n_brows * bpr, br, bc)
    bcol: jax.Array,  # (n_brows * bpr,) int32
    x: jax.Array,  # (n_bcols, bc, r) RHS block
    *,
    n_brows: int,
    bpr: int,
    interpret: bool = False,
) -> jax.Array:
    """Multi-RHS sibling of :func:`bcsr_spmv`: each (br, bc) matrix tile is
    fetched ONCE and contracted against the full (bc, r) RHS tile, so matrix
    traffic is amortized across the batch while the grid/prefetch schedule
    stays identical to the SpMV kernel."""
    _, br, bc = blocks.shape
    r = x.shape[2]
    kernel = functools.partial(_bcsr_spmm_kernel, bpr=bpr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, bpr),
        in_specs=[
            pl.BlockSpec(
                (1, br, bc), lambda i, j, bcol_ref: (i * bpr + j, 0, 0)
            ),
            pl.BlockSpec(
                (1, bc, r), lambda i, j, bcol_ref: (bcol_ref[i * bpr + j], 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, br, r), lambda i, j, bcol_ref: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, br, r), x.dtype),
        interpret=interpret,
    )(bcol, blocks, x)


def bcsr_prepare_x(blocks, x, *, n_brows: int, bpr: int, n_out: int | None):
    """Shared ragged-size guard for the uniform-layout BCSR SpMV callers.

    Validates the packing (``blocks.shape[0] == n_brows * bpr``) and, for a
    flat ``(n,)`` vector with ``n % bc != 0``, zero-pads the trailing block
    column up to the tile grid. Returns ``(x2, flat, n_out)`` where ``x2``
    is the kernel's native (n_bcols, bc) layout and ``n_out`` the length to
    trim the flattened result to (None for native-layout inputs). Both
    ``kernels/ops.bcsr_spmv`` and the dispatch ``OpSet.bcsr_spmv`` go
    through here, so the two entry points cannot drift apart.
    """
    _, br, bc = blocks.shape
    if blocks.shape[0] != n_brows * bpr:
        raise ValueError(
            f"blocks leading dim {blocks.shape[0]} != n_brows*bpr "
            f"({n_brows}*{bpr}); pack with core.sparse.pack_bcsr"
        )
    flat = x.ndim == 1
    if flat:
        n = x.shape[0]
        n_bcols = -(-n // bc)
        pad = n_bcols * bc - n
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        x = x.reshape(n_bcols, bc)
        if n_out is None:
            n_out = min(n, n_brows * br)
    return x, flat, n_out


def bcsr_finish_y(y, flat: bool, n_out: int | None):
    """Inverse of :func:`bcsr_prepare_x`'s flat handling: flatten and trim
    the (n_brows, br) kernel result back to the caller's vector length."""
    return y.reshape(-1)[:n_out] if flat else y


def bcsr_prepare_xb(blocks, x, *, n_brows: int, bpr: int, n_out: int | None):
    """:func:`bcsr_prepare_x` for (n, r) RHS blocks: zero-pads the row
    dimension to the tile grid and reshapes to the kernel's native
    (n_bcols, bc, r) layout. Native 3-D inputs pass through untouched."""
    _, br, bc = blocks.shape
    if blocks.shape[0] != n_brows * bpr:
        raise ValueError(
            f"blocks leading dim {blocks.shape[0]} != n_brows*bpr "
            f"({n_brows}*{bpr}); pack with core.sparse.pack_bcsr"
        )
    flat = x.ndim == 2
    if flat:
        n, r = x.shape
        n_bcols = -(-n // bc)
        pad = n_bcols * bc - n
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, r), x.dtype)], axis=0)
        x = x.reshape(n_bcols, bc, r)
        if n_out is None:
            n_out = min(n, n_brows * br)
    return x, flat, n_out


def bcsr_finish_yb(y, flat: bool, n_out: int | None):
    """Flatten/trim the (n_brows, br, r) SpMM result to (n_out, r)."""
    return y.reshape(-1, y.shape[-1])[:n_out] if flat else y


# Host-side packing lives with the other format conversions in
# core/sparse.py (one block-packing implementation); re-exported here for
# the kernel-facing import path.
from repro.core.sparse import pack_bcsr  # noqa: E402, F401
