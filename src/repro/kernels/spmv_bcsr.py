"""Block-CSR SpMV Pallas kernel with scalar-prefetched column indices.

The TPU-native analog of the paper's CSR warp-per-row SpMV for *unstructured*
matrices: TPUs have no efficient per-element gather, so the sparse structure
is blocked into dense (br, bc) tiles; the block-column indices are
**scalar-prefetched** (``PrefetchScalarGridSpec``) so the pipeline can issue
the HBM->VMEM copy of the right x tile ahead of compute — the TPU equivalent
of the GPU kernel's latency hiding via massive thread parallelism.

Layout: every block-row is padded to a uniform ``bpr`` blocks (padding blocks
are all-zero with bcol=0, contributing nothing). Grid = (n_brows, bpr),
j-fastest; the output tile for block-row i is revisited across j and
accumulated in place (sequential TPU grid semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bcsr_kernel(bcol_ref, blocks_ref, x_ref, y_ref, *, bpr):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = blocks_ref[0]  # (br, bc)
    xv = x_ref[0]  # (bc,)
    y_ref[0, :] += jnp.dot(blk, xv, preferred_element_type=y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_brows", "bpr", "interpret"))
def bcsr_spmv(
    blocks: jax.Array,  # (n_brows * bpr, br, bc)
    bcol: jax.Array,  # (n_brows * bpr,) int32
    x: jax.Array,  # (n_bcols, bc)
    *,
    n_brows: int,
    bpr: int,
    interpret: bool = False,
) -> jax.Array:
    _, br, bc = blocks.shape
    kernel = functools.partial(_bcsr_kernel, bpr=bpr)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_brows, bpr),
        in_specs=[
            pl.BlockSpec((1, br, bc), lambda i, j, bcol_ref: (i * bpr + j, 0, 0)),
            pl.BlockSpec((1, bc), lambda i, j, bcol_ref: (bcol_ref[i * bpr + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j, bcol_ref: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_brows, br), x.dtype),
        interpret=interpret,
    )(bcol, blocks, x)


# ---------------------------------------------------------------------------
# Host-side packing: scipy CSR -> uniform-bpr BCSR arrays
# ---------------------------------------------------------------------------


def pack_bcsr(a_csr, br: int, bc: int, dtype=np.float32):
    """Pack a scipy matrix into the kernel's uniform blocks-per-row layout.

    Returns (blocks (n_brows*bpr, br, bc), bcol (n_brows*bpr,), n_brows, bpr,
    n_bcols). Zero-pads the matrix up to block multiples and each block-row
    to the max block count.
    """
    import scipy.sparse as sp

    a = a_csr.tocsr()
    n, m = a.shape
    n_brows = -(-n // br)
    n_bcols = -(-m // bc)
    ap = sp.csr_matrix((a.data, a.indices, a.indptr), shape=(n, m))
    ap.resize(n_brows * br, n_bcols * bc)
    coo = ap.tocoo()
    bi = (coo.row // br).astype(np.int64)
    bj = (coo.col // bc).astype(np.int64)
    keys = bi * n_bcols + bj
    uniq, inv = np.unique(keys, return_inverse=True)
    ubi, ubj = uniq // n_bcols, uniq % n_bcols
    counts = np.bincount(ubi, minlength=n_brows)
    bpr = max(int(counts.max()), 1)
    blocks = np.zeros((n_brows * bpr, br, bc), dtype)
    bcol = np.zeros((n_brows * bpr,), np.int32)
    # slot of each unique block within its row
    slot = np.zeros(len(uniq), np.int64)
    next_slot = np.zeros(n_brows, np.int64)
    for u, r in enumerate(ubi):  # uniq is sorted by (bi, bj)
        slot[u] = next_slot[r]
        next_slot[r] += 1
    dst = ubi * bpr + slot
    bcol[dst] = ubj.astype(np.int32)
    blocks_flat_idx = dst[inv]
    blocks[blocks_flat_idx, coo.row % br, coo.col % bc] = coo.data
    return blocks, bcol, n_brows, bpr, n_bcols
