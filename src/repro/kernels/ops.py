"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU backends (this container) and False on
TPU — kernels are *written for* TPU (explicit BlockSpec VMEM tiling) and
*validated* in interpret mode against the pure-jnp oracles in ref.py.
"""

from __future__ import annotations

import jax

from repro.kernels import ref  # noqa: F401  (re-exported oracle module)
from repro.kernels.fused_reductions import fused_axpy as _fused_axpy
from repro.kernels.fused_reductions import fused_axpy2 as _fused_axpy2
from repro.kernels.fused_reductions import fused_axpy2_dots as _fused_axpy2_dots
from repro.kernels.fused_reductions import fused_dots3 as _fused_dots3
from repro.kernels.fused_reductions import fused_dots_n as _fused_dots_n
from repro.kernels.jacobi_stencil import jacobi_stencil_sweep as _jacobi
from repro.kernels.spmv_bcsr import bcsr_spmv as _bcsr_spmv
from repro.kernels.spmv_bcsr import pack_bcsr  # noqa: F401
from repro.kernels.spmv_stencil import pick_bz  # noqa: F401
from repro.kernels.spmv_stencil import stencil_spmv as _stencil_spmv
from repro.kernels.spmv_stencil import (
    stencil_spmv_boundary as _stencil_spmv_boundary,
)
from repro.kernels.spmv_stencil import stencil_spmv_halo as _stencil_spmv_halo


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def stencil_spmv(x, *, stencil="7pt", aniso=(1.0, 1.0, 1.0), bz=8, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _stencil_spmv(x, stencil=stencil, aniso=aniso, bz=bz, interpret=interpret)


def bcsr_spmv(blocks, bcol, x, *, n_brows, bpr, n_out=None, interpret=None):
    """Uniform-layout BCSR SpMV with ragged-size guarding.

    ``x`` may be the kernel's native ``(n_bcols, bc)`` tile layout or a flat
    ``(n,)`` vector with ``n % bc != 0`` — flat inputs are zero-padded up to
    the block grid (the trailing block-row/column is padded, not rejected)
    and the result comes back flat, trimmed to ``n_out`` (default: the
    input length capped at ``n_brows * br``).
    """
    from repro.kernels.spmv_bcsr import bcsr_finish_y, bcsr_prepare_x

    interpret = _default_interpret() if interpret is None else interpret
    x, flat, n_out = bcsr_prepare_x(
        blocks, x, n_brows=n_brows, bpr=bpr, n_out=n_out
    )
    y = _bcsr_spmv(
        blocks, bcol, x, n_brows=n_brows, bpr=bpr, interpret=interpret
    )
    return bcsr_finish_y(y, flat, n_out)


def stencil_spmv_halo(
    x, prev_halo, next_halo, *, stencil="7pt", aniso=(1.0, 1.0, 1.0), bz=8,
    interpret=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _stencil_spmv_halo(
        x, prev_halo, next_halo, stencil=stencil, aniso=aniso, bz=bz,
        interpret=interpret,
    )


def stencil_spmv_boundary(
    x, prev_halo, next_halo, *, stencil="7pt", aniso=(1.0, 1.0, 1.0),
    interpret=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _stencil_spmv_boundary(
        x, prev_halo, next_halo, stencil=stencil, aniso=aniso,
        interpret=interpret,
    )


def fused_dots3(p, w, r, *, chunk=65536, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fused_dots3(p, w, r, chunk=chunk, interpret=interpret)


def fused_dots_n(pairs, *, chunk=65536, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fused_dots_n(pairs, chunk=chunk, interpret=interpret)


def fused_axpy(a, x, y, *, chunk=65536, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fused_axpy(a, x, y, chunk=chunk, interpret=interpret)


def fused_axpy2(a1, x1, y1, a2, x2, y2, *, chunk=65536, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fused_axpy2(
        a1, x1, y1, a2, x2, y2, chunk=chunk, interpret=interpret
    )


def fused_axpy2_dots(a1, x1, y1, a2, x2, y2, *, chunk=65536, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fused_axpy2_dots(
        a1, x1, y1, a2, x2, y2, chunk=chunk, interpret=interpret
    )


def jacobi_stencil_sweep(
    x, b, dinv, *, stencil="7pt", aniso=(1.0, 1.0, 1.0), omega=1.0, bz=8,
    interpret=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _jacobi(
        x, b, dinv, stencil=stencil, aniso=aniso, omega=omega, bz=bz,
        interpret=interpret,
    )
