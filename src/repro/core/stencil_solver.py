"""Matrix-free distributed stencil CG (beyond-paper optimization).

The paper's benchmarks are structured 7/27-point Poisson stencils stored in
CSR; on TPU the roofline-optimal formulation drops the matrix entirely:
y = A x becomes shift-and-add on the local (nz_loc, ny, nx) grid, and the
halo exchange shrinks to ONE boundary plane per neighbor. Per SpMV this
removes ALL matrix-value and column-index HBM traffic:

    format        matrix B/row   vector B/row   total B/row   vs matfree
    ELL 7pt       7*(8+4) = 84   ~16            ~100          ~6x
    ELL 27pt      27*(8+4)= 324  ~16            ~340          ~21x
    matrix-free   0              ~16            ~16           1x

(f32 halves the matrix-free number again.) The single-node kernel-level
version of this operator is kernels/spmv_stencil.py (Pallas, VMEM-tiled);
this module is the shard_map-distributed form used by the production-mesh
dry-run and solvers.

The local slab SpMV dispatches through ``kernels/dispatch.py``: on TPU the
VMEM-tiled ``stencil_spmv_halo`` Pallas kernel runs the whole local
operator in one call (halo planes received via ``ppermute`` feed the
kernel's prev/next boundary inputs); on CPU the jnp reference executes the
identical math, and tests force ``kernels='interpret'`` to validate the
kernel code path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.cg import (
    SolveResult,
    _BODIES,
    identity_precond,
)
from repro.energy import trace
from repro.energy.accounting import OpCounts
from repro.kernels import dispatch as kd


def make_matvec(p, n_shards: int, axis: str = "shards",
                kernels: str | None = None, overlap: bool = True):
    """Per-shard matrix-free stencil operator (inside shard_map).

    v is the local flattened slab (nz_loc * ny * nx,). Requires a uniform
    slab partition (p.nz % n_shards == 0). ``kernels`` selects the SpMV
    backend (None = auto; see kernels/dispatch.py).

    ``overlap=True`` (and nz_loc >= 2, n_shards > 1): communication-hiding
    schedule — the boundary-plane ppermutes are issued first, the full slab
    is computed with zero halos while they fly (every interior output plane
    is already final), and the two slab-edge planes are patched with the
    fused boundary kernel on arrival; all attributed to the ``"overlap"``
    energy region. Otherwise: serialized exchange-then-multiply (regions
    ``"halo"`` + caller's ``"spmv"``). The split kernels are bitwise equal
    to the single-call planes per backend; end-to-end under jit the two
    schedules agree to XLA elementwise-fusion reassociation (~1 ulp).
    """
    assert p.nz % n_shards == 0, "matrix-free path needs uniform slabs"
    nz_loc = p.nz // n_shards
    ops = kd.ops_for(kernels)
    split = overlap and n_shards > 1 and nz_loc >= 2

    fwd = tuple((j, j + 1) for j in range(n_shards - 1))
    bwd = tuple((j, j - 1) for j in range(1, n_shards))

    def _exchange(x3):
        # one boundary plane to each neighbor (trace-time counts)
        trace.record_op(
            "halo_exchange",
            OpCounts(
                ici_bytes=2.0 * p.ny * p.nx * x3.dtype.itemsize,
                n_collectives=2.0,
            ),
        )
        prev = lax.ppermute(x3[-1], axis, fwd)  # from left neighbor
        nxt = lax.ppermute(x3[0], axis, bwd)  # from right neighbor
        return prev, nxt

    def A(v: jax.Array) -> jax.Array:
        x3 = v.reshape(nz_loc, p.ny, p.nx)
        if split:
            with trace.region(trace.OVERLAP):
                prev, nxt = _exchange(x3)
                zero = jnp.zeros_like(x3[0])
                # full slab with zero halos: interior planes final, no
                # dependence on the in-flight exchange
                y = ops.stencil_matvec(
                    x3, zero, zero, stencil=p.stencil, aniso=tuple(p.aniso)
                )
                # on arrival: patch the two slab-edge planes
                yb = ops.stencil_boundary(
                    x3, prev, nxt, stencil=p.stencil, aniso=tuple(p.aniso)
                )
                y = y.at[0].set(yb[0]).at[nz_loc - 1].set(yb[1])
            return y.reshape(-1)
        if n_shards > 1:
            with trace.region("halo"):
                prev, nxt = _exchange(x3)
        else:
            prev = jnp.zeros_like(x3[0])
            nxt = jnp.zeros_like(x3[0])
        y = ops.stencil_matvec(
            x3, prev, nxt, stencil=p.stencil, aniso=tuple(p.aniso)
        )
        return y.reshape(-1)

    return A


def make_stencil_solver_fn(
    mesh,
    p,
    n_shards: int,
    *,
    variant: str = "hs",
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis: str = "shards",
    kernels: str | None = None,
    overlap: bool = True,
):
    """Jitted matrix-free distributed CG: (b, x0) -> SolveResult.

    b/x0: (n_shards, R) with R = (nz/n_shards) * ny * nx. Accepts
    ShapeDtypeStructs (dry-run) or real arrays (execution). ``kernels``
    selects the hot-path backend for both the slab SpMV and the fused
    vector ops (None = auto); ``overlap`` the communication-hiding schedule
    (see :func:`make_matvec` and ``core/cg.make_solver``).
    """
    from jax.experimental.shard_map import shard_map

    pre = identity_precond()
    body = _BODIES[variant]
    kw = dict(tol=tol, maxiter=maxiter, axis=axis)
    if variant == "sstep":
        kw["s"] = s
    else:
        kw["ops"] = kd.ops_for(kernels)
    if variant == "pipecg":
        kw["overlap"] = overlap
    A = make_matvec(p, n_shards, axis, kernels=kernels, overlap=overlap)

    def fn(b, x0):
        x, iters, rr, bb = body(A, pre, (), b[0], x0[0], **kw)
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P("shards", None), P("shards", None)),
        out_specs=(P("shards", None), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(b, x0):
        x, iters, rr, bb = mapped(b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve
