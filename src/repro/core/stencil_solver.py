"""Matrix-free distributed stencil CG (beyond-paper optimization, §Perf).

The paper's benchmarks are structured 7/27-point Poisson stencils stored in
CSR; on TPU the roofline-optimal formulation drops the matrix entirely:
y = A x becomes shift-and-add on the local (nz_loc, ny, nx) grid, and the
halo exchange shrinks to ONE boundary plane per neighbor. Per SpMV this
removes ALL matrix-value and column-index HBM traffic:

    ELL 7pt:  7*(8+4) B/row matrix traffic + 12 B/row vector r/w  = 96 B/row
    matfree:  ~16 B/row (read x once + write y once, f64)          ~6x less

(27pt: 27*(8+4)+12 = 336 B/row vs the same ~16 B/row: ~21x.) The same idea
with f32 halves it again. The single-node kernel-level version of this
operator is kernels/spmv_stencil.py (Pallas, VMEM-tiled); this module is the
shard_map-distributed form used by the production-mesh dry-run and solvers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.cg import (
    SolveResult,
    _BODIES,
    identity_precond,
)


def _shift_yx(x, dy, dx):
    """Zero-fill shift along (y, x) of a (nz, ny, nx) block."""
    nz, ny, nx = x.shape
    out = x
    if dy:
        pad = ((0, 0), (dy, 0), (0, 0)) if dy > 0 else ((0, 0), (0, -dy), (0, 0))
        out = jnp.pad(out, pad)
        out = out[:, :ny, :] if dy > 0 else out[:, -dy : ny - dy, :]
    if dx:
        pad = ((0, 0), (0, 0), (dx, 0)) if dx > 0 else ((0, 0), (0, 0), (0, -dx))
        out = jnp.pad(out, pad)
        out = out[:, :, :nx] if dx > 0 else out[:, :, -dx : nx - dx]
    return out


def make_matvec(p, n_shards: int, axis: str = "shards"):
    """Per-shard matrix-free stencil operator (inside shard_map).

    v is the local flattened slab (nz_loc * ny * nx,). Requires a uniform
    slab partition (p.nz % n_shards == 0).
    """
    assert p.nz % n_shards == 0, "matrix-free path needs uniform slabs"
    nz_loc = p.nz // n_shards

    fwd = tuple((j, j + 1) for j in range(n_shards - 1))
    bwd = tuple((j, j - 1) for j in range(1, n_shards))

    def A(v: jax.Array) -> jax.Array:
        x3 = v.reshape(nz_loc, p.ny, p.nx)
        if n_shards > 1:
            prev = lax.ppermute(x3[-1], axis, fwd)  # from left neighbor
            nxt = lax.ppermute(x3[0], axis, bwd)  # from right neighbor
        else:
            prev = jnp.zeros_like(x3[0])
            nxt = jnp.zeros_like(x3[0])
        ext = jnp.concatenate([prev[None], x3, nxt[None]], axis=0)
        c = ext[1:-1]
        zm, zp = ext[:-2], ext[2:]
        if p.stencil == "7pt":
            ax, ay, az = p.aniso
            y = 2.0 * (ax + ay + az) * c
            y = y - ax * (_shift_yx(c, 0, 1) + _shift_yx(c, 0, -1))
            y = y - ay * (_shift_yx(c, 1, 0) + _shift_yx(c, -1, 0))
            y = y - az * (zm + zp)
        else:  # 27pt
            s9 = jnp.zeros_like(ext)
            for dy in (-1, 0, 1):
                for dx in (-1, 0, 1):
                    s9 = s9 + _shift_yx(ext, dy, dx)
            y = 27.0 * c - (s9[:-2] + s9[1:-1] + s9[2:])
        return y.reshape(-1)

    return A


def make_stencil_solver_fn(
    mesh,
    p,
    n_shards: int,
    *,
    variant: str = "hs",
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis: str = "shards",
):
    """Jitted matrix-free distributed CG: (b, x0) -> SolveResult.

    b/x0: (n_shards, R) with R = (nz/n_shards) * ny * nx. Accepts
    ShapeDtypeStructs (dry-run) or real arrays (execution).
    """
    from jax.experimental.shard_map import shard_map

    pre = identity_precond()
    body = _BODIES[variant]
    kw = dict(tol=tol, maxiter=maxiter, axis=axis)
    if variant == "sstep":
        kw["s"] = s
    A = make_matvec(p, n_shards, axis)

    def fn(b, x0):
        x, iters, rr, bb = body(A, pre, (), b[0], x0[0], **kw)
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P("shards", None), P("shards", None)),
        out_specs=(P("shards", None), P(), P(), P()),
    )

    @jax.jit
    def solve(b, x0):
        x, iters, rr, bb = mapped(b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve
