"""Device-side distributed SpMV (shard_map interior) + halo exchange.

The functions in this module run *inside* ``shard_map`` over a 1-D ``shards``
mesh axis: every argument is the local block (leading shard axis already
squeezed), collectives are explicit (``lax.ppermute`` / ``lax.all_gather`` /
``lax.psum``).

Key design point reproduced from the paper: each shard's rows are split at
partition time into an **interior block** (entries with locally-owned
columns) and a compact **boundary block** (the ghost-touching rows' external
entries only — see ``DistMat``). ``spmv_shard`` issues the halo ``ppermute``
first, multiplies the interior block while the exchange is in flight, and
scatter-adds the boundary block on arrival — the JAX analog of overlapping
CUDA kernels with MPI progress. The whole overlapped phase is attributed to
the ``"overlap"`` energy region (energy/trace.py), whose modeled time is
``max(compute, memory, collective)`` — i.e. halo communication hidden behind
the interior matvec; ``overlap=False`` restores the serialized
gather-then-multiply order (regions ``"spmv"`` + ``"halo"``, communication
fully exposed).
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.partition import (
    BCSRBlock,
    DistMat,
    ELLBlock,
    HaloPlan,
    HYBBlock,
    InteriorBlock,
)
from repro.energy import trace
from repro.energy.accounting import OpCounts


# ---------------------------------------------------------------------------
# Interior matvec primitives (local, per storage format)
# ---------------------------------------------------------------------------


def _nrhs(x: jax.Array) -> int:
    """RHS count of a vector (n,) or column block (n, r) operand."""
    return 1 if x.ndim == 1 else x.shape[1]


def ell_matvec(data: jax.Array, col: jax.Array, x: jax.Array) -> jax.Array:
    """y[r] = sum_k data[r,k] * x[col[r,k]].  Padding (data=0,col=0) is free.

    ``x`` may be an (n, r) column block: the SpMM form reuses the gathered
    ``x[col]`` tiles against the SAME streamed matrix pass, so matrix bytes
    are paid once while vector bytes scale with ``r`` — recorded as such.
    """
    # Executed-counts entry (trace-time only): matrix values + 4B indices
    # streamed once, source vector(s) read once, result(s) written once.
    b = data.dtype.itemsize
    r = _nrhs(x)
    mat_bytes = float(data.size * (b + col.dtype.itemsize))
    trace.record_op(
        "ell_matvec" if r == 1 else "ell_spmm",
        OpCounts(
            flops=2.0 * data.size * r,
            hbm_bytes=mat_bytes
            + float(x.shape[0] + data.shape[0]) * r * b,
            hbm_matrix_bytes=mat_bytes,
        ),
    )
    if x.ndim == 2:
        return jnp.einsum("rk,rkc->rc", data, x[col])
    return jnp.einsum("rk,rk->r", data, x[col])


def hyb_matvec(block: HYBBlock, x: jax.Array) -> jax.Array:
    """HYB interior matvec: ELL-prefix einsum + COO-tail scatter-add.

    ``block`` is the *local* (shard-axis-squeezed) HYBBlock. Tail padding
    (data 0, col 0, row 0) scatter-adds exact zeros. Accounted with the
    bytes this layout actually moves: ``k_typ`` slots/row with one 4 B
    index each, plus value + (col, row) index pairs for the tail — the
    stored-bytes saving vs ELL shows up directly in the SpMV region of the
    executed-energy ledger.
    """
    data, col = block.data, block.col
    b = data.dtype.itemsize
    r = _nrhs(x)
    mat_bytes = float(
        data.size * (b + col.dtype.itemsize)
        + block.tail_data.size * (b + 2 * block.tail_col.dtype.itemsize)
    )
    trace.record_op(
        "hyb_matvec" if r == 1 else "hyb_spmm",
        OpCounts(
            flops=2.0 * (data.size + block.tail_data.size) * r,
            hbm_bytes=mat_bytes
            + float(x.shape[0] + data.shape[0]) * r * b,
            hbm_matrix_bytes=mat_bytes,
        ),
    )
    if x.ndim == 2:
        y = jnp.einsum("rk,rkc->rc", data, x[col])
        tail = block.tail_data[:, None] * x[block.tail_col]
    else:
        y = jnp.einsum("rk,rk->r", data, x[col])
        tail = block.tail_data * x[block.tail_col]
    return y.at[block.tail_row].add(tail)


def interior_matvec(interior: InteriorBlock, x_own: jax.Array) -> jax.Array:
    """y_own = A_interior @ x_own for the local (squeezed) interior block.

    Dispatches on the storage format: ELL/HYB run their dense-gather jnp
    forms here; BCSR routes through the kernel-dispatch op ``bcsr_spmv``
    (kernels/dispatch.py) so the Pallas block kernel runs inside shard_map
    on the pallas/interpret backends. All formats return the same (R,)
    vector within fp tolerance.
    """
    if isinstance(interior, ELLBlock):
        return ell_matvec(interior.data, interior.col, x_own)
    if isinstance(interior, HYBBlock):
        return hyb_matvec(interior, x_own)
    if isinstance(interior, BCSRBlock):
        from repro.kernels import dispatch as kd

        op = kd.ops_for(None)
        fn = op.bcsr_spmm if x_own.ndim == 2 else op.bcsr_spmv
        return fn(
            interior.blocks,
            interior.bcol,
            x_own,
            n_brows=interior.n_brows,
            bpr=interior.bpr,
            n_out=x_own.shape[0],
        )
    raise TypeError(f"unknown interior block type {type(interior).__name__}")


def boundary_matvec(
    data_bnd: jax.Array,
    col_bnd: jax.Array,
    x_ext: jax.Array,
    *,
    src_elems: int | None = None,
) -> jax.Array:
    """Compact boundary-block matvec: ``yb[j] = sum_k data[j,k]*x_ext[col[j,k]]``.

    ``data_bnd/col_bnd`` are the (B, k_ext) ghost-entry rows of the shard
    (``DistMat.data_ext``); the caller scatter-adds ``yb`` into the interior
    result at ``bnd_rows``. Padded slots carry zero data, so their adds are
    exact zeros.

    ``src_elems`` is the number of distinct gatherable source elements the
    block can touch (units: elements of ``x_ext``) — the halo length for the
    ring layouts, where ``col_bnd`` indexes only the received buffers. The
    default bounds it by the entry count: a (B, k_ext) gather reads at most
    ``B*k_ext`` elements, NOT the whole ``x_ext`` stream — charging the full
    gathered vector would inflate the boundary block's memory time (and with
    it the comm-hiding credit of the overlap region).
    """
    b = data_bnd.dtype.itemsize
    B = data_bnd.shape[0]
    r = _nrhs(x_ext)
    if src_elems is None:
        src_elems = min(x_ext.shape[0], data_bnd.size)
    # entries + 4B indices streamed once, the touched source elements read
    # once, and the scatter-add's read-modify-write of the B result rows.
    mat_bytes = float(data_bnd.size * (b + col_bnd.dtype.itemsize))
    trace.record_op(
        "bnd_matvec" if r == 1 else "bnd_spmm",
        OpCounts(
            flops=2.0 * data_bnd.size * r,
            hbm_bytes=mat_bytes
            + float(
                min(int(src_elems), data_bnd.size) * r * b
                + B * (2 * b * r + 4)
            ),
            hbm_matrix_bytes=mat_bytes,
        ),
    )
    if x_ext.ndim == 2:
        return jnp.einsum("bk,bkc->bc", data_bnd, x_ext[col_bnd])
    return jnp.einsum("bk,bk->b", data_bnd, x_ext[col_bnd])


# ---------------------------------------------------------------------------
# Halo exchange
# ---------------------------------------------------------------------------


def _halo_exchange(
    x_own: jax.Array, send_sel: jax.Array, plan: HaloPlan, axis
) -> jax.Array:
    """Ring/grid halo exchange body (records counts in the *caller's* region).

    For an (R, r) column block the exchanged rows are r-wide, so the ICI
    payload scales with the RHS count (same number of ppermute launches).

    With a :class:`~repro.core.partition.GridPlan` (``axis`` is the
    ``(rows, cols)`` tuple of mesh axis names) each shift runs as
    per-dimension sub-axis ppermutes: the column hop first, then the row
    hop forwards the received buffer — a corner shift therefore launches
    two collectives and its payload crosses two links, which is exactly
    how ``GridPlan.collective_bytes_per_shard``/``n_launches`` price it.
    """
    grid = getattr(plan, "mode", None) == "grid"
    row_bytes = x_own.dtype.itemsize * _nrhs(x_own)
    trace.record_op(
        "halo_exchange",
        OpCounts(
            ici_bytes=float(plan.collective_bytes_per_shard(row_bytes)),
            n_collectives=float(
                plan.n_launches if grid else len(plan.shifts)
            ),
        ),
    )
    bufs = []
    off = 0
    for k, w in enumerate(plan.widths):
        sel = lax.slice_in_dim(send_sel, off, off + w)
        buf = x_own[sel]
        if grid:
            di, dj = plan.shifts[k]
            if dj:
                buf = lax.ppermute(buf, axis[1], plan.perm_cols(k))
            if di:
                buf = lax.ppermute(buf, axis[0], plan.perm_rows(k))
            bufs.append(buf)
        else:
            bufs.append(lax.ppermute(buf, axis, plan.perm(k)))
        off += w
    if not bufs:
        return jnp.zeros((0,) + x_own.shape[1:], x_own.dtype)
    return jnp.concatenate(bufs)


def halo_exchange(
    x_own: jax.Array, send_sel: jax.Array, plan: HaloPlan, axis
) -> jax.Array:
    """Ring/grid halo exchange: returns the concatenated receive buffers.

    ``send_sel`` is the local (W,) selector row; buffer k is sent to shard
    ``j - shifts[k]`` and received from ``j + shifts[k]`` (zeros at edges).
    Attributed to the ``"halo"`` energy region (the serialized path); the
    overlapped SpMV calls :func:`_halo_exchange` directly so the exchange
    lands in its ``"overlap"`` region instead.
    """
    with trace.region("halo"):
        return _halo_exchange(x_own, send_sel, plan, axis)


def gather_ext(mat: DistMat, x_own: jax.Array, axis) -> jax.Array:
    """Produce the external-vector buffer ``x_ext`` for this shard's rows."""
    if mat.plan.mode in ("ring", "grid"):
        halo = halo_exchange(x_own, mat.send_sel, mat.plan, axis)
        return jnp.concatenate([x_own, halo])
    # allgather mode: padded-global layout owner*R + local — exactly the
    # tiled all_gather of the padded shard vectors.
    with trace.region("halo"):
        trace.record_op(
            "allgather",
            OpCounts(
                ici_bytes=float(
                    mat.plan.collective_bytes_per_shard(
                        x_own.dtype.itemsize * _nrhs(x_own)
                    )
                ),
                n_collectives=1.0,
            ),
        )
        return lax.all_gather(x_own, axis, tiled=True)


# ---------------------------------------------------------------------------
# Distributed SpMV
# ---------------------------------------------------------------------------


# Trace-time default for spmv_shard's overlap flag. Solver factories set it
# for the whole body trace (``with overlap_default(flag)``), so call sites
# that don't thread the flag explicitly — the AMG V-cycle's level SpMVs,
# the Jacobi smoother residuals — follow the solver's schedule instead of
# silently staying overlapped under ``--no-overlap``.
_OVERLAP_DEFAULT = True


@contextlib.contextmanager
def overlap_default(on: bool):
    """Scoped default for :func:`spmv_shard`'s ``overlap`` (trace time)."""
    global _OVERLAP_DEFAULT
    prev = _OVERLAP_DEFAULT
    _OVERLAP_DEFAULT = bool(on)
    try:
        yield
    finally:
        _OVERLAP_DEFAULT = prev


def spmv_shard(
    mat: DistMat, x_own: jax.Array, axis: str, *, overlap: bool | None = None
) -> jax.Array:
    """y_own = (A @ x)_own via the interior/boundary row-block split.

    ``mat`` is the *local* DistMat block (leading shard axis squeezed; see
    ``local_block``); ``x_own`` the local (R,) vector shard or an (R, r)
    multi-RHS column block (the SpMM sweep: same schedule, matrix streamed
    once, vector traffic and halo payload scaled by ``r``). ``overlap=None``
    resolves the scoped :func:`overlap_default` (True unless a solver set
    otherwise).

    ``overlap=True`` (ring layouts with a real exchange): the halo
    ``ppermute`` is issued first, the interior block — every locally-indexed
    entry — is multiplied while the exchange is in flight, and the compact
    boundary block is scatter-added on arrival. The whole phase lands in the
    ``"overlap"`` energy region, modeled with the communication hidden
    behind the interior matvec. ``overlap=False`` (and the allgather /
    single-shard layouts): the serialized order — gather ``x_ext`` fully
    (region ``"halo"``), then multiply both blocks.

    Both orders compute bitwise-identical results; only the schedule and the
    energy-region attribution differ.
    """
    if overlap is None:
        overlap = _OVERLAP_DEFAULT
    ring = mat.plan.mode in ("ring", "grid") and len(mat.plan.shifts) > 0
    if overlap and ring:
        with trace.region(trace.OVERLAP):
            halo = _halo_exchange(x_own, mat.send_sel, mat.plan, axis)
            y = interior_matvec(mat.interior, x_own)
            x_ext = jnp.concatenate([x_own, halo])
            yb = boundary_matvec(
                mat.data_ext, mat.col_ext, x_ext, src_elems=halo.shape[0]
            )
            return y.at[mat.bnd_rows].add(yb)
    x_ext = gather_ext(mat, x_own, axis)
    y = interior_matvec(mat.interior, x_own)
    # ring: the boundary gathers touch only the received halo buffers
    src = x_ext.shape[0] - x_own.shape[0] if ring else None
    yb = boundary_matvec(mat.data_ext, mat.col_ext, x_ext, src_elems=src)
    return y.at[mat.bnd_rows].add(yb)


# ---------------------------------------------------------------------------
# Matrix-powers SpMV (communication-avoiding s-step interiors)
# ---------------------------------------------------------------------------


def ghost_matvec(
    ghost_data: jax.Array, ghost_col: jax.Array, x_ext: jax.Array
) -> jax.Array:
    """Redundant ghost-row matvec: ``yg[j] = sum_k data[j,k]*x_ext[col[j,k]]``.

    The deep-halo replicated rows (``DistMat.ghost_data``) recompute the
    halo region between chained applications instead of re-exchanging —
    the matrix-powers redundancy. Recorded under its own op name so the
    executed ledger prices the redundant flops/bytes honestly rather than
    folding them into the interior matvec.
    """
    b = ghost_data.dtype.itemsize
    G = ghost_data.shape[0]
    mat_bytes = float(ghost_data.size * (b + ghost_col.dtype.itemsize))
    trace.record_op(
        "ghost_matvec",
        OpCounts(
            flops=2.0 * ghost_data.size,
            hbm_bytes=mat_bytes
            + float(
                min(x_ext.shape[0], ghost_data.size) * b + G * (b + 4)
            ),
            hbm_matrix_bytes=mat_bytes,
        ),
    )
    return jnp.einsum("gk,gk->g", ghost_data, x_ext[ghost_col])


def matrix_powers(
    mat: DistMat, p: jax.Array, s: int, axis, *, overlap: bool | None = None
) -> jax.Array:
    """[A p, A² p, …, Aˢ p] (own rows, stacked (s, R)) from ONE exchange.

    The communication-avoiding kernel of the s-step CG body: a single
    *widened* halo exchange (``halo_depth >= s`` partition) delivers the
    depth-s transitive closure of the boundary coupling, after which the
    whole monomial block chains locally — each application multiplies the
    interior + boundary blocks for the own rows AND redundantly recomputes
    every replicated ghost row (depth < s), scattering the results back
    into the halo slots so the next application reads refreshed ghosts.
    Validity is inductive: application ``j`` is exact on own rows and on
    ghosts of depth ``<= s - j``; deeper slots decay to garbage that the
    valid region never reads (they are zero-filled, staying finite).

    One ppermute round and 1/s of the launch latency per SpMV, at the
    price of the ghost-row redundancy — both sides of the trade recorded
    honestly (``halo_exchange`` once, ``ghost_matvec`` per application).
    ``overlap=True`` wraps the whole block in a single ``overlap`` region:
    the one exchange hides behind s interior matvecs' compute.
    """
    if mat.plan.mode not in ("ring", "grid"):
        raise ValueError(
            "matrix_powers needs a ring/grid halo plan (allgather layouts "
            "re-gather the full vector every application)"
        )
    has_halo = len(mat.plan.shifts) > 0
    if has_halo and mat.halo_depth < s:
        raise ValueError(
            f"matrix_powers with s={s} needs a halo_depth >= {s} partition "
            f"(got halo_depth={mat.halo_depth}); rebuild with "
            f"partition_csr(..., halo_depth=s)"
        )
    if overlap is None:
        overlap = _OVERLAP_DEFAULT

    R = p.shape[0]

    def _chain(x_ext: jax.Array) -> jax.Array:
        halo_len = x_ext.shape[0] - R
        outs = []
        for j in range(s):
            x_own = x_ext[:R]
            y = interior_matvec(mat.interior, x_own)
            yb = boundary_matvec(
                mat.data_ext, mat.col_ext, x_ext, src_elems=halo_len or None
            )
            y_own = y.at[mat.bnd_rows].add(yb)
            outs.append(y_own)
            if j + 1 == s:
                break  # the last application's ghosts are never read
            if mat.ghost_data is not None and mat.ghost_data.size:
                yg = ghost_matvec(mat.ghost_data, mat.ghost_col, x_ext)
                halo_next = (
                    jnp.zeros((halo_len,), x_ext.dtype)
                    .at[mat.ghost_pos - R]
                    .set(yg, mode="drop")
                )
            else:
                halo_next = jnp.zeros((halo_len,), x_ext.dtype)
            x_ext = jnp.concatenate([y_own, halo_next])
        return jnp.stack(outs)

    if overlap and has_halo:
        with trace.region(trace.OVERLAP):
            halo = _halo_exchange(p, mat.send_sel, mat.plan, axis)
            return _chain(jnp.concatenate([p, halo]))
    return _chain(gather_ext(mat, p, axis))


# ---------------------------------------------------------------------------
# shard_map plumbing
# ---------------------------------------------------------------------------


def local_block(mat: DistMat) -> DistMat:
    """Squeeze the leading shard axis from every data leaf (inside shard_map)."""
    return jax.tree.map(lambda a: a[0] if a.ndim > 0 else a, mat)


def dist_specs(mat: DistMat, axis="shards"):
    """PartitionSpec pytree for a DistMat sharded over the shard axis.

    ``axis`` may be a single mesh axis name or a tuple of names (2-D
    grid meshes shard the flat leading dimension over both axes,
    row-major — flat shard ``s = i * C + j``).
    """
    return jax.tree.map(
        lambda a: P(axis, *([None] * (a.ndim - 1))), mat
    )


def vec_spec(axis="shards"):
    return P(axis)


def matrix_axis(mat: DistMat):
    """Mesh axis (name or tuple of names) this DistMat's plan shards over."""
    if getattr(mat.plan, "mode", None) == "grid":
        return tuple(mat.plan.axes)
    return "shards"


def shard_vector(mesh, xp, axis="shards") -> jax.Array:
    """(S, R[, r]) padded host vector or RHS block -> device array sharded
    over the shard axis (all trailing axes replicated)."""
    xp = jnp.asarray(xp)
    sh = jax.sharding.NamedSharding(
        mesh, P(axis, *([None] * (xp.ndim - 1)))
    )
    return jax.device_put(xp, sh)


def shard_matrix(mesh, mat: DistMat, axis=None) -> DistMat:
    if axis is None:
        axis = matrix_axis(mat)
    specs = dist_specs(mat, axis)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, jax.sharding.NamedSharding(mesh, s)),
        mat,
        specs,
    )


def make_spmv(mesh, mat: DistMat, axis="shards", *, overlap: bool = True):
    """Jitted end-to-end distributed SpMV: (S,R) -> (S,R) sharded arrays.

    ``overlap`` selects the communication-hiding schedule (see
    :func:`spmv_shard`). ``axis`` is the mesh axis name — or the
    ``(rows, cols)`` tuple for 2-D grid meshes.
    """
    from jax.experimental.shard_map import shard_map

    specs = dist_specs(mat, axis)

    def fn(m, x):
        mb = local_block(m)
        y = spmv_shard(mb, x[0], axis, overlap=overlap)
        return y[None]

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(specs, P(axis, None)),
        out_specs=P(axis, None),
        check_rep=False,  # jax 0.4.37: no replication rule for pallas_call
    )
    return jax.jit(mapped)
