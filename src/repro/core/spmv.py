"""Device-side distributed SpMV (shard_map interior) + halo exchange.

The functions in this module run *inside* ``shard_map`` over a 1-D ``shards``
mesh axis: every argument is the local block (leading shard axis already
squeezed), collectives are explicit (``lax.ppermute`` / ``lax.all_gather`` /
``lax.psum``).

Key design point reproduced from the paper: the sparse rows are split into a
local part (no communication needed) and an external part (needs the halo), so
the local SpMV is *issued before* the halo arrives and XLA's latency-hiding
scheduler overlaps the ``ppermute`` with the local gather/multiply — the JAX
analog of overlapping CUDA kernels with MPI progress.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.partition import DistELL, HaloPlan
from repro.energy import trace
from repro.energy.accounting import OpCounts


# ---------------------------------------------------------------------------
# ELL matvec primitive (local, dense-gather form; TPU kernels in kernels/)
# ---------------------------------------------------------------------------


def ell_matvec(data: jax.Array, col: jax.Array, x: jax.Array) -> jax.Array:
    """y[r] = sum_k data[r,k] * x[col[r,k]].  Padding (data=0,col=0) is free."""
    # Executed-counts entry (trace-time only): matrix values + 4B indices
    # streamed once, source vector read once, result written once.
    b = data.dtype.itemsize
    trace.record_op(
        "ell_matvec",
        OpCounts(
            flops=2.0 * data.size,
            hbm_bytes=float(
                data.size * (b + col.dtype.itemsize)
                + x.size * b
                + data.shape[0] * b
            ),
        ),
    )
    return jnp.einsum("rk,rk->r", data, x[col])


# ---------------------------------------------------------------------------
# Halo exchange
# ---------------------------------------------------------------------------


def halo_exchange(
    x_own: jax.Array, send_sel: jax.Array, plan: HaloPlan, axis: str
) -> jax.Array:
    """Ring halo exchange: returns the concatenated receive buffers.

    ``send_sel`` is the local (W,) selector row; buffer k is sent to shard
    ``j - shifts[k]`` and received from ``j + shifts[k]`` (zeros at edges).
    """
    with trace.region("halo"):
        b = x_own.dtype.itemsize
        trace.record_op(
            "halo_exchange",
            OpCounts(
                ici_bytes=float(plan.collective_bytes_per_shard(b)),
                n_collectives=float(len(plan.shifts)),
            ),
        )
        bufs = []
        off = 0
        for k, w in enumerate(plan.widths):
            sel = lax.slice_in_dim(send_sel, off, off + w)
            buf = x_own[sel]
            bufs.append(lax.ppermute(buf, axis, plan.perm(k)))
            off += w
        if not bufs:
            return jnp.zeros((0,), x_own.dtype)
        return jnp.concatenate(bufs)


def gather_ext(mat: DistELL, x_own: jax.Array, axis: str) -> jax.Array:
    """Produce the external-vector buffer ``x_ext`` for this shard's rows."""
    if mat.plan.mode == "ring":
        halo = halo_exchange(x_own, mat.send_sel, mat.plan, axis)
        return jnp.concatenate([x_own, halo])
    # allgather mode: padded-global layout owner*R + local — exactly the
    # tiled all_gather of the padded shard vectors.
    with trace.region("halo"):
        trace.record_op(
            "allgather",
            OpCounts(
                ici_bytes=float(
                    mat.plan.collective_bytes_per_shard(x_own.dtype.itemsize)
                ),
                n_collectives=1.0,
            ),
        )
        return lax.all_gather(x_own, axis, tiled=True)


# ---------------------------------------------------------------------------
# Distributed SpMV
# ---------------------------------------------------------------------------


def spmv_shard(mat: DistELL, x_own: jax.Array, axis: str) -> jax.Array:
    """y_own = (A @ x)_own, overlap-friendly ordering (per-shard view).

    ``mat`` here is the *local* DistELL block (leading shard axis squeezed;
    see ``local_block``).
    """
    # Communication is issued first so XLA can overlap it with the local part.
    x_ext = gather_ext(mat, x_own, axis)
    y = ell_matvec(mat.data_loc, mat.col_loc, x_own)
    y = y + ell_matvec(mat.data_ext, mat.col_ext, x_ext)
    return y


# ---------------------------------------------------------------------------
# shard_map plumbing
# ---------------------------------------------------------------------------


def local_block(mat: DistELL) -> DistELL:
    """Squeeze the leading shard axis from every data leaf (inside shard_map)."""
    return jax.tree.map(lambda a: a[0] if a.ndim > 0 else a, mat)


def dist_specs(mat: DistELL):
    """PartitionSpec pytree for a DistELL sharded over the ``shards`` axis."""
    return jax.tree.map(
        lambda a: P("shards", *([None] * (a.ndim - 1))), mat
    )


def vec_spec():
    return P("shards")


def shard_vector(mesh, xp) -> jax.Array:
    """(S, R) padded host vector -> device array sharded over shards axis."""
    sh = jax.sharding.NamedSharding(mesh, P("shards", None))
    return jax.device_put(jnp.asarray(xp), sh)


def shard_matrix(mesh, mat: DistELL) -> DistELL:
    specs = dist_specs(mat)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, jax.sharding.NamedSharding(mesh, s)),
        mat,
        specs,
    )


def make_spmv(mesh, mat: DistELL, axis: str = "shards"):
    """Jitted end-to-end distributed SpMV: (S,R) -> (S,R) sharded arrays."""
    from jax.experimental.shard_map import shard_map

    specs = dist_specs(mat)

    def fn(m, x):
        mb = local_block(m)
        y = spmv_shard(mb, x[0], axis)
        return y[None]

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(specs, P("shards", None)),
        out_specs=P("shards", None),
    )
    return jax.jit(mapped)
