"""Distributed Conjugate Gradient solvers (the paper's C2).

Three variants, mirroring BootCMatchGX:

* ``hs``    — the classical Hestenes–Stiefel PCG [23]. Two all-reduces per
  iteration in our implementation (the (p, Ap) dot, and a *fused* reduce of
  (r, z) + ||r||^2 — the library-style fusion the paper credits for part of
  its efficiency).
* ``fcg``   — the communication-reduced (flexible) CG: the single-
  synchronization Chronopoulos–Gear two-term recurrence, covering the
  Notay–Napov communication-reduction idea [24]: **one** fused all-reduce per
  iteration ((r, u), (w, u), ||r||^2 packed into a single psum). Tolerates a
  variable (flexible) preconditioner.
* ``sstep`` — s-step CG after Chronopoulos–Gear [25]: a block of ``s``
  iterations advances with **one** fused all-reduce (the whole Gram matrix
  P^T A P, the cross-block coupling W_prevᵀP, the moment vector Pᵀr, and
  ||r||² packed together). Monomial basis in (M A); A-conjugation against the
  previous block is reconstructed locally from the reduced Gram blocks, so no
  second reduction is needed.

All solvers run entirely inside one ``shard_map`` region: vectors are local
(R,) shards, the matrix is a local DistELL block, and every collective is
explicit. The number of all-reduces per iteration is therefore *visible in
the lowered HLO* — which is what the roofline collective term measures.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.partition import DistELL
from repro.core.spmv import dist_specs, local_block, spmv_shard
from repro.core.vectors import fused_blocks, fused_dots, pdot
from repro.energy import trace
from repro.kernels import dispatch as kd


class Preconditioner(NamedTuple):
    """A distributed preconditioner: per-shard apply + its sharded state.

    ``apply(data_local, r_own, axis) -> z_own`` runs inside shard_map.
    ``localize(data)`` converts the global-view pytree to the per-shard view
    inside shard_map (default: squeeze the leading shard axis; replicated
    leaves — e.g. the AMG coarsest-level dense inverse — override this).
    """

    data: Any  # pytree of device arrays, leading shard axis on each leaf
    specs: Any  # matching PartitionSpec pytree
    apply: Callable[[Any, jax.Array, str], jax.Array]
    localize: Callable[[Any], Any] = None  # type: ignore[assignment]
    # True for the identity preconditioner: lets the solver bodies skip the
    # apply AND reuse the fused-kernel residual norm for (r, z) — one fewer
    # full-vector sweep per iteration.
    is_identity: bool = False


def _default_localize(data):
    return jax.tree.map(
        lambda a: a[0] if hasattr(a, "ndim") and a.ndim > 0 else a, data
    )


def identity_precond() -> Preconditioner:
    return Preconditioner(
        data=(), specs=(), apply=lambda data, r, axis: r,
        localize=lambda d: d, is_identity=True,
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("x", "iters", "rr", "bb"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class SolveResult:
    x: jax.Array  # (S, R) padded sharded solution
    iters: jax.Array  # scalar int
    rr: jax.Array  # final ||r||^2
    bb: jax.Array  # ||b||^2 (for relative residual)

    @property
    def rel_residual(self):
        return jnp.sqrt(self.rr / jnp.maximum(self.bb, 1e-300))


# ---------------------------------------------------------------------------
# Per-shard solver bodies (inside shard_map)
# ---------------------------------------------------------------------------


def _hs_body(A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, axis, ops):
    """Hestenes–Stiefel PCG; 2 all-reduces/iter (one fused).

    Hot-loop vector work runs through the kernel dispatch ``ops``: with the
    identity preconditioner each iteration is 3 full-vector HBM sweeps
    outside the SpMV (p·w dot; fused x/r update + ||r||²; p update) instead
    of the ~6 of the op-by-op formulation. A non-trivial preconditioner adds
    one sweep for the fused (r·z, r·r) reduction.

    Components are region-marked (energy/trace.py): the SpMV, the fused
    reductions/updates, and the preconditioner apply each attribute their
    executed counts to their own energy region.
    """
    with trace.region("spmv"):
        r = b - A(x0)
    with trace.region("precond"):
        z = pre.apply(pdata, r, axis)
    with trace.region("reductions"):
        d0 = fused_dots([(r, z), (r, r), (b, b)], axis)
    rz, rr, bb = d0[0], d0[1], d0[2]
    tol2 = tol * tol * bb

    def cond(c):
        i, x, r, z, p, rz, rr = c
        return (i < maxiter) & (rr > tol2)

    def body(c):
        i, x, r, z, p, rz, rr = c
        with kd.ledger_section("iteration"):
            with trace.region("spmv"):
                w = A(p)
            with trace.region("reductions"):
                pw = lax.psum(ops.fused_dots_n([(p, w)])[0], axis)  # all-reduce 1
                trace.record_collective(1, w.dtype.itemsize)
                alpha = rz / pw
                # x += alpha p ; r -= alpha w ; local r'.r' — ONE pass
                x, r, rr_loc = ops.fused_axpy2_dots(alpha, p, x, -alpha, w, r)
            if pre.is_identity:
                z = r
                with trace.region("reductions"):
                    rr = lax.psum(rr_loc[0], axis)  # all-reduce 2
                    trace.record_collective(1, w.dtype.itemsize)
                rz_new = rr
            else:
                with trace.region("precond"):
                    z = pre.apply(pdata, r, axis)
                with trace.region("reductions"):
                    rz_loc = ops.fused_dots_n([(r, z)])[0]
                    d = lax.psum(jnp.stack([rz_loc, rr_loc[0]]), axis)  # AR 2 (fused)
                    trace.record_collective(2, w.dtype.itemsize)
                rz_new, rr = d[0], d[1]
            beta = rz_new / rz
            with trace.region("reductions"):
                p = ops.axpy(beta, p, z)
        return (i + 1, x, r, z, p, rz_new, rr)

    i0 = jnp.asarray(0, jnp.int32)
    c = lax.while_loop(cond, body, (i0, x0, r, z, z, rz, rr))
    return c[1], c[0], c[6], bb


def _fcg_body(A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, axis, ops):
    """Single-synchronization (communication-reduced flexible) CG.

    Chronopoulos–Gear two-term recurrence: ONE fused all-reduce per
    iteration. Hot-loop vector work runs through the kernel dispatch
    ``ops`` in 3 full-vector HBM sweeps outside the SpMV: the fused triple
    dot (reads {r, u, w} once — u aliases r under the identity
    preconditioner), the fused p/s update, and the fused x/r update.

    Components are region-marked (energy/trace.py) exactly as in the HS
    body: spmv / reductions / precond.
    """
    with trace.region("spmv"):
        r = b - A(x0)
    with trace.region("precond"):
        u = pre.apply(pdata, r, axis)
    with trace.region("spmv"):
        w = A(u)
    with trace.region("reductions"):
        d0 = fused_dots([(r, u), (w, u), (r, r), (b, b)], axis)
    gamma, delta, rr, bb = d0[0], d0[1], d0[2], d0[3]
    tol2 = tol * tol * bb

    alpha = gamma / delta
    p, s = u, w
    x = x0 + alpha * p
    r = r - alpha * s

    def cond(c):
        i, x, r, p, s, gamma, alpha, rr = c
        return (i < maxiter) & (rr > tol2)

    def body(c):
        i, x, r, p, s, gamma, alpha, rr = c
        with kd.ledger_section("iteration"):
            if pre.is_identity:
                u = r
            else:
                with trace.region("precond"):
                    u = pre.apply(pdata, r, axis)
            with trace.region("spmv"):
                w = A(u)
            with trace.region("reductions"):
                d = lax.psum(  # the ONE all-reduce
                    ops.fused_dots_n([(r, u), (w, u), (r, r)]), axis
                )
                trace.record_collective(3, w.dtype.itemsize)
                gamma_new, delta, rr = d[0], d[1], d[2]
                beta = gamma_new / gamma
                alpha_new = gamma_new / (delta - beta * gamma_new / alpha)
                p, s = ops.fused_axpy2(beta, p, u, beta, s, w)  # p=u+βp ; s=w+βs
                x, r = ops.fused_axpy2(alpha_new, p, x, -alpha_new, s, r)
        return (i + 1, x, r, p, s, gamma_new, alpha_new, rr)

    i0 = jnp.asarray(1, jnp.int32)
    c = lax.while_loop(cond, body, (i0, x, r, p, s, gamma, alpha, rr))
    return c[1], c[0], c[7], bb


def _sstep_body(A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, s, axis):
    """s-step CG (Chronopoulos–Gear): one fused all-reduce per s iterations.

    Monomial basis P = [u, (MA)u, ..., (MA)^{s-1}u] with u = M r; the block
    is A-conjugated against the previous block using only locally
    reconstructable Gram algebra (see module docstring).
    """
    dt = b.dtype
    R = b.shape[0]
    with trace.region("spmv"):
        r = b - A(x0)
    with trace.region("reductions"):
        bb = pdot(b, b, axis)
    tol2 = tol * tol * bb
    eye = jnp.eye(s, dtype=dt)

    def build_basis(r):
        def one(carry, _):
            u = carry
            with trace.region("precond"):
                p = pre.apply(pdata, u, axis)
            with trace.region("spmv"):
                w = A(p)
            return w, (p, w)

        # the scan body traces ONCE but executes s times per block — scale
        # its recorded counts accordingly (see energy/trace.py)
        with trace.repeated(s):
            _, (Ps, Ws) = lax.scan(one, r, None, length=s)
        # (s, R) -> (R, s)
        return Ps.T, Ws.T

    def body(c):
        with kd.ledger_section("iteration"):
            return _sstep_block(c)

    def _sstep_block(c):
        i, x, r, Qp, Wp, Gqq, rr = c
        Pb, Wb = build_basis(r)
        # ONE fused all-reduce: [P^T W (s*s) | W_prev^T P (s*s) | P^T r (s) | rr]
        with trace.region("reductions"):
            flat = fused_blocks(
                [Pb.T @ Wb, Wp.T @ Pb, Pb.T @ r, jnp.vdot(r, r)[None]], axis
            )
        Gpp = flat[: s * s].reshape(s, s)
        C = flat[s * s : 2 * s * s].reshape(s, s)
        g = flat[2 * s * s : 2 * s * s + s]
        rr = flat[-1]
        # A-conjugate against previous block: B = Gqq^{-1} C (Gqq from prev).
        B = jnp.linalg.solve(Gqq + 1e-300 * eye, C)
        Q = Pb - Qp @ B
        WQ = Wb - Wp @ B
        Gq = Gpp - B.T @ C - C.T @ B + B.T @ Gqq @ B
        # Q^T r == g because r ⟂ span(previous block) in exact arithmetic.
        a = jnp.linalg.solve(Gq + 1e-300 * eye, g)
        x = x + Q @ a
        r = r - WQ @ a
        return (i + s, x, r, Q, WQ, Gq, rr)

    def cond(c):
        i, x, r, Qp, Wp, Gqq, rr = c
        return (i < maxiter) & (rr > tol2)

    i0 = jnp.asarray(0, jnp.int32)
    # mark the zero-init blocks as shard-varying for the while_loop carry
    _pvary = (
        (lambda v: lax.pcast(v, (axis,), to="varying"))
        if hasattr(lax, "pcast")
        else (lambda v: lax.pvary(v, (axis,)))
        if hasattr(lax, "pvary")
        else (lambda v: v)  # check_rep=False: no replication tracking needed
    )
    Q0 = _pvary(jnp.zeros((R, s), dt))
    c = lax.while_loop(cond, body, (i0, x0, r, Q0, Q0, eye, bb))
    return c[1], c[0], c[6], bb


_BODIES = {"hs": _hs_body, "fcg": _fcg_body, "sstep": _sstep_body}
VARIANTS = tuple(_BODIES)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def make_solver(
    mesh,
    mat: DistELL,
    *,
    variant: str = "hs",
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis: str = "shards",
    kernels: str | None = None,
):
    """Build a jitted distributed solver: (b, x0) -> SolveResult.

    ``b``/``x0`` are (S, R) padded sharded arrays (see partition.pad_vector
    + spmv.shard_vector).
    """
    from jax.experimental.shard_map import shard_map

    pre = precond or identity_precond()
    body = _BODIES[variant]
    kw = dict(tol=tol, maxiter=maxiter, axis=axis)
    if variant == "sstep":
        if kernels not in (None, "auto"):
            raise ValueError(
                "kernels= only routes the hs/fcg bodies; the sstep body "
                "does its vector work in blocked Gram algebra"
            )
        kw["s"] = s
    else:
        kw["ops"] = kd.ops_for(kernels)

    mat_specs = dist_specs(mat)

    localize = pre.localize or _default_localize

    def fn(m, pdata, b, x0):
        mb = local_block(m)
        pl = localize(pdata)
        A = lambda v: spmv_shard(mb, v, axis)
        x, iters, rr, bb = body(A, pre, pl, b[0], x0[0], **kw)
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(mat_specs, pre.specs, P("shards", None), P("shards", None)),
        out_specs=(P("shards", None), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(b, x0):
        x, iters, rr, bb = mapped(mat, pre.data, b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve


def make_solver_fn(
    mesh,
    mat_like: DistELL,
    *,
    variant: str = "hs",
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis: str = "shards",
    kernels: str | None = None,
):
    """Lowerable variant: returns jitted fn(mat, b, x0) with the matrix as a
    runtime argument — accepts ShapeDtypeStruct trees, which is what the
    production-mesh dry-run lowers (no data, no allocation).

    ``mat_like`` only supplies shapes/plan for the sharding specs.
    """
    from jax.experimental.shard_map import shard_map

    pre = precond or identity_precond()
    body = _BODIES[variant]
    kw = dict(tol=tol, maxiter=maxiter, axis=axis)
    if variant == "sstep":
        if kernels not in (None, "auto"):
            raise ValueError(
                "kernels= only routes the hs/fcg bodies; the sstep body "
                "does its vector work in blocked Gram algebra"
            )
        kw["s"] = s
    else:
        kw["ops"] = kd.ops_for(kernels)
    mat_specs = dist_specs(mat_like)
    localize = pre.localize or _default_localize

    def fn(m, pdata, b, x0):
        mb = local_block(m)
        pl = localize(pdata)
        A = lambda v: spmv_shard(mb, v, axis)
        x, iters, rr, bb = body(A, pre, pl, b[0], x0[0], **kw)
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(mat_specs, pre.specs, P("shards", None), P("shards", None)),
        out_specs=(P("shards", None), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(mat_arg, b, x0):
        x, iters, rr, bb = mapped(mat_arg, pre.data, b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve


def abstract_stencil_dist(p, n_shards: int, dtype="float64") -> DistELL:
    """ShapeDtypeStruct DistELL for a slab-partitioned stencil problem —
    production-scale dry-runs lower this without ever materializing data."""
    import numpy as np

    from repro.core.partition import HaloPlan, plane_partition

    part = plane_partition(p.n, p.plane, n_shards)
    R = part.max_own
    H = p.plane
    k = p.k
    off_dz_pos = {"7pt": 1, "27pt": 9}[p.stencil]
    k_ext = max(off_dz_pos, 1)
    shifts, widths = ((-1, 1), (H, H)) if n_shards > 1 else ((), ())
    plan = HaloPlan("ring", shifts, widths, R, n_shards)
    S = n_shards
    sds = jax.ShapeDtypeStruct
    return DistELL(
        data_loc=sds((S, R, k), dtype),
        col_loc=sds((S, R, k), "int32"),
        data_ext=sds((S, R, k_ext), dtype),
        col_ext=sds((S, R, k_ext), "int32"),
        send_sel=sds((S, max(sum(widths), 1)), "int32"),
        plan=plan,
        n_global=p.n,
        row_starts=part.row_starts,
    )


def solve_cg(mesh, mat: DistELL, b_np, *, x0_np=None, **kw) -> SolveResult:
    """Convenience host-level solve: numpy in, SolveResult out."""
    import numpy as np

    from repro.core.partition import pad_vector
    from repro.core.spmv import shard_vector

    bp = pad_vector(np.asarray(b_np), mat)
    xp = (
        pad_vector(np.asarray(x0_np), mat)
        if x0_np is not None
        else np.zeros_like(bp)
    )
    solver = make_solver(mesh, mat, **kw)
    return solver(shard_vector(mesh, bp), shard_vector(mesh, xp))
