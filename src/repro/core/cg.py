"""Distributed Conjugate Gradient solvers (the paper's C2).

Four variants — three mirroring BootCMatchGX, one beyond-paper:

* ``hs``    — the classical Hestenes–Stiefel PCG [23]. Two all-reduces per
  iteration in our implementation (the (p, Ap) dot, and a *fused* reduce of
  (r, z) + ||r||^2 — the library-style fusion the paper credits for part of
  its efficiency).
* ``fcg``   — the communication-reduced (flexible) CG: the single-
  synchronization Chronopoulos–Gear two-term recurrence, covering the
  Notay–Napov communication-reduction idea [24]: **one** fused all-reduce per
  iteration ((r, u), (w, u), ||r||^2 packed into a single psum). Tolerates a
  variable (flexible) preconditioner.
* ``sstep`` — s-step CG after Chronopoulos–Gear [25]: a block of ``s``
  iterations advances with **one** fused all-reduce (the whole Gram matrix
  P^T A P, the cross-block coupling W_prevᵀP, the moment vector Pᵀr, and
  ||r||² packed together). Monomial basis in (M A); A-conjugation against the
  previous block is reconstructed locally from the reduced Gram blocks, so no
  second reduction is needed. With the identity preconditioner and a matrix
  partitioned with ``halo_depth >= s``, the basis comes from the
  matrix-powers SpMV (``core/spmv.matrix_powers``): ONE widened halo
  exchange per block instead of s round-trips — the communication-avoiding
  formulation. The basis columns are rescaled by their A-norms
  (``diag(PᵀAP)``, already in the reduction) before the block solves, so
  the Gram conditioning stays near the conjugation's intrinsic one instead
  of growing like κ^s with the raw monomial columns; a non-finite block
  solve freezes x/r and exits the loop (loud non-convergence, not NaNs).
* ``pipecg`` — pipelined CG after Ghysels & Vanroose: like ``fcg`` it needs
  only **one** fused all-reduce per iteration, but the reduction is *issued
  before* the iteration's SpMV + preconditioner application, whose results
  it does not depend on — so the all-reduce latency (the dominant strong-
  scaling cost at high shard counts) hides behind the matvec instead of
  stalling it. Costs two extra vector recurrences (+1 fused HBM sweep/iter
  with the identity preconditioner); see ``docs/solvers.md`` for when the
  trade wins.

All solvers run entirely inside one ``shard_map`` region: vectors are local
(R,) shards, the matrix is a local DistMat block, and every collective is
explicit. The number of all-reduces per iteration is therefore *visible in
the lowered HLO* — which is what the roofline collective term measures.
"""

from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.partition import DistMat
from repro.core.spmv import (
    dist_specs,
    local_block,
    matrix_powers,
    overlap_default,
    spmv_shard,
)
from repro.core.vectors import all_reduce, fused_blocks, fused_dots, pdot
from repro.energy import trace
from repro.kernels import dispatch as kd


class Preconditioner(NamedTuple):
    """A distributed preconditioner: per-shard apply + its sharded state.

    ``apply(data_local, r_own, axis) -> z_own`` runs inside shard_map.
    ``localize(data)`` converts the global-view pytree to the per-shard view
    inside shard_map (default: squeeze the leading shard axis; replicated
    leaves — e.g. the AMG coarsest-level dense inverse — override this).
    """

    data: Any  # pytree of device arrays, leading shard axis on each leaf
    specs: Any  # matching PartitionSpec pytree
    apply: Callable[[Any, jax.Array, str], jax.Array]
    localize: Callable[[Any], Any] = None  # type: ignore[assignment]
    # True for the identity preconditioner: lets the solver bodies skip the
    # apply AND reuse the fused-kernel residual norm for (r, z) — one fewer
    # full-vector sweep per iteration.
    is_identity: bool = False


def _default_localize(data):
    return jax.tree.map(
        lambda a: a[0] if hasattr(a, "ndim") and a.ndim > 0 else a, data
    )


def _safe_div(num, den):
    """num/den, but 0 when den == 0 — guards the pre-loop step of the
    fcg/pipecg bodies against a zero initial residual (r0 = 0 makes every
    Gram scalar 0; the update must then be a no-op, not NaN)."""
    safe = jnp.where(den != 0, den, 1.0)
    return jnp.where(den != 0, num / safe, 0.0)


def identity_precond() -> Preconditioner:
    return Preconditioner(
        data=(), specs=(), apply=lambda data, r, axis: r,
        localize=lambda d: d, is_identity=True,
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("x", "iters", "rr", "bb"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class SolveResult:
    x: jax.Array  # (S, R) padded sharded solution
    iters: jax.Array  # scalar int
    rr: jax.Array  # final ||r||^2
    bb: jax.Array  # ||b||^2 (for relative residual)

    @property
    def rel_residual(self):
        return jnp.sqrt(self.rr / jnp.maximum(self.bb, 1e-300))


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("x", "iters", "iters_cols", "rr", "bb"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class BlockSolveResult:
    """Result of a multi-RHS block solve (``make_block_solver``)."""

    x: jax.Array  # (S, R, r) padded sharded solution block
    iters: jax.Array  # scalar int — iterations until the LAST column converged
    iters_cols: jax.Array  # (r,) iteration at which each column first converged
    rr: jax.Array  # (r,) final per-column ||r_j||^2
    bb: jax.Array  # (r,) per-column ||b_j||^2

    @property
    def rel_residual(self):
        """(r,) per-column relative residuals."""
        return jnp.sqrt(self.rr / jnp.maximum(self.bb, 1e-300))


# ---------------------------------------------------------------------------
# Per-shard solver bodies (inside shard_map)
# ---------------------------------------------------------------------------


def _telemetry_emit(i, relres, axis):
    """Bake the per-iteration convergence callback into the loop body.

    Called at trace time only when the solver was built with
    ``telemetry=True`` (repro.obs.convergence): the compiled program then
    reports ``(i, relres)`` to the host once per *executed* iteration.
    """
    from repro.obs import convergence

    convergence.instrument(i, relres, axis)


def _hs_body(A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, axis, ops,
             telemetry=False):
    """Hestenes–Stiefel PCG; 2 all-reduces/iter (one fused).

    Hot-loop vector work runs through the kernel dispatch ``ops``: with the
    identity preconditioner each iteration is 3 full-vector HBM sweeps
    outside the SpMV (p·w dot; fused x/r update + ||r||²; p update) instead
    of the ~6 of the op-by-op formulation. A non-trivial preconditioner adds
    one sweep for the fused (r·z, r·r) reduction.

    Components are region-marked (energy/trace.py): the SpMV, the fused
    reductions/updates, and the preconditioner apply each attribute their
    executed counts to their own energy region.
    """
    with trace.region("spmv"):
        r = b - A(x0)
    with trace.region("precond"):
        z = pre.apply(pdata, r, axis)
    with trace.region("reductions"):
        d0 = fused_dots([(r, z), (r, r), (b, b)], axis)
    rz, rr, bb = d0[0], d0[1], d0[2]
    tol2 = tol * tol * bb

    def cond(c):
        i, x, r, z, p, rz, rr = c
        return (i < maxiter) & (rr > tol2)

    def body(c):
        i, x, r, z, p, rz, rr = c
        with kd.ledger_section("iteration"):
            with trace.region("spmv"):
                w = A(p)
            with trace.region("reductions"):
                pw = all_reduce(ops.fused_dots_n([(p, w)])[0], axis)  # all-reduce 1
                trace.record_collective(1, w.dtype.itemsize)
                alpha = rz / pw
                # x += alpha p ; r -= alpha w ; local r'.r' — ONE pass
                x, r, rr_loc = ops.fused_axpy2_dots(alpha, p, x, -alpha, w, r)
            if pre.is_identity:
                z = r
                with trace.region("reductions"):
                    rr = all_reduce(rr_loc[0], axis)  # all-reduce 2
                    trace.record_collective(1, w.dtype.itemsize)
                rz_new = rr
            else:
                with trace.region("precond"):
                    z = pre.apply(pdata, r, axis)
                with trace.region("reductions"):
                    rz_loc = ops.fused_dots_n([(r, z)])[0]
                    d = all_reduce(jnp.stack([rz_loc, rr_loc[0]]), axis)  # AR 2 (fused)
                    trace.record_collective(2, w.dtype.itemsize)
                rz_new, rr = d[0], d[1]
            beta = rz_new / rz
            with trace.region("reductions"):
                p = ops.axpy(beta, p, z)
        if telemetry:
            _telemetry_emit(i + 1, jnp.sqrt(rr / jnp.maximum(bb, 1e-300)), axis)
        return (i + 1, x, r, z, p, rz_new, rr)

    i0 = jnp.asarray(0, jnp.int32)
    c = lax.while_loop(cond, body, (i0, x0, r, z, z, rz, rr))
    return c[1], c[0], c[6], bb


def _fcg_body(A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, axis, ops,
              telemetry=False):
    """Single-synchronization (communication-reduced flexible) CG.

    Chronopoulos–Gear two-term recurrence: ONE fused all-reduce per
    iteration. Hot-loop vector work runs through the kernel dispatch
    ``ops`` in 3 full-vector HBM sweeps outside the SpMV: the fused triple
    dot (reads {r, u, w} once — u aliases r under the identity
    preconditioner), the fused p/s update, and the fused x/r update.

    Components are region-marked (energy/trace.py) exactly as in the HS
    body: spmv / reductions / precond.
    """
    with trace.region("spmv"):
        r = b - A(x0)
    with trace.region("precond"):
        u = pre.apply(pdata, r, axis)
    with trace.region("spmv"):
        w = A(u)
    with trace.region("reductions"):
        d0 = fused_dots([(r, u), (w, u), (r, r), (b, b)], axis)
    gamma, delta, rr, bb = d0[0], d0[1], d0[2], d0[3]
    tol2 = tol * tol * bb

    alpha = _safe_div(gamma, delta)  # r0 == 0 -> no-op first step, not NaN
    p, s = u, w
    x = x0 + alpha * p
    r = r - alpha * s

    def cond(c):
        i, x, r, p, s, gamma, alpha, rr = c
        return (i < maxiter) & (rr > tol2)

    def body(c):
        i, x, r, p, s, gamma, alpha, rr = c
        with kd.ledger_section("iteration"):
            if pre.is_identity:
                u = r
            else:
                with trace.region("precond"):
                    u = pre.apply(pdata, r, axis)
            with trace.region("spmv"):
                w = A(u)
            with trace.region("reductions"):
                d = all_reduce(  # the ONE all-reduce
                    ops.fused_dots_n([(r, u), (w, u), (r, r)]), axis
                )
                trace.record_collective(3, w.dtype.itemsize)
                gamma_new, delta, rr = d[0], d[1], d[2]
                beta = gamma_new / gamma
                alpha_new = gamma_new / (delta - beta * gamma_new / alpha)
                p, s = ops.fused_axpy2(beta, p, u, beta, s, w)  # p=u+βp ; s=w+βs
                x, r = ops.fused_axpy2(alpha_new, p, x, -alpha_new, s, r)
        if telemetry:
            # rr here is ||r||² *before* this body's update (the fused
            # reduction reads the incoming residual) — the reported curve
            # lags the true residual by one iteration
            _telemetry_emit(i + 1, jnp.sqrt(rr / jnp.maximum(bb, 1e-300)), axis)
        return (i + 1, x, r, p, s, gamma_new, alpha_new, rr)

    i0 = jnp.asarray(1, jnp.int32)
    c = lax.while_loop(cond, body, (i0, x, r, p, s, gamma, alpha, rr))
    return c[1], c[0], c[7], bb


def _pipecg_body(
    A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, axis, ops,
    overlap=True, telemetry=False,
):
    """Ghysels–Vanroose pipelined PCG: ONE all-reduce/iter, hidden.

    The fused reduction (gamma = r·u, delta = w·u, ||r||²) is issued at the
    top of the body; the SpMV ``n = A (M w)`` that follows does not depend
    on its result, so XLA schedules the all-reduce concurrently with the
    matvec — with ``overlap=True`` both are attributed to the ``"overlap"``
    energy region (modeled hidden; energy/trace.py). The price is the extra
    z (and q, under a real preconditioner) recurrences: 4 full-vector HBM
    sweeps per iteration outside the SpMV with the identity preconditioner
    (3 fused axpy2 passes + the fused dot pass) vs 3 for hs/fcg.

    The convergence check uses the ||r||² from the fused reduction, which
    lags the updated residual by one iteration — the standard pipelined-CG
    trade of one extra iteration for the hidden latency.
    """
    # -- init: r0, u0 = M r0, w0 = A u0, first reduction + first update -----
    with trace.region("spmv"):
        r = b - A(x0)
    if pre.is_identity:
        u = r
    else:
        with trace.region("precond"):
            u = pre.apply(pdata, r, axis)
    with trace.region("spmv"):
        w = A(u)
    with trace.region("reductions"):
        d0 = fused_dots([(r, u), (w, u), (r, r), (b, b)], axis)
    gamma, delta, rr, bb = d0[0], d0[1], d0[2], d0[3]
    tol2 = tol * tol * bb

    if pre.is_identity:
        m = w
    else:
        with trace.region("precond"):
            m = pre.apply(pdata, w, axis)
    with trace.region("spmv"):
        n = A(m)
    alpha = _safe_div(gamma, delta)  # r0 == 0 -> no-op first step, not NaN
    z, q, s_, p = n, m, w, u
    x = x0 + alpha * p
    r = r - alpha * s_
    u = r if pre.is_identity else u - alpha * q
    w = w - alpha * z

    def _reduce(r, u, w):
        """Issue the ONE fused all-reduce (the SpMV that follows does not
        depend on its result — that independence is the pipeline)."""
        pairs = (
            [(w, r), (r, r)] if pre.is_identity else [(r, u), (w, u), (r, r)]
        )
        d = all_reduce(ops.fused_dots_n(pairs), axis)
        trace.record_collective(len(pairs), w.dtype.itemsize)
        return d

    def _precond_w(w):
        if pre.is_identity:
            return w
        with trace.region("precond"):
            return pre.apply(pdata, w, axis)

    def body(c):
        i, x, r, u, w, p, s_, q, z, gamma, alpha, rr = c
        with kd.ledger_section("iteration"):
            if overlap:
                # reduction + concurrent SpMV: one co-scheduled phase
                with trace.region(trace.OVERLAP):
                    d = _reduce(r, u, w)
                    m = _precond_w(w)
                    n = A(m)
            else:
                # serialized A/B reference: the reduction blocks, then the
                # SpMV runs — attributed like the hs/fcg bodies
                with trace.region("reductions"):
                    d = _reduce(r, u, w)
                m = _precond_w(w)
                with trace.region("spmv"):
                    n = A(m)
            if pre.is_identity:
                delta, gamma_new, rr = d[0], d[1], d[1]
            else:
                gamma_new, delta, rr = d[0], d[1], d[2]
            beta = gamma_new / gamma
            alpha_new = gamma_new / (delta - beta * gamma_new / alpha)
            with trace.region("reductions"):
                if pre.is_identity:
                    # 3 fused passes: (z, s), (p, w), (x, r); u == r, q == s
                    z, s_ = ops.fused_axpy2(beta, z, n, beta, s_, w)
                    p, w = ops.fused_axpy2(beta, p, r, -alpha_new, z, w)
                    x, r = ops.fused_axpy2(alpha_new, p, x, -alpha_new, s_, r)
                    u, q = r, s_
                else:
                    z, q = ops.fused_axpy2(beta, z, n, beta, q, m)
                    s_, p = ops.fused_axpy2(beta, s_, w, beta, p, u)
                    x, r = ops.fused_axpy2(alpha_new, p, x, -alpha_new, s_, r)
                    u, w = ops.fused_axpy2(-alpha_new, q, u, -alpha_new, z, w)
        if telemetry:
            # pipelined trade-off: rr lags the updated residual by one iter
            _telemetry_emit(i + 1, jnp.sqrt(rr / jnp.maximum(bb, 1e-300)), axis)
        return (i + 1, x, r, u, w, p, s_, q, z, gamma_new, alpha_new, rr)

    def cond(c):
        i, x, r, u, w, p, s_, q, z, gamma, alpha, rr = c
        return (i < maxiter) & (rr > tol2)

    i0 = jnp.asarray(1, jnp.int32)
    c = lax.while_loop(
        cond, body, (i0, x, r, u, w, p, s_, q, z, gamma, alpha, rr)
    )
    return c[1], c[0], c[11], bb


def _sstep_body(
    A, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, s, axis, ops,
    mat=None, telemetry=False,
):
    """s-step CG (Chronopoulos–Gear): one fused all-reduce per s iterations.

    Monomial basis P = [u, (MA)u, ..., (MA)^{s-1}u] with u = M r; the block
    is A-conjugated against the previous block using only locally
    reconstructable Gram algebra (see module docstring).

    Basis construction routes through :func:`~repro.core.spmv.matrix_powers`
    when it can — identity preconditioner and a ``mat`` partitioned with
    ghost zones at least ``s`` deep — replacing the s sequential halo
    round-trips of the naive loop with ONE widened exchange per block (the
    communication-avoiding formulation). Otherwise the sequential scan is
    the fallback (real preconditioner, shallow halo, or all-gather layout).

    Vector work runs through the kernel dispatch ``ops`` in 3 full-vector
    HBM sweeps per block outside the SpMVs: the fused Gram reduction
    (``sstep_gram``), the A-conjugation + column-normalization update
    (``sstep_basis``), and the x/r update (``sstep_update``).

    Stability: the monomial columns are rescaled by their A-norms (the
    reduced ``diag(PᵀW)`` — no extra collective payload) before the block
    solves, and a non-finite step freezes x/r and exits the loop.
    """
    dt = b.dtype
    R = b.shape[0]
    with trace.region("spmv"):
        r = b - A(x0)
    with trace.region("reductions"):
        bb = pdot(b, b, axis)
    tol2 = tol * tol * bb
    eye = jnp.eye(s, dtype=dt)

    # the matrix-powers path needs ghost zones covering all s applications
    # (a lone shard has no halo at all — any depth works there)
    use_mp = (
        mat is not None
        and pre.is_identity
        and mat.plan.mode != "allgather"
        and (not mat.plan.shifts or mat.halo_depth >= s)
    )

    def build_basis(r):
        if use_mp:
            # ONE widened exchange for the whole block: [Ar, ..., A^s r]
            Ws = matrix_powers(mat, r, s, axis)
            Ps = jnp.concatenate([r[None], Ws[:-1]], axis=0)
            return Ps.T, Ws.T  # (s, R) -> (R, s)

        def one(carry, _):
            u = carry
            with trace.region("precond"):
                p = pre.apply(pdata, u, axis)
            with trace.region("spmv"):
                w = A(p)
            return w, (p, w)

        # the scan body traces ONCE but executes s times per block — scale
        # its recorded counts accordingly (see energy/trace.py)
        with trace.repeated(s):
            _, (Ps, Ws) = lax.scan(one, r, None, length=s)
        # (s, R) -> (R, s)
        return Ps.T, Ws.T

    def body(c):
        # The while body traces ONCE per s-iteration BLOCK, but the ledger
        # replays iteration-section counts once per ITERATION — record the
        # block's counts at their per-iteration average so sstep ledgers
        # are comparable with hs/fcg (one widened exchange per block shows
        # up as 1/s collectives per iteration, exactly the amortization).
        with kd.ledger_section("iteration"), trace.repeated(1.0 / s):
            return _sstep_block(c)

    def _sstep_block(c):
        i, ok, x, r, Qp, Wp, Gqq, rr = c
        Pb, Wb = build_basis(r)
        # ONE fused all-reduce: [P^T W (s*s) | W_prev^T P (s*s) | P^T r (s) | rr]
        with trace.region("reductions"):
            flat = fused_blocks([ops.sstep_gram(Pb, Wb, Wp, r)], axis)
        Gpp = flat[: s * s].reshape(s, s)
        C = flat[s * s : 2 * s * s].reshape(s, s)
        g = flat[2 * s * s : 2 * s * s + s]
        rr = flat[-1]
        # Rescale the basis columns by their A-norms (van der Sluis: the
        # diagonal scaling that near-minimizes the Gram condition number).
        # Raw monomial columns grow like rho(A)^j, so without this the Gram
        # conditioning explodes like kappa^s for large s.
        d = jnp.diagonal(Gpp)
        dinv = jnp.where(d > 0, lax.rsqrt(jnp.where(d > 0, d, 1.0)), 1.0)
        Gpp = Gpp * (dinv[:, None] * dinv[None, :])
        C = C * dinv[None, :]
        g = g * dinv
        # A-conjugate against previous block: B = Gqq^{-1} C (Gqq from prev).
        B = jnp.linalg.solve(Gqq + 1e-300 * eye, C)
        with trace.region("reductions"):
            # Q = Pb D - Qp B ; WQ = Wb D - Wp B — ONE fused pass
            Q, WQ = ops.sstep_basis(B, dinv, Qp, Pb, Wp, Wb)
        Gq = Gpp - B.T @ C - C.T @ B + B.T @ Gqq @ B
        # Q^T r == g because r ⟂ span(previous block) in exact arithmetic.
        a = jnp.linalg.solve(Gq + 1e-300 * eye, g)
        # breakdown guard: a non-finite step means the basis lost numerical
        # independence despite the scaling (s too large for this spectrum).
        # Freeze x/r and stop — the caller sees a loud non-converged
        # residual instead of silent NaNs.
        fin = jnp.isfinite(a).all() & jnp.isfinite(B).all()
        a = jnp.where(fin, a, jnp.zeros_like(a))
        with trace.region("reductions"):
            # x += Q a ; r -= WQ a — ONE fused pass
            x, r = ops.sstep_update(a, Q, WQ, x, r)
        if telemetry:
            # one report per s-iteration block; rr is the block-entry
            # residual (the fused Gram reads the incoming r)
            _telemetry_emit(i + s, jnp.sqrt(rr / jnp.maximum(bb, 1e-300)), axis)
        return (i + s, ok & fin, x, r, Q, WQ, Gq, rr)

    def cond(c):
        i, ok, x, r, Qp, Wp, Gqq, rr = c
        return ok & (i < maxiter) & (rr > tol2)

    i0 = jnp.asarray(0, jnp.int32)
    ok0 = jnp.asarray(True)
    # mark the zero-init blocks as shard-varying for the while_loop carry
    ax_names = (axis,) if isinstance(axis, str) else tuple(axis)
    _pvary = (
        (lambda v: lax.pcast(v, ax_names, to="varying"))
        if hasattr(lax, "pcast")
        else (lambda v: lax.pvary(v, ax_names))
        if hasattr(lax, "pvary")
        else (lambda v: v)  # check_rep=False: no replication tracking needed
    )
    Q0 = _pvary(jnp.zeros((R, s), dt))
    c = lax.while_loop(cond, body, (i0, ok0, x0, r, Q0, Q0, eye, bb))
    return c[2], c[0], c[7], bb


def _block_hs_body(A, B, X0, *, tol, maxiter, axis, ops, telemetry=False):
    """Breakdown-guarded block Hestenes–Stiefel CG for (R, r) RHS blocks.

    The scalar recurrences become r×r Gram algebra: alpha/beta are small
    matrix solves against the P'AP and R'R Grams, and the matrix is read
    ONCE per iteration for all r right-hand sides (the SpMM interior).
    Still 2 all-reduces/iter — each now carries r² scalars instead of 1.

    Guard policy (see docs/solvers.md):
      * deflation — a column whose residual has met its per-column target
        is masked out of both Gram solves (its alpha/beta columns are
        exactly zero, freezing x_j and r_j) and its search direction is
        zeroed, so a converged system cannot re-pollute the block;
      * ridge — the masked Grams get a trace-scaled ``eps`` ridge before
        the solve, so (near-)linearly-dependent RHS columns degrade the
        step slightly instead of producing NaNs (rank-deficient P'W).
    """
    dt = B.dtype
    nrhs = B.shape[1]
    eye = jnp.eye(nrhs, dtype=dt)

    with trace.region("spmv"):
        R_ = B - A(X0)
    with trace.region("reductions"):
        rr0_loc, bb_loc = ops.block_gram([(R_, R_), (B, B)])
        d0 = fused_blocks([rr0_loc, jnp.diagonal(bb_loc)], axis)
    RR = d0[: nrhs * nrhs].reshape(nrhs, nrhs)
    bb = d0[nrhs * nrhs :]
    tol2 = tol * tol * bb  # per-column targets

    def _msolve(G, RHS, md):
        # mask converged rows/cols out, keep the system well-posed with a
        # unit diagonal there, and ridge against RHS-column collinearity
        m2 = md[:, None] * md[None, :]
        Gm = G * m2 + jnp.diag(1.0 - md)
        ridge = jnp.finfo(dt).eps * jnp.trace(Gm) / nrhs
        return jnp.linalg.solve(Gm + ridge * eye, RHS * m2)

    def cond(c):
        i, X, R_, Pb, RR, it_cols = c
        return (i < maxiter) & jnp.any(jnp.diagonal(RR) > tol2)

    def body(c):
        i, X, R_, Pb, RR, it_cols = c
        md = (jnp.diagonal(RR) > tol2).astype(dt)  # 1 = still active
        with kd.ledger_section("iteration"):
            with trace.region("spmv"):
                W = A(Pb)  # matrix read once for all r columns
            with trace.region("reductions"):
                pw_loc = ops.block_gram([(Pb, W)])[0]
                PW = fused_blocks([pw_loc], axis).reshape(nrhs, nrhs)  # AR 1
                alpha = _msolve(PW, RR, md)
                # X += P alpha ; R -= W alpha — ONE fused pass
                X, R_ = ops.block_update2(alpha, Pb, X, -alpha, W, R_)
                rr_loc = ops.block_gram([(R_, R_)])[0]
                RRn = fused_blocks([rr_loc], axis).reshape(nrhs, nrhs)  # AR 2
                beta = _msolve(RR, RRn, md)
                Pb = ops.block_update(beta, Pb, R_, mask=md)
        it_cols = jnp.where(
            jnp.diagonal(RRn) <= tol2, jnp.minimum(it_cols, i + 1), it_cols
        )
        if telemetry:
            # per-column relative residuals: the history rows are vectors
            _telemetry_emit(
                i + 1,
                jnp.sqrt(jnp.diagonal(RRn) / jnp.maximum(bb, 1e-300)),
                axis,
            )
        return (i + 1, X, R_, Pb, RRn, it_cols)

    i0 = jnp.asarray(0, jnp.int32)
    maxit = jnp.asarray(maxiter, jnp.int32)
    it0 = jnp.where(
        jnp.diagonal(RR) <= tol2, jnp.zeros_like(maxit), maxit
    ).astype(jnp.int32)
    c = lax.while_loop(cond, body, (i0, X0, R_, R_, RR, it0))
    return c[1], c[0], c[5], jnp.diagonal(c[4]), bb


_BODIES = {
    "hs": _hs_body,
    "fcg": _fcg_body,
    "pipecg": _pipecg_body,
    "sstep": _sstep_body,
}
VARIANTS = tuple(_BODIES)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def make_solver(
    mesh,
    mat: DistMat,
    *,
    variant: str = "hs",
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis="shards",  # mesh axis name, or a (rows, cols) tuple for 2-D grids
    kernels: str | None = None,
    overlap: bool = True,
    telemetry: bool = False,
):
    """Build a jitted distributed solver: ``solve(b, x0) -> SolveResult``.

    Args:
        mesh: 1-D ``jax.sharding.Mesh`` with a ``shards`` axis (see
            ``launch/mesh.py``).
        mat: the distributed matrix (leading shard axis on every data leaf;
            build with ``partition_csr`` / ``partition_stencil`` +
            ``spmv.shard_matrix``).
        variant: ``"hs"`` | ``"fcg"`` | ``"pipecg"`` | ``"sstep"`` — see the
            module docstring and ``docs/solvers.md`` for the trade-offs.
        precond: a :class:`Preconditioner` (None = identity).
        tol: relative residual target; convergence is declared at
            ``||r||^2 <= tol^2 * ||b||^2``.
        maxiter: iteration cap (an s-step block counts as ``s`` iterations).
        s: block size for ``variant="sstep"`` (ignored otherwise).
        axis: shard_map mesh-axis name the collectives run over.
        kernels: hot-path backend for the solver bodies — one of
            ``kernels.dispatch.BACKENDS`` or None/'auto' (resolve from
            override/env/backend). All four variants route through it;
            the sstep body's blocked Gram algebra uses the fused
            ``sstep_gram`` / ``sstep_basis`` / ``sstep_update`` ops.
        overlap: communication-hiding schedule (default on): the SpMV uses
            the interior/boundary split with the halo exchange in flight,
            and ``pipecg`` issues its all-reduce before the concurrent
            SpMV. ``False`` restores the serialized order (for A/B energy
            comparisons — see ``benchmarks/overlap_scaling.py``).
        telemetry: bake a per-iteration convergence callback into the loop
            body (repro.obs.convergence) — the compiled program reports
            ``(iteration, relres)`` to the host while it runs. Off by
            default: the callback is part of the compiled program, so this
            flag is part of the solver-handle cache key.

    Returns:
        A jitted ``solve(b, x0) -> SolveResult`` where ``b``/``x0`` are
        (S, R) padded sharded arrays (``partition.pad_vector`` +
        ``spmv.shard_vector``) and the result carries the (S, R) solution,
        the executed iteration count, and ``||r||^2`` / ``||b||^2``.
    """
    from jax.experimental.shard_map import shard_map

    pre = precond or identity_precond()
    body = _BODIES[variant]
    kw = dict(
        tol=tol, maxiter=maxiter, axis=axis, ops=kd.ops_for(kernels),
        telemetry=telemetry,
    )
    if variant == "sstep":
        kw["s"] = s
    if variant == "pipecg":
        kw["overlap"] = overlap

    mat_specs = dist_specs(mat, axis)

    localize = pre.localize or _default_localize

    def fn(m, pdata, b, x0):
        mb = local_block(m)
        pl = localize(pdata)
        A = lambda v: spmv_shard(mb, v, axis, overlap=overlap)
        # the sstep body takes the local matrix block itself: its basis can
        # route through the matrix-powers SpMV (one widened halo exchange)
        kwb = dict(kw, mat=mb) if variant == "sstep" else kw
        # scope the default so preconditioner-internal SpMVs (the AMG
        # V-cycle's smoothers) follow the solver's schedule too
        with overlap_default(overlap):
            x, iters, rr, bb = body(A, pre, pl, b[0], x0[0], **kwb)
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(mat_specs, pre.specs, P(axis, None), P(axis, None)),
        out_specs=(P(axis, None), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(b, x0):
        x, iters, rr, bb = mapped(mat, pre.data, b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve


def make_solver_fn(
    mesh,
    mat_like: DistMat,
    *,
    variant: str = "hs",
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis="shards",  # mesh axis name, or a (rows, cols) tuple for 2-D grids
    kernels: str | None = None,
    overlap: bool = True,
):
    """Lowerable variant of :func:`make_solver`: returns a jitted
    ``solve(mat, b, x0)`` with the matrix as a *runtime argument* — accepts
    ShapeDtypeStruct trees, which is what the production-mesh dry-run lowers
    (no data, no allocation).

    ``mat_like`` only supplies shapes/plan for the sharding specs; all other
    arguments as in :func:`make_solver`.
    """
    from jax.experimental.shard_map import shard_map

    pre = precond or identity_precond()
    body = _BODIES[variant]
    kw = dict(tol=tol, maxiter=maxiter, axis=axis, ops=kd.ops_for(kernels))
    if variant == "sstep":
        kw["s"] = s
    if variant == "pipecg":
        kw["overlap"] = overlap
    mat_specs = dist_specs(mat_like, axis)
    localize = pre.localize or _default_localize

    def fn(m, pdata, b, x0):
        mb = local_block(m)
        pl = localize(pdata)
        A = lambda v: spmv_shard(mb, v, axis, overlap=overlap)
        kwb = dict(kw, mat=mb) if variant == "sstep" else kw
        with overlap_default(overlap):
            x, iters, rr, bb = body(A, pre, pl, b[0], x0[0], **kwb)
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(mat_specs, pre.specs, P(axis, None), P(axis, None)),
        out_specs=(P(axis, None), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(mat_arg, b, x0):
        x, iters, rr, bb = mapped(mat_arg, pre.data, b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve


def abstract_stencil_dist(p, n_shards: int, dtype="float64") -> DistMat:
    """ShapeDtypeStruct DistMat (ELL interior) for a slab-partitioned
    stencil problem —
    production-scale dry-runs lower this without ever materializing data."""
    import numpy as np

    from repro.core.partition import ELLBlock, HaloPlan, plane_partition

    part = plane_partition(p.n, p.plane, n_shards)
    R = part.max_own
    H = p.plane
    k = p.k
    off_dz_pos = {"7pt": 1, "27pt": 9}[p.stencil]
    k_ext = max(off_dz_pos, 1)
    shifts, widths = ((-1, 1), (H, H)) if n_shards > 1 else ((), ())
    plan = HaloPlan("ring", shifts, widths, R, n_shards)
    S = n_shards
    # boundary rows live in the slab's first/last plane (see
    # partition_stencil): 2H for interior shards, H for the 2-shard case
    if S <= 1:
        B, n_bnd = 1, (0,) * S
    elif S == 2:
        B, n_bnd = H, (H,) * S
    else:
        B = H * min(2, R // H)
        n_bnd = (H,) + (B,) * (S - 2) + (H,)
    sds = jax.ShapeDtypeStruct
    return DistMat(
        interior=ELLBlock(
            data=sds((S, R, k), dtype), col=sds((S, R, k), "int32")
        ),
        data_ext=sds((S, B, k_ext), dtype),
        col_ext=sds((S, B, k_ext), "int32"),
        bnd_rows=sds((S, B), "int32"),
        send_sel=sds((S, max(sum(widths), 1)), "int32"),
        plan=plan,
        n_global=p.n,
        row_starts=part.row_starts,
        n_bnd=n_bnd,
    )


def solve_cg(mesh, mat: DistMat, b_np, *, x0_np=None, **kw) -> SolveResult:
    """Convenience host-level solve: numpy in, SolveResult out."""
    import numpy as np

    from repro.core.partition import pad_vector
    from repro.core.spmv import shard_vector

    bp = pad_vector(np.asarray(b_np), mat)
    xp = (
        pad_vector(np.asarray(x0_np), mat)
        if x0_np is not None
        else np.zeros_like(bp)
    )
    solver = make_solver(mesh, mat, **kw)
    return solver(shard_vector(mesh, bp), shard_vector(mesh, xp))


def make_block_solver(
    mesh,
    mat: DistMat,
    *,
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    axis="shards",  # mesh axis name, or a (rows, cols) tuple for 2-D grids
    kernels: str | None = None,
    overlap: bool = True,
    telemetry: bool = False,
):
    """Build a jitted multi-RHS block solver: ``solve(B, X0) -> BlockSolveResult``.

    ``B``/``X0`` are (S, R, r) padded sharded blocks (``partition.pad_block``
    + ``spmv.shard_vector``). Runs the breakdown-guarded block-HS body: the
    matrix is streamed from HBM once per iteration for all ``r`` right-hand
    sides, converged columns are deflated, and each column's convergence is
    declared against its own ``tol^2 * ||b_j||^2`` target.

    Only the identity preconditioner is supported (the block recurrences
    assume the unpreconditioned R'R Gram); pass ``precond=None``.
    """
    from jax.experimental.shard_map import shard_map

    if precond is not None and not precond.is_identity:
        raise ValueError(
            "block-CG supports the identity preconditioner only; "
            "use make_solver(variant=...) per column for preconditioned solves"
        )
    ops = kd.ops_for(kernels)
    kw = dict(tol=tol, maxiter=maxiter, axis=axis, ops=ops,
              telemetry=telemetry)
    mat_specs = dist_specs(mat, axis)

    def fn(m, Bv, X0):
        mb = local_block(m)
        A = lambda v: spmv_shard(mb, v, axis, overlap=overlap)
        with overlap_default(overlap):
            X, iters, it_cols, rr, bb = _block_hs_body(A, Bv[0], X0[0], **kw)
        return X[None], iters, it_cols, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(
            mat_specs,
            P(axis, None, None),
            P(axis, None, None),
        ),
        out_specs=(P(axis, None, None), P(), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(Bv, X0):
        X, iters, it_cols, rr, bb = mapped(mat, Bv, X0)
        return BlockSolveResult(
            x=X, iters=iters, iters_cols=it_cols, rr=rr, bb=bb
        )

    return solve


def default_rhs_block(n: int, nrhs: int, dtype="float64"):
    """Deterministic (n, nrhs) RHS block with distinct, well-scaled columns.

    Column 0 is the all-ones vector the single-RHS benchmarks use; later
    columns add a small distinct sinusoid so the block is full-rank without
    changing the magnitude scale (keeps iteration counts comparable)."""
    import numpy as np

    i = np.arange(n, dtype=np.float64)
    cols = [
        np.ones(n) + 0.1 * j * np.sin((j + 1) * np.pi * (i + 0.5) / n)
        for j in range(nrhs)
    ]
    return np.stack(cols, axis=1).astype(dtype)


def solve_block_cg(mesh, mat: DistMat, B_np, *, x0_np=None, **kw):
    """Convenience host-level block solve: numpy (n, r) in, BlockSolveResult
    out."""
    import numpy as np

    from repro.core.partition import pad_block
    from repro.core.spmv import shard_vector

    Bp = pad_block(np.asarray(B_np), mat)
    Xp = (
        pad_block(np.asarray(x0_np), mat)
        if x0_np is not None
        else np.zeros_like(Bp)
    )
    solver = make_block_solver(mesh, mat, **kw)
    return solver(shard_vector(mesh, Bp), shard_vector(mesh, Xp))


# ---------------------------------------------------------------------------
# Session-reusable solver handles
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SolverHandle:
    """A compiled solver plus the energy trace captured at first warmup.

    Reuse subtlety: a jitted solver re-traces only on its *first* call —
    every later call is an XLA executable-cache hit, so wrapping it in
    ``trace.capture`` records nothing. The handle therefore snapshots the
    :class:`~repro.energy.trace.EnergyTrace` of the warmup call; repeat
    solves through the same handle integrate ledgers from that snapshot
    (the compiled program — hence its executed counts — cannot change
    without a new handle).

    The ``mesh``/``mat``/``precond`` references are load-bearing: the cache
    key uses their ``id()``, and holding them alive guarantees those ids
    are never recycled while the handle is cached.
    """

    fn: Callable
    key: tuple
    mesh: Any
    mat: Any
    precond: Any = None
    trace: Any = None  # EnergyTrace from the first warm(); None = cold

    @property
    def warmed(self) -> bool:
        return self.trace is not None

    def warm(self, *args):
        """Compile under the region trace on first use; no-op afterwards.

        Returns the warmup result (blocked until ready), or None when the
        handle is already warm."""
        if self.trace is not None:
            return None
        with trace.capture() as tr:
            res = self.fn(*args)
        jax.block_until_ready(res)
        self.trace = tr
        return res

    def __call__(self, *args):
        return self.fn(*args)


#: Process-global handle cache for callers without a session. LRU-bounded:
#: each handle deliberately pins its mesh/mat/precond (see SolverHandle),
#: so an unbounded cache grows without limit in a long-running process.
#: Session-owned solves pass their own ``cache=`` dict instead — those
#: handles live exactly as long as the session (dropping the session frees
#: its compiled executables and partitions together).
_HANDLES: "collections.OrderedDict[tuple, SolverHandle]" = (
    collections.OrderedDict()
)
_HANDLE_LIMIT = 32


def set_solver_handle_limit(limit: int) -> int:
    """Set the global handle cache's LRU bound; returns the previous one."""
    global _HANDLE_LIMIT
    if limit < 1:
        raise ValueError(f"handle limit must be >= 1: {limit}")
    prev, _HANDLE_LIMIT = _HANDLE_LIMIT, int(limit)
    while len(_HANDLES) > _HANDLE_LIMIT:
        _HANDLES.popitem(last=False)
    return prev


def clear_solver_handles():
    """Drop every cached handle (frees the compiled executables; tests)."""
    _HANDLES.clear()


def solver_handle(
    mesh,
    mat: DistMat,
    *,
    op: str = "cg",
    nrhs: int = 1,
    variant: str = "hs",
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    s: int = 2,
    axis="shards",  # mesh axis name, or a (rows, cols) tuple for 2-D grids
    kernels: str | None = None,
    overlap: bool = True,
    telemetry: bool = False,
    cache: dict | None = None,
) -> SolverHandle:
    """Cached solver keyed by (partition, config): build once, solve many.

    Repeat requests for the same sharded ``mat`` (identity, not equality —
    a re-partition is a new program) and the same solver configuration
    return the already-compiled handle, skipping re-trace/re-compile
    entirely. Routes to :func:`make_block_solver` when ``nrhs`` > 1, the
    Ginkgo-analog baseline for ``variant="naive"``, the distributed SpMV
    for ``op="spmv"`` (``variant="naive"`` selects the all-gather SpMV),
    and :func:`make_solver` otherwise.

    ``cache`` scopes handle lifetime: pass an owner's dict (e.g. a
    ``SolverSession``'s) so its handles die with it; the default is the
    process-global LRU (:data:`_HANDLE_LIMIT` entries).
    """
    key = (
        id(mesh), id(mat), str(op), int(max(nrhs, 1)), str(variant),
        None if precond is None else id(precond),
        float(tol), int(maxiter), int(s), axis, kernels, bool(overlap),
        bool(telemetry),  # the callback is part of the compiled program
    )
    store = _HANDLES if cache is None else cache
    h = store.get(key)
    if (
        h is not None
        and h.mesh is mesh
        and h.mat is mat
        and (precond is None or h.precond is precond)
    ):
        if store is _HANDLES:
            _HANDLES.move_to_end(key)
        return h
    if op == "spmv":
        from repro.core.baselines import make_naive_spmv
        from repro.core.spmv import make_spmv

        if variant == "naive":
            fn = make_naive_spmv(mesh, mat, axis)
        else:
            fn = make_spmv(mesh, mat, axis, overlap=overlap)
    elif nrhs > 1:
        fn = make_block_solver(
            mesh, mat, precond=precond, tol=tol, maxiter=maxiter,
            axis=axis, kernels=kernels, overlap=overlap,
            telemetry=telemetry,
        )
    elif variant == "naive":
        from repro.core.baselines import make_naive_solver

        fn = make_naive_solver(
            mesh, mat, precond=precond, tol=tol, maxiter=maxiter, axis=axis
        )
    else:
        fn = make_solver(
            mesh, mat, variant=variant, precond=precond, tol=tol,
            maxiter=maxiter, s=s, axis=axis, kernels=kernels, overlap=overlap,
            telemetry=telemetry,
        )
    h = SolverHandle(fn=fn, key=key, mesh=mesh, mat=mat, precond=precond)
    store[key] = h
    if store is _HANDLES:
        while len(_HANDLES) > _HANDLE_LIMIT:
            _HANDLES.popitem(last=False)
    return h
