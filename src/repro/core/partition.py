"""Block-row partitioning + halo-exchange planning (host side).

This module reproduces the paper's distribution substrate (C1):

* matrices are distributed in **blocks of contiguous rows** across shards;
* device-resident column indices are **4-byte local indices** obtained by a
  global->local shift + compaction — the global (possibly >2^32) index space
  exists only on the host at partition time (numpy ``int64``);
* every shard's sparse rows are split into an **interior block** (entries
  whose column is owned by the shard — no communication needed) and a compact
  **boundary block** holding only the ghost-touching rows' external entries,
  so that the halo ``ppermute`` can be issued first, the interior matvec runs
  while the exchange is in flight, and the boundary block is applied on
  arrival — the JAX analog of BootCMatchGX's overlap of GPU compute with MPI
  communication (see ``core/spmv.spmv_shard`` and ``docs/architecture.md``);
* the halo exchange itself is planned as a set of ``lax.ppermute`` shifts
  ("ring" mode, for matrices whose off-shard couplings reach at most
  ``max_ring`` neighbor shards — all banded/stencil problems) or falls back to
  a full ``all_gather`` ("allgather" mode) for irregular coupling patterns.
  The fallback mirrors the paper's observation that irregular matrices
  (G3_circuit-like) lose scalability to communication.

Everything here is numpy / scipy; the device-side execution lives in
``core/spmv.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Row partition
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowPartition:
    """Contiguous block-row partition of ``n_global`` rows over ``n_shards``."""

    n_global: int
    row_starts: tuple[int, ...]  # length n_shards + 1, row_starts[-1] == n_global

    @property
    def n_shards(self) -> int:
        return len(self.row_starts) - 1

    def owner_range(self, shard: int) -> tuple[int, int]:
        return self.row_starts[shard], self.row_starts[shard + 1]

    def n_own(self, shard: int) -> int:
        lo, hi = self.owner_range(shard)
        return hi - lo

    @property
    def max_own(self) -> int:
        return max(self.n_own(s) for s in range(self.n_shards))

    def owner_of(self, gcol: np.ndarray) -> np.ndarray:
        """Shard owning each global column (vectorized)."""
        starts = np.asarray(self.row_starts[1:], dtype=np.int64)
        return np.searchsorted(starts, gcol, side="right").astype(np.int64)


def balanced_partition(n_global: int, n_shards: int) -> RowPartition:
    starts = np.linspace(0, n_global, n_shards + 1).astype(np.int64)
    return RowPartition(n_global, tuple(int(s) for s in starts))


def plane_partition(n_global: int, plane: int, n_shards: int) -> RowPartition:
    """Partition along whole z-planes of size ``plane`` (stencil slabs)."""
    nz = n_global // plane
    assert nz * plane == n_global, "n_global must be a multiple of plane"
    if nz < n_shards:
        raise ValueError(f"cannot slab-partition nz={nz} over {n_shards} shards")
    zs = np.linspace(0, nz, n_shards + 1).astype(np.int64)
    return RowPartition(n_global, tuple(int(z) * plane for z in zs))


def default_grid(n_shards: int) -> tuple[int, int]:
    """Most-square ``(rows, cols)`` factorization with ``rows <= cols``.

    4 -> (2, 2), 8 -> (2, 4), 16 -> (4, 4), 32 -> (4, 8). Primes (and
    shard counts below 4) have no nontrivial factorization and map to
    ``(1, n_shards)`` — the 1-D layout.
    """
    n_shards = int(n_shards)
    r = max(int(np.sqrt(n_shards)), 1)
    while r > 1 and n_shards % r:
        r -= 1
    return (r, n_shards // r)


def pencil_partition(p, grid: tuple[int, int]) -> tuple[np.ndarray, RowPartition]:
    """Pencil (z-block x y-block) row ordering for an ``R x C`` process grid.

    Returns ``(perm, part)``: ``perm[new] = old`` is the symmetric row
    permutation that makes the flat shard ``s = i*C + j`` own the pencil
    ``z_blocks[i] x y_blocks[j] x [0, nx)`` as one contiguous row block, and
    ``part`` is the matching :class:`RowPartition`. Solving the permuted
    system ``A[perm][:, perm] x' = b[perm]`` with ``partition_csr(...,
    grid=grid, partition=part)`` gives per-dimension halos that scale with
    the pencil *surface* (``O(N^2 / sqrt(S))`` per shard), not the slab
    cross-section (``O(N^2)``) — the 2-D decomposition's whole point.

    ``p`` is duck-typed: it only needs ``nx``/``ny``/``nz`` (``PoissonProblem``
    qualifies). Empty z-blocks / y-blocks (grid larger than the axis) yield
    empty shards, which the partitioner handles.
    """
    gr, gc = int(grid[0]), int(grid[1])
    z_blocks = np.array_split(np.arange(p.nz, dtype=np.int64), gr)
    y_blocks = np.array_split(np.arange(p.ny, dtype=np.int64), gc)
    xs = np.arange(p.nx, dtype=np.int64)
    parts, starts, tot = [], [0], 0
    for zb in z_blocks:
        for yb in y_blocks:
            zz, yy, xx = np.meshgrid(zb, yb, xs, indexing="ij")
            ids = (xx + p.nx * (yy + p.ny * zz)).ravel()
            parts.append(ids)
            tot += ids.size
            starts.append(tot)
    perm = (
        np.concatenate(parts) if parts else np.zeros(0, np.int64)
    ).astype(np.int64)
    return perm, RowPartition(p.nx * p.ny * p.nz, tuple(starts))


# ---------------------------------------------------------------------------
# Halo plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    """Static description of a halo exchange.

    mode == "ring":
        ``shifts[k]`` means every shard i *receives* a buffer of width
        ``widths[k]`` from shard ``i + shifts[k]`` (edge shards receive
        zeros).  The receive buffers are concatenated after ``x_own`` in
        shift order, forming ``x_ext = [x_own | buf_0 | buf_1 | ...]``.
    mode == "allgather":
        ``x_ext`` is the full (padded) global vector, ``all_gather``-ed
        over the shard axis; widths/shifts are empty.
    """

    mode: str  # "ring" | "allgather"
    shifts: tuple[int, ...]
    widths: tuple[int, ...]
    n_own_pad: int  # uniform padded rows per shard
    n_shards: int

    @property
    def ext_len(self) -> int:
        if self.mode == "allgather":
            return self.n_own_pad * self.n_shards
        return self.n_own_pad + sum(self.widths)

    def buf_offset(self, k: int) -> int:
        """Offset of receive buffer ``k`` inside x_ext (ring mode)."""
        return self.n_own_pad + sum(self.widths[:k])

    def perm(self, k: int) -> tuple[tuple[int, int], ...]:
        """ppermute (src, dst) pairs for shift k: src j sends to j - shift."""
        d = self.shifts[k]
        return tuple(
            (j, j - d) for j in range(self.n_shards) if 0 <= j - d < self.n_shards
        )

    def collective_bytes_per_shard(self, itemsize: int = 8) -> int:
        """Bytes each shard sends per exchange (roofline collective term)."""
        if self.mode == "allgather":
            return self.n_own_pad * (self.n_shards - 1) * itemsize
        return sum(self.widths) * itemsize


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """Static halo-exchange description for a 2-D ``R x C`` process grid.

    Shards are laid out flat-row-major over the grid: flat shard
    ``s = i * C + j`` sits at grid position ``(i, j)``. Rows stay
    block-contiguous over the *flat* shard order (so the padded vector
    layout is identical to the 1-D one); what changes is the neighbor
    structure: ``shifts[k] = (di, dj)`` means shard ``(i, j)`` *receives* a
    buffer of width ``widths[k]`` from shard ``(i + di, j + dj)`` (edge
    shards receive zeros). Receive buffers concatenate after ``x_own`` in
    shift order, exactly like :class:`HaloPlan` ring mode.

    Each shift moves per dimension: a pure-column shift ``(0, dj)`` is one
    ``ppermute`` over the mesh's ``cols`` axis, a pure-row shift ``(di, 0)``
    one over ``rows``, and a corner shift ``(di, dj)`` chains the two (the
    column hop first, then the row hop forwards the received buffer), i.e.
    ``hops(k)`` ppermute launches and that many traversals of the buffer
    over the interconnect.
    """

    mode: str  # always "grid"
    grid: tuple[int, int]  # (rows, cols) of the process grid
    shifts: tuple[tuple[int, int], ...]  # (di, dj) receive-from deltas
    widths: tuple[int, ...]
    n_own_pad: int  # uniform padded rows per shard
    n_shards: int

    #: Mesh axis names the exchange runs over, in (rows, cols) order.
    axes: tuple[str, str] = ("rows", "cols")

    @property
    def ext_len(self) -> int:
        return self.n_own_pad + sum(self.widths)

    def buf_offset(self, k: int) -> int:
        """Offset of receive buffer ``k`` inside x_ext."""
        return self.n_own_pad + sum(self.widths[:k])

    def hops(self, k: int) -> int:
        """Interconnect hops of shift ``k`` (1 pure-axis, 2 corner)."""
        di, dj = self.shifts[k]
        return int(di != 0) + int(dj != 0)

    def perm_rows(self, k: int) -> tuple[tuple[int, int], ...]:
        """ppermute (src, dst) pairs over the ``rows`` axis for shift k."""
        di = self.shifts[k][0]
        gr = self.grid[0]
        return tuple((i, i - di) for i in range(gr) if 0 <= i - di < gr)

    def perm_cols(self, k: int) -> tuple[tuple[int, int], ...]:
        """ppermute (src, dst) pairs over the ``cols`` axis for shift k."""
        dj = self.shifts[k][1]
        gc = self.grid[1]
        return tuple((j, j - dj) for j in range(gc) if 0 <= j - dj < gc)

    @property
    def n_launches(self) -> int:
        """Total ppermute launches per exchange (corners count twice)."""
        return sum(self.hops(k) for k in range(len(self.shifts)))

    def dim_bytes_per_shard(self, itemsize: int = 8) -> tuple[int, int]:
        """(rows_bytes, cols_bytes) each shard moves per exchange.

        A corner shift traverses both dimensions, so its width counts in
        both entries; the sum of the two equals
        :meth:`collective_bytes_per_shard`.
        """
        rows_b = sum(
            w * itemsize for (di, _), w in zip(self.shifts, self.widths) if di
        )
        cols_b = sum(
            w * itemsize for (_, dj), w in zip(self.shifts, self.widths) if dj
        )
        return rows_b, cols_b

    def collective_bytes_per_shard(self, itemsize: int = 8) -> int:
        """Bytes each shard moves per exchange (hop-weighted: a corner
        buffer crosses two links)."""
        return sum(
            self.hops(k) * w * itemsize for k, w in enumerate(self.widths)
        )


# ---------------------------------------------------------------------------
# Interior storage blocks (format-polymorphic) + the distributed matrix
# ---------------------------------------------------------------------------


def _register(cls, data_fields, meta_fields):
    return partial(
        jax.tree_util.register_dataclass,
        data_fields=data_fields,
        meta_fields=meta_fields,
    )(cls)


def _size(a) -> int:
    """Element count from the static shape (works for ShapeDtypeStruct)."""
    return int(np.prod(a.shape, dtype=np.int64))


FORMATS = ("ell", "hyb", "bcsr")


@partial(_register, data_fields=("data", "col"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class ELLBlock:
    """Padded-ELL interior: (S, R, k) slots/row, padding data == 0, col == 0.

    The historical (and stencil-optimal) layout: every row gets
    ``k = max_row_nnz`` slots, so one long row inflates the storage of every
    row on every shard — exactly the blowup HYB exists to avoid.
    """

    data: jax.Array  # (S, R, k)
    col: jax.Array  # (S, R, k) int32, indexes x_own

    fmt = "ell"

    @property
    def slots(self) -> int:
        """Stored value slots, padding included."""
        return _size(self.data)

    @property
    def index_bytes(self) -> int:
        return _size(self.col) * 4

    @property
    def k(self) -> int:
        return self.data.shape[-1]


@partial(
    _register,
    data_fields=("data", "col", "tail_data", "tail_col", "tail_row"),
    meta_fields=("n_tail",),
)
@dataclasses.dataclass(frozen=True)
class HYBBlock:
    """Hybrid interior: dense ELL prefix + COO tail for the long rows.

    The first ``k_typ`` entries of every row live in the (S, R, k_typ) ELL
    part; the overflow of the few rows longer than ``k_typ`` lives in a
    (S, T) COO tail applied by scatter-add. ``k_typ`` is chosen by the
    stored-bytes cost model (``roofline/format_model.hyb_split``), which is
    what eliminates the ``k = max_row_nnz`` padding blowup on power-law
    matrices. Padding: data == 0, col == 0, tail_row == 0 (exact-zero adds).
    """

    data: jax.Array  # (S, R, k_typ)
    col: jax.Array  # (S, R, k_typ) int32
    tail_data: jax.Array  # (S, T)
    tail_col: jax.Array  # (S, T) int32, indexes x_own
    tail_row: jax.Array  # (S, T) int32, local destination row
    n_tail: tuple[int, ...] = ()  # genuine tail entries per shard (host meta)

    fmt = "hyb"

    @property
    def slots(self) -> int:
        return _size(self.data) + _size(self.tail_data)

    @property
    def index_bytes(self) -> int:
        # ELL part: one col id per slot; tail: col + destination row.
        return _size(self.col) * 4 + _size(self.tail_data) * 8

    @property
    def k_typ(self) -> int:
        return self.data.shape[-1]


@partial(
    _register,
    data_fields=("blocks", "bcol"),
    meta_fields=("n_brows", "bpr", "br", "bc"),
)
@dataclasses.dataclass(frozen=True)
class BCSRBlock:
    """Blocked interior: dense (br, bc) tiles in the Pallas kernel's uniform
    blocks-per-row layout (``core.sparse.pack_bcsr``).

    One block-column id per *block* instead of per entry — the index-traffic
    win on banded/FEM matrices — at the price of storing zero fill inside
    partially-populated tiles. The SpMV routes through the kernel dispatch
    op ``bcsr_spmv`` (kernels/dispatch.py), running the Pallas block kernel
    inside shard_map on TPU/interpret backends.
    """

    blocks: jax.Array  # (S, n_brows * bpr, br, bc)
    bcol: jax.Array  # (S, n_brows * bpr) int32, block-column ids
    n_brows: int
    bpr: int
    br: int
    bc: int

    fmt = "bcsr"

    @property
    def slots(self) -> int:
        return _size(self.blocks)

    @property
    def index_bytes(self) -> int:
        return _size(self.bcol) * 4


InteriorBlock = ELLBlock | HYBBlock | BCSRBlock


@partial(
    _register,
    data_fields=(
        "interior",
        "data_ext",
        "col_ext",
        "bnd_rows",
        "send_sel",
        "ghost_data",
        "ghost_col",
        "ghost_pos",
    ),
    meta_fields=("plan", "n_global", "row_starts", "n_bnd", "halo_depth"),
)
@dataclasses.dataclass(frozen=True)
class DistMat:
    """Block-row-distributed sparse matrix: format-polymorphic interior +
    format-agnostic compact boundary block.

    All arrays carry a leading ``n_shards`` axis (sharded over the solver
    mesh's ``shards`` axis outside shard_map; squeezed to the local block
    inside).

    * ``interior``          — the per-shard **interior block** (entries whose
      column is owned by the same shard, indexing ``x_own`` of length
      R = n_own_pad; no communication needed), stored as one of
      :class:`ELLBlock` / :class:`HYBBlock` / :class:`BCSRBlock` — chosen
      per matrix by the ``fmt`` argument of the builders, or by the
      stored-bytes cost model under ``fmt="auto"``
      (``roofline/format_model.py``).
    * ``data_ext/col_ext``  — (S, B, k_ext): the **boundary block** — the
      external (ghost-column) entries of the B = n_boundary ghost-touching
      rows only, compacted at partition time; ``col_ext`` indexes ``x_ext``
      (see HaloPlan). Row ``j`` of the block belongs to local row
      ``bnd_rows[:, j]``. Always ELL — it is tiny and format choice only
      concerns the interior.
    * ``bnd_rows``          — (S, B) int32: local row id of each boundary-block
      row; slots past ``n_bnd[s]`` are padding (index 0, zero data — a
      scatter-add of exact zeros).
    * ``n_bnd``             — per-shard count of genuine boundary rows (host
      metadata; the device path never needs it, ``expand_boundary`` does).
    * ``send_sel``          — (S, sum(widths)) int32: per shift k, the slice
      ``send_sel[:, off_k : off_k + widths[k]]`` lists the local indices each
      shard sends for that shift.
    * ``ghost_data/ghost_col/ghost_pos`` — the **ghost-row block** carried
      only by deep-halo partitions (``halo_depth > 1``): the sparse rows of
      the depth ``< halo_depth`` ghost columns, replicated onto the shard so
      ``core/spmv.matrix_powers`` can redundantly recompute the halo region
      between chained SpMV applications instead of re-exchanging.
      ``ghost_data/ghost_col`` are (S, G, kg) padded-ELL rows whose column
      ids index ``x_ext``; ``ghost_pos`` (S, G) is each ghost row's own
      position inside ``x_ext`` (the halo slot its recomputed value scatters
      back into). Padding rows carry ``ghost_pos == ext_len`` (an
      out-of-range scatter, dropped on device). Depth-1 matrices carry
      0-sized ghost arrays.
    * ``halo_depth``        — ghost-zone depth ``k``: one widened exchange
      delivers the transitive closure of the boundary coupling to depth k,
      enough to chain k SpMV applications locally.
    Padding: data == 0, col == 0 everywhere (gathers stay in bounds and
    contribute nothing).
    """

    interior: InteriorBlock
    data_ext: jax.Array
    col_ext: jax.Array
    bnd_rows: jax.Array
    send_sel: jax.Array
    plan: HaloPlan | GridPlan
    n_global: int
    row_starts: tuple[int, ...]
    n_bnd: tuple[int, ...] = ()
    ghost_data: jax.Array | None = None
    ghost_col: jax.Array | None = None
    ghost_pos: jax.Array | None = None
    halo_depth: int = 1

    @property
    def fmt(self) -> str:
        """Interior storage format: 'ell' | 'hyb' | 'bcsr'."""
        return self.interior.fmt

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    @property
    def n_own_pad(self) -> int:
        return self.plan.n_own_pad

    @property
    def n_boundary(self) -> int:
        """Padded boundary-block rows per shard (B)."""
        return self.bnd_rows.shape[-1]

    @property
    def n_ghost_rows(self) -> int:
        """Padded ghost-row-block rows per shard (G; 0 unless deep halo)."""
        return 0 if self.ghost_pos is None else self.ghost_pos.shape[-1]

    @property
    def ghost_slots(self) -> int:
        """Stored ghost-row value slots (padding included, all shards)."""
        return 0 if self.ghost_data is None else _size(self.ghost_data)

    @property
    def dtype(self):
        return (
            self.interior.blocks.dtype
            if isinstance(self.interior, BCSRBlock)
            else self.interior.data.dtype
        )

    # -- ELL back-compat views ----------------------------------------------

    @property
    def data_loc(self) -> jax.Array:
        """(S, R, k) interior values — ELL-format matrices only."""
        if not isinstance(self.interior, ELLBlock):
            raise AttributeError(
                f"data_loc is an ELL view; this DistMat stores its interior "
                f"as {self.fmt!r} (use mat.interior)"
            )
        return self.interior.data

    @property
    def col_loc(self) -> jax.Array:
        """(S, R, k) interior column ids — ELL-format matrices only."""
        if not isinstance(self.interior, ELLBlock):
            raise AttributeError(
                f"col_loc is an ELL view; this DistMat stores its interior "
                f"as {self.fmt!r} (use mat.interior)"
            )
        return self.interior.col

    # -- storage accounting ---------------------------------------------------

    @property
    def nnz_stored(self) -> int:
        """Stored value slots (incl. format padding) across all shards."""
        return self.interior.slots + _size(self.data_ext)

    def interior_stored_bytes(self, value_bytes: int = 8) -> int:
        """Interior bytes resident in HBM (values + indices, all shards)."""
        return self.interior.slots * value_bytes + self.interior.index_bytes

    def stored_bytes(self, value_bytes: int = 8) -> int:
        """Whole-matrix resident bytes: interior + boundary block + (deep
        halos only) the replicated ghost-row block."""
        return (
            self.interior_stored_bytes(value_bytes)
            + _size(self.data_ext) * (value_bytes + 4)
            + self.ghost_slots * (value_bytes + 4)
        )

    def spmv_flops(self) -> int:
        """2*nnz useful flops (upper bound incl. format padding slots)."""
        return 2 * self.nnz_stored


def DistELL(
    *,
    data_loc,
    col_loc,
    data_ext,
    col_ext,
    bnd_rows,
    send_sel,
    plan,
    n_global,
    row_starts,
    n_bnd=(),
) -> DistMat:
    """Back-compat constructor for the pre-refactor flat ELL layout: builds
    a :class:`DistMat` whose interior is an :class:`ELLBlock`."""
    return DistMat(
        interior=ELLBlock(data=data_loc, col=col_loc),
        data_ext=data_ext,
        col_ext=col_ext,
        bnd_rows=bnd_rows,
        send_sel=send_sel,
        plan=plan,
        n_global=n_global,
        row_starts=row_starts,
        n_bnd=n_bnd,
    )


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def _pad2(a: np.ndarray, rows: int, k: int, dtype) -> np.ndarray:
    out = np.zeros((rows, k), dtype=dtype)
    if a.size:
        out[: a.shape[0], : a.shape[1]] = a
    return out


def _rows_to_ell(rows_entries, n_rows: int, k: int, dtype):
    """rows_entries: list over rows of (cols int64 array, vals array)."""
    data = np.zeros((n_rows, k), dtype=dtype)
    col = np.zeros((n_rows, k), dtype=np.int32)
    for i, (c, v) in enumerate(rows_entries):
        m = len(c)
        if m:
            data[i, :m] = v
            col[i, :m] = c
    return data, col


# ---------------------------------------------------------------------------
# Interior packers: per-shard row lists -> one InteriorBlock
# ---------------------------------------------------------------------------


def _pack_interior_ell(shard_rows, R: int, dtype) -> ELLBlock:
    k = max(
        (len(c) for rows in shard_rows for c, _ in rows), default=0
    )
    k = max(k, 1)
    S = len(shard_rows)
    data = np.zeros((S, R, k), dtype)
    col = np.zeros((S, R, k), np.int32)
    for s, rows in enumerate(shard_rows):
        data[s], col[s] = _rows_to_ell(rows, R, k, dtype)
    return ELLBlock(data=jnp.asarray(data), col=jnp.asarray(col))


def _pack_interior_hyb(shard_rows, R: int, dtype, k_typ: int | None = None) -> HYBBlock:
    from repro.roofline.format_model import hyb_split

    lens = np.asarray(
        [len(c) for rows in shard_rows for c, _ in rows], np.int64
    )
    if k_typ is None:
        k_typ, _ = hyb_split(lens, n_rows=R * len(shard_rows))
    k_typ = max(int(k_typ), 1)
    S = len(shard_rows)
    tails = []
    for rows in shard_rows:
        td, tc, trw = [], [], []
        for r, (c, v) in enumerate(rows):
            if len(c) > k_typ:
                td.append(np.asarray(v[k_typ:], dtype))
                tc.append(np.asarray(c[k_typ:], np.int64))
                trw.append(np.full(len(c) - k_typ, r, np.int64))
        if td:
            tails.append(
                (np.concatenate(td), np.concatenate(tc), np.concatenate(trw))
            )
        else:
            tails.append(
                (np.zeros(0, dtype), np.zeros(0, np.int64), np.zeros(0, np.int64))
            )
    n_tail = tuple(len(t[0]) for t in tails)
    T = max(max(n_tail), 1)
    data = np.zeros((S, R, k_typ), dtype)
    col = np.zeros((S, R, k_typ), np.int32)
    tail_data = np.zeros((S, T), dtype)
    tail_col = np.zeros((S, T), np.int32)
    tail_row = np.zeros((S, T), np.int32)
    for s, rows in enumerate(shard_rows):
        prefix = [(c[:k_typ], v[:k_typ]) for c, v in rows]
        data[s], col[s] = _rows_to_ell(prefix, R, k_typ, dtype)
        td, tc, trw = tails[s]
        tail_data[s, : len(td)] = td
        tail_col[s, : len(td)] = tc.astype(np.int32)
        tail_row[s, : len(td)] = trw.astype(np.int32)
    return HYBBlock(
        data=jnp.asarray(data),
        col=jnp.asarray(col),
        tail_data=jnp.asarray(tail_data),
        tail_col=jnp.asarray(tail_col),
        tail_row=jnp.asarray(tail_row),
        n_tail=n_tail,
    )


def _shard_rows_to_scipy(rows, R: int):
    import scipy.sparse as sp

    if rows:
        cols = np.concatenate([np.asarray(c, np.int64) for c, _ in rows])
        vals = np.concatenate([np.asarray(v, np.float64) for _, v in rows])
    else:
        cols, vals = np.zeros(0, np.int64), np.zeros(0)
    rids = np.repeat(
        np.arange(len(rows), dtype=np.int64), [len(c) for c, _ in rows]
    )
    return sp.coo_matrix((vals, (rids, cols)), shape=(R, R)).tocsr()


def _pack_interior_bcsr(shard_rows, R: int, dtype, br: int, bc: int) -> BCSRBlock:
    from repro.core.sparse import pack_bcsr

    packed = [
        pack_bcsr(_shard_rows_to_scipy(rows, R), br, bc, dtype)
        for rows in shard_rows
    ]
    n_brows = packed[0][2]
    bpr = max(p[3] for p in packed)
    S = len(shard_rows)
    blocks = np.zeros((S, n_brows * bpr, br, bc), dtype)
    bcol = np.zeros((S, n_brows * bpr), np.int32)
    for s, (bl, bcl, nbr, bpr_s, _) in enumerate(packed):
        # re-layout from the shard's own bpr_s to the fleet-wide bpr
        blocks[s].reshape(n_brows, bpr, br, bc)[:, :bpr_s] = bl.reshape(
            nbr, bpr_s, br, bc
        )
        bcol[s].reshape(n_brows, bpr)[:, :bpr_s] = bcl.reshape(nbr, bpr_s)
    return BCSRBlock(
        blocks=jnp.asarray(blocks),
        bcol=jnp.asarray(bcol),
        n_brows=n_brows,
        bpr=bpr,
        br=br,
        bc=bc,
    )


def pack_interior(
    fmt: str, shard_rows, R: int, *, dtype=np.float64, block=(4, 4)
) -> InteriorBlock:
    """Pack per-shard interior row lists into one :class:`InteriorBlock`.

    ``shard_rows``: per shard, a list over local rows of ``(cols, vals)``
    with locally-shifted int column ids. ``fmt`` is one of :data:`FORMATS`
    or ``"auto"``, which resolves the format minimizing the stored-bytes /
    traffic cost model (``roofline/format_model.choose_format``) — never
    costlier than ELL by construction, since ELL is always a candidate.
    """
    if fmt == "auto":
        from repro.roofline.format_model import choose_format

        fmt, _ = choose_format(
            [[len(c) for c, _ in rows] for rows in shard_rows],
            n_rows=R,
            shard_blocks=[
                _shard_block_stats(rows, R, block[0], block[1])
                for rows in shard_rows
            ],
            br=block[0],
            bc=block[1],
        )
    if fmt == "ell":
        return _pack_interior_ell(shard_rows, R, dtype)
    if fmt == "hyb":
        return _pack_interior_hyb(shard_rows, R, dtype)
    if fmt == "bcsr":
        return _pack_interior_bcsr(shard_rows, R, dtype, block[0], block[1])
    raise ValueError(f"unknown interior format {fmt!r}; want {FORMATS} or 'auto'")


def block_stats_from_arrays(
    r_loc: np.ndarray, c_loc: np.ndarray, R: int, br: int, bc: int
) -> tuple[int, int]:
    """(n_blocks, max_blocks_per_block_row) of one shard's interior, from
    flat local (row, col) index arrays.

    Single source of the BCSR block-counting formula — the packer/auto
    selector (via :func:`_shard_block_stats`) and the autotune pricing
    model (``autotune/prune.interior_stats``) must count the same tiles.
    """
    n_bcols = -(-R // bc)
    if not len(c_loc):
        return 0, 0
    keys = np.unique(
        (np.asarray(r_loc, np.int64) // br) * n_bcols
        + np.asarray(c_loc, np.int64) // bc
    )
    counts = np.bincount(keys // n_bcols)
    return len(keys), int(counts.max())


def _shard_block_stats(rows, R: int, br: int, bc: int) -> tuple[int, int]:
    """(n_blocks, max_blocks_per_block_row) of one shard's interior."""
    rids = np.repeat(
        np.arange(len(rows), dtype=np.int64), [len(c) for c, _ in rows]
    )
    cols = (
        np.concatenate([np.asarray(c, np.int64) for c, _ in rows])
        if rows
        else np.zeros(0, np.int64)
    )
    return block_stats_from_arrays(rids, cols, R, br, bc)


def _csr_rows_cols(indptr, indices, rows: np.ndarray) -> np.ndarray:
    """All column ids referenced by CSR ``rows`` (flat, duplicates kept)."""
    rows = np.asarray(rows, np.int64)
    starts = indptr[rows].astype(np.int64)
    lens = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
    tot = int(lens.sum())
    if not tot:
        return np.zeros(0, np.int64)
    idx = np.repeat(starts - (np.cumsum(lens) - lens), lens) + np.arange(tot)
    return indices[idx]


def partition_csr(
    a_csr,
    n_shards: int,
    *,
    max_ring: int = 3,
    partition: RowPartition | None = None,
    dtype=np.float64,
    force_allgather: bool = False,
    fmt: str = "ell",
    block: tuple[int, int] = (4, 4),
    grid: tuple[int, int] | None = None,
    halo_depth: int = 1,
) -> DistMat:
    """Partition a host scipy CSR matrix into a DistMat.

    Chooses ring mode iff every off-shard coupling reaches at most
    ``max_ring`` shards away; otherwise falls back to allgather mode.
    ``force_allgather=True`` always uses allgather mode — this is the
    Ginkgo-analog baseline layout (full-vector gather, no halo
    minimization).

    ``fmt`` selects the interior storage format — one of :data:`FORMATS`
    (``ell``/``hyb``/``bcsr``) or ``"auto"`` (stored-bytes cost model, see
    ``roofline/format_model.py``); ``block`` is the BCSR tile shape. The
    boundary block and halo plan are format-agnostic.

    ``grid=(R, C)`` (with ``R * C == n_shards``) plans the halo exchange
    for a 2-D process grid instead: neighbor deltas become per-dimension
    ``(di, dj)`` shifts executed as chained sub-axis ppermutes
    (:class:`GridPlan`; ring-mode criterion applies per dimension). Rows
    remain contiguously block-partitioned over the flat shard order, so
    the vector layout — and, for ``grid=(1, N)``, the entire DistMat — is
    identical to the 1-D build. Pair with :func:`pencil_partition` to make
    the per-shard halo scale with the pencil surface.

    ``halo_depth=k`` builds k-deep ghost zones: the ghost-column set is the
    transitive closure of the boundary coupling to depth k (depth-(d+1)
    ghosts are the off-shard columns referenced by the depth-d ghost
    *rows*), so ONE widened exchange feeds k chained SpMV applications
    (``core/spmv.matrix_powers``). The matrix rows of the depth ``< k``
    ghosts are replicated into the ghost-row block for the redundant
    recompute. The ring criterion scales with depth (``max_ring * k``
    reach) — a matrix whose depth-1 coupling is ring-shaped stays ring at
    any depth. ``halo_depth=1`` is bit-identical to the historical build.
    """
    halo_depth = int(halo_depth)
    if halo_depth < 1:
        raise ValueError(f"halo_depth must be >= 1, got {halo_depth}")
    a = a_csr.tocsr()
    n = a.shape[0]
    part = partition or balanced_partition(n, n_shards)
    R = part.max_own

    if grid is not None:
        gr, gc = int(grid[0]), int(grid[1])
        if gr * gc != n_shards:
            raise ValueError(
                f"grid {gr}x{gc} does not cover n_shards={n_shards}"
            )
        if gr == 1:
            grid = None  # 1 x N *is* the 1-D layout; build it identically

    indptr, indices, vals = a.indptr, a.indices.astype(np.int64), a.data

    # --- pass 1: discover shifts + per-(shard,shift) needed columns --------
    # halo_depth > 1 widens the per-shard ghost set to the transitive
    # closure of the boundary coupling: depth-(d+1) ghosts are the
    # off-shard columns referenced by the depth-d ghost *rows*. All depths
    # merge into one sorted column set, so the existing recv/send planning
    # below widens without change (depth 1 reduces to the historical
    # np.unique of the boundary columns, bit for bit).
    owners_cache = {}
    depth_cache = {}  # s -> per-ghost-column depth, aligned with ext_cols
    shifts_seen: set = set()  # int deltas (1-D) or (di, dj) tuples (grid)
    for s in range(n_shards):
        lo, hi = part.owner_range(s)
        cols = indices[indptr[lo] : indptr[hi]]
        own_mask = (cols >= lo) & (cols < hi)
        frontier = np.unique(cols[~own_mask])
        ghost_cols = [frontier]
        ghost_depths = [np.full(len(frontier), 1, np.int64)]
        for depth in range(2, halo_depth + 1):
            if not len(frontier):
                break
            ref = np.unique(_csr_rows_cols(indptr, indices, frontier))
            ref = ref[(ref < lo) | (ref >= hi)]  # off-shard columns only
            frontier = np.setdiff1d(
                ref, np.concatenate(ghost_cols), assume_unique=True
            )
            ghost_cols.append(frontier)
            ghost_depths.append(np.full(len(frontier), depth, np.int64))
        merged = np.concatenate(ghost_cols)
        order = np.argsort(merged)
        ext_cols = merged[order]
        owners = part.owner_of(ext_cols)
        owners_cache[s] = (ext_cols, owners)
        depth_cache[s] = np.concatenate(ghost_depths)[order]
        if grid is not None:
            di = owners // gc - s // gc
            dj = owners % gc - s % gc
            shifts_seen.update(zip(di.tolist(), dj.tolist()))
        else:
            for d in np.unique(owners - s):
                shifts_seen.add(int(d))

    reach = max_ring * halo_depth
    if grid is not None:
        near = all(max(abs(di), abs(dj)) <= reach for di, dj in shifts_seen)
        mode = "grid" if near else "allgather"
    else:
        mode = (
            "ring" if all(abs(d) <= reach for d in shifts_seen) else "allgather"
        )
    if force_allgather:
        mode = "allgather"
    if grid is not None:
        shifts = tuple(
            sorted(shifts_seen, key=lambda t: (max(abs(t[0]), abs(t[1])), t))
        )
    else:
        shifts = tuple(sorted(shifts_seen, key=lambda d: (abs(d), d)))

    if mode == "ring":
        # recv_lists[k][i]: sorted global cols shard i receives from i+shifts[k]
        recv_lists = [[np.zeros(0, np.int64) for _ in range(n_shards)] for _ in shifts]
        for s in range(n_shards):
            ext_cols, owners = owners_cache[s]
            for k, d in enumerate(shifts):
                sel = owners == s + d
                recv_lists[k][s] = ext_cols[sel]
        widths = tuple(
            max((len(recv_lists[k][i]) for i in range(n_shards)), default=0)
            for k in range(len(shifts))
        )
        plan = HaloPlan("ring", shifts, widths, R, n_shards)

        # send_sel[j]: for shift k, shard j sends x_own[sel] to j - shifts[k];
        # the receiver (j - d) needs recv_lists[k][j - d] (cols owned by j).
        W = sum(widths)
        send_sel = np.zeros((n_shards, max(W, 1)), np.int32)
        for j in range(n_shards):
            off = 0
            jlo, _ = part.owner_range(j)
            for k, d in enumerate(shifts):
                i = j - d  # receiver
                if 0 <= i < n_shards:
                    g = recv_lists[k][i]
                    send_sel[j, off : off + len(g)] = (g - jlo).astype(np.int32)
                off += widths[k]
    elif mode == "grid":
        # Same recv-list construction, with (di, dj) grid deltas: shard
        # (i, j) receives recv_lists[k][s] from shard (i+di, j+dj).
        recv_lists = [[np.zeros(0, np.int64) for _ in range(n_shards)] for _ in shifts]
        for s in range(n_shards):
            ext_cols, owners = owners_cache[s]
            di = owners // gc - s // gc
            dj = owners % gc - s % gc
            for k, (ki, kj) in enumerate(shifts):
                sel = (di == ki) & (dj == kj)
                recv_lists[k][s] = ext_cols[sel]
        widths = tuple(
            max((len(recv_lists[k][i]) for i in range(n_shards)), default=0)
            for k in range(len(shifts))
        )
        plan = GridPlan("grid", (gr, gc), shifts, widths, R, n_shards)

        # Sender (ji, jj) serves the receiver at (ji - di, jj - dj); the
        # chained per-dimension ppermutes deliver the buffer unchanged, so
        # the sender packs it in the receiver's recv-list order.
        W = sum(widths)
        send_sel = np.zeros((n_shards, max(W, 1)), np.int32)
        for j in range(n_shards):
            off = 0
            jlo, _ = part.owner_range(j)
            ji, jj = divmod(j, gc)
            for k, (ki, kj) in enumerate(shifts):
                ri, rj = ji - ki, jj - kj  # receiver grid position
                if 0 <= ri < gr and 0 <= rj < gc:
                    g = recv_lists[k][ri * gc + rj]
                    send_sel[j, off : off + len(g)] = (g - jlo).astype(np.int32)
                off += widths[k]
    else:
        plan = HaloPlan("allgather", (), (), R, n_shards)
        send_sel = np.zeros((n_shards, 1), np.int32)
        recv_lists = None

    # --- pass 2: build the split interior/boundary blocks -------------------
    k_ext_max = 1
    per_shard = []
    ghost_lists = []  # per shard: (x_ext col ids, vals, own x_ext pos) rows
    for s in range(n_shards):
        lo, hi = part.owner_range(s)
        loc_rows, ext_rows = [], []
        # Map global ext col -> x_ext position for this shard.
        if mode != "allgather":
            ext_map = {}
            for k in range(len(shifts)):
                base = plan.buf_offset(k)
                for p, g in enumerate(recv_lists[k][s]):
                    ext_map[int(g)] = base + p
        # Ghost-row block: replicate the rows of the depth < halo_depth
        # ghosts, with columns remapped into this shard's x_ext space (own
        # columns land in [0, n_own), closure guarantees every off-shard
        # column is in ext_map).
        ghost_rows_s = []
        if halo_depth > 1 and mode != "allgather":
            deep = owners_cache[s][0][depth_cache[s] < halo_depth]
            for g in deep:
                g = int(g)
                gcols = indices[indptr[g] : indptr[g + 1]]
                gvals = vals[indptr[g] : indptr[g + 1]]
                lidx = np.fromiter(
                    (
                        int(c) - lo if lo <= c < hi else ext_map[int(c)]
                        for c in gcols
                    ),
                    dtype=np.int64,
                    count=len(gcols),
                )
                ghost_rows_s.append((lidx, gvals, ext_map[g]))
        ghost_lists.append(ghost_rows_s)
        for r in range(lo, hi):
            cs = indices[indptr[r] : indptr[r + 1]]
            vs = vals[indptr[r] : indptr[r + 1]]
            own = (cs >= lo) & (cs < hi)
            loc_rows.append(((cs[own] - lo).astype(np.int64), vs[own]))
            ec, ev = cs[~own], vs[~own]
            if mode != "allgather":
                lidx = np.fromiter(
                    (ext_map[int(g)] for g in ec), dtype=np.int64, count=len(ec)
                )
            else:
                # padded global layout: owner * R + (g - owner_start)
                owners = part.owner_of(ec)
                starts = np.asarray(part.row_starts, np.int64)[owners]
                lidx = owners * R + (ec - starts)
            ext_rows.append((lidx, ev))
            k_ext_max = max(k_ext_max, len(ec))
        per_shard.append((loc_rows, ext_rows))

    S = n_shards
    interior = pack_interior(
        fmt, [loc_rows for loc_rows, _ in per_shard], R, dtype=dtype,
        block=block,
    )
    # Interior/boundary row split: boundary rows are the rows with at least
    # one external (ghost-column) entry; only they get boundary-block slots.
    bnd_lists = [
        [r for r, (_, ev) in enumerate(ext_rows) if len(ev)]
        for _, ext_rows in per_shard
    ]
    n_bnd = tuple(len(b) for b in bnd_lists)
    B = max(max(n_bnd), 1)
    data_ext = np.zeros((S, B, k_ext_max), dtype)
    col_ext = np.zeros((S, B, k_ext_max), np.int32)
    bnd_rows = np.zeros((S, B), np.int32)
    for s, (_, ext_rows) in enumerate(per_shard):
        bnd = bnd_lists[s]
        de, ce = _rows_to_ell([ext_rows[r] for r in bnd], B, k_ext_max, dtype)
        data_ext[s], col_ext[s] = de, ce
        bnd_rows[s, : len(bnd)] = bnd

    # Pack the ghost-row block (0-sized at depth 1 / allgather). Padding
    # rows scatter to position ext_len — out of range, dropped on device.
    eff_depth = halo_depth if mode != "allgather" else 1
    G = max((len(gr) for gr in ghost_lists), default=0)
    kg = max((len(c) for gr in ghost_lists for c, _, _ in gr), default=0)
    kg = max(kg, 1) if G else 1
    ghost_data = np.zeros((S, G, kg), dtype)
    ghost_col = np.zeros((S, G, kg), np.int32)
    ghost_pos = np.full((S, G), plan.ext_len, np.int32)
    for s, gr in enumerate(ghost_lists):
        for j, (c, v, pos) in enumerate(gr):
            m = len(c)
            ghost_data[s, j, :m] = v
            ghost_col[s, j, :m] = c.astype(np.int32)
            ghost_pos[s, j] = pos

    return DistMat(
        interior=interior,
        data_ext=jnp.asarray(data_ext),
        col_ext=jnp.asarray(col_ext),
        bnd_rows=jnp.asarray(bnd_rows),
        send_sel=jnp.asarray(send_sel),
        plan=plan,
        n_global=n,
        row_starts=part.row_starts,
        n_bnd=n_bnd,
        ghost_data=jnp.asarray(ghost_data),
        ghost_col=jnp.asarray(ghost_col),
        ghost_pos=jnp.asarray(ghost_pos),
        halo_depth=eff_depth,
    )


def partition_stencil(
    p, n_shards: int, dtype=np.float64, mode: str = "ring",
    fmt: str = "ell", block: tuple[int, int] = (4, 4),
) -> DistMat:
    """Build a DistMat for a Poisson stencil problem WITHOUT materializing the
    global matrix: per-shard cost is O(n_local * k).

    Slab (z-plane) partition; both stencils reach exactly +-1 plane, so the
    halo plan is always ring mode with shifts (-1, +1) and width = nx*ny
    (except at single-shard, where there is no exchange).

    ``mode="allgather"`` builds the Ginkgo-analog layout instead (external
    columns in padded-global layout; full-vector gather at SpMV time).
    ``fmt`` selects the interior format as in :func:`partition_csr`; stencil
    rows are uniform-width, so ``"auto"`` resolves to ELL and the other
    formats exist for A/B measurements only.
    """
    from repro.matrices.poisson import stencil_offsets, stencil_values

    part = plane_partition(p.n, p.plane, n_shards)
    R = part.max_own
    H = p.plane
    offs = stencil_offsets(p.stencil)
    k = len(offs)
    svals = stencil_values(p)
    # Entries per row reaching planes z-1 / z / z+1.
    off_dz = offs[:, 2]
    k_ext = max(int((off_dz == -1).sum()), int((off_dz == 1).sum()))

    if n_shards > 1 and mode == "ring":
        shifts, widths = (-1, 1), (H, H)
    else:
        shifts, widths = (), ()
    plan = HaloPlan(mode if n_shards > 1 else "ring", shifts, widths, R, n_shards)

    S = n_shards
    data_loc = np.zeros((S, R, k), dtype)
    col_loc = np.zeros((S, R, k), np.int32)
    # Boundary rows live in the slab's first/last z-plane only: at most 2H
    # ghost-touching rows per shard (H for the edge shards / S == 2).
    B_ub = min(2 * H, R) if S > 1 else 1
    data_ext = np.zeros((S, B_ub, max(k_ext, 1)), dtype)
    col_ext = np.zeros((S, B_ub, max(k_ext, 1)), np.int32)
    bnd_rows = np.zeros((S, B_ub), np.int32)
    n_bnd = [0] * S
    W = sum(widths)
    send_sel = np.zeros((S, max(W, 1)), np.int32)

    for s in range(S):
        lo, hi = part.owner_range(s)
        z0, z1 = lo // H, hi // H
        n_own = hi - lo
        zz, yy, xx = np.meshgrid(
            np.arange(z0, z1), np.arange(p.ny), np.arange(p.nx), indexing="ij"
        )
        coords = np.stack([xx.ravel(), yy.ravel(), zz.ravel()], axis=1)
        nbr = coords[:, None, :] + offs[None, :, :]  # (n_own, k, 3)
        valid = (
            (nbr[..., 0] >= 0)
            & (nbr[..., 0] < p.nx)
            & (nbr[..., 1] >= 0)
            & (nbr[..., 1] < p.ny)
            & (nbr[..., 2] >= 0)
            & (nbr[..., 2] < p.nz)
        )
        gcol = nbr[..., 0] + p.nx * (nbr[..., 1] + p.ny * nbr[..., 2])
        vals = np.broadcast_to(svals[None, :], valid.shape) * valid

        own = valid & (gcol >= lo) & (gcol < hi)
        ext = valid & ~own
        # local part
        dl = np.where(own, vals, 0.0).astype(dtype)
        cl = np.where(own, gcol - lo, 0).astype(np.int32)
        data_loc[s, :n_own], col_loc[s, :n_own] = dl, cl
        # ext part: left plane (z0-1) -> buffer 0; right plane (z1) -> buffer 1
        if S > 1:
            left = ext & (gcol < lo)
            right = ext & (gcol >= hi)
            # position within plane = gcol mod H
            pos = (gcol % H).astype(np.int64)
            if mode == "ring":
                lcol = np.where(left, R + pos, 0) + np.where(right, R + H + pos, 0)
            else:
                gsafe = np.where(ext, gcol, lo)
                owners = part.owner_of(gsafe.ravel()).reshape(gsafe.shape)
                starts = np.asarray(part.row_starts, np.int64)[owners]
                lcol = np.where(ext, owners * R + (gsafe - starts), 0)
            de = np.where(ext, vals, 0.0).astype(dtype)
            # compact ext entries into k_ext slots per row
            order = np.argsort(~ext, axis=1, kind="stable")  # ext first
            de_s = np.take_along_axis(de, order, axis=1)[:, :k_ext]
            ce_s = np.take_along_axis(
                np.where(ext, lcol, 0).astype(np.int32), order, axis=1
            )[:, :k_ext]
            # ...and compact the ghost-touching rows into the boundary block
            bnd = np.nonzero(ext.any(axis=1))[0]
            n_bnd[s] = len(bnd)
            data_ext[s, : len(bnd)] = de_s[bnd]
            col_ext[s, : len(bnd)] = ce_s[bnd]
            bnd_rows[s, : len(bnd)] = bnd.astype(np.int32)
            # send selectors: shift -1 (recv from left): shard j sends its LAST
            # plane to j+1 <=> under perm (j, j-(-1))... define per plan.perm:
            # shift d=-1: receiver i gets from i-1; sender j sends to j+1 its
            # last plane rows [n_own-H, n_own).
            # shift d=+1: sender j sends to j-1 its first plane rows [0, H).
            off = 0
            for kk, d in enumerate(shifts):
                if d == -1:
                    sel = np.arange(n_own - H, n_own, dtype=np.int32)
                else:
                    sel = np.arange(0, H, dtype=np.int32)
                send_sel[s, off : off + H] = sel
                off += widths[kk]

    B = max(max(n_bnd), 1)
    if fmt in ("ell", "auto"):
        interior = ELLBlock(data=jnp.asarray(data_loc), col=jnp.asarray(col_loc))
    else:
        interior = pack_interior(
            fmt, _ell_to_shard_rows(data_loc, col_loc), R, dtype=dtype,
            block=block,
        )
    return DistMat(
        interior=interior,
        data_ext=jnp.asarray(data_ext[:, :B]),
        col_ext=jnp.asarray(col_ext[:, :B]),
        bnd_rows=jnp.asarray(bnd_rows[:, :B]),
        send_sel=jnp.asarray(send_sel),
        plan=plan,
        n_global=p.n,
        row_starts=part.row_starts,
        n_bnd=tuple(n_bnd),
    )


def _ell_to_shard_rows(data: np.ndarray, col: np.ndarray):
    """Recover per-shard (cols, vals) row lists from packed ELL arrays.

    Entries are identified by ``data != 0 or col != 0`` — the repo-wide
    padding convention; a genuine zero-valued entry at column 0 (which no
    stencil produces) would be dropped, hence this is only used to convert
    stencil-built interiors to the alternative formats.
    """
    S, R, _ = data.shape
    out = []
    for s in range(S):
        rows = []
        for r in range(R):
            m = (data[s, r] != 0) | (col[s, r] != 0)
            rows.append((col[s, r][m].astype(np.int64), data[s, r][m]))
        out.append(rows)
    return out


def expand_boundary(mat: DistMat) -> tuple[np.ndarray, np.ndarray]:
    """Full-row ``(S, R, k_ext)`` view of the compact boundary block (host).

    Inverse of the boundary-row compaction: scatter each shard's compact
    ``(B, k_ext)`` ghost-entry rows back to their ``bnd_rows`` positions.
    Tests use this to rebuild the pre-split ("unsplit") SpMV formulation and
    check the interior/boundary split reproduces it bitwise.
    """
    S, R = mat.n_shards, mat.n_own_pad
    de = np.asarray(mat.data_ext)
    ce = np.asarray(mat.col_ext)
    rows = np.asarray(mat.bnd_rows)
    k = de.shape[-1]
    full_d = np.zeros((S, R, k), de.dtype)
    full_c = np.zeros((S, R, k), ce.dtype)
    for s in range(S):
        nb = mat.n_bnd[s] if mat.n_bnd else 0
        full_d[s, rows[s, :nb]] = de[s, :nb]
        full_c[s, rows[s, :nb]] = ce[s, :nb]
    return full_d, full_c


# ---------------------------------------------------------------------------
# Distributed vectors (host <-> device layout helpers)
# ---------------------------------------------------------------------------


def pad_vector(x: np.ndarray, mat: DistMat) -> np.ndarray:
    """Global vector -> (S, R) padded shard layout."""
    S, R = mat.n_shards, mat.n_own_pad
    out = np.zeros((S, R), x.dtype)
    for s in range(S):
        lo, hi = mat.row_starts[s], mat.row_starts[s + 1]
        out[s, : hi - lo] = x[lo:hi]
    return out


def unpad_vector(xp: np.ndarray, mat: DistMat) -> np.ndarray:
    """(S, R) padded shard layout -> global vector."""
    xp = np.asarray(xp)
    parts = []
    for s in range(mat.n_shards):
        lo, hi = mat.row_starts[s], mat.row_starts[s + 1]
        parts.append(xp[s, : hi - lo])
    return np.concatenate(parts)


def pad_block(X: np.ndarray, mat: DistMat) -> np.ndarray:
    """Global (n, r) RHS block -> (S, R, r) padded shard layout."""
    S, R = mat.n_shards, mat.n_own_pad
    out = np.zeros((S, R, X.shape[1]), X.dtype)
    for s in range(S):
        lo, hi = mat.row_starts[s], mat.row_starts[s + 1]
        out[s, : hi - lo] = X[lo:hi]
    return out


def unpad_block(Xp: np.ndarray, mat: DistMat) -> np.ndarray:
    """(S, R, r) padded shard layout -> global (n, r) block."""
    Xp = np.asarray(Xp)
    parts = []
    for s in range(mat.n_shards):
        lo, hi = mat.row_starts[s], mat.row_starts[s + 1]
        parts.append(Xp[s, : hi - lo])
    return np.concatenate(parts)
