"""Distributed dense-vector operations (per-shard view, inside shard_map).

The paper's library provides dot / axpy / norm in a distributed-memory
setting with GPU-side local work; the communication-reduction discipline
(C2) shows up here as **fused reductions**: any group of inner products
needed at the same algorithmic point is packed into a single ``lax.psum``
of a small vector, producing exactly one collective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.energy import trace


def _record_dots(pairs, n_out: int | None = None):
    """Executed-counts entry for a fused local-dots + all-reduce op
    (trace-time only; formulas live in energy/trace.py)."""
    trace.record_op("fused_dots", trace.fused_dots_counts(pairs, n_out))


def pdot(x: jax.Array, y: jax.Array, axis: str) -> jax.Array:
    """Global <x, y> — ONE all-reduce."""
    _record_dots([(x, y)])
    return lax.psum(jnp.vdot(x, y), axis)


def pnorm2(x: jax.Array, axis: str) -> jax.Array:
    """Global ||x||^2 — ONE all-reduce."""
    return pdot(x, x, axis)


def fused_dots(pairs, axis: str) -> jax.Array:
    """Global inner products for a list of (x, y) pairs — ONE all-reduce.

    Returns a (len(pairs),) vector. This is the building block of the
    communication-reduced CG variants: local partial dots are stacked and
    reduced together.
    """
    _record_dots(pairs)
    local = jnp.stack([jnp.vdot(x, y) for x, y in pairs])
    return lax.psum(local, axis)


def fused_blocks(parts, axis: str) -> jax.Array:
    """Fuse arbitrary local reduction blocks into ONE all-reduce.

    ``parts`` is a list of arrays (any shapes); they are flattened,
    concatenated, psum-ed once, and returned as one flat vector — callers
    re-split with known sizes.  Used by s-step CG to reduce the whole Gram
    matrix + moment vector in a single collective.
    """
    flat = jnp.concatenate([p.reshape(-1) for p in parts])
    trace.record_collective(flat.size, flat.dtype.itemsize, op="fused_blocks")
    return lax.psum(flat, axis)


def axpy(alpha, x: jax.Array, y: jax.Array) -> jax.Array:
    """alpha*x + y (local; no communication)."""
    return alpha * x + y
