"""Distributed dense-vector operations (per-shard view, inside shard_map).

The paper's library provides dot / axpy / norm in a distributed-memory
setting with GPU-side local work; the communication-reduction discipline
(C2) shows up here as **fused reductions**: any group of inner products
needed at the same algorithmic point is packed into a single ``lax.psum``
of a small vector, producing exactly one collective.

On a 2-D process grid (``axis`` a tuple of mesh axis names, see
``core/partition.GridPlan``) every reduction routes through
:func:`all_reduce`, which stages the psum hierarchically: intra-row-group
(over the fast ``cols`` axis) first, then inter-group (over ``rows``) —
two shallow trees of depth ``log C`` + ``log R`` instead of one deep tree
of depth ``log (R*C)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.energy import trace

#: Ledger op name of the extra per-stage collectives a hierarchical
#: (tuple-axis) all-reduce launches beyond the single fused one the
#: caller already recorded.
HIER_STAGE_OP = "hier_reduce_stage"


def all_reduce(v: jax.Array, axis) -> jax.Array:
    """Sum ``v`` over the shard axis.

    ``axis`` a string: exactly ``lax.psum(v, axis)`` — the 1-D path, with
    byte-identical traces to the pre-grid code. ``axis`` a tuple of mesh
    axis names (ordered coarse-to-fine, e.g. ``("rows", "cols")``): a
    hierarchical reduction, one psum per sub-axis starting with the
    innermost. Only the *extra* stages are recorded here; the caller's
    existing single-collective record covers the first.
    """
    if isinstance(axis, str):
        return lax.psum(v, axis)
    axes = tuple(axis)
    out = v
    for i, a in enumerate(reversed(axes)):
        out = lax.psum(out, a)
        if i > 0:
            trace.record_collective(
                jnp.asarray(v).size, jnp.asarray(v).dtype.itemsize,
                op=HIER_STAGE_OP,
            )
    return out


def _record_dots(pairs, n_out: int | None = None):
    """Executed-counts entry for a fused local-dots + all-reduce op
    (trace-time only; formulas live in energy/trace.py)."""
    trace.record_op("fused_dots", trace.fused_dots_counts(pairs, n_out))


def pdot(x: jax.Array, y: jax.Array, axis) -> jax.Array:
    """Global <x, y> — ONE all-reduce (one per grid dimension)."""
    _record_dots([(x, y)])
    return all_reduce(jnp.vdot(x, y), axis)


def pnorm2(x: jax.Array, axis) -> jax.Array:
    """Global ||x||^2 — ONE all-reduce."""
    return pdot(x, x, axis)


def fused_dots(pairs, axis) -> jax.Array:
    """Global inner products for a list of (x, y) pairs — ONE all-reduce.

    Returns a (len(pairs),) vector. This is the building block of the
    communication-reduced CG variants: local partial dots are stacked and
    reduced together.
    """
    _record_dots(pairs)
    local = jnp.stack([jnp.vdot(x, y) for x, y in pairs])
    return all_reduce(local, axis)


def fused_blocks(parts, axis) -> jax.Array:
    """Fuse arbitrary local reduction blocks into ONE all-reduce.

    ``parts`` is a list of arrays (any shapes); they are flattened,
    concatenated, psum-ed once, and returned as one flat vector — callers
    re-split with known sizes.  Used by s-step CG to reduce the whole Gram
    matrix + moment vector in a single collective.
    """
    flat = jnp.concatenate([p.reshape(-1) for p in parts])
    trace.record_collective(flat.size, flat.dtype.itemsize, op="fused_blocks")
    return all_reduce(flat, axis)


def axpy(alpha, x: jax.Array, y: jax.Array) -> jax.Array:
    """alpha*x + y (local; no communication)."""
    return alpha * x + y
