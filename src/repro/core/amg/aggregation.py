"""Aggregation from composed pairwise matchings + tentative prolongator.

BootCMatch composes ``k`` matching sweeps per AMG level so aggregates reach
size 2^k (k=3 -> 8, the paper's configuration): match the fine graph, collapse
matched pairs into super-vertices, re-match the collapsed graph, repeat.
Unmatched vertices stay as singletons (so sizes are *up to* 2^k).

The prolongator is the compatible-matching tentative operator: one nonzero
per fine row,

    P[i, agg(i)] = w_i / || w|_{agg(i)} ||_2

(with w = ones this is piecewise-constant normalized columns).

``decoupled_aggregate`` restricts matching to intra-shard edges, which makes
P block-diagonal w.r.t. the row partition — the scale-out discipline the GPU
library uses, and what keeps every AMG level representable as a halo-planned
DistMat.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from repro.core.amg.matching import (
    MATCHERS,
    compatible_weights,
    locally_dominant_matching_np,
    plain_weights,
    weights_to_ell,
)


def match_to_aggregates(match: np.ndarray) -> np.ndarray:
    """match array -> agg id per vertex (pairs share an id; singletons own).

    Ids are compact 0..n_agg-1, ordered by smallest member.
    """
    n = len(match)
    rep = np.minimum(np.arange(n), match)  # pair representative
    uniq, agg = np.unique(rep, return_inverse=True)
    return agg


def compose_matchings(w_csr, sweeps: int, weighting_fn, matcher=locally_dominant_matching_np) -> np.ndarray:
    """Run ``sweeps`` matching rounds with graph collapsing; returns agg ids.

    ``w_csr`` is the level matrix A (weights are derived per round from the
    collapsed matrix via ``weighting_fn``).
    """
    a = w_csr.tocsr()
    n = a.shape[0]
    agg = np.arange(n)  # current aggregate id per original vertex
    cur = a
    for _ in range(sweeps):
        m = cur.shape[0]
        if m <= 1:
            break
        w = weighting_fn(cur)
        if w.nnz == 0:
            break
        wdata, wcol = weights_to_ell(w)
        match = matcher(wdata, wcol)
        sub = match_to_aggregates(match)
        agg = sub[agg]
        # collapse: Q (m x m') boolean aggregation, cur' = Q^T cur Q
        mprime = int(sub.max()) + 1
        q = sp.csr_matrix(
            (np.ones(m), (np.arange(m), sub)), shape=(m, mprime)
        )
        cur = (q.T @ cur @ q).tocsr()
    return agg


def tentative_prolongator(agg: np.ndarray, w: np.ndarray | None = None) -> sp.csr_matrix:
    """P (n x n_agg): P[i, agg[i]] = w_i / ||w|_agg||."""
    n = len(agg)
    w = np.ones(n) if w is None else np.asarray(w, np.float64)
    n_agg = int(agg.max()) + 1 if n else 0
    norm2 = np.zeros(n_agg)
    np.add.at(norm2, agg, w * w)
    vals = w / np.sqrt(norm2[agg])
    return sp.csr_matrix((vals, (np.arange(n), agg)), shape=(n, n_agg))


def decoupled_aggregate(
    a_csr,
    row_starts,
    *,
    sweeps: int = 3,
    weighting: str = "compatible",
    smooth_vec: np.ndarray | None = None,
    matcher: str = "locdom",
):
    """Per-shard (decoupled) aggregation.

    Returns (P global csr — block-diagonal w.r.t. the partition,
             coarse_row_starts tuple).
    """
    a = a_csr.tocsr()
    n = a.shape[0]
    w_fn = compatible_weights if weighting == "compatible" else (
        lambda m: plain_weights(m)
    )
    n_shards = len(row_starts) - 1
    blocks = []
    coarse_starts = [0]
    for s in range(n_shards):
        lo, hi = row_starts[s], row_starts[s + 1]
        a_ss = a[lo:hi, lo:hi].tocsr()
        agg = compose_matchings(a_ss, sweeps, w_fn, MATCHERS[matcher])
        wv = None if smooth_vec is None else smooth_vec[lo:hi]
        p_s = tentative_prolongator(agg, wv)
        blocks.append(p_s)
        coarse_starts.append(coarse_starts[-1] + p_s.shape[1])
    p = sp.block_diag(blocks, format="csr")
    return p, tuple(coarse_starts)
