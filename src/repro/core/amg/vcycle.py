"""Device-side V-cycle (per-shard view, inside shard_map).

Level data layout (see hierarchy.py):

* ``mat``              — A_l as a halo-planned DistMat block (ELL interior);
* ``p_data / p_col``   — the tentative prolongator: ONE nonzero per fine row,
  ``p_col`` is the *local* coarse aggregate id (decoupled aggregation keeps
  it shard-local), so prolongation is a pure local gather;
* ``pt_data / pt_col`` — P^T in ELL over coarse rows (width = max aggregate
  size, 8 in the paper configuration); restriction is a pure local ELL
  matvec;
* ``dinv``             — 1 / l1-Jacobi diagonal of A_l.

The coarsest level is solved with a replicated dense inverse applied to the
all-gathered coarse residual (coarse sizes are a few hundred at most).

Energy accounting: the whole cycle runs inside ``region("vcycle")``
(energy/trace.py) and its vector updates go through the kernel dispatch
OpSet, so every SpMV, smoother sweep, transfer, and the coarse solve record
their executed OpCounts — the "preconditioner" component of the paper's
per-kernel energy profile. The level SpMVs use the overlapped
interior/boundary schedule by default, so their matvec + in-flight halo
attribute to the "overlap" region (innermost marker wins); restriction,
prolongation, smoother scaling, and the coarse solve stay in "vcycle".
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
from jax import lax

from repro.core.partition import DistMat
from repro.core.spmv import ell_matvec, spmv_shard
from repro.energy import trace
from repro.energy.accounting import OpCounts
from repro.kernels import dispatch as kd


def _register(cls, data_fields, meta_fields):
    return partial(
        jax.tree_util.register_dataclass,
        data_fields=data_fields,
        meta_fields=meta_fields,
    )(cls)


@partial(
    _register,
    data_fields=("mat", "p_data", "p_col", "pt_data", "pt_col", "dinv"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class AMGLevel:
    mat: DistMat
    p_data: jax.Array  # (S, Rf) or (Rf,) locally
    p_col: jax.Array  # int32 local coarse ids
    pt_data: jax.Array  # (S, Rc, W)
    pt_col: jax.Array  # int32 local fine ids
    dinv: jax.Array  # (S, Rf)


def _record_pointwise(op: str, n: int, itemsize: int, reads: int):
    """Elementwise vector work not covered by a dispatch op (formula shared
    via energy/trace.py)."""
    trace.record_op(op, trace.pointwise_counts(n, itemsize, reads))


def jacobi_sweeps(
    mat: DistMat, dinv: jax.Array, b: jax.Array, x: jax.Array | None,
    n: int, omega: float, axis: str, ops: kd.OpSet | None = None,
) -> jax.Array:
    """n sweeps of (damped) l1-Jacobi; x=None means zero initial guess, in
    which case the first sweep is the free half-sweep x = omega*dinv*b."""
    ops = ops or kd.ops_for(None)
    if x is None:
        _record_pointwise("jacobi_scale", b.size, b.dtype.itemsize, 2)
        x = omega * dinv * b
        n = n - 1
    for _ in range(n):
        r = ops.axpy(-1.0, spmv_shard(mat, x, axis), b)  # r = b - A x
        _record_pointwise("jacobi_scale", b.size, b.dtype.itemsize, 2)
        x = ops.axpy(omega, dinv * r, x)
    return x


def coarse_solve(dense_inv: jax.Array, rc: jax.Array, axis: str) -> jax.Array:
    """Replicated dense inverse applied to the gathered coarse residual."""
    nc = dense_inv.shape[0]
    b = rc.dtype.itemsize
    S = max(nc // max(rc.shape[0], 1), 1)
    trace.record_op(
        "coarse_gather",
        OpCounts(ici_bytes=float(rc.shape[0] * (S - 1) * b),
                 n_collectives=1.0 if S > 1 else 0.0),
    )
    trace.record_op(
        "coarse_solve",
        OpCounts(flops=2.0 * nc * nc,
                 hbm_bytes=float(nc * nc * b + 2 * nc * b)),
    )
    r_full = lax.all_gather(rc, axis, tiled=True)
    x_full = dense_inv @ r_full
    idx = lax.axis_index(axis)
    return lax.dynamic_slice_in_dim(x_full, idx * rc.shape[0], rc.shape[0])


def vcycle_shard(
    levels, dense_inv: jax.Array, b: jax.Array, axis: str,
    *, n_smooth: int = 4, omega: float = 1.0, ops: kd.OpSet | None = None,
) -> jax.Array:
    """One V(n_smooth, n_smooth) cycle applied to b (zero initial guess).

    ``ops`` is the kernel-dispatch OpSet the cycle's vector updates route
    through (None = resolve the active backend).
    """
    ops = ops or kd.ops_for(None)

    def down(l: int, bl: jax.Array) -> jax.Array:
        lev = levels[l]
        x = jacobi_sweeps(
            lev.mat, lev.dinv, bl, None, n_smooth, omega, axis, ops
        )
        r = ops.axpy(-1.0, spmv_shard(lev.mat, x, axis), bl)
        rc = ell_matvec(lev.pt_data, lev.pt_col, r)  # restriction (local)
        if l + 1 < len(levels):
            xc = down(l + 1, rc)
        else:
            xc = coarse_solve(dense_inv, rc, axis)
        _record_pointwise("prolongation", x.size, x.dtype.itemsize, 3)
        x = x + lev.p_data * xc[lev.p_col]  # prolongation (local)
        x = jacobi_sweeps(
            lev.mat, lev.dinv, bl, x, n_smooth, omega, axis, ops
        )
        return x

    with trace.region("vcycle"):
        return down(0, b)
