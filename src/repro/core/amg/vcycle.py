"""Device-side V-cycle (per-shard view, inside shard_map).

Level data layout (see hierarchy.py):

* ``mat``              — A_l as a halo-planned DistELL block;
* ``p_data / p_col``   — the tentative prolongator: ONE nonzero per fine row,
  ``p_col`` is the *local* coarse aggregate id (decoupled aggregation keeps
  it shard-local), so prolongation is a pure local gather;
* ``pt_data / pt_col`` — P^T in ELL over coarse rows (width = max aggregate
  size, 8 in the paper configuration); restriction is a pure local ELL
  matvec;
* ``dinv``             — 1 / l1-Jacobi diagonal of A_l.

The coarsest level is solved with a replicated dense inverse applied to the
all-gathered coarse residual (coarse sizes are a few hundred at most).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.partition import DistELL
from repro.core.spmv import ell_matvec, spmv_shard


def _register(cls, data_fields, meta_fields):
    return partial(
        jax.tree_util.register_dataclass,
        data_fields=data_fields,
        meta_fields=meta_fields,
    )(cls)


@partial(
    _register,
    data_fields=("mat", "p_data", "p_col", "pt_data", "pt_col", "dinv"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class AMGLevel:
    mat: DistELL
    p_data: jax.Array  # (S, Rf) or (Rf,) locally
    p_col: jax.Array  # int32 local coarse ids
    pt_data: jax.Array  # (S, Rc, W)
    pt_col: jax.Array  # int32 local fine ids
    dinv: jax.Array  # (S, Rf)


def jacobi_sweeps(
    mat: DistELL, dinv: jax.Array, b: jax.Array, x: jax.Array | None,
    n: int, omega: float, axis: str,
) -> jax.Array:
    """n sweeps of (damped) l1-Jacobi; x=None means zero initial guess, in
    which case the first sweep is the free half-sweep x = omega*dinv*b."""
    if x is None:
        x = omega * dinv * b
        n = n - 1
    for _ in range(n):
        x = x + omega * dinv * (b - spmv_shard(mat, x, axis))
    return x


def coarse_solve(dense_inv: jax.Array, rc: jax.Array, axis: str) -> jax.Array:
    """Replicated dense inverse applied to the gathered coarse residual."""
    r_full = lax.all_gather(rc, axis, tiled=True)
    x_full = dense_inv @ r_full
    idx = lax.axis_index(axis)
    return lax.dynamic_slice_in_dim(x_full, idx * rc.shape[0], rc.shape[0])


def vcycle_shard(
    levels, dense_inv: jax.Array, b: jax.Array, axis: str,
    *, n_smooth: int = 4, omega: float = 1.0,
) -> jax.Array:
    """One V(n_smooth, n_smooth) cycle applied to b (zero initial guess)."""

    def down(l: int, bl: jax.Array) -> jax.Array:
        lev = levels[l]
        x = jacobi_sweeps(lev.mat, lev.dinv, bl, None, n_smooth, omega, axis)
        r = bl - spmv_shard(lev.mat, x, axis)
        rc = ell_matvec(lev.pt_data, lev.pt_col, r)  # restriction (local)
        if l + 1 < len(levels):
            xc = down(l + 1, rc)
        else:
            xc = coarse_solve(dense_inv, rc, axis)
        x = x + lev.p_data * xc[lev.p_col]  # prolongation (local)
        x = jacobi_sweeps(lev.mat, lev.dinv, bl, x, n_smooth, omega, axis)
        return x

    return down(0, b)
