"""AMG hierarchy construction (host setup) -> distributed Preconditioner.

Setup follows the paper's configuration: per level, aggregates of size up to
8 via 3 composed pairwise matchings (compatible weighting), decoupled
(per-shard) so prolongators stay shard-local; Galerkin RAP on the host;
l1-Jacobi smoother diagonals; dense inverse at the coarsest level.

``weighting="plain"`` builds the AmgX-analog preconditioner: identical
aggregate sizes / cycle structure / smoother, but strength-only matching
weights — the convergence gap between the two is exactly the paper's
BootCMatchGX-vs-AmgX PCG comparison (C5).
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import PartitionSpec as P

import jax
import jax.numpy as jnp

from repro.core.amg.aggregation import decoupled_aggregate
from repro.core.amg.galerkin import l1_diagonal, rap
from repro.core.amg.vcycle import AMGLevel, vcycle_shard
from repro.core.cg import Preconditioner
from repro.core.partition import RowPartition, partition_csr


@dataclasses.dataclass(frozen=True)
class AMGParams:
    sweeps_per_level: int = 3  # 2^3 = size-8 aggregates (paper config)
    max_levels: int = 10
    coarse_size: int = 200  # stop when global size <= this
    n_smooth: int = 4  # paper: 4 l1-Jacobi sweeps
    omega: float = 1.0
    weighting: str = "compatible"  # "compatible" | "plain" (AmgX analog)
    matcher: str = "locdom"  # "locdom" | "scan" (AmgX analog)
    max_ring: int = 3


@dataclasses.dataclass(frozen=True)
class AMGInfo:
    level_rows: tuple[int, ...]
    level_nnz: tuple[int, ...]
    coarse_rows: int

    @property
    def operator_complexity(self) -> float:
        return sum(self.level_nnz) / max(self.level_nnz[0], 1)

    @property
    def n_levels(self) -> int:
        return len(self.level_rows)


def _pad_per_shard(vec: np.ndarray, row_starts, R: int) -> np.ndarray:
    S = len(row_starts) - 1
    out = np.zeros((S, R), vec.dtype)
    for s in range(S):
        lo, hi = row_starts[s], row_starts[s + 1]
        out[s, : hi - lo] = vec[lo:hi]
    return out


def _build_p_arrays(p_csr, fine_starts, coarse_starts, Rf: int, Rc: int, dtype):
    """Per-shard P (1 nnz/row gather form) and P^T (ELL over coarse rows)."""
    S = len(fine_starts) - 1
    p = p_csr.tocsr()
    pt = p_csr.T.tocsr()
    # max aggregate size across shards = ELL width of P^T
    W = max(int(np.diff(pt.indptr).max()) if pt.nnz else 1, 1)

    p_data = np.zeros((S, Rf), dtype)
    p_col = np.zeros((S, Rf), np.int32)
    pt_data = np.zeros((S, Rc, W), dtype)
    pt_col = np.zeros((S, Rc, W), np.int32)
    for s in range(S):
        flo, fhi = fine_starts[s], fine_starts[s + 1]
        clo, chi = coarse_starts[s], coarse_starts[s + 1]
        for i in range(flo, fhi):
            lo, hi = p.indptr[i], p.indptr[i + 1]
            if hi > lo:  # exactly one entry
                p_data[s, i - flo] = p.data[lo]
                p_col[s, i - flo] = p.indices[lo] - clo
        for a in range(clo, chi):
            lo, hi = pt.indptr[a], pt.indptr[a + 1]
            c = hi - lo
            pt_data[s, a - clo, :c] = pt.data[lo:hi]
            pt_col[s, a - clo, :c] = (pt.indices[lo:hi] - flo).astype(np.int32)
    return p_data, p_col, pt_data, pt_col


def build_amg(
    a_csr,
    n_shards: int,
    params: AMGParams | None = None,
    *,
    partition: RowPartition | None = None,
    smooth_vec: np.ndarray | None = None,
    dtype=np.float64,
    kernels: str | None = None,
) -> tuple[Preconditioner, AMGInfo]:
    """Build the distributed AMG preconditioner for ``a_csr``.

    ``kernels`` selects the dispatch backend (kernels/dispatch.py) the
    V-cycle's vector updates route through inside the solver's shard_map
    (None = auto). The apply is region-marked: its executed counts land in
    the "vcycle" energy region (see energy/trace.py).
    """
    params = params or AMGParams()
    a = a_csr.tocsr().astype(np.float64)
    n = a.shape[0]
    part = partition or _balanced(n, n_shards)
    row_starts = part.row_starts

    levels = []
    level_rows, level_nnz = [], []
    cur = a
    while (
        len(levels) < params.max_levels - 1
        and cur.shape[0] > max(params.coarse_size, 2 * n_shards)
    ):
        p_op, coarse_starts = decoupled_aggregate(
            cur,
            row_starts,
            sweeps=params.sweeps_per_level,
            weighting=params.weighting,
            matcher=params.matcher,
            smooth_vec=smooth_vec if len(levels) == 0 else None,
        )
        if p_op.shape[1] >= cur.shape[0]:  # no coarsening progress
            break
        dist = partition_csr(
            cur,
            n_shards,
            partition=RowPartition(cur.shape[0], row_starts),
            dtype=dtype,
            max_ring=params.max_ring,
        )
        Rf = dist.n_own_pad
        Rc = max(
            coarse_starts[s + 1] - coarse_starts[s] for s in range(n_shards)
        )
        Rc = max(Rc, 1)
        pd, pc, ptd, ptc = _build_p_arrays(
            p_op, row_starts, coarse_starts, Rf, Rc, dtype
        )
        dinv_g = np.zeros(cur.shape[0])
        d = l1_diagonal(cur)
        dinv_g = np.where(d > 0, 1.0 / np.maximum(d, 1e-300), 0.0)
        levels.append(
            AMGLevel(
                mat=dist,
                p_data=jnp.asarray(pd),
                p_col=jnp.asarray(pc),
                pt_data=jnp.asarray(ptd),
                pt_col=jnp.asarray(ptc),
                dinv=jnp.asarray(
                    _pad_per_shard(dinv_g.astype(dtype), row_starts, Rf)
                ),
            )
        )
        level_rows.append(cur.shape[0])
        level_nnz.append(cur.nnz)
        cur = rap(cur, p_op)
        row_starts = coarse_starts

    # ---- coarsest level: replicated dense inverse in padded layout --------
    nL = cur.shape[0]
    S = n_shards
    RcL = max(
        max(row_starts[s + 1] - row_starts[s] for s in range(S)), 1
    )
    dense = np.eye(S * RcL)
    ad = cur.toarray()
    for si in range(S):
        li, hi_ = row_starts[si], row_starts[si + 1]
        for sj in range(S):
            lj, hj = row_starts[sj], row_starts[sj + 1]
            dense[
                si * RcL : si * RcL + (hi_ - li), sj * RcL : sj * RcL + (hj - lj)
            ] = ad[li:hi_, lj:hj]
    dense_inv = jnp.asarray(np.linalg.inv(dense).astype(dtype))
    level_rows.append(nL)
    level_nnz.append(cur.nnz)

    levels = tuple(levels)
    specs = (
        jax.tree.map(lambda x: P("shards", *([None] * (x.ndim - 1))), levels),
        P(None, None),
    )

    n_smooth, omega = params.n_smooth, params.omega
    from repro.kernels import dispatch as kd

    ops = kd.ops_for(kernels)

    def apply(pdata, r, axis):
        lv, dinv_mat = pdata
        return vcycle_shard(
            lv, dinv_mat, r, axis, n_smooth=n_smooth, omega=omega, ops=ops
        )

    def localize(pdata):
        lv, dinv_mat = pdata
        lv_local = jax.tree.map(lambda x: x[0], lv)
        return lv_local, dinv_mat

    pre = Preconditioner(
        data=(levels, dense_inv), specs=specs, apply=apply, localize=localize
    )
    info = AMGInfo(tuple(level_rows), tuple(level_nnz), nL)
    return pre, info


def make_amg_preconditioner(
    a_csr,
    n_shards: int,
    params: AMGParams | None = None,
    *,
    amgx_analog: bool = False,
    kernels: str | None = None,
    **kw,
) -> tuple[Preconditioner, AMGInfo]:
    """One-stop executed-AMG entry point for solvers and benchmarks.

    Builds the hierarchy (host setup) and returns a Preconditioner whose
    apply runs the *real* V-cycle through the kernel dispatch layer inside
    ``make_solver``'s shard_map — no synthetic cycle profile anywhere.
    ``amgx_analog=True`` selects the plain-strength/scan-order matching
    baseline (the paper's AmgX comparison, C5).
    """
    params = params or AMGParams()
    if amgx_analog:
        params = dataclasses.replace(params, weighting="plain", matcher="scan")
    return build_amg(a_csr, n_shards, params, kernels=kernels, **kw)


def _balanced(n, n_shards):
    from repro.core.partition import balanced_partition

    return balanced_partition(n, n_shards)
