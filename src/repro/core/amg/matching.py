"""Weighted graph matching for AMG aggregation.

Two weightings:

* ``compatible`` — BootCMatch's compatible weighted matching [18]: for a
  smooth vector ``w`` (default: ones), edge (i, j) gets

      c_ij = 1 - (2 a_ij w_i w_j) / (a_ii w_i^2 + a_jj w_j^2)

  Large c_ij means aggregating (i, j) interferes little with the smooth
  error component — pairs that a pointwise smoother handles badly get
  aggregated, which is what preserves V-cycle convergence.
* ``plain`` — |a_ij| (strength-of-connection only). This is the AmgX-analog
  aggregation quality baseline: same aggregate sizes, same cycle cost,
  weaker convergence.

The matching itself is the **locally-dominant** (parallel greedy) algorithm
the GPU library uses: repeatedly, every unmatched vertex points at its
heaviest unmatched neighbor and mutual pairs are matched. It 1/2-approximates
maximum weight matching and is embarrassingly parallel. We provide a pure
numpy host version (setup path) and an equivalent JAX ``lax.while_loop``
version (device path; tested for equivalence).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# ---------------------------------------------------------------------------
# Edge weights
# ---------------------------------------------------------------------------


def compatible_weights(a_csr, w: np.ndarray | None = None):
    """Return CSR-like weight matrix (same sparsity, off-diag only).

    c_ij = 1 - 2 a_ij w_i w_j / (a_ii w_i^2 + a_jj w_j^2).
    """
    import scipy.sparse as sp

    a = a_csr.tocsr()
    n = a.shape[0]
    w = np.ones(n) if w is None else np.asarray(w, dtype=np.float64)
    d = a.diagonal() * w * w  # a_ii w_i^2
    coo = a.tocoo()
    off = coo.row != coo.col
    r, c, v = coo.row[off], coo.col[off], coo.data[off]
    denom = d[r] + d[c]
    denom = np.where(np.abs(denom) < 1e-300, 1e-300, denom)
    cw = 1.0 - (2.0 * v * w[r] * w[c]) / denom
    return sp.csr_matrix((cw, (r, c)), shape=(n, n))


def plain_weights(a_csr):
    """AmgX-analog strength weights: |a_ij| off-diagonal."""
    import scipy.sparse as sp

    a = a_csr.tocoo()
    off = a.row != a.col
    return sp.csr_matrix(
        (np.abs(a.data[off]), (a.row[off], a.col[off])), shape=a.shape
    )


# ---------------------------------------------------------------------------
# ELL padding of a weight matrix (shared by np and jax matchers)
# ---------------------------------------------------------------------------


def weights_to_ell(w_csr):
    """(wdata (n,k), wcol (n,k) int32); padded slots weight=-inf, col=self."""
    w = w_csr.tocsr()
    n = w.shape[0]
    counts = np.diff(w.indptr)
    k = max(int(counts.max()) if n else 0, 1)
    wdata = np.full((n, k), -np.inf)
    wcol = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, k))
    for i in range(n):
        lo, hi = w.indptr[i], w.indptr[i + 1]
        c = hi - lo
        if c:
            wdata[i, :c] = w.data[lo:hi]
            wcol[i, :c] = w.indices[lo:hi]
    return wdata, wcol


# ---------------------------------------------------------------------------
# Locally-dominant matching — numpy (host setup path)
# ---------------------------------------------------------------------------


def locally_dominant_matching_np(wdata: np.ndarray, wcol: np.ndarray) -> np.ndarray:
    """match[i] = partner of i, or i if unmatched. Deterministic.

    Ties are broken toward the smaller column index (achieved by a tiny
    index-dependent perturbation identical in the JAX version).
    """
    n, k = wdata.shape
    eps = 1e-12
    wd = wdata - eps * wcol  # deterministic tie-break
    match = np.arange(n, dtype=np.int64)
    unmatched = np.ones(n, dtype=bool)
    for _ in range(64):  # converges in O(log n) rounds in practice
        # candidate: heaviest unmatched neighbor of each unmatched vertex
        avail = unmatched[wcol] & (wcol != np.arange(n)[:, None])
        masked = np.where(avail, wd, -np.inf)
        best_slot = np.argmax(masked, axis=1)
        has = masked[np.arange(n), best_slot] > -np.inf
        cand = np.where(has & unmatched, wcol[np.arange(n), best_slot], np.arange(n))
        mutual = (cand[cand] == np.arange(n)) & (cand != np.arange(n))
        if not mutual.any():
            break
        match = np.where(mutual, cand, match)
        unmatched = unmatched & ~mutual
    return match


def greedy_scan_matching_np(wdata: np.ndarray, wcol: np.ndarray) -> np.ndarray:
    """Scan-order greedy matching (the AmgX plain-aggregation analog).

    Visits vertices in index order and pairs each unmatched vertex with its
    strongest still-unmatched neighbor — commits early, so it produces
    lower-weight matchings than the locally-dominant algorithm when edge
    weights vary. Sequential by construction (host setup only).
    """
    n, k = wdata.shape
    match = np.arange(n, dtype=np.int64)
    unmatched = np.ones(n, dtype=bool)
    order = np.argsort(-wdata, axis=1, kind="stable")
    for i in range(n):
        if not unmatched[i]:
            continue
        for s in order[i]:
            j = wcol[i, s]
            if wdata[i, s] == -np.inf:
                break
            if j != i and unmatched[j]:
                match[i] = j
                match[j] = i
                unmatched[i] = unmatched[j] = False
                break
    return match


MATCHERS = {
    "locdom": locally_dominant_matching_np,
    "scan": greedy_scan_matching_np,
}


# ---------------------------------------------------------------------------
# Locally-dominant matching — JAX (device path)
# ---------------------------------------------------------------------------


@jax.jit
def locally_dominant_matching_jax(wdata: jax.Array, wcol: jax.Array) -> jax.Array:
    """JAX equivalent of the numpy matcher (same tie-breaks)."""
    n, k = wdata.shape
    idx = jnp.arange(n, dtype=jnp.int32)
    wd = wdata - 1e-12 * wcol

    def cond(c):
        _, _, changed, rounds = c
        return changed & (rounds < 64)

    def body(c):
        match, unmatched, _, rounds = c
        avail = unmatched[wcol] & (wcol != idx[:, None])
        masked = jnp.where(avail, wd, -jnp.inf)
        best_slot = jnp.argmax(masked, axis=1)
        has = masked[idx, best_slot] > -jnp.inf
        cand = jnp.where(has & unmatched, wcol[idx, best_slot], idx)
        mutual = (cand[cand] == idx) & (cand != idx)
        match = jnp.where(mutual, cand, match)
        unmatched = unmatched & ~mutual
        return match, unmatched, mutual.any(), rounds + 1

    init = (idx, jnp.ones(n, bool), jnp.asarray(True), jnp.asarray(0, jnp.int32))
    match, _, _, _ = lax.while_loop(cond, body, init)
    return match
