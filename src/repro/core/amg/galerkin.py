"""Galerkin triple product A_c = P^T A P (host, setup time).

The paper's library computes the RAP on device; our setup phase runs it on
the host with scipy (the solve phase — all SpMVs, smoothing, cycling — is
100% device). The distributed cost attribution (setup energy on the host
CPU) is recorded in the energy accounting exactly like the paper's CPU
column.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp


def rap(a_csr, p_csr) -> sp.csr_matrix:
    ac = (p_csr.T @ (a_csr @ p_csr)).tocsr()
    ac.sum_duplicates()
    # Drop numerically-zero fill to keep ELL widths tight.
    ac.data[np.abs(ac.data) < 1e-300] = 0.0
    ac.eliminate_zeros()
    return ac


def l1_diagonal(a_csr) -> np.ndarray:
    """l1-Jacobi diagonal: d_i = a_ii + sum_{j != i} |a_ij|.

    Guaranteed-convergent Jacobi scaling for SPD matrices (the paper's
    smoother choice: 4 l1-Jacobi sweeps in the V-cycle).
    """
    a = a_csr.tocsr()
    diag = a.diagonal()
    absrow = np.abs(a).sum(axis=1)
    absrow = np.asarray(absrow).ravel()
    return diag + (absrow - np.abs(diag))
