"""AmgX-analog AMG baseline (C5 comparison).

The paper configures NVIDIA AmgX "with the matching-based aggregation
preconditioner, using aggregates of size 8, as in BootCMatchGX", the same
4-sweep l1-Jacobi smoother, and default hierarchy settings — so the PCG gap
it reports comes from the *quality* of the aggregation (and per-iteration
implementation efficiency), not the cycle structure.

The analog here is therefore ``build_amg`` with ``weighting="plain"``:
identical sweeps / aggregate size / smoother / coarse solve, but matching on
strength-of-connection |a_ij| instead of the compatibility weights — the
component the paper credits for BootCMatchGX's better convergence.
"""

from __future__ import annotations

from repro.core.amg.hierarchy import AMGParams, make_amg_preconditioner


def build_amgx_analog(a_csr, n_shards: int, params: AMGParams | None = None, **kw):
    return make_amg_preconditioner(
        a_csr, n_shards, params, amgx_analog=True, **kw
    )
