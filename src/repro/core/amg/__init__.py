"""Algebraic MultiGrid preconditioner via compatible weighted matching (C3).

The paper's AMG coarsens by aggregating DOFs with a maximum-weight matching
on a weighted graph derived from the system matrix ("compatible weighted
matching", [18, 21]); aggregates of size 8 are obtained by composing three
pairwise matching sweeps per level; the V-cycle smoother is 4 sweeps of
l1-Jacobi; coarsening is *decoupled* (per-shard) at scale so prolongators
never cross shard boundaries — which keeps every inter-shard coupling inside
the (already halo-planned) system matrices.
"""

from repro.core.amg.hierarchy import (  # noqa: F401
    AMGInfo,
    AMGParams,
    build_amg,
    make_amg_preconditioner,
)
