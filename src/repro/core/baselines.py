"""Ginkgo-analog baseline: the *algorithmically naive* distributed path.

The paper compares BootCMatchGX against Ginkgo; the binaries are not
available here, so the comparison is reproduced as an in-framework analog
that removes exactly the design choices the paper credits for the gap:

* SpMV gathers the **full global vector** (``all_gather``) before any local
  work starts — no halo minimization, no compute/communication overlap
  (the local part depends on the gathered vector by construction);
* CG performs **three separate all-reduces** per iteration (p·Ap, r·z,
  ||r||²) — no reduction fusion.

Both paths share the exact same local ELL arithmetic, so the measured /
modeled difference isolates the communication-reduction strategies (C1+C2).
Use ``partition_csr(..., force_allgather=True)`` or
``partition_stencil(..., mode="allgather")`` to build the matching layout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.cg import Preconditioner, SolveResult, identity_precond
from repro.core.partition import DistMat
from repro.core.spmv import (
    boundary_matvec,
    dist_specs,
    ell_matvec,
    gather_ext,
    local_block,
)
from repro.core.vectors import pdot
from repro.energy import trace
from repro.kernels import dispatch as kd


def _rec_updates(x: jax.Array, n_updates: int):
    """Unfused axpy-class updates: 3 streamed vectors each (trace-time)."""
    trace.record_op(
        "axpy_unfused",
        trace.streamed_axpy_counts(x.size, x.dtype.itemsize, n_updates),
    )


def spmv_naive_shard(mat: DistMat, x_own: jax.Array, axis: str) -> jax.Array:
    """Ginkgo-analog SpMV: gather the whole vector first, then multiply.

    Requires an allgather-mode, ELL-interior DistMat (external columns in
    padded-global layout). The local part reads its slice *from the gathered
    copy*, which serializes communication before compute — deliberately.
    """
    assert mat.plan.mode == "allgather", "naive SpMV needs allgather layout"
    R = mat.n_own_pad
    # gather_ext provides the instrumented allgather (region "halo" + counts)
    x_full = gather_ext(mat, x_own, axis)
    idx = lax.axis_index(axis)
    x_own_from_full = lax.dynamic_slice_in_dim(x_full, idx * R, R)
    y = ell_matvec(mat.data_loc, mat.col_loc, x_own_from_full)
    yb = boundary_matvec(mat.data_ext, mat.col_ext, x_full)
    return y.at[mat.bnd_rows].add(yb)


def _cg_unfused_body(mat, pre: Preconditioner, pdata, b, x0, *, tol, maxiter, axis):
    """HS PCG with 3 *separate* all-reduces per iteration (no fusion)."""
    with trace.region("spmv"):
        r = b - spmv_naive_shard(mat, x0, axis)
    with trace.region("precond"):
        z = pre.apply(pdata, r, axis)
    with trace.region("reductions"):
        rz = pdot(r, z, axis)  # separate
        rr = pdot(r, r, axis)  # separate
        bb = pdot(b, b, axis)  # separate
    tol2 = tol * tol * bb

    def cond(c):
        i, x, r, z, p, rz, rr = c
        return (i < maxiter) & (rr > tol2)

    def body(c):
        i, x, r, z, p, rz, rr = c
        with kd.ledger_section("iteration"):
            with trace.region("spmv"):
                w = spmv_naive_shard(mat, p, axis)
            with trace.region("reductions"):
                pw = pdot(p, w, axis)  # all-reduce 1
                alpha = rz / pw
                _rec_updates(x, 2)  # two unfused axpy-class updates
                x = x + alpha * p
                r = r - alpha * w
            with trace.region("precond"):
                z = pre.apply(pdata, r, axis)
            with trace.region("reductions"):
                rz_new = pdot(r, z, axis)  # all-reduce 2
                rr = pdot(r, r, axis)  # all-reduce 3
                beta = rz_new / rz
                _rec_updates(x, 1)
                p = z + beta * p
        return (i + 1, x, r, z, p, rz_new, rr)

    i0 = jnp.asarray(0, jnp.int32)
    c = lax.while_loop(cond, body, (i0, x0, r, z, z, rz, rr))
    return c[1], c[0], c[6], bb


def make_naive_solver(
    mesh,
    mat: DistMat,
    *,
    precond: Preconditioner | None = None,
    tol: float = 1e-8,
    maxiter: int = 100,
    axis: str = "shards",
):
    """Jitted Ginkgo-analog CG solver: (b, x0) -> SolveResult."""
    from jax.experimental.shard_map import shard_map

    pre = precond or identity_precond()
    mat_specs = dist_specs(mat)

    from repro.core.cg import _default_localize

    localize = pre.localize or _default_localize

    def fn(m, pdata, b, x0):
        mb = local_block(m)
        pl = localize(pdata)
        x, iters, rr, bb = _cg_unfused_body(
            mb, pre, pl, b[0], x0[0], tol=tol, maxiter=maxiter, axis=axis
        )
        return x[None], iters, rr, bb

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(mat_specs, pre.specs, P("shards", None), P("shards", None)),
        out_specs=(P("shards", None), P(), P(), P()),
        check_rep=False,  # jax 0.4.37: no replication rule for while_loop
    )

    @jax.jit
    def solve(b, x0):
        x, iters, rr, bb = mapped(mat, pre.data, b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve


def make_naive_spmv(mesh, mat: DistMat, axis: str = "shards"):
    """Jitted Ginkgo-analog distributed SpMV."""
    from jax.experimental.shard_map import shard_map

    specs = dist_specs(mat)

    def fn(m, x):
        mb = local_block(m)
        return spmv_naive_shard(mb, x[0], axis)[None]

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(specs, P("shards", None)),
        out_specs=P("shards", None),
    )
    return jax.jit(mapped)
