"""Local (per-shard) sparse-matrix formats and pure-jnp SpMV implementations.

The paper's library stores matrices in CSR with 4-byte *local* column indices
(global→local shift + compaction). We keep the same discipline:

* all device-resident column indices are ``int32`` and index a *local extended
  vector* ``x_ext = [x_own | halo buffers]`` (see ``core/partition.py``);
* the global 64-bit index space only exists on the host at partition time;
* distributed matrices additionally split rows into an interior block and a
  compact ghost-touching boundary block (``partition.DistMat``) so the halo
  exchange can overlap the interior SpMV — the formats here are the
  *single-shard* building blocks underneath that split.

Formats:

* ``CSR``  — data/col/row_ids triple (row_ids instead of indptr so that SpMV is
  a single ``segment_sum``; TPU/XLA lowers this to a scatter-add).
* ``ELL``  — (n, k) padded rows; the TPU-friendly jnp format (dense gather +
  reduction, no scatter). Default on-device format for stencil matrices.
* ``BCSR`` — dense (br, bc) blocks + block-column indices; the Pallas-kernel
  format (see ``kernels/spmv_bcsr.py``).

Padding conventions: padded entries carry ``data == 0`` and ``col == 0`` so any
gather stays in bounds and contributes nothing.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls, data_fields, meta_fields):
    return partial(
        jax.tree_util.register_dataclass,
        data_fields=data_fields,
        meta_fields=meta_fields,
    )(cls)


@partial(_register, data_fields=("data", "col", "row_ids"), meta_fields=("n_rows", "n_cols"))
@dataclasses.dataclass(frozen=True)
class CSR:
    """CSR stored as COO-with-sorted-rows (row_ids) for segment_sum SpMV."""

    data: jax.Array  # (nnz,)
    col: jax.Array  # (nnz,) int32, local indices
    row_ids: jax.Array  # (nnz,) int32, non-decreasing; padding rows use n_rows
    n_rows: int
    n_cols: int

    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    def matvec(self, x: jax.Array) -> jax.Array:
        """y = A @ x, x of length n_cols. Padding row_ids==n_rows are dropped."""
        contrib = self.data * x[self.col]
        y = jax.ops.segment_sum(contrib, self.row_ids, num_segments=self.n_rows + 1)
        return y[: self.n_rows]


@partial(_register, data_fields=("data", "col"), meta_fields=("n_cols",))
@dataclasses.dataclass(frozen=True)
class ELL:
    """ELLPACK: fixed k slots per row. Padded slots: data=0, col=0."""

    data: jax.Array  # (n_rows, k)
    col: jax.Array  # (n_rows, k) int32
    n_cols: int

    @property
    def n_rows(self) -> int:
        return self.data.shape[0]

    @property
    def k(self) -> int:
        return self.data.shape[1]

    def matvec(self, x: jax.Array) -> jax.Array:
        return jnp.einsum("rk,rk->r", self.data, x[self.col])


@partial(
    _register,
    data_fields=("blocks", "bcol", "brow_ids"),
    meta_fields=("n_brows", "n_bcols", "br", "bc"),
)
@dataclasses.dataclass(frozen=True)
class BCSR:
    """Block-CSR with dense (br, bc) blocks; the Pallas SpMV format.

    Block rows are padded to a uniform number of blocks per block-row when
    used by the Pallas kernel (see kernels/spmv_bcsr.py); here we keep the
    general ragged form with brow_ids for the jnp reference path.
    """

    blocks: jax.Array  # (nnzb, br, bc)
    bcol: jax.Array  # (nnzb,) int32
    brow_ids: jax.Array  # (nnzb,) int32, padding uses n_brows
    n_brows: int
    n_bcols: int
    br: int
    bc: int

    def matvec(self, x: jax.Array) -> jax.Array:
        xb = x.reshape(self.n_bcols, self.bc)
        contrib = jnp.einsum("nij,nj->ni", self.blocks, xb[self.bcol])
        yb = jax.ops.segment_sum(contrib, self.brow_ids, num_segments=self.n_brows + 1)
        return yb[: self.n_brows].reshape(-1)


# ---------------------------------------------------------------------------
# Host-side conversions (numpy; used at partition/setup time only).
# ---------------------------------------------------------------------------


def csr_from_scipy(a, pad_nnz_to: int | None = None, dtype=np.float32) -> CSR:
    """Build a device CSR from a scipy.sparse CSR matrix (host).

    Mirrors :func:`ell_from_scipy`'s contract: an insufficient capacity
    request raises (it used to be silently ignored), and padding slots carry
    ``data == 0``, ``col == 0`` (``row_ids == n_rows``, dropped by matvec) —
    the repo-wide padding convention every format shares.
    """
    a = a.tocsr()
    n_rows, n_cols = a.shape
    nnz = a.nnz
    row_ids = np.repeat(np.arange(n_rows, dtype=np.int32), np.diff(a.indptr))
    data = a.data.astype(dtype)
    col = a.indices.astype(np.int32)
    if pad_nnz_to is not None:
        if pad_nnz_to < nnz:
            raise ValueError(
                f"pad_nnz_to={pad_nnz_to} below the matrix nnz={nnz}"
            )
        pad = pad_nnz_to - nnz
        data = np.concatenate([data, np.zeros(pad, dtype)])
        col = np.concatenate([col, np.zeros(pad, np.int32)])
        row_ids = np.concatenate([row_ids, np.full(pad, n_rows, np.int32)])
    return CSR(
        data=jnp.asarray(data),
        col=jnp.asarray(col),
        row_ids=jnp.asarray(row_ids),
        n_rows=n_rows,
        n_cols=n_cols,
    )


def ell_from_scipy(a, k: int | None = None, dtype=np.float32, n_cols: int | None = None):
    """Build an ELL matrix (host). k defaults to max nnz/row.

    Empty rows (and the padded tail of every short row) carry ``data == 0``,
    ``col == 0``; non-square inputs keep their column count in ``n_cols`` so
    the gather length is the *column* space, never the row count.
    """
    a = a.tocsr()
    n_rows, a_cols = a.shape
    n_cols = a_cols if n_cols is None else n_cols
    counts = np.diff(a.indptr)
    kmax = int(counts.max()) if n_rows else 0
    if k is None:
        k = kmax
    if kmax > k:
        raise ValueError(f"row with {kmax} nnz exceeds requested k={k}")
    data = np.zeros((n_rows, k), dtype)
    col = np.zeros((n_rows, k), np.int32)
    for i in range(n_rows):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        c = hi - lo
        data[i, :c] = a.data[lo:hi]
        col[i, :c] = a.indices[lo:hi]
    return ELL(data=jnp.asarray(data), col=jnp.asarray(col), n_cols=n_cols)


def block_partition(a, br: int, bc: int, dtype=np.float32):
    """Dense-block decomposition of a scipy matrix (host) — the ONE
    block-packing implementation.

    Zero-pads the matrix up to block multiples and materializes every block
    containing a structural nonzero densely. Returns numpy arrays
    ``(blocks (nnzb, br, bc), bcol (nnzb,) int32, brow_ids (nnzb,) int32,
    n_brows, n_bcols)`` with ``brow_ids`` non-decreasing and block columns
    sorted within each block row. Both :func:`bcsr_from_scipy` (ragged
    device format) and :func:`pack_bcsr` (the Pallas kernel's uniform
    blocks-per-row layout) build on this.
    """
    import scipy.sparse as sp

    a = a.tocsr()
    n, m = a.shape
    n_brows = -(-n // br)
    n_bcols = -(-m // bc)
    ap = sp.csr_matrix((a.data, a.indices, a.indptr), shape=(n, m))
    ap.resize(max(n_brows, 1) * br, max(n_bcols, 1) * bc)
    coo = ap.tocoo()
    bi = (coo.row // br).astype(np.int64)
    bj = (coo.col // bc).astype(np.int64)
    keys = bi * max(n_bcols, 1) + bj
    uniq, inv = np.unique(keys, return_inverse=True)
    nnzb = len(uniq)
    blocks = np.zeros((nnzb, br, bc), dtype)
    blocks[inv, coo.row % br, coo.col % bc] = coo.data
    brow_ids = (uniq // max(n_bcols, 1)).astype(np.int32)
    bcol = (uniq % max(n_bcols, 1)).astype(np.int32)
    return blocks, bcol, brow_ids, n_brows, n_bcols


def bcsr_from_scipy(a, br: int, bc: int, dtype=np.float32) -> BCSR:
    """Build a BCSR matrix with dense (br, bc) blocks (host).

    The matrix is zero-padded up to multiples of the block size; blocks with
    any nonzero are materialized densely (see :func:`block_partition`).
    """
    blocks, bcol, brow_ids, n_brows, n_bcols = block_partition(
        a, br, bc, dtype
    )
    return BCSR(
        blocks=jnp.asarray(blocks),
        bcol=jnp.asarray(bcol),
        brow_ids=jnp.asarray(brow_ids),
        n_brows=n_brows,
        n_bcols=n_bcols,
        br=br,
        bc=bc,
    )


def pack_bcsr(a_csr, br: int, bc: int, dtype=np.float32):
    """Pack a scipy matrix into the Pallas kernel's uniform
    blocks-per-row layout (see ``kernels/spmv_bcsr.py``).

    Returns ``(blocks (n_brows*bpr, br, bc), bcol (n_brows*bpr,), n_brows,
    bpr, n_bcols)``: every block-row padded to the max block count ``bpr``;
    padding blocks are all-zero with ``bcol == 0`` (in-bounds gathers that
    contribute nothing).
    """
    blocks_r, bcol_r, brow_ids, n_brows, n_bcols = block_partition(
        a_csr, br, bc, dtype
    )
    counts = np.bincount(brow_ids, minlength=max(n_brows, 1))
    bpr = max(int(counts.max()) if counts.size else 0, 1)
    blocks = np.zeros((max(n_brows, 1) * bpr, br, bc), dtype)
    bcol = np.zeros((max(n_brows, 1) * bpr,), np.int32)
    # brow_ids is sorted, so the slot of each block within its row is its
    # offset from the row's first block
    starts = np.concatenate([[0], np.cumsum(counts)])
    slot = np.arange(len(brow_ids), dtype=np.int64) - starts[brow_ids]
    dst = brow_ids.astype(np.int64) * bpr + slot
    blocks[dst] = blocks_r
    bcol[dst] = bcol_r
    return blocks, bcol, max(n_brows, 1), bpr, n_bcols
