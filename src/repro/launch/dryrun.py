import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 placeholder host devices back the production
meshes:

    single-pod : (data=16, model=16)           = 256 chips
    multi-pod  : (pod=2, data=16, model=16)    = 512 chips

Per cell this script builds ShapeDtypeStruct stand-ins for params /
optimizer state / inputs (``input_specs`` — zero allocation), jits the step
with explicit shardings, ``.lower().compile()``s it, and records:

    memory_analysis()  -> per-device bytes (proves it fits),
    cost_analysis()    -> HLO FLOPs / bytes for the roofline,
    compiled.as_text() -> collective bytes by kind (roofline collective
                          term; parsed by roofline/analysis.py).

Solver cells (--solver) lower the paper's distributed CG on the flattened
512-way block-row mesh at the paper's weak-scaled production size
(405^3 DOFs per device) — both the BCMGX-analog (ring halo) and the
Ginkgo-analog (allgather) layouts.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--out runs/dryrun]
    python -m repro.launch.dryrun --solver --all-solver
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ArchConfig, ShapeConfig

try:  # repro.dist is only needed for the LM cells, not the solver cells
    from repro.dist.sharding import (
        batch_specs,
        cache_specs,
        dp_axes,
        param_specs,
        shardings_of,
    )

    HAS_DIST = True
except ModuleNotFoundError:  # pragma: no cover - container without repro.dist
    HAS_DIST = False
from repro.launch.mesh import make_production_mesh
from repro.models import lm, transformer as tfm
from repro.roofline import analysis as ra
from repro.train.loop import make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

P = jax.sharding.PartitionSpec


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    if shape.kind == "decode" and cfg.is_encoder_only:
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return None


# microbatch counts chosen so train activations fit 16 GB/chip (see DESIGN)
TRAIN_MICROBATCHES = {"default": 1}


def _cell_fns(cfg: ArchConfig, shape: ShapeConfig, mesh, microbatches: int = 1):
    """Build (jitted fn, example args as SDS) for one cell."""
    if not HAS_DIST:
        raise ModuleNotFoundError(
            "repro.dist is required for LM dry-run cells (solver cells via "
            "--solver / --solver-matfree work without it)"
        )
    specs = lm.input_specs(cfg, shape)
    params_sds = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.key(0)))
    p_sh = shardings_of(param_specs(params_sds, mesh), mesh)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(
            lambda: init_opt_state(params_sds, OptConfig())
        )
        o_sh = {
            "mu": shardings_of(param_specs(opt_sds["mu"], mesh), mesh),
            "nu": shardings_of(param_specs(opt_sds["nu"], mesh), mesh),
            "step": jax.sharding.NamedSharding(mesh, P()),
            "skipped": jax.sharding.NamedSharding(mesh, P()),
        }
        b_sh = shardings_of(
            batch_specs(specs["batch"], mesh, shape.global_batch), mesh
        )
        step = make_train_step(cfg, OptConfig(), kv_chunk=1024, remat=True,
                               microbatches=microbatches)
        fn = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        return fn, (params_sds, opt_sds, specs["batch"])

    if shape.kind == "prefill":
        b_sh = shardings_of(
            batch_specs(specs["batch"], mesh, shape.global_batch), mesh
        )

        def pre_fn(params, batch):
            return lm.prefill(params, cfg, batch, kv_chunk=1024)

        fn = jax.jit(pre_fn, in_shardings=(p_sh, b_sh))
        return fn, (params_sds, specs["batch"])

    # decode
    c_sh = shardings_of(
        cache_specs(specs["cache"], mesh, shape.global_batch, shape.seq_len),
        mesh,
    )
    dp = dp_axes(mesh)
    dpn = 1
    for a in dp:
        dpn *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    t_spec = P(dp) if shape.global_batch % dpn == 0 and shape.global_batch > 1 else P()
    t_sh = jax.sharding.NamedSharding(mesh, t_spec)
    s_sh = jax.sharding.NamedSharding(mesh, P())

    def dec_fn(params, token, cache, pos):
        return lm.serve_step(params, cfg, token, cache, pos)

    fn = jax.jit(
        dec_fn,
        in_shardings=(p_sh, t_sh, c_sh, s_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(2,),
    )
    return fn, (params_sds, specs["token"], specs["cache"], specs["pos"])


def _cost_dict(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: list of per-program dicts
        cost = cost[0] if cost else {}
    return cost


def _analyze(compiled, chips: int, model_flops: float) -> dict:
    cost = _cost_dict(compiled)
    # cost_analysis is per-module (one device's program under SPMD)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    colls = ra.collective_bytes(hlo)
    terms = ra.roofline(
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_acc,
        collective_bytes_per_device=colls["total_bytes"],
        chips=chips,
        model_flops=model_flops,
    )
    mem = {}
    try:
        m = compiled.memory_analysis()
        if m is not None:
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                mem[k] = int(getattr(m, k, 0) or 0)
            mem["total_per_device"] = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0)
            )
    except Exception as e:  # pragma: no cover - backend-dependent
        mem["error"] = str(e)
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collectives": colls,
        "memory": mem,
        "roofline": {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
            "step_s": terms.step_s,
            "model_flops": model_flops,
            "useful_ratio": terms.useful_ratio,
            "mfu": terms.mfu,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str | None,
             probe: bool = False, attn_bf16: bool = False, microbatches: int = 1,
             ssm_chunk: int = 0, tag: str = "", ssd_bf16: bool = False):
    """probe=True additionally compiles the cell with every static-length
    scan UNROLLED and replaces the roofline flops/bytes with the exact
    unrolled HLO costs (XLA cost analysis counts while bodies once — see
    models/flags.py). Memory + collective schedule always come from the
    rolled (deployable) module."""
    from repro.models import flags as mflags

    cfg = get_config(arch)
    if ssm_chunk and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk)
        )
    mflags.ATTN_SCORE_BF16 = attn_bf16
    mflags.SSD_BF16 = ssd_bf16
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec: dict = {"arch": arch + tag, "shape": shape_name, "mesh": mesh_name,
                 "perf_levers": {"attn_bf16": attn_bf16,
                                  "microbatches": microbatches,
                                  "ssm_chunk": ssm_chunk}}
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skip", skip_reason=reason)
        _emit(rec, out_dir)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        t0 = time.perf_counter()
        fn, args = _cell_fns(cfg, shape, mesh, microbatches)
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        mf = {
            "train": ra.model_flops_train,
            "prefill": ra.model_flops_prefill,
            "decode": ra.model_flops_decode,
        }[shape.kind](cfg, shape)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            **_analyze(compiled, chips, mf),
        )
        if probe:
            try:
                mflags.UNROLL_SCANS = True
                t0 = time.perf_counter()
                fn_u, args_u = _cell_fns(cfg, shape, mesh, microbatches)
                compiled_u = fn_u.lower(*args_u).compile()
                cost_u = _cost_dict(compiled_u)
                rec["probe_compile_s"] = round(time.perf_counter() - t0, 2)
                flops_u = float(cost_u.get("flops", 0.0))
                bytes_u = float(cost_u.get("bytes accessed", 0.0))
                # collectives inside scan loops are also text-counted once in
                # the rolled module; the unrolled text has every instance.
                colls_u = ra.collective_bytes(compiled_u.as_text())
                rec["collectives_rolled"] = rec["collectives"]
                rec["collectives"] = colls_u
                rec["flops_per_device_rolled"] = rec["flops_per_device"]
                rec["bytes_per_device_rolled"] = rec["bytes_per_device"]
                rec["flops_per_device"] = flops_u
                rec["bytes_per_device"] = bytes_u
                terms = ra.roofline(
                    hlo_flops_per_device=flops_u,
                    hlo_bytes_per_device=bytes_u,
                    collective_bytes_per_device=colls_u["total_bytes"],
                    chips=chips,
                    model_flops=mf,
                )
                rec["roofline"] = {
                    "compute_s": terms.compute_s,
                    "memory_s": terms.memory_s,
                    "collective_s": terms.collective_s,
                    "dominant": terms.dominant,
                    "step_s": terms.step_s,
                    "model_flops": mf,
                    "useful_ratio": terms.useful_ratio,
                    "mfu": terms.mfu,
                }
                rec["cost_source"] = "unrolled-probe"
                if cfg.xlstm is not None:
                    rec["cost_note"] = (
                        "sLSTM time scan kept rolled (<1% of cell flops)"
                    )
            finally:
                mflags.UNROLL_SCANS = False
        mflags.ATTN_SCORE_BF16 = False
        mflags.SSD_BF16 = False
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _emit(rec, out_dir)
    return rec


# ---------------------------------------------------------------------------
# Solver cells (the paper's technique at production scale)
# ---------------------------------------------------------------------------


def run_solver_cell(
    variant: str,
    stencil: str,
    dofs_per_device: int,
    out_dir: str | None,
    *,
    layout: str = "ring",
    maxiter: int = 100,
):
    """Lower distributed CG at the paper's weak-scaled production size."""
    from repro.core.cg import abstract_stencil_dist, make_solver_fn
    from repro.core.spmv import dist_specs
    from repro.matrices.poisson import PoissonProblem

    n_shards = len(jax.devices())
    mesh = jax.sharding.Mesh(jax.devices(), ("shards",))
    side = dofs_per_device
    p = PoissonProblem(side, side, side * n_shards, stencil)
    if layout != "ring":
        variant = "naive"  # allgather layout always runs the unfused body
    rec = {
        "arch": f"solver-cg-{variant}-{layout}",
        "shape": f"{stencil}-{side}^3x{n_shards}",
        "mesh": f"flat{n_shards}",
    }
    try:
        mat_sds = abstract_stencil_dist(p, n_shards)
        if layout == "allgather":
            mat_sds = dataclasses.replace(
                mat_sds,
                plan=dataclasses.replace(
                    mat_sds.plan, mode="allgather", shifts=(), widths=()
                ),
                data_ext=jax.ShapeDtypeStruct(
                    mat_sds.data_ext.shape, mat_sds.data_ext.dtype
                ),
            )
        R = mat_sds.n_own_pad
        vec = jax.ShapeDtypeStruct((n_shards, R), "float64")
        if layout == "ring":
            solve = make_solver_fn(mesh, mat_sds, variant=variant, maxiter=maxiter)
        else:
            # naive solver closes over the matrix; rebuild as arg-style
            from repro.core.cg import identity_precond
            from jax.experimental.shard_map import shard_map
            from repro.core.baselines import _cg_unfused_body
            from repro.core.spmv import local_block

            pre = identity_precond()
            specs = dist_specs(mat_sds)

            def fn(m, b, x0):
                mb = local_block(m)
                x, iters, rr, bb = _cg_unfused_body(
                    mb, pre, (), b[0], x0[0], tol=1e-8, maxiter=maxiter,
                    axis="shards",
                )
                return x[None], iters, rr, bb

            mapped = shard_map(
                fn,
                mesh=mesh,
                in_specs=(specs, jax.sharding.PartitionSpec("shards", None),
                          jax.sharding.PartitionSpec("shards", None)),
                out_specs=(jax.sharding.PartitionSpec("shards", None),
                           jax.sharding.PartitionSpec(),
                           jax.sharding.PartitionSpec(),
                           jax.sharding.PartitionSpec()),
            )
            solve = jax.jit(lambda m, b, x0: mapped(m, b, x0))

        t0 = time.perf_counter()
        lowered = solve.lower(mat_sds, vec, vec)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        # model flops: maxiter x (2nnz + vector ops ~ 10n) per device x chips
        nnz = p.n * p.k
        model_flops = maxiter * (2.0 * nnz + 10.0 * p.n)
        rec.update(
            status="ok",
            chips=n_shards,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            **_analyze(compiled, n_shards, model_flops),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _emit(rec, out_dir)
    return rec


def run_solver_matfree_cell(
    variant: str,
    stencil: str,
    dofs_per_device: int,
    out_dir: str | None,
    *,
    dtype: str = "float64",
    maxiter: int = 100,
):
    """Beyond-paper optimization (§Perf): matrix-free stencil CG."""
    from repro.core.stencil_solver import make_stencil_solver_fn
    from repro.matrices.poisson import PoissonProblem

    n_shards = len(jax.devices())
    mesh = jax.sharding.Mesh(jax.devices(), ("shards",))
    side = dofs_per_device
    p = PoissonProblem(side, side, side * n_shards, stencil)
    rec = {
        "arch": f"solver-cg-{variant}-matfree-{dtype[-2:]}",
        "shape": f"{stencil}-{side}^3x{n_shards}",
        "mesh": f"flat{n_shards}",
    }
    try:
        R = p.n // n_shards
        vec = jax.ShapeDtypeStruct((n_shards, R), dtype)
        solve = make_stencil_solver_fn(
            mesh, p, n_shards, variant=variant, maxiter=maxiter
        )
        t0 = time.perf_counter()
        lowered = solve.lower(vec, vec)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        nnz = p.n * p.k
        model_flops = maxiter * (2.0 * nnz + 10.0 * p.n)
        rec.update(
            status="ok",
            chips=n_shards,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            **_analyze(compiled, n_shards, model_flops),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _emit(rec, out_dir)
    return rec


def _emit(rec: dict, out_dir: str | None):
    line = f"[{rec['status']:5s}] {rec['arch']:24s} {rec['shape']:22s} {rec['mesh']}"
    if rec["status"] == "ok":
        r = rec["roofline"]
        line += (
            f"  dom={r['dominant']:10s} step={r['step_s']:.4g}s"
            f" mfu={r['mfu']:.3f} compile={rec['compile_s']}s"
        )
    elif rec["status"] == "skip":
        line += f"  ({rec['skip_reason']})"
    else:
        line += f"  {rec['error'][:120]}"
    print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json".replace("/", "_")
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--solver", action="store_true")
    ap.add_argument("--all-solver", action="store_true")
    ap.add_argument("--solver-matfree", action="store_true")
    ap.add_argument("--dtype", default="float64")
    ap.add_argument("--variant", default="fcg")
    ap.add_argument("--layout", default="ring", choices=["ring", "allgather"])
    ap.add_argument("--stencil", default="7pt", choices=["7pt", "27pt"])
    ap.add_argument("--dofs", type=int, default=405)
    ap.add_argument("--out", default=None)
    ap.add_argument("--probe", action="store_true",
                    help="also compile unrolled cost probe per cell")
    ap.add_argument("--attn-bf16", action="store_true",
                    help="perf lever: bf16-operand attention matmuls")
    ap.add_argument("--ssd-bf16", action="store_true",
                    help="perf lever: bf16-operand SSD einsums")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--tag", default="", help="suffix for record names")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.solver or args.all_solver or args.solver_matfree:
        # solver cells follow the paper's double precision (f32 is the
        # mixed-precision optimization variant, selected via --dtype)
        if args.dtype == "float64":
            jax.config.update("jax_enable_x64", True)

    if args.solver_matfree:
        run_solver_matfree_cell(
            args.variant, args.stencil, args.dofs, args.out, dtype=args.dtype
        )
        return

    if args.solver or args.all_solver:
        if args.all_solver:
            from repro.api import VARIANTS

            # the sweep covers every user-selectable CG body — a variant
            # added to the API without a dry-run cell fails loudly here
            sweep = ("hs", "fcg", "pipecg", "sstep")
            assert sweep == VARIANTS, (sweep, VARIANTS)
            for variant in sweep:
                run_solver_cell(variant, "7pt", args.dofs, args.out)
            run_solver_cell("fcg", "27pt", 260, args.out)
            # Ginkgo-analog (allgather) at full 405^3/device x 512 exceeds
            # int32 local addressing (512 * 66.4M = 3.4e10 columns) AND HBM
            # (272 GB gathered vector) — the paper's global->local compaction
            # point. Recorded at the largest size that fits (128^3/device).
            run_solver_cell("hs", "7pt", 128, args.out, layout="allgather")
            run_solver_cell("hs", "7pt", 128, args.out, layout="ring")
        else:
            run_solver_cell(
                args.variant, args.stencil, args.dofs, args.out, layout=args.layout
            )
        return

    if args.all:
        for arch in ARCHS:
            for shape_name in SHAPES:
                for mp in meshes:
                    run_cell(arch, shape_name, mp, args.out, probe=args.probe)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    for mp in meshes:
        run_cell(args.arch, args.shape, mp, args.out, probe=args.probe,
                 attn_bf16=args.attn_bf16, microbatches=args.microbatches,
                 ssm_chunk=args.ssm_chunk, tag=args.tag, ssd_bf16=args.ssd_bf16)


if __name__ == "__main__":
    main()
