"""Solve-as-a-service: a serving engine over warm ``SolverSession``s.

    python -m repro.launch.serve_solver --problem poisson7 --side 12 \\
        --shards 2 --devices 2 --requests 16 --slots 8 \\
        --ledger runs/serve.json

The paper's thesis — minimizing data movement cuts both time-to-solution
and energy — pays off most when one partitioned, format-packed, autotuned
matrix is reused across many incoming solves. This engine is that reuse
loop:

* **Sessions** — every request's matrix is fingerprinted into a
  :class:`repro.autotune.pool.SessionPool`; the warm
  :class:`repro.api.SolverSession` holds the partition(s), the autotune
  decision (``--autotune``: first request for a fingerprint tunes — or
  hits ``runs/autotune/cache.json`` — later requests are served with zero
  trials) and the compiled shard_map solver. Repeat requests therefore do
  **zero** partitions and **zero** tuning trials.
* **Slot admission** — requests queue into ``--slots`` RHS slots per
  session; a full queue flushes through the batched block-HS CG
  (``core.cg.make_block_solver``) as one width-``r`` batch: the matrix is
  streamed from HBM once per iteration for all columns. A ragged final
  batch is padded with zero RHS columns, which the deflation mask retires
  at iteration 0. ``--slots 1`` serves sequentially (the single-RHS
  comparison leg).
* **Per-request energy** — the batch's executed-energy ledger is split
  back into per-request shares via the per-column convergence iterations
  (``energy.attribution.split_block_energy``): a request pays its part of
  the setup plus its share of every iteration its column was still
  unconverged in. The shares sum to the engine total exactly.

The engine ledger (``--ledger``) records per-request rows (iters, energy,
wall latency), per-batch rows (cold/warm, new partitions, new tuning
trials), per-session counters, and throughput totals (solves/sec, p50/p99
latency, J/solve) — see docs/serving.md.

Observability (docs/observability.md): the engine keeps a
:class:`repro.obs.metrics.MetricsRegistry` — request/batch/eviction
counters, queue-depth gauge, batch-width / J-per-request / latency
histograms — snapshotted into the ledger's ``metrics`` block and written
as Prometheus text via ``--metrics-out``; ``--profile`` exports every
flushed batch's power timeline as one sequential Chrome trace.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Any, Callable

from repro.obs.log import get_logger  # stdlib-only: safe before jax

LOG = get_logger("serve")


@dataclasses.dataclass
class Request:
    """One admitted solve request (RHS vector against a session matrix)."""

    rid: int
    b: Any  # (n,) host RHS
    t_submit: float


@dataclasses.dataclass
class RequestResult:
    """One served request: solution + its slice of the batch accounting."""

    rid: int
    batch: int
    iters: int
    relres: float
    energy_j: float
    latency_s: float
    cold: bool  # True = this request paid the session's compile/tune cost
    x: Any = None  # (n,) solution (not serialized into the ledger)

    def to_ledger(self) -> dict:
        return dict(
            rid=self.rid, batch=self.batch, iters=self.iters,
            relres=self.relres, energy_j=self.energy_j,
            wall_latency_s=self.latency_s, cold=self.cold,
        )


class ServeEngine:
    """Admit solve requests, flush them through warm batched solvers.

    ``clock`` is injectable (a zero-argument callable) so the latency
    statistics are deterministic under test; defaults to
    ``time.perf_counter``. ``pool`` is injectable so engines can share
    warm sessions; defaults to a fresh :class:`SessionPool`.
    """

    def __init__(
        self,
        n_shards: int,
        *,
        slots: int = 8,
        fmt: str = "ell",
        block: int = 4,
        variant: str = "hs",
        overlap: bool = True,
        s: int | None = None,
        tol: float = 1e-8,
        maxiter: int = 200,
        autotune: bool = False,
        objective: str = "energy",
        tune_budget: int = 4,
        tune_cache: str | None = None,
        grid: tuple | None = None,
        grid_partition=None,
        pool=None,
        clock: Callable[[], float] | None = None,
        verbose: bool = False,
        collect_timelines: bool = False,
    ):
        from repro.autotune.pool import SessionPool
        from repro.obs.metrics import MetricsRegistry

        if grid is not None and autotune:
            raise ValueError(
                "--grid with --autotune is not supported: the tuner owns "
                "the layout axis (it searches grids itself at >= 8 shards)"
            )
        # 1 x N *is* the 1-D layout; normalize so the engine takes the
        # plain path (same normalization as partition_csr / api.solve)
        if grid is not None and int(grid[0]) <= 1:
            grid = None
        self.grid = (
            (int(grid[0]), int(grid[1])) if grid is not None else None
        )
        self.grid_partition = grid_partition
        self.n_shards = int(n_shards)
        self.slots = max(int(slots), 1)
        self.fmt, self.block = fmt, int(block)
        self.variant, self.overlap = variant, bool(overlap)
        if s is not None:
            from repro.api import _SSTEP_MSG, ConfigError

            if int(s) < 1:
                raise ConfigError(f"s must be >= 1: {s}")
            if variant != "sstep":
                raise ConfigError(_SSTEP_MSG)
        self.s = int(s) if s is not None else None
        self.tol, self.maxiter = float(tol), int(maxiter)
        self.autotune = bool(autotune)
        self.objective = objective
        self.tune_budget = int(tune_budget)
        self.tune_cache = tune_cache
        self.pool = pool if pool is not None else SessionPool()
        self.clock = clock if clock is not None else time.perf_counter
        self.verbose = bool(verbose)
        self.pending: dict[str, list[Request]] = {}
        # session ref per pending queue: queued requests must survive a
        # pool LRU eviction of their session, so the engine (not the pool)
        # owns the session until its queue flushes
        self._queued_sessions: dict[str, Any] = {}
        self.results: list[RequestResult] = []
        self.batches: list[dict] = []
        self._configs: dict[str, dict] = {}
        self._next_rid = 0
        # per-flush power timelines (obs.timeline), collected only when the
        # caller asked for a --profile export: building one costs a monitor
        # replay per batch
        self.collect_timelines = bool(collect_timelines)
        self.timelines: list = []
        self.metrics = MetricsRegistry()
        self._evictions_seen = 0
        self._m_requests = self.metrics.counter(
            "serve_requests_total", "solve requests admitted"
        )
        self._m_batches = self.metrics.counter(
            "serve_batches_total", "batches flushed"
        )
        self._m_cold = self.metrics.counter(
            "serve_cold_batches_total",
            "flushes that paid a compile/tune (cold) cost",
        )
        self._m_warm = self.metrics.counter(
            "serve_warm_batches_total", "flushes served fully warm"
        )
        self._m_iters = self.metrics.counter(
            "serve_iterations_total", "CG iterations executed across batches"
        )
        self._m_evict = self.metrics.counter(
            "serve_session_evictions_total", "sessions evicted by the pool LRU"
        )
        self._m_queue = self.metrics.gauge(
            "serve_queue_depth", "requests waiting across all session queues"
        )
        self._m_width = self.metrics.histogram(
            "serve_batch_width", "real (non-padding) requests per flush"
        )
        self._m_req_e = self.metrics.histogram(
            "serve_request_energy_j", "attributed dynamic energy per request"
        )
        self._m_req_lat = self.metrics.histogram(
            "serve_request_latency_s", "submit-to-solution wall latency"
        )

    # -- admission ----------------------------------------------------------

    def submit(self, a_csr, b) -> int:
        """Admit one request; flushes its session's queue when slots fill.

        Raises ``ValueError`` before admission when the RHS length does
        not match the session matrix. Returns the request id (results
        carry it)."""
        import numpy as np

        sess = self.pool.session(a_csr, self.n_shards)
        b = np.asarray(b, dtype=np.float64)
        if b.shape != (sess.n,):
            raise ValueError(
                f"RHS shape {b.shape} does not match the session matrix: "
                f"expected ({sess.n},)"
            )
        req = Request(
            rid=self._next_rid, b=b, t_submit=self.clock(),
        )
        self._next_rid += 1
        self._queued_sessions[sess.key] = sess
        q = self.pending.setdefault(sess.key, [])
        q.append(req)
        self._m_requests.inc()
        self._m_queue.set(self._queue_depth())
        if len(q) >= self.slots:
            self._flush(sess)
        return req.rid

    def _queue_depth(self) -> int:
        return sum(len(q) for q in self.pending.values())

    def drain(self):
        """Flush every partially-filled queue (ragged final batches)."""
        for key in list(self.pending):
            if self.pending[key]:
                self._flush(self._queued_sessions[key])

    def serve(self, a_csr, rhs_columns) -> list[RequestResult]:
        """Submit a request per RHS column, drain, return results by rid."""
        for b in rhs_columns:
            self.submit(a_csr, b)
        self.drain()
        return sorted(self.results, key=lambda r: r.rid)

    # -- session configuration (once per fingerprint) -----------------------

    def _session_config(self, sess) -> dict:
        """Resolve (fmt/variant/overlap/cost) for a session, tuning once.

        With ``--autotune`` the first flush for a fingerprint runs the
        two-stage autotuner at the engine's batch width (``nrhs=slots``) —
        or hits the persistent tuning cache with zero trials — and every
        later flush reuses the decision."""
        cfg = self._configs.get(sess.key)
        if cfg is not None:
            return cfg
        from repro.energy.accounting import CostModel

        cost = CostModel()
        if self.grid is not None:
            from repro.roofline.analysis import reduce_hops

            # grid collectives stage over the sub-axes (same pricing as
            # api.solve): no launch is deeper than the longer sub-axis
            cost = dataclasses.replace(
                cost,
                coll_hops=float(reduce_hops(self.n_shards, self.grid)),
            )
        fmt, block = self.fmt, self.block
        variant, overlap = self.variant, self.overlap
        sstep_s = self.s or 2  # s-step block size (used iff variant == sstep)
        tuned_label = None
        cached = None
        if self.autotune:
            tune = sess.autotune(
                objective=self.objective, budget=self.tune_budget,
                cache_path=self.tune_cache, tol=self.tol, nrhs=self.slots,
            )
            ch = tune.chosen
            fmt, block, overlap = ch.fmt, ch.block, ch.overlap
            # the batched flush path is block-HS; the variant axis only
            # matters for sequential (slots=1) serving
            variant = ch.variant if self.slots == 1 else "hs"
            if variant == "sstep":
                sstep_s = ch.s
            cost = cost.at_freq(ch.freq)
            tuned_label = ch.label
            cached = tune.cached
        cfg = dict(
            fmt=fmt, block=block, variant=variant, overlap=overlap,
            s=sstep_s, cost=cost, tuned_label=tuned_label,
            tune_cached=cached,
        )
        self._configs[sess.key] = cfg
        return cfg

    # -- flushing -----------------------------------------------------------

    def _flush(self, sess):
        import jax
        import numpy as np

        from repro.core.partition import pad_block, pad_vector, unpad_block, \
            unpad_vector
        from repro.core.spmv import matrix_axis, shard_vector
        from repro.energy import trace
        from repro.energy.attribution import split_block_energy

        reqs = self.pending.pop(sess.key, [])
        self._queued_sessions.pop(sess.key, None)
        if not reqs:
            return
        bi = len(self.batches)
        t_start = self.clock()
        p0, t0 = sess.partitions, sess.tune_trials
        cfg = self._session_config(sess)
        # a sequential sstep config solves on a halo_depth=s partition
        # (matrix-powers ghost zones); batched flushes are block-HS
        depth = cfg["s"] if (cfg["variant"] == "sstep" and
                             self.slots == 1) else 1
        mat = sess.matrix(
            cfg["fmt"], cfg["block"], grid=self.grid,
            partition=self.grid_partition, halo_depth=depth,
        )
        mesh = sess.mesh_for(mat)
        axis = matrix_axis(mat)
        r, k = self.slots, len(reqs)
        h = sess.solver(
            mat, nrhs=r, variant=cfg["variant"], tol=self.tol,
            maxiter=self.maxiter, overlap=cfg["overlap"], s=cfg["s"],
        )
        cold = not h.warmed
        led_kw = dict(
            n_shards=sess.n_shards, cost=cfg["cost"],
            overlap=cfg["overlap"], idle_s=0.01,
        )

        if r == 1:
            # sequential serving: each request is its own "batch of one"
            req = reqs[0]
            bp = shard_vector(mesh, pad_vector(req.b, mat), axis)
            x0 = shard_vector(
                mesh, np.zeros_like(pad_vector(req.b, mat)), axis
            )
            res = h.warm(bp, x0)
            if res is None:
                res = h.fn(bp, x0)
                jax.block_until_ready(res.x)
            t_done = self.clock()
            iters = int(res.iters)
            led = trace.ledger_from_trace(h.trace, iters=iters, **led_kw)
            energies = [led["totals"]["de_total"]]
            iters_out = [iters]
            rel = [float(res.rel_residual)]
            X = np.asarray(unpad_vector(np.asarray(res.x), mat))[:, None]
            batch_energy = energies[0]
            hbm_bytes = sum(
                rg["hbm_bytes"] for rg in led["regions"].values()
            )
        else:
            B = np.zeros((sess.n, r), dtype=np.float64)
            for j, req in enumerate(reqs):
                B[:, j] = req.b
            Bp = pad_block(B, mat)
            bp = shard_vector(mesh, Bp, axis)
            x0 = shard_vector(mesh, np.zeros_like(Bp), axis)
            res = h.warm(bp, x0)
            if res is None:
                res = h.fn(bp, x0)
                jax.block_until_ready(res.x)
            t_done = self.clock()
            iters = int(res.iters)
            led = trace.ledger_from_trace(h.trace, iters=iters, **led_kw)
            led0 = trace.ledger_from_trace(h.trace, iters=0, **led_kw)
            batch_energy = led["totals"]["de_total"]
            it_cols = np.asarray(res.iters_cols)
            real = np.arange(r) < k
            shares = split_block_energy(
                batch_energy, led0["totals"]["de_total"], iters, it_cols,
                real,
            )
            energies = [float(shares[j]) for j in range(k)]
            iters_out = [int(it_cols[j]) for j in range(k)]
            rel = [float(v) for v in np.asarray(res.rel_residual)[:k]]
            X = unpad_block(np.asarray(res.x), mat)
            hbm_bytes = sum(
                rg["hbm_bytes"] for rg in led["regions"].values()
            )

        for j, req in enumerate(reqs):
            self.results.append(
                RequestResult(
                    rid=req.rid, batch=bi, iters=iters_out[j], relres=rel[j],
                    energy_j=energies[j], latency_s=t_done - req.t_submit,
                    cold=cold, x=X[:, j],
                )
            )
            self._m_req_e.observe(energies[j])
            self._m_req_lat.observe(t_done - req.t_submit)
        sess.solves += k
        self._m_batches.inc()
        (self._m_cold if cold else self._m_warm).inc()
        self._m_iters.inc(iters)
        self._m_width.observe(k)
        self._m_queue.set(self._queue_depth())
        if self.collect_timelines:
            from repro.obs.timeline import build_timeline

            self.timelines.append(
                (
                    f"batch {bi}",
                    build_timeline(
                        trace.monitor_from_trace(h.trace, iters=iters, **led_kw)
                    ),
                )
            )
        self.batches.append(
            dict(
                batch=bi, size=k, slots=r, cold=cold, iters=iters,
                energy_j=batch_energy, hbm_bytes=float(hbm_bytes),
                new_partitions=sess.partitions - p0,
                new_tune_trials=sess.tune_trials - t0,
                wall_s=t_done - t_start,
            )
        )
        if self.verbose:
            b = self.batches[-1]
            LOG.info(
                "batch %d: size=%d cold=%s iters=%d DE=%.4fJ wall=%.4fs "
                "new_partitions=%d new_trials=%d",
                bi, k, cold, iters, batch_energy, b["wall_s"],
                b["new_partitions"], b["new_tune_trials"],
            )

    # -- reporting ----------------------------------------------------------

    def _sync_pool_metrics(self):
        # counters are monotonic; the pool owns the eviction count, so fold
        # in only the delta since the last snapshot
        ev = int(self.pool.stats().get("evictions", 0))
        if ev > self._evictions_seen:
            self._m_evict.inc(ev - self._evictions_seen)
            self._evictions_seen = ev

    def metrics_snapshot(self) -> dict:
        """JSON metrics snapshot (counters/gauges/histograms), pool-synced."""
        self._sync_pool_metrics()
        return self.metrics.snapshot()

    def metrics_prometheus(self) -> str:
        """Prometheus text-exposition snapshot (``--metrics-out``)."""
        self._sync_pool_metrics()
        return self.metrics.to_prometheus()

    def ledger(self) -> dict:
        """JSON-ready engine ledger; field reference in docs/serving.md."""
        import numpy as np

        from repro.obs.provenance import ledger_meta

        results = sorted(self.results, key=lambda r: r.rid)
        lat = np.array([r.latency_s for r in results], dtype=np.float64)
        total_e = float(sum(b["energy_j"] for b in self.batches))
        req_e = float(sum(r.energy_j for r in results))
        warm_b = [b for b in self.batches if not b["cold"]]
        cold_b = [b for b in self.batches if b["cold"]]

        def rate(batches):
            wall = sum(b["wall_s"] for b in batches)
            n = sum(b["size"] for b in batches)
            return (n / wall) if wall > 0 else 0.0

        wall_total = float(sum(b["wall_s"] for b in self.batches))
        n_req = len(results)
        totals = dict(
            energy_j=total_e,
            energy_requests_j=req_e,
            energy_per_solve_j=total_e / n_req if n_req else 0.0,
            iters=int(sum(b["iters"] for b in self.batches)),
            hbm_bytes=float(sum(b["hbm_bytes"] for b in self.batches)),
            wall_s=wall_total,
            solves_per_wall_sec=(n_req / wall_total) if wall_total else 0.0,
            warm_solves_per_wall_sec=rate(warm_b),
            cold_solves_per_wall_sec=rate(cold_b),
            wall_latency_p50_s=(
                float(np.percentile(lat, 50)) if n_req else 0.0
            ),
            wall_latency_p99_s=(
                float(np.percentile(lat, 99)) if n_req else 0.0
            ),
        )
        sessions = [
            dict(index=i, **s.stats())
            for i, s in enumerate(self.pool.sessions.values())
        ]
        engine = dict(
            slots=self.slots, shards=self.n_shards, format=self.fmt,
            block=self.block, variant=self.variant,
            overlap=self.overlap, tol=self.tol, maxiter=self.maxiter,
            autotune=self.autotune, objective=self.objective,
            tune_budget=self.tune_budget,
        )
        if self.grid is not None:  # absent on the 1-D path: ledgers stay
            engine["grid"] = [self.grid[0], self.grid[1]]  # byte-identical
        if self.s is not None:  # absent unless --s was given: same contract
            engine["s"] = self.s
        return dict(
            schema=1,
            meta=ledger_meta(),
            engine=engine,
            metrics=self.metrics_snapshot(),
            n_requests=n_req,
            n_batches=len(self.batches),
            cold_batches=len(cold_b),
            warm_batches=len(warm_b),
            requests=[r.to_ledger() for r in results],
            batches=list(self.batches),
            sessions=sessions,
            tuned=[
                dict(
                    index=i, tuned_label=c["tuned_label"],
                    tune_cached=c["tune_cached"],
                )
                for i, c in enumerate(self._configs.values())
            ],
            pool=self.pool.stats(),
            totals=totals,
        )


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="poisson7",
                    help="poisson7 | poisson27 | <suitesparse name>")
    ap.add_argument("--side", type=int, default=12)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--shards", type=int, default=0, help="0 = all devices")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--requests", type=int, default=16,
                    help="solve requests to stream through the engine "
                         "(deterministic RHS columns: "
                         "core.cg.default_rhs_block)")
    ap.add_argument("--slots", type=int, default=8,
                    help="RHS slots per batch: a full queue flushes as one "
                         "width-r block solve; 1 = sequential serving")
    ap.add_argument("--format", dest="fmt", default="ell",
                    choices=["auto", "ell", "hyb", "bcsr"])
    ap.add_argument("--block", type=int, default=4)
    ap.add_argument("--variant", default="hs",
                    choices=["hs", "fcg", "pipecg", "sstep"],
                    help="sequential-serving variant (batched flushes are "
                         "block-HS)")
    ap.add_argument("--s", type=int, default=None,
                    help="s-step block size (requires --variant sstep; "
                         "default 2): sequential serving solves on a "
                         "halo_depth=s matrix-powers partition")
    ap.add_argument("--no-overlap", dest="overlap", action="store_false")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--autotune", action="store_true",
                    help="first request per fingerprint tunes at the "
                         "engine's batch width (or hits the tuning cache); "
                         "later requests are served with zero trials")
    ap.add_argument("--objective", default="energy",
                    choices=["energy", "edp", "time"])
    ap.add_argument("--tune-budget", type=int, default=4)
    ap.add_argument("--tune-cache", default=None)
    ap.add_argument("--grid", default=None,
                    help="RxC process grid for the 2-D partitioned path "
                         "(R*C must equal the shard count; 1xN is the 1-D "
                         "identity; incompatible with --autotune). Poisson "
                         "problems are pencil-reordered as in launch.solve "
                         "(docs/scaling.md)")
    ap.add_argument("--ledger", default=None,
                    help="write the engine ledger JSON here")
    ap.add_argument("--profile", default=None, metavar="TRACE_JSON",
                    help="write a Chrome trace-event JSON of every flushed "
                         "batch's power timeline, laid end-to-end (open in "
                         "chrome://tracing or ui.perfetto.dev; validate "
                         "with tools/check_trace.py)")
    ap.add_argument("--metrics-out", default=None, metavar="PROM_TXT",
                    help="write the engine metrics snapshot in Prometheus "
                         "text exposition format (docs/observability.md)")
    ap.add_argument("--log-level", default=None,
                    choices=["debug", "info", "warning", "error"],
                    help="progress-output verbosity (default info, or "
                         "$REPRO_LOG); 'debug' prefixes each line with its "
                         "source logger")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    from repro.obs import log as olog

    olog.setup(args.log_level)
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.api import ProblemSpec, parse_grid, write_ledger_json
    from repro.core.cg import default_rhs_block

    spec = ProblemSpec(
        problem=args.problem, side=args.side, scale=args.scale,
        shards=args.shards,
    )
    a, name = spec.load()
    n = a.shape[0]
    n_shards = args.shards or len(jax.devices())
    grid = parse_grid(args.grid) if args.grid else None
    grid_part = None
    perm = None
    if grid is not None:
        if grid[0] * grid[1] != n_shards:
            raise SystemExit(
                f"--grid {args.grid} covers {grid[0] * grid[1]} shards; "
                f"serving with {n_shards}"
            )
        if grid[0] > 1 and args.problem.startswith("poisson"):
            # pencil reordering, exactly as api.solve (docs/scaling.md)
            from repro.core.partition import pencil_partition
            from repro.matrices import poisson as _poisson

            stencil = "7pt" if args.problem == "poisson7" else "27pt"
            perm, grid_part = pencil_partition(
                _poisson.cube(args.side, stencil), grid
            )
            a = a[perm][:, perm].tocsr()
    LOG.info(
        "serve: problem=%s n=%d nnz=%d shards=%d slots=%d requests=%d%s",
        name, n, a.nnz, n_shards, args.slots, args.requests,
        f" grid={args.grid}" if args.grid else "",
    )
    engine = ServeEngine(
        n_shards, slots=args.slots, fmt=args.fmt, block=args.block,
        variant=args.variant, overlap=args.overlap, s=args.s, tol=args.tol,
        maxiter=args.maxiter, autotune=args.autotune,
        objective=args.objective, tune_budget=args.tune_budget,
        tune_cache=args.tune_cache, grid=grid, grid_partition=grid_part,
        verbose=True, collect_timelines=bool(args.profile),
    )
    B = default_rhs_block(n, max(int(args.requests), 1))
    if perm is not None:
        # permute the RHS rows with the system so each request solves the
        # same problem as its 1-D counterpart (up to the permutation)
        B = B[perm]
    engine.serve(a, (B[:, j] for j in range(B.shape[1])))
    led = engine.ledger()
    tot = led["totals"]
    LOG.info(
        "served %d requests in %.4fs: %.2f solves/s (warm %.2f, cold %.2f) "
        "p50=%.4fs p99=%.4fs",
        led["n_requests"], tot["wall_s"], tot["solves_per_wall_sec"],
        tot["warm_solves_per_wall_sec"], tot["cold_solves_per_wall_sec"],
        tot["wall_latency_p50_s"], tot["wall_latency_p99_s"],
    )
    LOG.info(
        "energy: total=%.4fJ per-solve=%.4fJ requests-sum=%.4fJ",
        tot["energy_j"], tot["energy_per_solve_j"],
        tot["energy_requests_j"],
    )
    if args.profile and engine.timelines:
        from repro.obs.trace_export import write_chrome_trace

        write_chrome_trace(
            args.profile, engine.timelines,
            meta=dict(
                problem=name, n=n, shards=n_shards, slots=args.slots,
                requests=args.requests,
            ),
            sequential=True,
        )
        LOG.info("profile written: %s", args.profile)
    if args.metrics_out:
        d = os.path.dirname(args.metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.metrics_out, "w") as f:
            f.write(engine.metrics_prometheus())
        LOG.info("metrics written: %s", args.metrics_out)
    write_ledger_json(args.ledger, led)


if __name__ == "__main__":
    main()
