"""Mesh construction for single-pod and multi-pod deployments.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state; callers decide when the
device backend is initialized (the dry-run launcher forces 512 host devices
*before* importing anything from ``repro``).
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The production target mesh.

    Single pod: 256 chips as (data=16, model=16).
    Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
    axis is an outer pure-data axis (it only appears in gradient/optimizer
    collectives), which is what lets it scale to O(100) pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_solver_mesh(n_shards: int | None = None):
    """1-D mesh for the sparse-solver side (block-row partition).

    The paper distributes matrices in blocks of contiguous rows across all
    ranks; the JAX analog is a single flattened ``shards`` axis over every
    addressable device (or the first ``n_shards`` of them).
    """
    devs = np.asarray(jax.devices())
    if n_shards is not None:
        devs = devs[:n_shards]
    return jax.sharding.Mesh(devs, ("shards",))


def make_grid_mesh(rows: int, cols: int):
    """2-D ``(rows, cols)`` mesh for grid-partitioned solves.

    Flat shard ``s = i * cols + j`` maps to grid position ``(i, j)`` —
    sharding a leading axis with ``PartitionSpec(("rows", "cols"))`` gives
    the same flat-row-major placement as the 1-D ``shards`` mesh over the
    same devices, so the padded vector layout is identical; what the two
    named sub-axes buy is per-dimension collectives (``GridPlan`` halo
    ppermutes, hierarchical all-reduce).
    """
    devs = np.asarray(jax.devices())[: rows * cols]
    if devs.size < rows * cols:
        raise ValueError(
            f"grid {rows}x{cols} needs {rows * cols} devices; "
            f"only {devs.size} available"
        )
    return jax.sharding.Mesh(devs.reshape(rows, cols), ("rows", "cols"))


def flatten_to_solver_mesh(mesh: jax.sharding.Mesh):
    """Reinterpret a production mesh's devices as a 1-D solver mesh."""
    return jax.sharding.Mesh(mesh.devices.reshape(-1), ("shards",))
