"""End-to-end training driver.

Parses args BEFORE importing jax so ``--devices N`` can force host device
count (never set globally — see dryrun.py note).

Examples (CPU container):

    # ~100M-class model (xlstm-350m smoke-scaled up) for a few hundred steps
    python -m repro.launch.train --arch xlstm-350m --smoke --steps 300 \\
        --batch 8 --seq 256 --devices 4

    # resume after a kill: same command; restores from --ckpt-dir/LATEST
"""

from __future__ import annotations

import argparse
import os
import time


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0, help="force host devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--moment-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--dtype", default="float32")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )

    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.data.synthetic import TokenStream

    try:
        from repro.dist import checkpoint as ckpt
        from repro.dist.sharding import batch_specs, param_specs, shardings_of
    except ModuleNotFoundError as e:  # pragma: no cover
        raise SystemExit(
            f"repro.launch.train needs the repro.dist package (missing {e.name})"
        )
    from repro.models import transformer as tfm
    from repro.train.loop import make_train_step
    from repro.train.optimizer import OptConfig, init_opt_state

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, dtype=args.dtype)

    n_dev = len(jax.devices())
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()).reshape(n_dev, 1), ("data", "model")
    )
    print(f"arch={cfg.name} devices={n_dev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt_cfg = OptConfig(lr=args.lr, moment_dtype=args.moment_dtype, warmup_steps=20)
    stream = TokenStream(cfg.vocab_size, args.seq, args.batch)

    params = tfm.init_params(cfg, jax.random.key(0))
    opt_state = init_opt_state(params, opt_cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        sh = (
            shardings_of(param_specs(params, mesh), mesh),
            jax.tree.map(
                lambda x: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                opt_state,
            ),
        )
        # opt moments reuse param rules
        sh = (sh[0], {
            "mu": shardings_of(param_specs(opt_state["mu"], mesh), mesh),
            "nu": shardings_of(param_specs(opt_state["nu"], mesh), mesh),
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            "skipped": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        })
        (params, opt_state), start_step, _ = ckpt.restore(args.ckpt_dir, shardings=sh)
        print(f"restored step {start_step} from {args.ckpt_dir}")

    step_fn = make_train_step(
        cfg, opt_cfg, microbatches=args.microbatches, kv_chunk=256
    )
    p_sh = shardings_of(param_specs(params, mesh), mesh)
    o_sh = {
        "mu": shardings_of(param_specs(opt_state["mu"], mesh), mesh),
        "nu": shardings_of(param_specs(opt_state["nu"], mesh), mesh),
        "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        "skipped": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    example = stream.batch_at(0)
    b_sh = shardings_of(batch_specs(example, mesh, args.batch), mesh)
    jitted = jax.jit(
        step_fn, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None)
    )

    t_start = time.perf_counter()
    losses = []
    for step in range(start_step, args.steps):
        batch = jax.device_put(stream.batch_at(step), b_sh)
        params, opt_state, metrics = jitted(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t_start
            print(
                f"step {step+1:5d} loss {loss:8.4f} gnorm "
                f"{float(metrics['grad_norm']):8.3f} skipped "
                f"{int(metrics['skipped'])} ({dt:.1f}s)",
                flush=True,
            )
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, (params, opt_state))
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, (params, opt_state))
    print("done.")
    return losses


if __name__ == "__main__":
    main()
