"""Sparse-solver driver: the paper's workload end-to-end.

    python -m repro.launch.solve --problem poisson7 --side 32 --shards 4 \\
        --variant fcg --devices 4
    python -m repro.launch.solve --problem g3_circuit --scale 0.01 --amg

Prints runtime + iteration counts + the full energy report (powerMonitor
analog), for both the BCMGX-analog and the Ginkgo-analog paths.
"""

from __future__ import annotations

import argparse
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="poisson7",
                    help="poisson7 | poisson27 | <suitesparse name>")
    ap.add_argument("--side", type=int, default=24)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--shards", type=int, default=0, help="0 = all devices")
    ap.add_argument("--variant", default="hs", choices=["hs", "fcg", "sstep"])
    ap.add_argument("--op", default="cg", choices=["cg", "spmv"])
    ap.add_argument("--amg", action="store_true", help="PCG with AMG")
    ap.add_argument("--amgx-analog", action="store_true",
                    help="PCG with the plain-aggregation (AmgX-analog) AMG")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=1)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    import time

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core.baselines import make_naive_solver
    from repro.core.cg import make_solver
    from repro.core.partition import pad_vector, partition_csr, unpad_vector
    from repro.core.spmv import shard_matrix, shard_vector
    from repro.energy.accounting import CostModel, cg_iteration_counts
    from repro.energy.monitor import PowerMonitor
    from repro.launch.mesh import make_solver_mesh
    from repro.matrices import poisson
    from repro.matrices.suitesparse import TABLE1, load_or_generate

    n_shards = args.shards or len(jax.devices())
    mesh = make_solver_mesh(n_shards)

    if args.problem.startswith("poisson"):
        stencil = "7pt" if args.problem == "poisson7" else "27pt"
        p = poisson.cube(args.side, stencil)
        a = poisson.poisson_scipy(p)
        name = f"{stencil}-{args.side}^3"
    else:
        a = load_or_generate(args.problem, scale=args.scale)
        name = args.problem
    n = a.shape[0]
    b = np.ones(n)
    print(f"problem={name} n={n} nnz={a.nnz} shards={n_shards}")

    precond = None
    amg_info = None
    setup_time = 0.0
    if args.amg or args.amgx_analog:
        if args.amgx_analog:
            from repro.core.amg.baseline import build_amgx_analog as builder
        else:
            from repro.core.amg import build_amg as builder

        t0 = time.perf_counter()
        precond, amg_info = builder(a, n_shards)
        setup_time = time.perf_counter() - t0
        print(
            f"AMG: {amg_info.n_levels} levels rows={amg_info.level_rows} "
            f"opcx={amg_info.operator_complexity:.2f} setup={setup_time:.4f}s"
        )

    mat = shard_matrix(mesh, partition_csr(a, n_shards))
    matg = shard_matrix(mesh, partition_csr(a, n_shards, force_allgather=True))

    bp = shard_vector(mesh, pad_vector(b, mat))
    x0 = shard_vector(mesh, np.zeros_like(pad_vector(b, mat)))

    if args.op == "spmv":
        from repro.core.baselines import make_naive_spmv
        from repro.core.spmv import make_spmv
        from repro.energy.accounting import spmv_counts

        for label, m, fn in [
            ("BCMGX-analog", mat, make_spmv(mesh, mat)),
            ("Ginkgo-analog", matg, make_naive_spmv(mesh, matg)),
        ]:
            y = fn(m, bp)
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            for _ in range(100):
                y = fn(m, bp)
            jax.block_until_ready(y)
            wall = (time.perf_counter() - t0) / 100
            overlap = label == "BCMGX-analog"
            counts = spmv_counts(m, overlap)
            mon = PowerMonitor(n_devices=n_shards, cost=CostModel())
            mon.idle(0.01)
            t_model = mon.region(
                "spmv", counts, n_shards=n_shards, overlap=overlap, repeats=100
            )
            mon.idle(0.01)
            e = mon.energy()
            print(
                f"{label:14s} iters=100 relres=0.0e+00 "
                f"wall={wall:.6f}s modeled={t_model/100:.4e}s "
                f"DE={e['de_total']:.4f}J peak={e['gpu_power_peak']:.0f}W "
                f"DEgpu={e['de_gpu']:.4f}J DEcpu={e['de_cpu']:.4f}J"
            )
        return

    solver = make_solver(
        mesh, mat, variant=args.variant, precond=precond,
        tol=args.tol, maxiter=args.maxiter,
    )
    naive = make_naive_solver(mesh, matg, tol=args.tol, maxiter=args.maxiter)

    bcmgx_label = "BCMGX-analog"
    if args.amgx_analog:
        bcmgx_label = "AmgX-analog"
    for label, fn, m in [(bcmgx_label, solver, mat), ("Ginkgo-analog", naive, matg)]:
        if label == "Ginkgo-analog" and (args.amg or args.amgx_analog):
            continue  # paper compares PCG against AmgX, not Ginkgo
        res = fn(bp, x0)  # warmup/compile
        jax.block_until_ready(res.x)
        t0 = time.perf_counter()
        for _ in range(args.repeats):
            res = fn(bp, x0)
            jax.block_until_ready(res.x)
        wall = (time.perf_counter() - t0) / args.repeats
        iters = int(res.iters)
        # energy report from the powerMonitor analog
        variant = args.variant if label != "Ginkgo-analog" else "naive"
        counts = cg_iteration_counts(m, variant)
        if precond is not None:
            from repro.energy.accounting import vcycle_counts

            counts = counts + vcycle_counts(amg_info, m)
        mon = PowerMonitor(n_devices=n_shards, cost=CostModel())
        mon.idle(0.01)
        t_model = mon.region(
            "cg", counts, n_shards=n_shards,
            overlap=(label != "Ginkgo-analog"), repeats=max(iters, 1),
        )
        mon.idle(0.01)
        e = mon.energy()
        print(
            f"{label:14s} iters={iters} relres={float(res.rel_residual):.2e} "
            f"wall={wall:.4f}s modeled={t_model:.4e}s "
            f"DE={e['de_total']:.4f}J peak={e['gpu_power_peak']:.0f}W "
            f"DEgpu={e['de_gpu']:.4f}J DEcpu={e['de_cpu']:.4f}J "
            f"setup={setup_time:.4f}s solve={wall:.4f}s"
        )


if __name__ == "__main__":
    main()
