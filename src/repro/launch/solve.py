"""Sparse-solver driver: the paper's workload end-to-end.

    python -m repro.launch.solve --problem poisson7 --side 32 --shards 4 \\
        --variant fcg --devices 4
    python -m repro.launch.solve --problem g3_circuit --scale 0.01 --amg

Prints runtime + iteration counts + the full energy report, for both the
BCMGX-analog and the Ginkgo-analog paths.

Energy accounting is *executed*, not declared: the solver is compiled under
the region trace (energy/trace.py), which records the OpCounts of every
dispatched op into the component region that ran it (spmv / reductions /
halo / vcycle — plus ``overlap``, the merged interior-SpMV + in-flight-halo
phase, when the default communication-hiding schedule is on; pass
``--no-overlap`` for the serialized A/B reference). The PowerMonitor then
integrates those counts — scaled by the executed iteration count — into the
per-region energy ledger printed below the summary line and written as JSON
via ``--ledger``; ``totals.comm_exposed_s`` vs ``totals.comm_hidden_s``
quantify the hiding (schema: docs/ledger_schema.md).

``--autotune`` delegates the configuration choice (interior format, CG
variant, overlap schedule, BCSR block, DVFS frequency) to the two-stage
autotuner (``repro.autotune``, docs/autotune.md), minimizing
``--objective``; the decision lands in the ledger's ``autotune`` section
and repeat solves are served from ``runs/autotune/cache.json``.

This module is the *CLI adapter* over :mod:`repro.api`: ``parse_args``
keeps every historical flag spelling (the deprecation shim — benchmarks
and docs drive it unchanged), builds :class:`repro.api.ProblemSpec` +
:class:`repro.api.SolverConfig`, and ``main`` delegates to
:func:`repro.api.solve`, converting typed :class:`repro.api.ConfigError`
back into the historical ``SystemExit`` messages. The driver body —
partition/tune/compile through a warm ``SolverSession``, run under the
energy trace, print, write the ledger — lives in ``api.solve``; repeat
solves in one process (``--repeats``, or any caller holding the session)
reuse one compiled solver instead of re-partitioning and re-tracing.
"""

from __future__ import annotations

import argparse
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="poisson7",
                    help="poisson7 | poisson27 | <suitesparse name>")
    ap.add_argument("--side", type=int, default=24)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--shards", type=int, default=0, help="0 = all devices")
    ap.add_argument("--variant", default="hs",
                    choices=["hs", "fcg", "pipecg", "sstep"])
    ap.add_argument("--op", default="cg", choices=["cg", "spmv"])
    ap.add_argument("--no-overlap", dest="overlap", action="store_false",
                    help="serialize the halo exchange before the SpMV (and "
                         "the pipecg all-reduce before its matvec) instead "
                         "of the default communication-hiding schedule")
    ap.add_argument("--format", dest="fmt", default="ell",
                    choices=["auto", "ell", "hyb", "bcsr"],
                    help="interior storage format of the distributed matrix "
                         "(auto = stored-bytes cost model; see "
                         "docs/formats.md)")
    ap.add_argument("--block", type=int, default=4,
                    help="BCSR tile side (br = bc)")
    ap.add_argument("--autotune", action="store_true",
                    help="pick (format x variant x overlap x block x "
                         "frequency) via the two-stage autotuner "
                         "(docs/autotune.md) instead of the flags above; "
                         "repeat solves are served from the tuning cache")
    ap.add_argument("--objective", default="energy",
                    choices=["energy", "edp", "time"],
                    help="what --autotune minimizes (docs/autotune.md)")
    ap.add_argument("--tune-budget", type=int, default=6,
                    help="max executions the trial stage may budget for "
                         "(the default config always rides along, so up to "
                         "budget+1 trial solves run; candidates differing "
                         "only in frequency share one execution)")
    ap.add_argument("--tune-cache", default=None,
                    help="tuning-cache path (default runs/autotune/cache.json)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="right-hand sides per solve; > 1 runs the batched "
                         "block-CG (core/cg.make_block_solver): the matrix "
                         "is streamed once per iteration for all RHS "
                         "columns (docs/solvers.md). Requires --op cg, "
                         "--variant hs, no AMG")
    ap.add_argument("--s", type=int, default=None,
                    help="s-step block size (requires --variant sstep; "
                         "default 2): partitions with halo_depth=s ghost "
                         "zones so the matrix-powers basis pays ONE "
                         "widened halo exchange and one fused Gram "
                         "reduction per s iterations (docs/solvers.md)")
    ap.add_argument("--grid", default=None,
                    help="RxC process grid for the 2-D partitioned CG path "
                         "(R*C must equal the shard count; 1xN reproduces "
                         "the 1-D layout exactly). Poisson problems are "
                         "pencil-reordered so the halo scales with the "
                         "pencil surface (docs/scaling.md)")
    ap.add_argument("--amg", action="store_true", help="PCG with AMG")
    ap.add_argument("--amgx-analog", action="store_true",
                    help="PCG with the plain-aggregation (AmgX-analog) AMG")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--ledger", default=None,
                    help="write the executed energy/time ledger JSON here")
    ap.add_argument("--telemetry", action="store_true",
                    help="record per-iteration convergence telemetry "
                         "(residual history via host callback) into the "
                         "ledger's 'telemetry' block "
                         "(docs/observability.md)")
    ap.add_argument("--profile", default=None, metavar="TRACE_JSON",
                    help="write a Chrome trace-event JSON of the executed "
                         "legs' power timelines (open in chrome://tracing "
                         "or ui.perfetto.dev; validate with "
                         "tools/check_trace.py)")
    ap.add_argument("--log-level", default=None,
                    choices=["debug", "info", "warning", "error"],
                    help="progress-output verbosity (default info, or "
                         "$REPRO_LOG); 'debug' prefixes each line with its "
                         "source logger")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    from repro.obs import log as olog

    olog.setup(args.log_level)
    # import AFTER the device-count env var is set (api.solve imports jax)
    from repro import api

    try:
        spec = api.ProblemSpec.from_args(args)
        config = api.SolverConfig.from_args(args)
        api.solve(spec, config, ledger=args.ledger, profile=args.profile)
    except api.ConfigError as e:
        # the historical argparse-era behavior: message on stderr, exit 1
        raise SystemExit(str(e)) from e


if __name__ == "__main__":
    main()
