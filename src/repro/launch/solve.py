"""Sparse-solver driver: the paper's workload end-to-end.

    python -m repro.launch.solve --problem poisson7 --side 32 --shards 4 \\
        --variant fcg --devices 4
    python -m repro.launch.solve --problem g3_circuit --scale 0.01 --amg

Prints runtime + iteration counts + the full energy report, for both the
BCMGX-analog and the Ginkgo-analog paths.

Energy accounting is *executed*, not declared: the solver is compiled under
the region trace (energy/trace.py), which records the OpCounts of every
dispatched op into the component region that ran it (spmv / reductions /
halo / vcycle — plus ``overlap``, the merged interior-SpMV + in-flight-halo
phase, when the default communication-hiding schedule is on; pass
``--no-overlap`` for the serialized A/B reference). The PowerMonitor then
integrates those counts — scaled by the executed iteration count — into the
per-region energy ledger printed below the summary line and written as JSON
via ``--ledger``; ``totals.comm_exposed_s`` vs ``totals.comm_hidden_s``
quantify the hiding (schema: docs/ledger_schema.md).

``--autotune`` delegates the configuration choice (interior format, CG
variant, overlap schedule, BCSR block, DVFS frequency) to the two-stage
autotuner (``repro.autotune``, docs/autotune.md), minimizing
``--objective``; the decision lands in the ledger's ``autotune`` section
and repeat solves are served from ``runs/autotune/cache.json``.
"""

from __future__ import annotations

import argparse
import json
import os


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="poisson7",
                    help="poisson7 | poisson27 | <suitesparse name>")
    ap.add_argument("--side", type=int, default=24)
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--shards", type=int, default=0, help="0 = all devices")
    ap.add_argument("--variant", default="hs",
                    choices=["hs", "fcg", "pipecg", "sstep"])
    ap.add_argument("--op", default="cg", choices=["cg", "spmv"])
    ap.add_argument("--no-overlap", dest="overlap", action="store_false",
                    help="serialize the halo exchange before the SpMV (and "
                         "the pipecg all-reduce before its matvec) instead "
                         "of the default communication-hiding schedule")
    ap.add_argument("--format", dest="fmt", default="ell",
                    choices=["auto", "ell", "hyb", "bcsr"],
                    help="interior storage format of the distributed matrix "
                         "(auto = stored-bytes cost model; see "
                         "docs/formats.md)")
    ap.add_argument("--block", type=int, default=4,
                    help="BCSR tile side (br = bc)")
    ap.add_argument("--autotune", action="store_true",
                    help="pick (format x variant x overlap x block x "
                         "frequency) via the two-stage autotuner "
                         "(docs/autotune.md) instead of the flags above; "
                         "repeat solves are served from the tuning cache")
    ap.add_argument("--objective", default="energy",
                    choices=["energy", "edp", "time"],
                    help="what --autotune minimizes (docs/autotune.md)")
    ap.add_argument("--tune-budget", type=int, default=6,
                    help="max executions the trial stage may budget for "
                         "(the default config always rides along, so up to "
                         "budget+1 trial solves run; candidates differing "
                         "only in frequency share one execution)")
    ap.add_argument("--tune-cache", default=None,
                    help="tuning-cache path (default runs/autotune/cache.json)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="right-hand sides per solve; > 1 runs the batched "
                         "block-CG (core/cg.make_block_solver): the matrix "
                         "is streamed once per iteration for all RHS "
                         "columns (docs/solvers.md). Requires --op cg, "
                         "--variant hs, no AMG")
    ap.add_argument("--amg", action="store_true", help="PCG with AMG")
    ap.add_argument("--amgx-analog", action="store_true",
                    help="PCG with the plain-aggregation (AmgX-analog) AMG")
    ap.add_argument("--tol", type=float, default=1e-8)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--ledger", default=None,
                    help="write the executed energy/time ledger JSON here")
    return ap.parse_args(argv)


def _print_regions(label: str, ledger: dict):
    for name, r in sorted(ledger["regions"].items()):
        print(
            f"  [{label}] region {name:12s} t={r['time_s']:.4e}s "
            f"DE={r['de_j']:.4f}J flops={r['flops']:.3e} "
            f"hbm={r['hbm_bytes']:.3e}B ici={r['ici_bytes']:.3e}B"
        )


def _write_ledger(path: str | None, payload: dict):
    if not path:
        return
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    # atomic: a reader (or a killed run) never sees a half-written ledger
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    print(f"ledger written: {path}")


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        )
    import time

    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core.baselines import make_naive_solver
    from repro.core.cg import default_rhs_block, make_block_solver, make_solver
    from repro.core.partition import pad_block, pad_vector, partition_csr
    from repro.core.spmv import shard_matrix, shard_vector
    from repro.energy import trace
    from repro.energy.accounting import CostModel
    from repro.launch.mesh import make_solver_mesh
    from repro.matrices import poisson
    from repro.matrices.suitesparse import load_or_generate

    n_shards = args.shards or len(jax.devices())
    mesh = make_solver_mesh(n_shards)

    if args.problem.startswith("poisson"):
        stencil = "7pt" if args.problem == "poisson7" else "27pt"
        p = poisson.cube(args.side, stencil)
        a = poisson.poisson_scipy(p)
        name = f"{stencil}-{args.side}^3"
    else:
        a = load_or_generate(args.problem, scale=args.scale)
        name = args.problem
    n = a.shape[0]
    b = np.ones(n)
    nrhs = max(int(args.nrhs), 1)
    if nrhs > 1 and (
        args.op != "cg" or args.amg or args.amgx_analog
        or args.variant != "hs"
    ):
        raise SystemExit(
            "--nrhs > 1 runs the batched block-HS CG: requires --op cg, "
            "--variant hs, and no --amg/--amgx-analog"
        )
    print(f"problem={name} n={n} nnz={a.nnz} shards={n_shards} nrhs={nrhs}")

    cost = CostModel()
    tune = None
    tune_mats: dict = {}
    if args.autotune:
        if args.op != "cg" or args.amg or args.amgx_analog:
            raise SystemExit(
                "--autotune tunes the unpreconditioned CG path "
                "(--op cg without --amg/--amgx-analog)"
            )
        from repro.autotune import DEFAULT_PATH
        from repro.autotune import autotune as run_autotune

        tune = run_autotune(
            a, mesh, n_shards, objective=args.objective,
            budget=args.tune_budget,
            cache_path=args.tune_cache or DEFAULT_PATH, tol=args.tol,
            mats=tune_mats, nrhs=nrhs,
        )
        ch = tune.chosen
        args.fmt, args.block = ch.fmt, ch.block
        args.variant, args.overlap = ch.variant, ch.overlap
        cost = cost.at_freq(ch.freq)
        print(
            f"autotune: objective={tune.objective} chosen={ch.label} "
            f"cached={tune.cached} trialed={tune.candidates_trialed} "
            f"(space {tune.candidates_total})"
        )

    payload = dict(
        schema=1, problem=name, n=int(n), nnz=int(a.nnz),
        shards=int(n_shards), op=args.op, overlap=bool(args.overlap),
        format=args.fmt, nrhs=nrhs, solvers={},
    )
    if tune is not None:
        payload["autotune"] = tune.ledger_section()

    precond = None
    amg_info = None
    setup_time = 0.0
    if args.amg or args.amgx_analog:
        from repro.core.amg import make_amg_preconditioner

        t0 = time.perf_counter()
        precond, amg_info = make_amg_preconditioner(
            a, n_shards, amgx_analog=args.amgx_analog
        )
        setup_time = time.perf_counter() - t0
        print(
            f"AMG: {amg_info.n_levels} levels rows={amg_info.level_rows} "
            f"opcx={amg_info.operator_complexity:.2f} setup={setup_time:.4f}s"
        )
        payload["amg"] = dict(
            n_levels=amg_info.n_levels,
            level_rows=list(amg_info.level_rows),
            level_nnz=list(amg_info.level_nnz),
            operator_complexity=amg_info.operator_complexity,
        )

    # The autotune trials already partitioned the winner's format — reuse
    # that sharded DistMat instead of re-packing it.
    mat = tune_mats.get((args.fmt, args.block))
    if mat is None:
        mat = shard_matrix(
            mesh,
            partition_csr(
                a, n_shards, fmt=args.fmt, block=(args.block, args.block)
            ),
        )
    # The Ginkgo-analog baseline keeps the flat ELL layout by definition;
    # only build its (expensive) padded-global partition when a naive leg
    # will actually run — the format sweep (--format != ell), the AMG
    # comparisons, and the tuned path (whose comparison legs are the
    # autotune trials themselves) never consume it.
    need_naive = (
        mat.fmt == "ell"  # resolved format: --format auto may pick ELL
        if args.op == "spmv"
        # the naive baseline is single-RHS by definition: the batched
        # path's comparison legs are sequential nrhs=1 runs of this driver
        # (benchmarks/multirhs_scaling.py)
        else not (args.amg or args.amgx_analog or args.autotune or nrhs > 1)
    )
    matg = (
        shard_matrix(mesh, partition_csr(a, n_shards, force_allgather=True))
        if need_naive
        else None
    )
    print(
        f"format={mat.fmt} (requested {args.fmt}) "
        f"interior_bytes={mat.interior_stored_bytes()} "
        f"stored_bytes={mat.stored_bytes()}"
    )
    payload["resolved_format"] = mat.fmt
    payload["interior_stored_bytes"] = int(mat.interior_stored_bytes())
    payload["stored_bytes"] = int(mat.stored_bytes())

    if nrhs > 1:
        Bpad = pad_block(default_rhs_block(n, nrhs), mat)
        bp = shard_vector(mesh, Bpad)
        x0 = shard_vector(mesh, np.zeros_like(Bpad))
    else:
        bp = shard_vector(mesh, pad_vector(b, mat))
        x0 = shard_vector(mesh, np.zeros_like(pad_vector(b, mat)))

    if args.op == "spmv":
        from repro.core.baselines import make_naive_spmv
        from repro.core.spmv import make_spmv

        legs = [
            ("BCMGX-analog", mat, make_spmv(mesh, mat, overlap=args.overlap)),
        ]
        if need_naive:
            legs.append(("Ginkgo-analog", matg, make_naive_spmv(mesh, matg)))
        for label, m, fn in legs:
            with trace.capture() as tr:
                y = fn(m, bp)  # compile: executed counts recorded
            jax.block_until_ready(y)
            t0 = time.perf_counter()
            for _ in range(100):
                # sync every launch: keeps exactly one execution in flight,
                # so the per-run collective rendezvous can't interleave with
                # the next launch's (XLA CPU spin-waits; on a starved host
                # two in-flight ppermute rounds can livelock each other)
                jax.block_until_ready(fn(m, bp))
            wall = (time.perf_counter() - t0) / 100
            overlap = args.overlap and label == "BCMGX-analog"
            led = trace.ledger_from_trace(
                tr, iters=0, n_shards=n_shards, cost=cost, overlap=overlap,
                idle_s=0.01, setup_repeats=100,
            )
            e = led["totals"]
            t_model = sum(r["time_s"] for r in led["regions"].values())
            print(
                f"{label:14s} iters=100 relres=0.0e+00 "
                f"wall={wall:.6f}s modeled={t_model/100:.4e}s "
                f"DE={e['de_total']:.4f}J peak={e['gpu_power_peak']:.0f}W "
                f"DEgpu={e['de_gpu']:.4f}J DEcpu={e['de_cpu']:.4f}J"
            )
            _print_regions(label, led)
            payload["solvers"][label] = dict(
                led, wall_s=wall, modeled_s=t_model / 100
            )
        _write_ledger(args.ledger, payload)
        return

    if nrhs > 1:
        solver = make_block_solver(
            mesh, mat, tol=args.tol, maxiter=args.maxiter,
            overlap=args.overlap,
        )
    else:
        solver = make_solver(
            mesh, mat, variant=args.variant, precond=precond,
            tol=args.tol, maxiter=args.maxiter, overlap=args.overlap,
        )
    legs = [("BCMGX-analog" if not args.amgx_analog else "AmgX-analog",
             solver)]
    if need_naive:  # paper compares PCG against AmgX, not Ginkgo
        legs.append(
            ("Ginkgo-analog",
             make_naive_solver(mesh, matg, tol=args.tol,
                               maxiter=args.maxiter))
        )
    bcmgx_label = legs[0][0]
    for label, fn in legs:
        with trace.capture() as tr:
            res = fn(bp, x0)  # warmup/compile: executed counts recorded
        jax.block_until_ready(res.x)
        walls = []
        for _ in range(args.repeats):
            t0 = time.perf_counter()
            res = fn(bp, x0)
            jax.block_until_ready(res.x)
            walls.append(time.perf_counter() - t0)
        wall = sum(walls) / len(walls)
        iters = int(res.iters)
        # the batched leg converges each column independently: report the
        # slowest column's residual (convergence of the whole batch)
        relres = float(np.max(np.asarray(res.rel_residual)))
        # energy ledger: executed per-region counts x executed iterations
        led = trace.ledger_from_trace(
            tr, iters=iters, n_shards=n_shards, cost=cost,
            overlap=(args.overlap and label != "Ginkgo-analog"), idle_s=0.01,
        )
        e = led["totals"]
        t_model = sum(r["time_s"] for r in led["regions"].values())
        matrix_bytes = sum(
            r.get("hbm_matrix_bytes", 0.0) for r in led["regions"].values()
        )
        print(
            f"{label:14s} iters={iters} relres={relres:.2e} "
            f"wall={wall:.4f}s modeled={t_model:.4e}s "
            f"DE={e['de_total']:.4f}J peak={e['gpu_power_peak']:.0f}W "
            f"DEgpu={e['de_gpu']:.4f}J DEcpu={e['de_cpu']:.4f}J "
            f"setup={setup_time:.4f}s solve={wall:.4f}s"
        )
        _print_regions(label, led)
        entry = dict(
            led, wall_s=wall, modeled_s=t_model,
            relres=relres, setup_s=setup_time,
            variant=args.variant if label == bcmgx_label else "naive",
            # per-solve amortization view: a batched run is nrhs solves
            nrhs=nrhs,
            per_solve_modeled_s=t_model / nrhs,
            per_solve_de_j=e["de_total"] / nrhs,
            per_solve_spmv_matrix_bytes=matrix_bytes / nrhs,
            wall_repeats_s=walls,
            per_solve_wall_s=wall / nrhs,
        )
        if nrhs > 1:
            entry["iters_cols"] = [
                int(v) for v in np.asarray(res.iters_cols)
            ]
        payload["solvers"][label] = entry
    _write_ledger(args.ledger, payload)


if __name__ == "__main__":
    main()
