"""3-D Poisson benchmark matrices (7-point and 27-point stencils).

These mirror the paper's benchmark problems: the 3-D Poisson equation with
homogeneous Dirichlet boundary conditions on a uniform mesh, discretized with
a 7-point stencil (classical FD Laplacian: diag 6, neighbors -1) or the
HPCG-style 27-point stencil (diag 26, all 26 neighbors -1).

Two build paths:

* ``poisson_scipy`` — global scipy CSR, host-side, for small problems / AMG
  setup / oracles.
* ``local_stencil_ell`` — builds ONLY the rows owned by one shard of a slab
  (z-plane) partition, directly in numpy, vectorized, never materializing the
  global matrix. This is what makes O(1e10)-DOF weak-scaling configurations
  describable: per-shard cost is O(n_local * k). Column indices are local
  int32 offsets into ``x_ext = [halo_lo | x_own | halo_hi]`` with halo width
  H = nx*ny (one plane each side — both stencils reach exactly +-1 plane).
"""

from __future__ import annotations

import dataclasses

import numpy as np

STENCILS = ("7pt", "27pt")


def stencil_offsets(stencil: str) -> np.ndarray:
    """(k, 3) integer offsets, diagonal entry first."""
    if stencil == "7pt":
        offs = [(0, 0, 0), (-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)]
    elif stencil == "27pt":
        offs = [(0, 0, 0)] + [
            (dx, dy, dz)
            for dz in (-1, 0, 1)
            for dy in (-1, 0, 1)
            for dx in (-1, 0, 1)
            if (dx, dy, dz) != (0, 0, 0)
        ]
    else:
        raise ValueError(f"unknown stencil {stencil!r}")
    return np.asarray(offs, dtype=np.int64)


def stencil_diag(stencil: str) -> float:
    return 6.0 if stencil == "7pt" else 26.0


def stencil_values(p) -> np.ndarray:
    """Per-offset stencil coefficients, diagonal first (matches
    ``stencil_offsets`` ordering). Honors 7-point anisotropy."""
    offs = stencil_offsets(p.stencil)
    if p.stencil == "7pt":
        ax, ay, az = p.aniso
        per_axis = np.array([ax, ay, az])
        vals = np.empty(len(offs))
        vals[0] = 2.0 * (ax + ay + az)
        for i, off in enumerate(offs[1:], start=1):
            axis = int(np.nonzero(off)[0][0])
            vals[i] = -per_axis[axis]
        return vals
    # 27pt: HPCG-style uniform stencil (diag 26, neighbors -1).
    vals = np.full(len(offs), -1.0)
    vals[0] = 26.0
    return vals


@dataclasses.dataclass(frozen=True)
class PoissonProblem:
    """Global problem description (no data).

    ``aniso`` = per-axis diffusion coefficients (ax, ay, az); only meaningful
    for the 7-point stencil (27-point is the HPCG-style uniform stencil).
    Anisotropy differentiates the compatible-weighted matching from plain
    strength matching (the AmgX-analog comparison).
    """

    nx: int
    ny: int
    nz: int
    stencil: str  # "7pt" | "27pt"
    aniso: tuple[float, float, float] = (1.0, 1.0, 1.0)

    @property
    def n(self) -> int:
        return self.nx * self.ny * self.nz

    @property
    def k(self) -> int:
        return 7 if self.stencil == "7pt" else 27

    @property
    def plane(self) -> int:
        return self.nx * self.ny

    @property
    def nnz_estimate(self) -> int:
        # interior rows have k entries; boundary fewer. Upper bound:
        return self.n * self.k


def cube(n_side: int, stencil: str = "7pt") -> PoissonProblem:
    return PoissonProblem(n_side, n_side, n_side, stencil)


def weak_scaled(base: PoissonProblem, n_shards: int) -> PoissonProblem:
    """Weak scaling: extrude the domain along z (paper: local size constant)."""
    return dataclasses.replace(base, nz=base.nz * n_shards)


def _slab_rows(p: PoissonProblem, shard: int, n_shards: int) -> tuple[int, int]:
    """Contiguous z-plane range owned by ``shard`` (balanced; nz >= n_shards)."""
    if p.nz < n_shards:
        raise ValueError(f"cannot slab-partition nz={p.nz} over {n_shards} shards")
    zs = np.linspace(0, p.nz, n_shards + 1).astype(np.int64)
    return int(zs[shard]), int(zs[shard + 1])


def local_stencil_ell(
    p: PoissonProblem,
    shard: int,
    n_shards: int,
    dtype=np.float64,
    uniform_rows: int | None = None,
):
    """Build the local ELL block for one shard of a z-slab partition.

    Returns (data, col, meta) with
      data: (n_rows_padded, k) float
      col : (n_rows_padded, k) int32 — indices into x_ext of length
            H + n_own + H, H = nx*ny.  Padded slots: data=0, col=0.
      meta: dict(z0, z1, n_own, halo=H)

    ``uniform_rows`` pads the row count so every shard has identical shapes
    (required to stack shard blocks into one sharded global array).
    """
    z0, z1 = _slab_rows(p, shard, n_shards)
    n_own = (z1 - z0) * p.plane
    H = p.plane
    offs = stencil_offsets(p.stencil)
    k = len(offs)

    # Global coordinates of owned DOFs, lexicographic x-fastest.
    zz, yy, xx = np.meshgrid(
        np.arange(z0, z1), np.arange(p.ny), np.arange(p.nx), indexing="ij"
    )
    coords = np.stack([xx.ravel(), yy.ravel(), zz.ravel()], axis=1)  # (n_own, 3)

    nbr = coords[:, None, :] + offs[None, :, :]  # (n_own, k, 3)
    valid = (
        (nbr[..., 0] >= 0)
        & (nbr[..., 0] < p.nx)
        & (nbr[..., 1] >= 0)
        & (nbr[..., 1] < p.ny)
        & (nbr[..., 2] >= 0)
        & (nbr[..., 2] < p.nz)
    )
    gcol = nbr[..., 0] + p.nx * (nbr[..., 1] + p.ny * nbr[..., 2])
    r0 = z0 * p.plane
    lcol = gcol - r0 + H  # into x_ext
    lcol = np.where(valid, lcol, 0).astype(np.int32)

    diag = stencil_diag(p.stencil)
    vals = np.where((offs == 0).all(axis=1)[None, :], diag, -1.0)
    data = (np.broadcast_to(vals, (n_own, k)) * valid).astype(dtype)

    if uniform_rows is not None and uniform_rows > n_own:
        pad = uniform_rows - n_own
        data = np.concatenate([data, np.zeros((pad, k), dtype)])
        lcol = np.concatenate([lcol, np.zeros((pad, k), np.int32)])
    meta = dict(z0=z0, z1=z1, n_own=n_own, halo=H)
    return data, lcol, meta


def poisson_scipy(p: PoissonProblem, dtype=np.float64):
    """Global scipy CSR (host; small problems only)."""
    import scipy.sparse as sp

    n = p.n
    offs = stencil_offsets(p.stencil)
    zz, yy, xx = np.meshgrid(
        np.arange(p.nz), np.arange(p.ny), np.arange(p.nx), indexing="ij"
    )
    coords = np.stack([xx.ravel(), yy.ravel(), zz.ravel()], axis=1)
    rows, cols, vals = [], [], []
    svals = stencil_values(p)
    base = np.arange(n, dtype=np.int64)
    for oi, off in enumerate(offs):
        nbr = coords + off[None, :]
        valid = (
            (nbr[:, 0] >= 0)
            & (nbr[:, 0] < p.nx)
            & (nbr[:, 1] >= 0)
            & (nbr[:, 1] < p.ny)
            & (nbr[:, 2] >= 0)
            & (nbr[:, 2] < p.nz)
        )
        gcol = nbr[:, 0] + p.nx * (nbr[:, 1] + p.ny * nbr[:, 2])
        rows.append(base[valid])
        cols.append(gcol[valid])
        vals.append(np.full(valid.sum(), svals[oi], dtype))
    a = sp.coo_matrix(
        (np.concatenate(vals), (np.concatenate(rows), np.concatenate(cols))), shape=(n, n)
    )
    return a.tocsr()


def default_rhs(n: int, dtype=np.float64, kind: str = "ones") -> np.ndarray:
    if kind == "ones":
        return np.ones(n, dtype)
    if kind == "rand":
        return np.random.default_rng(0).standard_normal(n).astype(dtype)
    raise ValueError(kind)
