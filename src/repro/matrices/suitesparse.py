"""Synthetic analogs of the paper's SuiteSparse SPD test matrices (Table 1).

The SuiteSparse collection is not reachable offline, so we generate SPD
matrices matched on the characteristics the paper's analysis keys on: row
count, average nnz/row, and sparsity-pattern *character* (regular band vs
irregular / long-range couplings), which drives the communication behavior
the paper observes (e.g. G3_circuit scaling poorly, boneS10 scaling well).

Every generator takes ``scale`` (fraction of the original row count) so the
full-size patterns are describable while CPU-run benchmarks stay tractable.
If real MatrixMarket files are present under $REPRO_SUITESPARSE_DIR they are
loaded instead (see ``matrices/io.py``).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp


@dataclasses.dataclass(frozen=True)
class MatrixInfo:
    name: str
    rows: int
    nnz: int
    avg_nnz_row: float
    character: str  # "irregular" | "band" | "grid"


# Paper Table 1.
TABLE1 = {
    "G3_circuit": MatrixInfo("G3_circuit", 1585478, 7660826, 4.8, "irregular"),
    "af_shell8": MatrixInfo("af_shell8", 504855, 17579155, 34.8, "band"),
    "boneS10": MatrixInfo("boneS10", 914898, 40878708, 44.7, "band"),
    "ecology2": MatrixInfo("ecology2", 999999, 4995991, 5.0, "grid"),
    "parabolic_fem": MatrixInfo("parabolic_fem", 525825, 3674625, 7.0, "grid"),
}


def _spd_from_pattern(rows, cols, vals, n, dtype):
    """Symmetrize and make strictly diagonally dominant (hence SPD)."""
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    a = (a + a.T) * 0.5
    a.setdiag(0)
    a.eliminate_zeros()
    rowsum = np.abs(a).sum(axis=1).A.ravel()
    d = rowsum + 1.0  # strict dominance margin
    a = a + sp.diags(d)
    return a.tocsr().astype(dtype)


def _grid2d(nx: int, ny: int, k: int, rng, dtype):
    """2-D grid Laplacian-like SPD pattern with k-point stencil (5 or 7)."""
    n = nx * ny
    if k == 5:
        offs = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    elif k == 7:  # hex/triangular FEM-like
        offs = [(-1, 0), (1, 0), (0, -1), (0, 1), (1, 1), (-1, -1)]
    else:
        raise ValueError(k)
    yy, xx = np.meshgrid(np.arange(ny), np.arange(nx), indexing="ij")
    base = (yy * nx + xx).ravel()
    rows, cols, vals = [], [], []
    for dx, dy in offs:
        nxx, nyy = xx + dx, yy + dy
        valid = ((nxx >= 0) & (nxx < nx) & (nyy >= 0) & (nyy < ny)).ravel()
        rows.append(base[valid])
        cols.append((nyy * nx + nxx).ravel()[valid])
        vals.append(-rng.uniform(0.2, 1.8, int(valid.sum())))
    return _spd_from_pattern(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n, dtype
    )


def _banded(n: int, nnz_row: int, band: int, rng, dtype):
    """Regular banded SPD pattern: ~nnz_row fixed offsets within +-band."""
    half = (nnz_row - 1) // 2
    near = [o for o in range(1, min(half, band) + 1)]
    far_needed = half - len(near)
    far = list(np.unique(rng.integers(2, band + 1, size=max(far_needed * 2, 1))))[:far_needed]
    offsets = sorted(set(near + far))
    rows, cols, vals = [], [], []
    base = np.arange(n, dtype=np.int64)
    for o in offsets:
        valid = base + o < n
        rows.append(base[valid])
        cols.append(base[valid] + o)
        vals.append(-rng.uniform(0.2, 1.8, int(valid.sum())))
    return _spd_from_pattern(
        np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n, dtype
    )


def _irregular(n: int, nnz_row: float, rng, dtype):
    """Circuit-like irregular pattern: mostly local + a tail of long edges."""
    m_local = int(n * (nnz_row - 1) * 0.40)  # off-diag halves
    m_far = int(n * (nnz_row - 1) * 0.10)
    r_loc = rng.integers(0, n - 1, m_local)
    c_loc = np.minimum(n - 1, r_loc + rng.integers(1, 16, m_local))
    r_far = rng.integers(0, n, m_far)
    c_far = rng.integers(0, n, m_far)
    keep = r_far != c_far
    rows = np.concatenate([r_loc, r_far[keep]])
    cols = np.concatenate([c_loc, c_far[keep]])
    vals = -rng.uniform(0.2, 1.8, len(rows))
    return _spd_from_pattern(rows, cols, vals, n, dtype)


def _powerlaw(n: int, rng, dtype):
    """Power-law (hub-dominated) SPD pattern: most rows carry a handful of
    near-diagonal couplings, a Zipf-tail of hub rows reaches a large
    neighborhood — the ``max_row_nnz >> median`` regime where one long row
    inflates the padded-ELL layout on every shard (the HYB format's target
    workload; see docs/formats.md)."""
    # base band: 2 off-diagonal couplings per row
    base = np.arange(n - 1, dtype=np.int64)
    rows = [base]
    cols = [base + 1]
    # Zipf-distributed extra degree, capped so hubs stay local-ish
    extra = np.minimum(rng.zipf(1.5, n), max(n // 4, 4)).astype(np.int64)
    hubs = np.nonzero(extra > 2)[0]
    for h in hubs:
        m = int(extra[h])
        tgt = rng.integers(0, n, m)
        tgt = tgt[tgt != h]
        rows.append(np.full(len(tgt), h, np.int64))
        cols.append(tgt)
    r = np.concatenate(rows)
    c = np.concatenate(cols)
    vals = -rng.uniform(0.2, 1.8, len(r))
    return _spd_from_pattern(r, c, vals, n, dtype)


# Beyond-Table-1 synthetic: the format sweep's power-law stress pattern.
POWERLAW = MatrixInfo("powerlaw", 20000, 140000, 7.0, "powerlaw")


def generate(name: str, scale: float = 1.0, dtype=np.float64, seed: int = 0):
    """Generate the synthetic analog of a Table-1 matrix (or the
    ``powerlaw`` stress pattern) at ``scale``."""
    if name == "powerlaw":
        rng = np.random.default_rng(seed)
        return _powerlaw(max(64, int(POWERLAW.rows * scale)), rng, dtype)
    info = TABLE1[name]
    rng = np.random.default_rng(seed)
    n = max(64, int(info.rows * scale))
    if name == "ecology2":  # genuinely a 2-D 5-pt grid Laplacian
        side = max(8, int(np.sqrt(n)))
        return _grid2d(side, side, 5, rng, dtype)
    if name == "parabolic_fem":  # 2-D FEM, 7 nnz/row
        side = max(8, int(np.sqrt(n)))
        return _grid2d(side, side, 7, rng, dtype)
    if name == "G3_circuit":
        return _irregular(n, info.avg_nnz_row, rng, dtype)
    if name == "af_shell8":
        return _banded(n, int(round(info.avg_nnz_row)), max(16, int(np.sqrt(n))), rng, dtype)
    if name == "boneS10":
        return _banded(n, int(round(info.avg_nnz_row)), max(24, int(np.sqrt(n))), rng, dtype)
    raise KeyError(name)


def load_or_generate(name: str, scale: float = 1.0, dtype=np.float64):
    """Prefer a real MatrixMarket file if $REPRO_SUITESPARSE_DIR provides it."""
    import os

    d = os.environ.get("REPRO_SUITESPARSE_DIR")
    if d:
        path = os.path.join(d, f"{name}.mtx")
        if os.path.exists(path):
            from scipy.io import mmread

            return sp.csr_matrix(mmread(path)).astype(dtype)
    return generate(name, scale=scale, dtype=dtype)
