"""AdamW with optional reduced-precision moments (distributed-friendly).

Optimizer state mirrors the parameter pytree, so the FSDP sharding rules in
dist/sharding.py apply verbatim (ZeRO-style sharded optimizer). ``bf16
moments`` halve optimizer memory — a standard large-scale trick; the first
moment keeps an f32 master only when requested.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer memory
    warmup_steps: int = 100
    grad_clip: float = 1.0


def _mdt(cfg: OptConfig):
    return jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32


def init_opt_state(params, cfg: OptConfig):
    mdt = _mdt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
        "skipped": jnp.zeros((), jnp.int32),  # NaN-guard counter (fault.py)
    }


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_opt_state, grad_norm)."""
    mdt = _mdt(cfg)
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu_new = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_new = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mu_new / bc1
        vhat = nu_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), mu_new.astype(mdt), nu_new.astype(mdt)

    out = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {
        "mu": new_mu,
        "nu": new_nu,
        "step": step,
        "skipped": opt_state["skipped"],
    }
    return new_params, new_state, gnorm
