"""Training step + loop: microbatch accumulation, NaN-skip, checkpointing.

``make_train_step`` builds the jitted SPMD step:

* loss/grad over ``microbatches`` gradient-accumulation slices
  (``lax.scan``; activation memory scales with the slice, not the global
  batch);
* collectives are GSPMD-inserted from the param/batch shardings (DP
  gradient reduction, FSDP all-gathers, TP reductions);
* NaN/Inf guard: a non-finite loss or gradient norm skips the optimizer
  update (params/opt state pass through) and bumps ``opt_state["skipped"]``
  — the in-step half of the fault story (dist/fault.py has the host side).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import flags

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.train.optimizer import OptConfig, adamw_update, global_norm


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OptConfig,
    *,
    microbatches: int = 1,
    kv_chunk: int = 1024,
    remat: bool = True,
    nan_guard: bool = True,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_of(params, batch):
        return lm.loss_fn(params, cfg, batch, kv_chunk=kv_chunk, remat=remat)

    def grads_of(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_of)(params, batch)

        def mb(batch_tree, i):
            return jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:])[i], batch_tree
            )

        def acc_step(carry, i):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_of)(params, mb(batch, i))
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (loss_acc + l, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = lax.scan(
            acc_step, (jnp.zeros(()), g0), jnp.arange(microbatches),
            unroll=flags.scan_unroll(),
        )
        scale = 1.0 / microbatches
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        gnorm = global_norm(grads)
        new_params, new_opt, _ = adamw_update(grads, opt_state, params, opt_cfg)
        if nan_guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params = jax.tree.map(
                lambda new, old: jnp.where(ok, new, old), new_params, params
            )
            new_opt = {
                "mu": jax.tree.map(
                    lambda new, old: jnp.where(ok, new, old),
                    new_opt["mu"],
                    opt_state["mu"],
                ),
                "nu": jax.tree.map(
                    lambda new, old: jnp.where(ok, new, old),
                    new_opt["nu"],
                    opt_state["nu"],
                ),
                "step": jnp.where(ok, new_opt["step"], opt_state["step"]),
                "skipped": opt_state["skipped"] + jnp.where(ok, 0, 1).astype(jnp.int32),
            }
        metrics = {"loss": loss, "grad_norm": gnorm, "skipped": new_opt["skipped"]}
        return new_params, new_opt, metrics

    return train_step


def jit_train_step(train_step, mesh, params, opt_state, batch_tree, global_batch):
    """Wrap with explicit in/out shardings for the production mesh."""
    from repro.dist.sharding import batch_specs, param_specs, shardings_of

    pspec = shardings_of(param_specs(params, mesh), mesh)
    ospec = {
        "mu": shardings_of(param_specs(opt_state["mu"], mesh), mesh),
        "nu": shardings_of(param_specs(opt_state["nu"], mesh), mesh),
        "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        "skipped": jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
    }
    bspec = shardings_of(batch_specs(batch_tree, mesh, global_batch), mesh)
    return jax.jit(
        train_step,
        in_shardings=(pspec, ospec, bspec),
        out_shardings=(pspec, ospec, None),
        donate_argnums=(0, 1),
    )
