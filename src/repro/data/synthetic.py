"""Deterministic, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step): restart at step k reproduces
exactly the stream a crash interrupted — the data-side half of
checkpoint/restart fault tolerance (no cursor files needed). Per-shard
slicing is derived from the same key, so elastic re-sharding (different dp
degree after a remesh) still yields the same *global* batch for a given
step.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Full global batch for ``step`` (tokens + next-token labels)."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        toks = jax.random.randint(
            key,
            (self.global_batch, self.seq_len + 1),
            0,
            self.vocab_size,
            dtype=jnp.int32,
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch_at(self, step: int) -> dict:
        """numpy version (host-side pipelines / tests)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        toks = rng.integers(
            0, self.vocab_size, (self.global_batch, self.seq_len + 1), dtype=np.int32
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard_batch_at(self, step: int, shard: int, n_shards: int) -> dict:
        """The rows shard ``shard`` of ``n_shards`` owns — identical to the
        corresponding slice of batch_at(step) regardless of n_shards."""
        assert self.global_batch % n_shards == 0
        per = self.global_batch // n_shards
        full = self.host_batch_at(step)
        return {k: v[shard * per : (shard + 1) * per] for k, v in full.items()}
