"""Opt-in per-iteration convergence telemetry via host callbacks.

The ledger reports the *final* iteration count and relative residual; the
convergence *curve* — how the residual fell per executed iteration — never
leaves the device. This module taps it out with ``jax.debug.callback``:

* :func:`instrument` is called at **trace time** inside a solver's
  ``while_loop`` body (gated by the ``telemetry`` flag threaded through
  ``core/cg.py``). It bakes an unordered host callback into the compiled
  program that fires once per *executed* iteration with
  ``(iteration, relres)``. Only the shard at index 0 along the solve axis
  reports — the reduced residual is identical on every shard, and one
  reporter keeps the history free of duplicates.
* :func:`record` is the host-side sink: a context manager that collects
  the callbacks fired while it is active into a :class:`ConvergenceRecord`.
  Without an active recorder the callback is a no-op, so a telemetry-built
  solver stays usable (and cheap) outside recording.

Because the callback is unordered and a handle may run several times while
recording (warm-up + repeats), :meth:`ConvergenceRecord.history` splits the
arrival stream into runs at iteration-counter resets and returns the last
run sorted by iteration — the converged curve of the final solve.

The compiled program either contains the callback or it does not, so the
``telemetry`` flag is part of the solver-handle cache key (core/cg.py).
"""

from __future__ import annotations

import contextlib

import numpy as np


class ConvergenceRecord:
    """Arrival-ordered (iteration, relres) entries from one recording."""

    def __init__(self):
        self.entries: list[tuple[int, object]] = []

    def add(self, i: int, relres):
        self.entries.append((int(i), relres))

    def runs(self) -> list[list[tuple[int, object]]]:
        """Split the arrival stream into runs at iteration resets."""
        out: list[list[tuple[int, object]]] = []
        prev = None
        for i, v in self.entries:
            if prev is None or i <= prev:
                out.append([])
            out[-1].append((i, v))
            prev = i
        return out

    def history(self) -> list[tuple[int, object]]:
        """The last run, sorted by iteration (callbacks are unordered)."""
        rs = self.runs()
        return sorted(rs[-1], key=lambda e: e[0]) if rs else []

    def residuals(self) -> list:
        return [v for _, v in self.history()]

    def ledger(self) -> dict:
        """JSON-ready ``telemetry`` block for the solve ledger."""
        h = self.history()
        return dict(
            iters_recorded=len(h),
            first_iter=h[0][0] if h else 0,
            residual_history=[v for _, v in h],
        )


_ACTIVE: list[ConvergenceRecord] = []


@contextlib.contextmanager
def record():
    """Collect telemetry callbacks into a fresh :class:`ConvergenceRecord`."""
    rec = ConvergenceRecord()
    _ACTIVE.append(rec)
    try:
        yield rec
    finally:
        _ACTIVE.remove(rec)


def emit(shard_index, i, relres):
    """Host-side callback target (one call per executed iteration per
    shard); keeps only shard 0's reports, into the innermost recorder."""
    if not _ACTIVE or int(shard_index) != 0:
        return
    v = np.asarray(relres)
    _ACTIVE[-1].add(int(i), v.tolist() if v.ndim else float(v))


def instrument(i, relres, axis):
    """Bake the per-iteration host callback into the traced loop body.

    ``i`` is the iteration counter *after* this body's update, ``relres``
    the matching relative residual (scalar, or a vector for block solves),
    ``axis`` the solve mesh axis name (or tuple of names for 2-D grids).
    """
    import jax
    from jax import lax

    names = (axis,) if isinstance(axis, str) else tuple(axis)
    idx = lax.axis_index(names[0])
    for nm in names[1:]:
        # any linear combination is 0 only at the (0, ..., 0) coordinate
        idx = idx * 65536 + lax.axis_index(nm)
    jax.debug.callback(emit, idx, i, relres)
