"""Wall-clock power timelines + an emulated fixed-Hz (NVML-style) sampler.

The PowerMonitor already holds a time-resolved record — its segments
partition ``[0, duration]`` — but exposes it only as exact integrals
(``energy()`` / ``energy_by_region()``). :func:`build_timeline` lifts the
segments into a :class:`Timeline` of :class:`Span` rows carrying everything
a viewer or sampler needs per slice of wall-clock: region, section, watts
(chip + host), HBM bytes moved, and the exposed/hidden communication split.

Two integration routes over the same timeline:

* **event-boundary** (:meth:`Timeline.energy`): integrate span-by-span —
  arithmetic mirrors ``PowerMonitor.energy()`` term for term, so the result
  equals the ledger's ``totals`` *exactly* (bitwise), not approximately.
* **sampled** (:func:`sample_power` + :func:`integrate_samples`): emulate a
  real power sensor polled at a fixed rate — one instantaneous reading per
  sample interval, multiplied by the interval width (what powerMonitor /
  GPowerU actually compute from NVML readings). Sampling cannot see inside
  an interval, so short spans alias: :func:`sampling_error` quantifies the
  under-sampling error, which decays as the rate rises — the Magoulès-style
  error curve reproduced by ``benchmarks/obs_sampling.py``.

No jax imports here: timelines are plain-python/numpy post-processing of a
monitor and are usable from tools and tests without a device runtime.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.energy.monitor import PowerMonitor


@dataclasses.dataclass(frozen=True)
class Span:
    """One wall-clock slice of the timeline (maps 1:1 to a monitor segment)."""

    t0: float
    t1: float
    region: str
    section: str  # "setup" / "iteration" / "idle" ("" when unattributed)
    chip_w: float  # per-device power over the span
    host_w: float  # per-host power over the span
    hbm_bytes: float  # HBM traffic attributed to the span (per device)
    comm_s: float  # modeled collective seconds inside the span
    comm_exposed_s: float
    comm_hidden_s: float
    overlapped: bool

    @property
    def dt(self) -> float:
        return self.t1 - self.t0


@dataclasses.dataclass
class Timeline:
    """Contiguous spans over ``[0, duration]`` + the constants needed to
    integrate them exactly the way the monitor does."""

    spans: list[Span]
    n_devices: int
    n_hosts: int
    chip_static_w: float
    host_static_w: float
    duration: float

    def energy(self) -> dict:
        """Event-boundary integration — mirrors ``PowerMonitor.energy()``
        term for term (same summation order over the same floats), so the
        result matches the ledger ``totals`` bitwise."""
        T = self.duration
        te_chip = sum(sp.chip_w * sp.dt for sp in self.spans) * self.n_devices
        se_chip = self.chip_static_w * T * self.n_devices
        te_host = sum(sp.host_w * sp.dt for sp in self.spans) * self.n_hosts
        se_host = self.host_static_w * T * self.n_hosts
        peak = max((sp.chip_w for sp in self.spans), default=self.chip_static_w)
        return dict(
            runtime=T,
            comm_s=sum(sp.comm_s for sp in self.spans),
            comm_exposed_s=sum(sp.comm_exposed_s for sp in self.spans),
            comm_hidden_s=sum(sp.comm_hidden_s for sp in self.spans),
            te_gpu=te_chip,
            se_gpu=se_chip,
            de_gpu=te_chip - se_chip,
            te_cpu=te_host,
            se_cpu=se_host,
            de_cpu=te_host - se_host,
            de_total=(te_chip - se_chip) + (te_host - se_host),
            gpu_power_peak=peak,
        )

    def energy_by_region(self) -> dict:
        """Per-region event-boundary integration — same accumulation order
        and arithmetic as ``PowerMonitor.energy_by_region()``."""
        out: dict[str, dict] = {}
        for sp in self.spans:
            d = out.setdefault(
                sp.region,
                dict(time_s=0.0, te_gpu_j=0.0, de_gpu_j=0.0, de_cpu_j=0.0,
                     de_j=0.0, comm_s=0.0, comm_exposed_s=0.0,
                     comm_hidden_s=0.0),
            )
            de_gpu = (sp.chip_w - self.chip_static_w) * sp.dt * self.n_devices
            de_cpu = (sp.host_w - self.host_static_w) * sp.dt * self.n_hosts
            d["time_s"] += sp.dt
            d["te_gpu_j"] += sp.chip_w * sp.dt * self.n_devices
            d["de_gpu_j"] += de_gpu
            d["de_cpu_j"] += de_cpu
            d["de_j"] += de_gpu + de_cpu
            d["comm_s"] += sp.comm_s
            d["comm_exposed_s"] += sp.comm_exposed_s
            d["comm_hidden_s"] += sp.comm_hidden_s
        return out


def build_timeline(mon: PowerMonitor) -> Timeline:
    """Lift a monitor's segments into a :class:`Timeline` (1:1 spans).

    Per-span HBM bytes are back-derived from the segment's modeled memory
    time through the same effective bandwidth the cost model used to
    produce it, so the timeline's byte counters sum to the traffic the
    ledger accounted.
    """
    eff_bw = mon.cost.power.chip.hbm_bw * mon.cost.bw_efficiency
    spans = [
        Span(
            t0=s.t0,
            t1=s.t1,
            region=s.name,
            section=s.section,
            chip_w=s.chip_w,
            host_w=mon.model.host_power(s.host_active),
            hbm_bytes=s.t_mem * eff_bw,
            comm_s=s.t_coll,
            comm_exposed_s=s.comm_exposed_s,
            comm_hidden_s=s.comm_hidden_s,
            overlapped=s.overlapped,
        )
        for s in mon.segments
    ]
    return Timeline(
        spans=spans,
        n_devices=mon.n_devices,
        n_hosts=max(mon.n_devices // mon.devices_per_host, 1),
        chip_static_w=mon.model.chip_static_w,
        host_static_w=mon.model.host_static_w,
        duration=mon.duration,
    )


@dataclasses.dataclass(frozen=True)
class SampledPower:
    """Fixed-rate sampler output: one instantaneous reading per interval."""

    hz: float
    ts: np.ndarray  # sample times (interval midpoints), seconds
    widths: np.ndarray  # interval widths (1/hz, shorter final interval)
    p_chip: np.ndarray  # per-device power readings [W]
    p_host: np.ndarray  # per-host power readings [W]


def sample_power(tl: Timeline, hz: float) -> SampledPower:
    """Emulate a power sensor polled at ``hz`` over the timeline.

    One reading per sample interval (taken at the interval midpoint — a
    real sensor reads *somewhere* inside each period; the midpoint is the
    unbiased choice). The reading is the instantaneous span power at that
    time: spans shorter than the sample period can be missed entirely,
    which is exactly the under-sampling failure mode short kernels hit on
    real NVML at its ~50 Hz effective refresh.
    """
    if hz <= 0:
        raise ValueError(f"sampling rate must be positive, got {hz}")
    T = tl.duration
    period = 1.0 / float(hz)
    n = max(int(np.ceil(T / period - 1e-12)), 1)
    edges = np.minimum(np.arange(n + 1, dtype=np.float64) * period, T)
    mids = 0.5 * (edges[:-1] + edges[1:])
    widths = np.diff(edges)
    starts = np.array([sp.t0 for sp in tl.spans], dtype=np.float64)
    chip = np.array([sp.chip_w for sp in tl.spans], dtype=np.float64)
    host = np.array([sp.host_w for sp in tl.spans], dtype=np.float64)
    if len(tl.spans) == 0:
        p_chip = np.full(n, tl.chip_static_w)
        p_host = np.full(n, tl.host_static_w)
    else:
        idx = np.clip(
            np.searchsorted(starts, mids, side="right") - 1, 0, len(starts) - 1
        )
        p_chip = chip[idx]
        p_host = host[idx]
    return SampledPower(hz=float(hz), ts=mids, widths=widths,
                        p_chip=p_chip, p_host=p_host)


def integrate_samples(tl: Timeline, sp: SampledPower) -> dict:
    """Integrate sampler readings into the ledger's energy quantities —
    the rectangle rule a real power monitor applies to NVML readings.

    Static energy needs only the run duration (known exactly), so the
    sampling error lives entirely in the total-energy terms and flows into
    the dynamic quantities by subtraction.
    """
    T = tl.duration
    te_chip = float(np.sum(sp.p_chip * sp.widths)) * tl.n_devices
    se_chip = tl.chip_static_w * T * tl.n_devices
    te_host = float(np.sum(sp.p_host * sp.widths)) * tl.n_hosts
    se_host = tl.host_static_w * T * tl.n_hosts
    return dict(
        runtime=T,
        te_gpu=te_chip,
        se_gpu=se_chip,
        de_gpu=te_chip - se_chip,
        te_cpu=te_host,
        se_cpu=se_host,
        de_cpu=te_host - se_host,
        de_total=(te_chip - se_chip) + (te_host - se_host),
    )


def sampling_error(tl: Timeline, hz: float) -> float:
    """Relative error of sampled-and-integrated ``de_total`` vs the exact
    event-boundary integral (== ledger totals)."""
    exact = tl.energy()["de_total"]
    sampled = integrate_samples(tl, sample_power(tl, hz))["de_total"]
    return abs(sampled - exact) / max(abs(exact), 1e-300)
