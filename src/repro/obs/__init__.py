"""Time-resolved observability over the energy ledger machinery.

The paper's methodology is *time-resolved*: board power is sampled during
execution and attributed to instrumented regions (Fig. 1/2). The ledger
(energy/trace.py + energy/monitor.py) reproduces the attribution as exact
per-segment integrals; this package restores the time axis on top of it:

* :mod:`repro.obs.timeline` — replay monitor segments into wall-clock
  spans, emulate a fixed-Hz (NVML-style) power sampler over them, and show
  how sampled-and-integrated energy converges to the exact ledger total.
* :mod:`repro.obs.trace_export` — Chrome trace-event / Perfetto JSON
  export of a timeline (regions + sections as duration events, power and
  HBM traffic as counter tracks); ``--profile`` on both launchers.
* :mod:`repro.obs.convergence` — opt-in per-iteration residual telemetry
  via host callback (``--telemetry``), recorded into the solve ledger.
* :mod:`repro.obs.metrics` — counters/gauges/histograms for the serving
  engine with a Prometheus-text snapshot (``--metrics-out``).
* :mod:`repro.obs.log` — structured logging (``--log-level`` /
  ``REPRO_LOG``) whose default output is byte-identical to ``print``.
* :mod:`repro.obs.provenance` — the ``meta`` block stamped into every
  written ledger (schema version, jax version, backend, git SHA).

See docs/observability.md for the user-facing tour.

Import-order note: this package ``__init__`` is deliberately empty of
imports. ``repro.obs.timeline`` reaches jax transitively (through the
energy/cost model), while the CLI adapters import ``repro.obs.log`` /
``repro.obs.provenance`` at parse time — *before* the device-count env
vars are set — so those must stay jax-free and the package must not eagerly
pull the heavy modules in. Import submodules directly.
"""
