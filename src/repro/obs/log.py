"""Structured logging for the launchers (``--log-level`` / ``REPRO_LOG``).

The repo's CLI output is a *contract*: benchmark parsers and the pinned
stdout tests consume exact lines. So the default configuration renders
messages bare (``%(message)s``) on **stdout** at INFO — byte-identical to
the ``print`` calls it replaces — while ``--log-level debug`` (or
``REPRO_LOG=debug``) switches the whole ``repro`` logger family to a
prefixed diagnostic format and unlocks the debug chatter, and
``--log-level warning`` silences progress output entirely without touching
the code that emits it.

Usage::

    from repro.obs import log as olog
    LOG = olog.get_logger("serve")        # the "repro.serve" logger
    LOG.info("served %d requests", n)     # contract line: stays bare
    LOG.debug("flush: %d queued", depth)  # visible only at debug level

``setup`` is idempotent per process; the first ``get_logger`` call
configures from the environment, an explicit ``setup(level=...)`` (the
``--log-level`` flag) reconfigures.
"""

from __future__ import annotations

import logging
import os
import sys

LEVELS = ("debug", "info", "warning", "error")

_BARE_FORMAT = "%(message)s"
_DEBUG_FORMAT = "[%(levelname).1s %(name)s] %(message)s"

_configured = False


class _StdoutHandler(logging.StreamHandler):
    """StreamHandler that resolves ``sys.stdout`` at emit time.

    Binding the stream at setup time would freeze whatever object
    ``sys.stdout`` was then — breaking capture-based tests (pytest swaps
    the stream per test) and any caller that redirects stdout after the
    first ``get_logger``.
    """

    def __init__(self):
        super().__init__(sys.stdout)

    @property
    def stream(self):
        return sys.stdout

    @stream.setter
    def stream(self, value):  # the base __init__/setStream assign it
        pass


def setup(level: str | None = None) -> logging.Logger:
    """Configure the root ``repro`` logger (idempotent unless ``level``).

    ``level`` wins over ``REPRO_LOG``; both default to ``info``. At
    ``info`` the handler writes bare messages to stdout — exactly what the
    historical ``print`` calls produced.
    """
    global _configured
    root = logging.getLogger("repro")
    if _configured and level is None:
        return root
    name = (level or os.environ.get("REPRO_LOG") or "info").lower()
    if name not in LEVELS:
        raise ValueError(
            f"unknown log level {name!r} (choose from {', '.join(LEVELS)})"
        )
    root.setLevel(getattr(logging, name.upper()))
    root.propagate = False
    fmt = _DEBUG_FORMAT if name == "debug" else _BARE_FORMAT
    if root.handlers:
        for h in root.handlers:
            h.setFormatter(logging.Formatter(fmt))
    else:
        h = _StdoutHandler()
        h.setFormatter(logging.Formatter(fmt))
        root.addHandler(h)
    _configured = True
    return root


def get_logger(name: str = "") -> logging.Logger:
    """The ``repro[.name]`` logger, configuring defaults on first use."""
    setup()
    return logging.getLogger(f"repro.{name}" if name else "repro")
