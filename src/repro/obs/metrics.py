"""A small in-process metrics registry (Prometheus text exposition).

The serving engine (launch/serve_solver.py) is a long-lived loop: totals in
its final ledger say *what happened*, but operating it needs the standard
service signals — queue depth, batch width, warm/cold split, Joules and
latency per request. This module provides the three canonical instrument
types with no dependencies:

* :class:`Counter` — monotone totals (``requests_total``, ``evictions``);
* :class:`Gauge` — point-in-time levels (``queue_depth``);
* :class:`Histogram` — distributions with explicit buckets
  (``batch_width``, ``request_energy_j``, ``request_latency_s``), tracking
  cumulative bucket counts plus ``_sum``/``_count`` like the Prometheus
  client does.

:meth:`MetricsRegistry.to_prometheus` renders the text exposition format
(``--metrics-out`` on the serving CLI writes it; a scraper can lift the
file as-is); :meth:`MetricsRegistry.snapshot` returns the same state as a
JSON-ready dict, embedded in the engine ledger under ``metrics``.
"""

from __future__ import annotations

import math


class Counter:
    """Monotonically increasing total."""

    def __init__(self, name: str, help_: str = ""):
        self.name = name
        self.help = help_
        self.value = 0.0

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value += amount


class Gauge:
    """Point-in-time level; set/inc/dec."""

    def __init__(self, name: str, help_: str = ""):
        self.name = name
        self.help = help_
        self.value = 0.0

    def set(self, value: float):
        self.value = float(value)

    def inc(self, amount: float = 1.0):
        self.value += amount

    def dec(self, amount: float = 1.0):
        self.value -= amount


# default buckets cover microjoule-to-kilojoule energies and
# microsecond-to-minute latencies on a log scale
DEFAULT_BUCKETS = tuple(10.0 ** e for e in range(-6, 7))


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: ``le`` bounds,
    implicit ``+Inf`` bucket, running ``_sum`` and ``_count``)."""

    def __init__(self, name: str, help_: str = "", buckets=DEFAULT_BUCKETS):
        self.name = name
        self.help = help_
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # + the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float):
        v = float(value)
        self.sum += v
        self.count += 1
        for k, bound in enumerate(self.buckets):
            if v <= bound:
                self.counts[k] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> list[int]:
        out, running = [], 0
        for c in self.counts:
            running += c
            out.append(running)
        return out


class MetricsRegistry:
    """Named instruments; idempotent getters (same name -> same object)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}

    def _get(self, cls, name: str, help_: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help_, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}"
            )
        return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_, buckets=buckets)

    def snapshot(self) -> dict:
        """JSON-ready state of every instrument (ledger ``metrics`` block)."""
        out: dict[str, dict] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                out[name] = dict(
                    type="histogram",
                    buckets=list(m.buckets),
                    counts=list(m.counts),
                    sum=m.sum,
                    count=m.count,
                )
            else:
                kind = "counter" if isinstance(m, Counter) else "gauge"
                out[name] = dict(type=kind, value=m.value)
        return out

    def to_prometheus(self) -> str:
        """Text exposition format (one block per metric, sorted by name)."""
        lines: list[str] = []
        for name, m in sorted(self._metrics.items()):
            kind = (
                "counter" if isinstance(m, Counter)
                else "gauge" if isinstance(m, Gauge)
                else "histogram"
            )
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                cum = m.cumulative()
                for bound, c in zip(m.buckets, cum[:-1]):
                    lines.append(
                        f'{name}_bucket{{le="{_fmt(bound)}"}} {c}'
                    )
                lines.append(f'{name}_bucket{{le="+Inf"}} {cum[-1]}')
                lines.append(f"{name}_sum {_fmt(m.sum)}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    """Prometheus-friendly number rendering (integers without the .0)."""
    if math.isfinite(v) and float(v).is_integer():
        return str(int(v))
    return repr(float(v))
