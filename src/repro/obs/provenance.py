"""Provenance ``meta`` block stamped into every written ledger.

A ledger JSON outlives the process that wrote it — CI artifacts, baseline
diffs, serving logs. ``ledger_meta()`` records where a ledger came from:
the ledger schema version, the jax version and backend that executed (or
modeled) the run, the device count, and the repo git SHA when the tree is
available. Everything here is *info*, never gated: the baseline differ
(benchmarks/check_ledgers.py) compares only the ``gate`` side, so meta can
vary across machines without breaking the energy-ledger job.

jax is imported lazily — the launchers must set device-count env vars
before jax initializes, and this module is imported at CLI-parse time.
"""

from __future__ import annotations

import os
import subprocess

# Version of the ledger envelope written by benchmarks/common.write_ledger,
# api.write_ledger_json, and the serving engine. Bump on breaking changes
# to the shared envelope (docs/ledger_schema.md).
SCHEMA_VERSION = 1


def git_sha(repo: str | None = None) -> str | None:
    """Short HEAD SHA of ``repo`` (default: this file's repo), or None."""
    if repo is None:
        repo = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    try:
        r = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=repo, capture_output=True, text=True, timeout=5,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = r.stdout.strip()
    return sha if r.returncode == 0 and sha else None


def ledger_meta() -> dict:
    """The ``meta`` block: schema version + runtime + tree provenance."""
    import jax

    meta = dict(
        schema_version=SCHEMA_VERSION,
        jax=jax.__version__,
        backend=jax.default_backend(),
        device_count=jax.device_count(),
    )
    sha = git_sha()
    if sha:
        meta["git_sha"] = sha
    return meta
