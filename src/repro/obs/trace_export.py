"""Chrome trace-event (Perfetto-compatible) JSON export of timelines.

Emits the JSON Object Format of the Trace Event spec — loadable in
``chrome://tracing`` and https://ui.perfetto.dev — from one or more
:class:`~repro.obs.timeline.Timeline` objects:

* each timeline becomes one *process* (``pid``) named by its label;
* ``tid 0`` ("regions") holds one complete ``X`` (duration) event per span,
  with watts / HBM bytes / exposed-comm seconds in ``args``;
* ``tid 1`` ("sections") holds the setup/iteration/idle phases — runs of
  consecutive same-section spans merged into one event;
* counter (``C``) tracks sample ``chip_power_w`` / ``host_power_w`` /
  ``hbm_bytes_total`` at every span boundary, so the viewer draws the
  paper-style power-over-time staircase next to the region lanes.

Timestamps are microseconds (the spec's unit). ``write_chrome_trace`` lays
multiple timelines out either on a shared clock (default: concurrent
processes) or end-to-end (``sequential=True`` — the serving engine's
batches execute one after another on one engine).

Validation lives in ``tools/check_trace.py`` (structure, required counter
tracks, per-lane non-overlap); CI runs it on a solve and a serve profile.
"""

from __future__ import annotations

import json
import os

from repro.obs.timeline import Timeline

_US = 1e6  # trace-event timestamps are microseconds

# counter tracks every exported timeline must carry (check_trace enforces)
REQUIRED_COUNTERS = ("chip_power_w", "hbm_bytes_total")


def timeline_events(
    tl: Timeline, *, pid: int = 0, label: str = "timeline",
    t_offset: float = 0.0,
) -> list[dict]:
    """Trace events for one timeline under process ``pid``.

    ``t_offset`` shifts the whole timeline (seconds) — used to lay serving
    batches end-to-end on the engine's clock.
    """
    ev: list[dict] = [
        dict(ph="M", name="process_name", pid=pid, tid=0,
             args={"name": label}),
        dict(ph="M", name="thread_name", pid=pid, tid=0,
             args={"name": "regions"}),
        dict(ph="M", name="thread_name", pid=pid, tid=1,
             args={"name": "sections"}),
    ]
    for sp in tl.spans:
        ev.append(dict(
            ph="X", name=sp.region, cat="region", pid=pid, tid=0,
            ts=(t_offset + sp.t0) * _US, dur=sp.dt * _US,
            args=dict(
                section=sp.section,
                chip_w=sp.chip_w,
                host_w=sp.host_w,
                hbm_bytes=sp.hbm_bytes,
                comm_s=sp.comm_s,
                comm_exposed_s=sp.comm_exposed_s,
                comm_hidden_s=sp.comm_hidden_s,
                overlapped=sp.overlapped,
            ),
        ))
    # section lane: merge consecutive spans sharing a section phase
    run_t0, run_sec = None, None

    def _close(t1):
        if run_sec:
            ev.append(dict(
                ph="X", name=run_sec, cat="section", pid=pid, tid=1,
                ts=(t_offset + run_t0) * _US, dur=(t1 - run_t0) * _US,
                args={},
            ))

    for sp in tl.spans:
        if sp.section != run_sec:
            if run_sec is not None:
                _close(sp.t0)
            run_t0, run_sec = sp.t0, sp.section
    if tl.spans:
        _close(tl.spans[-1].t1)
    # counter tracks: step at every span boundary + a closing static sample
    hbm_total = 0.0
    for sp in tl.spans:
        ts = (t_offset + sp.t0) * _US
        ev.append(dict(ph="C", name="chip_power_w", pid=pid, ts=ts,
                       args={"watts": sp.chip_w}))
        ev.append(dict(ph="C", name="host_power_w", pid=pid, ts=ts,
                       args={"watts": sp.host_w}))
        ev.append(dict(ph="C", name="hbm_bytes_total", pid=pid, ts=ts,
                       args={"bytes": hbm_total}))
        hbm_total += sp.hbm_bytes
    t_end = (t_offset + tl.duration) * _US
    ev.append(dict(ph="C", name="chip_power_w", pid=pid, ts=t_end,
                   args={"watts": tl.chip_static_w}))
    ev.append(dict(ph="C", name="host_power_w", pid=pid, ts=t_end,
                   args={"watts": tl.host_static_w}))
    ev.append(dict(ph="C", name="hbm_bytes_total", pid=pid, ts=t_end,
                   args={"bytes": hbm_total}))
    return ev


def chrome_trace(
    timelines, *, meta: dict | None = None, sequential: bool = False,
) -> dict:
    """Assemble the trace object for ``[(label, timeline), ...]``."""
    events: list[dict] = []
    offset = 0.0
    for pid, (label, tl) in enumerate(timelines):
        events.extend(timeline_events(tl, pid=pid, label=str(label),
                                      t_offset=offset))
        if sequential:
            offset += tl.duration
    return dict(
        traceEvents=events,
        displayTimeUnit="ms",
        otherData=dict(meta or {}, exporter="repro.obs.trace_export"),
    )


def write_chrome_trace(
    path: str, timelines, *, meta: dict | None = None,
    sequential: bool = False,
) -> str:
    """Write the trace JSON atomically; returns ``path``."""
    obj = chrome_trace(timelines, meta=meta, sequential=sequential)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path
