"""arctic-480b [moe]: 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per expert AND dense residual)
vocab=32000. Dense-MoE hybrid: every block runs a small dense MLP in
parallel with the routed MoE FFN.
"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(
        n_experts=128, top_k=2, d_ff_expert=4864,
        dense_residual=True, d_ff_dense=4864,
    ),
)
