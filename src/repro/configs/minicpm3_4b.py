"""minicpm3-4b [dense]: MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B; hf].

62L d_model=2560 40H (kv=40 logical; MLA caches the 256-d latent instead)
d_ff=6400 vocab=73448.
"""

from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
)
