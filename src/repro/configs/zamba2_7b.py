"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
One shared (weight-tied) attention+MLP block is applied every 6 Mamba2
blocks (13 applications over 81 layers); simplification vs the HF
implementation (concat-embedding input + per-application LoRA) noted in
DESIGN.md. Sub-quadratic backbone -> runs long_500k.
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attn_every=6,
)
