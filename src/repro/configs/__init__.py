"""Architecture registry: ``get_config(name)`` / ``ARCHS``.

One module per assigned architecture; importing this package registers all
ten. The paper's own benchmark configs (Poisson problems, solver settings)
live in ``solver.py``.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    MLAConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    applicable_shapes,
)

_ARCH_MODULES = [
    "xlstm_350m",
    "qwen2_5_3b",
    "qwen3_8b",
    "minicpm3_4b",
    "gemma_7b",
    "zamba2_7b",
    "hubert_xlarge",
    "arctic_480b",
    "moonshot_v1_16b_a3b",
    "llava_next_34b",
]

ARCHS: dict[str, ArchConfig] = {}
for _m in _ARCH_MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    ARCHS[mod.CONFIG.name] = mod.CONFIG


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
