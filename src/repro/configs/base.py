"""Architecture + shape configuration schema.

One ``ArchConfig`` describes any of the ten assigned architectures; family-
specific behavior is keyed on ``family`` / block-pattern fields. Exact
assigned hyperparameters live in one file per architecture
(``src/repro/configs/<id>.py``); reduced smoke variants come from
``ArchConfig.smoke()``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # Arctic: dense MLP in parallel with MoE
    d_ff_dense: int = 0  # width of the parallel dense MLP (Arctic)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def n_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block parameters (mLSTM + periodic sLSTM)."""

    slstm_every: int = 8  # every k-th block is an sLSTM (0 = all mLSTM)
    proj_factor: float = 2.0  # mLSTM up-projection
    chunk: int = 256  # chunkwise-parallel mLSTM chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention details
    qk_norm: bool = False  # qwen3
    qkv_bias: bool = False  # qwen2.5
    causal: bool = True  # False for encoder-only (hubert)
    rope_theta: float = 10000.0
    mlp_act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): shared attention block applied every k ssm blocks
    shared_attn_every: int = 0
    # vlm: number of (stub) image patch embeddings prepended
    n_patches: int = 0
    # norm
    rmsnorm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Physical embedding rows (padded to 256 for TP divisibility)."""
        return pad_to(self.vocab_size, 256)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.xlstm is None and self.ssm is not None

    @property
    def subquadratic(self) -> bool:
        """Supports long_500k (recurrent-state decode)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.n_layers
        hd, h, kvh = self.hd, self.n_heads, self.n_kv_heads
        n = self.vocab_padded * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_padded * d
        per_layer = 0
        if self.xlstm is not None:
            pf = self.xlstm.proj_factor
            di = int(pf * d)
            per_layer = 2 * d * di + 3 * di * (di // max(self.n_heads, 1)) // max(
                di // max(self.n_heads, 1), 1
            )  # projections dominate
            per_layer = 2 * d * di + 4 * di * di // max(h, 1) + d * d
        elif self.ssm is not None:
            di = self.ssm.expand * d
            nh = self.ssm.n_heads(d)
            per_layer = d * (2 * di + 2 * self.ssm.d_state + nh) + di * d
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            if self.mla is not None:
                m = self.mla
                attn = (
                    d * m.q_lora_rank
                    + m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                    + h * m.v_head_dim * d
                )
            else:
                attn = d * (h * hd) + 2 * d * (kvh * hd) + (h * hd) * d
            if self.moe is not None:
                ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                if self.moe.dense_residual:
                    ff += 3 * d * self.moe.d_ff_dense
            else:
                ff = 3 * d * self.d_ff
            if self.family == "hybrid":
                # zamba2: ssm blocks + one shared attn block
                di = self.ssm.expand * d
                nh = self.ssm.n_heads(d)
                ssm_p = d * (2 * di + 2 * self.ssm.d_state + nh) + di * d
                n += L * ssm_p + (attn + ff)  # shared block counted once
                return n
            per_layer = attn + ff
        n += L * per_layer
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        all_experts = L * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        active = L * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return full - all_experts + active

    # ---- reduced smoke variant ---------------------------------------------

    def smoke(self) -> "ArchConfig":
        """Tiny same-family config for CPU forward/train-step smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2 if self.shared_attn_every == 0 else max(2, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16 if self.head_dim else 0,
        )
        cfg = dataclasses.replace(self, **kw)
        if self.moe is not None:
            cfg = dataclasses.replace(
                cfg,
                moe=dataclasses.replace(
                    self.moe, n_experts=4, top_k=2, d_ff_expert=64,
                    d_ff_dense=64 if self.moe.dense_residual else 0,
                ),
            )
        if self.mla is not None:
            cfg = dataclasses.replace(
                cfg,
                mla=MLAConfig(
                    q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                    qk_rope_head_dim=8, v_head_dim=16,
                ),
            )
        if self.ssm is not None:
            cfg = dataclasses.replace(
                cfg, ssm=dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=16)
            )
        if self.xlstm is not None:
            cfg = dataclasses.replace(
                cfg, xlstm=dataclasses.replace(self.xlstm, slstm_every=2, chunk=16)
            )
        if self.shared_attn_every:
            cfg = dataclasses.replace(cfg, shared_attn_every=2, n_layers=4)
        if self.n_patches:
            cfg = dataclasses.replace(cfg, n_patches=4)
        return cfg


# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch pairs with these four
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> dict[str, "ShapeConfig | None"]:
    """Shape -> ShapeConfig if the cell runs, None with a skip reason handled
    by the caller. Encoder-only archs have no decode; pure full-attention
    archs skip long_500k (quadratic)."""
    out: dict[str, ShapeConfig | None] = {}
    for name, sc in SHAPES.items():
        if sc.kind == "decode" and cfg.is_encoder_only:
            out[name] = None
        elif name == "long_500k" and not cfg.subquadratic:
            out[name] = None
        else:
            out[name] = sc
    return out


SKIP_REASONS = {
    ("decode", "encoder"): "encoder-only arch has no decode step",
    ("long_500k", "quadratic"): (
        "pure full-attention arch: 500k decode needs sub-quadratic attention"
    ),
}
