"""xlstm-350m [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304. d_ff=0 means the block IS
the (m/s)LSTM cell with its own up/down projections (factor 2); every 8th
block is an sLSTM (xLSTM [7:1] mix), the rest mLSTM. Recurrent state ->
runs long_500k.
"""

from repro.configs.base import ArchConfig, XLSTMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk=256),
    tie_embeddings=True,
)
