"""Typed public API: problem + config dataclasses, warm solver sessions.

The driver surface of this repo used to be ``launch/solve.py``'s ~20-flag
argparse soup; every benchmark re-derived the same wiring (partition →
shard → build solver → trace → ledger) from raw flag lists. This module is
the typed replacement:

* :class:`ProblemSpec` — *what* to solve (problem/side/scale/shards);
* :class:`SolverConfig` — *how* to solve it (variant/format/overlap/nrhs/
  tolerances/AMG/autotune knobs), with :class:`ConfigError` validation
  instead of argparse deaths;
* :func:`solve` — the full driver (the body ``launch.solve:main`` used to
  inline), returning a :class:`SolveReport`;
* :class:`SolverSession` — the warm per-matrix state behind it: partition
  once, autotune-or-cache-hit once, keep every compiled shard_map solver
  alive (``core.cg.solver_handle``). Repeat solves against the same matrix
  skip repartition and re-trace entirely — this is what
  ``launch/serve_solver.py`` serves requests from;
* :data:`SESSIONS` — the process-wide fingerprint-keyed session pool
  (:class:`repro.autotune.pool.SessionPool`).

``launch.solve`` remains a thin CLI adapter over this module (flag
spellings and ledger output unchanged — the deprecation shim contract,
tested in ``tests/test_api.py``).

Import order note: this module must not import jax at module scope — the
CLI adapters set ``XLA_FLAGS`` (device count) before the first jax import.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

from repro.obs.log import get_logger

LOG = get_logger("api")

VARIANTS = ("hs", "fcg", "pipecg", "sstep")
OPS = ("cg", "spmv")
FORMATS = ("auto", "ell", "hyb", "bcsr")
OBJECTIVES = ("energy", "edp", "time")


class ConfigError(ValueError):
    """A :class:`SolverConfig` combination that cannot run.

    Raised at dataclass construction time (typed, catchable) instead of an
    argparse ``SystemExit`` deep inside the driver. The CLI adapter
    (``launch.solve``) converts it to the historical ``SystemExit`` text.
    """


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """What to solve: the matrix source and its partitioning width.

    ``problem`` is ``poisson7`` / ``poisson27`` (side³ cube stencils) or a
    SuiteSparse name (``scale`` subsamples it — see
    ``matrices/suitesparse.py``). ``shards == 0`` means "all visible
    devices" (resolved at :func:`solve` time, not here).
    """

    problem: str = "poisson7"
    side: int = 24
    scale: float = 0.01
    shards: int = 0

    @classmethod
    def from_args(cls, args) -> "ProblemSpec":
        """Build from a ``launch.solve``-style argparse namespace."""
        return cls(
            problem=str(args.problem), side=int(args.side),
            scale=float(args.scale), shards=int(args.shards),
        )

    def to_argv(self) -> list[str]:
        """The equivalent ``launch.solve`` CLI flags (round-trip tested)."""
        return [
            "--problem", self.problem, "--side", str(self.side),
            "--scale", str(self.scale), "--shards", str(self.shards),
        ]

    def load(self):
        """Materialize the host matrix: ``(scipy CSR, display name)``."""
        from repro.matrices import poisson
        from repro.matrices.suitesparse import load_or_generate

        if self.problem.startswith("poisson"):
            stencil = "7pt" if self.problem == "poisson7" else "27pt"
            p = poisson.cube(self.side, stencil)
            return poisson.poisson_scipy(p), f"{stencil}-{self.side}^3"
        return load_or_generate(self.problem, scale=self.scale), self.problem


# the historical launch.solve validation messages, byte-for-byte — the CLI
# shim re-raises ConfigError as SystemExit(str(e)), so these strings ARE
# the CLI contract (tests/test_api.py pins them)
_NRHS_MSG = (
    "--nrhs > 1 runs the batched block-HS CG: requires --op cg, "
    "--variant hs, and no --amg/--amgx-analog"
)
_AUTOTUNE_MSG = (
    "--autotune tunes the unpreconditioned CG path "
    "(--op cg without --amg/--amgx-analog)"
)
_GRID_MSG = (
    "--grid RxC runs the 2-D partitioned CG path: requires --op cg and "
    "no --amg/--amgx-analog/--autotune"
)
_SSTEP_MSG = (
    "--s sets the s-step block size: requires --variant sstep"
)


def parse_grid(text: str) -> tuple[int, int]:
    """``"RxC"`` -> ``(R, C)`` with positive integers (ConfigError on junk)."""
    parts = str(text).lower().split("x")
    try:
        r, c = (int(p) for p in parts)
    except ValueError:
        raise ConfigError(
            f"grid must look like RxC (e.g. 4x4): {text!r}"
        ) from None
    if r < 1 or c < 1:
        raise ConfigError(f"grid dimensions must be >= 1: {text!r}")
    return r, c


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """How to solve: every knob of the distributed solver stack.

    Invalid combinations raise :class:`ConfigError` at construction
    (``__post_init__`` → :meth:`validate`), so a config that exists is a
    config that runs.
    """

    op: str = "cg"
    variant: str = "hs"
    fmt: str = "ell"
    block: int = 4
    overlap: bool = True
    nrhs: int = 1
    tol: float = 1e-8
    maxiter: int = 200
    amg: bool = False
    amgx_analog: bool = False
    autotune: bool = False
    objective: str = "energy"
    tune_budget: int = 6
    tune_cache: str | None = None
    repeats: int = 1
    grid: str | None = None  # "RxC" process grid; None = 1-D row layout
    # s-step block size (variant == "sstep" only). None = the solver
    # default (s=2); setting it partitions with halo_depth=s ghost zones
    # so the matrix-powers basis pays one widened exchange per block.
    s: int | None = None
    # per-iteration convergence telemetry (repro.obs.convergence): bakes a
    # host callback into the compiled loop body and records the residual
    # history into the ledger's "telemetry" block. Off by default — the
    # callback changes the compiled program, so it is part of the
    # solver-handle cache key.
    telemetry: bool = False

    def __post_init__(self):
        self.validate()

    @property
    def grid_shape(self) -> tuple[int, int] | None:
        """``(rows, cols)`` of the requested process grid, or ``None``."""
        return parse_grid(self.grid) if self.grid else None

    def validate(self):
        if self.op not in OPS:
            raise ConfigError(f"op must be one of {OPS}: {self.op!r}")
        if self.variant not in VARIANTS:
            raise ConfigError(
                f"variant must be one of {VARIANTS}: {self.variant!r}"
            )
        if self.fmt not in FORMATS:
            raise ConfigError(
                f"format must be one of {FORMATS}: {self.fmt!r}"
            )
        if self.objective not in OBJECTIVES:
            raise ConfigError(
                f"objective must be one of {OBJECTIVES}: {self.objective!r}"
            )
        if self.block < 1:
            raise ConfigError(f"block must be >= 1: {self.block}")
        if self.nrhs < 1:
            raise ConfigError(f"nrhs must be >= 1: {self.nrhs}")
        if self.repeats < 1:
            raise ConfigError(f"repeats must be >= 1: {self.repeats}")
        if self.maxiter < 1:
            raise ConfigError(f"maxiter must be >= 1: {self.maxiter}")
        if not self.tol > 0.0:
            raise ConfigError(f"tol must be > 0: {self.tol}")
        if self.tune_budget < 1:
            raise ConfigError(
                f"tune-budget must be >= 1: {self.tune_budget}"
            )
        if self.s is not None:
            if self.s < 1:
                raise ConfigError(f"s must be >= 1: {self.s}")
            if self.variant != "sstep":
                raise ConfigError(_SSTEP_MSG)
        if self.nrhs > 1 and (
            self.op != "cg" or self.amg or self.amgx_analog
            or self.variant != "hs"
        ):
            raise ConfigError(_NRHS_MSG)
        if self.autotune and (
            self.op != "cg" or self.amg or self.amgx_analog
        ):
            raise ConfigError(_AUTOTUNE_MSG)
        if self.grid:
            parse_grid(self.grid)  # shape errors surface at construction
            if (
                self.op != "cg" or self.amg or self.amgx_analog
                or self.autotune
            ):
                raise ConfigError(_GRID_MSG)

    @classmethod
    def from_args(cls, args) -> "SolverConfig":
        """Build from a ``launch.solve``-style argparse namespace.

        Preserves the historical ``--nrhs 0`` clamp-to-1 behavior."""
        return cls(
            op=str(args.op), variant=str(args.variant), fmt=str(args.fmt),
            block=int(args.block), overlap=bool(args.overlap),
            nrhs=max(int(args.nrhs), 1), tol=float(args.tol),
            maxiter=int(args.maxiter), amg=bool(args.amg),
            amgx_analog=bool(args.amgx_analog),
            autotune=bool(args.autotune), objective=str(args.objective),
            tune_budget=int(args.tune_budget), tune_cache=args.tune_cache,
            repeats=int(args.repeats),
            grid=getattr(args, "grid", None),
            s=(
                int(args.s)
                if getattr(args, "s", None) is not None else None
            ),
            telemetry=bool(getattr(args, "telemetry", False)),
        )

    def to_argv(self) -> list[str]:
        """The equivalent ``launch.solve`` CLI flags (round-trip tested)."""
        argv = [
            "--op", self.op, "--variant", self.variant,
            "--format", self.fmt, "--block", str(self.block),
            "--nrhs", str(self.nrhs), "--tol", str(self.tol),
            "--maxiter", str(self.maxiter),
            "--repeats", str(self.repeats),
            "--objective", self.objective,
            "--tune-budget", str(self.tune_budget),
        ]
        if not self.overlap:
            argv.append("--no-overlap")
        if self.amg:
            argv.append("--amg")
        if self.amgx_analog:
            argv.append("--amgx-analog")
        if self.autotune:
            argv.append("--autotune")
        if self.tune_cache:
            argv += ["--tune-cache", self.tune_cache]
        if self.grid:
            argv += ["--grid", self.grid]
        if self.s is not None:
            argv += ["--s", str(self.s)]
        if self.telemetry:
            argv.append("--telemetry")
        return argv


@dataclasses.dataclass(frozen=True)
class SolveReport:
    """What one :func:`solve` produced: identity, summary, full ledger.

    ``summary`` holds one compact dict per executed leg (label →
    iters/relres/wall/modeled/energy); ``ledger`` is the complete JSON
    payload ``--ledger`` writes (docs/ledger_schema.md)."""

    problem: str
    n: int
    nnz: int
    shards: int
    config: SolverConfig
    summary: dict
    ledger: dict

    @property
    def solvers(self) -> dict:
        return self.ledger["solvers"]


class SolverSession:
    """Warm per-matrix solver state: the unit the serving engine keeps.

    One session owns one host CSR matrix pinned to one shard count, and
    accumulates everything expensive derived from it:

    * ``mats`` — ``(fmt, block) -> sharded DistMat`` partitions (the
      all-gather Ginkgo-analog partition lives under ``("allgather", 0)``);
    * the autotune decision (the PR 5 fingerprint cache is the cross-
      process warm path; this is the in-process one);
    * compiled solver handles (``core.cg.solver_handle``), each carrying
      the energy trace captured at first warmup.

    ``partitions`` / ``tune_trials`` / ``solves`` count the *work actually
    performed* through this session — the serving tests assert a warm
    session serves repeat requests with zero new partitions and zero new
    tuning trials.
    """

    def __init__(self, a_csr, n_shards: int, *, mesh=None, key=None):
        from repro.launch.mesh import make_solver_mesh

        self.a = a_csr.tocsr()
        self.n = int(self.a.shape[0])
        self.n_shards = int(n_shards)
        self.mesh = mesh if mesh is not None else make_solver_mesh(
            self.n_shards
        )
        self.key = key
        self.mats: dict[tuple, Any] = {}
        # (rows, cols) -> 2-D jax Mesh over the same devices, built lazily
        self.grid_meshes: dict[tuple, Any] = {}
        # session-owned solver handles (core.cg.solver_handle cache=):
        # dropping the session frees its compiled executables with it,
        # instead of pinning them in the process-global handle LRU
        self.handles: dict[tuple, Any] = {}
        self.tune = None  # last TuneResult routed through this session
        self.partitions = 0
        self.tune_trials = 0
        self.solves = 0

    # -- partitions ---------------------------------------------------------

    def grid_mesh(self, grid):
        """The 2-D ``(rows, cols)`` mesh over this session's devices."""
        from repro.launch.mesh import make_grid_mesh

        g = (int(grid[0]), int(grid[1]))
        if g not in self.grid_meshes:
            self.grid_meshes[g] = make_grid_mesh(*g)
        return self.grid_meshes[g]

    def mesh_for(self, mat):
        """The mesh ``mat`` runs on: its grid mesh for a GridPlan matrix,
        else the session's 1-D ``shards`` mesh."""
        if getattr(mat.plan, "mode", None) == "grid":
            return self.grid_mesh(mat.plan.grid)
        return self.mesh

    def matrix(self, fmt: str = "ell", block: int = 4, *, grid=None,
               partition=None, halo_depth: int = 1):
        """The sharded DistMat for (fmt, block[, grid]); partitions on
        first use. ``grid=(R, C)`` plans per-dimension halos and shards
        onto the matching 2-D mesh (1-D keys stay 2-tuples, so pre-grid
        callers and the autotune trial cache share unchanged keys);
        ``partition`` optionally fixes the row blocks (e.g. the
        ``pencil_partition`` layout of a permuted Poisson system);
        ``halo_depth > 1`` builds the s-step ghost zones under a
        depth-tagged key — the same key shape the autotune trial stage
        uses, so a tuned sstep winner's partition is reused here."""
        from repro.core.partition import partition_csr
        from repro.core.spmv import shard_matrix

        if grid is not None:
            grid = (int(grid[0]), int(grid[1]))
            k = (fmt, int(block), grid)
        else:
            k = (fmt, int(block))
        depth = max(int(halo_depth), 1)
        if depth > 1:
            k = k + (("halo", depth),)
        if k not in self.mats:
            mat = partition_csr(
                self.a, self.n_shards, fmt=fmt, block=(block, block),
                grid=grid, partition=partition, halo_depth=depth,
            )
            self.mats[k] = shard_matrix(self.mesh_for(mat), mat)
            self.partitions += 1
        return self.mats[k]

    def naive_matrix(self):
        """The padded-global (all-gather) partition of the naive baseline."""
        from repro.core.partition import partition_csr
        from repro.core.spmv import shard_matrix

        k = ("allgather", 0)
        if k not in self.mats:
            self.mats[k] = shard_matrix(
                self.mesh,
                partition_csr(self.a, self.n_shards, force_allgather=True),
            )
            self.partitions += 1
        return self.mats[k]

    # -- tuning -------------------------------------------------------------

    def autotune(self, *, objective: str = "energy", budget: int = 6,
                 cache_path: str | None = None, tol: float = 1e-8,
                 nrhs: int = 1):
        """Run (or cache-hit) the two-stage autotuner through this session.

        Trial partitions land in ``self.mats`` so the winning format is
        reused by the final solve; executed trials and new partitions are
        charged to the session counters."""
        from repro.autotune import DEFAULT_PATH
        from repro.autotune import autotune as run_autotune

        before = len(self.mats)
        tune = run_autotune(
            self.a, self.mesh, self.n_shards, objective=objective,
            budget=budget, cache_path=cache_path or DEFAULT_PATH, tol=tol,
            mats=self.mats, nrhs=nrhs,
        )
        self.partitions += len(self.mats) - before
        self.tune_trials += tune.candidates_trialed
        self.tune = tune
        return tune

    # -- compiled solvers ---------------------------------------------------

    def solver(self, mat, *, op: str = "cg", nrhs: int = 1,
               variant: str = "hs", precond=None, tol: float = 1e-8,
               maxiter: int = 100, overlap: bool = True, s: int = 2,
               telemetry: bool = False):
        """Cached :class:`~repro.core.cg.SolverHandle` for (mat, config).

        Handles live in the session's own cache (``self.handles``), so
        their compiled executables are released with the session (e.g. on
        :class:`~repro.autotune.pool.SessionPool` LRU eviction). A
        GridPlan matrix is routed onto its 2-D mesh with the
        ``("rows", "cols")`` collective axes automatically."""
        from repro.core.cg import solver_handle
        from repro.core.spmv import matrix_axis

        axis = matrix_axis(mat)
        return solver_handle(
            self.mesh_for(mat), mat, op=op, nrhs=nrhs, variant=variant,
            precond=precond, tol=tol, maxiter=maxiter, overlap=overlap,
            axis=axis, s=s, telemetry=telemetry, cache=self.handles,
        )

    def close(self):
        """Release everything expensive: partitions + compiled handles.

        Called on pool eviction; the session object stays usable but the
        next solve through it pays the cold path again."""
        self.mats.clear()
        self.handles.clear()
        self.tune = None

    def stats(self) -> dict:
        """JSON-ready counters (the serving ledger's ``sessions`` rows)."""
        return dict(
            n=self.n, shards=self.n_shards, partitions=self.partitions,
            tune_trials=self.tune_trials, solves=self.solves,
            mats=len(self.mats),
        )


def _session_pool():
    from repro.autotune.pool import SessionPool

    return SessionPool(factory=SolverSession)


#: Process-wide session pool: ``solve()`` calls against the same matrix
#: fingerprint + shard count share one warm :class:`SolverSession`.
SESSIONS = None


def default_pool():
    """The lazily-created process-wide session pool."""
    global SESSIONS
    if SESSIONS is None:
        SESSIONS = _session_pool()
    return SESSIONS


def _print_regions(label: str, ledger: dict):
    for name, r in sorted(ledger["regions"].items()):
        LOG.info(
            "  [%s] region %-12s t=%.4es DE=%.4fJ flops=%.3e hbm=%.3eB "
            "ici=%.3eB",
            label, name, r["time_s"], r["de_j"], r["flops"],
            r["hbm_bytes"], r["ici_bytes"],
        )


def _plan_dim_bytes(plan) -> tuple[float, float]:
    """Per-shard halo bytes per exchange, split by grid dimension.

    GridPlan: the per-dimension widths (a corner buffer crosses both links,
    so it counts in both entries and the two sum to the hop-weighted
    collective total). 1-D plans: all traffic rides the single flat axis —
    the ``cols`` axis of the equivalent ``1 x N`` grid."""
    if getattr(plan, "mode", None) == "grid":
        rows_b, cols_b = plan.dim_bytes_per_shard(8)
        return float(rows_b), float(cols_b)
    return 0.0, float(plan.collective_bytes_per_shard(8))


def _write_profile(path: str | None, timelines, payload: dict, log):
    """Write the Chrome-trace profile of the executed legs (``--profile``)."""
    if not path or not timelines:
        return
    from repro.obs.trace_export import write_chrome_trace

    write_chrome_trace(
        path, timelines,
        meta=dict(
            problem=payload.get("problem"), n=payload.get("n"),
            shards=payload.get("shards"), op=payload.get("op"),
        ),
    )
    log(f"profile written: {path}")


def write_ledger_json(path: str | None, payload: dict):
    """Atomically write a ledger JSON (a reader never sees a half-write)."""
    if not path:
        return
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    LOG.info("ledger written: %s", path)


def solve(
    spec: ProblemSpec,
    config: SolverConfig | None = None,
    *,
    ledger: str | None = None,
    profile: str | None = None,
    session: SolverSession | None = None,
    pool=None,
    x64: bool = True,
    verbose: bool = True,
) -> SolveReport:
    """The full solver driver: the body ``launch.solve:main`` used to be.

    Loads (or reuses) the problem, partitions/tunes/compiles through a
    warm :class:`SolverSession` (``session``, else one from ``pool``, else
    the process-wide :data:`SESSIONS` pool — repeat calls for the same
    matrix skip repartition and re-compile), runs the requested legs under
    the energy trace, prints the historical driver report (``verbose``),
    optionally writes the ledger JSON, and returns a :class:`SolveReport`.

    ``profile`` writes a Chrome trace-event JSON of every executed leg's
    power timeline (repro.obs.trace_export; load in chrome://tracing or
    Perfetto — docs/observability.md). With ``config.telemetry`` the
    BCMGX-analog leg additionally records its per-iteration residual
    history into the ledger's ``telemetry`` block.

    ``x64=False`` leaves the caller's JAX precision untouched (in-process
    tests run f32); the CLI always enables x64.
    """
    config = config or SolverConfig()
    config.validate()

    import contextlib
    import time

    import jax

    if x64:
        jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core.partition import pad_block, pad_vector
    from repro.core.spmv import shard_vector
    from repro.energy import trace
    from repro.energy.accounting import CostModel
    from repro.obs.provenance import ledger_meta
    from repro.obs.timeline import build_timeline

    def log(msg):
        if verbose:
            LOG.info("%s", msg)

    a, name = spec.load()
    n = a.shape[0]
    n_shards = spec.shards or len(jax.devices())
    b = np.ones(n)
    grid_cfg = config.grid_shape
    grid = None
    grid_part = None
    if grid_cfg is not None:
        if grid_cfg[0] * grid_cfg[1] != n_shards:
            raise ConfigError(
                f"--grid {config.grid} covers "
                f"{grid_cfg[0] * grid_cfg[1]} shards; running with "
                f"{n_shards}"
            )
        if grid_cfg[0] > 1:  # 1 x N *is* the 1-D layout; build it identically
            grid = grid_cfg
    if grid is not None and spec.problem.startswith("poisson"):
        # Pencil reordering: solve the symmetrically permuted system (same
        # spectrum, CG iterates identical up to the permutation) so each
        # shard owns a z x y pencil and the halo scales with its surface,
        # not the full slab cross-section.
        from repro.core.partition import pencil_partition
        from repro.matrices import poisson as _poisson

        stencil = "7pt" if spec.problem == "poisson7" else "27pt"
        perm, grid_part = pencil_partition(
            _poisson.cube(spec.side, stencil), grid
        )
        a = a[perm][:, perm].tocsr()
        b = b[perm]
    if session is None:
        if pool is None:
            pool = default_pool()
        session = pool.session(a, n_shards)
    mesh = session.mesh
    nrhs = config.nrhs
    log(f"problem={name} n={n} nnz={a.nnz} shards={n_shards} nrhs={nrhs}")

    cost = CostModel()
    tune = None
    fmt, block = config.fmt, config.block
    variant, overlap = config.variant, config.overlap
    sstep_s = config.s or 2  # s-step block size (used iff variant == sstep)
    if config.autotune:
        tune = session.autotune(
            objective=config.objective, budget=config.tune_budget,
            cache_path=config.tune_cache, tol=config.tol, nrhs=nrhs,
        )
        ch = tune.chosen
        fmt, block = ch.fmt, ch.block
        variant, overlap = ch.variant, ch.overlap
        if ch.variant == "sstep":
            sstep_s = ch.s
        grid = ch.grid  # --grid and --autotune are mutually exclusive
        cost = cost.at_freq(ch.freq)
        log(
            f"autotune: objective={tune.objective} chosen={ch.label} "
            f"cached={tune.cached} trialed={tune.candidates_trialed} "
            f"(space {tune.candidates_total})"
        )

    if grid is not None:
        from repro.roofline.analysis import reduce_hops

        # grid collectives stage over the sub-axes: no launch is deeper
        # than the longer one (the extra stage launches are in the trace)
        cost = dataclasses.replace(
            cost, coll_hops=float(reduce_hops(n_shards, grid))
        )

    payload = dict(
        schema=1, problem=name, n=int(n), nnz=int(a.nnz),
        shards=int(n_shards), op=config.op, overlap=bool(overlap),
        format=fmt, nrhs=nrhs, solvers={}, meta=ledger_meta(),
    )
    timelines = []  # (label, Timeline) per executed leg when profiling
    if tune is not None:
        payload["autotune"] = tune.ledger_section()

    precond = None
    amg_info = None
    setup_time = 0.0
    if config.amg or config.amgx_analog:
        from repro.core.amg import make_amg_preconditioner

        t0 = time.perf_counter()
        precond, amg_info = make_amg_preconditioner(
            a, n_shards, amgx_analog=config.amgx_analog
        )
        setup_time = time.perf_counter() - t0
        log(
            f"AMG: {amg_info.n_levels} levels rows={amg_info.level_rows} "
            f"opcx={amg_info.operator_complexity:.2f} setup={setup_time:.4f}s"
        )
        payload["amg"] = dict(
            n_levels=amg_info.n_levels,
            level_rows=list(amg_info.level_rows),
            level_nnz=list(amg_info.level_nnz),
            operator_complexity=amg_info.operator_complexity,
        )

    # the session's partition cache already holds the autotune trials'
    # formats — the winner (and any repeat solve) reuses them; an s-step
    # solve partitions with halo_depth=s so the matrix-powers basis pays
    # one widened exchange per s-iteration block
    depth = sstep_s if (variant == "sstep" and config.op == "cg") else 1
    mat = session.matrix(
        fmt, block, grid=grid, partition=grid_part, halo_depth=depth
    )
    # The Ginkgo-analog baseline keeps the flat ELL layout by definition;
    # only build its (expensive) padded-global partition when a naive leg
    # will actually run — the format sweep (--format != ell), the AMG
    # comparisons, the 2-D grid path (its comparison leg is the 1-D run of
    # the same problem), and the tuned path (whose comparison legs are the
    # autotune trials themselves) never consume it.
    need_naive = (
        mat.fmt == "ell"  # resolved format: --format auto may pick ELL
        if config.op == "spmv"
        # the naive baseline is single-RHS by definition: the batched
        # path's comparison legs are sequential nrhs=1 runs of this driver
        # (benchmarks/multirhs_scaling.py)
        else not (
            config.amg or config.amgx_analog or config.autotune or nrhs > 1
            or grid is not None
        )
    )
    matg = session.naive_matrix() if need_naive else None
    log(
        f"format={mat.fmt} (requested {fmt}) "
        f"interior_bytes={mat.interior_stored_bytes()} "
        f"stored_bytes={mat.stored_bytes()}"
    )
    payload["resolved_format"] = mat.fmt
    payload["interior_stored_bytes"] = int(mat.interior_stored_bytes())
    payload["stored_bytes"] = int(mat.stored_bytes())
    if depth > 1:
        # s-step run: record the ghost-zone depth actually built (allgather
        # fallbacks report 1 — the matrix-powers path did not engage)
        payload["halo_depth"] = int(mat.halo_depth)
        payload["s"] = int(sstep_s)
    if grid is not None or grid_cfg is not None:
        from repro.core.spmv import matrix_axis

        g = grid or grid_cfg
        rows_b, cols_b = _plan_dim_bytes(mat.plan)
        payload["grid"] = [int(g[0]), int(g[1])]
        payload["halo_bytes_rows"] = float(rows_b)
        payload["halo_bytes_cols"] = float(cols_b)
        mesh = session.mesh_for(mat)
        vec_axis = matrix_axis(mat)
    else:
        vec_axis = "shards"

    if nrhs > 1:
        from repro.core.cg import default_rhs_block

        Bpad = pad_block(default_rhs_block(n, nrhs), mat)
        bp = shard_vector(mesh, Bpad, vec_axis)
        x0 = shard_vector(mesh, np.zeros_like(Bpad), vec_axis)
    else:
        bp = shard_vector(mesh, pad_vector(b, mat), vec_axis)
        x0 = shard_vector(mesh, np.zeros_like(pad_vector(b, mat)), vec_axis)

    if config.op == "spmv":
        legs = [
            ("BCMGX-analog", mat,
             session.solver(mat, op="spmv", overlap=overlap)),
        ]
        if need_naive:
            legs.append(
                ("Ginkgo-analog", matg,
                 session.solver(matg, op="spmv", variant="naive"))
            )
        for label, m, h in legs:
            h.warm(m, bp)  # compile: executed counts recorded
            tr = h.trace
            fn = h.fn
            t0 = time.perf_counter()
            for _ in range(100):
                # sync every launch: keeps exactly one execution in flight,
                # so the per-run collective rendezvous can't interleave with
                # the next launch's (XLA CPU spin-waits; on a starved host
                # two in-flight ppermute rounds can livelock each other)
                jax.block_until_ready(fn(m, bp))
            wall = (time.perf_counter() - t0) / 100
            leg_overlap = overlap and label == "BCMGX-analog"
            led = trace.ledger_from_trace(
                tr, iters=0, n_shards=n_shards, cost=cost,
                overlap=leg_overlap, idle_s=0.01, setup_repeats=100,
            )
            if profile:
                timelines.append((label, build_timeline(
                    trace.monitor_from_trace(
                        tr, iters=0, n_shards=n_shards, cost=cost,
                        overlap=leg_overlap, idle_s=0.01, setup_repeats=100,
                    )
                )))
            e = led["totals"]
            t_model = sum(r["time_s"] for r in led["regions"].values())
            log(
                f"{label:14s} iters=100 relres=0.0e+00 "
                f"wall={wall:.6f}s modeled={t_model/100:.4e}s "
                f"DE={e['de_total']:.4f}J peak={e['gpu_power_peak']:.0f}W "
                f"DEgpu={e['de_gpu']:.4f}J DEcpu={e['de_cpu']:.4f}J"
            )
            if verbose:
                _print_regions(label, led)
            payload["solvers"][label] = dict(
                led, wall_s=wall, modeled_s=t_model / 100
            )
        _write_profile(profile, timelines, payload, log)
        write_ledger_json(ledger, payload)
        summary = {
            label: dict(
                wall_s=entry["wall_s"], modeled_s=entry["modeled_s"],
                de_total=entry["totals"]["de_total"],
            )
            for label, entry in payload["solvers"].items()
        }
        return SolveReport(
            problem=name, n=int(n), nnz=int(a.nnz), shards=int(n_shards),
            config=config, summary=summary, ledger=payload,
        )

    h = session.solver(
        mat, nrhs=nrhs, variant=variant, precond=precond,
        tol=config.tol, maxiter=config.maxiter, overlap=overlap,
        s=sstep_s, telemetry=config.telemetry,
    )
    legs = [
        ("BCMGX-analog" if not config.amgx_analog else "AmgX-analog", h)
    ]
    if need_naive:  # paper compares PCG against AmgX, not Ginkgo
        legs.append(
            ("Ginkgo-analog",
             session.solver(matg, variant="naive", tol=config.tol,
                            maxiter=config.maxiter))
        )
    bcmgx_label = legs[0][0]
    summary = {}
    for label, hdl in legs:
        rec = None
        with contextlib.ExitStack() as stack:
            if config.telemetry and label == bcmgx_label:
                from repro.obs import convergence

                # collect the baked-in per-iteration callbacks; the last
                # recorded run (= the final repeat) becomes the history
                rec = stack.enter_context(convergence.record())
            res = hdl.warm(bp, x0)  # warmup/compile: counts recorded
            tr = hdl.trace
            fn = hdl.fn
            walls = []
            for _ in range(config.repeats):
                t0 = time.perf_counter()
                res = fn(bp, x0)
                jax.block_until_ready(res.x)
                walls.append(time.perf_counter() - t0)
            if rec is not None:
                # debug callbacks run on a side thread; drain them before
                # the recorder closes
                jax.effects_barrier()
        wall = sum(walls) / len(walls)
        iters = int(res.iters)
        # the batched leg converges each column independently: report the
        # slowest column's residual (convergence of the whole batch)
        relres = float(np.max(np.asarray(res.rel_residual)))
        # energy ledger: executed per-region counts x executed iterations
        led = trace.ledger_from_trace(
            tr, iters=iters, n_shards=n_shards, cost=cost,
            overlap=(overlap and label != "Ginkgo-analog"), idle_s=0.01,
        )
        if profile:
            timelines.append((label, build_timeline(
                trace.monitor_from_trace(
                    tr, iters=iters, n_shards=n_shards, cost=cost,
                    overlap=(overlap and label != "Ginkgo-analog"),
                    idle_s=0.01,
                )
            )))
        e = led["totals"]
        t_model = sum(r["time_s"] for r in led["regions"].values())
        matrix_bytes = sum(
            r.get("hbm_matrix_bytes", 0.0) for r in led["regions"].values()
        )
        log(
            f"{label:14s} iters={iters} relres={relres:.2e} "
            f"wall={wall:.4f}s modeled={t_model:.4e}s "
            f"DE={e['de_total']:.4f}J peak={e['gpu_power_peak']:.0f}W "
            f"DEgpu={e['de_gpu']:.4f}J DEcpu={e['de_cpu']:.4f}J "
            f"setup={setup_time:.4f}s solve={wall:.4f}s"
        )
        if verbose:
            _print_regions(label, led)
        entry = dict(
            led, wall_s=wall, modeled_s=t_model,
            relres=relres, setup_s=setup_time,
            variant=variant if label == bcmgx_label else "naive",
            # per-solve amortization view: a batched run is nrhs solves
            nrhs=nrhs,
            per_solve_modeled_s=t_model / nrhs,
            per_solve_de_j=e["de_total"] / nrhs,
            per_solve_spmv_matrix_bytes=matrix_bytes / nrhs,
            wall_repeats_s=walls,
            per_solve_wall_s=wall / nrhs,
        )
        if nrhs > 1:
            entry["iters_cols"] = [
                int(v) for v in np.asarray(res.iters_cols)
            ]
        if rec is not None:
            entry["telemetry"] = rec.ledger()
        payload["solvers"][label] = entry
        summary[label] = dict(
            iters=iters, relres=relres, wall_s=wall, modeled_s=t_model,
            de_total=e["de_total"],
        )
        if label == bcmgx_label:
            session.solves += nrhs * config.repeats
    _write_profile(profile, timelines, payload, log)
    write_ledger_json(ledger, payload)
    return SolveReport(
        problem=name, n=int(n), nnz=int(a.nnz), shards=int(n_shards),
        config=config, summary=summary, ledger=payload,
    )
