"""Attention: GQA (+ qk-norm, bias, explicit head_dim) and MLA.

Full-sequence paths use **blockwise (flash-style) attention** — an online-
softmax scan over KV chunks — so 32k-prefill activation memory stays
O(S * chunk) per head instead of O(S^2); this is what makes the prefill_32k
dry-run cells fit. Decode paths attend one query position against the whole
cache.

MLA (MiniCPM3/DeepSeek): queries/keys split into a no-PE part (projected
from a low-rank latent) and a small RoPE part; the decode cache stores only
the (kv_lora + rope) latent per position — the architecture's KV-cache
compression is preserved faithfully.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import flags

from repro.models.layers import (
    KeyGen,
    apply_rope,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise softmax attention core
# ---------------------------------------------------------------------------


def blockwise_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,  # (B, Sk, KVH, hd)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,  # global position of q[0] (decode/prefill)
    kv_chunk: int = 1024,
    scale: float | None = None,
) -> jax.Array:
    """Online-softmax attention over KV chunks (flash-style, jnp-level).

    v may have a different head dim than q/k (MLA).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    hdv = v.shape[-1]
    groups = H // KVH
    scale = (hd**-0.5) if scale is None else scale
    # bf16-score mode: operands stay in their native (bf16) dtype — no
    # upcasts at all — and the MXU accumulates f32. Baseline mode upcasts
    # q/k/v to f32 first (numerically identical softmax stats either way).
    if flags.ATTN_SCORE_BF16:
        op_cast = lambda t: t
        qf = q * jnp.asarray(scale, q.dtype)
    else:
        op_cast = lambda t: t.astype(jnp.float32)
        qf = q.astype(jnp.float32) * scale
    # fold q heads into kv-head groups: (B, Sq, KVH, G, hd)
    qf = qf.reshape(B, Sq, KVH, groups, hd)

    nchunks = -(-Sk // kv_chunk)
    Sk_pad = nchunks * kv_chunk
    if Sk_pad != Sk:
        pad = [(0, 0), (0, Sk_pad - Sk), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(B, nchunks, kv_chunk, KVH, hd)
    vc = v.reshape(B, nchunks, kv_chunk, KVH, hdv)

    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)  # (Sq,)

    def step(carry, inp):
        m, l, acc = carry  # (B,Sq,KVH,G), (B,Sq,KVH,G), (B,Sq,KVH,G,hd)
        kb, vb, c0 = inp  # (B, kv_chunk, KVH, hd), ..., scalar chunk start
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qf, op_cast(kb),
            preferred_element_type=jnp.float32,
        )  # (B,Sq,KVH,G,C) f32
        kv_pos = c0 + jnp.arange(kv_chunk)
        valid = kv_pos < Sk
        if causal:
            mask = (kv_pos[None, :] <= q_pos[:, None]) & valid[None, :]
        else:
            mask = jnp.broadcast_to(valid[None, :], (Sq, kv_chunk))
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        p_op = p.astype(vb.dtype) if flags.ATTN_SCORE_BF16 else p
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p_op, op_cast(vb),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KVH, groups), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, groups), jnp.float32)
    a0 = jnp.zeros((B, Sq, KVH, groups, hdv), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)  # (nchunks, B, C, KVH, hd)
    vc_t = jnp.moveaxis(vc, 1, 0)
    starts = jnp.arange(nchunks) * kv_chunk
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (kc_t, vc_t, starts), unroll=flags.scan_unroll())
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hdv).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, Smax, KVH, hd)
    v_cache: jax.Array,
    pos: jax.Array,  # scalar: index of the new token
    *,
    scale: float | None = None,
) -> jax.Array:
    """One-step attention against the cache (positions > pos masked)."""
    B, _, H, hd = q.shape
    _, Smax, KVH, _ = k_cache.shape
    hdv = v_cache.shape[-1]
    groups = H // KVH
    scale = (hd**-0.5) if scale is None else scale
    qf = (q * scale).astype(jnp.float32).reshape(B, KVH, groups, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    mask = jnp.arange(Smax)[None, None, None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hdv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block-level attention (projections + rope + qk-norm)
# ---------------------------------------------------------------------------


def init_gqa(kg: KeyGen, cfg, dtype) -> dict:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": init_linear(kg, d, h * hd, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(kg, d, kvh * hd, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(kg, d, kvh * hd, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(kg, h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = init_rmsnorm(kg, hd, dtype)
        p["knorm"] = init_rmsnorm(kg, hd, dtype)
    return p


def _gqa_qkv(x, p, cfg, pos):
    B = x.shape[0]
    S = x.shape[1]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = linear(x, p["wq"]).reshape(B, S, h, hd)
    k = linear(x, p["wk"]).reshape(B, S, kvh, hd)
    v = linear(x, p["wv"]).reshape(B, S, kvh, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qnorm"]["scale"], cfg.rmsnorm_eps)
        k = rmsnorm(k, p["knorm"]["scale"], cfg.rmsnorm_eps)
    if cfg.causal:  # encoders (hubert) use learned/no positions; RoPE for LMs
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def gqa_full(x: jax.Array, p: dict, cfg, *, q_offset=0, kv_chunk=1024):
    """Full-sequence GQA (train / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    pos = jnp.asarray(q_offset) + jnp.arange(S)[None, :]
    q, k, v = _gqa_qkv(x, p, cfg, pos)
    o = blockwise_attention(
        q, k, v, causal=cfg.causal, q_offset=q_offset, kv_chunk=kv_chunk
    )
    out = linear(o.reshape(B, S, -1), p["wo"])
    return out, (k, v)


def gqa_decode(x: jax.Array, p: dict, cfg, cache: dict, pos):
    """One-token GQA against the cache. cache = {k: (B,Smax,KVH,hd), v: ...}."""
    B = x.shape[0]
    posv = jnp.full((B, 1), pos)
    q, k, v = _gqa_qkv(x, p, cfg, posv)
    kc = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    vc = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    o = decode_attention(q, kc, vc, pos)
    out = linear(o.reshape(B, 1, -1), p["wo"])
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA (MiniCPM3)
# ---------------------------------------------------------------------------


def init_mla(kg: KeyGen, cfg, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": init_linear(kg, d, m.q_lora_rank, dtype),
        "q_a_norm": init_rmsnorm(kg, m.q_lora_rank, dtype),
        "wq_b": init_linear(kg, m.q_lora_rank, h * qk_dim, dtype),
        "wkv_a": init_linear(kg, d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_a_norm": init_rmsnorm(kg, m.kv_lora_rank, dtype),
        "wkv_b": init_linear(
            kg, m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype
        ),
        "wo": init_linear(kg, h * m.v_head_dim, d, dtype),
    }


def _mla_q(x, p, cfg, pos):
    m = cfg.mla
    B, S = x.shape[0], x.shape[1]
    h = cfg.n_heads
    qa = rmsnorm(linear(x, p["wq_a"]), p["q_a_norm"]["scale"], cfg.rmsnorm_eps)
    q = linear(qa, p["wq_b"]).reshape(B, S, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(x, p, cfg, pos):
    m = cfg.mla
    kv_a = linear(x, p["wkv_a"])  # (B,S, kv_lora + rope)
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_a_norm"]["scale"], cfg.rmsnorm_eps)
    k_rope = apply_rope(k_rope[..., None, :], pos, cfg.rope_theta)  # 1 shared head
    return c_kv, k_rope[..., 0, :]


def _mla_expand(c_kv, p, cfg):
    m = cfg.mla
    B, S = c_kv.shape[0], c_kv.shape[1]
    h = cfg.n_heads
    kv = linear(c_kv, p["wkv_b"]).reshape(
        B, S, h, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    return k_nope, v


def mla_full(x: jax.Array, p: dict, cfg, *, q_offset=0, kv_chunk=1024):
    """Full-sequence MLA. Returns (out, (c_kv, k_rope)) for cache seeding."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    pos = jnp.asarray(q_offset) + jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(x, p, cfg, pos)
    c_kv, k_rope = _mla_kv_latent(x, p, cfg, pos)
    k_nope, v = _mla_expand(c_kv, p, cfg)
    # assemble full q/k with rope part appended; k_rope shared across heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, m.qk_rope_head_dim))],
        axis=-1,
    )
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o = blockwise_attention(
        q, k, v, causal=True, q_offset=q_offset, kv_chunk=kv_chunk, scale=scale
    )
    out = linear(o.reshape(B, S, -1), p["wo"])
    return out, (c_kv, k_rope)


def mla_decode(x: jax.Array, p: dict, cfg, cache: dict, pos):
    """One-token MLA against the latent cache {c_kv: (B,Smax,r), k_rope}."""
    m = cfg.mla
    B = x.shape[0]
    h = cfg.n_heads
    posv = jnp.full((B, 1), pos)
    q_nope, q_rope = _mla_q(x, p, cfg, posv)  # (B,1,h,*)
    c_new, kr_new = _mla_kv_latent(x, p, cfg, posv)
    ckv = lax.dynamic_update_slice(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), (0, pos, 0)
    )
    krc = lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, pos, 0)
    )
    k_nope, v = _mla_expand(ckv, p, cfg)  # (B,Smax,h,*) expanded on the fly
    Smax = ckv.shape[1]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [
            k_nope,
            jnp.broadcast_to(krc[:, :, None, :], (B, Smax, h, m.qk_rope_head_dim)),
        ],
        axis=-1,
    )
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o = decode_attention(q, k, v, pos, scale=scale)
    out = linear(o.reshape(B, 1, -1), p["wo"])
    return out, {"c_kv": ckv, "k_rope": krc}
