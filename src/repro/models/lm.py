"""LM-level API: loss, prefill/decode steps, and ``input_specs``.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of an (arch x shape) cell — weak-type-correct, shardable, no
device allocation — the dry-run contract. Modality frontends are stubs per
the assignment: audio provides frame embeddings, vlm provides patch
embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import flags

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.kvcache import cache_shapes, init_cache
from repro.models.layers import dtype_of, unembed


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------


def chunked_ce_loss(
    hidden: jax.Array,  # (B, S, d)
    head: jax.Array,  # (Vp, d)
    labels: jax.Array,  # (B, S) int32 in [0, vocab)
    *,
    chunk: int = 512,
) -> jax.Array:
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nch = S // chunk
    xc = hidden.reshape(B, nch, chunk, d).swapaxes(0, 1)  # (nch, B, chunk, d)
    lc = labels.reshape(B, nch, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def step(acc, inp):
        x, lbl = inp
        logits = unembed(x, head)  # f32 (B, chunk, Vp)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        return acc + (lse - gold).sum(), None

    total, _ = lax.scan(step, jnp.zeros((), jnp.float32), (xc, lc), unroll=flags.scan_unroll())
    return total / (B * S)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, kv_chunk=1024, remat=True):
    hidden, _, aux = tfm.forward_full(
        params, cfg, batch, kv_chunk=kv_chunk, remat=remat
    )
    ce = chunked_ce_loss(hidden, tfm.head_table(params, cfg), batch["labels"])
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, batch: dict, *, kv_chunk=1024):
    """Full-prompt forward. Returns (last-position logits, cache-seed)."""
    hidden, cache, _ = tfm.forward_full(
        params, cfg, batch, kv_chunk=kv_chunk, remat=False, want_cache=True
    )
    logits = unembed(hidden[:, -1:], tfm.head_table(params, cfg))[:, 0]
    return logits, cache


def serve_step(params, cfg: ArchConfig, token: jax.Array, cache, pos):
    """One decode step: (B,) token ids + cache -> (B, Vp) logits + cache'."""
    hidden, new_cache = tfm.forward_decode(params, cfg, token, cache, pos)
    logits = unembed(hidden, tfm.head_table(params, cfg))[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins per (arch x shape)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Model inputs for one cell. Keys depend on kind:

    train:   {batch: {tokens/frames, labels, [patch_embeds]}}
    prefill: {batch: {tokens/frames, [patch_embeds]}}
    decode:  {token, cache, pos}
    """
    B, S = shape.global_batch, shape.seq_len
    act = dtype_of(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.family == "audio":
            batch["frames"] = _sds((B, S, cfg.d_model), act)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
        if cfg.n_patches:
            batch["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model), act)
        if shape.kind == "train":
            batch["labels"] = _sds((B, S), jnp.int32)
        return {"batch": batch}
    # decode: one new token against a cache of length seq_len
    return {
        "token": _sds((B,), jnp.int32),
        "cache": cache_shapes(cfg, B, S),
        "pos": _sds((), jnp.int32),
    }


def make_inputs(cfg: ArchConfig, shape: ShapeConfig, key=None):
    """Concrete (small-value) inputs matching input_specs — smoke tests."""
    import numpy as np

    rng = np.random.default_rng(0)
    B, S = shape.global_batch, shape.seq_len
    act = dtype_of(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        batch: dict = {}
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model)), act
            )
        else:
            batch["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            )
        if cfg.n_patches:
            batch["patch_embeds"] = jnp.asarray(
                rng.standard_normal((B, cfg.n_patches, cfg.d_model)), act
            )
        if shape.kind == "train":
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            )
        return {"batch": batch}
    return {
        "token": jnp.asarray(rng.integers(0, cfg.vocab_size, (B,)), jnp.int32),
        "cache": init_cache(cfg, B, S),
        "pos": jnp.asarray(S - 1, jnp.int32),
    }
