"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM.

mLSTM (matrix-memory LSTM) is linear attention with exponential gating; we
implement the *stabilized chunkwise* form: within a chunk of length Q the
masked quadratic form, across chunks a carried (H, dk, dv) matrix state, a
(H, dk) normalizer and a (H,) max-stabilizer — O(S*Q) work, O(1)-state
decode (runs ``long_500k``).

sLSTM (scalar-memory, exponential gating, per-head recurrence) is a true
sequential recurrence — implemented as a ``lax.scan`` over time.

Block layout follows the xLSTM paper: pre-norm -> up-projection (factor 2)
-> causal conv -> gated cell -> down-projection; every
``cfg.xlstm.slstm_every``-th block is an sLSTM, the rest mLSTM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import flags

from repro.models.layers import KeyGen, init_rmsnorm, normal_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(kg: KeyGen, cfg, dtype) -> dict:
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    H = cfg.n_heads
    return {
        "norm": init_rmsnorm(kg, d, dtype),
        "w_up": normal_init(kg(), (d, 2 * di), dtype),
        "conv_w": normal_init(kg(), (4, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": normal_init(kg(), (di, di), dtype),
        "wk": normal_init(kg(), (di, di), dtype),
        "wv": normal_init(kg(), (di, di), dtype),
        "w_if": normal_init(kg(), (di, 2 * H), jnp.float32, scale=0.02),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # forget-gate bias init high
        "out_norm": init_rmsnorm(kg, di, dtype),
        "w_down": normal_init(kg(), (di, d), dtype),
    }


def _conv_silu(x, w, b, state=None):
    from repro.models.ssm import _causal_conv

    return _causal_conv(x, w, b, state)


def _mlstm_qkvif(xin, p, cfg, conv_state=None):
    di = p["wq"].shape[0]
    H = cfg.n_heads
    B, S, _ = xin.shape
    xc, new_conv = _conv_silu(xin, p["conv_w"], p["conv_b"], conv_state)
    dk = di // H
    q = jnp.einsum("bsd,de->bse", xc, p["wq"]).reshape(B, S, H, dk)
    k = jnp.einsum("bsd,de->bse", xc, p["wk"]).reshape(B, S, H, dk)
    v = jnp.einsum("bsd,de->bse", xin, p["wv"]).reshape(B, S, H, dk)
    gates = jnp.einsum("bsd,dg->bsg", xc.astype(jnp.float32), p["w_if"])
    i_pre = gates[..., :H] + p["b_i"]
    f_pre = gates[..., H:] + p["b_f"]
    log_f = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    return q, k, v, i_pre, log_f, new_conv, dk


def mlstm_forward(x: jax.Array, p: dict, cfg, state=None):
    """Chunkwise mLSTM block. x: (B,S,d) -> (y, new_state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    from repro.models.ssm import pick_chunk
    Q = pick_chunk(S, cfg.xlstm.chunk)
    nc = S // Q

    xn = rmsnorm(x, p["norm"]["scale"], cfg.rmsnorm_eps)
    up = jnp.einsum("bsd,de->bse", xn, p["w_up"])
    xin, z = jnp.split(up, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    q, k, v, i_pre, log_f, new_conv, dk = _mlstm_qkvif(xin, p, cfg, conv_state)
    scale = dk**-0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, nc, Q, H, dk)
    kf = k.astype(jnp.float32).reshape(B, nc, Q, H, dk)
    vf = v.astype(jnp.float32).reshape(B, nc, Q, H, dk)
    ic = i_pre.reshape(B, nc, Q, H)
    lfc = log_f.reshape(B, nc, Q, H)

    if state is None:
        C0 = jnp.zeros((B, H, dk, dk), jnp.float32)
        n0 = jnp.zeros((B, H, dk), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = (
            state["C"].astype(jnp.float32),
            state["n"].astype(jnp.float32),
            state["m"],
        )

    def chunk_step(carry, inp):
        C, n, m = carry
        qc, kc, vc, icq, lf = inp  # (B,Q,H,dk) x3, (B,Q,H) x2
        b = jnp.cumsum(lf, axis=1)  # (B,Q,H) cumulative log decay in chunk
        # stabilizers
        a_s = icq - b  # (B,Q,H): i_s - b_s
        M = lax.cummax(a_s, axis=1)  # running max over s
        m_intra = b + M
        m_carry = m[:, None, :] + b
        m_t = jnp.maximum(m_intra, m_carry)  # (B,Q,H)
        # intra-chunk decay matrix D_ts = exp(b_t - b_s + i_s - m_t), s <= t
        Dlog = (
            b[:, :, None, :] - b[:, None, :, :] + icq[:, None, :, :]
        )  # (B,t,s,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        Dlog = jnp.where(tri[None, :, :, None], Dlog, -jnp.inf)
        D = jnp.exp(Dlog - m_t[:, :, None, :])
        G = jnp.einsum("bthd,bshd->btsh", qc, kc)  # (B,t,s,H)
        num = jnp.einsum("btsh,btsh,bshd->bthd", G, D, vc)
        den = jnp.einsum("btsh,btsh->bth", G, D)  # q.n intra
        # carry contribution
        carry_scale = jnp.exp(m[:, None, :] + b - m_t)  # (B,Q,H)
        num = num + carry_scale[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C)
        den = den + carry_scale * jnp.einsum("bthd,bhd->bth", qc, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # update carry to end of chunk
        b_last = b[:, -1, :]  # (B,H)
        m_new = jnp.maximum(m + b_last, m_intra[:, -1, :])
        dec_end = jnp.exp(b_last[:, None, :] - b + icq - m_new[:, None, :])
        C_new = jnp.exp(m + b_last - m_new)[..., None, None] * C + jnp.einsum(
            "bsh,bshd,bshe->bhde", dec_end, kc, vc
        )
        n_new = jnp.exp(m + b_last - m_new)[..., None] * n + jnp.einsum(
            "bsh,bshd->bhd", dec_end, kc
        )
        return (C_new, n_new, m_new), h

    inputs = tuple(
        jnp.moveaxis(t, 1, 0) for t in (qf, kf, vf, ic, lfc)
    )
    (Cf, nf, mf), hs = lax.scan(chunk_step, (C0, n0, m0), inputs, unroll=flags.scan_unroll())
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, -1)  # (B,S,di)
    h = rmsnorm(h.astype(x.dtype), p["out_norm"]["scale"], cfg.rmsnorm_eps)
    h = h * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", h, p["w_down"])
    new_state = {
        "conv": new_conv.astype(x.dtype),
        "C": Cf,
        "n": nf,
        "m": mf,
    }
    return x + y, new_state


def mlstm_decode(x: jax.Array, p: dict, cfg, state: dict):
    """One-token mLSTM step. x: (B,1,d)."""
    B = x.shape[0]
    H = cfg.n_heads
    xn = rmsnorm(x, p["norm"]["scale"], cfg.rmsnorm_eps)
    up = jnp.einsum("bsd,de->bse", xn, p["w_up"])
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, log_f, new_conv, dk = _mlstm_qkvif(
        xin, p, cfg, state["conv"]
    )
    qf = q.astype(jnp.float32)[:, 0] * dk**-0.5  # (B,H,dk)
    kf = k.astype(jnp.float32)[:, 0]
    vf = v.astype(jnp.float32)[:, 0]
    iv = i_pre[:, 0]  # (B,H)
    lf = log_f[:, 0]
    C, n, m = state["C"].astype(jnp.float32), state["n"].astype(jnp.float32), state["m"]
    m_new = jnp.maximum(lf + m, iv)
    f_s = jnp.exp(lf + m - m_new)
    i_s = jnp.exp(iv - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", kf, vf
    )
    n = f_s[..., None] * n + i_s[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new)
    )
    h = (num / den[..., None]).reshape(B, 1, -1)
    h = rmsnorm(h.astype(x.dtype), p["out_norm"]["scale"], cfg.rmsnorm_eps)
    h = h * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", h, p["w_down"])
    return x + y, {"conv": new_conv.astype(x.dtype), "C": C, "n": n, "m": m_new}


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    H = cfg.n_heads
    dk = di // H
    return {
        "conv": jnp.zeros((batch, 3, di), dtype),
        "C": jnp.zeros((batch, H, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, H, dk), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(kg: KeyGen, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    return {
        "norm": init_rmsnorm(kg, d, dtype),
        "w": normal_init(kg(), (d, 4 * d), dtype),  # z, i, f, o pre-acts
        "r": normal_init(kg(), (H, hd, 4 * hd), dtype, scale=0.02),  # per-head rec
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": init_rmsnorm(kg, d, dtype),
        "w_down": normal_init(kg(), (d, d), dtype),
    }


def _slstm_cell(carry, wx, p, cfg):
    """One time step. carry = (c, n, h, m), each (B, H, hd)."""
    c, n, h, m = carry
    H = cfg.n_heads
    B = c.shape[0]
    hd = c.shape[-1]
    rec = jnp.einsum("bhd,hde->bhe", h.astype(p["r"].dtype), p["r"])  # (B,H,4hd)
    pre = wx.reshape(B, H, 4 * hd).astype(jnp.float32) + rec.astype(jnp.float32)
    zp, ip, fp, op = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zp)
    o = jax.nn.sigmoid(op)
    log_f = jax.nn.log_sigmoid(fp)
    m_new = jnp.maximum(log_f + m, ip)
    i_s = jnp.exp(ip - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(x: jax.Array, p: dict, cfg, state=None):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xn = rmsnorm(x, p["norm"]["scale"], cfg.rmsnorm_eps)
    wx = jnp.einsum("bsd,de->bse", xn, p["w"]) + p["b"].astype(xn.dtype)
    if state is None:
        z0 = jnp.zeros((B, H, hd), jnp.float32)
        carry = (z0, z0, z0, jnp.full((B, H, hd), -jnp.inf, jnp.float32))
    else:
        carry = (state["c"], state["n"], state["h"], state["m"])

    def step(carry, wx_t):
        return _slstm_cell(carry, wx_t, p, cfg)

    carry, hs = lax.scan(step, carry, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"]["scale"], cfg.rmsnorm_eps)
    y = jnp.einsum("bsd,de->bsd", h, p["w_down"])
    c, n, hh, m = carry
    return x + y, {"c": c, "n": n, "h": hh, "m": m}


def slstm_decode(x: jax.Array, p: dict, cfg, state: dict):
    y, new_state = slstm_forward(
        x, p, cfg, state={k: state[k] for k in ("c", "n", "h", "m")}
    )
    return y, new_state


def init_slstm_state(cfg, batch: int):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, H, hd), -jnp.inf)}
