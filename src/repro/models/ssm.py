"""Mamba2 (SSD) block: chunked-parallel training scan + O(1) decode step.

Implements the state-space duality algorithm from the Mamba2 paper: the
sequence is split into chunks of length Q; within a chunk the output is the
masked-decay quadratic form, across chunks a (head, P, N) state is carried
by a linear recurrence — total work O(S * Q) instead of O(S^2), and decode
is a single state update (this is what makes ``long_500k`` runnable for the
ssm/hybrid architectures).

Layout: d_inner = expand * d_model, heads H = d_inner / head_dim P,
state size N = d_state, ngroups = 1 (B/C shared across heads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import flags

from repro.models.layers import KeyGen, init_rmsnorm, normal_init, rmsnorm


def init_mamba2(kg: KeyGen, cfg, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = s.n_heads(d)
    N = s.d_state
    conv_dim = di + 2 * N  # conv over [x, B, C]
    return {
        "in_proj": normal_init(kg(), (d, 2 * di + 2 * N + H), dtype),
        "conv_w": normal_init(kg(), (s.d_conv, conv_dim), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.zeros((H,), jnp.float32),  # A = -exp(a_log)
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(kg, di, dtype),
        "out_proj": normal_init(kg(), (di, d), dtype),
    }


def _split_proj(xz, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = s.n_heads(d)
    N = s.d_state
    z, xBC, dt = jnp.split(xz, [di, 2 * di + 2 * N], axis=-1)
    return z, xBC, dt, di, H, N


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv, width K. state: (B, K-1, C) trailing context."""
    K = w.shape[0]
    B, S, C = xBC.shape
    if state is None:
        pad = jnp.zeros((B, K - 1, C), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, k : k + S, :] * w[k][None, None, :] for k in range(K))
    new_state = xp[:, S:, :]  # last K-1 inputs
    return jax.nn.silu(out + b), new_state


def pick_chunk(S: int, max_q: int) -> int:
    """Largest divisor of S that is <= max_q (chunked scans need S % Q == 0)."""
    q = min(S, max_q)
    while S % q:
        q -= 1
    return max(q, 1)


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums:
    out[i, j] = sum_{j < t <= i} a[t] for i >= j, -inf otherwise."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_forward(x: jax.Array, p: dict, cfg, state=None):
    """Chunked SSD forward. x: (B, S, d). Returns (y, new_state).

    state = {"conv": (B, K-1, conv_dim), "ssm": (B, H, P, N)} or None.
    S must be a multiple of cfg.ssm.chunk (pad upstream) unless S == 1.
    """
    s = cfg.ssm
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt, di, H, N = _split_proj(xz, cfg)
    P = s.head_dim

    conv_state = None if state is None else state["conv"]
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"])  # (H,)
    da = dt * A  # (B,S,H) log decay per step
    xdt = xs.astype(jnp.float32) * dt[..., None]  # dt-scaled input

    h0 = (
        jnp.zeros((B, H, P, N), jnp.float32)
        if state is None
        else state["ssm"].astype(jnp.float32)
    )

    Q = pick_chunk(S, s.chunk)
    nc = S // Q
    dac = da.reshape(B, nc, Q, H)
    xc = xdt.reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)

    # intra-chunk (diagonal blocks): decay matrix L (B,nc,H,Q,Q)
    op_dt = jnp.bfloat16 if flags.SSD_BF16 else jnp.float32
    L = jnp.exp(_segsum(jnp.moveaxis(dac, -1, 2)))  # (B,nc,H,Q,Q)
    G = jnp.einsum(
        "bcqn,bckn->bcqk", Cc.astype(op_dt), Bc.astype(op_dt),
        preferred_element_type=jnp.float32,
    )  # (B,nc,Q,Q)
    y_diag = jnp.einsum(
        "bcqk,bchqk,bckhp->bcqhp",
        G.astype(op_dt), L.astype(op_dt), xc.astype(op_dt),
        preferred_element_type=jnp.float32,
    )

    # chunk-end states: S_c = sum_j exp(cum_end - cum_j) B_j (x_j dt_j)
    cum = jnp.cumsum(dac, axis=2)  # (B,nc,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    S_c = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchpn",
        decay_to_end.astype(op_dt), Bc.astype(op_dt), xc.astype(op_dt),
        preferred_element_type=jnp.float32,
    )

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H)

    def step(h, inp):
        dchunk, s_c = inp  # (B,H), (B,H,P,N)
        h_new = h * dchunk[..., None, None] + s_c
        return h_new, h  # emit state BEFORE this chunk

    (h_final, h_prevs) = lax.scan(
        step,
        h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0)),
        unroll=flags.scan_unroll(),
    )
    h_prev = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk contribution: y_t += exp(cum_t) C_t . h_prev
    decay_in = jnp.exp(cum)  # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bcqh,bcqn,bchpn->bcqhp", decay_in, Cc, h_prev
    )

    y = (y_diag + y_inter).reshape(B, S, H, P)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"]["scale"], cfg.rmsnorm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_state = {"conv": new_conv.astype(x.dtype), "ssm": h_final.astype(jnp.float32)}
    return out, new_state


def mamba2_decode(x: jax.Array, p: dict, cfg, state: dict):
    """One-token step. x: (B, 1, d)."""
    s = cfg.ssm
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt, di, H, N = _split_proj(xz, cfg)
    P = s.head_dim
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], state["conv"])
    xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
    xs = xs.reshape(B, 1, H, P).astype(jnp.float32)[:, 0]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * A)  # (B,H)
    h = state["ssm"].astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)  # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    h = h * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xs, Bv
    )
    y = jnp.einsum("bn,bhpn->bhp", Cv, h) + xs * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"]["scale"], cfg.rmsnorm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv.astype(x.dtype), "ssm": h}


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = s.n_heads(d)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state), dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }
