"""Cache construction for every architecture family.

``init_cache(cfg, batch, max_len)`` returns the pytree expected by
``transformer.forward_decode`` (stacking matches the scan structure), filled
with zeros; ``cache_shapes`` returns the matching ShapeDtypeStruct tree for
the dry-run (no allocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import dtype_of


def _kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def _stack(tree, n: int):
    return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype), tree)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    dtype = dtype_of(cfg.dtype)
    if cfg.xlstm is not None:
        k = cfg.xlstm.slstm_every
        G = cfg.n_layers // k
        m_state = xlstm_mod.init_mlstm_state(cfg, batch, dtype)
        s_state = xlstm_mod.init_slstm_state(cfg, batch)
        return (_stack(_stack(m_state, k - 1), G), _stack(s_state, G))
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        G = cfg.n_layers // k
        T = cfg.n_layers - G * k
        m_state = ssm_mod.init_mamba2_state(cfg, batch, dtype)
        kv = _kv_cache(cfg, batch, max_len, dtype)
        g = (_stack(_stack(m_state, k), G), _stack(kv, G))
        t = _stack(m_state, T) if T else None
        return (g, t)
    if cfg.ssm is not None:
        return _stack(ssm_mod.init_mamba2_state(cfg, batch, dtype), cfg.n_layers)
    return _stack(_kv_cache(cfg, batch, max_len, dtype), cfg.n_layers)


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def cache_bytes(cfg: ArchConfig, batch: int, max_len: int) -> int:
    tree = cache_shapes(cfg, batch, max_len)
    return sum(
        int(np_prod(l.shape)) * l.dtype.itemsize for l in jax.tree.leaves(tree)
    )


def np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out
