"""Mixture-of-Experts FFN: top-k routing with capacity + sort-based dispatch.

Expert-parallel friendly: expert tensors carry a leading E axis (sharded over
the ``model``/EP mesh axis); tokens are dispatched by a scatter into the
(E*C, d) buffer and combined by a gather — both well-handled by GSPMD as
all-to-all-class collectives.

Arctic-style ``dense_residual`` runs a small dense MLP in parallel with the
routed experts and sums the outputs.

Capacity: C = ceil(T * top_k * capacity_factor / E); overflow tokens are
dropped (their combine weight contribution is zero) — standard GShard
semantics, load-balance loss included.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import KeyGen, init_mlp, mlp, normal_init


def init_moe(kg: KeyGen, cfg, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    e, f = m.n_experts, m.d_ff_expert
    p = {
        "router": normal_init(kg(), (d, e), jnp.float32, scale=0.02),
        "wg": normal_init(kg(), (e, d, f), dtype),
        "wu": normal_init(kg(), (e, d, f), dtype),
        "wd": normal_init(kg(), (e, f, d), dtype),
    }
    if m.dense_residual:
        p["dense"] = init_mlp(kg, d, m.d_ff_dense, dtype, cfg.mlp_act)
    return p


def moe_ffn(x: jax.Array, p: dict, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = max(int(-(-T * K * m.capacity_factor // E)), 1)

    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    # --- capacity assignment: rank of each (token, k) within its expert ----
    flat_e = top_e.reshape(-1)  # (T*K,) arrival order = token order
    order = jnp.argsort(flat_e, stable=True)  # group by expert
    # rank within expert for the sorted sequence
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    rank_sorted = jnp.arange(T * K) - seg_start[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)  # drop slot at the end

    # --- dispatch: scatter tokens into (E*C+1, d) ---------------------------
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = buf.at[slot].set(xt[tok_idx])  # later duplicates overwrite; same token
    eb = buf[: E * C].reshape(E, C, d)

    # --- expert FFN (E-parallel) --------------------------------------------
    from repro.models.layers import act_fn

    g = act_fn(cfg.mlp_act)(jnp.einsum("ecd,edf->ecf", eb, p["wg"]))
    u = jnp.einsum("ecd,edf->ecf", eb, p["wu"])
    out_e = jnp.einsum("ecf,efd->ecd", g * u, p["wd"])  # (E, C, d)

    # --- combine: gather back and weight ------------------------------------
    flat_out = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), out_e.dtype)], axis=0
    )
    y = flat_out[slot].reshape(T, K, d)
    w = (top_p * keep.reshape(T, K)).astype(y.dtype)
    yt = jnp.einsum("tkd,tk->td", y, w)

    if m.dense_residual:
        yt = yt + mlp(xt, p["dense"], cfg.mlp_act)
    return yt.reshape(B, S, d), aux
