"""Global lowering flags.

``UNROLL_SCANS`` — when True, every static-trip-count ``lax.scan`` in the
model stack lowers fully unrolled. Used ONLY by the dry-run cost probe:
XLA's HLO cost analysis counts a while-loop body once regardless of trip
count, so the rolled (deployable) module under-reports FLOPs/bytes by ~L x.
Unrolling yields the exact per-step HLO cost; the rolled module still
provides memory_analysis + the collective schedule. The sLSTM time scan is
exempt (unrolling 32k time steps is infeasible; its body is <1% of xlstm
cell cost — see DESIGN.md).
"""

UNROLL_SCANS = False

# §Perf hillclimb lever: compute attention score/PV matmuls from bf16
# operands with f32 accumulation (MXU-native) instead of casting inputs to
# f32 first. Halves the dominant score-tensor HBM traffic; softmax
# statistics stay f32.
ATTN_SCORE_BF16 = False

# Same lever for the Mamba2/SSD intra-chunk einsums: bf16 operands, f32
# accumulation (decay logits/stabilizers stay f32).
SSD_BF16 = False


def scan_unroll():
    """Value for lax.scan(..., unroll=...)."""
    return True if UNROLL_SCANS else 1
