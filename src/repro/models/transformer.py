"""Model assembly: blocks, scan-over-layers stacks, init, forward paths.

All ten assigned architectures are built from four stack patterns:

* ``uniform``  — dense / moe / audio / vlm: one homogeneous block scanned
  over L layers (params stacked on a leading L axis — keeps HLO size and
  compile time O(1) in depth, the production-framework discipline);
* ``xlstm``    — groups of (slstm_every-1) mLSTM blocks + 1 sLSTM block,
  scanned over groups with a nested scan inside;
* ``hybrid``   — zamba2: groups of k Mamba2 blocks followed by ONE shared
  (weight-tied) attention+MLP block, plus trailing Mamba2 blocks;
* encoder-only is ``uniform`` with bidirectional attention and no decode.

Every forward path exists in two flavors: full-sequence (train / prefill,
returning per-layer cache/state) and single-token decode (cache in/out).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import flags
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    KeyGen,
    dtype_of,
    embed,
    init_embed,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
)


# ---------------------------------------------------------------------------
# Standard transformer block (attention + FFN)
# ---------------------------------------------------------------------------


def init_block(kg: KeyGen, cfg: ArchConfig, dtype) -> dict:
    p = {"ln1": init_rmsnorm(kg, cfg.d_model, dtype)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(kg, cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(kg, cfg, dtype)
    p["ln2"] = init_rmsnorm(kg, cfg.d_model, dtype)
    if cfg.moe is not None:
        p["ffn"] = moe_mod.init_moe(kg, cfg, dtype)
    else:
        p["ffn"] = init_mlp(kg, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_act)
    return p


def block_full(x, p, cfg: ArchConfig, *, q_offset=0, kv_chunk=1024):
    """Full-seq block. Returns (x, cache_seed, aux_loss)."""
    h = rmsnorm(x, p["ln1"]["scale"], cfg.rmsnorm_eps)
    if cfg.mla is not None:
        a, kv = attn.mla_full(h, p["attn"], cfg, q_offset=q_offset, kv_chunk=kv_chunk)
        cache = {"c_kv": kv[0], "k_rope": kv[1]}
    else:
        a, kv = attn.gqa_full(h, p["attn"], cfg, q_offset=q_offset, kv_chunk=kv_chunk)
        cache = {"k": kv[0], "v": kv[1]}
    x = x + a
    h = rmsnorm(x, p["ln2"]["scale"], cfg.rmsnorm_eps)
    if cfg.moe is not None:
        f, aux = moe_mod.moe_ffn(h, p["ffn"], cfg)
    else:
        f, aux = mlp(h, p["ffn"], cfg.mlp_act), jnp.zeros((), jnp.float32)
    return x + f, cache, aux


def block_decode(x, p, cfg: ArchConfig, cache, pos):
    h = rmsnorm(x, p["ln1"]["scale"], cfg.rmsnorm_eps)
    if cfg.mla is not None:
        a, cache = attn.mla_decode(h, p["attn"], cfg, cache, pos)
    else:
        a, cache = attn.gqa_decode(h, p["attn"], cfg, cache, pos)
    x = x + a
    h = rmsnorm(x, p["ln2"]["scale"], cfg.rmsnorm_eps)
    if cfg.moe is not None:
        f, _ = moe_mod.moe_ffn(h, p["ffn"], cfg)
    else:
        f = mlp(h, p["ffn"], cfg.mlp_act)
    return x + f, cache


# ---------------------------------------------------------------------------
# Parameter init (eval_shape-safe)
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    kg = KeyGen(key)
    params: dict = {"embed": init_embed(kg, cfg.vocab_padded, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embed(kg, cfg.vocab_padded, cfg.d_model, dtype)
    params["final_norm"] = init_rmsnorm(kg, cfg.d_model, dtype)

    def stack(init_fn, n):
        """Stack n inits on a leading axis (vmapped keys, identical shapes)."""
        keys = jax.random.split(kg(), n)
        return jax.vmap(lambda k: init_fn(KeyGen(k)))(keys)

    if cfg.xlstm is not None:
        k = cfg.xlstm.slstm_every
        if k <= 0:
            params["blocks"] = stack(
                lambda g: xlstm_mod.init_mlstm(g, cfg, dtype), cfg.n_layers
            )
        else:
            G = cfg.n_layers // k
            assert G * k == cfg.n_layers, "n_layers must divide into slstm groups"
            params["mlstm"] = stack(
                lambda g: jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[xlstm_mod.init_mlstm(g, cfg, dtype) for _ in range(k - 1)],
                ),
                G,
            )
            params["slstm"] = stack(
                lambda g: xlstm_mod.init_slstm(g, cfg, dtype), G
            )
    elif cfg.family == "hybrid":
        k = cfg.shared_attn_every
        G = cfg.n_layers // k
        T = cfg.n_layers - G * k
        params["mamba_g"] = stack(
            lambda g: jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[ssm_mod.init_mamba2(g, cfg, dtype) for _ in range(k)],
            ),
            G,
        )
        if T:
            params["mamba_t"] = stack(
                lambda g: ssm_mod.init_mamba2(g, cfg, dtype), T
            )
        params["shared"] = init_block(
            KeyGen(kg()), dataclasses.replace(cfg, moe=None), dtype
        )
    elif cfg.ssm is not None:
        params["blocks"] = stack(
            lambda g: ssm_mod.init_mamba2(g, cfg, dtype), cfg.n_layers
        )
    else:
        params["blocks"] = stack(lambda g: init_block(g, cfg, dtype), cfg.n_layers)
    return params


# ---------------------------------------------------------------------------
# Embedding / head helpers
# ---------------------------------------------------------------------------


def embed_inputs(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    if cfg.family == "audio":
        return batch["frames"].astype(dtype_of(cfg.dtype))
    x = embed(batch["tokens"], params["embed"])
    if cfg.n_patches and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = lax.dynamic_update_slice(x, pe, (0, 0, 0))
    return x


def head_table(params, cfg: ArchConfig):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_full(
    params, cfg: ArchConfig, batch: dict, *, kv_chunk=1024, remat=True,
    want_cache=False,
):
    """Returns (hidden (B,S,d), cache_tree_or_None, aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.xlstm is not None and "mlstm" in params:
        def group(x, gp):
            mp, sp = gp

            def inner(x, lp):
                y, st = xlstm_mod.mlstm_forward(x, lp, cfg)
                return y, st

            inner_fn = jax.checkpoint(inner) if remat else inner
            x, m_states = lax.scan(inner_fn, x, mp, unroll=flags.scan_unroll())
            x, s_state = xlstm_mod.slstm_forward(x, sp, cfg)
            return x, (m_states, s_state)

        group_fn = jax.checkpoint(group) if remat else group
        x, states = lax.scan(group_fn, x, (params["mlstm"], params["slstm"]), unroll=flags.scan_unroll())
        cache = states if want_cache else None
        return _finish(x, params, cfg), cache, aux_total

    if cfg.family == "hybrid":
        def group(x, gp):
            mp, shared_dummy = gp

            def inner(x, lp):
                y, st = ssm_mod.mamba2_forward(x, lp, cfg)
                return y, st

            inner_fn = jax.checkpoint(inner) if remat else inner
            x, m_states = lax.scan(inner_fn, x, mp, unroll=flags.scan_unroll())
            x, kv, _ = block_full(x, params["shared"], cfg, kv_chunk=kv_chunk)
            return x, (m_states, kv)

        G = jax.tree.leaves(params["mamba_g"])[0].shape[0]
        group_fn = jax.checkpoint(group) if remat else group
        x, states = lax.scan(
            group_fn, x, (params["mamba_g"], jnp.zeros((G,), jnp.int32)),
            unroll=flags.scan_unroll(),
        )
        t_states = None
        if "mamba_t" in params:
            def trail(x, lp):
                y, st = ssm_mod.mamba2_forward(x, lp, cfg)
                return y, st

            trail_fn = jax.checkpoint(trail) if remat else trail
            x, t_states = lax.scan(trail_fn, x, params["mamba_t"], unroll=flags.scan_unroll())
        cache = (states, t_states) if want_cache else None
        return _finish(x, params, cfg), cache, aux_total

    if cfg.ssm is not None:  # pure mamba stack (not among assigned, but supported)
        def body(x, lp):
            y, st = ssm_mod.mamba2_forward(x, lp, cfg)
            return y, st

        body_fn = jax.checkpoint(body) if remat else body
        x, states = lax.scan(body_fn, x, params["blocks"], unroll=flags.scan_unroll())
        return _finish(x, params, cfg), (states if want_cache else None), aux_total

    # uniform attention stack
    def body(carry, lp):
        x, aux = carry
        y, kv, a = block_full(x, lp, cfg, kv_chunk=kv_chunk)
        return (y, aux + a), kv

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux_total), kvs = lax.scan(body_fn, (x, aux_total), params["blocks"], unroll=flags.scan_unroll())
    cache = kvs if want_cache else None
    return _finish(x, params, cfg), cache, aux_total / max(cfg.n_layers, 1)


def _finish(x, params, cfg):
    return rmsnorm(x, params["final_norm"]["scale"], cfg.rmsnorm_eps)


# ---------------------------------------------------------------------------
# Single-token decode
# ---------------------------------------------------------------------------


def forward_decode(params, cfg: ArchConfig, token: jax.Array, cache, pos):
    """token: (B,) int32; cache from kvcache.init_cache; pos: scalar int32.

    Returns (hidden (B,1,d), new_cache).
    """
    x = embed(token[:, None], params["embed"])

    if cfg.xlstm is not None and "mlstm" in params:
        m_c, s_c = cache

        def group(x, gp):
            mp, sp, mc, sc = gp

            def inner(x, lp_c):
                lp, c = lp_c
                y, st = xlstm_mod.mlstm_decode(x, lp, cfg, c)
                return y, st

            x, m_new = lax.scan(inner, x, (mp, mc), unroll=flags.scan_unroll())
            x, s_new = xlstm_mod.slstm_decode(x, sp, cfg, sc)
            return x, (m_new, s_new)

        x, (m_new, s_new) = lax.scan(
            group, x, (params["mlstm"], params["slstm"], m_c, s_c),
            unroll=flags.scan_unroll(),
        )
        return _finish(x, params, cfg), (m_new, s_new)

    if cfg.family == "hybrid":
        (g_states, kv_caches), t_states = cache

        def group(x, gp):
            mp, mc, kvc = gp

            def inner(x, lp_c):
                lp, c = lp_c
                y, st = ssm_mod.mamba2_decode(x, lp, cfg, c)
                return y, st

            x, m_new = lax.scan(inner, x, (mp, mc), unroll=flags.scan_unroll())
            x, kv_new = block_decode(x, params["shared"], cfg, kvc, pos)
            return x, (m_new, kv_new)

        x, (g_new, kv_new) = lax.scan(
            group, x, (params["mamba_g"], g_states, kv_caches),
            unroll=flags.scan_unroll(),
        )
        t_new = None
        if "mamba_t" in params:
            def trail(x, lp_c):
                lp, c = lp_c
                y, st = ssm_mod.mamba2_decode(x, lp, cfg, c)
                return y, st

            x, t_new = lax.scan(trail, x, (params["mamba_t"], t_states), unroll=flags.scan_unroll())
        return _finish(x, params, cfg), ((g_new, kv_new), t_new)

    if cfg.ssm is not None:
        def body(x, lp_c):
            lp, c = lp_c
            y, st = ssm_mod.mamba2_decode(x, lp, cfg, c)
            return y, st

        x, new = lax.scan(body, x, (params["blocks"], cache), unroll=flags.scan_unroll())
        return _finish(x, params, cfg), new

    def body(x, lp_c):
        lp, c = lp_c
        y, c2 = block_decode(x, lp, cfg, c, pos)
        return y, c2

    x, new_cache = lax.scan(body, x, (params["blocks"], cache), unroll=flags.scan_unroll())
    return _finish(x, params, cfg), new_cache
