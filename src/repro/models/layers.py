"""Model primitives: norms, projections, RoPE, activations, embeddings.

Pure functions over dict-shaped parameter trees (no framework dependency);
every ``init_*`` works under ``jax.eval_shape`` so the dry-run can build
parameter ShapeDtypeStructs without allocating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# Initializers (keyed, eval_shape-safe)
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    scale = (1.0 / np.sqrt(fan_in)) if scale is None else scale
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Deterministic key splitter for init functions."""

    def __init__(self, key):
        self.key = key

    def __call__(self):
        self.key, sub = jax.random.split(self.key)
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rmsnorm(kg, d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # stored as (scale - 1)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


def linear(x: jax.Array, p: dict) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def init_linear(kg, d_in, d_out, dtype, bias=False):
    p = {"w": normal_init(kg(), (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# Activations / gated MLPs
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_plain": lambda x: jax.nn.gelu(x, approximate=True),
    }[name]


def mlp(x: jax.Array, p: dict, act: str) -> jax.Array:
    """SwiGLU/GeGLU (3 mats) or plain 2-mat MLP (act == *_plain)."""
    if "wg" in p:
        g = act_fn(act)(jnp.einsum("...d,df->...f", x, p["wg"]))
        u = jnp.einsum("...d,df->...f", x, p["wu"])
        return jnp.einsum("...f,fd->...d", g * u, p["wd"])
    h = act_fn(act)(jnp.einsum("...d,df->...f", x, p["wu"]))
    return jnp.einsum("...f,fd->...d", h, p["wd"])


def init_mlp(kg, d, d_ff, dtype, act: str):
    if act.endswith("_plain"):
        return {
            "wu": normal_init(kg(), (d, d_ff), dtype),
            "wd": normal_init(kg(), (d_ff, d), dtype),
        }
    return {
        "wg": normal_init(kg(), (d, d_ff), dtype),
        "wu": normal_init(kg(), (d, d_ff), dtype),
        "wd": normal_init(kg(), (d_ff, d), dtype),
    }


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) or (..., H, hd) with pos broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return table[tokens]


def unembed(x: jax.Array, table_or_head: jax.Array) -> jax.Array:
    """Logits in f32 (numerics) regardless of param dtype."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), table_or_head.astype(jnp.float32)
    )


def init_embed(kg, vocab_padded, d, dtype):
    return normal_init(kg(), (vocab_padded, d), dtype, scale=0.02)
