"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on the
target chip (TPU v5e constants in roofline/hw.py):

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes_per_device / link_bw

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: ``collective_bytes`` parses the
post-SPMD-partitioning HLO (``compiled.as_text()``) and sums the operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (operand size = bytes each participant
contributes per instruction execution).

Also reported: MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the
ratio MODEL_FLOPS / HLO_FLOPs — how much of the compiled compute is
"useful" (catches remat/redundancy waste), and the dominant term.
"""

from __future__ import annotations

import dataclasses
import math
import re

from repro.roofline.hw import DEFAULT_CHIP, ChipSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# shape literal like  bf16[8,128]{1,0}  or f32[] ; capture dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# "%name = <result-shapes> <kind>(" — operands print WITHOUT inline shapes in
# optimized HLO, so bytes are derived from the RESULT shape + replica groups.
_INSTR_RE = re.compile(
    r"=\s*(?P<res>(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<async>-start|-done)?\("
)
# replica_groups=[G,P]<=...  (G groups of P participants) or explicit {{...}}
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _participants(line: str) -> int | None:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


def collective_bytes(hlo_text: str, default_participants: int = 1) -> dict:
    """Per-device operand bytes per collective kind, from post-SPMD HLO text.

    Conventions (operand = what each participant contributes once):
      all-gather         operand = result / participants
      all-reduce         operand = result
      reduce-scatter     operand = result * participants
      all-to-all         operand = result
      collective-permute operand = result

    Async pairs: ``-start`` ops are counted (their result carries the
    payload shape), ``-done`` ops skipped.
    """
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    count: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m.group("async") == "-done":
            continue
        kind = m.group("kind")
        res = m.group("res")
        shapes = _SHAPE_RE.findall(res)
        if m.group("async") == "-start" and len(shapes) > 1:
            # start-op result is a (operand, result) tuple: keep the result
            shapes = shapes[len(shapes) // 2 :]
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        p = _participants(line) or default_participants
        if kind == "all-gather":
            b = b / max(p, 1)
        elif kind == "reduce-scatter":
            b = b * max(p, 1)
        out[kind] += b
        count[kind] += 1
    out_all = {f"{k}_bytes": v for k, v in out.items()}
    out_all.update({f"{k}_count": count[k] for k in COLLECTIVE_OPS})
    out_all["total_bytes"] = sum(out.values())
    out_all["total_count"] = sum(count.values())
    return out_all


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline-optimal step time (perfect overlap of the three engines)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline-optimal step time."""
        if self.step_s <= 0:
            return 0.0
        chip = DEFAULT_CHIP
        return self.model_flops / (self.step_s * self.chips * chip.peak_flops_bf16)


def roofline(
    *,
    hlo_flops_per_device: float,
    hlo_bytes_per_device: float,
    collective_bytes_per_device: float,
    chips: int,
    model_flops: float = 0.0,
    chip: ChipSpec = DEFAULT_CHIP,
    dtype_peak: str = "bf16",
) -> RooflineTerms:
    peak = chip.peak_flops_bf16 if dtype_peak == "bf16" else chip.peak_flops_f32
    return RooflineTerms(
        compute_s=hlo_flops_per_device / peak,
        memory_s=hlo_bytes_per_device / chip.hbm_bw,
        collective_s=collective_bytes_per_device / chip.ici_bw,
        hlo_flops=hlo_flops_per_device,
        hlo_bytes=hlo_bytes_per_device,
        coll_bytes=collective_bytes_per_device,
        model_flops=model_flops,
        chips=chips,
    )


# ---------------------------------------------------------------------------
# CG hot-path HBM traffic model (the kernel-fusion term)
# ---------------------------------------------------------------------------

# Full-vector HBM *streams* (one read or write of n elements) per CG
# iteration OUTSIDE the SpMV, and the number of kernel passes ("sweeps")
# they are grouped into. "unfused" is the op-by-op formulation (every
# axpy/dot its own pass); "fused" is the dispatch-layer kernel path
# (fused_dots_n with operand dedup + fused_axpy2[_dots]), identity
# preconditioner. Derivation in core/cg.py body docstrings. pipecg pays
# +1 fused sweep (the z recurrence) to buy the hidden all-reduce — see
# CG_COMM below for the latency side of that trade.
CG_HOTPATH = {
    # variant: {mode: (streams, sweeps)}
    "hs": {"unfused": (15, 6), "fused": (11, 3)},
    "fcg": {"unfused": (18, 5), "fused": (14, 3)},
    "pipecg": {"unfused": (22, 8), "fused": (20, 4)},
    # multi-RHS block-HS (core/cg.py:_block_hs_body): streams are in n*r
    # element units (pass nrhs to the traffic helpers below). Fused path:
    # gram(P,W) reads 2 blocks + the fused X/R update reads 4 writes 2 +
    # gram(R,R) reads 1 (R still hot is not assumed) + P update reads 2
    # writes 1 = 12 streams in 4 kernel passes. Unfused op-by-op: 15
    # streams / 7 passes (each gram, axpy-like update, and the mask its
    # own pass).
    "block_hs": {"unfused": (15, 7), "fused": (12, 4)},
    # s-step CG (core/cg.py:_sstep_body), PER-ITERATION amortized values at
    # the s=2 accounting default — exact s-parameterized values come from
    # cg_sstep_hotpath(s). Fused path per block: sstep_gram reads the three
    # (n, s) basis blocks + r (3s+1 streams), sstep_basis reads 4 / writes
    # 2 blocks (6s), sstep_update reads 2 blocks + x, r and writes both
    # (2s+4) -> (11s+5)/s streams in 3/s passes per iteration. Unfused
    # op-by-op Gram algebra: (13s+6)/s streams in 8/s passes.
    "sstep": {"unfused": (16.0, 4.0), "fused": (13.5, 1.5)},
}


def cg_sstep_hotpath(s: int = 2, *, fused: bool = True) -> tuple[float, float]:
    """Exact per-iteration (streams, sweeps) of the s-step body for block
    size ``s`` — the s-parameterized version of ``CG_HOTPATH['sstep']``
    (which carries the s=2 accounting default)."""
    s = max(int(s), 1)
    if fused:
        return ((11 * s + 5) / s, 3 / s)
    return ((13 * s + 6) / s, 8 / s)

# All-reduce phases per iteration and how many of them the variant issues
# concurrently with compute (the hidden-latency term): hs blocks on both of
# its reductions, fcg on its single fused one; pipecg issues its single
# reduction before the SpMV + preconditioner it does not depend on, so its
# latency is absorbed up to the concurrent compute time.
CG_COMM = {
    "hs": {"allreduces": 2, "hidden": 0},
    "fcg": {"allreduces": 1, "hidden": 0},
    "pipecg": {"allreduces": 1, "hidden": 1},
    # block-HS keeps the scalar-HS latency structure (2 blocking
    # all-reduces/iter) but each carries r^2 scalars — see
    # cg_reduce_scalars(nrhs=...)
    "block_hs": {"allreduces": 2, "hidden": 0},
    # s-step CG: ONE blocking all-reduce PER s-ITERATION BLOCK — the
    # communication-avoiding trade. cg_exposed_latency_s divides the
    # latency by s for this variant (pass ``s``); same for the widened
    # halo exchange (1 per block) priced in energy/accounting.py.
    "sstep": {"allreduces": 1, "hidden": 0},
}


def reduce_hops(n_shards: int, grid: tuple[int, int] | None = None) -> int:
    """Per-collective tree depth the cost model charges.

    1-D (``grid`` is ``None`` or ``(1, N)``): one tree over all ``S``
    shards — ``ceil(log2(S))``. On a ``(R, C)`` grid with ``R > 1`` the
    hierarchical all-reduce stages over the sub-axes, so no single launch
    is deeper than its longer sub-axis: ``ceil(log2(max(R, C)))``.
    """
    if grid is not None and grid[0] > 1:
        n_shards = max(grid)
    return max(math.ceil(math.log2(max(n_shards, 2))), 1)


def reduce_launches(grid: tuple[int, int] | None = None) -> int:
    """Collective launches per logical all-reduce: 1 on a flat axis, 2 for
    the staged intra-row-group + inter-group reduction on a true 2-D grid."""
    return 2 if (grid is not None and grid[0] > 1) else 1


def cg_exposed_latency_s(
    variant: str, n_shards: int, *, alpha: float = 5e-6,
    hide_budget_s: float = float("inf"),
    grid: tuple[int, int] | None = None,
    s: int = 2,
) -> float:
    """Exposed all-reduce latency per CG iteration (seconds).

    Each all-reduce costs ``alpha * hops * launches`` with ``hops`` from
    :func:`reduce_hops` and ``launches`` from :func:`reduce_launches`
    (flat axis: one ``ceil(log2(S))``-deep tree; 2-D grid: two shallower
    staged trees); a variant's ``hidden`` reductions are absorbed into the
    concurrent SpMV/preconditioner up to ``hide_budget_s`` (pass that
    phase's compute time; the default — an unbounded budget — models the
    asymptotic large-problem regime where the matvec always covers the
    latency).

    ``sstep``'s single blocking all-reduce serves a whole s-iteration block
    (``CG_COMM``), so its per-iteration latency is divided by ``s`` — the
    communication-avoiding amortization the variant exists for.
    """
    if n_shards <= 1:
        return 0.0
    c = CG_COMM[variant]
    lat = alpha * reduce_hops(n_shards, grid) * reduce_launches(grid)
    exposed = c["allreduces"] * lat - min(c["hidden"] * lat, hide_budget_s)
    if variant == "sstep":
        exposed = exposed / max(int(s), 1)
    return max(exposed, 0.0)


def pencil_halo_widths(p, grid: tuple[int, int]) -> dict:
    """Closed-form per-shift halo widths for a pencil-partitioned Poisson
    cube — the surface-not-volume law the 2-D layout is built on.

    ``p`` is a ``matrices.poisson.PoissonProblem``; ``grid = (R, C)`` splits
    z into ``R`` blocks and y into ``C`` slabs (``core.partition.
    pencil_partition``), every shard keeping full x lines. Returns
    ``{(di, dj): width}`` where width is the receive-buffer length the
    worst-placed shard needs from its ``(i+di, j+dj)`` neighbor:

      z-face (±1, 0):  nx * ceil(ny / C)   one z-plane, own y-slab wide
      y-face (0, ±1):  nx * ceil(nz / R)   one y-plane, own z-block deep
      corner (±1, ±1): nx                  one x line (27pt stencil only)

    This must match ``GridPlan.widths`` built from the actual sparsity —
    asserted in the scale-out tests.
    """
    gr, gc = grid
    max_zb = -(-p.nz // gr)
    max_yb = -(-p.ny // gc)
    widths: dict[tuple[int, int], int] = {}
    if gr > 1:
        widths[(1, 0)] = widths[(-1, 0)] = p.nx * max_yb
    if gc > 1:
        widths[(0, 1)] = widths[(0, -1)] = p.nx * max_zb
    if p.stencil == "27pt" and gr > 1 and gc > 1:
        for di in (-1, 1):
            for dj in (-1, 1):
                widths[(di, dj)] = p.nx
    return widths


def cg_vector_traffic(n: int, *, variant: str = "hs", fused: bool = True,
                      dtype_bytes: int = 8, nrhs: int = 1,
                      s: int | None = None) -> float:
    """Vector-op HBM bytes per CG iteration outside the SpMV. For the
    multi-RHS ``block_hs`` body the streams are in n*r units — pass
    ``nrhs``. For ``sstep`` pass ``s`` for the exact block size (the table
    row carries the s=2 accounting default)."""
    if variant == "sstep" and s is not None:
        streams, _ = cg_sstep_hotpath(s, fused=fused)
    else:
        streams, _ = CG_HOTPATH[variant]["fused" if fused else "unfused"]
    return float(streams) * n * dtype_bytes * max(int(nrhs), 1)


def cg_vector_sweeps(variant: str = "hs", *, fused: bool = True,
                     s: int | None = None) -> float:
    """Full-vector kernel passes per CG iteration outside the SpMV."""
    if variant == "sstep" and s is not None:
        return cg_sstep_hotpath(s, fused=fused)[1]
    return CG_HOTPATH[variant]["fused" if fused else "unfused"][1]


def cg_vector_flops(n: int, *, variant: str = "hs", fused: bool = True,
                    nrhs: int = 1, s: int | None = None) -> float:
    """Vector-op FLOPs per CG iteration outside the SpMV: ~1 flop per
    streamed element (axpy: 2 flops / 3 streams, dot: 2 flops / 2 streams —
    the hot path sits between, and these ops are all memory-bound anyway).
    The block body's Gram/update matmuls do ~2r flops per streamed element,
    but at the r ≤ 16 the solver targets they remain memory-bound, so the
    same per-stream pricing is kept (scaled by ``nrhs`` streamed elements).
    Used by the autotune pruning model (autotune/prune.py) to price a
    variant's compute engine next to :func:`cg_vector_traffic`'s memory
    term."""
    if variant == "sstep" and s is not None:
        streams, _ = cg_sstep_hotpath(s, fused=fused)
    else:
        streams, _ = CG_HOTPATH[variant]["fused" if fused else "unfused"]
    return float(streams) * n * max(int(nrhs), 1)


def cg_reduce_scalars(variant: str = "hs", nrhs: int = 1, s: int = 2) -> float:
    """Scalars carried by the variant's fused all-reduce(s) per iteration
    (hs: alpha pair + beta; fcg: one 3-term fusion; pipecg: the single
    Ghysels–Vanroose fusion; block_hs: two r x r Grams; sstep: the whole
    (2s² + s + 1)-scalar Gram payload amortized over its s iterations)."""
    if variant == "block_hs":
        r = max(int(nrhs), 1)
        return 2 * r * r
    if variant == "sstep":
        s = max(int(s), 1)
        return (2 * s * s + s + 1) / s
    return {"hs": 3, "fcg": 3, "pipecg": 3}[variant]


def spmv_traffic(n: int, k: int, *, matfree: bool = False,
                 dtype_bytes: int = 8, idx_bytes: int = 4,
                 nrhs: int = 1) -> float:
    """SpMV HBM bytes per application: ELL (values + local indices + vector
    r/w) or matrix-free stencil (read x + write y only). With ``nrhs`` > 1
    (the SpMM interior) the matrix term is paid ONCE while the vector r/w
    term scales with r — the amortization the block solver is built on."""
    r = max(int(nrhs), 1)
    if matfree:
        return float(n) * 2 * dtype_bytes * r
    return float(n) * (k * (dtype_bytes + idx_bytes) + 2 * dtype_bytes * r)


def cg_iteration_memory_s(
    n: int, k: int, *, variant: str = "hs", fused: bool = True,
    matfree: bool = False, dtype_bytes: int = 8,
    chip: ChipSpec = DEFAULT_CHIP,
) -> float:
    """Roofline memory term (seconds) for ONE CG iteration on one chip:
    one SpMV + the variant's vector-op traffic."""
    total = spmv_traffic(n, k, matfree=matfree, dtype_bytes=dtype_bytes)
    total += cg_vector_traffic(n, variant=variant, fused=fused,
                               dtype_bytes=dtype_bytes)
    return total / chip.hbm_bw


def model_flops_train(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for one training step."""
    n = cfg.active_param_count()
    d_tokens = shape.global_batch * shape.seq_len
    return 6.0 * n * d_tokens


def model_flops_decode(cfg, shape) -> float:
    """2*N_active per generated token (forward only) x batch."""
    n = cfg.active_param_count()
    return 2.0 * n * shape.global_batch


def model_flops_prefill(cfg, shape) -> float:
    n = cfg.active_param_count()
    return 2.0 * n * shape.global_batch * shape.seq_len
