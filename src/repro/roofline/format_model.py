"""Stored-bytes / traffic cost model for the DistMat interior formats.

The paper's central lever is minimizing data movement: on memory-bound
sparse kernels, the bytes a format keeps resident (and therefore streams on
every SpMV) are the time *and* energy proxy. This module scores the three
interior layouts of ``core/partition.py`` — ELL, HYB, BCSR — on the host
row-length / block statistics available at partition time, in the same
counting conventions as the rest of the roofline layer (8 B values, 4 B
int32 local indices; cf. ``roofline/analysis.cg_vector_traffic`` and
``energy/accounting.spmv_counts``):

* ELL   — ``R * max_row_nnz`` slots, one 4 B column id per slot. One long
  row pads every row.
* HYB   — an ELL prefix of ``k_typ`` slots/row plus a COO tail (value +
  (col, row) id pair = 16 B/entry) for the overflow of rows longer than
  ``k_typ``. :func:`hyb_split` picks the ``k_typ`` minimizing the total.
* BCSR  — dense (br, bc) tiles in the uniform blocks-per-row kernel layout:
  ``n_brows * bpr`` blocks of ``br*bc`` values + ONE 4 B id per block
  (the index-traffic win), zero fill inside partial tiles (the price).

``choose_format`` resolves ``fmt="auto"``: it picks the candidate with the
smallest modeled SpMV traffic (stored bytes + the format-independent vector
read/write term), so by construction auto never selects a layout storing
more bytes than ELL.
"""

from __future__ import annotations

import dataclasses

import numpy as np

VALUE_BYTES = 8
INDEX_BYTES = 4


@dataclasses.dataclass(frozen=True)
class FormatCost:
    """Modeled cost of storing one distributed interior in one format."""

    fmt: str
    stored_bytes: int  # values + indices resident in HBM, all shards
    traffic_bytes: int  # bytes one distributed SpMV streams (all shards)
    params: dict  # format-specific packing parameters


def spmv_traffic_bytes(
    stored_bytes: int, n_rows: int, n_shards: int, value_bytes: int = VALUE_BYTES
) -> int:
    """Bytes one SpMV streams: the stored matrix once + the source vector
    read and the result written per shard (``cg_vector_traffic``-style
    stream counting; the halo term is format-independent and omitted)."""
    return int(stored_bytes + 2 * n_rows * n_shards * value_bytes)


def ell_cost(
    shard_row_lens, n_rows: int, *, value_bytes: int = VALUE_BYTES
) -> FormatCost:
    """``shard_row_lens``: per shard, the interior nnz of each local row;
    ``n_rows`` the padded rows per shard (R = n_own_pad)."""
    k = max((int(max(lens, default=0)) for lens in shard_row_lens), default=0)
    k = max(k, 1)
    S = len(shard_row_lens)
    stored = S * n_rows * k * (value_bytes + INDEX_BYTES)
    return FormatCost(
        "ell", stored, spmv_traffic_bytes(stored, n_rows, S, value_bytes),
        {"k": k},
    )


def hyb_split(
    row_lens, *, n_rows: int, value_bytes: int = VALUE_BYTES
) -> tuple[int, int]:
    """Optimal ELL-prefix width for a pooled row-length distribution.

    Returns ``(k_typ, stored_bytes)`` minimizing
    ``n_rows * k * (vb + 4) + tail(k) * (vb + 8)`` over ``k`` in
    ``[0, max_row_nnz]``, where ``tail(k) = sum(max(len - k, 0))`` — the
    exact byte count of the HYBBlock layout (per-shard tail padding not
    included; it is second-order and bounded by S-1 entries per slot row).
    """
    lens = np.asarray(row_lens, np.int64)
    kmax = int(lens.max()) if lens.size else 0
    if kmax == 0:
        return 1, n_rows * (value_bytes + INDEX_BYTES)
    ks = np.arange(kmax + 1, dtype=np.int64)
    # tail(k) via the sorted suffix: tail(k) = sum_{l > k} (l - k)
    sorted_lens = np.sort(lens)
    suffix_sum = np.cumsum(sorted_lens[::-1])[::-1]
    idx = np.searchsorted(sorted_lens, ks, side="right")
    n_longer = lens.size - idx
    tail = np.where(
        n_longer > 0, suffix_sum[np.minimum(idx, lens.size - 1)] - ks * n_longer, 0
    )
    cost = n_rows * ks * (value_bytes + INDEX_BYTES) + tail * (
        value_bytes + 2 * INDEX_BYTES
    )
    # clamp to the packed layout's minimum prefix of 1 slot/row, and price
    # the tail at the *clamped* k so the return is the exact layout bytes
    k_typ = max(int(ks[np.argmin(cost)]), 1)  # kmax >= 1 here, so k_typ <= kmax
    return k_typ, int(cost[k_typ])


def hyb_cost(
    shard_row_lens, n_rows: int, *, value_bytes: int = VALUE_BYTES
) -> FormatCost:
    pooled = np.concatenate(
        [np.asarray(lens, np.int64) for lens in shard_row_lens]
    ) if shard_row_lens else np.zeros(0, np.int64)
    S = len(shard_row_lens)
    # same pooled-distribution call the packer makes, so the k_typ priced
    # here is the k_typ actually packed
    k_typ, _ = hyb_split(
        pooled, n_rows=n_rows * S, value_bytes=value_bytes
    )
    # rebuild the stored size exactly: S shards of ELL prefix + the tail
    # padded to the max per-shard tail length (the stacked (S, T) layout)
    tails = [
        int(np.maximum(np.asarray(lens, np.int64) - k_typ, 0).sum())
        for lens in shard_row_lens
    ]
    T = max(max(tails, default=0), 1)
    stored = S * (
        n_rows * k_typ * (value_bytes + INDEX_BYTES)
        + T * (value_bytes + 2 * INDEX_BYTES)
    )
    return FormatCost(
        "hyb", stored, spmv_traffic_bytes(stored, n_rows, S, value_bytes),
        {"k_typ": k_typ, "tail": tails},
    )


def bcsr_cost(
    shard_blocks, n_rows: int, *, br: int = 4, bc: int = 4,
    value_bytes: int = VALUE_BYTES,
) -> FormatCost:
    """``shard_blocks``: per shard, ``(n_blocks, max_blocks_per_block_row)``
    of the interior (``partition._shard_block_stats``)."""
    S = len(shard_blocks)
    n_brows = -(-n_rows // br)
    bpr = max((b for _, b in shard_blocks), default=0)
    bpr = max(bpr, 1)
    stored = S * n_brows * bpr * (br * bc * value_bytes + INDEX_BYTES)
    return FormatCost(
        "bcsr", stored, spmv_traffic_bytes(stored, n_rows, S, value_bytes),
        {"n_brows": n_brows, "bpr": bpr, "br": br, "bc": bc},
    )


def format_costs(
    shard_row_lens, *, n_rows: int, shard_blocks=None, br: int = 4,
    bc: int = 4, value_bytes: int = VALUE_BYTES,
) -> dict[str, FormatCost]:
    """All candidate costs for one partitioned interior (keyed by format)."""
    out = {
        "ell": ell_cost(shard_row_lens, n_rows, value_bytes=value_bytes),
        "hyb": hyb_cost(shard_row_lens, n_rows, value_bytes=value_bytes),
    }
    if shard_blocks is not None:
        out["bcsr"] = bcsr_cost(
            shard_blocks, n_rows, br=br, bc=bc, value_bytes=value_bytes
        )
    return out


def choose_format(
    shard_row_lens, *, n_rows: int, shard_blocks=None, br: int = 4,
    bc: int = 4, value_bytes: int = VALUE_BYTES,
) -> tuple[str, FormatCost]:
    """Resolve ``fmt="auto"``: the candidate with the least modeled SpMV
    traffic. Ties break toward ELL (the simplest kernel), then HYB.

    ELL is always a candidate, so the winner never stores more bytes than
    ELL — the invariant the property tests pin down.
    """
    costs = format_costs(
        shard_row_lens, n_rows=n_rows, shard_blocks=shard_blocks, br=br,
        bc=bc, value_bytes=value_bytes,
    )
    order = {"ell": 0, "hyb": 1, "bcsr": 2}
    fmt = min(costs, key=lambda f: (costs[f].traffic_bytes, order[f]))
    return fmt, costs[fmt]
