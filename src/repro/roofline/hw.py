"""Hardware constants for the roofline/energy models.

Target platform: Google TPU v5e (the dry-run target). The container itself is
CPU-only; these constants parameterize the analytical models only and are never
used to configure XLA.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip capability + power envelope."""

    name: str
    # Compute.
    peak_flops_bf16: float  # FLOP/s
    peak_flops_f32: float  # FLOP/s
    # Memory.
    hbm_bytes: float
    hbm_bw: float  # bytes/s
    vmem_bytes: float
    # Interconnect (per-link, per-direction).
    ici_bw: float  # bytes/s per link
    ici_links: int  # links per chip
    # Power model (see energy/model.py for calibration notes).
    p_idle_w: float
    p_peak_w: float


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    vmem_bytes=128 * 2**20,
    ici_bw=50e9,
    ici_links=4,
    p_idle_w=60.0,
    p_peak_w=215.0,
)


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """Host (CPU socket) power envelope — LIKWID/RAPL-style socket scope."""

    name: str
    p_idle_w: float
    p_active_w: float  # additional power when the host is driving collectives/IO


HOST_XEON = HostSpec(name="xeon_gold_2s", p_idle_w=90.0, p_active_w=35.0)

# Default platform used across roofline + energy accounting.
DEFAULT_CHIP = TPU_V5E
DEFAULT_HOST = HOST_XEON
