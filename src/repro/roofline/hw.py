"""Hardware constants for the roofline/energy models.

Target platform: Google TPU v5e (the dry-run target). The container itself is
CPU-only; these constants parameterize the analytical models only and are never
used to configure XLA.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip capability + power envelope."""

    name: str
    # Compute.
    peak_flops_bf16: float  # FLOP/s
    peak_flops_f32: float  # FLOP/s
    # Memory.
    hbm_bytes: float
    hbm_bw: float  # bytes/s
    vmem_bytes: float
    # Interconnect (per-link, per-direction).
    ici_bw: float  # bytes/s per link
    ici_links: int  # links per chip
    # Power model (see energy/model.py for calibration notes).
    p_idle_w: float
    p_peak_w: float
    # DVFS axis (autotune/): relative core-frequency grid the autotuner may
    # select from; 1.0 is the calibration point of the constants above.
    freq_points: tuple[float, ...] = (0.6, 0.8, 1.0)
    # Voltage floor as a fraction of nominal V as f -> 0; V scales linearly
    # with f above the floor (the classic P_dyn ~ f * V^2 DVFS model).
    v_floor: float = 0.5

    def v_frac(self, freq: float) -> float:
        """Relative supply voltage at relative core frequency ``freq``."""
        return self.v_floor + (1.0 - self.v_floor) * freq

    def at_freq(self, freq: float) -> "ChipSpec":
        """This chip downclocked to relative core frequency ``freq``.

        The compute engines and their dynamic power envelope scale with the
        core clock (``P_dyn ~ f * V(f)^2``, ``V`` linear in ``f`` down to
        ``v_floor``); the HBM and ICI run their own clock domains and are
        held flat. That asymmetry is what makes slow-and-efficient beat
        race-to-idle on memory-bound sparse kernels (time barely moves,
        dynamic energy drops) and lose on compute-bound ones (time — and
        with it static energy — grows 1/f). Static (idle) power is leakage
        and does not scale with the core clock.
        """
        if not 0.0 < freq <= 1.0:
            raise ValueError(f"relative frequency must be in (0, 1]: {freq}")
        if freq == 1.0:
            return self
        v = self.v_frac(freq)
        dyn = (self.p_peak_w - self.p_idle_w) * freq * v * v
        return dataclasses.replace(
            self,
            name=f"{self.name}@f{freq:g}",
            peak_flops_bf16=self.peak_flops_bf16 * freq,
            peak_flops_f32=self.peak_flops_f32 * freq,
            p_peak_w=self.p_idle_w + dyn,
        )


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    vmem_bytes=128 * 2**20,
    ici_bw=50e9,
    ici_links=4,
    p_idle_w=60.0,
    p_peak_w=215.0,
)


@dataclasses.dataclass(frozen=True)
class HostSpec:
    """Host (CPU socket) power envelope — LIKWID/RAPL-style socket scope."""

    name: str
    p_idle_w: float
    p_active_w: float  # additional power when the host is driving collectives/IO


HOST_XEON = HostSpec(name="xeon_gold_2s", p_idle_w=90.0, p_active_w=35.0)

# Default platform used across roofline + energy accounting.
DEFAULT_CHIP = TPU_V5E
DEFAULT_HOST = HOST_XEON
