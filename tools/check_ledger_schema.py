"""Ledger ↔ docs schema gate (CI `energy-ledger` job).

    python tools/check_ledger_schema.py            # validate all ledgers
    python tools/check_ledger_schema.py --list     # dump the inventory

Validates every JSON under ``runs/ledgers/`` and ``benchmarks/baselines/``
against the field inventory of ``docs/ledger_schema.md``, in both
directions:

* **undocumented** — a dict key appearing in any ledger that the doc never
  names fails the check (new fields must be documented before they ship);
* **missing-documented** — a field the doc's ``| field | ... |`` tables
  promise that appears in *no* scanned ledger also fails (the doc may not
  describe fields that no longer exist).

What counts as "documented": every `backticked` identifier in the page
(tables and prose; cells like ``a`` / ``b`` contribute each token) and
every ``"key":`` inside its fenced JSON examples. What counts as
"promised": rows of tables whose header's first cell is ``field`` —
tables with other headers (the region-name table, the autotune *member*
table) document vocabulary that smoke ledgers may legitimately lack.

Together with ``benchmarks/check_ledgers.py`` (value drift) this makes
ledger and docs unable to drift apart silently: the former gates numbers,
this gates structure.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA_DOC = os.path.join(REPO, "docs", "ledger_schema.md")
SCAN_DIRS = (
    os.path.join(REPO, "runs", "ledgers"),
    os.path.join(REPO, "benchmarks", "baselines"),
)

_BACKTICK_RE = re.compile(r"`([^`]+)`")
_FENCE_KEY_RE = re.compile(r'"([^"\\]+)"\s*:')
_IDENT_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_.\-]*$")
_TABLE_ROW_RE = re.compile(r"^\|([^|]*)\|")


def doc_inventory(path: str = SCHEMA_DOC) -> tuple[set[str], set[str]]:
    """Parse the doc -> (documented keys, required ``| field |`` keys)."""
    documented: set[str] = set()
    required: set[str] = set()
    in_fence = False
    required_table = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if line.startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                for m in _FENCE_KEY_RE.finditer(line):
                    documented.add(m.group(1))
                continue
            tokens = [
                t for t in _BACKTICK_RE.findall(line) if _IDENT_RE.match(t)
            ]
            documented.update(tokens)
            row = _TABLE_ROW_RE.match(line.strip())
            if not row:
                required_table = False
                continue
            first_cell = row.group(1).strip()
            if first_cell == "field":
                required_table = True  # header row of a required table
                continue
            if set(first_cell) <= {"-", " ", ":"}:
                continue  # separator row keeps the current table state
            if required_table:
                required.update(
                    t
                    for t in _BACKTICK_RE.findall(row.group(1))
                    if _IDENT_RE.match(t)
                )
    return documented, required


def ledger_files() -> list[str]:
    out = []
    for d in SCAN_DIRS:
        if not os.path.isdir(d):
            continue
        out += sorted(
            os.path.join(d, fn) for fn in os.listdir(d)
            if fn.endswith(".json")
        )
    return out


def collect_keys(obj, keys: set[str]):
    if isinstance(obj, dict):
        for k, v in obj.items():
            keys.add(k)
            collect_keys(v, keys)
    elif isinstance(obj, list):
        for v in obj:
            collect_keys(v, keys)


def check(files: list[str] | None = None) -> list[str]:
    documented, required = doc_inventory()
    errors: list[str] = []
    seen: set[str] = set()
    files = files if files is not None else ledger_files()
    if not files:
        return ["no ledgers found to validate (run benchmarks.run --smoke)"]
    for path in files:
        rel = os.path.relpath(path, REPO)
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{rel}: unreadable JSON ({e})")
            continue
        keys: set[str] = set()
        collect_keys(data, keys)
        seen |= keys
        for k in sorted(keys - documented):
            errors.append(
                f"{rel}: field {k!r} is not documented in "
                "docs/ledger_schema.md"
            )
    for k in sorted(required - seen):
        errors.append(
            f"docs/ledger_schema.md: documents field {k!r} but no ledger "
            "under runs/ledgers/ or benchmarks/baselines/ carries it"
        )
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="print the parsed doc inventory and exit")
    args = ap.parse_args(argv)

    documented, required = doc_inventory()
    if args.list:
        print(f"documented ({len(documented)}): {sorted(documented)}")
        print(f"required ({len(required)}): {sorted(required)}")
        return 0
    files = ledger_files()
    errors = check(files)
    print(f"validated {len(files)} ledger(s) against "
          f"{len(documented)} documented / {len(required)} required fields")
    if errors:
        print(f"\n{len(errors)} schema problem(s):")
        for e in errors[:80]:
            print(f"  {e}")
        if len(errors) > 80:
            print(f"  ... and {len(errors) - 80} more")
        return 1
    print("ledger schema OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
