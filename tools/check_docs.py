"""Docs link/anchor checker + README quickstart executor (CI `docs` job).

    python tools/check_docs.py               # link + anchor check
    python tools/check_docs.py --quickstart  # execute the README quickstart

Link check: every relative markdown link in README.md and docs/*.md must
point at an existing file, and every ``#anchor`` (same-file or cross-file)
must match a heading slug of its target (GitHub slugging: lowercase, drop
punctuation, spaces become hyphens). External http(s)/mailto links are not
fetched.

Quickstart: extracts the fenced ``bash`` block(s) under the README's
``## Quickstart`` heading and runs each command line verbatim (backslash
continuations joined, comment lines skipped) from the repo root. The
quickstart is written in smoke form — toy problem sizes and
``benchmarks.run --smoke`` — precisely so this job can execute it on every
push; a quickstart that stops working fails CI instead of rotting.
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[([^\]]*)\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
FENCE_RE = re.compile(r"^```")


def doc_files() -> list[str]:
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(
            os.path.join(docs, f) for f in os.listdir(docs) if f.endswith(".md")
        )
    return [f for f in files if os.path.exists(f)]


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading line (close approximation)."""
    s = heading.strip().replace("`", "")
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.lower().replace(" ", "-")


def heading_slugs(path: str) -> set[str]:
    """All anchor slugs a file exposes (with GitHub's -1 dedup suffixes)."""
    slugs: dict[str, int] = {}
    out: set[str] = set()
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for line in f:
            if FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            m = HEADING_RE.match(line)
            if not m:
                continue
            slug = github_slug(m.group(2))
            n = slugs.get(slug, 0)
            slugs[slug] = n + 1
            out.add(slug if n == 0 else f"{slug}-{n}")
    return out


def iter_links(path: str):
    """Yield (lineno, text, target) for markdown links outside code fences."""
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            if FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                yield i, m.group(1), m.group(2)


def check_links() -> list[str]:
    errors = []
    for path in doc_files():
        rel = os.path.relpath(path, REPO)
        for lineno, _text, target in iter_links(path):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, anchor = target.partition("#")
            if file_part:
                dest = os.path.normpath(
                    os.path.join(os.path.dirname(path), file_part)
                )
                if not os.path.exists(dest):
                    errors.append(
                        f"{rel}:{lineno}: broken link target {target!r}"
                    )
                    continue
            else:
                dest = path
            if anchor and dest.endswith(".md"):
                if anchor not in heading_slugs(dest):
                    errors.append(
                        f"{rel}:{lineno}: anchor #{anchor} not found in "
                        f"{os.path.relpath(dest, REPO)}"
                    )
    return errors


def quickstart_commands() -> list[str]:
    """Command lines of the bash fences under README's '## Quickstart'."""
    readme = os.path.join(REPO, "README.md")
    cmds: list[str] = []
    in_section = in_fence = in_bash = False
    pending = ""
    with open(readme, encoding="utf-8") as f:
        for line in f:
            if line.startswith("```"):
                # track ALL fences (a '# ...' line inside a python/plain
                # fence must not be mistaken for a heading), but only
                # collect commands from bash ones
                in_bash = not in_fence and line.strip() == "```bash"
                in_fence = not in_fence
                continue
            m = None if in_fence else HEADING_RE.match(line)
            if m:
                in_section = m.group(2).strip().lower() == "quickstart"
                continue
            if not in_section or not in_bash:
                continue
            chunk = line.rstrip("\n")
            if pending:
                chunk = pending + " " + chunk.strip()
                pending = ""
            if chunk.rstrip().endswith("\\"):
                pending = chunk.rstrip()[:-1].rstrip()
                continue
            cmd = chunk.strip()
            if cmd and not cmd.startswith("#"):
                cmds.append(cmd)
    return cmds


def run_quickstart(timeout: int = 2400) -> list[str]:
    errors = []
    cmds = quickstart_commands()
    if not cmds:
        return ["README.md: no bash commands found under '## Quickstart'"]
    for cmd in cmds:
        print(f"$ {cmd}", flush=True)
        r = subprocess.run(
            cmd, shell=True, cwd=REPO, timeout=timeout,
            capture_output=True, text=True,
        )
        tail = (r.stdout + r.stderr)[-2000:]
        if r.returncode != 0:
            errors.append(f"quickstart command failed ({cmd}):\n{tail}")
        else:
            print(tail.splitlines()[-1] if tail.splitlines() else "(ok)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quickstart", action="store_true",
                    help="execute the README quickstart commands")
    args = ap.parse_args(argv)

    if args.quickstart:
        errors = run_quickstart()
    else:
        errors = check_links()
        files = [os.path.relpath(p, REPO) for p in doc_files()]
        print(f"checked {len(files)} files: {', '.join(files)}")
    if errors:
        print(f"\n{len(errors)} docs problem(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
