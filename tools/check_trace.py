"""Chrome-trace validation gate (CI `profile-smoke` step).

    python tools/check_trace.py out.trace.json [more.trace.json ...]
    python tools/check_trace.py --summary out.trace.json

Validates traces exported by ``repro.obs.trace_export`` (the ``--profile``
flag on ``launch.solve`` / ``launch.serve_solver``) well enough that a
regression cannot ship an unloadable or self-contradictory profile:

* the file is the JSON Object Format: an object whose ``traceEvents`` is a
  list of event dicts, each with a known phase (``M``/``X``/``C``);
* every duration (``X``) event carries ``name``/``pid``/``tid``, a numeric
  ``ts``, and a non-negative ``dur``;
* every counter (``C``) event carries ``name``/``pid``, a numeric ``ts``,
  and an ``args`` dict of numeric samples;
* within each (pid, tid) lane, duration events do not overlap — spans are
  a partition of the timeline, so an overlap means the exporter (or an
  offset computation) broke;
* the trace contains at least one duration event, and every process
  carries the required counter tracks (``chip_power_w``,
  ``hbm_bytes_total``) — the power/traffic staircase IS the point of the
  export.

Exit 0 when every file passes, 1 with per-file messages otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys

KNOWN_PHASES = {"M", "X", "C", "B", "E", "i"}
REQUIRED_COUNTERS = ("chip_power_w", "hbm_bytes_total")


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_trace(obj) -> list[str]:
    """All structural violations in one parsed trace object (empty = ok)."""
    errs: list[str] = []
    if not isinstance(obj, dict) or not isinstance(
        obj.get("traceEvents"), list
    ):
        return ["top level must be an object with a 'traceEvents' list"]
    lanes: dict[tuple, list[tuple[float, float, str]]] = {}
    counters: dict[object, set] = {}
    n_x = 0
    for k, ev in enumerate(obj["traceEvents"]):
        where = f"traceEvents[{k}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: event must be an object")
            continue
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            errs.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph == "X":
            n_x += 1
            if not isinstance(ev.get("name"), str) or not ev.get("name"):
                errs.append(f"{where}: X event needs a non-empty 'name'")
                continue
            if "pid" not in ev or "tid" not in ev:
                errs.append(f"{where}: X event needs 'pid' and 'tid'")
                continue
            if not _num(ev.get("ts")) or not _num(ev.get("dur")):
                errs.append(f"{where}: X event needs numeric 'ts' and 'dur'")
                continue
            if ev["dur"] < 0:
                errs.append(f"{where}: negative dur {ev['dur']}")
                continue
            lanes.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["dur"]), ev["name"])
            )
        elif ph == "C":
            if not isinstance(ev.get("name"), str) or not ev.get("name"):
                errs.append(f"{where}: C event needs a non-empty 'name'")
                continue
            if "pid" not in ev or not _num(ev.get("ts")):
                errs.append(f"{where}: C event needs 'pid' and numeric 'ts'")
                continue
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                _num(v) for v in args.values()
            ):
                errs.append(
                    f"{where}: C event needs numeric samples in 'args'"
                )
                continue
            counters.setdefault(ev["pid"], set()).add(ev["name"])
    if n_x == 0:
        errs.append("trace has no duration (X) events")
    for (pid, tid), spans in lanes.items():
        spans.sort()
        for (t0, d0, n0), (t1, _, n1) in zip(spans, spans[1:]):
            end = t0 + d0
            # float-rounding slack: offsets are computed in seconds and
            # scaled to us, so boundaries may disagree in the last bits
            if t1 < end - 1e-9 * max(1.0, abs(end)):
                errs.append(
                    f"lane (pid={pid}, tid={tid}): {n0!r} "
                    f"[{t0}, {end}) overlaps {n1!r} starting at {t1}"
                )
                break
    for pid in {p for p, _ in lanes}:
        have = counters.get(pid, set())
        for name in REQUIRED_COUNTERS:
            if name not in have:
                errs.append(f"pid {pid}: missing counter track {name!r}")
    return errs


def summarize(obj) -> str:
    evs = obj.get("traceEvents", [])
    n_x = sum(1 for e in evs if isinstance(e, dict) and e.get("ph") == "X")
    n_c = sum(1 for e in evs if isinstance(e, dict) and e.get("ph") == "C")
    pids = {e.get("pid") for e in evs if isinstance(e, dict) and "pid" in e}
    t_end = max(
        (
            e["ts"] + e.get("dur", 0.0)
            for e in evs
            if isinstance(e, dict) and isinstance(e.get("ts"), (int, float))
        ),
        default=0.0,
    )
    return (
        f"{len(pids)} process(es), {n_x} duration events, "
        f"{n_c} counter samples, span {t_end / 1e6:.6f}s"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="+", help="trace JSON files to validate")
    ap.add_argument("--summary", action="store_true",
                    help="print a one-line summary per valid trace")
    args = ap.parse_args(argv)
    failed = False
    for path in args.paths:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, ValueError) as e:
            print(f"{path}: FAIL: unreadable ({e})")
            failed = True
            continue
        errs = validate_trace(obj)
        if errs:
            failed = True
            print(f"{path}: FAIL")
            for e in errs[:20]:
                print(f"  - {e}")
            if len(errs) > 20:
                print(f"  ... and {len(errs) - 20} more")
        else:
            tail = f" ({summarize(obj)})" if args.summary else ""
            print(f"{path}: ok{tail}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
