"""Fig. 4/5/6 + Tables 2/3 analog: SpMV dynamic energy breakdown (GPU/CPU),
power peaks, energy per DOF, static-vs-dynamic percentages.

PowerMonitor workflow exactly as the paper's Fig. 1: start monitor, run the
region-marked kernel 100x, integrate the power-time curve, split static /
dynamic. 5-run averaging is kept for methodological fidelity (the model is
deterministic; the loop demonstrates the pipeline).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import SHARD_COUNTS, abstract_poisson_mat, write_results
from repro.energy.accounting import CostModel, spmv_counts
from repro.energy.monitor import PowerMonitor

CASES = [("7pt", 405), ("27pt", 260)]
REPEATS = 100
N_RUNS = 5


def one_case(stencil, side, mode, s, lib) -> dict:
    layout = "ring" if lib == "BCMGX" else "allgather"
    overlap = lib == "BCMGX"
    p, mat = abstract_poisson_mat(side, stencil, s, weak=(mode == "weak"), layout=layout)
    c = spmv_counts(mat, overlap)
    runs = []
    for _ in range(N_RUNS):
        mon = PowerMonitor(n_devices=s, cost=CostModel())
        mon.idle(0.05)
        mon.region("spmv", c, n_shards=s, overlap=overlap, repeats=REPEATS)
        mon.idle(0.05)
        runs.append(mon.energy())
    e = {k: float(np.mean([r[k] for r in runs])) for k in runs[0]}
    return dict(
        figure="fig4-6_tab2-3",
        stencil=stencil,
        mode=mode,
        n_shards=s,
        library=lib,
        dofs=p.n,
        de_per_dof=e["de_total"] / p.n,
        **e,
    )


def run(shard_counts=SHARD_COUNTS) -> list[dict]:
    rows = []
    for stencil, side in CASES:
        for mode in ("weak", "strong"):
            for s in shard_counts:
                for lib in ("BCMGX", "Ginkgo"):
                    rows.append(one_case(stencil, side, mode, s, lib))
    write_results("spmv_energy", rows)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import STATIC_DYNAMIC_COLUMNS, fmt_table

    rows = run(shard_counts=(1, 2, 4) if smoke else SHARD_COUNTS)
    weak7 = [r for r in rows if r["stencil"] == "7pt" and r["mode"] == "weak"]
    cols = [
        ("n_shards", "#GPUs"), ("library", "library"),
        ("de_gpu", "GPU dyn E (J)"), ("de_cpu", "CPU dyn E (J)"),
        ("de_total", "total (J)"), ("gpu_power_peak", "peak (W)"),
        ("de_per_dof", "dyn E/DOF (J)"),
    ]
    print(fmt_table(weak7, cols, "Fig 4/5/6 analog: SpMV energy, 7pt weak"))
    print(fmt_table(weak7, STATIC_DYNAMIC_COLUMNS, "Table 2 analog: static vs dynamic %"))
    w27 = [r for r in rows if r["stencil"] == "27pt" and r["mode"] == "weak"]
    print(fmt_table(w27, STATIC_DYNAMIC_COLUMNS, "Table 3 analog: 27pt weak"))
    # headline ratio (paper: ~2x)
    top = max(r["n_shards"] for r in rows)
    for stencil in ("7pt", "27pt"):
        sel = [r for r in rows if r["stencil"] == stencil and r["mode"] == "weak"
               and r["n_shards"] == top]
        g = next(r for r in sel if r["library"] == "Ginkgo")
        b = next(r for r in sel if r["library"] == "BCMGX")
        print(f"{stencil} weak @{top}: Ginkgo/BCMGX dynamic-energy ratio = "
              f"{g['de_total']/b['de_total']:.2f}x  "
              f"peak {b['gpu_power_peak']:.0f}W vs {g['gpu_power_peak']:.0f}W")


if __name__ == "__main__":
    main()
