"""Hot-path fusion accounting: fused vs unfused CG vector work (§Perf).

Three views of the same claim — routing the CG hot loop through the fused
Pallas kernel family (kernels/dispatch.py) removes roughly half the
full-vector HBM sweeps per iteration outside the SpMV:

* **measured sweeps** — trace the dispatch-routed hs/fcg solvers under the
  sweep ledger (``lax.while_loop`` traces its body exactly once, so op
  calls per trace == op calls per iteration). HARD-ASSERTS the acceptance
  bound: <= 3 full-vector sweeps/iteration outside the SpMV.
* **modeled traffic** — the roofline memory term per iteration at the
  paper's sizes (405^3/device 7pt, 260^3 27pt), fused vs unfused, ELL vs
  matrix-free SpMV (roofline/analysis.py CG_HOTPATH model).
* **executed** — real solves at a CPU-tractable size, fused dispatch body
  vs an op-by-op unfused body over the IDENTICAL matrix-free SpMV:
  convergence must match exactly; wall time on CPU is reported but not
  TPU-representative (the modeled numbers carry the perf story — see
  benchmarks/common.py).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_results

PAPER_CASES = [("7pt", 405, 7), ("27pt", 260, 27)]


def measured_sweeps() -> list[dict]:
    import jax

    from repro.core.stencil_solver import make_stencil_solver_fn
    from repro.kernels import dispatch as kd
    from repro.matrices.poisson import PoissonProblem

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
    p = PoissonProblem(8, 8, 8, "7pt")
    vec = jax.ShapeDtypeStruct((1, p.n), "float64")
    rows = []
    # pipecg's extra z recurrence buys the hidden all-reduce: bound 4, not 3
    bounds = {"hs": 3, "fcg": 3, "pipecg": 4}
    for variant, bound in bounds.items():
        with kd.record_sweeps() as led:
            solve = make_stencil_solver_fn(mesh, p, 1, variant=variant)
            solve.lower(vec, vec)
        sweeps = led.vector_sweeps("iteration")
        rows.append(dict(variant=variant, vector_sweeps_per_iter=sweeps,
                         spmv_per_iter=led.spmv_calls("iteration")))
        assert sweeps <= bound, (
            f"{variant}: {sweeps} full-vector sweeps/iter > {bound} — "
            "hot-path fusion regressed (acceptance bound)"
        )
    return rows


def modeled_table() -> list[dict]:
    from repro.roofline.analysis import (
        CG_HOTPATH,
        cg_iteration_memory_s,
        cg_vector_traffic,
    )

    rows = []
    for stencil, side, k in PAPER_CASES:
        n = side**3
        for variant in ("hs", "fcg", "pipecg"):
            for matfree in (False, True):
                row = dict(
                    stencil=stencil, variant=variant,
                    spmv="matfree" if matfree else "ell", dofs=n,
                )
                for mode in ("unfused", "fused"):
                    fused = mode == "fused"
                    row[f"{mode}_sweeps"] = CG_HOTPATH[variant][mode][1]
                    row[f"{mode}_vec_gb"] = (
                        cg_vector_traffic(n, variant=variant, fused=fused) / 1e9
                    )
                    row[f"{mode}_mem_s"] = cg_iteration_memory_s(
                        n, k, variant=variant, fused=fused, matfree=matfree
                    )
                row["mem_term_speedup"] = row["unfused_mem_s"] / row["fused_mem_s"]
                rows.append(row)
    return rows


def _unfused_hs_stencil_solver(mesh, p, n_shards, *, tol, maxiter):
    """Seed-style op-by-op hs body over the same matrix-free SpMV."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.cg import SolveResult
    from repro.core.stencil_solver import make_matvec
    from repro.core.vectors import fused_dots, pdot

    A = make_matvec(p, n_shards, "shards", kernels="jnp")

    def body_fn(b, x0):
        r = b - A(x0)
        d0 = fused_dots([(r, r), (b, b)], "shards")
        rr, bb = d0[0], d0[1]
        tol2 = tol * tol * bb

        def cond(c):
            i, x, r, p_, rz, rr = c
            return (i < maxiter) & (rr > tol2)

        def body(c):
            i, x, r, p_, rz, rr = c
            w = A(p_)
            pw = pdot(p_, w, "shards")
            alpha = rz / pw
            x = x + alpha * p_
            r = r - alpha * w
            rz_new = pdot(r, r, "shards")
            rr = pdot(r, r, "shards")
            beta = rz_new / rz
            p_ = r + beta * p_
            return (i + 1, x, r, p_, rz_new, rr)

        i0 = jnp.asarray(0, jnp.int32)
        c = lax.while_loop(cond, body, (i0, x0, r, r, rr, rr))
        return c[1][None], c[0], c[5], bb

    mapped = shard_map(
        lambda b, x0: body_fn(b[0], x0[0]),
        mesh=mesh,
        in_specs=(P("shards", None), P("shards", None)),
        out_specs=(P("shards", None), P(), P(), P()),
        check_rep=False,
    )

    @jax.jit
    def solve(b, x0):
        x, iters, rr, bb = mapped(b, x0)
        return SolveResult(x=x, iters=iters, rr=rr, bb=bb)

    return solve


def executed(side: int = 24, maxiter: int = 200) -> list[dict]:
    """Run the f64 solves in a subprocess: enabling x64 is process-global
    and must not leak into the other benchmarks (or skew the f32 traces
    already made in this process)."""
    import json
    import os
    import subprocess
    import sys

    from benchmarks.common import REPO, SRC

    env = dict(os.environ)
    env["PYTHONPATH"] = (
        REPO + os.pathsep + SRC + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["JAX_ENABLE_X64"] = "1"
    code = (
        "import json, benchmarks.hotpath_fusion as h; "
        f"print('ROWS=' + json.dumps(h._executed_body({side}, {maxiter})))"
    )
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=1800, env=env, cwd=REPO)
    if r.returncode != 0:
        raise RuntimeError(f"executed solves failed:\n{r.stdout[-2000:]}\n"
                           f"{r.stderr[-2000:]}")
    line = next(l for l in r.stdout.splitlines() if l.startswith("ROWS="))
    return json.loads(line[len("ROWS="):])


def _executed_body(side: int, maxiter: int) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.core.stencil_solver import make_stencil_solver_fn
    from repro.matrices.poisson import PoissonProblem

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
    p = PoissonProblem(side, side, side, "7pt")
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(p.n)).reshape(1, p.n)
    x0 = jnp.zeros_like(b)
    rows = []

    def timed(solve):
        res = solve(b, x0)  # compile + run
        jax.block_until_ready(res.x)
        t0 = time.perf_counter()
        res = solve(b, x0)
        jax.block_until_ready(res.x)
        return res, time.perf_counter() - t0

    res_u, t_u = timed(
        _unfused_hs_stencil_solver(mesh, p, 1, tol=1e-8, maxiter=maxiter)
    )
    rows.append(dict(body="hs-unfused", iters=int(res_u.iters),
                     relres=float(res_u.rel_residual), wall_s=t_u))
    for variant in ("hs", "fcg"):
        res, t = timed(make_stencil_solver_fn(
            mesh, p, 1, variant=variant, tol=1e-8, maxiter=maxiter
        ))
        rows.append(dict(body=f"{variant}-fused", iters=int(res.iters),
                         relres=float(res.rel_residual), wall_s=t))
    # identical convergence: fused hs must match the unfused reference
    hs = next(r for r in rows if r["body"] == "hs-fused")
    assert hs["iters"] == rows[0]["iters"], (hs, rows[0])
    assert abs(hs["relres"] - rows[0]["relres"]) < 1e-10 * max(rows[0]["relres"], 1e-30)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    sw = measured_sweeps()
    print(fmt_table(sw, [("variant", "variant"),
                         ("vector_sweeps_per_iter", "vec sweeps/iter"),
                         ("spmv_per_iter", "SpMV/iter")],
                    "Measured (traced) HBM sweeps per CG iteration"))
    mo = modeled_table()
    cols = [
        ("stencil", "stencil"), ("variant", "variant"), ("spmv", "SpMV"),
        ("unfused_sweeps", "sweeps unfused"), ("fused_sweeps", "fused"),
        ("unfused_mem_s", "mem term unfused (s)"),
        ("fused_mem_s", "fused (s)"), ("mem_term_speedup", "speedup"),
    ]
    print(fmt_table(mo, cols, "Modeled memory term per iteration (paper sizes)"))
    ex = executed(side=10 if smoke else 24, maxiter=50 if smoke else 200)
    print(fmt_table(ex, [("body", "body"), ("iters", "iters"),
                         ("relres", "relres"), ("wall_s", "wall (s)")],
                    "Executed toy-size solves (CPU wall time, not TPU-representative)"))
    write_results("hotpath_fusion", sw + mo + ex)


if __name__ == "__main__":
    main()
