"""§Observability: power-sampler fidelity vs the exact energy ledger.

The paper's measurements come from a fixed-rate board-power sampler whose
readings are integrated over the run; the ledger (energy/monitor.py)
instead computes exact per-segment integrals. This benchmark closes the
loop between the two (obs/timeline.py):

* **exactness** — HARD-ASSERTS that replaying the monitor's segments into
  a wall-clock timeline and integrating at event boundaries reproduces
  ``energy()`` and ``energy_by_region()`` *bitwise* (same summation order
  over the same floats — no tolerance).
* **under-sampling curve** — emulates an NVML-style sampler at a sweep of
  rates over the same timeline and reports the relative error of
  sampled-and-integrated dynamic energy vs the exact ledger total: the
  Magoulès-style picture of how coarse sampling misattributes energy
  across fast region transitions. HARD-ASSERTS the acceptance bounds:
  <= 1% relative error at 10 kHz, and a decaying error curve (the
  finest rate beats the coarsest by >= 10x).
"""

from __future__ import annotations

from benchmarks.common import write_results

RATES_HZ = (10, 100, 1_000, 10_000, 100_000)


def reference_timeline(iters: int, n_shards: int = 8):
    """A deterministic solve-shaped timeline (setup + iterated regions)."""
    from repro.energy.accounting import OpCounts
    from repro.energy.trace import EnergyTrace, monitor_from_trace
    from repro.obs.timeline import build_timeline

    tr = EnergyTrace()
    tr.enter("setup")
    tr.enter("iteration")
    tr.record("setup", "spmv", "spmv",
              OpCounts(flops=1e11, hbm_bytes=1e11))
    tr.record("iteration", "overlap", "spmv",
              OpCounts(flops=5e10, hbm_bytes=6e10, ici_bytes=1e7,
                       n_collectives=1))
    tr.record("iteration", "reductions", "dot",
              OpCounts(flops=1e9, hbm_bytes=4e9, ici_bytes=64,
                       n_collectives=1))
    mon = monitor_from_trace(tr, iters=iters, n_shards=n_shards,
                             idle_s=0.01)
    return mon, build_timeline(mon)


def exactness_rows(mon, tl) -> list[dict]:
    """Event-boundary integration vs the monitor: bitwise, not approximate."""
    e_mon, e_tl = mon.energy(), tl.energy()
    # every field the timeline reports must match the monitor bitwise (the
    # monitor additionally derives presentation-only pct fields)
    mismatched = [k for k in e_tl if e_tl[k] != e_mon[k]]
    assert not mismatched, f"timeline energy() diverged on: {mismatched}"
    assert tl.energy_by_region() == mon.energy_by_region(), \
        "timeline energy_by_region() diverged from the monitor"
    span_s = sum(sp.dt for sp in tl.spans)
    assert span_s == mon.duration, (span_s, mon.duration)
    return [dict(check="energy_bitwise", fields=len(e_tl), ok="yes",
                 de_total_j=e_tl["de_total"]),
            dict(check="by_region_bitwise",
                 fields=len(mon.energy_by_region()), ok="yes",
                 de_total_j=e_tl["de_total"])]


def sampling_rows(tl, rates=RATES_HZ) -> list[dict]:
    """Relative error of the emulated fixed-Hz sampler at each rate."""
    from repro.obs.timeline import sample_power, sampling_error

    rows = []
    for hz in rates:
        err = sampling_error(tl, hz)
        rows.append(dict(hz=hz, n_samples=len(sample_power(tl, hz).ts),
                         rel_err=err))
    # acceptance: 10 kHz within 1% of the exact ledger total, and the
    # curve actually decays (finest rate >= 10x better than coarsest)
    by_hz = {r["hz"]: r["rel_err"] for r in rows}
    assert by_hz[10_000] <= 0.01, f"10 kHz error {by_hz[10_000]:.3e} > 1%"
    assert by_hz[max(by_hz)] * 10 <= by_hz[min(by_hz)] or \
        by_hz[min(by_hz)] == 0.0, f"no decay: {by_hz}"
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    iters = 120 if smoke else 500
    mon, tl = reference_timeline(iters)
    ex = exactness_rows(mon, tl)
    print(fmt_table(ex, [("check", "check"), ("fields", "fields"),
                         ("ok", "bitwise"), ("de_total_j", "DE total (J)")],
                    "Timeline vs monitor: event-boundary integration"))
    sw = sampling_rows(tl)
    print(fmt_table(sw, [("hz", "rate (Hz)"), ("n_samples", "samples"),
                         ("rel_err", "rel. energy error")],
                    "Emulated power sampler: under-sampling error"))
    write_results("obs_sampling", ex + sw)


if __name__ == "__main__":
    main()
