"""Fig. 7/8/9/10 + Tables 4/5 analog: un-preconditioned CG, 100 iterations.

Libraries: BCMGX-analog (three variants: hs / fcg / sstep), AmgX-CG analog
(tuned SpMV, unfused reductions; 7pt only, as in the paper), Ginkgo analog
(all-gather SpMV + unfused). Paper sizes: 408^3 (7pt) / 265^3 (27pt) per GPU
weak; same totals strong. Fixed 100 iterations (tol 1e-16 in the paper —
cost-per-iteration study).
"""

from __future__ import annotations

from benchmarks.common import SHARD_COUNTS, abstract_poisson_mat, write_results
from repro.energy.accounting import CostModel, cg_iteration_counts
from repro.energy.monitor import PowerMonitor

CASES = [("7pt", 408), ("27pt", 265)]
ITERS = 100

LIBS = [
    # (label, layout, counts-variant, overlap)
    ("BCMGX-hs", "ring", "hs", True),
    ("BCMGX-fcg", "ring", "fcg", True),
    ("BCMGX-sstep", "ring", "sstep", True),
    ("AmgX", "ring", "amgx", True),
    ("Ginkgo", "allgather", "naive", False),
]


def run(shard_counts=SHARD_COUNTS) -> list[dict]:
    rows = []
    for stencil, side in CASES:
        for mode in ("weak", "strong"):
            for s in shard_counts:
                for label, layout, variant, overlap in LIBS:
                    if label == "AmgX" and stencil == "27pt":
                        continue  # paper: AmgX has no 27pt benchmark
                    p, mat = abstract_poisson_mat(
                        side, stencil, s, weak=(mode == "weak"), layout=layout
                    )
                    c = cg_iteration_counts(mat, variant)
                    mon = PowerMonitor(n_devices=s, cost=CostModel())
                    mon.idle(0.05)
                    t = mon.region("cg", c, n_shards=s, overlap=overlap, repeats=ITERS)
                    mon.idle(0.05)
                    e = mon.energy()
                    rows.append(
                        dict(
                            figure="fig7-10_tab4-5",
                            stencil=stencil,
                            mode=mode,
                            n_shards=s,
                            library=label,
                            dofs=p.n,
                            iters=ITERS,
                            time=t,
                            de_per_iter=e["de_total"] / ITERS,
                            de_per_dof=e["de_total"] / p.n,
                            **e,
                        )
                    )
    write_results("cg_scaling", rows)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import STATIC_DYNAMIC_COLUMNS, fmt_table

    rows = run(shard_counts=(1, 2, 4) if smoke else SHARD_COUNTS)
    weak7 = [r for r in rows if r["stencil"] == "7pt" and r["mode"] == "weak"]
    cols = [
        ("n_shards", "#GPUs"), ("library", "library"), ("time", "time (s)"),
        ("de_per_iter", "dyn E/iter (J)"), ("de_per_dof", "dyn E/DOF (J)"),
        ("gpu_power_peak", "peak (W)"),
    ]
    print(fmt_table(weak7, cols, "Fig 7-9 analog: CG 100 iters, 7pt weak"))
    print(fmt_table(weak7, STATIC_DYNAMIC_COLUMNS, "Table 4 analog"))
    w27 = [r for r in rows if r["stencil"] == "27pt" and r["mode"] == "weak"]
    print(fmt_table(w27, STATIC_DYNAMIC_COLUMNS, "Table 5 analog"))
    top = max(r["n_shards"] for r in weak7)
    sel = {r["library"]: r for r in weak7 if r["n_shards"] == top}
    print(
        f"7pt weak @{top} energy/iter ratios vs BCMGX-hs: "
        + ", ".join(
            f"{k}: {v['de_per_iter']/sel['BCMGX-hs']['de_per_iter']:.2f}x"
            for k, v in sel.items()
        )
    )


if __name__ == "__main__":
    main()
