"""§Roofline table: renders the dry-run JSON records (runs/dryrun/*.json).

One row per (arch x shape x mesh) cell: the three roofline terms, dominant
bottleneck, MODEL_FLOPS ratio, per-device memory. Also emits the markdown
table embedded in EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import REPO

DRYRUN_DIR = os.path.join(REPO, "runs", "dryrun")


def load_records(d: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def row_of(rec: dict) -> dict:
    base = dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                status=rec["status"])
    if rec["status"] == "skip":
        base["note"] = rec["skip_reason"]
        return base
    if rec["status"] != "ok":
        base["note"] = rec.get("error", "")[:80]
        return base
    r = rec["roofline"]
    mem = rec.get("memory", {})
    base.update(
        src=("probe" if rec.get("cost_source") == "unrolled-probe" else "rolled"),
        compute_s=r["compute_s"],
        memory_s=r["memory_s"],
        collective_s=r["collective_s"],
        dominant=r["dominant"],
        step_s=r["step_s"],
        mfu=r["mfu"],
        useful_ratio=r["useful_ratio"],
        gib_per_device=(mem.get("total_per_device", 0) or 0) / 2**30,
        coll_count=rec["collectives"]["total_count"],
    )
    return base


def markdown_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " step s | MFU | useful | GiB/dev | src |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec["mesh"] != mesh:
            continue
        r = row_of(rec)
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |"
                f" {r['note'][:40]} |"
            )
        elif r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} |"
                f" {r['memory_s']:.3e} | {r['collective_s']:.3e} |"
                f" {r['dominant']} | {r['step_s']:.3e} | {r['mfu']:.3f} |"
                f" {r['useful_ratio']:.2f} | {r['gib_per_device']:.2f} |"
                f" {r['src']} |"
            )
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: {r['note']} |")
    return "\n".join(lines)


def main():
    recs = load_records()
    if not recs:
        print("no dry-run records found — run: python -m repro.launch.dryrun --all"
              f" --out {DRYRUN_DIR}")
        return
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] not in ("ok", "skip")]
    print(f"dry-run records: {len(recs)} (ok={len(ok)} skip={len(skip)} err={len(err)})")
    print()
    print(markdown_table(recs, "single"))
    print()
    # summary stats
    import numpy as np

    by_dom = {}
    for r in ok:
        by_dom.setdefault(r["roofline"]["dominant"], []).append(r)
    for dom, rs in sorted(by_dom.items()):
        print(f"dominant={dom}: {len(rs)} cells")
    train = [r for r in ok if r["shape"] == "train_4k" and r["mesh"] == "single"]
    if train:
        mfus = [r["roofline"]["mfu"] for r in train]
        print(f"train_4k single-pod MFU: min={min(mfus):.3f} "
              f"median={float(np.median(mfus)):.3f} max={max(mfus):.3f}")


if __name__ == "__main__":
    main()
