"""Strong scaling at 4-32 emulated shards: 1-D slabs vs the 2-D grid
(§ScaleOut, docs/scaling.md).

The paper's headline claims live at shard counts where halo
surface-to-volume and all-reduce depth — not per-GPU throughput — decide
time and energy. This benchmark stresses exactly that regime on emulated
devices (``--xla_force_host_platform_device_count=N``) and gates the
crossover methodology of docs/scaling.md:

* **modeled** — the smoke-size Poisson cube is partitioned host-side both
  ways at every shard count (real ``partition_csr`` plans, not abstract
  shapes), and the per-iteration *exposed* communication of the hs body is
  priced through the CostModel: the 1-D leg with the flat ``ceil(log2 S)``
  tree, the grid leg with ``coll_hops = reduce_hops(S, grid)`` plus the
  extra hierarchical-stage launches the executed trace records
  (core/vectors.HIER_STAGE_OP).
* **executed** — real ``--no-overlap`` solves through ``api.solve`` (all
  communication exposed by construction), 1-D via ``--grid 1xS`` (the
  identity layout — also byte-compared against a plain no-grid run) and
  2-D via ``--grid RxC``. Exposed comm per iteration comes from the
  executed ledger's ``totals.comm_exposed_s``.

HARD-ASSERTS (the ISSUE 8 acceptance gate):

1. at >= 16 shards the 2-D layout's interior + halo bytes per shard are
   strictly below 1-D (the slab halo is the full side^2 cross-section; the
   pencil halo is its surface);
2. the modeled and executed exposed-comm crossover shard counts — where
   the 2-D leg's per-iteration exposed comm first drops below 1-D,
   log2-interpolated between sweep points — agree within 5%;
3. ``--grid 1xS`` reproduces the plain 1-D run exactly (identical region
   counts, totals, and iterations).

Why the crossover sits where it does: the grid pays twice the collective
launches (4 halo faces vs 2, 2-stage hierarchical reductions) at shallower
depth ``ceil(log2 max(R, C))``. At square grids (16 = 4x4) the latency
terms tie exactly and the halved halo payload decides; at rectangular
grids (8 = 2x4) the extra launches cost more than the payload saves at
smoke sizes. The paper-scale modeled rows show the crossover migrating
toward smaller shard counts as the cube grows and payload, not latency,
dominates — the surface-to-volume story the 2-D layout exists for.

The smoke cube side is 40: at 16 shards a 40^3 cube keeps 2.5 z-planes
per 1-D slab, so rows with both z-neighbors in-shard exist and the ELL
interior pads to k=7 in BOTH layouts — the interior-bytes comparison is
then decided by the halo structure, not by a padding artifact of
one-plane slabs. Past that point (32 shards on the smoke cube) the 1-D
slab thins below 2.5 planes, its interior degenerates to k=6 padding,
and the interior comparison stops being layout-vs-layout — so the
interior+halo gate applies only while ``n_shards <= side / 2.5``; the
halo-undercut gate holds at every shard count >= 16 regardless.
"""

from __future__ import annotations

import dataclasses
import math

from benchmarks.common import run_api_solve, write_results
from repro.api import ProblemSpec, SolverConfig

SIDE = 40  # divisible by every grid dimension used (2, 4, 8)
MODELED_SHARDS = (4, 8, 16, 32)
SMOKE_EXECUTED_SHARDS = (8, 16)
FULL_EXECUTED_SHARDS = (4, 8, 16, 32)
PAPER_SIDES = (320, 1024)
PAPER_SHARDS = (4, 8, 16, 32, 64)

# Per-iteration extras of the hierarchical reduction path beyond what
# cg_iteration_counts already carries: the hs body launches one pdot (1
# scalar) and one fused pair (2 scalars) per iteration; on a 2-axis mesh
# each runs one extra psum stage (core/vectors.all_reduce records
# HIER_STAGE_OP with the same payload).
_HS_STAGE_ICI = 24.0  # (1 + 2) scalars * 8 B
_HS_STAGE_LAUNCHES = 2.0
_HS_REDUCE_ICI = 24.0
_HS_REDUCE_LAUNCHES = 2.0


def _grid_for(s: int):
    from repro.core.partition import default_grid

    r, c = default_grid(s)
    return (r, c), f"{r}x{c}"


def _grid_cost(cost, s: int, grid):
    from repro.roofline.analysis import reduce_hops

    return dataclasses.replace(
        cost, coll_hops=float(reduce_hops(s, grid))
    )


def _exposed_iter_s(cost, counts, s: int) -> float:
    _, (_, _, t_coll) = cost.times(counts, s, overlap=False)
    return t_coll


def crossover_shards(points) -> float | None:
    """First shard count where D = exposed_1d - exposed_2d crosses zero
    from below, log2-interpolated between sweep points.

    ``points``: [(n_shards, D)] sorted by shard count. None if the 2-D
    leg never wins inside the sweep.
    """
    for (s0, d0), (s1, d1) in zip(points, points[1:]):
        if d0 < 0.0 <= d1:
            x0, x1 = math.log2(s0), math.log2(s1)
            x = x0 + (0.0 - d0) / (d1 - d0) * (x1 - x0)
            return float(2.0**x)
    return None


def modeled(shard_counts=MODELED_SHARDS, side: int = SIDE):
    """Real host-side partitions of the smoke cube, priced per iteration.

    Returns (rows, {n_shards: exposed_1d - exposed_2d}).
    """
    from repro.core.partition import partition_csr, pencil_partition
    from repro.energy.accounting import (
        CostModel,
        OpCounts,
        cg_iteration_counts,
    )
    from repro.matrices import poisson

    p = poisson.cube(side, "7pt")
    a = poisson.poisson_scipy(p)
    cost = CostModel()
    rows, deltas = [], {}
    for s in shard_counts:
        grid, grid_str = _grid_for(s)
        mat1 = partition_csr(a, s)
        perm, part = pencil_partition(p, grid)
        ag = a[perm][:, perm].tocsr()
        matg = partition_csr(ag, s, grid=grid, partition=part)
        assert matg.plan.mode == "grid", (s, grid, matg.plan.mode)

        c1 = cg_iteration_counts(mat1, "hs")
        cg = cg_iteration_counts(matg, "hs") + OpCounts(
            ici_bytes=_HS_STAGE_ICI, n_collectives=_HS_STAGE_LAUNCHES
        )
        t1 = _exposed_iter_s(cost, c1, s)
        tg = _exposed_iter_s(_grid_cost(cost, s, grid), cg, s)
        deltas[s] = t1 - tg

        for layout, grid_lbl, mat, t in (
            ("1d", f"1x{s}", mat1, t1),
            ("2d", grid_str, matg, tg),
        ):
            interior = mat.interior_stored_bytes() / s
            halo = mat.plan.collective_bytes_per_shard(8)
            rows.append(
                dict(
                    figure="strong_modeled",
                    layout=layout,
                    grid=grid_lbl,
                    n_shards=s,
                    side=side,
                    dofs=side**3,
                    interior_bytes_shard=interior,
                    halo_bytes_shard=halo,
                    bytes_shard=interior + halo,
                    n_launches=(
                        mat.plan.n_launches
                        if mat.plan.mode == "grid"
                        else len(mat.plan.shifts)
                    ),
                    comm_exposed_iter_s=t,
                )
            )
        if s >= 16:
            # tentpole gate: pencil surface beats slab cross-section
            m1 = next(
                r for r in rows
                if r["n_shards"] == s and r["layout"] == "1d"
            )
            m2 = next(
                r for r in rows
                if r["n_shards"] == s and r["layout"] == "2d"
            )
            assert m2["halo_bytes_shard"] < m1["halo_bytes_shard"], (
                f"2-D halo did not undercut 1-D at {s} shards: "
                f"{m2['halo_bytes_shard']} !< {m1['halo_bytes_shard']}"
            )
            if s <= side / 2.5:  # both interiors pad to k=7 (docstring)
                assert m2["bytes_shard"] < m1["bytes_shard"], (
                    f"2-D interior+halo bytes not below 1-D at {s} "
                    f"shards: {m2['bytes_shard']} !< {m1['bytes_shard']}"
                )
    return rows, deltas


def paper_modeled(sides=PAPER_SIDES, shard_counts=PAPER_SHARDS):
    """Analytic paper-scale rows (no materialization): per-iteration
    exposed comm of the hs body with 1-D slab vs pencil halos. Shows the
    crossover migrating to smaller shard counts as payload outgrows
    launch latency."""
    from repro.energy.accounting import CostModel, OpCounts
    from repro.matrices.poisson import PoissonProblem
    from repro.roofline.analysis import pencil_halo_widths

    cost = CostModel()
    rows = []
    for side in sides:
        points = []
        for s in shard_counts:
            grid, grid_str = _grid_for(s)
            # 1-D: two full-cross-section faces, two launches
            c1 = OpCounts(
                ici_bytes=2.0 * side * side * 8.0 + _HS_REDUCE_ICI,
                n_collectives=2.0 + _HS_REDUCE_LAUNCHES,
            )
            t1 = _exposed_iter_s(cost, c1, s)
            # 2-D: per-face pencil surfaces, hop-weighted like GridPlan
            w = pencil_halo_widths(
                PoissonProblem(side, side, side, "7pt"), grid
            )
            halo = sum(
                width * 8.0 * ((di != 0) + (dj != 0))
                for (di, dj), width in w.items()
            )
            launches = float(
                sum((di != 0) + (dj != 0) for di, dj in w)
            )
            cg = OpCounts(
                ici_bytes=halo + _HS_REDUCE_ICI + _HS_STAGE_ICI,
                n_collectives=(
                    launches + _HS_REDUCE_LAUNCHES + _HS_STAGE_LAUNCHES
                ),
            )
            tg = _exposed_iter_s(_grid_cost(cost, s, grid), cg, s)
            points.append((s, t1 - tg))
            for layout, grid_lbl, t, hb in (
                ("1d", f"1x{s}", t1, 2.0 * side * side * 8.0),
                ("2d", grid_str, tg, halo),
            ):
                rows.append(
                    dict(
                        figure="strong_modeled_paper",
                        layout=layout,
                        grid=grid_lbl,
                        n_shards=s,
                        side=side,
                        halo_bytes_shard=hb,
                        comm_exposed_iter_s=t,
                    )
                )
        x = crossover_shards(points)
        rows.append(
            dict(
                figure="strong_crossover_paper",
                side=side,
                crossover_shards=0.0 if x is None else x,
            )
        )
    return rows


def executed(
    shards=SMOKE_EXECUTED_SHARDS,
    side: int = SIDE,
    maxiter: int = 300,
    tol: float = 1e-8,
):
    """Real --no-overlap solves, 1-D (--grid 1xS) vs 2-D (--grid RxC).

    Returns (rows, {n_shards: exposed_1d - exposed_2d} per iteration).
    Asserts the byte gate at >= 16 shards and the 1xS identity.
    """
    rows, deltas = [], {}
    for s in shards:
        spec = ProblemSpec(problem="poisson7", side=side, shards=s)
        grid, grid_str = _grid_for(s)
        got = {}
        for layout, g in (("1d", f"1x{s}"), ("2d", grid_str)):
            cfg = SolverConfig(
                overlap=False, tol=tol, maxiter=maxiter, grid=g
            )
            _, led = run_api_solve(spec, cfg)
            sol = led["solvers"]["BCMGX-analog"]
            tot = sol["totals"]
            iters = int(sol["iters"])
            assert iters < maxiter, (
                f"{layout} leg did not converge at {s} shards"
            )
            halo = led["halo_bytes_rows"] + led["halo_bytes_cols"]
            got[layout] = dict(
                sol=sol,
                bytes_shard=led["interior_stored_bytes"] / s + halo,
                exposed_iter=tot["comm_exposed_s"] / iters,
            )
            rows.append(
                dict(
                    figure="strong_executed",
                    layout=layout,
                    grid=g,
                    n_shards=s,
                    side=side,
                    iters=iters,
                    relres=sol["relres"],
                    interior_bytes_shard=led["interior_stored_bytes"] / s,
                    halo_bytes_rows=led["halo_bytes_rows"],
                    halo_bytes_cols=led["halo_bytes_cols"],
                    bytes_shard=got[layout]["bytes_shard"],
                    comm_exposed_s=tot["comm_exposed_s"],
                    comm_exposed_iter_s=got[layout]["exposed_iter"],
                    de_total=tot["de_total"],
                    wall_s=sol["wall_s"],
                )
            )
        deltas[s] = got["1d"]["exposed_iter"] - got["2d"]["exposed_iter"]
        # CG on the symmetrically permuted system converges identically
        assert got["1d"]["sol"]["iters"] == got["2d"]["sol"]["iters"], (
            f"pencil permutation changed convergence at {s} shards: "
            f"{got['1d']['sol']['iters']} vs {got['2d']['sol']['iters']}"
        )
        if s >= 16 and s <= side / 2.5:
            assert got["2d"]["bytes_shard"] < got["1d"]["bytes_shard"], (
                f"executed 2-D interior+halo bytes not below 1-D at {s} "
                f"shards: {got['2d']['bytes_shard']} !< "
                f"{got['1d']['bytes_shard']}"
            )

    # --grid 1xS is the identity layout: a plain run must match it in
    # every deterministic ledger field (region counts, totals, iters)
    s0 = shards[0]
    spec = ProblemSpec(problem="poisson7", side=side, shards=s0)
    cfg_plain = SolverConfig(overlap=False, tol=tol, maxiter=maxiter)
    _, led_plain = run_api_solve(spec, cfg_plain)
    cfg_1x = SolverConfig(
        overlap=False, tol=tol, maxiter=maxiter, grid=f"1x{s0}"
    )
    _, led_1x = run_api_solve(spec, cfg_1x)
    assert led_1x["grid"] == [1, s0], led_1x["grid"]
    assert led_1x["halo_bytes_rows"] == 0.0
    a = led_plain["solvers"]["BCMGX-analog"]
    b = led_1x["solvers"]["BCMGX-analog"]
    for key in ("iters", "regions", "totals"):
        assert a[key] == b[key], (
            f"--grid 1x{s0} diverged from the plain 1-D run in {key}"
        )
    rows.append(
        dict(
            figure="strong_identity",
            n_shards=s0,
            side=side,
            grid=f"1x{s0}",
            identity_fields="iters,regions,totals",
            identity_ok=True,
        )
    )
    return rows, deltas


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    mo, d_model = modeled()
    pa = paper_modeled()
    ex, d_exec = executed(
        shards=SMOKE_EXECUTED_SHARDS if smoke else FULL_EXECUTED_SHARDS
    )

    # crossover agreement: restrict the modeled curve to the executed
    # sweep so both interpolate between the same shard counts
    ex_shards = sorted(d_exec)
    x_model = crossover_shards([(s, d_model[s]) for s in ex_shards])
    x_exec = crossover_shards([(s, d_exec[s]) for s in ex_shards])
    assert x_model is not None, (
        f"no modeled exposed-comm crossover in {ex_shards}: {d_model}"
    )
    assert x_exec is not None, (
        f"no executed exposed-comm crossover in {ex_shards}: {d_exec}"
    )
    rel = abs(x_model - x_exec) / x_exec
    assert rel <= 0.05, (
        f"modeled vs executed crossover disagree: {x_model:.2f} vs "
        f"{x_exec:.2f} shards ({rel:.1%} > 5%)"
    )
    rows = mo + pa + ex + [
        dict(
            figure="strong_crossover",
            side=SIDE,
            crossover_modeled_shards=x_model,
            crossover_executed_shards=x_exec,
            crossover_rel_err=rel,
        )
    ]

    print(fmt_table(
        mo,
        [("n_shards", "#GPUs"), ("layout", "layout"), ("grid", "grid"),
         ("interior_bytes_shard", "interior B/shard"),
         ("halo_bytes_shard", "halo B/shard"),
         ("comm_exposed_iter_s", "exposed/iter (s)")],
        f"Modeled strong scaling ({SIDE}^3, 7pt, hs, no overlap)",
    ))
    print(fmt_table(
        [r for r in ex if r["figure"] == "strong_executed"],
        [("n_shards", "#GPUs"), ("layout", "layout"), ("grid", "grid"),
         ("iters", "iters"), ("bytes_shard", "int+halo B/shard"),
         ("comm_exposed_iter_s", "exposed/iter (s)"),
         ("wall_s", "wall (s)")],
        "Executed strong scaling (--no-overlap)",
    ))
    print(
        f"exposed-comm crossover: modeled {x_model:.2f} shards, "
        f"executed {x_exec:.2f} shards ({rel:.2%} apart)"
    )
    write_results("strong_scaling", rows)


if __name__ == "__main__":
    main()
