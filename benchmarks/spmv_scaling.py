"""Fig. 3 analog: SpMV execution times, weak + strong scaling, 7pt & 27pt.

BCMGX-analog (ring halo, overlap) vs Ginkgo-analog (full all-gather,
serialized). Modeled times at the paper's sizes (405^3 / 260^3 per GPU weak;
same totals strong), 1..64 shards.
"""

from __future__ import annotations

from benchmarks.common import SHARD_COUNTS, abstract_poisson_mat, write_results
from repro.energy.accounting import CostModel, spmv_counts


CASES = [("7pt", 405), ("27pt", 260)]


def run(shard_counts=SHARD_COUNTS) -> list[dict]:
    cm = CostModel()
    rows = []
    for stencil, side in CASES:
        for mode in ("weak", "strong"):
            for s in shard_counts:
                if mode == "strong" and side // s < 1:
                    continue
                for lib, layout, overlap in (
                    ("BCMGX", "ring", True),
                    ("Ginkgo", "allgather", False),
                ):
                    p, mat = abstract_poisson_mat(
                        side, stencil, s, weak=(mode == "weak"), layout=layout
                    )
                    c = spmv_counts(mat, overlap)
                    t, (tc, tm, tcoll) = cm.times(c, s, overlap)
                    rows.append(
                        dict(
                            figure="fig3",
                            stencil=stencil,
                            mode=mode,
                            n_shards=s,
                            library=lib,
                            dofs=p.n,
                            time=t,
                            t_compute=tc,
                            t_memory=tm,
                            t_collective=tcoll,
                        )
                    )
    write_results("spmv_scaling", rows)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    rows = run(shard_counts=(1, 2, 4) if smoke else SHARD_COUNTS)
    cols = [
        ("stencil", "stencil"), ("mode", "mode"), ("n_shards", "#GPUs"),
        ("library", "library"), ("time", "time (s)"),
        ("t_memory", "mem term"), ("t_collective", "coll term"),
    ]
    print(fmt_table(rows, cols, "Fig 3 analog: SpMV times (modeled, paper sizes)"))
    # headline: BCMGX/Ginkgo speedup at the largest weak shard count
    top = max(r["n_shards"] for r in rows)
    for stencil, _ in CASES:
        sel = {
            r["library"]: r["time"]
            for r in rows
            if r["stencil"] == stencil and r["mode"] == "weak" and r["n_shards"] == top
        }
        print(
            f"{stencil} weak @{top}: Ginkgo/BCMGX time ratio = "
            f"{sel['Ginkgo'] / sel['BCMGX']:.2f}x"
        )


if __name__ == "__main__":
    main()
