"""s-step CG at 4-32 emulated shards: matrix-powers SpMV vs per-iteration
halo exchanges (§CommAvoid, docs/solvers.md).

The communication-avoiding claim is about LAUNCHES, not volume: the
depth-s widened exchange of the matrix-powers basis moves exactly the
same total halo bytes per iteration as s depth-1 exchanges (the 1-D slab
ghost zones nest, so widening conserves volume), but pays the per-launch
collective latency 1/s as often — and replaces s all-reduces with ONE
fused Gram reduction per block. This benchmark pins that physics down
both modeled and executed, and checks the end of the pipeline (the
autotuner's ``s`` axis) never regresses the untuned default.

* **modeled** — the smoke cube is partitioned host-side at depth 1 (hs)
  and depth s (sstep) at every shard count (real ``partition_csr`` ghost
  plans), and the per-iteration *exposed* communication of each body is
  priced through the CostModel (``cg_iteration_counts`` with the
  matrix-powers pricing).
* **executed** — real ``--no-overlap`` solves through ``api.solve`` (all
  communication exposed by construction); exposed comm per iteration from
  the executed ledger, halo bytes from the traced ``halo`` region.
* **agreement** — x64 subprocess solves of the same system with hs and
  sstep, comparing the returned solutions directly.
* **autotune** — ``--autotune`` at 8 shards (where the ``s`` axis opens)
  on a fresh cache; the default config always rides along as a trial.

HARD-ASSERTS (the ISSUE 9 acceptance gate):

1. modeled: the widened depth-s exchange moves exactly ``s *`` the
   depth-1 bytes per shard (volume conservation), and sstep's
   per-iteration exposed comm is strictly below hs at >= 16 shards;
2. executed: same exposed-comm win at >= 16 shards, and the traced halo
   bytes equal the modeled plan bytes EXACTLY — total halo ici ==
   ``widened + widened / s * iters`` (one setup exchange plus the
   per-iteration average the 1/s-normalized trace records);
3. sstep solutions agree with hs to <= 1e-10 (x64, relative max-norm) on
   1 and 4 shards, for s in {2, 4};
4. the autotuner with the ``s`` axis enumerated trials at least one
   sstep candidate and its chosen config scores <= the untuned default's
   trial (the axis can only win, never lose).

The s-step basis pays for its cheaper communication with a modest
iteration penalty (the monomial basis conditions worse than the coupled
two-term recurrence; the A-norm column scaling keeps it bounded), so the
smoke-size autotuner legitimately picks hs — the gate is that the
*search* never loses, not that sstep always wins. The modeled win factors
(~2.6x exposed comm at s=2) are what pay at paper scale where the
latency term dominates strong scaling.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import SRC, run_api_solve, write_results
from repro.api import ProblemSpec, SolverConfig

SIDE = 40  # same smoke cube as strong_scaling (2.5 z-planes at 16 shards)
MODELED_SHARDS = (4, 8, 16, 32)
SSTEP_S = (2, 4)
SMOKE_EXECUTED_SHARDS = (16,)
FULL_EXECUTED_SHARDS = (8, 16, 32)
AGREE_SIDE = 16
AGREE_TOL = 1e-10
AGREE_CASES = ((1, (2,)), (4, (2, 4)))  # (n_shards, s values)


def _exposed_iter_s(cost, counts, s: int) -> float:
    _, (_, _, t_coll) = cost.times(counts, s, overlap=False)
    return t_coll


def modeled(shard_counts=MODELED_SHARDS, side: int = SIDE):
    """Real host-side partitions at depth 1 vs depth s, priced per
    iteration. Returns (rows, {n_shards: depth-1 plan bytes per shard}).
    """
    from repro.core.partition import partition_csr
    from repro.energy.accounting import CostModel, cg_iteration_counts
    from repro.matrices import poisson

    p = poisson.cube(side, "7pt")
    a = poisson.poisson_scipy(p)
    cost = CostModel()
    rows, plan_bytes = [], {}
    for s in shard_counts:
        mat1 = partition_csr(a, s)
        b1 = mat1.plan.collective_bytes_per_shard(8)
        plan_bytes[s] = b1
        th = _exposed_iter_s(cost, cg_iteration_counts(mat1, "hs"), s)
        rows.append(
            dict(
                figure="sstep_modeled", variant="hs", s_step=1,
                n_shards=s, side=side, dofs=side**3,
                halo_bytes_iter=b1, comm_exposed_iter_s=th,
            )
        )
        for sv in SSTEP_S:
            mats = partition_csr(a, s, halo_depth=sv)
            widened = mats.plan.collective_bytes_per_shard(8)
            # volume conservation: the nested slab ghost zones widen to
            # exactly s times the depth-1 exchange — same bytes per
            # iteration, 1/s the launches
            assert widened == sv * b1, (
                f"widened exchange is not volume-conserving at {s} "
                f"shards, s={sv}: {widened} != {sv} * {b1}"
            )
            ts = _exposed_iter_s(
                cost, cg_iteration_counts(mats, "sstep", s=sv), s
            )
            rows.append(
                dict(
                    figure="sstep_modeled", variant="sstep", s_step=sv,
                    n_shards=s, side=side, dofs=side**3,
                    halo_bytes_iter=widened / sv, comm_exposed_iter_s=ts,
                    comm_win_vs_hs=th / ts,
                )
            )
            if s >= 16:
                # tentpole gate: fewer launches beat equal volume
                assert ts < th, (
                    f"modeled sstep exposed comm not below hs at {s} "
                    f"shards, s={sv}: {ts} !< {th}"
                )
    return rows, plan_bytes


def _halo_ici(sol: dict) -> float:
    regions = sol["regions"]
    return sum(
        regions[r]["ici_bytes"] for r in ("halo", "overlap") if r in regions
    )


def executed(
    plan_bytes: dict,
    shards=SMOKE_EXECUTED_SHARDS,
    side: int = SIDE,
    maxiter: int = 300,
    tol: float = 1e-8,
):
    """Real --no-overlap solves, hs vs sstep s=2, halo bytes gated exact.

    ``plan_bytes``: the modeled leg's depth-1 exchange bytes per shard at
    each shard count (the executed solves run the same cube, so the
    traced halo region must integrate to exactly ``widened + widened / s
    * iters`` — one setup exchange plus the normalized per-iteration
    average).
    """
    rows = []
    for s in shards:
        spec = ProblemSpec(problem="poisson7", side=side, shards=s)
        got = {}
        for variant, sv in (("hs", None), ("sstep", 2)):
            cfg = SolverConfig(
                variant=variant, s=sv, overlap=False, tol=tol,
                maxiter=maxiter,
            )
            _, led = run_api_solve(spec, cfg)
            sol = led["solvers"]["BCMGX-analog"]
            iters = int(sol["iters"])
            assert iters < maxiter, (
                f"{variant} leg did not converge at {s} shards"
            )
            depth = sv or 1
            if depth > 1:
                # the s knob must surface in the ledger (schema gate)
                assert led["halo_depth"] == depth, led.get("halo_depth")
                assert led["s"] == sv, led.get("s")
            else:
                assert "halo_depth" not in led and "s" not in led
            widened = depth * plan_bytes[s]
            traced = _halo_ici(sol)
            expect = widened + widened / depth * iters
            # the traced exchange must equal the plan EXACTLY — the
            # 1/s-normalized while-body counts are the model, measured
            assert traced == expect, (
                f"traced halo bytes diverge from the plan at {s} shards "
                f"({variant}, s={depth}): {traced} != {expect}"
            )
            exposed_iter = sol["totals"]["comm_exposed_s"] / iters
            got[variant] = exposed_iter
            rows.append(
                dict(
                    figure="sstep_executed", variant=variant,
                    s_step=depth, n_shards=s, side=side, iters=iters,
                    relres=sol["relres"],
                    halo_bytes_iter=widened / depth,
                    comm_exposed_s=sol["totals"]["comm_exposed_s"],
                    comm_exposed_iter_s=exposed_iter,
                    de_total=sol["totals"]["de_total"],
                    wall_s=sol["wall_s"],
                )
            )
        if s >= 16:
            assert got["sstep"] < got["hs"], (
                f"executed sstep exposed comm not below hs at {s} "
                f"shards: {got['sstep']} !< {got['hs']}"
            )
    return rows


_AGREE_SCRIPT = """
import json, sys
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
from repro.core.cg import make_solver
from repro.core.partition import pad_vector, partition_csr
from repro.core.spmv import shard_matrix, shard_vector
from repro.launch.mesh import make_solver_mesh
from repro.matrices.poisson import PoissonProblem, poisson_scipy

S = int(sys.argv[1])
svals = [int(v) for v in sys.argv[2].split(",")]
side = int(sys.argv[3])
a = poisson_scipy(PoissonProblem(side, side, side, "7pt"))
n = a.shape[0]
b = np.ones(n)
mesh = make_solver_mesh(S)


def solve(variant, s):
    kw = {"s": s} if variant == "sstep" else {}
    mat = shard_matrix(mesh, partition_csr(a, S, halo_depth=s))
    solver = make_solver(
        mesh, mat, variant=variant, tol=1e-11, maxiter=600, **kw
    )
    bp = shard_vector(mesh, pad_vector(b, mat), "shards")
    x0 = shard_vector(mesh, np.zeros_like(pad_vector(b, mat)), "shards")
    res = solver(bp, x0)
    return np.asarray(res.x)[:n], int(res.iters)


xh, iters_hs = solve("hs", 1)
out = []
for s in svals:
    xs, iters_s = solve("sstep", s)
    err = float(np.max(np.abs(xs - xh)) / np.max(np.abs(xh)))
    out.append(dict(s=s, iters_hs=iters_hs, iters_sstep=iters_s, err=err))
print(json.dumps(out))
"""


def agreement(cases=AGREE_CASES, side: int = AGREE_SIDE):
    """x64 subprocess per shard count: sstep vs hs solution max-norm."""
    rows = []
    for n_shards, svals in cases:
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_shards}"
        )
        r = subprocess.run(
            [
                sys.executable, "-c", _AGREE_SCRIPT, str(n_shards),
                ",".join(str(s) for s in svals), str(side),
            ],
            capture_output=True, text=True, timeout=1800, env=env,
        )
        if r.returncode != 0:
            raise RuntimeError(
                f"agreement leg failed at {n_shards} shards:\n"
                f"{r.stdout[-2000:]}\n{r.stderr[-2000:]}"
            )
        for rec in json.loads(r.stdout.splitlines()[-1]):
            assert rec["err"] <= AGREE_TOL, (
                f"sstep diverged from hs at {n_shards} shards, "
                f"s={rec['s']}: {rec['err']} > {AGREE_TOL}"
            )
            rows.append(
                dict(
                    figure="sstep_agreement", n_shards=n_shards,
                    s_step=rec["s"], side=side,
                    iters_hs=rec["iters_hs"],
                    iters_sstep=rec["iters_sstep"],
                    agree_tol=f"{AGREE_TOL:g}", agree_ok=True,
                    agree_relerr=rec["err"],
                )
            )
    return rows


def autotuned(side: int = 12, shards: int = 8, budget: int = 6):
    """--autotune where the s axis opens: the search may only ever win."""
    import shutil

    from repro.autotune import DEFAULT

    cache_dir = tempfile.mkdtemp(prefix="sstep_autotune_")
    try:
        spec = ProblemSpec(problem="poisson7", side=side, shards=shards)
        cfg = SolverConfig(
            autotune=True, objective="energy", tune_budget=budget,
            tune_cache=os.path.join(cache_dir, "cache.json"), maxiter=200,
        )
        _, led = run_api_solve(spec, cfg)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    at = led["autotune"]
    trials = at["trials"]
    sstep_trials = [t for t in trials if t.get("variant") == "sstep"]
    assert sstep_trials, (
        f"the s axis enumerated no sstep trials at {shards} shards"
    )
    assert any(t["executed"] for t in sstep_trials), (
        "no sstep candidate was actually executed by the trial stage"
    )
    default = next(
        (t for t in trials if t["label"] == DEFAULT.label), None
    )
    assert default is not None, (
        f"the untuned default {DEFAULT.label} did not ride along: "
        f"{[t['label'] for t in trials]}"
    )
    chosen_score = trials[0]["score"]  # sorted best-first
    assert chosen_score <= default["score"], (
        f"autotune with the s axis lost to the untuned default: "
        f"{at['chosen_label']} scores {chosen_score} > "
        f"{default['score']}"
    )
    best_sstep = min(sstep_trials, key=lambda t: t["score"])
    return [
        dict(
            figure="sstep_autotune", n_shards=shards, side=side,
            chosen=at["chosen_label"], chosen_score=chosen_score,
            candidates_total=at["candidates_total"],
            candidates_pruned=at["candidates_pruned"],
            candidates_trialed=at["candidates_trialed"],
            sstep_trials=len(sstep_trials),
            best_sstep=best_sstep["label"],
            best_sstep_score=best_sstep["score"],
            default_score=default["score"],
        )
    ]


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    mo, plan_bytes = modeled()
    ex = executed(
        plan_bytes,
        shards=SMOKE_EXECUTED_SHARDS if smoke else FULL_EXECUTED_SHARDS,
    )
    ag = agreement()
    au = autotuned()
    rows = mo + ex + ag + au

    print(fmt_table(
        mo,
        [("n_shards", "#GPUs"), ("variant", "variant"), ("s_step", "s"),
         ("halo_bytes_iter", "halo B/iter"),
         ("comm_exposed_iter_s", "exposed/iter (s)")],
        f"Modeled s-step exposed comm ({SIDE}^3, 7pt, no overlap)",
    ))
    print(fmt_table(
        ex,
        [("n_shards", "#GPUs"), ("variant", "variant"), ("s_step", "s"),
         ("iters", "iters"), ("halo_bytes_iter", "halo B/iter"),
         ("comm_exposed_iter_s", "exposed/iter (s)"),
         ("wall_s", "wall (s)")],
        "Executed s-step exposed comm (--no-overlap)",
    ))
    print(fmt_table(
        ag,
        [("n_shards", "#GPUs"), ("s_step", "s"), ("iters_hs", "hs iters"),
         ("iters_sstep", "sstep iters"), ("agree_relerr", "max rel err")],
        f"sstep vs hs solution agreement (x64, tol {AGREE_TOL:g})",
    ))
    a = au[0]
    print(
        f"autotune @{a['n_shards']} shards: chose {a['chosen']} "
        f"(score {a['chosen_score']:.3e}) vs default "
        f"{a['default_score']:.3e}; {a['sstep_trials']} sstep trials, "
        f"best {a['best_sstep']} at {a['best_sstep_score']:.3e}"
    )
    write_results("sstep_scaling", rows)


if __name__ == "__main__":
    main()
