"""Fill EXPERIMENTS.md placeholders from runs/dryrun records.

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO
from benchmarks.roofline_table import load_records, markdown_table

EXP = os.path.join(REPO, "EXPERIMENTS.md")
DRY = os.path.join(REPO, "runs", "dryrun")


def rec_of(name: str):
    p = os.path.join(DRY, name)
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def fmt_cell(rec):
    if rec is None or rec.get("status") != "ok":
        return "(not available)"
    r = rec["roofline"]
    mem = rec.get("memory", {})
    gib = (mem.get("total_per_device", 0) or 0) / 2**30
    return (
        f"step {r['step_s']:.3g}s (C {r['compute_s']:.3g} / M {r['memory_s']:.3g}"
        f" / X {r['collective_s']:.3g}), MFU {r['mfu']:.3f}, {gib:.1f} GiB/dev"
    )


def verdict(base, new, what="memory_s"):
    if base is None or new is None or base.get("status") != "ok" or new.get("status") != "ok":
        return "(pending)"
    b = base["roofline"][what]
    n = new["roofline"][what]
    if n < b * 0.95:
        return f"confirmed: {what} {b:.3g} -> {n:.3g} ({b/n:.2f}x)"
    if n > b * 1.05:
        return f"refuted: {what} {b:.3g} -> {n:.3g} (regression {n/b:.2f}x)"
    return f"neutral: {what} {b:.3g} -> {n:.3g}"


def main():
    text = open(EXP).read()

    recs = load_records()
    # probe-corrected table: only records with cost_source
    probe_recs = [r for r in recs if r.get("cost_source") == "unrolled-probe"
                  and "+".join([]) == "" and "+" not in r["arch"]]
    base_recs = [r for r in recs if "solver" not in r["arch"] and "+" not in r["arch"]]
    text = text.replace(
        "<!-- ROOFLINE_PROBE_TABLE -->",
        markdown_table(probe_recs, "single") if probe_recs else "(probe table pending)",
    )
    text = text.replace(
        "<!-- ROOFLINE_FULL_TABLE -->", markdown_table(base_recs, "single")
    )

    qb = rec_of("qwen3-8b__train_4k__single.json")
    q1 = rec_of("qwen3-8b+attnbf16__train_4k__single.json")
    q2 = rec_of("qwen3-8b+attnbf16+mb16__train_4k__single.json")
    zb = rec_of("zamba2-7b__train_4k__single.json")
    z1 = rec_of("zamba2-7b+q128__train_4k__single.json")
    z2 = rec_of("zamba2-7b+q64__train_4k__single.json")

    subs = {
        "<!-- QWEN3_BASE -->": fmt_cell(qb),
        "<!-- QWEN3_BF16 -->": fmt_cell(q1),
        "<!-- QWEN3_BF16_V -->": verdict(qb, q1),
        "<!-- QWEN3_MB -->": fmt_cell(q2),
        "<!-- QWEN3_MB_V -->": verdict(q1, q2, "memory_s")
        + (
            f"; temp mem {((qb or {}).get('memory', {}).get('total_per_device', 0))/2**30:.0f}"
            f" -> {((q2 or {}).get('memory', {}).get('total_per_device', 0))/2**30:.0f} GiB/dev"
            if q2 and qb
            else ""
        ),
        "<!-- ZAMBA_BASE -->": fmt_cell(zb),
        "<!-- ZAMBA_Q128 -->": fmt_cell(z1),
        "<!-- ZAMBA_Q128_V -->": verdict(zb, z1),
        "<!-- ZAMBA_Q64 -->": fmt_cell(z2),
        "<!-- ZAMBA_Q64_V -->": verdict(z1, z2),
    }

    def summary(base, best, label):
        if base is None or best is None or best.get("status") != "ok":
            return f"{label}: (pending)"
        b, n = base["roofline"]["step_s"], best["roofline"]["step_s"]
        return (
            f"{b:.3g} s/step | optimized {n:.3g} s/step | **{b/n:.2f}x**"
        )

    subs["<!-- QWEN3_SUMMARY -->"] = summary(qb, q2 or q1, "qwen3")
    subs["<!-- ZAMBA_SUMMARY -->"] = summary(zb, z2 or z1, "zamba2")

    for k, v in subs.items():
        text = text.replace(k, v)
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md filled.")
    for k, v in subs.items():
        print(f"  {k[5:-4]:18s} {v[:90]}")


if __name__ == "__main__":
    main()
