"""Batched multi-RHS block-CG: matrix-traffic amortization (§MultiRHS).

The paper's energy argument is that sparse solves are dominated by
streaming the matrix from HBM; with r right-hand sides batched into one
block solve (core/cg.make_block_solver + the SpMM interiors), the matrix
is read ONCE per iteration while only the O(n*r) vector traffic scales —
so energy-per-solve falls toward the vector-bound floor as r grows.

* **modeled** — per-iteration traffic/time/energy at the paper's sizes for
  r in {1, 4, 8, 16} (spmv_counts(nrhs=...) + the block-HS hot-path row of
  roofline/analysis.CG_HOTPATH), reporting the per-solve matrix-byte
  amortization curve.
* **executed** — real solves through the typed API
  (``ProblemSpec``/``SolverConfig`` → ``common.run_api_solve``):
  ``nrhs=8`` batched vs sequential ``nrhs=1``, with per-repeat wall
  times (p50/p99 per-solve latency, solves/sec, GB/s — info side).
  HARD-ASSERTS the acceptance invariants:

  1. per-solve SpMV-region HBM *matrix* bytes at nrhs=8 are <= 0.2x the
     nrhs=1 value in the executed ledger, and the traced matrix bytes
     match the stored-bytes model within 5% on both legs;
  2. batched solves/sec at nrhs=8 are >= 2x eight sequential nrhs=1
     solves of the same system;
  3. a tuned ``--nrhs 8 --autotune`` run never loses (ledger energy) to
     the untuned batched default, and its decision comes from an
     nrhs-keyed cache entry.

Gated: modeled curves, iteration counts, per-solve modeled energy/time,
matrix-byte ratios, autotune decisions. Info: everything wall-derived.
"""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from benchmarks.common import (
    SHARD_COUNTS,
    abstract_poisson_mat,
    run_api_solve,
    write_results,
)
from repro.api import ProblemSpec, SolverConfig

PAPER_SIDE = 405  # 7pt weak-scaled DOFs/device, as in cg_scaling
RHS_COUNTS = (1, 4, 8, 16)


def modeled(
    shard_counts=SHARD_COUNTS, side: int = PAPER_SIDE, rhs=RHS_COUNTS
) -> list[dict]:
    """Per-iteration, per-shard traffic/time/energy of the block solve."""
    from repro.energy.accounting import CostModel, spmv_counts
    from repro.roofline.analysis import cg_vector_traffic

    cost = CostModel()
    rows = []
    for s in shard_counts:
        _, mat = abstract_poisson_mat(side, "7pt", s, weak=True)
        base = None
        for r in rhs:
            variant = "block_hs" if r > 1 else "hs"
            c = spmv_counts(mat, nrhs=r)
            vec_bytes = cg_vector_traffic(
                mat.n_own_pad, variant=variant, nrhs=r
            )
            t_sp, _ = cost.times(c, s, True)
            p_chip = cost.power.chip_power(
                c.flops / t_sp, c.hbm_bytes / t_sp, c.ici_bytes / t_sp
            )
            per_solve_mat = c.hbm_matrix_bytes / r
            if base is None:
                base = per_solve_mat  # r == 1 reference (rhs is sorted)
            rows.append(
                dict(
                    figure="multirhs_modeled",
                    stencil="7pt",
                    n_shards=s,
                    nrhs=r,
                    dofs=side**3 * s,
                    matrix_bytes_iter=c.hbm_matrix_bytes,
                    per_solve_matrix_bytes=per_solve_mat,
                    matrix_amortization=per_solve_mat / base,
                    vector_bytes_iter=vec_bytes,
                    spmv_iter_s=t_sp,
                    spmv_iter_j=p_chip * t_sp,
                    per_solve_spmv_j=p_chip * t_sp / r,
                )
            )
    return rows


def _solver_entry(led: dict) -> dict:
    return led["solvers"]["BCMGX-analog"]


def _total_energy(led: dict) -> float:
    tot = _solver_entry(led)["totals"]
    return tot["te_gpu"] + tot["te_cpu"]


def _traced_matrix_bytes(sol: dict) -> float:
    return sum(
        reg.get("hbm_matrix_bytes", 0.0) for reg in sol["regions"].values()
    )


def executed(
    shards: int = 2, side: int = 12, maxiter: int = 300, tol: float = 1e-8,
    nrhs: int = 8, repeats: int = 5,
) -> list[dict]:
    """Batched vs sequential solves; asserts the amortization invariants."""
    rows = []
    spec = ProblemSpec(problem="poisson7", side=side, shards=shards)
    legs = {}
    for r in (1, nrhs):
        cfg = SolverConfig(nrhs=r, maxiter=maxiter, tol=tol, repeats=repeats)
        _, led = run_api_solve(spec, cfg)
        sol = _solver_entry(led)
        walls = np.asarray(sol["wall_repeats_s"], dtype=float)
        per_solve_wall = walls / r
        traced_mat = _traced_matrix_bytes(sol)
        # stored-bytes model: one full matrix stream per sweep, per shard,
        # (iters + 1) sweeps (init residual + one per iteration)
        modeled_mat = (
            led["stored_bytes"] / led["shards"] * (sol["iters"] + 1)
        )
        hbm_total = sum(
            reg["hbm_bytes"] for reg in sol["regions"].values()
        )
        legs[r] = dict(sol=sol, led=led, traced_mat=traced_mat,
                       modeled_mat=modeled_mat, wall=float(walls.mean()))
        rows.append(
            dict(
                figure="multirhs_executed",
                n_shards=shards,
                nrhs=r,
                iters=sol["iters"],
                relres=sol["relres"],
                per_solve_spmv_matrix_bytes=sol["per_solve_spmv_matrix_bytes"],
                traced_matrix_bytes=traced_mat,
                modeled_matrix_bytes=modeled_mat,
                per_solve_modeled_s=sol["per_solve_modeled_s"],
                per_solve_de_j=sol["per_solve_de_j"],
                # wall-derived (machine-dependent): info side
                wall_s=legs[r]["wall"],
                per_solve_wall_p50_s=float(np.percentile(per_solve_wall, 50)),
                per_solve_wall_p99_s=float(np.percentile(per_solve_wall, 99)),
                solves_per_wall_sec=r / legs[r]["wall"],
                hbm_gbps_wall=hbm_total / legs[r]["wall"] / 1e9,
            )
        )
    # invariant 1a: modeled == traced matrix bytes (both legs, 5%)
    for r, leg in legs.items():
        err = abs(leg["traced_mat"] - leg["modeled_mat"]) / leg["modeled_mat"]
        assert err <= 0.05, (
            f"traced matrix bytes diverge from the stored-bytes model at "
            f"nrhs={r}: traced {leg['traced_mat']} vs modeled "
            f"{leg['modeled_mat']} ({100 * err:.1f}%)"
        )
    # invariant 1b: batched per-solve matrix traffic <= 0.2x single-RHS
    ps_batched = legs[nrhs]["sol"]["per_solve_spmv_matrix_bytes"]
    ps_single = legs[1]["sol"]["per_solve_spmv_matrix_bytes"]
    assert ps_batched <= 0.2 * ps_single, (
        f"per-solve matrix bytes at nrhs={nrhs} ({ps_batched}) exceed 0.2x "
        f"the nrhs=1 value ({ps_single}): amortization broke"
    )
    # invariant 2: batched throughput >= 2x sequential single-RHS solves
    batched_rate = nrhs / legs[nrhs]["wall"]
    sequential_rate = 1.0 / legs[1]["wall"]  # nrhs solves take nrhs*wall
    assert batched_rate >= 2.0 * sequential_rate, (
        f"batched nrhs={nrhs} at {batched_rate:.2f} solves/s is not 2x the "
        f"sequential rate {sequential_rate:.2f} solves/s"
    )
    # invariant 3: a tuned batched run never loses to the untuned default
    untuned_e = _total_energy(legs[nrhs]["led"])
    cache_dir = tempfile.mkdtemp(prefix="multirhs_bench_")
    try:
        cache = os.path.join(cache_dir, "cache.json")
        tuned = SolverConfig(
            nrhs=nrhs, maxiter=maxiter, tol=tol, repeats=repeats,
            autotune=True, objective="energy", tune_budget=4,
            tune_cache=cache,
        )
        _, tled = run_api_solve(spec, tuned)
        at = tled["autotune"]
        tuned_e = _total_energy(tled)
        assert at["fingerprint"]["nrhs"] == nrhs, (
            f"tuned run keyed its cache entry at nrhs="
            f"{at['fingerprint']['nrhs']}, not {nrhs}"
        )
        assert tuned_e <= untuned_e, (
            f"tuned nrhs={nrhs} solve ({tuned_e} J) lost to the untuned "
            f"batched default ({untuned_e} J)"
        )
        rows.append(
            dict(
                figure="multirhs_tuned",
                n_shards=shards,
                nrhs=nrhs,
                chosen=at["chosen_label"],
                candidates_trialed=at["candidates_trialed"],
                iters=_solver_entry(tled)["iters"],
                tuned_energy_j=tuned_e,
                untuned_energy_j=untuned_e,
                wall_s=_solver_entry(tled)["wall_s"],
            )
        )
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    return rows


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    mo = modeled(
        shard_counts=(1, 4) if smoke else SHARD_COUNTS,
        side=32 if smoke else PAPER_SIDE,
    )
    print(fmt_table(
        mo,
        [("n_shards", "#GPUs"), ("nrhs", "r"),
         ("per_solve_matrix_bytes", "matrix B/solve"),
         ("matrix_amortization", "amortized x"),
         ("spmv_iter_s", "SpMV iter (s)"),
         ("per_solve_spmv_j", "SpMV J/solve")],
        "Modeled per-iteration matrix amortization (paper sizes, 7pt weak)",
    ))
    ex = executed(
        shards=2,
        side=10 if smoke else 16,
        maxiter=200 if smoke else 400,
        repeats=5 if smoke else 20,
    )
    print(fmt_table(
        ex,
        [("figure", "figure"), ("nrhs", "r"), ("iters", "iters"),
         ("per_solve_spmv_matrix_bytes", "matrix B/solve"),
         ("per_solve_de_j", "DE J/solve"),
         ("solves_per_wall_sec", "solves/s"),
         ("per_solve_wall_p99_s", "p99 (s)")],
        "Executed: batched nrhs=8 vs sequential nrhs=1",
    ))
    write_results("multirhs_scaling", mo + ex)


if __name__ == "__main__":
    main()
