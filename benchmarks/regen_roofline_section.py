"""Regenerate the §Roofline tables inside EXPERIMENTS.md (idempotent).

Replaces the markdown tables under the two section headings with fresh
renders from runs/dryrun — run after any new probe/hillclimb cells.

    PYTHONPATH=src python -m benchmarks.regen_roofline_section
"""

from __future__ import annotations

import os
import re

from benchmarks.common import REPO
from benchmarks.roofline_table import load_records, markdown_table

EXP = os.path.join(REPO, "EXPERIMENTS.md")

PROBE_HEAD = "### Probe-corrected roofline, representative cells (single-pod, 256 chips)"
FULL_HEAD = "### Full baseline table (all 40 assigned cells, single-pod)"
END_MARK = "\nReading the table:"


def main():
    recs = load_records()
    probe_recs = [
        r for r in recs
        if r.get("cost_source") == "unrolled-probe" and "+" not in r["arch"]
    ]
    base_recs = [
        r for r in recs if "solver" not in r["arch"] and "+" not in r["arch"]
    ]
    probe_tbl = markdown_table(probe_recs, "single") if probe_recs else "(none yet)"
    full_tbl = markdown_table(base_recs, "single")

    text = open(EXP).read()
    pat = re.compile(
        re.escape(PROBE_HEAD) + r".*?" + re.escape(FULL_HEAD) + r".*?" + re.escape(END_MARK),
        re.DOTALL,
    )
    new = (
        f"{PROBE_HEAD}\n\n{probe_tbl}\n\n{FULL_HEAD}\n\n{full_tbl}\n{END_MARK}"
    )
    text, n = pat.subn(new, text)
    assert n == 1, "section markers not found"
    open(EXP, "w").write(text)
    print(f"regenerated: {len(probe_recs)} probe rows, "
          f"{sum(1 for r in base_recs if r['mesh'] == 'single')} baseline rows")


if __name__ == "__main__":
    main()
