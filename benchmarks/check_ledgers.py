"""CI regression gate for the benchmark energy/time ledgers.

    python -m benchmarks.check_ledgers            # compare against baselines
    python -m benchmarks.check_ledgers --update   # refresh the baselines

Every benchmark emits a machine-readable JSON ledger (see
``benchmarks/common.write_ledger``): the ``gate`` side holds deterministic
quantities — modeled energy/time from the executed-counts trace, iteration
counts, op counts — and the ``info`` side holds wall-clock measurements.

This checker recursively diffs each emitted ledger's ``gate`` against the
checked-in baseline in ``benchmarks/baselines/``: numbers must agree within
``--tol`` (default 5%, relative; tiny values compared absolutely), strings
and structure must match exactly. Any drift beyond tolerance — more energy
per iteration, more iterations to converge, lost regions — fails the CI
``energy-ledger`` job.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import sys

from benchmarks.common import LEDGERS, REPO

BASELINES = os.path.join(REPO, "benchmarks", "baselines")


def _diff(base, new, tol: float, path: str, errors: list[str]):
    if isinstance(base, dict) and isinstance(new, dict):
        for k in base:
            if k not in new:
                errors.append(f"{path}.{k}: missing from new ledger")
            else:
                _diff(base[k], new[k], tol, f"{path}.{k}", errors)
        for k in new:
            if k not in base:
                errors.append(f"{path}.{k}: not in baseline (new field)")
        return
    if isinstance(base, list) and isinstance(new, list):
        if len(base) != len(new):
            errors.append(f"{path}: length {len(base)} -> {len(new)}")
            return
        for i, (b, n) in enumerate(zip(base, new)):
            _diff(b, n, tol, f"{path}[{i}]", errors)
        return
    if isinstance(base, bool) or isinstance(new, bool):
        if base != new:
            errors.append(f"{path}: {base} -> {new}")
        return
    if isinstance(base, (int, float)) and isinstance(new, (int, float)):
        if math.isclose(base, new, rel_tol=tol, abs_tol=1e-9):
            return
        rel = abs(new - base) / max(abs(base), 1e-300)
        errors.append(f"{path}: {base} -> {new} ({100 * rel:.1f}% drift)")
        return
    if base != new:
        errors.append(f"{path}: {base!r} -> {new!r}")


def check_one(name: str, tol: float) -> list[str]:
    with open(os.path.join(BASELINES, name)) as f:
        base = json.load(f)
    led_path = os.path.join(LEDGERS, name)
    if not os.path.exists(led_path):
        return [f"{name}: ledger was not emitted (run benchmarks.run --smoke)"]
    with open(led_path) as f:
        new = json.load(f)
    errors: list[str] = []
    _diff(base.get("gate", {}), new.get("gate", {}), tol, "gate", errors)
    return [f"{name}: {e}" for e in errors]


def _smoke_ledgers() -> list[str]:
    """CI gates the smoke run only — full-size ledgers stay local."""
    if not os.path.isdir(LEDGERS):
        return []
    return sorted(
        fn for fn in os.listdir(LEDGERS) if fn.endswith("_smoke.json")
    )


def update_baselines() -> int:
    os.makedirs(BASELINES, exist_ok=True)
    n = 0
    for fn in _smoke_ledgers():
        shutil.copyfile(os.path.join(LEDGERS, fn), os.path.join(BASELINES, fn))
        print(f"baseline updated: {fn}")
        n += 1
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative tolerance on gated numbers (default 5%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy the emitted ledgers over the baselines")
    args = ap.parse_args(argv)

    if args.update:
        if update_baselines() == 0:
            print("no ledgers found — run `python -m benchmarks.run --smoke`")
            return 1
        return 0

    if not os.path.isdir(BASELINES):
        print(f"no baselines directory at {BASELINES}")
        return 1
    names = sorted(fn for fn in os.listdir(BASELINES) if fn.endswith(".json"))
    if not names:
        print("no baseline ledgers checked in")
        return 1
    failures: list[str] = []
    for name in names:
        errs = check_one(name, args.tol)
        status = "OK" if not errs else f"FAIL ({len(errs)} diffs)"
        print(f"[{status:>14s}] {name}")
        failures.extend(errs)
    # every emitted smoke ledger must be gated — a benchmark added without a
    # baseline would otherwise silently run ungated forever
    for fn in _smoke_ledgers():
        if fn not in names:
            failures.append(
                f"{fn}: emitted but has no baseline — check one in with "
                "`python -m benchmarks.check_ledgers --update`"
            )
    if failures:
        print(f"\n{len(failures)} ledger regression(s) beyond "
              f"{100 * args.tol:.0f}% tolerance:")
        for e in failures[:50]:
            print(f"  {e}")
        return 1
    print(f"\nall {len(names)} ledgers within {100 * args.tol:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
