"""CI regression gate for the benchmark energy/time ledgers.

    python -m benchmarks.check_ledgers            # compare against baselines
    python -m benchmarks.check_ledgers --update   # refresh the baselines

Every benchmark emits a machine-readable JSON ledger (see
``benchmarks/common.write_ledger``): the ``gate`` side holds deterministic
quantities — modeled energy/time from the executed-counts trace, iteration
counts, op counts — and the ``info`` side holds wall-clock measurements.

This checker recursively diffs each emitted ledger's ``gate`` against the
checked-in baseline in ``benchmarks/baselines/``: numbers must agree within
``--tol`` (default 5%, relative; tiny values compared absolutely), strings
and structure must match exactly. Any drift beyond tolerance — more energy
per iteration, more iterations to converge, lost regions — fails the CI
``energy-ledger`` job.

Mismatches are reported as a per-field unified diff (field path, baseline
value, emitted value, relative error), one ``@@`` hunk per drifted field —
a tuning sweep or model change typically moves many fields at once, and
diagnosing multi-field drift needs all of them side by side, not the first
failure.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import shutil
import sys

from benchmarks.common import LEDGERS, REPO

BASELINES = os.path.join(REPO, "benchmarks", "baselines")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One field-level mismatch between a baseline and an emitted ledger."""

    path: str  # dotted field path inside the gate, e.g. gate.rows[3].de_j
    base: object | None  # baseline value (None = field is new)
    got: object | None  # emitted value (None = field disappeared)
    rel_err: float | None  # relative error for numeric drift, else None
    note: str = ""  # classification, e.g. "missing from new ledger"

    def lines(self) -> list[str]:
        """Unified-diff hunk for this field."""
        head = f"@@ {self.path}" + (f"  [{self.note}]" if self.note else "")
        out = [head]
        if self.base is not None:
            out.append(f"- {self.base!r}")
        if self.got is not None:
            rel = (
                f"    rel-err {100 * self.rel_err:.2f}%"
                if self.rel_err is not None
                else ""
            )
            out.append(f"+ {self.got!r}{rel}")
        return out


def _diff(base, new, tol: float, path: str, errors: list[Finding]):
    if isinstance(base, dict) and isinstance(new, dict):
        for k in base:
            if k not in new:
                errors.append(
                    Finding(f"{path}.{k}", base[k], None, None,
                            "missing from new ledger")
                )
            else:
                _diff(base[k], new[k], tol, f"{path}.{k}", errors)
        for k in new:
            if k not in base:
                errors.append(
                    Finding(f"{path}.{k}", None, new[k], None,
                            "not in baseline (new field)")
                )
        return
    if isinstance(base, list) and isinstance(new, list):
        if len(base) != len(new):
            errors.append(
                Finding(path, len(base), len(new), None, "length changed")
            )
            return
        for i, (b, n) in enumerate(zip(base, new)):
            _diff(b, n, tol, f"{path}[{i}]", errors)
        return
    if isinstance(base, bool) or isinstance(new, bool):
        if base != new:
            errors.append(Finding(path, base, new, None))
        return
    if isinstance(base, (int, float)) and isinstance(new, (int, float)):
        if math.isclose(base, new, rel_tol=tol, abs_tol=1e-9):
            return
        rel = abs(new - base) / max(abs(base), 1e-300)
        errors.append(Finding(path, base, new, rel, "numeric drift"))
        return
    if base != new:
        errors.append(Finding(path, base, new, None))


def check_one(name: str, tol: float) -> list[Finding]:
    with open(os.path.join(BASELINES, name)) as f:
        base = json.load(f)
    led_path = os.path.join(LEDGERS, name)
    if not os.path.exists(led_path):
        return [
            Finding("gate", None, None, None,
                    "ledger was not emitted (run benchmarks.run --smoke)")
        ]
    with open(led_path) as f:
        new = json.load(f)
    errors: list[Finding] = []
    _diff(base.get("gate", {}), new.get("gate", {}), tol, "gate", errors)
    return errors


def render_diff(name: str, findings: list[Finding], limit: int = 40) -> str:
    """Per-file unified diff: header + one hunk per drifted field."""
    lines = [
        f"--- {os.path.join('benchmarks', 'baselines', name)}",
        f"+++ {os.path.join('runs', 'ledgers', name)}",
    ]
    for f in findings[:limit]:
        lines.extend(f.lines())
    if len(findings) > limit:
        lines.append(f"... and {len(findings) - limit} more field(s)")
    return "\n".join(lines)


def _smoke_ledgers() -> list[str]:
    """CI gates the smoke run only — full-size ledgers stay local."""
    if not os.path.isdir(LEDGERS):
        return []
    return sorted(
        fn for fn in os.listdir(LEDGERS) if fn.endswith("_smoke.json")
    )


def update_baselines() -> int:
    os.makedirs(BASELINES, exist_ok=True)
    n = 0
    for fn in _smoke_ledgers():
        shutil.copyfile(os.path.join(LEDGERS, fn), os.path.join(BASELINES, fn))
        print(f"baseline updated: {fn}")
        n += 1
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative tolerance on gated numbers (default 5%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy the emitted ledgers over the baselines")
    args = ap.parse_args(argv)

    if args.update:
        if update_baselines() == 0:
            print("no ledgers found — run `python -m benchmarks.run --smoke`")
            return 1
        return 0

    if not os.path.isdir(BASELINES):
        print(f"no baselines directory at {BASELINES}")
        return 1
    names = sorted(fn for fn in os.listdir(BASELINES) if fn.endswith(".json"))
    if not names:
        print("no baseline ledgers checked in")
        return 1
    per_file: dict[str, list[Finding]] = {}
    n_failures = 0
    for name in names:
        errs = check_one(name, args.tol)
        status = "OK" if not errs else f"FAIL ({len(errs)} diffs)"
        print(f"[{status:>14s}] {name}")
        if errs:
            per_file[name] = errs
            n_failures += len(errs)
    # every emitted smoke ledger must be gated — a benchmark added without a
    # baseline would otherwise silently run ungated forever
    ungated = [fn for fn in _smoke_ledgers() if fn not in names]
    if per_file or ungated:
        print(f"\n{n_failures} ledger regression(s) beyond "
              f"{100 * args.tol:.0f}% tolerance, "
              f"{len(ungated)} ungated ledger(s):")
        for name, errs in per_file.items():
            print()
            print(render_diff(name, errs))
        for fn in ungated:
            print(f"\n{fn}: emitted but has no baseline — check one in with "
                  "`python -m benchmarks.check_ledgers --update`")
        return 1
    print(f"\nall {len(names)} ledgers within {100 * args.tol:.0f}% of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
