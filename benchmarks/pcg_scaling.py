"""Fig. 11-16 + Table 6 analog: PCG with the AMG preconditioner.

BCMGX-analog (compatible weighted matching, locally-dominant) vs AmgX-analog
(plain strength weights, scan-order greedy).

The DEFAULT path is **executed**: real PCG runs (subprocess, multi host
devices) where the AMG V-cycle built by ``make_amg_preconditioner`` actually
runs inside the solver's shard_map, and the per-region energy ledger
(overlap — the SpMVs with their in-flight halo — / reductions / vcycle) is
integrated from the region trace of the compiled program — no synthetic
cycle profile anywhere on this path. The
emitted JSON ledger's per-region energies sum to the PowerMonitor total by
construction, and CI gates them against checked-in baselines.

``--modeled`` additionally evaluates the paper's 370^3-per-GPU weak-scaling
configuration through the analytic cost model, using a synthetic perfect-8x
hierarchy profile (documented approximation — kept ONLY as an explicitly
requested fallback for paper-scale extrapolation; the default output
reflects executed work).
"""

from __future__ import annotations

from benchmarks.common import (
    SHARD_COUNTS,
    abstract_poisson_mat,
    parse_solver_output,
    run_solver_with_ledger,
    write_ledger,
    write_results,
)
from repro.energy.accounting import CostModel, cg_iteration_counts, vcycle_counts
from repro.energy.monitor import PowerMonitor

SIDE = 370  # paper single-GPU PCG size (7pt)
REGIONS = ("overlap", "reductions", "vcycle")


def executed(side: int = 20, shards: int = 4) -> list[dict]:
    """Real AMG-PCG solves; rows carry the executed per-region energies."""
    rows = []
    ledgers = {}
    for flag, lib in (("--amg", "BCMGX-analog"), ("--amgx-analog", "AmgX-analog")):
        out, led = run_solver_with_ledger(
            ["--problem", "poisson7", "--side", str(side), "--shards", str(shards),
             flag, "--tol", "1e-6", "--maxiter", "100"],
            n_devices=shards,
        )
        r = parse_solver_output(out)[lib]
        sled = led["solvers"][lib]
        regions = sled["regions"]
        per_region = {
            f"de_{name}_j": regions.get(name, {}).get("de_j", 0.0)
            for name in REGIONS
        }
        ledgers[lib] = dict(
            iters=sled["iters"],
            regions=regions,
            totals=sled["totals"],
            amg=led.get("amg"),
        )
        rows.append(dict(figure="fig11-12_exec", library=lib, n_shards=shards,
                         side=side, **r, **per_region))
    write_ledger(
        "pcg_regions",
        gate=dict(side=side, n_shards=shards, solvers=ledgers),
    )
    return rows


def synthetic_amg_info(n: int, k: int = 7, coarse_size: int = 200):
    """--modeled ONLY: perfect 8x coarsening profile (nnz/row -> 27).

    The default benchmark path never touches this — it executes the real
    hierarchy. This profile exists solely to extrapolate the modeled energy
    tables to the paper's 370^3-per-GPU sizes, where building a genuine
    hierarchy on a CPU container is not tractable.
    """
    from repro.core.amg.hierarchy import AMGInfo

    rows, nnz = [], []
    cur, kk = n, k
    while cur > coarse_size:
        rows.append(cur)
        nnz.append(cur * kk)
        cur = max(cur // 8, 1)
        kk = min(int(kk * 1.8), 27)
    rows.append(cur)
    nnz.append(cur * kk)
    return AMGInfo(tuple(rows), tuple(nnz), cur)


def modeled(iters_by_lib: dict, shard_counts=SHARD_COUNTS) -> list[dict]:
    rows = []
    cm = CostModel()
    for mode in ("weak", "strong"):
        for s in shard_counts:
            for lib, variant in (("BCMGX", "hs"), ("AmgX", "amgx")):
                p, mat = abstract_poisson_mat(SIDE, "7pt", s, weak=(mode == "weak"))
                info = synthetic_amg_info(p.n)
                c = cg_iteration_counts(mat, variant) + vcycle_counts(info, mat)
                iters = iters_by_lib.get(lib, 12)
                mon = PowerMonitor(n_devices=s, cost=cm)
                mon.idle(0.05)
                t = mon.region("pcg", c, n_shards=s, overlap=True, repeats=iters)
                mon.idle(0.05)
                e = mon.energy()
                rows.append(
                    dict(
                        figure="fig11-16_tab6",
                        mode=mode,
                        n_shards=s,
                        library=lib,
                        dofs=p.n,
                        iters=iters,
                        solve_time=t,
                        time_per_iter=t / iters,
                        de_per_iter=e["de_total"] / iters,
                        de_per_dof=e["de_total"] / p.n,
                        **e,
                    )
                )
    write_results("pcg_scaling", rows)
    return rows


def run(exec_side: int = 20, exec_shards: int = 4, shard_counts=SHARD_COUNTS,
        with_modeled: bool = False):
    ex = executed(exec_side, exec_shards)
    write_results("pcg_executed", ex)
    mo = []
    if with_modeled:
        iters_by_lib = {
            "BCMGX": next(r["iters"] for r in ex if r["library"] == "BCMGX-analog"),
            "AmgX": next(r["iters"] for r in ex if r["library"] == "AmgX-analog"),
        }
        mo = modeled(iters_by_lib, shard_counts=shard_counts)
    return ex, mo


def main(smoke: bool = False, with_modeled: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    if smoke:
        ex, mo = run(exec_side=10, exec_shards=2, shard_counts=(1, 2),
                     with_modeled=with_modeled)
    else:
        ex, mo = run(with_modeled=with_modeled)
    cols_ex = [
        ("library", "library"), ("n_shards", "#GPUs"), ("iters", "iters"),
        ("setup_s", "setup (s)"), ("solve_s", "solve (s)"),
        ("relres", "relres"), ("de_total", "dyn E (J)"),
    ]
    shards = ex[0]["n_shards"] if ex else 0
    print(fmt_table(ex, cols_ex, f"Fig 11 analog (EXECUTED, CPU, {shards} shards)"))
    cols_regions = [("library", "library")] + [
        (f"de_{name}_j", f"DE {name} (J)") for name in REGIONS
    ]
    print(fmt_table(
        ex, cols_regions,
        "Executed per-region dynamic energy (region trace -> PowerMonitor)",
    ))
    if not mo:
        print("(paper-scale modeled tables: pass --modeled — synthetic "
              "hierarchy profile, executed iteration counts)")
        return
    weak = [r for r in mo if r["mode"] == "weak"]
    cols = [
        ("n_shards", "#GPUs"), ("library", "library"), ("iters", "iters"),
        ("solve_time", "solve (s)"), ("time_per_iter", "s/iter"),
        ("de_per_iter", "dyn E/iter (J)"), ("de_per_dof", "dyn E/DOF"),
        ("gpu_power_peak", "peak (W)"),
    ]
    print(fmt_table(weak, cols, "Fig 11-16 analog: PCG modeled, 370^3/GPU weak"))
    from repro.energy.report import STATIC_DYNAMIC_COLUMNS

    print(fmt_table(weak, STATIC_DYNAMIC_COLUMNS, "Table 6 analog"))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes (CI rot check)")
    ap.add_argument("--modeled", action="store_true",
                    help="ALSO run the synthetic-profile paper-scale model")
    a = ap.parse_args()
    main(smoke=a.smoke, with_modeled=a.modeled)
