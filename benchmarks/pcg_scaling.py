"""Fig. 11-16 + Table 6 analog: PCG with the AMG preconditioner.

BCMGX-analog (compatible weighted matching, locally-dominant) vs AmgX-analog
(plain strength weights, scan-order greedy). Two parts:

* **executed** — real PCG runs (subprocess, 4 host devices) at CPU-tractable
  sizes: true iteration counts, setup/solve split, convergence to 1e-6.
* **modeled**  — per-iteration cost + energy at the paper's 370^3-per-GPU
  weak scaling, 1..64 shards, using a synthetic perfect-8x AMG hierarchy
  profile and the executed iteration counts (documented approximation —
  the paper's iteration counts at 370^3 are likewise in the 20-40 range).
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import (
    SHARD_COUNTS,
    abstract_poisson_mat,
    parse_solver_output,
    run_solver_subprocess,
    write_results,
)
from repro.core.amg.hierarchy import AMGInfo
from repro.energy.accounting import CostModel, cg_iteration_counts, vcycle_counts
from repro.energy.monitor import PowerMonitor

SIDE = 370  # paper single-GPU PCG size (7pt)


def synthetic_amg_info(n: int, k: int = 7, coarse_size: int = 200) -> AMGInfo:
    """Perfect 8x coarsening profile; nnz/row grows toward 27 then stable."""
    rows, nnz = [], []
    cur, kk = n, k
    while cur > coarse_size:
        rows.append(cur)
        nnz.append(cur * kk)
        cur = max(cur // 8, 1)
        kk = min(int(kk * 1.8), 27)
    rows.append(cur)
    nnz.append(cur * kk)
    return AMGInfo(tuple(rows), tuple(nnz), cur)


def executed(side: int = 20, shards: int = 4) -> list[dict]:
    rows = []
    for flag, lib in (("--amg", "BCMGX-analog"), ("--amgx-analog", "AmgX-analog")):
        out = run_solver_subprocess(
            ["--problem", "poisson7", "--side", str(side), "--shards", str(shards),
             flag, "--tol", "1e-6", "--maxiter", "100"],
            n_devices=shards,
        )
        r = parse_solver_output(out)[lib]
        rows.append(dict(figure="fig11-12_exec", library=lib, n_shards=shards,
                         side=side, **r))
    return rows


def modeled(iters_by_lib: dict, shard_counts=SHARD_COUNTS) -> list[dict]:
    rows = []
    cm = CostModel()
    for mode in ("weak", "strong"):
        for s in shard_counts:
            for lib, variant in (("BCMGX", "hs"), ("AmgX", "amgx")):
                p, mat = abstract_poisson_mat(SIDE, "7pt", s, weak=(mode == "weak"))
                info = synthetic_amg_info(p.n)
                c = cg_iteration_counts(mat, variant) + vcycle_counts(info, mat)
                iters = iters_by_lib.get(lib, 12)
                mon = PowerMonitor(n_devices=s, cost=cm)
                mon.idle(0.05)
                t = mon.region("pcg", c, n_shards=s, overlap=True, repeats=iters)
                mon.idle(0.05)
                e = mon.energy()
                rows.append(
                    dict(
                        figure="fig11-16_tab6",
                        mode=mode,
                        n_shards=s,
                        library=lib,
                        dofs=p.n,
                        iters=iters,
                        solve_time=t,
                        time_per_iter=t / iters,
                        de_per_iter=e["de_total"] / iters,
                        de_per_dof=e["de_total"] / p.n,
                        **e,
                    )
                )
    write_results("pcg_scaling", rows)
    return rows


def run(exec_side: int = 20, exec_shards: int = 4, shard_counts=SHARD_COUNTS):
    ex = executed(exec_side, exec_shards)
    iters_by_lib = {
        "BCMGX": next(r["iters"] for r in ex if r["library"] == "BCMGX-analog"),
        "AmgX": next(r["iters"] for r in ex if r["library"] == "AmgX-analog"),
    }
    mo = modeled(iters_by_lib, shard_counts=shard_counts)
    write_results("pcg_executed", ex)
    return ex, mo


def main(smoke: bool = False):
    from benchmarks.common import set_smoke

    set_smoke(smoke)
    from repro.energy.report import fmt_table

    if smoke:
        ex, mo = run(exec_side=10, exec_shards=2, shard_counts=(1, 2))
    else:
        ex, mo = run()
    cols_ex = [
        ("library", "library"), ("n_shards", "#GPUs"), ("iters", "iters"),
        ("setup_s", "setup (s)"), ("solve_s", "solve (s)"),
        ("relres", "relres"), ("de_total", "dyn E (J)"),
    ]
    shards = ex[0]["n_shards"] if ex else 0
    print(fmt_table(ex, cols_ex, f"Fig 11 analog (EXECUTED, CPU, {shards} shards)"))
    weak = [r for r in mo if r["mode"] == "weak"]
    cols = [
        ("n_shards", "#GPUs"), ("library", "library"), ("iters", "iters"),
        ("solve_time", "solve (s)"), ("time_per_iter", "s/iter"),
        ("de_per_iter", "dyn E/iter (J)"), ("de_per_dof", "dyn E/DOF"),
        ("gpu_power_peak", "peak (W)"),
    ]
    print(fmt_table(weak, cols, "Fig 11-16 analog: PCG modeled, 370^3/GPU weak"))
    from repro.energy.report import STATIC_DYNAMIC_COLUMNS

    print(fmt_table(weak, STATIC_DYNAMIC_COLUMNS, "Table 6 analog"))


if __name__ == "__main__":
    main()
